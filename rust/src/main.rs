//! `bulkmi` binary: the Layer-3 coordinator CLI.
//! See `bulkmi help` or `rust/src/cli/mod.rs` for usage.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(bulkmi::cli::run(&argv));
}
