//! Lightweight metrics: counters and wall-time histograms with a text
//! report, used by the coordinator service and the bench harness.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// A monotonically increasing counter.
#[derive(Default, Debug)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Fixed-boundary latency histogram (seconds) with sum/count, so mean
/// and tail buckets are reportable without storing samples.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// sum in nanoseconds for lock-free accumulation
    sum_nanos: AtomicU64,
    max_nanos: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        // 100µs .. 100s, decade-ish boundaries
        Self::new(&[1e-4, 1e-3, 1e-2, 0.1, 0.5, 1.0, 5.0, 30.0, 100.0])
    }
}

impl Histogram {
    pub fn new(bounds: &[f64]) -> Self {
        Histogram {
            bounds: bounds.to_vec(),
            buckets: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_nanos: AtomicU64::new(0),
            max_nanos: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, secs: f64) {
        let idx = self.bounds.iter().position(|&b| secs <= b).unwrap_or(self.bounds.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let nanos = (secs * 1e9) as u64;
        self.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
        self.max_nanos.fetch_max(nanos, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_nanos.load(Ordering::Relaxed) as f64 / 1e9 / c as f64
    }

    pub fn max_secs(&self) -> f64 {
        self.max_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn total_secs(&self) -> f64 {
        self.sum_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }
}

/// A named registry of counters and histograms.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        let mut guard = self.counters.lock().unwrap();
        guard.entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        let mut guard = self.histograms.lock().unwrap();
        guard.entry(name.to_string()).or_default().clone()
    }

    /// Time a closure into the named histogram.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let h = self.histogram(name);
        let t0 = Instant::now();
        let out = f();
        h.observe(t0.elapsed().as_secs_f64());
        out
    }

    /// Text report, sorted by name.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {name} = {}\n", c.get()));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "timer   {name}: count={} mean={} max={} total={}\n",
                h.count(),
                crate::util::timer::fmt_secs(h.mean_secs()),
                crate::util::timer::fmt_secs(h.max_secs()),
                crate::util::timer::fmt_secs(h.total_secs()),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_stats() {
        let h = Histogram::default();
        h.observe(0.2);
        h.observe(0.4);
        assert_eq!(h.count(), 2);
        assert!((h.mean_secs() - 0.3).abs() < 1e-6);
        assert!((h.max_secs() - 0.4).abs() < 1e-6);
        assert!((h.total_secs() - 0.6).abs() < 1e-6);
    }

    #[test]
    fn registry_reuses_instruments() {
        let m = Metrics::new();
        m.counter("jobs").inc();
        m.counter("jobs").inc();
        assert_eq!(m.counter("jobs").get(), 2);
        let out = m.time("work", || 7);
        assert_eq!(out, 7);
        assert_eq!(m.histogram("work").count(), 1);
        let report = m.report();
        assert!(report.contains("counter jobs = 2"));
        assert!(report.contains("timer   work"));
    }

    #[test]
    fn concurrent_counting() {
        let m = std::sync::Arc::new(Metrics::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = std::sync::Arc::clone(&m);
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.counter("n").inc();
                    }
                });
            }
        });
        assert_eq!(m.counter("n").get(), 4000);
    }
}
