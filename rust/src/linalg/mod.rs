//! Linear-algebra substrates for the one operation that dominates the
//! paper's algorithm: the Gram matrix `G11 = D^T D` of a binary matrix.
//!
//! Four strategies, mirroring the paper's implementation comparison:
//!
//! * [`dense`] + [`blas`] — dense f32 row-major matrix with a
//!   cache-blocked `syrk`-style Gram (the NumPy/Numba analog).
//! * [`bitmat`] — bit-packed columns, Gram via `AND` + `popcount`
//!   (64 elements per word; the "hardware-optimized framework" analog).
//!   Its popcount primitive dispatches through [`kernels`], which picks
//!   the fastest hardware-adaptive kernel (scalar / Harley–Seal CSA /
//!   AVX2) once per process.
//! * [`csr`] — compressed sparse rows, Gram via row-pair expansion
//!   (the SciPy-sparse analog; cost ∝ Σ nnz(row)²).
//! * the XLA/PJRT path lives in [`crate::runtime`] and [`crate::mi::xla`].

pub mod bitmat;
pub mod blas;
pub mod csr;
pub mod dense;
pub mod kernels;
