//! Compressed-sparse-row binary matrix (values are implicitly 1).
//!
//! The SciPy-sparse analog of the paper's Opt-SS row. The Gram is
//! computed by *row-pair expansion*: for every row, every ordered pair
//! of its nonzero columns increments one Gram cell, so total work is
//! `Σ_r nnz(r)²` — quadratic in density, which is exactly the cost
//! profile that makes the sparse implementation lose at 90% sparsity
//! and win at ≥99% (paper Fig. 3).

use super::dense::Mat64;
use crate::util::error::{Error, Result};

/// CSR binary matrix.
#[derive(Clone, Debug)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    /// `indptr[r]..indptr[r+1]` indexes `indices` for row r.
    indptr: Vec<usize>,
    /// Column indices of nonzeros, sorted within each row.
    indices: Vec<u32>,
}

impl CsrMatrix {
    /// Build from row-major binary bytes.
    pub fn from_row_major(rows: usize, cols: usize, bytes: &[u8]) -> Result<Self> {
        if bytes.len() != rows * cols {
            return Err(Error::Shape(format!(
                "buffer length {} != {rows}x{cols}",
                bytes.len()
            )));
        }
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        indptr.push(0);
        for r in 0..rows {
            let row = &bytes[r * cols..(r + 1) * cols];
            for (c, &v) in row.iter().enumerate() {
                if v != 0 {
                    indices.push(c as u32);
                }
            }
            indptr.push(indices.len());
        }
        Ok(CsrMatrix { rows, cols, indptr, indices })
    }

    /// Build from a bit-packed column-major matrix without unpacking to
    /// bytes: a word-skipping counting sort — one pass counts per-row
    /// nonzeros, a second drops each one into its row's slot (columns
    /// visited in ascending order, so rows come out sorted). Work is
    /// `O(words + nnz)` rather than the `O(rows × cols)` byte scan of
    /// [`Self::from_row_major`], which keeps the sparse substrate's
    /// per-block construction proportional to the ones it stores — the
    /// regime where the sparse backend wins in the first place.
    pub fn from_bitmatrix(bits: &super::bitmat::BitMatrix) -> Self {
        let (rows, cols) = (bits.rows(), bits.cols());
        let mut row_nnz = vec![0usize; rows];
        for c in 0..cols {
            for (w, &word) in bits.col(c).iter().enumerate() {
                let mut word = word;
                while word != 0 {
                    row_nnz[w * 64 + word.trailing_zeros() as usize] += 1;
                    word &= word - 1;
                }
            }
        }
        let mut indptr = Vec::with_capacity(rows + 1);
        indptr.push(0);
        let mut total = 0usize;
        for &k in &row_nnz {
            total += k;
            indptr.push(total);
        }
        let mut cursor = indptr.clone(); // next free slot per row
        let mut indices = vec![0u32; total];
        for c in 0..cols {
            for (w, &word) in bits.col(c).iter().enumerate() {
                let mut word = word;
                while word != 0 {
                    let r = w * 64 + word.trailing_zeros() as usize;
                    indices[cursor[r]] = c as u32;
                    cursor[r] += 1;
                    word &= word - 1;
                }
            }
        }
        CsrMatrix { rows, cols, indptr, indices }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Total number of stored ones.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Fraction of zero cells.
    pub fn sparsity(&self) -> f64 {
        if self.rows * self.cols == 0 {
            return 0.0;
        }
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Nonzero column indices of one row.
    #[inline]
    pub fn row_indices(&self, r: usize) -> &[u32] {
        &self.indices[self.indptr[r]..self.indptr[r + 1]]
    }

    /// Count of ones per column.
    pub fn col_counts(&self) -> Vec<u64> {
        let mut counts = vec![0u64; self.cols];
        for &c in &self.indices {
            counts[c as usize] += 1;
        }
        counts
    }

    /// Symmetric Gram `D^T D` via row-pair expansion (upper triangle,
    /// mirrored). Output is dense m x m — the Gram of sparse data is
    /// generally dense, as the paper notes for ¬D.
    pub fn gram(&self) -> Mat64 {
        let m = self.cols;
        let mut acc = vec![0u32; m * m];
        for r in 0..self.rows {
            let nz = self.row_indices(r);
            for (a, &i) in nz.iter().enumerate() {
                let base = i as usize * m;
                for &j in &nz[a..] {
                    acc[base + j as usize] += 1;
                }
            }
        }
        let mut out = Mat64::zeros(m, m);
        for i in 0..m {
            for j in i..m {
                let v = acc[i * m + j] as f64;
                out.set(i, j, v);
                out.set(j, i, v);
            }
        }
        out
    }

    /// Cross Gram `A^T B` for two CSR matrices over the same rows.
    pub fn gram_cross(&self, other: &CsrMatrix) -> Result<Mat64> {
        if self.rows != other.rows {
            return Err(Error::Shape(format!(
                "gram_cross: row mismatch {} vs {}",
                self.rows, other.rows
            )));
        }
        let (ma, mb) = (self.cols, other.cols);
        let mut acc = vec![0u32; ma * mb];
        for r in 0..self.rows {
            let nza = self.row_indices(r);
            let nzb = other.row_indices(r);
            for &i in nza {
                let base = i as usize * mb;
                for &j in nzb {
                    acc[base + j as usize] += 1;
                }
            }
        }
        let mut out = Mat64::zeros(ma, mb);
        for i in 0..ma {
            for j in 0..mb {
                out.set(i, j, acc[i * mb + j] as f64);
            }
        }
        Ok(out)
    }

    /// Extract a contiguous column block as its own CsrMatrix.
    pub fn col_block(&self, start: usize, len: usize) -> Result<CsrMatrix> {
        if start + len > self.cols {
            return Err(Error::Shape(format!(
                "col_block [{start}, {}) out of {} cols",
                start + len,
                self.cols
            )));
        }
        let (lo, hi) = (start as u32, (start + len) as u32);
        let mut indptr = Vec::with_capacity(self.rows + 1);
        let mut indices = Vec::new();
        indptr.push(0);
        for r in 0..self.rows {
            for &c in self.row_indices(r) {
                if c >= lo && c < hi {
                    indices.push(c - lo);
                }
            }
            indptr.push(indices.len());
        }
        Ok(CsrMatrix { rows: self.rows, cols: len, indptr, indices })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::linalg::dense::Mat32;
    use crate::util::rng::Rng;

    fn random_bytes(rng: &mut Rng, n: usize, m: usize, density: f64) -> Vec<u8> {
        (0..n * m).map(|_| if rng.bernoulli(density) { 1 } else { 0 }).collect()
    }

    #[test]
    fn construction_and_nnz() {
        let bytes = vec![1, 0, 0, 1, 1, 0];
        let c = CsrMatrix::from_row_major(2, 3, &bytes).unwrap();
        assert_eq!(c.nnz(), 3);
        assert_eq!(c.row_indices(0), &[0]);
        assert_eq!(c.row_indices(1), &[0, 1]);
        assert!((c.sparsity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_length() {
        assert!(CsrMatrix::from_row_major(2, 3, &[0u8; 5]).is_err());
    }

    #[test]
    fn from_bitmatrix_matches_from_row_major() {
        use crate::linalg::bitmat::BitMatrix;
        let mut rng = Rng::new(21);
        for &(n, m, d) in &[
            (1usize, 1usize, 1.0f64),
            (63, 5, 0.3),
            (64, 4, 0.0),
            (65, 7, 0.9),
            (200, 13, 0.05),
        ] {
            let bytes = random_bytes(&mut rng, n, m, d);
            let want = CsrMatrix::from_row_major(n, m, &bytes).unwrap();
            let bits = BitMatrix::from_row_major(n, m, &bytes).unwrap();
            let got = CsrMatrix::from_bitmatrix(&bits);
            assert_eq!((got.rows(), got.cols()), (n, m), "n={n} m={m} d={d}");
            assert_eq!(got.nnz(), want.nnz(), "n={n} m={m} d={d}");
            for r in 0..n {
                assert_eq!(got.row_indices(r), want.row_indices(r), "row {r}");
            }
        }
    }

    #[test]
    fn col_counts_match() {
        let mut rng = Rng::new(1);
        let (n, m) = (80, 11);
        let bytes = random_bytes(&mut rng, n, m, 0.2);
        let c = CsrMatrix::from_row_major(n, m, &bytes).unwrap();
        let counts = c.col_counts();
        for j in 0..m {
            let want: u64 = (0..n).map(|r| bytes[r * m + j] as u64).sum();
            assert_eq!(counts[j], want);
        }
    }

    #[test]
    fn gram_matches_dense() {
        let mut rng = Rng::new(2);
        for &(n, m, d) in &[(60usize, 9usize, 0.1f64), (128, 16, 0.5), (40, 5, 0.95)] {
            let bytes = random_bytes(&mut rng, n, m, d);
            let sparse = CsrMatrix::from_row_major(n, m, &bytes).unwrap();
            let dense =
                Mat32::from_vec(n, m, bytes.iter().map(|&b| b as f32).collect()).unwrap();
            let want = blas::gram(&dense);
            assert_eq!(sparse.gram().max_abs_diff(&want), 0.0, "n={n} m={m} d={d}");
        }
    }

    #[test]
    fn gram_cross_matches_dense() {
        let mut rng = Rng::new(3);
        let n = 100;
        let ba = random_bytes(&mut rng, n, 7, 0.15);
        let bb = random_bytes(&mut rng, n, 5, 0.3);
        let ca = CsrMatrix::from_row_major(n, 7, &ba).unwrap();
        let cb = CsrMatrix::from_row_major(n, 5, &bb).unwrap();
        let da = Mat32::from_vec(n, 7, ba.iter().map(|&b| b as f32).collect()).unwrap();
        let db = Mat32::from_vec(n, 5, bb.iter().map(|&b| b as f32).collect()).unwrap();
        let want = blas::gemm_at_b(&da, &db).unwrap();
        assert_eq!(ca.gram_cross(&cb).unwrap().max_abs_diff(&want), 0.0);
    }

    #[test]
    fn col_block_extracts() {
        let mut rng = Rng::new(4);
        let (n, m) = (50, 12);
        let bytes = random_bytes(&mut rng, n, m, 0.25);
        let c = CsrMatrix::from_row_major(n, m, &bytes).unwrap();
        let blk = c.col_block(4, 5).unwrap();
        assert_eq!(blk.cols(), 5);
        let full = c.gram();
        let sub = blk.gram();
        for i in 0..5 {
            for j in 0..5 {
                assert_eq!(sub.get(i, j), full.get(i + 4, j + 4));
            }
        }
    }

    #[test]
    fn empty_matrix_gram_is_zero() {
        let c = CsrMatrix::from_row_major(5, 3, &[0u8; 15]).unwrap();
        assert_eq!(c.nnz(), 0);
        let g = c.gram();
        assert!(g.data().iter().all(|&v| v == 0.0));
    }
}
