//! Bit-packed binary matrix: each column stored as ⌈n/64⌉ u64 words, so
//! the Gram inner product becomes `popcount(a & b)` over words — 64
//! elements per instruction. This is the crate's "hardware-optimized
//! framework" analog of the paper's PyTorch row (Opt-T): same algorithm,
//! substrate tuned to the machine.
//!
//! The popcount primitive itself is pluggable: `gram`/`gram_cross`
//! dispatch through [`crate::linalg::kernels`], which picks the fastest
//! AND-popcount kernel for this CPU (scalar unroll, Harley–Seal CSA,
//! AVX2 nibble-lookup, AVX-512 `VPOPCNTQ`, or NEON `vcntq_u8`) once per
//! process. Every kernel is bit-identical, so the choice never changes
//! a result.

use super::dense::{Mat32, Mat64};
use super::kernels::{self, Kernel};
use crate::util::error::{Error, Result};

/// Column-major packed bit matrix.
#[derive(Clone, Debug)]
pub struct BitMatrix {
    rows: usize,
    cols: usize,
    words_per_col: usize,
    /// Column-major: column `c` occupies
    /// `data[c * words_per_col .. (c+1) * words_per_col]`.
    data: Vec<u64>,
}

impl BitMatrix {
    /// Pack row-major binary bytes (values 0/1) of shape n x m.
    pub fn from_row_major(rows: usize, cols: usize, bytes: &[u8]) -> Result<Self> {
        if bytes.len() != rows * cols {
            return Err(Error::Shape(format!(
                "buffer length {} != {rows}x{cols}",
                bytes.len()
            )));
        }
        let words_per_col = rows.div_ceil(64);
        let mut data = vec![0u64; words_per_col * cols];
        for r in 0..rows {
            let word = r / 64;
            let bit = r % 64;
            let row = &bytes[r * cols..(r + 1) * cols];
            for (c, &v) in row.iter().enumerate() {
                debug_assert!(v <= 1, "binary data expected");
                if v != 0 {
                    data[c * words_per_col + word] |= 1u64 << bit;
                }
            }
        }
        Ok(BitMatrix { rows, cols, words_per_col, data })
    }

    /// Construct directly from column-major packed words — `cols`
    /// columns of `rows.div_ceil(64)` words each, bit `r % 64` of word
    /// `r / 64` holding row `r`. This is the `.bmat` v2 on-disk payload
    /// layout, so a [`crate::data::colstore::ColumnSource`] block read
    /// becomes a straight copy with **no unpack/repack round trip**.
    /// Bits at row positions `>= rows` in each column's last word are
    /// masked off so the popcount invariants hold even for payloads
    /// written by other tools.
    pub fn from_packed_cols(rows: usize, cols: usize, mut data: Vec<u64>) -> Result<Self> {
        let words_per_col = rows.div_ceil(64);
        let want = words_per_col
            .checked_mul(cols)
            .ok_or_else(|| Error::Shape(format!("packed shape {rows}x{cols} overflows")))?;
        if data.len() != want {
            return Err(Error::Shape(format!(
                "packed buffer has {} words, {rows}x{cols} needs {want}",
                data.len()
            )));
        }
        let tail_bits = rows % 64;
        if tail_bits != 0 {
            let mask = (1u64 << tail_bits) - 1;
            for c in 0..cols {
                data[(c + 1) * words_per_col - 1] &= mask;
            }
        }
        Ok(BitMatrix { rows, cols, words_per_col, data })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Packed words per column (`rows.div_ceil(64)`).
    pub fn words_per_col(&self) -> usize {
        self.words_per_col
    }

    /// All packed words, column-major ([`Self::words_per_col`] words
    /// per column) — the `.bmat` v2 payload layout, verbatim.
    pub fn words(&self) -> &[u64] {
        &self.data
    }

    /// Unpack to row-major 0/1 bytes (the `BinaryDataset` cell layout).
    pub fn to_row_major_bytes(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.rows * self.cols];
        for c in 0..self.cols {
            let col = self.col(c);
            for r in 0..self.rows {
                if col[r / 64] >> (r % 64) & 1 == 1 {
                    out[r * self.cols + c] = 1;
                }
            }
        }
        out
    }

    /// Unpack to a row-major dense f32 matrix (the BLAS substrate's
    /// input layout; exact — every count fits f32).
    pub fn to_mat32(&self) -> Mat32 {
        let mut out = Mat32::zeros(self.rows, self.cols);
        let data = out.data_mut();
        for c in 0..self.cols {
            let col = self.col(c);
            for r in 0..self.rows {
                if col[r / 64] >> (r % 64) & 1 == 1 {
                    data[r * self.cols + c] = 1.0;
                }
            }
        }
        out
    }

    /// Packed words of one column.
    #[inline]
    pub fn col(&self, c: usize) -> &[u64] {
        &self.data[c * self.words_per_col..(c + 1) * self.words_per_col]
    }

    /// Read a single bit.
    pub fn get(&self, r: usize, c: usize) -> bool {
        self.col(c)[r / 64] >> (r % 64) & 1 == 1
    }

    /// Count of ones per column.
    pub fn col_counts(&self) -> Vec<u64> {
        (0..self.cols)
            .map(|c| self.col(c).iter().map(|w| w.count_ones() as u64).sum())
            .collect()
    }

    /// Co-occurrence count of ones between two of *this* matrix's columns.
    #[inline]
    pub fn and_count(&self, i: usize, j: usize) -> u64 {
        kernels::active().dot(self.col(i), self.col(j))
    }

    /// Symmetric Gram `D^T D` via AND+popcount (upper triangle
    /// mirrored), on the process-wide fastest kernel.
    ///
    /// The inner loop is 4-wide across *output columns*: each word of
    /// column `i` is loaded once and ANDed against four `j` columns with
    /// four independent accumulator chains in flight — about 1.5-2x
    /// over the one-output-at-a-time reference
    /// ([`Self::gram_reference`], kept for the ablation bench).
    ///
    /// ```
    /// use bulkmi::linalg::bitmat::BitMatrix;
    ///
    /// // 3 rows x 2 cols, row-major 0/1 bytes: the columns have two
    /// // ones each and co-occur in exactly one row.
    /// let bm = BitMatrix::from_row_major(3, 2, &[1, 1, 1, 0, 0, 1]).unwrap();
    /// let g = bm.gram();
    /// assert_eq!(g.get(0, 0), 2.0); // ones in column 0
    /// assert_eq!(g.get(1, 1), 2.0); // ones in column 1
    /// assert_eq!(g.get(0, 1), 1.0); // co-occurrences
    /// ```
    pub fn gram(&self) -> Mat64 {
        self.gram_with(kernels::active())
    }

    /// [`Self::gram`] on an explicit kernel (bench / equivalence tests).
    pub fn gram_with(&self, kernel: &Kernel) -> Mat64 {
        let m = self.cols;
        let mut out = Mat64::zeros(m, m);
        for i in 0..m {
            let ci = self.col(i);
            let mut j = i;
            while j + 4 <= m {
                let v = kernel.dot_x4(
                    ci,
                    self.col(j),
                    self.col(j + 1),
                    self.col(j + 2),
                    self.col(j + 3),
                );
                for (off, &count) in v.iter().enumerate() {
                    out.set(i, j + off, count as f64);
                    out.set(j + off, i, count as f64);
                }
                j += 4;
            }
            while j < m {
                let v = kernel.dot(ci, self.col(j)) as f64;
                out.set(i, j, v);
                out.set(j, i, v);
                j += 1;
            }
        }
        out
    }

    /// Pre-unroll reference Gram (one output cell at a time, scalar
    /// kernel). Kept so `benches/ablation_gram.rs` can report the
    /// before/after of the 4-wide accumulator unroll and so the kernel
    /// equivalence tests have a fixed baseline; not used on any compute
    /// path.
    pub fn gram_reference(&self) -> Mat64 {
        let kernel = kernels::reference();
        let m = self.cols;
        let mut out = Mat64::zeros(m, m);
        for i in 0..m {
            let ci = self.col(i);
            for j in i..m {
                let v = kernel.dot(ci, self.col(j)) as f64;
                out.set(i, j, v);
                out.set(j, i, v);
            }
        }
        out
    }

    /// Cross Gram `A^T B` against another bit matrix with the same rows
    /// (same 4-wide output-column unroll as [`Self::gram`]).
    pub fn gram_cross(&self, other: &BitMatrix) -> Result<Mat64> {
        self.gram_cross_with(other, kernels::active())
    }

    /// [`Self::gram_cross`] on an explicit kernel.
    pub fn gram_cross_with(&self, other: &BitMatrix, kernel: &Kernel) -> Result<Mat64> {
        if self.rows != other.rows {
            return Err(Error::Shape(format!(
                "gram_cross: row mismatch {} vs {}",
                self.rows, other.rows
            )));
        }
        let (ma, mb) = (self.cols, other.cols);
        let mut out = Mat64::zeros(ma, mb);
        for i in 0..ma {
            let ci = self.col(i);
            let mut j = 0;
            while j + 4 <= mb {
                let v = kernel.dot_x4(
                    ci,
                    other.col(j),
                    other.col(j + 1),
                    other.col(j + 2),
                    other.col(j + 3),
                );
                for (off, &count) in v.iter().enumerate() {
                    out.set(i, j + off, count as f64);
                }
                j += 4;
            }
            while j < mb {
                out.set(i, j, kernel.dot(ci, other.col(j)) as f64);
                j += 1;
            }
        }
        Ok(out)
    }

    /// Extract a contiguous column block as its own BitMatrix (cheap:
    /// column-major layout makes this a memcpy).
    pub fn col_block(&self, start: usize, len: usize) -> Result<BitMatrix> {
        if start + len > self.cols {
            return Err(Error::Shape(format!(
                "col_block [{start}, {}) out of {} cols",
                start + len,
                self.cols
            )));
        }
        let data =
            self.data[start * self.words_per_col..(start + len) * self.words_per_col].to_vec();
        Ok(BitMatrix { rows: self.rows, cols: len, words_per_col: self.words_per_col, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::linalg::dense::Mat32;
    use crate::util::rng::Rng;

    fn random_bytes(rng: &mut Rng, n: usize, m: usize, density: f64) -> Vec<u8> {
        (0..n * m).map(|_| if rng.bernoulli(density) { 1 } else { 0 }).collect()
    }

    #[test]
    fn pack_round_trip() {
        let mut rng = Rng::new(1);
        let (n, m) = (131, 9); // non-multiple of 64 rows
        let bytes = random_bytes(&mut rng, n, m, 0.5);
        let bm = BitMatrix::from_row_major(n, m, &bytes).unwrap();
        for r in 0..n {
            for c in 0..m {
                assert_eq!(bm.get(r, c), bytes[r * m + c] == 1, "({r},{c})");
            }
        }
    }

    #[test]
    fn rejects_bad_length() {
        assert!(BitMatrix::from_row_major(4, 4, &[0u8; 15]).is_err());
    }

    #[test]
    fn col_counts_match() {
        let mut rng = Rng::new(2);
        let (n, m) = (200, 12);
        let bytes = random_bytes(&mut rng, n, m, 0.3);
        let bm = BitMatrix::from_row_major(n, m, &bytes).unwrap();
        let counts = bm.col_counts();
        for c in 0..m {
            let want: u64 = (0..n).map(|r| bytes[r * m + c] as u64).sum();
            assert_eq!(counts[c], want);
        }
    }

    #[test]
    fn gram_matches_dense_gram() {
        let mut rng = Rng::new(3);
        for &(n, m, d) in &[(64usize, 8usize, 0.5f64), (129, 17, 0.1), (300, 31, 0.9)] {
            let bytes = random_bytes(&mut rng, n, m, d);
            let bm = BitMatrix::from_row_major(n, m, &bytes).unwrap();
            let dense =
                Mat32::from_vec(n, m, bytes.iter().map(|&b| b as f32).collect()).unwrap();
            let want = blas::gram(&dense);
            assert_eq!(bm.gram().max_abs_diff(&want), 0.0, "n={n} m={m} d={d}");
        }
    }

    #[test]
    fn unrolled_gram_matches_reference() {
        // cover every remainder of the 4-wide unroll (m mod 4 = 0..3)
        let mut rng = Rng::new(7);
        for m in [4usize, 5, 6, 7, 8, 13] {
            let n = 130;
            let bytes = random_bytes(&mut rng, n, m, 0.4);
            let bm = BitMatrix::from_row_major(n, m, &bytes).unwrap();
            assert_eq!(bm.gram().max_abs_diff(&bm.gram_reference()), 0.0, "m={m}");
        }
    }

    #[test]
    fn gram_with_every_kernel_matches_reference() {
        let mut rng = Rng::new(9);
        for &(n, m) in &[(65usize, 6usize), (130, 9), (257, 13)] {
            let bytes = random_bytes(&mut rng, n, m, 0.35);
            let bm = BitMatrix::from_row_major(n, m, &bytes).unwrap();
            let want = bm.gram_reference();
            for k in kernels::available() {
                assert_eq!(bm.gram_with(k).max_abs_diff(&want), 0.0, "{}", k.name());
            }
        }
    }

    #[test]
    fn gram_cross_matches_dense() {
        let mut rng = Rng::new(4);
        let n = 150;
        let ba = random_bytes(&mut rng, n, 6, 0.4);
        let bb = random_bytes(&mut rng, n, 9, 0.7);
        let bma = BitMatrix::from_row_major(n, 6, &ba).unwrap();
        let bmb = BitMatrix::from_row_major(n, 9, &bb).unwrap();
        let da = Mat32::from_vec(n, 6, ba.iter().map(|&b| b as f32).collect()).unwrap();
        let db = Mat32::from_vec(n, 9, bb.iter().map(|&b| b as f32).collect()).unwrap();
        let want = blas::gemm_at_b(&da, &db).unwrap();
        assert_eq!(bma.gram_cross(&bmb).unwrap().max_abs_diff(&want), 0.0);
    }

    #[test]
    fn gram_cross_row_mismatch_errors() {
        let a = BitMatrix::from_row_major(3, 2, &[0u8; 6]).unwrap();
        let b = BitMatrix::from_row_major(4, 2, &[0u8; 8]).unwrap();
        assert!(a.gram_cross(&b).is_err());
    }

    #[test]
    fn col_block_extracts() {
        let mut rng = Rng::new(5);
        let (n, m) = (70, 10);
        let bytes = random_bytes(&mut rng, n, m, 0.5);
        let bm = BitMatrix::from_row_major(n, m, &bytes).unwrap();
        let blk = bm.col_block(3, 4).unwrap();
        assert_eq!(blk.cols(), 4);
        for r in 0..n {
            for c in 0..4 {
                assert_eq!(blk.get(r, c), bm.get(r, c + 3));
            }
        }
        assert!(bm.col_block(8, 4).is_err());
    }

    #[test]
    fn packed_cols_round_trip() {
        let mut rng = Rng::new(11);
        for &(n, m) in &[(1usize, 1usize), (63, 3), (64, 4), (65, 5), (200, 9)] {
            let bytes = random_bytes(&mut rng, n, m, 0.4);
            let bm = BitMatrix::from_row_major(n, m, &bytes).unwrap();
            let back =
                BitMatrix::from_packed_cols(n, m, bm.words().to_vec()).unwrap();
            assert_eq!(back.words(), bm.words(), "n={n} m={m}");
            assert_eq!(back.to_row_major_bytes(), bytes, "n={n} m={m}");
        }
    }

    #[test]
    fn packed_cols_masks_tail_bits_and_validates_length() {
        // 65 rows -> 2 words per column; poison the tail word's high bits
        let mut words = vec![0u64; 2];
        words[1] = !0u64; // row 64 set, rows 65..127 are garbage
        let bm = BitMatrix::from_packed_cols(65, 1, words).unwrap();
        assert_eq!(bm.col_counts(), vec![1], "garbage past row 65 masked off");
        assert!(bm.get(64, 0));
        // wrong word count rejected
        assert!(BitMatrix::from_packed_cols(65, 1, vec![0u64; 3]).is_err());
        assert!(BitMatrix::from_packed_cols(64, 2, vec![0u64; 1]).is_err());
    }

    #[test]
    fn to_mat32_matches_cells() {
        let mut rng = Rng::new(12);
        let (n, m) = (130, 7);
        let bytes = random_bytes(&mut rng, n, m, 0.5);
        let bm = BitMatrix::from_row_major(n, m, &bytes).unwrap();
        let dense = bm.to_mat32();
        for r in 0..n {
            for c in 0..m {
                assert_eq!(dense.get(r, c), bytes[r * m + c] as f32);
            }
        }
    }

    #[test]
    fn and_count_is_intersection() {
        let bytes = vec![
            1, 1, //
            1, 0, //
            0, 1, //
            1, 1, //
        ];
        let bm = BitMatrix::from_row_major(4, 2, &bytes).unwrap();
        assert_eq!(bm.and_count(0, 1), 2);
        assert_eq!(bm.and_count(0, 0), 3);
    }
}
