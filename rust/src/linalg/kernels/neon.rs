//! NEON AND-popcount kernel (aarch64 only).
//!
//! AArch64 has no scalar popcount instruction — `u64::count_ones`
//! lowers to a NEON `cnt` + `addv` round-trip per word — so the win
//! here is batching: `vcntq_u8` popcounts 16 bytes of the ANDed
//! 128-bit vector at once, per-byte counts accumulate with plain
//! `vaddq_u8` for up to 31 vectors (each lane is <= 8, and
//! 31 x 8 = 248 < 256, so a `u8` lane cannot overflow), and each full
//! batch folds once into a 64-bit accumulator through the widening
//! horizontal pairwise adds `vpaddlq_u8` -> `vpaddlq_u16` ->
//! `vpadalq_u32`. One fold per 62 words keeps the inner loop at two
//! loads, an AND, a `cnt`, and a byte add.
//!
//! NEON (ASIMD) is a baseline feature of every aarch64 target, so this
//! kernel is eligible on all Apple Silicon / Graviton / ARM CI hosts;
//! the dispatch table still micro-probes it against `scalar` and
//! `portable` and commits to whichever is fastest on the machine.

use core::arch::aarch64::*;

/// 128-bit vectors per byte-accumulator batch before a `u8` lane could
/// overflow (each `vcntq_u8` lane is <= 8; 31 * 8 = 248 < 256).
const BATCH: usize = 31;

/// Safe wrapper. NEON is a mandatory aarch64 feature and the dispatch
/// table additionally confirms it with
/// `is_aarch64_feature_detected!("neon")` before listing this kernel,
/// so the `target_feature` call is sound on every path that reaches it.
pub(crate) fn dot(a: &[u64], b: &[u64]) -> u64 {
    debug_assert!(std::arch::is_aarch64_feature_detected!("neon"));
    unsafe { dot_impl(a, b) }
}

/// Safe wrapper; same soundness argument as [`dot`].
pub(crate) fn dot_x4(a: &[u64], b0: &[u64], b1: &[u64], b2: &[u64], b3: &[u64]) -> [u64; 4] {
    debug_assert!(std::arch::is_aarch64_feature_detected!("neon"));
    unsafe { dot_x4_impl(a, b0, b1, b2, b3) }
}

/// Fold a batch of per-byte counts into the running u64x2 accumulator:
/// u8x16 -> u16x8 -> u32x4 pairwise widenings, then accumulate-long.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn fold(acc: uint64x2_t, bytes: uint8x16_t) -> uint64x2_t {
    vpadalq_u32(acc, vpaddlq_u16(vpaddlq_u8(bytes)))
}

#[target_feature(enable = "neon")]
unsafe fn dot_impl(a: &[u64], b: &[u64]) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let vecs = n / 2; // two u64 words per 128-bit vector
    let mut acc = vdupq_n_u64(0);
    let mut k = 0usize;
    while k < vecs {
        let batch_end = (k + BATCH).min(vecs);
        let mut bytes = vdupq_n_u8(0);
        while k < batch_end {
            let va = vld1q_u64(a.as_ptr().add(k * 2));
            let vb = vld1q_u64(b.as_ptr().add(k * 2));
            let and = vreinterpretq_u8_u64(vandq_u64(va, vb));
            bytes = vaddq_u8(bytes, vcntq_u8(and));
            k += 1;
        }
        acc = fold(acc, bytes);
    }
    let mut total = vaddvq_u64(acc);
    for i in vecs * 2..n {
        total += (a[i] & b[i]).count_ones() as u64;
    }
    total
}

#[target_feature(enable = "neon")]
unsafe fn dot_x4_impl(
    a: &[u64],
    b0: &[u64],
    b1: &[u64],
    b2: &[u64],
    b3: &[u64],
) -> [u64; 4] {
    debug_assert!(
        a.len() == b0.len() && a.len() == b1.len() && a.len() == b2.len() && a.len() == b3.len()
    );
    let n = a.len();
    let vecs = n / 2;
    let mut acc0 = vdupq_n_u64(0);
    let mut acc1 = vdupq_n_u64(0);
    let mut acc2 = vdupq_n_u64(0);
    let mut acc3 = vdupq_n_u64(0);
    let mut k = 0usize;
    while k < vecs {
        let batch_end = (k + BATCH).min(vecs);
        let mut by0 = vdupq_n_u8(0);
        let mut by1 = vdupq_n_u8(0);
        let mut by2 = vdupq_n_u8(0);
        let mut by3 = vdupq_n_u8(0);
        while k < batch_end {
            // `a` is loaded once and ANDed against four columns — the
            // same reuse pattern as the scalar 4-wide unroll
            let va = vld1q_u64(a.as_ptr().add(k * 2));
            let v0 = vandq_u64(va, vld1q_u64(b0.as_ptr().add(k * 2)));
            let v1 = vandq_u64(va, vld1q_u64(b1.as_ptr().add(k * 2)));
            let v2 = vandq_u64(va, vld1q_u64(b2.as_ptr().add(k * 2)));
            let v3 = vandq_u64(va, vld1q_u64(b3.as_ptr().add(k * 2)));
            by0 = vaddq_u8(by0, vcntq_u8(vreinterpretq_u8_u64(v0)));
            by1 = vaddq_u8(by1, vcntq_u8(vreinterpretq_u8_u64(v1)));
            by2 = vaddq_u8(by2, vcntq_u8(vreinterpretq_u8_u64(v2)));
            by3 = vaddq_u8(by3, vcntq_u8(vreinterpretq_u8_u64(v3)));
            k += 1;
        }
        acc0 = fold(acc0, by0);
        acc1 = fold(acc1, by1);
        acc2 = fold(acc2, by2);
        acc3 = fold(acc3, by3);
    }
    let mut out = [
        vaddvq_u64(acc0),
        vaddvq_u64(acc1),
        vaddvq_u64(acc2),
        vaddvq_u64(acc3),
    ];
    for i in vecs * 2..n {
        let w = a[i];
        out[0] += (w & b0[i]).count_ones() as u64;
        out[1] += (w & b1[i]).count_ones() as u64;
        out[2] += (w & b2[i]).count_ones() as u64;
        out[3] += (w & b3[i]).count_ones() as u64;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::kernels::scalar;
    use crate::util::rng::Rng;

    #[test]
    fn matches_scalar_on_every_tail_length() {
        let mut rng = Rng::new(0xE0);
        // cover 0..1 %2 remainders, batch boundaries (62 words = one
        // full batch), and multi-batch lengths
        for len in (0usize..=20).chain([61, 62, 63, 64, 124, 125, 200]) {
            let a: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let c: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let d: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let e: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            assert_eq!(dot(&a, &b), scalar::dot(&a, &b), "len={len}");
            assert_eq!(
                dot_x4(&a, &b, &c, &d, &e),
                scalar::dot_x4(&a, &b, &c, &d, &e),
                "len={len}"
            );
        }
    }

    #[test]
    fn saturated_words_cannot_overflow_byte_lanes() {
        // all-ones data maximizes every vcntq_u8 lane (8 per byte): a
        // batch bound above 31 would overflow u8 here and undercount
        for len in [62usize, 63, 124, 300] {
            let a = vec![u64::MAX; len];
            assert_eq!(dot(&a, &a), 64 * len as u64);
            assert_eq!(dot_x4(&a, &a, &a, &a, &a), [64 * len as u64; 4]);
        }
    }
}
