//! Scalar AND-popcount kernel: plain `u64::count_ones` with a 4-wide
//! accumulator unroll. Portable to every target and the dispatch
//! table's last-resort fallback; also the reference the other kernels
//! are property-tested against (`rust/tests/kernels.rs`).

/// popcount dot product of two packed columns.
pub(crate) fn dot(a: &[u64], b: &[u64]) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled: keeps several popcnt chains in flight
    let mut acc0 = 0u64;
    let mut acc1 = 0u64;
    let mut acc2 = 0u64;
    let mut acc3 = 0u64;
    let chunks = a.len() / 4;
    for k in 0..chunks {
        let i = k * 4;
        acc0 += (a[i] & b[i]).count_ones() as u64;
        acc1 += (a[i + 1] & b[i + 1]).count_ones() as u64;
        acc2 += (a[i + 2] & b[i + 2]).count_ones() as u64;
        acc3 += (a[i + 3] & b[i + 3]).count_ones() as u64;
    }
    for i in chunks * 4..a.len() {
        acc0 += (a[i] & b[i]).count_ones() as u64;
    }
    acc0 + acc1 + acc2 + acc3
}

/// Four popcount dot products of one packed column against four others
/// in a single pass: `a` is loaded once per word, and the four
/// `count_ones` accumulators are independent dependency chains, so
/// superscalar cores keep several popcnt units busy.
pub(crate) fn dot_x4(a: &[u64], b0: &[u64], b1: &[u64], b2: &[u64], b3: &[u64]) -> [u64; 4] {
    debug_assert!(
        a.len() == b0.len() && a.len() == b1.len() && a.len() == b2.len() && a.len() == b3.len()
    );
    let mut acc = [0u64; 4];
    for (k, &w) in a.iter().enumerate() {
        acc[0] += (w & b0[k]).count_ones() as u64;
        acc[1] += (w & b1[k]).count_ones() as u64;
        acc[2] += (w & b2[k]).count_ones() as u64;
        acc[3] += (w & b3[k]).count_ones() as u64;
    }
    acc
}
