//! AVX2 AND-popcount kernel (x86-64 only, runtime-detected).
//!
//! AVX2 has no vector popcount, so this uses the Muła nibble-lookup:
//! `vpshufb` maps each nibble of the ANDed 256-bit lane to its bit
//! count through a 16-entry table, and `vpsadbw` horizontally folds the
//! per-byte counts into four u64 lanes — 4 words per iteration with no
//! scalar popcount at all. Selected by the dispatch table only after
//! `is_x86_feature_detected!("avx2")` succeeds; everything else falls
//! back to the portable kernels.

use core::arch::x86_64::*;

/// Safe wrapper. The dispatch table is the only constructor of a
/// [`super::Kernel`] pointing here, and it includes this kernel only
/// when AVX2 was detected at startup, so the `target_feature` call is
/// sound on every path that can reach it.
pub(crate) fn dot(a: &[u64], b: &[u64]) -> u64 {
    debug_assert!(std::arch::is_x86_feature_detected!("avx2"));
    unsafe { dot_impl(a, b) }
}

/// Safe wrapper; same soundness argument as [`dot`].
pub(crate) fn dot_x4(a: &[u64], b0: &[u64], b1: &[u64], b2: &[u64], b3: &[u64]) -> [u64; 4] {
    debug_assert!(std::arch::is_x86_feature_detected!("avx2"));
    unsafe { dot_x4_impl(a, b0, b1, b2, b3) }
}

/// Bit counts of the 16 possible nibbles, twice (one per 128-bit half).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn nibble_table() -> __m256i {
    _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
    )
}

/// Per-byte popcount of `v` via two table lookups.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn byte_counts(v: __m256i, table: __m256i, low_mask: __m256i) -> __m256i {
    let lo = _mm256_and_si256(v, low_mask);
    let hi = _mm256_and_si256(_mm256_srli_epi64::<4>(v), low_mask);
    _mm256_add_epi8(
        _mm256_shuffle_epi8(table, lo),
        _mm256_shuffle_epi8(table, hi),
    )
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum_epi64(acc: __m256i) -> u64 {
    let mut lanes = [0u64; 4];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
    lanes[0] + lanes[1] + lanes[2] + lanes[3]
}

#[target_feature(enable = "avx2")]
unsafe fn dot_impl(a: &[u64], b: &[u64]) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let table = nibble_table();
    let low_mask = _mm256_set1_epi8(0x0f);
    let zero = _mm256_setzero_si256();
    let mut acc = zero;
    for k in 0..chunks {
        let va = _mm256_loadu_si256(a.as_ptr().add(k * 4) as *const __m256i);
        let vb = _mm256_loadu_si256(b.as_ptr().add(k * 4) as *const __m256i);
        let cnt = byte_counts(_mm256_and_si256(va, vb), table, low_mask);
        // per-byte counts are <= 8, so one vpsadbw per iteration can
        // never overflow anything
        acc = _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, zero));
    }
    let mut total = hsum_epi64(acc);
    for i in chunks * 4..n {
        total += (a[i] & b[i]).count_ones() as u64;
    }
    total
}

#[target_feature(enable = "avx2")]
unsafe fn dot_x4_impl(
    a: &[u64],
    b0: &[u64],
    b1: &[u64],
    b2: &[u64],
    b3: &[u64],
) -> [u64; 4] {
    debug_assert!(
        a.len() == b0.len() && a.len() == b1.len() && a.len() == b2.len() && a.len() == b3.len()
    );
    let n = a.len();
    let chunks = n / 4;
    let table = nibble_table();
    let low_mask = _mm256_set1_epi8(0x0f);
    let zero = _mm256_setzero_si256();
    let mut acc0 = zero;
    let mut acc1 = zero;
    let mut acc2 = zero;
    let mut acc3 = zero;
    for k in 0..chunks {
        // `a` is loaded once and ANDed against four columns — the same
        // reuse pattern as the scalar 4-wide unroll, in 256-bit lanes
        let va = _mm256_loadu_si256(a.as_ptr().add(k * 4) as *const __m256i);
        let v0 = _mm256_and_si256(va, _mm256_loadu_si256(b0.as_ptr().add(k * 4) as *const __m256i));
        let v1 = _mm256_and_si256(va, _mm256_loadu_si256(b1.as_ptr().add(k * 4) as *const __m256i));
        let v2 = _mm256_and_si256(va, _mm256_loadu_si256(b2.as_ptr().add(k * 4) as *const __m256i));
        let v3 = _mm256_and_si256(va, _mm256_loadu_si256(b3.as_ptr().add(k * 4) as *const __m256i));
        acc0 = _mm256_add_epi64(acc0, _mm256_sad_epu8(byte_counts(v0, table, low_mask), zero));
        acc1 = _mm256_add_epi64(acc1, _mm256_sad_epu8(byte_counts(v1, table, low_mask), zero));
        acc2 = _mm256_add_epi64(acc2, _mm256_sad_epu8(byte_counts(v2, table, low_mask), zero));
        acc3 = _mm256_add_epi64(acc3, _mm256_sad_epu8(byte_counts(v3, table, low_mask), zero));
    }
    let mut out = [
        hsum_epi64(acc0),
        hsum_epi64(acc1),
        hsum_epi64(acc2),
        hsum_epi64(acc3),
    ];
    for i in chunks * 4..n {
        let w = a[i];
        out[0] += (w & b0[i]).count_ones() as u64;
        out[1] += (w & b1[i]).count_ones() as u64;
        out[2] += (w & b2[i]).count_ones() as u64;
        out[3] += (w & b3[i]).count_ones() as u64;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::kernels::scalar;
    use crate::util::rng::Rng;

    #[test]
    fn matches_scalar_when_available() {
        if !std::arch::is_x86_feature_detected!("avx2") {
            eprintln!("avx2 unavailable; kernel untested on this host");
            return;
        }
        let mut rng = Rng::new(0xA2);
        for len in 0usize..=20 {
            let a: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let c: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let d: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let e: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            assert_eq!(dot(&a, &b), scalar::dot(&a, &b), "len={len}");
            assert_eq!(
                dot_x4(&a, &b, &c, &d, &e),
                scalar::dot_x4(&a, &b, &c, &d, &e),
                "len={len}"
            );
        }
    }
}
