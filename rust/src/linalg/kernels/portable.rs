//! Portable wide-accumulator kernel: Harley–Seal carry-save adders.
//!
//! Instead of popcounting every ANDed word, eight words per iteration
//! are compressed through a tree of carry-save adders (full adders over
//! whole 64-bit lanes) into running `ones`/`twos`/`fours` bit-planes;
//! only the weight-8 carry needs a real `count_ones` per 8-word chunk.
//! That amortizes the popcount to 1/8 per word — a large win on targets
//! where `count_ones` lowers to a multi-instruction SWAR sequence (the
//! default x86-64 baseline without `popcnt`) and still competitive where
//! it is a single instruction. This is the stable-Rust stand-in for a
//! `std::simd` kernel (portable SIMD is nightly-only at our MSRV); the
//! same CSA structure vectorizes directly once `std::simd` stabilizes.

/// Carry-save adder over 64 independent bit lanes:
/// returns `(sum, carry)` with `sum = a ^ b ^ c` and `carry = maj(a, b, c)`.
#[inline(always)]
fn csa(a: u64, b: u64, c: u64) -> (u64, u64) {
    let u = a ^ b;
    (u ^ c, (a & b) | (u & c))
}

pub(crate) fn dot(a: &[u64], b: &[u64]) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut eights = 0u64; // count of weight-8 carry bits seen so far
    let mut ones = 0u64;
    let mut twos = 0u64;
    let mut fours = 0u64;
    for k in 0..chunks {
        let i = k * 8;
        let d0 = a[i] & b[i];
        let d1 = a[i + 1] & b[i + 1];
        let d2 = a[i + 2] & b[i + 2];
        let d3 = a[i + 3] & b[i + 3];
        let d4 = a[i + 4] & b[i + 4];
        let d5 = a[i + 5] & b[i + 5];
        let d6 = a[i + 6] & b[i + 6];
        let d7 = a[i + 7] & b[i + 7];
        let (s, t0) = csa(ones, d0, d1);
        let (s, t1) = csa(s, d2, d3);
        let (s2, f0) = csa(twos, t0, t1);
        let (s, t0) = csa(s, d4, d5);
        let (s, t1) = csa(s, d6, d7);
        let (s2, f1) = csa(s2, t0, t1);
        let (s4, e) = csa(fours, f0, f1);
        ones = s;
        twos = s2;
        fours = s4;
        eights += e.count_ones() as u64;
    }
    let mut tail = 0u64;
    for i in chunks * 8..n {
        tail += (a[i] & b[i]).count_ones() as u64;
    }
    8 * eights
        + 4 * fours.count_ones() as u64
        + 2 * twos.count_ones() as u64
        + ones.count_ones() as u64
        + tail
}

pub(crate) fn dot_x4(a: &[u64], b0: &[u64], b1: &[u64], b2: &[u64], b3: &[u64]) -> [u64; 4] {
    // Four independent CSA pipelines would quadruple the register
    // pressure past what most cores hold; four sequential passes keep
    // the inner loop tight and `a` hot in L1.
    [dot(a, b0), dot(a, b1), dot(a, b2), dot(a, b3)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::kernels::scalar;
    use crate::util::rng::Rng;

    #[test]
    fn csa_is_a_full_adder() {
        for a in 0..2u64 {
            for b in 0..2u64 {
                for c in 0..2u64 {
                    let (s, carry) = csa(a, b, c);
                    assert_eq!(2 * carry + s, a + b + c, "({a},{b},{c})");
                }
            }
        }
    }

    #[test]
    fn matches_scalar_on_every_tail_length() {
        let mut rng = Rng::new(0xC5A);
        // cover 0..3 %4 and 0..7 %8 remainders plus multi-chunk lengths
        for len in 0usize..=40 {
            let a: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            assert_eq!(dot(&a, &b), scalar::dot(&a, &b), "len={len}");
        }
    }

    #[test]
    fn saturated_words() {
        let a = vec![u64::MAX; 17];
        assert_eq!(dot(&a, &a), 17 * 64);
        let z = vec![0u64; 17];
        assert_eq!(dot(&a, &z), 0);
    }
}
