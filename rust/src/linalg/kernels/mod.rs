//! Hardware-adaptive AND-popcount Gram kernels.
//!
//! The bit-packed Gram ([`crate::linalg::bitmat::BitMatrix`]) spends
//! essentially all of its time in one primitive: the popcount dot
//! product of two packed columns. This module ships several
//! implementations of that primitive —
//!
//! * `scalar` — `u64::count_ones` with a 4-wide accumulator unroll
//!   (works everywhere; the correctness reference);
//! * `portable` — Harley–Seal carry-save adders, amortizing the
//!   popcount to 1/8 per word (fast where `count_ones` is emulated);
//! * `avx2` — Muła nibble-lookup via `vpshufb`/`vpsadbw` (x86-64,
//!   runtime-detected with `is_x86_feature_detected!`);
//! * `avx512` — native 512-bit `VPOPCNTQ` (x86-64, runtime-detected
//!   behind `avx512f` + `avx512vpopcntdq`; Ice Lake and newer);
//! * `neon` — `vcntq_u8` + widening pairwise adds (aarch64; the
//!   default winner on Apple Silicon / Graviton hosts);
//!
//! — and a [`KernelDispatch`] table that picks one **once per process**:
//! an explicit `BULKMI_KERNEL` env override wins (an override naming a
//! kernel that is not eligible on this CPU is a hard error listing the
//! eligible set — a silent fallback would quietly invalidate perf
//! runs), otherwise every kernel eligible on this CPU is micro-probed
//! on a small resident buffer and the fastest is committed. All kernels
//! return bit-identical counts (property-tested in
//! `rust/tests/kernels.rs`), so selection is purely a throughput
//! decision and never a correctness one.

pub(crate) mod scalar;

pub(crate) mod portable;

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx512;

#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;

use crate::util::error::{Error, Result};
use crate::util::rng::Rng;
use std::sync::OnceLock;
use std::time::Instant;

/// One AND-popcount kernel: a name plus the two dot-product entry
/// points the Gram loops need. Instances are `'static` and only ever
/// constructed by this module, so holding a `&'static Kernel` from
/// [`available`] / [`active`] guarantees the kernel is safe to call on
/// this CPU (the ISA entries — AVX2, AVX-512, NEON — are listed only
/// after their runtime feature detection succeeds).
pub struct Kernel {
    name: &'static str,
    dot: fn(&[u64], &[u64]) -> u64,
    dot_x4: fn(&[u64], &[u64], &[u64], &[u64], &[u64]) -> [u64; 4],
}

impl Kernel {
    /// Stable identifier (`scalar` / `portable` / `avx2` / `avx512` /
    /// `neon`) used by `BULKMI_KERNEL`, bench output and sink metadata.
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// popcount(a & b) over two equal-length packed columns.
    #[inline]
    pub fn dot(&self, a: &[u64], b: &[u64]) -> u64 {
        (self.dot)(a, b)
    }

    /// Four dots of `a` against `b0..b3` in one pass.
    #[inline]
    pub fn dot_x4(&self, a: &[u64], b0: &[u64], b1: &[u64], b2: &[u64], b3: &[u64]) -> [u64; 4] {
        (self.dot_x4)(a, b0, b1, b2, b3)
    }
}

impl std::fmt::Debug for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Kernel").field("name", &self.name).finish()
    }
}

static SCALAR: Kernel = Kernel { name: "scalar", dot: scalar::dot, dot_x4: scalar::dot_x4 };

static PORTABLE: Kernel =
    Kernel { name: "portable", dot: portable::dot, dot_x4: portable::dot_x4 };

#[cfg(target_arch = "x86_64")]
static AVX2: Kernel = Kernel { name: "avx2", dot: avx2::dot, dot_x4: avx2::dot_x4 };

#[cfg(target_arch = "x86_64")]
static AVX512: Kernel = Kernel { name: "avx512", dot: avx512::dot, dot_x4: avx512::dot_x4 };

#[cfg(target_arch = "aarch64")]
static NEON: Kernel = Kernel { name: "neon", dot: neon::dot, dot_x4: neon::dot_x4 };

/// The scalar reference kernel (always present; what
/// [`crate::linalg::bitmat::BitMatrix::gram_reference`] runs on).
pub fn reference() -> &'static Kernel {
    &SCALAR
}

/// Every kernel that is safe to call on this CPU, reference first.
pub fn available() -> Vec<&'static Kernel> {
    #[allow(unused_mut)]
    let mut kernels = vec![&SCALAR, &PORTABLE];
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            kernels.push(&AVX2);
        }
        if avx512::detected() {
            kernels.push(&AVX512);
        }
    }
    #[cfg(target_arch = "aarch64")]
    if std::arch::is_aarch64_feature_detected!("neon") {
        kernels.push(&NEON);
    }
    kernels
}

/// Every kernel name the crate ships on *any* architecture (whether or
/// not it is eligible on this host) — what the bench baseline gate uses
/// to tell "kernel not present on this host" from a stale entry.
pub fn known_names() -> &'static [&'static str] {
    &["scalar", "portable", "avx2", "avx512", "neon"]
}

/// Look up an available kernel by its stable name.
pub fn by_name(name: &str) -> Option<&'static Kernel> {
    available().into_iter().find(|k| k.name == name)
}

/// The per-process kernel choice: which kernels were eligible, how each
/// probed, and which one every `BitMatrix::gram*` call now dispatches
/// to.
#[derive(Debug)]
pub struct KernelDispatch {
    active: &'static Kernel,
    /// `(kernel, probe_secs)` per eligible kernel; secs is 0.0 when the
    /// probe was skipped because `BULKMI_KERNEL` forced the choice.
    probes: Vec<(&'static Kernel, f64)>,
    forced: bool,
}

impl KernelDispatch {
    /// The process-wide table, built on first use and cached.
    pub fn global() -> &'static KernelDispatch {
        static TABLE: OnceLock<KernelDispatch> = OnceLock::new();
        TABLE.get_or_init(KernelDispatch::select)
    }

    /// The committed kernel.
    pub fn active(&self) -> &'static Kernel {
        self.active
    }

    /// Was the choice forced by `BULKMI_KERNEL` (vs. micro-probed)?
    pub fn forced(&self) -> bool {
        self.forced
    }

    /// Probe timings, fastest first (empty when the choice was forced
    /// by `BULKMI_KERNEL`, so nothing was probed).
    pub fn probes(&self) -> &[(&'static Kernel, f64)] {
        &self.probes
    }

    /// One-line report for logs / `bulkmi info`.
    pub fn summary(&self) -> String {
        let mut s = format!("gram kernel: {}", self.active.name);
        if self.forced {
            s.push_str(" (BULKMI_KERNEL override)");
        } else {
            let detail: Vec<String> = self
                .probes
                .iter()
                .map(|(k, t)| format!("{} {:.1}us", k.name, t * 1e6))
                .collect();
            s.push_str(&format!(" (probed: {})", detail.join(", ")));
        }
        s
    }

    fn select() -> KernelDispatch {
        let override_name = std::env::var("BULKMI_KERNEL").ok();
        match KernelDispatch::try_select(override_name.as_deref()) {
            Ok(table) => table,
            // A mistyped override silently falling back to auto-dispatch
            // would invalidate every perf number taken under it; the
            // process must not continue on the wrong kernel.
            Err(e) => panic!("{e}"),
        }
    }

    /// Build a dispatch table, honoring an explicit kernel-name
    /// override when one is given (what `BULKMI_KERNEL` feeds in).
    /// An override that does not name a kernel eligible on this CPU is
    /// an error listing the eligible set.
    pub fn try_select(override_name: Option<&str>) -> Result<KernelDispatch> {
        if let Some(name) = override_name {
            let Some(k) = by_name(name) else {
                return Err(override_error(name));
            };
            return Ok(KernelDispatch { active: k, probes: Vec::new(), forced: true });
        }
        let mut probes: Vec<(&'static Kernel, f64)> = available()
            .into_iter()
            .map(|k| (k, probe_secs(k)))
            .collect();
        probes.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
        Ok(KernelDispatch { active: probes[0].0, probes, forced: false })
    }
}

/// The kernel every `BitMatrix::gram*` call dispatches to.
#[inline]
pub fn active() -> &'static Kernel {
    KernelDispatch::global().active()
}

fn override_error(name: &str) -> Error {
    let eligible: Vec<&str> = available().iter().map(|k| k.name()).collect();
    Error::Config(format!(
        "BULKMI_KERNEL='{name}' is not an eligible kernel on this CPU \
         (eligible: {})",
        eligible.join(", ")
    ))
}

/// Check `BULKMI_KERNEL` against this CPU *without* committing the
/// dispatch table: `Ok` when unset or naming an eligible kernel. Entry
/// points that own an error channel (the CLI dispatcher, the job
/// service's `submit`) call this up front so a bad override surfaces
/// as a clean error to the caller instead of the dispatch-table panic
/// firing later inside a worker thread.
pub fn validate_env_override() -> Result<()> {
    match std::env::var("BULKMI_KERNEL") {
        Ok(name) if by_name(&name).is_none() => Err(override_error(&name)),
        _ => Ok(()),
    }
}

/// Micro-probe one kernel: best-of-5 `dot_x4` sweeps over small
/// L1-resident buffers (deterministic contents; ~a few hundred
/// microseconds per kernel, paid once per process).
fn probe_secs(kernel: &Kernel) -> f64 {
    const WORDS: usize = 2048; // 16 KiB per column: resident, realistic
    let mut rng = Rng::new(0xBEEF);
    let col = |rng: &mut Rng| -> Vec<u64> { (0..WORDS).map(|_| rng.next_u64()).collect() };
    let a = col(&mut rng);
    let b: Vec<Vec<u64>> = (0..4).map(|_| col(&mut rng)).collect();
    let mut best = f64::INFINITY;
    let mut checksum = 0u64;
    // iteration 0 is the warmup; 5 timed reps after it
    for rep in 0..6 {
        let t0 = Instant::now();
        let v = kernel.dot_x4(&a, &b[0], &b[1], &b[2], &b[3]);
        let secs = t0.elapsed().as_secs_f64();
        checksum = checksum.wrapping_add(v[0] + v[1] + v[2] + v[3]);
        if rep > 0 {
            best = best.min(secs);
        }
    }
    std::hint::black_box(checksum);
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_and_portable_always_available() {
        let names: Vec<&str> = available().iter().map(|k| k.name()).collect();
        assert!(names.contains(&"scalar"));
        assert!(names.contains(&"portable"));
        assert_eq!(names[0], "scalar", "reference kernel listed first");
    }

    #[test]
    fn by_name_round_trips() {
        for k in available() {
            assert_eq!(by_name(k.name()).unwrap().name(), k.name());
        }
        assert!(by_name("warp-drive").is_none());
    }

    #[test]
    fn every_available_kernel_is_a_known_name() {
        for k in available() {
            assert!(known_names().contains(&k.name()), "{} not in known_names", k.name());
        }
    }

    #[test]
    fn unknown_kernel_override_is_a_hard_error() {
        let err = KernelDispatch::try_select(Some("warp-drive")).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("warp-drive"), "names the bad override: {msg}");
        for k in available() {
            assert!(msg.contains(k.name()), "lists eligible kernel {}: {msg}", k.name());
        }
        // a kernel the crate ships but this CPU lacks is equally rejected
        for name in known_names() {
            if by_name(name).is_none() {
                assert!(KernelDispatch::try_select(Some(name)).is_err(), "{name}");
            }
        }
        // a valid name is still honored without probing
        let table = KernelDispatch::try_select(Some("portable")).unwrap();
        assert!(table.forced());
        assert!(table.probes().is_empty());
        assert_eq!(table.active().name(), "portable");
    }

    #[test]
    fn env_override_validation_passes_when_unset_or_valid() {
        // CI runs without BULKMI_KERNEL (or with a valid one); the
        // invalid-name path is covered via try_select above, since
        // mutating the process env would race the parallel test
        // threads that build the global dispatch table.
        assert!(validate_env_override().is_ok());
    }

    #[test]
    fn dispatch_commits_an_available_kernel() {
        let table = KernelDispatch::global();
        assert!(available().iter().any(|k| k.name() == table.active().name()));
        assert!(!table.summary().is_empty());
        if !table.forced() {
            // probed: the committed kernel is the fastest-probing one
            assert_eq!(table.probes()[0].0.name(), table.active().name());
            for w in table.probes().windows(2) {
                assert!(w[0].1 <= w[1].1);
            }
        }
    }

    #[test]
    fn all_kernels_agree_on_dots() {
        let mut rng = Rng::new(7);
        for len in [0usize, 1, 3, 4, 7, 8, 9, 31, 64, 65] {
            let a: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let bs: Vec<Vec<u64>> =
                (0..4).map(|_| (0..len).map(|_| rng.next_u64()).collect()).collect();
            let want = reference().dot(&a, &bs[0]);
            let want4 = reference().dot_x4(&a, &bs[0], &bs[1], &bs[2], &bs[3]);
            for k in available() {
                assert_eq!(k.dot(&a, &bs[0]), want, "{} len={len}", k.name());
                assert_eq!(
                    k.dot_x4(&a, &bs[0], &bs[1], &bs[2], &bs[3]),
                    want4,
                    "{} len={len}",
                    k.name()
                );
            }
        }
    }
}
