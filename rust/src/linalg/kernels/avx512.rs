//! AVX-512 `VPOPCNTQ` AND-popcount kernel (x86-64, runtime-detected).
//!
//! Ice Lake and newer x86-64 cores with the AVX512VPOPCNTDQ extension
//! have a *native* 512-bit popcount, so the whole inner loop collapses
//! to load / `vpandq` / `vpopcntq` / `vpaddq` — 8 words per iteration
//! with no nibble tables and roughly half the uops of the AVX2 Muła
//! lookup. AVX-512 intrinsics (and the `avx512*` target features)
//! stabilized in Rust 1.89, which sets the crate's MSRV.
//!
//! Eligibility is runtime-gated on `avx512f` **and** `avx512vpopcntdq`
//! ([`detected`]); like the other ISA kernels it is only ever reached
//! through the dispatch table, which lists it after detection succeeds.

use core::arch::x86_64::*;

/// Does this CPU support the instructions this kernel emits?
#[inline]
pub(crate) fn detected() -> bool {
    std::arch::is_x86_feature_detected!("avx512f")
        && std::arch::is_x86_feature_detected!("avx512vpopcntdq")
}

/// Safe wrapper. The dispatch table is the only constructor of a
/// [`super::Kernel`] pointing here, and it includes this kernel only
/// when [`detected`] succeeded at startup, so the `target_feature`
/// call is sound on every path that can reach it.
pub(crate) fn dot(a: &[u64], b: &[u64]) -> u64 {
    debug_assert!(detected());
    unsafe { dot_impl(a, b) }
}

/// Safe wrapper; same soundness argument as [`dot`].
pub(crate) fn dot_x4(a: &[u64], b0: &[u64], b1: &[u64], b2: &[u64], b3: &[u64]) -> [u64; 4] {
    debug_assert!(detected());
    unsafe { dot_x4_impl(a, b0, b1, b2, b3) }
}

/// Unaligned 512-bit load of 8 packed words. `read_unaligned` lowers
/// to a plain `vmovdqu64` under the enabled features.
#[inline]
#[target_feature(enable = "avx512f")]
unsafe fn load8(p: *const u64) -> __m512i {
    std::ptr::read_unaligned(p as *const __m512i)
}

#[target_feature(enable = "avx512f,avx512vpopcntdq")]
unsafe fn dot_impl(a: &[u64], b: &[u64]) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = _mm512_setzero_si512();
    for k in 0..chunks {
        let va = load8(a.as_ptr().add(k * 8));
        let vb = load8(b.as_ptr().add(k * 8));
        acc = _mm512_add_epi64(acc, _mm512_popcnt_epi64(_mm512_and_si512(va, vb)));
    }
    // lane sums are word popcounts (<= 64 each), far from i64 overflow
    let mut total = _mm512_reduce_add_epi64(acc) as u64;
    for i in chunks * 8..n {
        total += (a[i] & b[i]).count_ones() as u64;
    }
    total
}

#[target_feature(enable = "avx512f,avx512vpopcntdq")]
unsafe fn dot_x4_impl(
    a: &[u64],
    b0: &[u64],
    b1: &[u64],
    b2: &[u64],
    b3: &[u64],
) -> [u64; 4] {
    debug_assert!(
        a.len() == b0.len() && a.len() == b1.len() && a.len() == b2.len() && a.len() == b3.len()
    );
    let n = a.len();
    let chunks = n / 8;
    let mut acc0 = _mm512_setzero_si512();
    let mut acc1 = _mm512_setzero_si512();
    let mut acc2 = _mm512_setzero_si512();
    let mut acc3 = _mm512_setzero_si512();
    for k in 0..chunks {
        // `a` is loaded once and ANDed against four columns — the same
        // reuse pattern as the scalar 4-wide unroll, in 512-bit lanes
        let va = load8(a.as_ptr().add(k * 8));
        let v0 = _mm512_and_si512(va, load8(b0.as_ptr().add(k * 8)));
        let v1 = _mm512_and_si512(va, load8(b1.as_ptr().add(k * 8)));
        let v2 = _mm512_and_si512(va, load8(b2.as_ptr().add(k * 8)));
        let v3 = _mm512_and_si512(va, load8(b3.as_ptr().add(k * 8)));
        acc0 = _mm512_add_epi64(acc0, _mm512_popcnt_epi64(v0));
        acc1 = _mm512_add_epi64(acc1, _mm512_popcnt_epi64(v1));
        acc2 = _mm512_add_epi64(acc2, _mm512_popcnt_epi64(v2));
        acc3 = _mm512_add_epi64(acc3, _mm512_popcnt_epi64(v3));
    }
    let mut out = [
        _mm512_reduce_add_epi64(acc0) as u64,
        _mm512_reduce_add_epi64(acc1) as u64,
        _mm512_reduce_add_epi64(acc2) as u64,
        _mm512_reduce_add_epi64(acc3) as u64,
    ];
    for i in chunks * 8..n {
        let w = a[i];
        out[0] += (w & b0[i]).count_ones() as u64;
        out[1] += (w & b1[i]).count_ones() as u64;
        out[2] += (w & b2[i]).count_ones() as u64;
        out[3] += (w & b3[i]).count_ones() as u64;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::kernels::scalar;
    use crate::util::rng::Rng;

    #[test]
    fn matches_scalar_when_available() {
        if !detected() {
            eprintln!("avx512vpopcntdq unavailable; kernel untested on this host");
            return;
        }
        let mut rng = Rng::new(0x512);
        // cover every %8 remainder, multi-chunk lengths, and empty
        for len in (0usize..=20).chain([24, 31, 32, 33, 64, 100]) {
            let a: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let c: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let d: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let e: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            assert_eq!(dot(&a, &b), scalar::dot(&a, &b), "len={len}");
            assert_eq!(
                dot_x4(&a, &b, &c, &d, &e),
                scalar::dot_x4(&a, &b, &c, &d, &e),
                "len={len}"
            );
        }
        let ones = vec![u64::MAX; 33];
        assert_eq!(dot(&ones, &ones), 33 * 64);
    }
}
