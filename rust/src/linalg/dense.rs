//! Dense row-major matrices (f32 for data, f64 for results).
//!
//! `Mat32` holds binary data as f32 — matching what the NumPy/PyTorch/XLA
//! paths operate on — while MI outputs accumulate in f64 (`Mat64`) since
//! the Rust-native backends derive them from exact integer counts.

use crate::util::error::{Error, Result};

/// Row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat32 {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat32 {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat32 { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "buffer length {} != {rows}x{cols}",
                data.len()
            )));
        }
        Ok(Mat32 { rows, cols, data })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Contiguous row slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Out-of-place transpose.
    pub fn transpose(&self) -> Mat32 {
        let mut out = Mat32::zeros(self.cols, self.rows);
        // simple cache-blocked transpose
        const B: usize = 64;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Column sums (counts of ones for binary data).
    pub fn col_sums(&self) -> Vec<f64> {
        let mut sums = vec![0.0f64; self.cols];
        for r in 0..self.rows {
            let row = self.row(r);
            for (s, &v) in sums.iter_mut().zip(row) {
                *s += v as f64;
            }
        }
        sums
    }

    /// Element-wise `1 - x` (the paper's complementary matrix ¬D).
    pub fn complement(&self) -> Mat32 {
        let data = self.data.iter().map(|&v| 1.0 - v).collect();
        Mat32 { rows: self.rows, cols: self.cols, data }
    }
}

/// Row-major f64 matrix (results: Gram counts, MI values).
#[derive(Clone, Debug, PartialEq)]
pub struct Mat64 {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat64 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat64 { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "buffer length {} != {rows}x{cols}",
                data.len()
            )));
        }
        Ok(Mat64 { rows, cols, data })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn add_assign_at(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.cols + c] += v;
    }

    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn transpose(&self) -> Mat64 {
        let mut out = Mat64::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Diagonal as a vector (marginal counts in the paper's step 3).
    pub fn diag(&self) -> Vec<f64> {
        (0..self.rows.min(self.cols)).map(|i| self.get(i, i)).collect()
    }

    /// max |a - b| across all cells; matrices must be same shape.
    pub fn max_abs_diff(&self, other: &Mat64) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let mut m = Mat32::zeros(2, 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.get(0, 0), 0.0);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
    }

    #[test]
    fn from_vec_validates_length() {
        assert!(Mat32::from_vec(2, 2, vec![1.0; 3]).is_err());
        assert!(Mat32::from_vec(2, 2, vec![1.0; 4]).is_ok());
    }

    #[test]
    fn transpose_round_trip() {
        let m = Mat32::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        assert_eq!(t.get(2, 1), 6.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn transpose_large_blocked() {
        // exercise the blocked path with a non-multiple-of-64 shape
        let mut m = Mat32::zeros(100, 70);
        for r in 0..100 {
            for c in 0..70 {
                m.set(r, c, (r * 70 + c) as f32);
            }
        }
        let t = m.transpose();
        for r in 0..100 {
            for c in 0..70 {
                assert_eq!(t.get(c, r), m.get(r, c));
            }
        }
    }

    #[test]
    fn col_sums_counts_ones() {
        let m = Mat32::from_vec(3, 2, vec![1., 0., 1., 1., 0., 1.]).unwrap();
        assert_eq!(m.col_sums(), vec![2.0, 2.0]);
    }

    #[test]
    fn complement_flips() {
        let m = Mat32::from_vec(1, 3, vec![1., 0., 1.]).unwrap();
        assert_eq!(m.complement().data(), &[0., 1., 0.]);
    }

    #[test]
    fn mat64_diag_and_diff() {
        let a = Mat64::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        assert_eq!(a.diag(), vec![1., 4.]);
        let b = Mat64::from_vec(2, 2, vec![1., 2., 3., 5.]).unwrap();
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
