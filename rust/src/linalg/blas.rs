//! Cache-blocked dense kernels: the Gram `D^T D` and the general
//! `A^T B` product used by the basic (Section-2) algorithm.
//!
//! Implementation notes (perf pass is logged in EXPERIMENTS.md §Perf):
//!
//! * Row-major `D` is consumed as rank-k updates: for each row `k`,
//!   `C[i][j] += D[k][i] * D[k][j]`. The (i, j) space is tiled so the
//!   accumulator tile stays in L1/L2 while row slivers stream through.
//! * Accumulation is f32: for binary data every partial sum is an
//!   integer ≤ n, exactly representable up to n = 2^24 (16.7M rows) —
//!   far beyond the paper's largest dataset (100k rows).
//! * The symmetric case computes only the upper triangle's tiles and
//!   mirrors, saving ~2x.

use super::dense::{Mat32, Mat64};
use crate::util::error::{Error, Result};

/// Output rows accumulated per strip pass (strip buffer = STRIP·m f32;
/// 64 rows x 1000 cols ≈ 256 KiB, L2-resident).
const STRIP: usize = 64;

/// Symmetric Gram `D^T D` for a BINARY matrix (counts of co-occurring
/// ones).
///
/// Strip-gather structure (perf-pass iteration 3, see EXPERIMENTS.md
/// §Perf): for each strip of output rows `[ib, ihi)`, stream all data
/// rows once; for each data row gather the nonzero columns inside the
/// strip (cheap: one pass over STRIP cells), and for each hit add the
/// row's upper-triangle slice into the strip accumulator — for binary
/// data the multiply disappears (`a == 1`). Work is proportional to
/// `nnz · m/2` instead of `m²·n/2`, so the dense path gets the same
/// sparsity advantage NumPy's BLAS cannot see.
pub fn gram(d: &Mat32) -> Mat64 {
    let (n, m) = (d.rows(), d.cols());
    debug_assert!(
        d.data().iter().all(|&v| v == 0.0 || v == 1.0),
        "blas::gram is specialized for binary matrices"
    );
    let mut out = Mat64::zeros(m, m);
    let mut strip = vec![0.0f32; STRIP * m];
    let mut nz: Vec<u32> = Vec::with_capacity(STRIP);
    for ib in (0..m).step_by(STRIP) {
        let ihi = (ib + STRIP).min(m);
        strip[..(ihi - ib) * m].iter_mut().for_each(|v| *v = 0.0);
        for k in 0..n {
            let row = d.row(k);
            nz.clear();
            for (di, &a) in row[ib..ihi].iter().enumerate() {
                if a != 0.0 {
                    nz.push(di as u32);
                }
            }
            for &di in &nz {
                let i = ib + di as usize;
                // accumulate the triangle slice j in [i, m)
                let dst = &mut strip[di as usize * m + i..di as usize * m + m];
                let src = &row[i..m];
                for (t, &b) in dst.iter_mut().zip(src) {
                    *t += b; // binary: a == 1
                }
            }
        }
        for di in 0..(ihi - ib) {
            let i = ib + di;
            for j in i..m {
                let v = strip[di * m + j] as f64;
                out.set(i, j, v);
                out.set(j, i, v);
            }
        }
    }
    out
}

/// General product `A^T B` for same-row-count BINARY matrices (used by
/// the Section-2 basic algorithm for the ¬D Gram matrices, and by the
/// coordinator for cross column-block Grams). Same strip-gather
/// structure as [`gram`], full rectangle instead of the triangle.
pub fn gemm_at_b(a: &Mat32, b: &Mat32) -> Result<Mat64> {
    if a.rows() != b.rows() {
        return Err(Error::Shape(format!(
            "gemm_at_b: row mismatch {} vs {}",
            a.rows(),
            b.rows()
        )));
    }
    let (n, ma, mb) = (a.rows(), a.cols(), b.cols());
    let mut out = Mat64::zeros(ma, mb);
    let mut strip = vec![0.0f32; STRIP * mb];
    let mut nz: Vec<u32> = Vec::with_capacity(STRIP);
    for ib in (0..ma).step_by(STRIP) {
        let ihi = (ib + STRIP).min(ma);
        strip[..(ihi - ib) * mb].iter_mut().for_each(|v| *v = 0.0);
        for k in 0..n {
            let arow = a.row(k);
            let brow = b.row(k);
            nz.clear();
            for (di, &av) in arow[ib..ihi].iter().enumerate() {
                if av != 0.0 {
                    nz.push(di as u32);
                }
            }
            for &di in &nz {
                let dst = &mut strip[di as usize * mb..(di as usize + 1) * mb];
                for (t, &bv) in dst.iter_mut().zip(brow) {
                    *t += bv; // binary: a == 1
                }
            }
        }
        for di in 0..(ihi - ib) {
            for j in 0..mb {
                out.set(ib + di, j, strip[di * mb + j] as f64);
            }
        }
    }
    Ok(out)
}

/// Naive reference Gram — O(m² n) triple loop, used only to validate the
/// blocked kernels in tests and the gram-strategy ablation bench.
pub fn gram_naive(d: &Mat32) -> Mat64 {
    let (n, m) = (d.rows(), d.cols());
    let mut out = Mat64::zeros(m, m);
    for i in 0..m {
        for j in i..m {
            let mut acc = 0.0f64;
            for k in 0..n {
                acc += (d.get(k, i) * d.get(k, j)) as f64;
            }
            out.set(i, j, acc);
            out.set(j, i, acc);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_binary(rng: &mut Rng, n: usize, m: usize, density: f64) -> Mat32 {
        let data = (0..n * m)
            .map(|_| if rng.bernoulli(density) { 1.0f32 } else { 0.0 })
            .collect();
        Mat32::from_vec(n, m, data).unwrap()
    }

    #[test]
    fn gram_matches_naive_small() {
        let mut rng = Rng::new(1);
        for &(n, m) in &[(1usize, 1usize), (7, 3), (65, 17), (130, 70), (513, 129)] {
            let d = random_binary(&mut rng, n, m, 0.3);
            let fast = gram(&d);
            let slow = gram_naive(&d);
            assert_eq!(fast.max_abs_diff(&slow), 0.0, "n={n} m={m}");
        }
    }

    #[test]
    fn gram_is_symmetric_with_count_diag() {
        let mut rng = Rng::new(2);
        let d = random_binary(&mut rng, 100, 20, 0.5);
        let g = gram(&d);
        let sums = d.col_sums();
        for i in 0..20 {
            assert_eq!(g.get(i, i), sums[i]); // diag = column counts
            for j in 0..20 {
                assert_eq!(g.get(i, j), g.get(j, i));
            }
        }
    }

    #[test]
    fn gemm_matches_gram_on_self() {
        let mut rng = Rng::new(3);
        let d = random_binary(&mut rng, 90, 33, 0.4);
        let g1 = gram(&d);
        let g2 = gemm_at_b(&d, &d).unwrap();
        assert_eq!(g1.max_abs_diff(&g2), 0.0);
    }

    #[test]
    fn gemm_cross_rectangular() {
        let mut rng = Rng::new(4);
        let a = random_binary(&mut rng, 50, 10, 0.6);
        let b = random_binary(&mut rng, 50, 7, 0.2);
        let g = gemm_at_b(&a, &b).unwrap();
        assert_eq!((g.rows(), g.cols()), (10, 7));
        // check one cell by hand
        let mut acc = 0.0;
        for k in 0..50 {
            acc += (a.get(k, 3) * b.get(k, 5)) as f64;
        }
        assert_eq!(g.get(3, 5), acc);
    }

    #[test]
    fn gemm_rejects_row_mismatch() {
        let a = Mat32::zeros(3, 2);
        let b = Mat32::zeros(4, 2);
        assert!(gemm_at_b(&a, &b).is_err());
    }

    #[test]
    fn section2_identity_g00() {
        // G00 = N - C - C^T + G11 must equal ¬D^T ¬D computed directly.
        let mut rng = Rng::new(5);
        let d = random_binary(&mut rng, 64, 12, 0.35);
        let n = d.rows() as f64;
        let g11 = gram(&d);
        let nd = d.complement();
        let g00_direct = gram(&nd);
        let c = d.col_sums();
        for i in 0..12 {
            for j in 0..12 {
                let derived = n - c[j] - c[i] + g11.get(i, j);
                assert_eq!(g00_direct.get(i, j), derived, "({i},{j})");
            }
        }
    }
}
