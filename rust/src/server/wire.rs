//! Versioned JSON wire schema (`"v": 1`) shared by every serving
//! surface: the HTTP handlers in [`super::http`], `bulkmi serve
//! --stdin`, and the CLI's option parsing. There is exactly one parse
//! path from the wire strings (`backend` / `measure` / `sink` /
//! `schedule` / `priority`) to the typed enums — the per-flag ad-hoc
//! parsing that used to live in `cli/commands.rs` delegates here.
//!
//! A request names a server-registered dataset and carries the job
//! knobs of [`JobSpec`]; unknown keys are rejected (typo protection,
//! same policy as the config layer). Responses are hand-formatted JSON
//! (the crate is serde-free); all floats render through Rust's shortest
//! round-trip `Display`, so a value parsed back with
//! [`Json::parse`] is bit-identical to what the engine computed.

use crate::coordinator::admission::Priority;
use crate::coordinator::scheduler::Schedule;
use crate::coordinator::service::{JobInfo, JobSpec, JobStatus};
use crate::mi::backend::Backend;
use crate::mi::measure::CombineKind;
use crate::mi::sink::{SinkData, SinkOutput, SinkSpec};
use crate::util::error::{Error, Result};
use crate::util::json::{escape, Json};

/// The wire schema version every request and response carries.
pub const WIRE_VERSION: u64 = 1;

// ---------------------------------------------------------------------
// the one parse path for wire-level option strings
// ---------------------------------------------------------------------

/// Parse a backend name, listing the valid names on failure.
pub fn parse_backend(s: &str) -> Result<Backend> {
    Backend::parse(s).ok_or_else(|| {
        Error::Parse(format!(
            "unknown backend '{s}' (expected one of: {})",
            Backend::ALL.map(Backend::name).join(" ")
        ))
    })
}

/// [`parse_backend`] restricted to the native (always-available)
/// backends — the job service cannot run XLA jobs.
pub fn parse_native_backend(s: &str) -> Result<Backend> {
    let backend = parse_backend(s)?;
    if !backend.is_native() {
        return Err(Error::Parse(format!(
            "backend '{s}' is not native (expected one of: {})",
            Backend::ALL
                .iter()
                .filter(|b| b.is_native())
                .map(|b| b.name())
                .collect::<Vec<_>>()
                .join(" ")
        )));
    }
    Ok(backend)
}

/// Parse a measure name, listing the valid names on failure.
pub fn parse_measure(s: &str) -> Result<CombineKind> {
    CombineKind::parse(s).ok_or_else(|| {
        Error::Parse(format!(
            "unknown measure '{s}' (expected one of: {})",
            CombineKind::ALL.map(CombineKind::name).join(" ")
        ))
    })
}

/// Parse a schedule name, listing the valid names on failure.
pub fn parse_schedule(s: &str) -> Result<Schedule> {
    Schedule::parse(s).ok_or_else(|| {
        Error::Parse(format!(
            "unknown schedule '{s}' (expected one of: sequential largest-first \
             diagonal-first panel)"
        ))
    })
}

/// Parse an admission priority, listing the valid names on failure.
pub fn parse_priority(s: &str) -> Result<Priority> {
    Priority::parse(s).ok_or_else(|| {
        Error::Parse(format!(
            "unknown priority '{s}' (expected one of: interactive batch)"
        ))
    })
}

/// Parse a sink spec (`--sink` syntax; delegates to
/// [`SinkSpec::parse`], which already reports the valid forms).
pub fn parse_sink(s: &str) -> Result<SinkSpec> {
    SinkSpec::parse(s)
}

/// Render a [`SinkSpec`] back to its `--sink` string — the inverse of
/// [`parse_sink`].
pub fn sink_string(sink: &SinkSpec) -> String {
    match sink {
        SinkSpec::Dense => "dense".to_string(),
        SinkSpec::TopK { k, per_column: false } => format!("topk:{k}"),
        SinkSpec::TopK { k, per_column: true } => format!("topk-per-col:{k}"),
        SinkSpec::ThresholdMi { threshold } => format!("threshold:{threshold}"),
        SinkSpec::ThresholdPvalue { pvalue } => format!("pvalue:{pvalue}"),
        SinkSpec::Spill { dir } => format!("spill:{}", dir.display()),
    }
}

// ---------------------------------------------------------------------
// JobRequest: the submit payload
// ---------------------------------------------------------------------

/// A wire-level job submission: which registered dataset to run over,
/// plus the job knobs. Parsed by the HTTP `POST /v1/jobs` handler and
/// by `bulkmi serve --stdin` (one request per line) through the same
/// code.
#[derive(Clone, Debug)]
pub struct JobRequest {
    /// Name of a server-registered dataset.
    pub dataset: String,
    /// The validated job spec ([`JobSpec::builder`] output).
    pub spec: JobSpec,
}

/// Every key a v1 request may carry.
const REQUEST_KEYS: &[&str] = &[
    "v",
    "dataset",
    "tenant",
    "backend",
    "measure",
    "sink",
    "schedule",
    "block_cols",
    "workers",
    "cache_bytes",
    "readahead",
    "task_latency_secs",
    "priority",
    "tiles",
];

fn req_str<'a>(obj: &'a Json, key: &str) -> Result<Option<&'a str>> {
    match obj.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_str()
            .map(Some)
            .ok_or_else(|| Error::Parse(format!("request key '{key}' must be a string"))),
    }
}

fn req_usize(obj: &Json, key: &str) -> Result<Option<usize>> {
    match obj.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => {
            let n = v
                .as_f64()
                .ok_or_else(|| Error::Parse(format!("request key '{key}' must be a number")))?;
            if !n.is_finite() || n < 0.0 || n.fract() != 0.0 || n > 9.0e15 {
                return Err(Error::Parse(format!(
                    "request key '{key}' must be a non-negative integer, got {n}"
                )));
            }
            Ok(Some(n as usize))
        }
    }
}

fn req_f64(obj: &Json, key: &str) -> Result<Option<f64>> {
    match obj.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| Error::Parse(format!("request key '{key}' must be a number"))),
    }
}

impl JobRequest {
    /// Parse a request from JSON text (one HTTP body, one stdin line).
    pub fn parse(text: &str) -> Result<JobRequest> {
        Self::from_json(&Json::parse(text)?)
    }

    /// Build from a parsed JSON value, rejecting unknown keys and
    /// validating the spec through [`JobSpec::builder`].
    pub fn from_json(json: &Json) -> Result<JobRequest> {
        let Json::Obj(fields) = json else {
            return Err(Error::Parse("job request must be a JSON object".into()));
        };
        for (key, _) in fields {
            if !REQUEST_KEYS.contains(&key.as_str()) {
                return Err(Error::Parse(format!(
                    "unknown request key '{key}' (expected: {})",
                    REQUEST_KEYS.join(" ")
                )));
            }
        }
        let v = json
            .get("v")
            .and_then(Json::as_f64)
            .ok_or_else(|| Error::Parse("job request needs a numeric \"v\" field".into()))?;
        if v != WIRE_VERSION as f64 {
            return Err(Error::Parse(format!(
                "unsupported wire version {v} (this server speaks v{WIRE_VERSION})"
            )));
        }
        let dataset = req_str(json, "dataset")?
            .ok_or_else(|| Error::Parse("job request needs a \"dataset\" name".into()))?
            .to_string();

        let mut builder = JobSpec::builder();
        if let Some(s) = req_str(json, "backend")? {
            builder = builder.backend(parse_native_backend(s)?);
        }
        if let Some(s) = req_str(json, "measure")? {
            builder = builder.measure(parse_measure(s)?);
        }
        if let Some(s) = req_str(json, "sink")? {
            builder = builder.sink(parse_sink(s)?);
        }
        if let Some(s) = req_str(json, "schedule")? {
            builder = builder.schedule(parse_schedule(s)?);
        }
        if let Some(s) = req_str(json, "priority")? {
            builder = builder.priority(parse_priority(s)?);
        }
        if let Some(s) = req_str(json, "tenant")? {
            builder = builder.tenant(s);
        }
        if let Some(n) = req_usize(json, "block_cols")? {
            builder = builder.block_cols(n);
        }
        // "workers" is overloaded exactly like the CLI flag: a number
        // is the local thread count, a "host:port,..." string turns
        // the job into a cluster run over those workers
        match json.get("workers") {
            None | Some(Json::Null) => {}
            Some(Json::Str(s)) => {
                let addrs: Vec<String> = s
                    .split(',')
                    .map(str::trim)
                    .filter(|a| !a.is_empty())
                    .map(str::to_string)
                    .collect();
                if addrs.is_empty() || addrs.iter().any(|a| !a.contains(':')) {
                    return Err(Error::Parse(format!(
                        "request key 'workers' as a string must be a comma-separated \
                         host:port list, got '{s}'"
                    )));
                }
                builder = builder.cluster_workers(addrs);
            }
            Some(_) => {
                if let Some(n) = req_usize(json, "workers")? {
                    builder = builder.inner_workers(n);
                }
            }
        }
        if let Some(n) = req_usize(json, "cache_bytes")? {
            builder = builder.cache_bytes(Some(n));
        }
        if let Some(n) = req_usize(json, "readahead")? {
            builder = builder.readahead(n);
        }
        if let Some(t) = req_f64(json, "task_latency_secs")? {
            builder = builder.task_latency_secs(t);
        }
        // numeric 0/1 like every other wire flag (the schema has no
        // boolean type yet)
        if let Some(n) = req_usize(json, "tiles")? {
            if n > 1 {
                return Err(Error::Parse(format!(
                    "request key 'tiles' must be 0 or 1, got {n}"
                )));
            }
            builder = builder.tiles(n == 1);
        }
        Ok(JobRequest { dataset, spec: builder.build()? })
    }

    /// Render back to wire JSON — `parse(to_json(r))` reproduces the
    /// request (round-trip tested below).
    pub fn to_json(&self) -> String {
        let s = &self.spec;
        let mut out = format!(
            "{{\"v\":{WIRE_VERSION},\"dataset\":\"{}\",\"backend\":\"{}\",\
             \"measure\":\"{}\",\"sink\":\"{}\",\"block_cols\":{},\
             \"readahead\":{},\"task_latency_secs\":{}",
            escape(&self.dataset),
            s.backend.name(),
            s.measure.name(),
            escape(&sink_string(&s.sink)),
            s.block_cols,
            s.readahead,
            s.task_latency_secs,
        );
        // the overloaded key renders in whichever form the spec uses
        if s.cluster_workers.is_empty() {
            out.push_str(&format!(",\"workers\":{}", s.inner_workers));
        } else {
            out.push_str(&format!(
                ",\"workers\":\"{}\"",
                escape(&s.cluster_workers.join(","))
            ));
        }
        if let Some(schedule) = s.schedule {
            out.push_str(&format!(",\"schedule\":\"{}\"", schedule.name()));
        }
        if let Some(cache) = s.cache_bytes {
            out.push_str(&format!(",\"cache_bytes\":{cache}"));
        }
        if let Some(priority) = s.priority {
            out.push_str(&format!(",\"priority\":\"{}\"", priority.name()));
        }
        if let Some(tenant) = &s.tenant {
            out.push_str(&format!(",\"tenant\":\"{}\"", escape(tenant)));
        }
        if s.tiles {
            out.push_str(",\"tiles\":1");
        }
        out.push('}');
        out
    }
}

// ---------------------------------------------------------------------
// responses
// ---------------------------------------------------------------------

/// The status envelope for one job (`GET /v1/jobs/{id}` and the submit
/// acknowledgement).
pub fn status_json(id: u64, info: &JobInfo) -> String {
    let progress = match &info.status {
        JobStatus::Queued => 0.0,
        JobStatus::Running(f) => *f,
        _ => 1.0,
    };
    let error = match &info.status {
        JobStatus::Failed(msg) => format!(",\"error\":\"{}\"", escape(msg)),
        _ => String::new(),
    };
    format!(
        "{{\"v\":{WIRE_VERSION},\"job\":{id},\"state\":\"{}\",\"progress\":{progress},\
         \"priority\":\"{}\",\"estimated_bytes\":{}{error}}}",
        info.status.name(),
        info.priority.name(),
        info.estimated_bytes,
    )
}

fn pairs_json(pairs: &[crate::mi::topk::MiPair]) -> String {
    let cells: Vec<String> = pairs
        .iter()
        .map(|p| format!("{{\"i\":{},\"j\":{},\"value\":{}}}", p.i, p.j, p.mi))
        .collect();
    format!("[{}]", cells.join(","))
}

fn opt_str_json(v: Option<&str>) -> String {
    match v {
        Some(s) => format!("\"{}\"", escape(s)),
        None => "null".to_string(),
    }
}

fn meta_json(out: &SinkOutput) -> String {
    let m = &out.meta;
    let admission = match &m.admission {
        None => "null".to_string(),
        Some(a) => format!(
            "{{\"estimated_bytes\":{},\"queued_secs\":{},\"priority\":\"{}\"}}",
            a.estimated_bytes, a.queued_secs, a.priority
        ),
    };
    let tiles = match &m.tiles {
        None => "null".to_string(),
        Some(t) => format!(
            "{{\"hits\":{},\"misses\":{},\"evictions\":{},\"inserted_bytes\":{},\
             \"budget_bytes\":{}}}",
            t.hits, t.misses, t.evictions, t.inserted_bytes, t.budget_bytes
        ),
    };
    let cluster = match &m.cluster {
        None => "null".to_string(),
        Some(c) => format!(
            "{{\"workers\":{},\"tasks\":{},\"retried\":{},\"worker_failures\":{}}}",
            c.workers, c.tasks, c.retried, c.worker_failures
        ),
    };
    format!(
        "{{\"backend\":{},\"requested_backend\":{},\"measure\":{},\"schedule\":{},\
         \"admission\":{admission},\"tiles\":{tiles},\"cluster\":{cluster}}}",
        opt_str_json(m.backend.as_deref()),
        opt_str_json(m.requested_backend.as_deref()),
        opt_str_json(m.measure.as_deref()),
        opt_str_json(m.schedule),
    )
}

/// The result envelope (`GET /v1/jobs/{id}/result`): the sink's payload
/// rendered per kind, plus the run meta (backend, measure, admission
/// audit). Dense results carry the full matrix row-major; spill results
/// carry the manifest path instead of data.
pub fn result_json(id: u64, out: &SinkOutput) -> String {
    let result = match &out.data {
        SinkData::Dense(mi) => {
            let m = mi.dim();
            let rows: Vec<String> = (0..m)
                .map(|i| {
                    let cells: Vec<String> =
                        (0..m).map(|j| mi.get(i, j).to_string()).collect();
                    format!("[{}]", cells.join(","))
                })
                .collect();
            format!("{{\"kind\":\"dense\",\"dim\":{m},\"rows\":[{}]}}", rows.join(","))
        }
        SinkData::TopK(pairs) => {
            format!("{{\"kind\":\"topk\",\"pairs\":{}}}", pairs_json(pairs))
        }
        SinkData::TopKPerColumn(cols) => {
            let per_col: Vec<String> = cols.iter().map(|c| pairs_json(c)).collect();
            format!(
                "{{\"kind\":\"topk-per-col\",\"columns\":[{}]}}",
                per_col.join(",")
            )
        }
        SinkData::Sparse(sp) => {
            let pvalue =
                sp.pvalue.map_or("null".to_string(), |p| p.to_string());
            format!(
                "{{\"kind\":\"sparse\",\"threshold\":{},\"pvalue\":{pvalue},\"pairs\":{}}}",
                sp.threshold,
                pairs_json(&sp.pairs)
            )
        }
        SinkData::Spilled(info) => format!(
            "{{\"kind\":\"spill\",\"dir\":\"{}\",\"manifest\":\"{}\",\"m\":{},\
             \"tiles\":{},\"bytes\":{}}}",
            escape(&info.dir.display().to_string()),
            escape(&info.dir.join("manifest.csv").display().to_string()),
            info.m,
            info.tiles,
            info.bytes,
        ),
    };
    format!(
        "{{\"v\":{WIRE_VERSION},\"job\":{id},\"result\":{result},\"meta\":{}}}",
        meta_json(out)
    )
}

/// A uniform error envelope.
pub fn error_json(msg: &str) -> String {
    format!("{{\"v\":{WIRE_VERSION},\"error\":\"{}\"}}", escape(msg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mi::sink::{AdmissionReport, SinkMeta, SparsePairs};
    use crate::mi::topk::MiPair;
    use crate::mi::MiMatrix;

    #[test]
    fn request_round_trips_through_json() {
        let spec = JobSpec::builder()
            .backend(Backend::BulkSparse)
            .measure(CombineKind::Jaccard)
            .sink(SinkSpec::TopK { k: 7, per_column: false })
            .schedule(Schedule::Panel)
            .block_cols(16)
            .inner_workers(3)
            .cache_bytes(Some(1 << 20))
            .readahead(2)
            .task_latency_secs(0.5)
            .priority(Priority::Interactive)
            .tenant("acme")
            .tiles(true)
            .build()
            .unwrap();
        let req = JobRequest { dataset: "bg".into(), spec };
        let back = JobRequest::parse(&req.to_json()).unwrap();
        assert_eq!(back.dataset, "bg");
        assert_eq!(back.spec.backend, Backend::BulkSparse);
        assert_eq!(back.spec.measure, CombineKind::Jaccard);
        assert_eq!(back.spec.sink, SinkSpec::TopK { k: 7, per_column: false });
        assert_eq!(back.spec.schedule, Some(Schedule::Panel));
        assert_eq!(back.spec.block_cols, 16);
        assert_eq!(back.spec.inner_workers, 3);
        assert_eq!(back.spec.cache_bytes, Some(1 << 20));
        assert_eq!(back.spec.readahead, 2);
        assert_eq!(back.spec.task_latency_secs, 0.5);
        assert_eq!(back.spec.priority, Some(Priority::Interactive));
        assert_eq!(back.spec.tenant.as_deref(), Some("acme"));
        assert!(back.spec.tiles);
        // default-off requests omit the key entirely
        let plain = JobRequest { dataset: "bg".into(), spec: JobSpec::default() };
        assert!(!plain.to_json().contains("tiles"));
        assert!(!JobRequest::parse(&plain.to_json()).unwrap().spec.tiles);
    }

    #[test]
    fn workers_key_is_overloaded_by_json_type() {
        // a number stays the local thread count
        let req = JobRequest::parse(r#"{"v":1,"dataset":"bg","workers":3}"#).unwrap();
        assert_eq!(req.spec.inner_workers, 3);
        assert!(req.spec.cluster_workers.is_empty());
        // a host:port string list turns the job into a cluster run
        let req = JobRequest::parse(
            r#"{"v":1,"dataset":"bg","workers":"10.0.0.1:7070, 10.0.0.2:7070"}"#,
        )
        .unwrap();
        assert_eq!(req.spec.cluster_workers, ["10.0.0.1:7070", "10.0.0.2:7070"]);
        // and the cluster form round-trips through to_json
        let back = JobRequest::parse(&req.to_json()).unwrap();
        assert_eq!(back.spec.cluster_workers, req.spec.cluster_workers);
        // strings that are not address lists are rejected, not ignored
        for bad in [r#""""#, r#""threads""#, r#""a:1,,b""#] {
            let body = format!(r#"{{"v":1,"dataset":"bg","workers":{bad}}}"#);
            assert!(JobRequest::parse(&body).is_err(), "{bad}");
        }
    }

    #[test]
    fn cluster_meta_renders_in_results() {
        let out = SinkOutput {
            data: SinkData::TopK(vec![]),
            meta: SinkMeta {
                cluster: Some(crate::mi::sink::ClusterReport {
                    workers: 2,
                    tasks: 10,
                    retried: 3,
                    worker_failures: 1,
                }),
                ..SinkMeta::default()
            },
        };
        let doc = Json::parse(&result_json(5, &out)).unwrap();
        let cluster = doc.get("meta").unwrap().get("cluster").unwrap();
        assert_eq!(cluster.get("workers").unwrap().as_f64(), Some(2.0));
        assert_eq!(cluster.get("tasks").unwrap().as_f64(), Some(10.0));
        assert_eq!(cluster.get("retried").unwrap().as_f64(), Some(3.0));
        assert_eq!(cluster.get("worker_failures").unwrap().as_f64(), Some(1.0));
        // single-process runs render null, not a zeroed report
        let local = SinkOutput::from(SinkData::TopK(vec![]));
        let doc = Json::parse(&result_json(6, &local)).unwrap();
        assert!(matches!(doc.get("meta").unwrap().get("cluster"), Some(Json::Null)));
    }

    #[test]
    fn minimal_request_uses_spec_defaults() {
        let req = JobRequest::parse(r#"{"v":1,"dataset":"bg"}"#).unwrap();
        let def = JobSpec::default();
        assert_eq!(req.spec.backend, def.backend);
        assert_eq!(req.spec.sink, def.sink);
        assert_eq!(req.spec.measure, def.measure);
        assert_eq!(req.spec.priority, None);
    }

    #[test]
    fn version_is_checked() {
        assert!(JobRequest::parse(r#"{"dataset":"bg"}"#).is_err());
        let err = JobRequest::parse(r#"{"v":2,"dataset":"bg"}"#).unwrap_err();
        assert!(err.to_string().contains("wire version"), "{err}");
    }

    #[test]
    fn unknown_keys_and_bad_values_rejected() {
        let err = JobRequest::parse(r#"{"v":1,"dataset":"bg","bogus":1}"#).unwrap_err();
        assert!(err.to_string().contains("unknown request key 'bogus'"), "{err}");
        assert!(JobRequest::parse(r#"{"v":1,"dataset":"bg","block_cols":1.5}"#).is_err());
        assert!(JobRequest::parse(r#"{"v":1,"dataset":"bg","block_cols":-4}"#).is_err());
        assert!(JobRequest::parse(r#"{"v":1,"dataset":"bg","backend":7}"#).is_err());
        assert!(JobRequest::parse(r#"{"v":1,"dataset":"bg","tiles":2}"#).is_err());
        assert!(JobRequest::parse(r#"{"v":1,"dataset":"bg","tiles":0}"#).is_ok());
        assert!(JobRequest::parse(r#"{"v":1}"#).is_err(), "dataset is required");
        assert!(JobRequest::parse(r#"[1,2]"#).is_err(), "must be an object");
    }

    #[test]
    fn wire_parsers_reject_with_the_valid_names() {
        let err = parse_backend("warp").unwrap_err();
        assert!(err.to_string().contains("bulk-bitpack"), "{err}");
        let err = parse_native_backend("xla").unwrap_err();
        assert!(err.to_string().contains("not native"), "{err}");
        let err = parse_measure("pearson").unwrap_err();
        assert!(err.to_string().contains("jaccard"), "{err}");
        let err = parse_schedule("random").unwrap_err();
        assert!(err.to_string().contains("panel"), "{err}");
        let err = parse_priority("urgent").unwrap_err();
        assert!(err.to_string().contains("interactive"), "{err}");
        assert!(parse_sink("warp:1").is_err());
    }

    #[test]
    fn sink_strings_round_trip() {
        for s in ["dense", "topk:5", "topk-per-col:2", "threshold:0.25", "pvalue:0.001"] {
            let spec = parse_sink(s).unwrap();
            assert_eq!(sink_string(&spec), s);
            assert_eq!(parse_sink(&sink_string(&spec)).unwrap(), spec);
        }
    }

    #[test]
    fn status_json_parses_back() {
        let info = JobInfo {
            status: JobStatus::Running(0.25),
            priority: Priority::Batch,
            estimated_bytes: 4096,
        };
        let doc = Json::parse(&status_json(7, &info)).unwrap();
        assert_eq!(doc.get("v").unwrap().as_f64(), Some(1.0));
        assert_eq!(doc.get("job").unwrap().as_f64(), Some(7.0));
        assert_eq!(doc.get("state").unwrap().as_str(), Some("running"));
        assert_eq!(doc.get("progress").unwrap().as_f64(), Some(0.25));
        assert_eq!(doc.get("priority").unwrap().as_str(), Some("batch"));
        assert_eq!(doc.get("estimated_bytes").unwrap().as_f64(), Some(4096.0));

        let failed = JobInfo {
            status: JobStatus::Failed("boom \"quoted\"".into()),
            priority: Priority::Interactive,
            estimated_bytes: 1,
        };
        let doc = Json::parse(&status_json(8, &failed)).unwrap();
        assert_eq!(doc.get("error").unwrap().as_str(), Some("boom \"quoted\""));
    }

    #[test]
    fn dense_result_round_trips_bit_identically() {
        let mut mat = crate::linalg::dense::Mat64::zeros(2, 2);
        mat.set(0, 1, 0.123456789012345678);
        mat.set(1, 0, 0.123456789012345678);
        mat.set(1, 1, 1.0 / 3.0);
        let out = SinkOutput {
            data: SinkData::Dense(MiMatrix::from_mat(mat)),
            meta: SinkMeta {
                backend: Some("bulk-bitpack".into()),
                admission: Some(AdmissionReport {
                    estimated_bytes: 100,
                    queued_secs: 0.0,
                    priority: "batch",
                }),
                ..SinkMeta::default()
            },
        };
        let doc = Json::parse(&result_json(3, &out)).unwrap();
        let result = doc.get("result").unwrap();
        assert_eq!(result.get("kind").unwrap().as_str(), Some("dense"));
        let rows = result.get("rows").unwrap().as_arr().unwrap();
        // shortest round-trip Display -> parse reproduces the exact f64
        assert_eq!(rows[0].as_arr().unwrap()[1].as_f64(), Some(0.123456789012345678));
        assert_eq!(rows[1].as_arr().unwrap()[1].as_f64(), Some(1.0 / 3.0));
        let meta = doc.get("meta").unwrap();
        assert_eq!(meta.get("backend").unwrap().as_str(), Some("bulk-bitpack"));
        let adm = meta.get("admission").unwrap();
        assert_eq!(adm.get("estimated_bytes").unwrap().as_f64(), Some(100.0));
        assert_eq!(adm.get("priority").unwrap().as_str(), Some("batch"));
    }

    #[test]
    fn non_dense_results_render() {
        let pairs = vec![MiPair { i: 0, j: 3, mi: 0.5 }, MiPair { i: 1, j: 2, mi: 0.25 }];
        let topk = SinkOutput::from(SinkData::TopK(pairs.clone()));
        let doc = Json::parse(&result_json(1, &topk)).unwrap();
        let got = doc.get("result").unwrap().get("pairs").unwrap().as_arr().unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].get("i").unwrap().as_f64(), Some(0.0));
        assert_eq!(got[0].get("value").unwrap().as_f64(), Some(0.5));

        let sparse = SinkOutput::from(SinkData::Sparse(SparsePairs {
            threshold: 0.1,
            pvalue: Some(0.01),
            pairs,
        }));
        let doc = Json::parse(&result_json(2, &sparse)).unwrap();
        let result = doc.get("result").unwrap();
        assert_eq!(result.get("kind").unwrap().as_str(), Some("sparse"));
        assert_eq!(result.get("pvalue").unwrap().as_f64(), Some(0.01));

        let spilled = SinkOutput::from(SinkData::Spilled(crate::mi::sink::SpillInfo {
            dir: std::path::PathBuf::from("/tmp/tiles"),
            m: 10,
            tiles: 3,
            bytes: 800,
        }));
        let doc = Json::parse(&result_json(4, &spilled)).unwrap();
        let result = doc.get("result").unwrap();
        assert_eq!(result.get("kind").unwrap().as_str(), Some("spill"));
        assert!(result
            .get("manifest")
            .unwrap()
            .as_str()
            .unwrap()
            .ends_with("manifest.csv"));
    }

    #[test]
    fn error_json_escapes() {
        let doc = Json::parse(&error_json("bad \"thing\"")).unwrap();
        assert_eq!(doc.get("error").unwrap().as_str(), Some("bad \"thing\""));
    }
}
