//! The serving layer: an HTTP/JSON job server over the coordinator's
//! [`JobService`](crate::coordinator::service::JobService), plus the
//! versioned wire schema and signal handling it shares with the CLI's
//! `serve` subcommand.
//!
//! - [`wire`] — the `"v":1` request/response schema. One parse path
//!   from wire strings to typed specs; used by HTTP bodies, the
//!   `serve --stdin` line protocol, and CLI flag parsing.
//! - [`http`] — a dependency-free HTTP server on `std::net` (submit /
//!   status / result / cancel / metrics / drain).
//! - [`signal`] — SIGINT/SIGTERM latch driving graceful drain.
//!
//! Datasets are *server-registered*: jobs name a dataset the operator
//! mounted (`--dataset NAME=PATH` or `POST /v1/datasets`), so clients
//! never send bulk data through the control plane. [`open_source`] is
//! the one spot deciding how a path becomes a
//! [`ColumnSource`]: packed `.bmat` v2 streams from disk (the
//! out-of-core path prices only resident blocks), anything else loads
//! into memory once at registration.

pub mod http;
pub mod signal;
pub mod wire;

pub use http::{Server, ServerConfig};
pub use wire::{JobRequest, WIRE_VERSION};

use std::path::Path;
use std::sync::Arc;

use crate::data::colstore::{ColumnSource, InMemorySource, PackedFileSource};
use crate::data::io;
use crate::util::error::Result;

/// Open a dataset path as a [`ColumnSource`]: `.bmat` v2 files become
/// streaming [`PackedFileSource`]s (column blocks read on demand),
/// everything else ([`io::load`]-able CSV / legacy `.bmat`) is
/// materialized into an [`InMemorySource`].
pub fn open_source(path: &Path) -> Result<Arc<dyn ColumnSource>> {
    if io::is_bmat_v2(path)? {
        Ok(Arc::new(PackedFileSource::open(path)?))
    } else {
        Ok(Arc::new(InMemorySource::new(&io::load(path)?)))
    }
}
