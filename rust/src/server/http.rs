//! The HTTP/JSON front end over [`JobService`], hand-rolled on
//! `std::net::TcpListener` (the crate is dependency-free by design —
//! no tokio/hyper). Requests are small control messages, so the server
//! handles connections serially on the accept thread with short stream
//! timeouts; the actual compute runs on the job service's worker pool,
//! so a slow job never blocks status polls for longer than one
//! request/response exchange.
//!
//! Routes (all bodies are the v1 wire schema of [`super::wire`]):
//!
//! | method & path            | action                                  |
//! |--------------------------|-----------------------------------------|
//! | `GET  /healthz`          | liveness probe                          |
//! | `GET  /metrics`          | counters, timers, admission gate, cache |
//! | `GET  /v1/datasets`      | list registered datasets                |
//! | `POST /v1/datasets`      | register `{"v":1,"name":..,"path":..}`  |
//! | `POST /v1/jobs`          | submit a [`super::wire::JobRequest`]    |
//! | `GET  /v1/jobs/{id}`     | status + live progress                  |
//! | `GET  /v1/jobs/{id}/result` | fetch + consume the result (one-shot) |
//! | `POST /v1/jobs/{id}/cancel` | cancel a queued/running job          |
//! | `POST /v1/admin/drain`   | finish all jobs, then exit the loop     |
//!
//! Shutdown: the accept loop polls [`super::signal::requested`] (set by
//! SIGINT/SIGTERM) and the drain endpoint's flag between connections,
//! then drains the job service so in-flight jobs complete before
//! [`Server::run`] returns `Ok`.

use std::collections::BTreeMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::signal;
use super::wire::{self, JobRequest};
use crate::coordinator::service::{JobHandle, JobService};
use crate::data::colstore::ColumnSource;
use crate::util::error::{Error, Result};
use crate::util::json::{escape, Json};

/// How the server binds and sizes its job service.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// `ADDR:PORT` to listen on; port 0 picks a free port (the chosen
    /// address is printed as `serving on http://...` for scripts).
    pub listen: String,
    /// Job service worker threads (concurrent jobs).
    pub workers: usize,
    /// Admission queue slots beyond the running jobs.
    pub max_queued: usize,
    /// Aggregate resident-byte cap across concurrent jobs
    /// ([`crate::coordinator::admission`]); `None` = unbounded.
    pub memory_budget: Option<usize>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            listen: "127.0.0.1:8371".to_string(),
            workers: 2,
            max_queued: 64,
            memory_budget: None,
        }
    }
}

struct DatasetEntry {
    path: PathBuf,
    src: Arc<dyn ColumnSource>,
}

/// A bound-but-not-yet-running job server. [`Server::run`] executes the
/// accept loop on the calling thread until shutdown is requested.
pub struct Server {
    listener: TcpListener,
    addr: SocketAddr,
    svc: JobService,
    datasets: Mutex<BTreeMap<String, DatasetEntry>>,
    shutdown: AtomicBool,
}

impl Server {
    /// Bind the listen address and build the job service (with the
    /// admission byte gate when `memory_budget` is set).
    pub fn bind(cfg: &ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.listen)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let svc = match cfg.memory_budget {
            Some(budget) => JobService::with_budget(cfg.workers, cfg.max_queued, budget),
            None => JobService::new(cfg.workers, cfg.max_queued),
        };
        Ok(Server {
            listener,
            addr,
            svc,
            datasets: Mutex::new(BTreeMap::new()),
            shutdown: AtomicBool::new(false),
        })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The underlying job service (tests submit/poll directly).
    pub fn service(&self) -> &JobService {
        &self.svc
    }

    /// Request the accept loop to exit (same effect as the drain
    /// endpoint or a SIGTERM).
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Register a dataset under `name` so wire requests can target it.
    /// Packed `.bmat` v2 files stream from disk; anything else is
    /// loaded into memory once. Returns `(n_rows, n_cols)`.
    pub fn register_dataset(&self, name: &str, path: &Path) -> Result<(usize, usize)> {
        if name.is_empty() {
            return Err(Error::Parse("dataset name must not be empty".into()));
        }
        let src = super::open_source(path)?;
        let dims = (src.n_rows(), src.n_cols());
        self.datasets
            .lock()
            .unwrap()
            .insert(name.to_string(), DatasetEntry { path: path.to_path_buf(), src });
        Ok(dims)
    }

    /// Serve until SIGINT/SIGTERM or the drain endpoint fires, then
    /// drain the job service (in-flight jobs finish) and return.
    pub fn run(&self) -> Result<()> {
        // scripts scrape this line to learn the port when listening on :0
        println!("serving on http://{}", self.addr);
        loop {
            if self.shutdown.load(Ordering::SeqCst) || signal::requested() {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    if let Err(err) = self.handle_conn(stream) {
                        crate::info!("connection error: {err}");
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(e) => return Err(e.into()),
            }
        }
        crate::info!("draining {} tracked job(s) before exit", self.svc.job_count());
        self.svc.drain();
        Ok(())
    }

    fn handle_conn(&self, mut stream: TcpStream) -> Result<()> {
        // accepted sockets may inherit the listener's non-blocking mode
        // on some platforms; force blocking + bounded timeouts
        stream.set_nonblocking(false)?;
        stream.set_read_timeout(Some(Duration::from_secs(10)))?;
        stream.set_write_timeout(Some(Duration::from_secs(10)))?;
        let (status, body) = match read_request(&mut stream) {
            Ok((method, path, body)) => self.dispatch(&method, &path, &body),
            Err(err) => (400, wire::error_json(&err.to_string())),
        };
        write_response(&mut stream, status, &body)
    }

    fn dispatch(&self, method: &str, path: &str, body: &str) -> (u16, String) {
        let segs: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
        match (method, segs.as_slice()) {
            ("GET", ["healthz"]) => (200, format!("{{\"v\":1,\"ok\":true,\"draining\":{}}}", self.svc.is_draining())),
            ("GET", ["metrics"]) => (200, self.metrics_text()),
            ("GET", ["v1", "datasets"]) => (200, self.datasets_json()),
            ("POST", ["v1", "datasets"]) => self.handle_register(body),
            ("POST", ["v1", "jobs"]) => self.handle_submit(body),
            ("GET", ["v1", "jobs", id]) => self.with_job_id(id, |h| self.handle_status(h)),
            ("GET", ["v1", "jobs", id, "result"]) => {
                self.with_job_id(id, |h| self.handle_result(h))
            }
            ("POST", ["v1", "jobs", id, "cancel"]) => {
                self.with_job_id(id, |h| self.handle_cancel(h))
            }
            ("POST", ["v1", "admin", "drain"]) => {
                self.request_shutdown();
                (200, "{\"v\":1,\"draining\":true}".to_string())
            }
            _ => (404, wire::error_json(&format!("no route for {method} {path}"))),
        }
    }

    fn with_job_id(
        &self,
        raw: &str,
        f: impl FnOnce(JobHandle) -> (u16, String),
    ) -> (u16, String) {
        match raw.parse::<u64>() {
            Ok(id) => f(JobHandle::from_id(id)),
            Err(_) => (400, wire::error_json(&format!("bad job id '{raw}'"))),
        }
    }

    fn handle_register(&self, body: &str) -> (u16, String) {
        let parsed = Json::parse(body).and_then(|doc| {
            let name = doc
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Parse("register needs a \"name\" string".into()))?
                .to_string();
            let path = doc
                .get("path")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Parse("register needs a \"path\" string".into()))?
                .to_string();
            Ok((name, path))
        });
        let (name, path) = match parsed {
            Ok(v) => v,
            Err(err) => return (400, wire::error_json(&err.to_string())),
        };
        match self.register_dataset(&name, Path::new(&path)) {
            Ok((rows, cols)) => (
                200,
                format!(
                    "{{\"v\":1,\"name\":\"{}\",\"rows\":{rows},\"cols\":{cols}}}",
                    escape(&name)
                ),
            ),
            Err(err) => (400, wire::error_json(&err.to_string())),
        }
    }

    fn datasets_json(&self) -> String {
        let datasets = self.datasets.lock().unwrap();
        let items: Vec<String> = datasets
            .iter()
            .map(|(name, entry)| {
                format!(
                    "{{\"name\":\"{}\",\"path\":\"{}\",\"rows\":{},\"cols\":{},\
                     \"out_of_core\":{}}}",
                    escape(name),
                    escape(&entry.path.display().to_string()),
                    entry.src.n_rows(),
                    entry.src.n_cols(),
                    entry.src.out_of_core(),
                )
            })
            .collect();
        format!("{{\"v\":1,\"datasets\":[{}]}}", items.join(","))
    }

    fn handle_submit(&self, body: &str) -> (u16, String) {
        let req = match JobRequest::parse(body) {
            Ok(r) => r,
            Err(err) => return (400, wire::error_json(&err.to_string())),
        };
        let src = {
            let datasets = self.datasets.lock().unwrap();
            match datasets.get(&req.dataset) {
                Some(entry) => Arc::clone(&entry.src),
                None => {
                    let known: Vec<&str> = datasets.keys().map(String::as_str).collect();
                    return (
                        404,
                        wire::error_json(&format!(
                            "unknown dataset '{}' (registered: {})",
                            req.dataset,
                            if known.is_empty() { "none".to_string() } else { known.join(" ") }
                        )),
                    );
                }
            }
        };
        match self.svc.submit_source(src, req.spec) {
            Ok(handle) => match self.svc.info(handle) {
                Ok(info) => (202, wire::status_json(handle.id(), &info)),
                Err(err) => error_response(&err),
            },
            Err(err) => error_response(&err),
        }
    }

    fn handle_status(&self, handle: JobHandle) -> (u16, String) {
        match self.svc.info(handle) {
            Ok(info) => (200, wire::status_json(handle.id(), &info)),
            Err(err) => error_response(&err),
        }
    }

    fn handle_result(&self, handle: JobHandle) -> (u16, String) {
        match self.svc.take(handle) {
            Ok(out) => (200, wire::result_json(handle.id(), &out)),
            Err(err) => error_response(&err),
        }
    }

    fn handle_cancel(&self, handle: JobHandle) -> (u16, String) {
        match self.svc.cancel(handle) {
            Ok(()) => (
                200,
                format!("{{\"v\":1,\"job\":{},\"state\":\"cancelled\"}}", handle.id()),
            ),
            Err(err) => error_response(&err),
        }
    }

    /// Text metrics: the service's counters/timers, the admission
    /// gate's live state, and the shared substrate cache — per-tenant
    /// counters (`tenant:NAME:...`) appear among the plain counters.
    fn metrics_text(&self) -> String {
        let mut out = self.svc.metrics().report();
        let gate = self.svc.admission();
        match gate.budget_bytes() {
            Some(b) => out.push_str(&format!("admission budget_bytes = {b}\n")),
            None => out.push_str("admission budget_bytes = unbounded\n"),
        }
        out.push_str(&format!("admission inflight_bytes = {}\n", gate.inflight_bytes()));
        out.push_str(&format!("admission inflight_jobs = {}\n", gate.inflight_jobs()));
        out.push_str(&format!("admission peak_bytes = {}\n", gate.peak_bytes()));
        out.push_str(&format!("admission admitted = {}\n", gate.admitted()));
        out.push_str(&format!("admission waiting = {}\n", gate.waiting()));
        let cache = self.svc.shared_cache().stats();
        out.push_str(&format!("cache shared hits = {}\n", cache.hits));
        out.push_str(&format!("cache shared misses = {}\n", cache.misses));
        out.push_str(&format!("cache shared evictions = {}\n", cache.evictions));
        out.push_str(&format!("cache shared prefetched = {}\n", cache.prefetched));
        out.push_str(&format!("cache shared inserted_bytes = {}\n", cache.inserted_bytes));
        out.push_str(&format!("cache shared stall_secs = {}\n", cache.stall_secs));
        let tiles = self.svc.shared_tile_cache().stats();
        out.push_str(&format!("cache tile hits = {}\n", tiles.hits));
        out.push_str(&format!("cache tile misses = {}\n", tiles.misses));
        out.push_str(&format!("cache tile evictions = {}\n", tiles.evictions));
        out.push_str(&format!("cache tile inserted_bytes = {}\n", tiles.inserted_bytes));
        out
    }
}

/// Map a service error to an HTTP status + error envelope.
fn error_response(err: &Error) -> (u16, String) {
    let status = match err {
        Error::JobCancelled(_) => 410,
        Error::JobFailed(_) => 500,
        Error::JobTerminal(_) => 409,
        Error::Parse(_) => 400,
        Error::Coordinator(msg) => {
            if msg.contains("unknown job") {
                404
            } else if msg.contains("in flight") {
                409
            } else if msg.contains("draining") || msg.contains("queue full") {
                503
            } else {
                400
            }
        }
        _ => 500,
    };
    (status, wire::error_json(&err.to_string()))
}

const MAX_HEADER_BYTES: usize = 64 * 1024;
const MAX_BODY_BYTES: usize = 16 * 1024 * 1024;

/// Read one request: `(method, path, body)`. Query strings are
/// stripped; the body is sized by `Content-Length` (no chunked
/// encoding — every client we speak to sends sized bodies).
fn read_request(stream: &mut TcpStream) -> Result<(String, String, String)> {
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEADER_BYTES {
            return Err(Error::Parse("http header too large".into()));
        }
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(Error::Parse("connection closed mid-header".into()));
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let header = String::from_utf8_lossy(&buf[..header_end]).into_owned();
    let mut lines = header.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let raw_path = parts.next().unwrap_or("");
    let path = raw_path.split('?').next().unwrap_or("").to_string();
    let mut content_length = 0usize;
    for line in lines {
        if let Some((key, value)) = line.split_once(':') {
            if key.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .map_err(|_| Error::Parse("bad Content-Length".into()))?;
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(Error::Parse("request body too large".into()));
    }
    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(Error::Parse("connection closed mid-body".into()));
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    Ok((method, path, String::from_utf8_lossy(&body).into_owned()))
}

fn write_response(stream: &mut TcpStream, status: u16, body: &str) -> Result<()> {
    let reason = match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        409 => "Conflict",
        410 => "Gone",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Error",
    };
    let content_type = if body.starts_with('{') || body.starts_with('[') {
        "application/json"
    } else {
        "text/plain; charset=utf-8"
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}
