//! Minimal SIGINT/SIGTERM latch for graceful drain, with no signal
//! crate: on unix we register a trivial `extern "C"` handler through
//! libc's `signal(2)` (already linked — std depends on libc) that flips
//! one `AtomicBool`. The serve accept loop polls [`requested`] between
//! connections and drains the job service before exiting, so a
//! `kill -TERM` produces exit code 0 with no job left mid-flight.
//!
//! Atomics are async-signal-safe; the handler does nothing else. On
//! non-unix targets [`install`] is a no-op and [`requested`] only
//! reflects in-process shutdown requests via [`trigger`].

use std::sync::atomic::{AtomicBool, Ordering};

static SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_signal(_signum: i32) {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

#[cfg(unix)]
extern "C" {
    fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
}

/// Latch SIGINT (2) and SIGTERM (15) into the shutdown flag.
#[cfg(unix)]
pub fn install() {
    unsafe {
        signal(2, on_signal);
        signal(15, on_signal);
    }
}

/// No signals to latch on this platform; [`trigger`] still works.
#[cfg(not(unix))]
pub fn install() {}

/// Has a shutdown been requested (signal or [`trigger`])?
pub fn requested() -> bool {
    SHUTDOWN.load(Ordering::SeqCst)
}

/// Request shutdown from inside the process (the `/v1/admin/drain`
/// endpoint and tests use this; signals use the same flag).
pub fn trigger() {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Clear the flag (test isolation only — the serve loop never resets).
pub fn reset() {
    SHUTDOWN.store(false, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trigger_sets_and_reset_clears() {
        reset();
        assert!(!requested());
        trigger();
        assert!(requested());
        reset();
        assert!(!requested());
    }
}
