//! Run configuration + a minimal TOML-subset parser (the offline
//! registry has no `serde`/`toml`). Supported syntax: `[section]`
//! headers, `key = value` with string / integer / float / boolean
//! values, `#` comments.

use crate::mi::backend::Backend;
use crate::mi::measure::CombineKind;
use crate::util::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Parsed key-value view of a TOML-subset document; keys are
/// `section.key` (or bare `key` before any section header).
#[derive(Clone, Debug, Default)]
pub struct RawConfig {
    values: BTreeMap<String, String>,
}

impl RawConfig {
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') || line.len() < 3 {
                    return Err(Error::Config(format!("line {}: bad section", lineno + 1)));
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected key = value", lineno + 1))
            })?;
            let key = key.trim();
            let mut value = value.trim().to_string();
            if value.starts_with('"') && value.ends_with('"') && value.len() >= 2 {
                value = value[1..value.len() - 1].to_string();
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            values.insert(full, value);
        }
        Ok(RawConfig { values })
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.typed(key, "integer", |s| s.parse().ok())
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.typed(key, "float", |s| s.parse().ok())
    }

    pub fn get_bool(&self, key: &str) -> Result<Option<bool>> {
        self.typed(key, "boolean", |s| match s {
            "true" => Some(true),
            "false" => Some(false),
            _ => None,
        })
    }

    fn typed<T>(&self, key: &str, ty: &str, parse: impl Fn(&str) -> Option<T>) -> Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(s) => parse(s)
                .map(Some)
                .ok_or_else(|| Error::Config(format!("{key}: expected {ty}, got '{s}'"))),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // no escaped-quote handling needed for our subset: cut at # outside quotes
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Typed run configuration for the compute/serve paths.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Backend to compute with.
    pub backend: Backend,
    /// Association measure the combine stage computes (MI by default;
    /// see [`crate::mi::measure::CombineKind`]).
    pub measure: CombineKind,
    /// Worker threads for parallel backends and the coordinator.
    pub workers: usize,
    /// Column-block size for the blockwise plan (0 = monolithic if it fits).
    pub block_cols: usize,
    /// Memory budget in bytes for the planner (0 = unlimited).
    pub memory_budget: usize,
    /// Per-task Gram latency target (seconds) for probe-throughput
    /// block sizing (`--task-latency`; see
    /// [`crate::coordinator::planner::throughput_block`]).
    pub task_latency_secs: f64,
    /// Block-substrate cache budget in bytes (`--cache-budget` /
    /// `run.cache_bytes`). `None` = auto: carve half the memory budget
    /// for out-of-core sources, no cache for in-memory ones. `Some(0)`
    /// disables the cache.
    pub cache_bytes: Option<usize>,
    /// Tasks of readahead for the executor's prefetch stage
    /// (`--readahead` / `run.readahead`; only active when a cache is).
    pub readahead: usize,
    /// Artifact directory override (None = default discovery).
    pub artifacts_dir: Option<String>,
    /// Consult the content-addressed Gram-tile cache (`--tiles` /
    /// `run.tiles`): finished tiles persist under `BULKMI_CACHE_DIR`
    /// (or a temp dir) keyed by input-block fingerprints, so re-runs
    /// over the same data skip the Gram stage. Off by default.
    pub tiles: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            backend: Backend::BulkBitpack,
            measure: CombineKind::Mi,
            workers: crate::util::threadpool::default_workers(),
            block_cols: 0,
            memory_budget: 0,
            task_latency_secs: crate::coordinator::planner::DEFAULT_TASK_LATENCY_SECS,
            cache_bytes: None,
            readahead: 1,
            artifacts_dir: None,
            tiles: false,
        }
    }
}

impl RunConfig {
    /// Build from a parsed document; unknown keys under `run.` are errors
    /// (typo protection), other sections are left to their consumers.
    pub fn from_raw(raw: &RawConfig) -> Result<Self> {
        let mut cfg = RunConfig::default();
        for key in raw.keys() {
            if let Some(name) = key.strip_prefix("run.") {
                match name {
                    "backend" | "measure" | "workers" | "block_cols" | "memory_budget"
                    | "task_latency_secs" | "cache_bytes" | "readahead" | "artifacts_dir"
                    | "tiles" => {}
                    other => {
                        return Err(Error::Config(format!("unknown key run.{other}")));
                    }
                }
            }
        }
        if let Some(b) = raw.get("run.backend") {
            cfg.backend = Backend::parse(b)
                .ok_or_else(|| Error::Config(format!("unknown backend '{b}'")))?;
        }
        if let Some(m) = raw.get("run.measure") {
            cfg.measure = CombineKind::parse(m)
                .ok_or_else(|| Error::Config(format!("unknown measure '{m}'")))?;
        }
        if let Some(w) = raw.get_usize("run.workers")? {
            cfg.workers = w.max(1);
        }
        if let Some(b) = raw.get_usize("run.block_cols")? {
            cfg.block_cols = b;
        }
        if let Some(m) = raw.get_usize("run.memory_budget")? {
            cfg.memory_budget = m;
        }
        if let Some(t) = raw.get_f64("run.task_latency_secs")? {
            if !t.is_finite() || t <= 0.0 {
                return Err(Error::Config(format!(
                    "run.task_latency_secs must be a positive number, got {t}"
                )));
            }
            cfg.task_latency_secs = t;
        }
        if let Some(c) = raw.get_usize("run.cache_bytes")? {
            cfg.cache_bytes = Some(c);
        }
        if let Some(r) = raw.get_usize("run.readahead")? {
            cfg.readahead = r;
        }
        if let Some(d) = raw.get("run.artifacts_dir") {
            cfg.artifacts_dir = Some(d.to_string());
        }
        if let Some(t) = raw.get_bool("run.tiles")? {
            cfg.tiles = t;
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::from_raw(&RawConfig::load(path)?)
    }
}

/// Typed `[serve]` section for `bulkmi serve --listen` deployments
/// (the CLI maps it onto [`crate::server::ServerConfig`]); unknown
/// `serve.` keys are errors, same typo protection as [`RunConfig`].
/// A `[run]` and `[serve]` section can share one file — each consumer
/// reads only its own section.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeConfig {
    /// `ADDR:PORT` to listen on (port 0 picks a free port).
    pub listen: String,
    /// Job service worker threads (concurrent jobs).
    pub workers: usize,
    /// Admission queue slots beyond the running jobs.
    pub max_queued: usize,
    /// Aggregate resident-byte cap across concurrent jobs; `None` (or
    /// an explicit 0) = unbounded.
    pub memory_budget: Option<usize>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            listen: "127.0.0.1:8371".to_string(),
            workers: 2,
            max_queued: 64,
            memory_budget: None,
        }
    }
}

impl ServeConfig {
    /// Build from a parsed document; unknown keys under `serve.` are
    /// errors.
    pub fn from_raw(raw: &RawConfig) -> Result<Self> {
        let mut cfg = ServeConfig::default();
        for key in raw.keys() {
            if let Some(name) = key.strip_prefix("serve.") {
                match name {
                    "listen" | "workers" | "max_queued" | "memory_budget" => {}
                    other => {
                        return Err(Error::Config(format!("unknown key serve.{other}")));
                    }
                }
            }
        }
        if let Some(l) = raw.get("serve.listen") {
            cfg.listen = l.to_string();
        }
        if let Some(w) = raw.get_usize("serve.workers")? {
            cfg.workers = w.max(1);
        }
        if let Some(q) = raw.get_usize("serve.max_queued")? {
            cfg.max_queued = q.max(1);
        }
        if let Some(b) = raw.get_usize("serve.memory_budget")? {
            cfg.memory_budget = if b == 0 { None } else { Some(b) };
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::from_raw(&RawConfig::load(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let raw = RawConfig::parse(
            "top = 1\n\
             [run]\n\
             backend = \"bulk-opt\"   # comment\n\
             workers = 4\n\
             flag = true\n\
             ratio = 0.5\n",
        )
        .unwrap();
        assert_eq!(raw.get("top"), Some("1"));
        assert_eq!(raw.get("run.backend"), Some("bulk-opt"));
        assert_eq!(raw.get_usize("run.workers").unwrap(), Some(4));
        assert_eq!(raw.get_bool("run.flag").unwrap(), Some(true));
        assert_eq!(raw.get_f64("run.ratio").unwrap(), Some(0.5));
        assert_eq!(raw.get("run.missing"), None);
    }

    #[test]
    fn type_errors_are_reported() {
        let raw = RawConfig::parse("[run]\nworkers = banana\n").unwrap();
        assert!(raw.get_usize("run.workers").is_err());
    }

    #[test]
    fn syntax_errors() {
        assert!(RawConfig::parse("[unclosed\n").is_err());
        assert!(RawConfig::parse("novalue\n").is_err());
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let raw = RawConfig::parse("k = \"a#b\"\n").unwrap();
        assert_eq!(raw.get("k"), Some("a#b"));
    }

    #[test]
    fn run_config_from_raw() {
        let raw = RawConfig::parse(
            "[run]\nbackend = \"pairwise\"\nworkers = 2\nblock_cols = 256\n",
        )
        .unwrap();
        let cfg = RunConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.backend, Backend::Pairwise);
        assert_eq!(cfg.workers, 2);
        assert_eq!(cfg.block_cols, 256);
        assert_eq!(cfg.measure, CombineKind::Mi, "measure defaults to mi");
    }

    #[test]
    fn measure_key_parses_and_rejects() {
        let raw = RawConfig::parse("[run]\nmeasure = \"jaccard\"\n").unwrap();
        assert_eq!(RunConfig::from_raw(&raw).unwrap().measure, CombineKind::Jaccard);
        let bad = RawConfig::parse("[run]\nmeasure = \"pearson\"\n").unwrap();
        assert!(RunConfig::from_raw(&bad).is_err());
    }

    #[test]
    fn task_latency_parses_and_validates() {
        let raw = RawConfig::parse("[run]\ntask_latency_secs = 0.5\n").unwrap();
        assert_eq!(RunConfig::from_raw(&raw).unwrap().task_latency_secs, 0.5);
        let default = RawConfig::parse("[run]\nworkers = 1\n").unwrap();
        assert_eq!(
            RunConfig::from_raw(&default).unwrap().task_latency_secs,
            crate::coordinator::planner::DEFAULT_TASK_LATENCY_SECS
        );
        for bad in ["0", "-1.5", "nan"] {
            let raw =
                RawConfig::parse(&format!("[run]\ntask_latency_secs = {bad}\n")).unwrap();
            assert!(RunConfig::from_raw(&raw).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn cache_and_readahead_keys_parse() {
        let raw = RawConfig::parse("[run]\ncache_bytes = 1048576\nreadahead = 3\n").unwrap();
        let cfg = RunConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.cache_bytes, Some(1048576));
        assert_eq!(cfg.readahead, 3);
        // explicit zero disables the cache (distinct from unset = auto)
        let raw = RawConfig::parse("[run]\ncache_bytes = 0\nreadahead = 0\n").unwrap();
        let cfg = RunConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.cache_bytes, Some(0));
        assert_eq!(cfg.readahead, 0);
        let defaults = RunConfig::default();
        assert_eq!(defaults.cache_bytes, None);
        assert_eq!(defaults.readahead, 1);
    }

    #[test]
    fn tiles_key_parses_and_defaults_off() {
        assert!(!RunConfig::default().tiles);
        let raw = RawConfig::parse("[run]\ntiles = true\n").unwrap();
        assert!(RunConfig::from_raw(&raw).unwrap().tiles);
        let bad = RawConfig::parse("[run]\ntiles = yes\n").unwrap();
        assert!(RunConfig::from_raw(&bad).is_err());
    }

    #[test]
    fn unknown_run_key_rejected() {
        let raw = RawConfig::parse("[run]\nbakcend = \"xla\"\n").unwrap();
        assert!(RunConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn bad_backend_rejected() {
        let raw = RawConfig::parse("[run]\nbackend = \"warp-drive\"\n").unwrap();
        assert!(RunConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn serve_config_from_raw() {
        let raw = RawConfig::parse(
            "[serve]\nlisten = \"0.0.0.0:9000\"\nworkers = 4\nmax_queued = 8\n\
             memory_budget = 1048576\n",
        )
        .unwrap();
        let cfg = ServeConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.listen, "0.0.0.0:9000");
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.max_queued, 8);
        assert_eq!(cfg.memory_budget, Some(1 << 20));
        // zero means unbounded, same convention as the run section
        let raw = RawConfig::parse("[serve]\nmemory_budget = 0\n").unwrap();
        assert_eq!(ServeConfig::from_raw(&raw).unwrap().memory_budget, None);
    }

    #[test]
    fn serve_and_run_sections_share_a_file() {
        let raw = RawConfig::parse(
            "[run]\nworkers = 3\n[serve]\nlisten = \"127.0.0.1:0\"\n",
        )
        .unwrap();
        assert_eq!(RunConfig::from_raw(&raw).unwrap().workers, 3);
        assert_eq!(ServeConfig::from_raw(&raw).unwrap().listen, "127.0.0.1:0");
    }

    #[test]
    fn unknown_serve_key_rejected() {
        let raw = RawConfig::parse("[serve]\nlisten_addr = \"x\"\n").unwrap();
        assert!(ServeConfig::from_raw(&raw).is_err());
    }
}
