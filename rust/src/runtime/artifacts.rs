//! Artifact registry: discovers the AOT-compiled HLO artifacts through
//! `artifacts/manifest.txt` and answers "which bucket serves shape
//! (n, m)?" queries for the runtime and coordinator.

use crate::util::error::{Error, Result};
use std::path::{Path, PathBuf};

/// What computation an artifact implements (see `python/compile/aot.py`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// `(D[R,C], n[1]) -> MI[C,C]` — fused optimized bulk MI.
    Mi,
    /// `D[R,C] -> (G11[C,C], colsums[C])` — partial Gram for row chunks.
    Gram,
    /// `(Da[R,B], Db[R,B]) -> (G[B,B], ca[B], cb[B])` — cross-block Gram.
    Xgram,
    /// `(G11[C,C], ca[C], cb[C], n[1]) -> MI[C,C]` — combine from counts.
    Combine,
    /// `D[R,C] -> MI[C,C]` — Section-2 basic algorithm (ablation only).
    MiBasic,
}

impl ArtifactKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "mi" => Some(ArtifactKind::Mi),
            "gram" => Some(ArtifactKind::Gram),
            "xgram" => Some(ArtifactKind::Xgram),
            "combine" => Some(ArtifactKind::Combine),
            "mi_basic" => Some(ArtifactKind::MiBasic),
            _ => None,
        }
    }
}

/// Which implementation variant the artifact was lowered from.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Impl {
    /// XLA-native dot for the Gram — the request-path default.
    Xla,
    /// Interpret-mode Pallas grid — correctness/ablation path.
    Pallas,
}

impl Impl {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "xla" => Some(Impl::Xla),
            "pallas" => Some(Impl::Pallas),
            _ => None,
        }
    }
}

/// One artifact's metadata from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: ArtifactKind,
    /// Bucket rows (0 for `Combine`, which is row-count independent).
    pub rows: usize,
    pub cols: usize,
    pub impl_: Impl,
    pub path: PathBuf,
}

/// Registry over a directory of artifacts + manifest.
#[derive(Clone, Debug)]
pub struct ArtifactRegistry {
    dir: PathBuf,
    artifacts: Vec<ArtifactMeta>,
}

/// Default artifact directory: `$BULKMI_ARTIFACTS` or `artifacts/`
/// relative to the working directory.
pub fn default_dir() -> PathBuf {
    std::env::var("BULKMI_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
        PathBuf::from("artifacts")
    })
}

impl ArtifactRegistry {
    /// Load the manifest from `dir` (missing artifact files are dropped
    /// with a warning so partially-built trees still work).
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest).map_err(|e| {
            Error::NoArtifact(format!(
                "cannot read {} ({e}); run `make artifacts` first",
                manifest.display()
            ))
        })?;
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            if fields.len() != 6 {
                return Err(Error::Parse(format!(
                    "manifest line {}: expected 6 fields, got {}",
                    lineno + 1,
                    fields.len()
                )));
            }
            let kind = ArtifactKind::parse(fields[1])
                .ok_or_else(|| Error::Parse(format!("unknown artifact kind {}", fields[1])))?;
            let impl_ = Impl::parse(fields[4])
                .ok_or_else(|| Error::Parse(format!("unknown impl {}", fields[4])))?;
            let rows: usize = fields[2]
                .parse()
                .map_err(|_| Error::Parse(format!("bad rows {}", fields[2])))?;
            let cols: usize = fields[3]
                .parse()
                .map_err(|_| Error::Parse(format!("bad cols {}", fields[3])))?;
            let path = dir.join(fields[5]);
            if !path.exists() {
                crate::warn_!("manifest names missing artifact {}", path.display());
                continue;
            }
            artifacts.push(ArtifactMeta {
                name: fields[0].to_string(),
                kind,
                rows,
                cols,
                impl_,
                path,
            });
        }
        Ok(ArtifactRegistry { dir: dir.to_path_buf(), artifacts })
    }

    /// Load from the default directory.
    pub fn load_default() -> Result<Self> {
        Self::load(&default_dir())
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn all(&self) -> &[ArtifactMeta] {
        &self.artifacts
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Smallest bucket of `kind`/`impl_` that fits `rows x cols`
    /// (padding up). "Smallest" minimizes padded cell count.
    pub fn find_bucket(
        &self,
        kind: ArtifactKind,
        impl_: Impl,
        rows: usize,
        cols: usize,
    ) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == kind
                    && a.impl_ == impl_
                    && (kind == ArtifactKind::Combine || a.rows >= rows)
                    && a.cols >= cols
            })
            .min_by_key(|a| a.rows.max(1) * a.cols)
    }

    /// Largest row capacity among `kind`/`impl_` buckets with cols >= `cols`
    /// (used to size row chunks).
    pub fn max_rows_for_cols(&self, kind: ArtifactKind, impl_: Impl, cols: usize) -> Option<usize> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind && a.impl_ == impl_ && a.cols >= cols)
            .map(|a| a.rows)
            .max()
    }

    /// Largest column capacity of any bucket of `kind`/`impl_`.
    pub fn max_cols(&self, kind: ArtifactKind, impl_: Impl) -> Option<usize> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind && a.impl_ == impl_)
            .map(|a| a.cols)
            .max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), body).unwrap();
    }

    fn touch(dir: &Path, name: &str) {
        std::fs::write(dir.join(name), "HloModule fake").unwrap();
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("bulkmi-art-{}-{name}", std::process::id()))
    }

    #[test]
    fn parses_manifest_and_selects_buckets() {
        let dir = tmp("sel");
        write_manifest(
            &dir,
            "# comment\n\
             mi_xla_1024x128 mi 1024 128 xla mi_xla_1024x128.hlo.txt\n\
             mi_xla_2048x256 mi 2048 256 xla mi_xla_2048x256.hlo.txt\n\
             combine_xla_128 combine 0 128 xla combine_xla_128.hlo.txt\n",
        );
        touch(&dir, "mi_xla_1024x128.hlo.txt");
        touch(&dir, "mi_xla_2048x256.hlo.txt");
        touch(&dir, "combine_xla_128.hlo.txt");
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert_eq!(reg.all().len(), 3);

        let b = reg.find_bucket(ArtifactKind::Mi, Impl::Xla, 1000, 100).unwrap();
        assert_eq!(b.name, "mi_xla_1024x128"); // smallest that fits
        let b = reg.find_bucket(ArtifactKind::Mi, Impl::Xla, 1025, 100).unwrap();
        assert_eq!(b.name, "mi_xla_2048x256");
        assert!(reg.find_bucket(ArtifactKind::Mi, Impl::Xla, 9999, 100).is_none());
        assert!(reg.find_bucket(ArtifactKind::Mi, Impl::Pallas, 10, 10).is_none());

        // combine buckets ignore rows
        let c = reg.find_bucket(ArtifactKind::Combine, Impl::Xla, 123_456, 100).unwrap();
        assert_eq!(c.name, "combine_xla_128");
    }

    #[test]
    fn missing_files_are_dropped() {
        let dir = tmp("drop");
        write_manifest(&dir, "ghost mi 8 8 xla ghost.hlo.txt\n");
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert!(reg.all().is_empty());
    }

    #[test]
    fn malformed_lines_error() {
        let dir = tmp("bad");
        write_manifest(&dir, "too few fields\n");
        assert!(ArtifactRegistry::load(&dir).is_err());
        write_manifest(&dir, "x unknownkind 8 8 xla f.hlo.txt\n");
        assert!(ArtifactRegistry::load(&dir).is_err());
    }

    #[test]
    fn missing_manifest_is_noartifact() {
        let err = ArtifactRegistry::load(&tmp("missing-nothing")).unwrap_err();
        assert!(matches!(err, Error::NoArtifact(_)));
    }

    #[test]
    fn capacity_queries() {
        let dir = tmp("cap");
        write_manifest(
            &dir,
            "gram_xla_2048x128 gram 2048 128 xla g1.hlo.txt\n\
             gram_xla_4096x1024 gram 4096 1024 xla g2.hlo.txt\n",
        );
        touch(&dir, "g1.hlo.txt");
        touch(&dir, "g2.hlo.txt");
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert_eq!(reg.max_rows_for_cols(ArtifactKind::Gram, Impl::Xla, 100), Some(4096));
        assert_eq!(reg.max_rows_for_cols(ArtifactKind::Gram, Impl::Xla, 2000), None);
        assert_eq!(reg.max_rows_for_cols(ArtifactKind::Gram, Impl::Xla, 1000), Some(4096));
        assert_eq!(reg.max_cols(ArtifactKind::Gram, Impl::Xla), Some(1024));
        assert_eq!(reg.max_cols(ArtifactKind::Xgram, Impl::Xla), None);
    }
}
