//! The PJRT client wrapper: compile cache + typed execution entry points
//! for each artifact kind.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! All artifacts are lowered with `return_tuple=True`, so results are
//! unwrapped with `to_tuple*`.
//!
//! Thread-affinity note: `XlaRuntime` is deliberately not `Sync`; each
//! coordinator worker that needs XLA owns its own runtime (executables
//! are cached per runtime). The PJRT CPU client itself multithreads its
//! compute internally.

use super::artifacts::{ArtifactKind, ArtifactMeta, ArtifactRegistry, Impl};
use crate::util::error::{Error, Result};
use std::cell::RefCell;
use std::collections::HashMap;

/// Owns the PJRT client, the artifact registry, and a name → compiled
/// executable cache.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    registry: ArtifactRegistry,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl XlaRuntime {
    /// Create a CPU runtime over the given artifact registry.
    pub fn new(registry: ArtifactRegistry) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e}")))?;
        Ok(XlaRuntime { client, registry, cache: RefCell::new(HashMap::new()) })
    }

    /// Create over the default artifact directory.
    pub fn load_default() -> Result<Self> {
        Self::new(ArtifactRegistry::load_default()?)
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    fn get_or_compile(&self, meta: &ArtifactMeta) -> Result<()> {
        if self.cache.borrow().contains_key(&meta.name) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(&meta.path)
            .map_err(|e| Error::Runtime(format!("parse {}: {e}", meta.path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {}: {e}", meta.name)))?;
        self.cache.borrow_mut().insert(meta.name.clone(), exe);
        Ok(())
    }

    /// Execute a cached executable with literal args, returning the
    /// result tuple as a Vec of literals.
    fn execute(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let cache = self.cache.borrow();
        let exe = cache
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("executable {name} not compiled")))?;
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| Error::Runtime(format!("execute {name}: {e}")))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetch {name}: {e}")))?;
        lit.to_tuple().map_err(|e| Error::Runtime(format!("untuple {name}: {e}")))
    }

    fn matrix_literal(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        xla::Literal::vec1(data)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| Error::Runtime(format!("reshape literal: {e}")))
    }

    fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| Error::Runtime(format!("literal to_vec: {e}")))
    }

    /// Pad a row-major f32 matrix with zeros up to (rp, cp).
    fn pad(data: &[f32], rows: usize, cols: usize, rp: usize, cp: usize) -> Vec<f32> {
        if rows == rp && cols == cp {
            return data.to_vec();
        }
        let mut out = vec![0.0f32; rp * cp];
        for r in 0..rows {
            out[r * cp..r * cp + cols].copy_from_slice(&data[r * cols..(r + 1) * cols]);
        }
        out
    }

    /// Pick the bucket for (kind, impl, rows, cols) or a descriptive error.
    pub fn bucket(
        &self,
        kind: ArtifactKind,
        impl_: Impl,
        rows: usize,
        cols: usize,
    ) -> Result<ArtifactMeta> {
        self.registry.find_bucket(kind, impl_, rows, cols).cloned().ok_or_else(|| {
            Error::NoArtifact(format!(
                "no {kind:?}/{impl_:?} bucket fits {rows}x{cols} (max cols {:?})",
                self.registry.max_cols(kind, impl_)
            ))
        })
    }

    /// Fused MI: pad `d` (row-major n x m) into the chosen bucket, pass
    /// the true `n`, slice the m x m result out of the padded output.
    pub fn run_mi_fused(
        &self,
        impl_: Impl,
        d: &[f32],
        n: usize,
        m: usize,
    ) -> Result<Vec<f64>> {
        let meta = self.bucket(ArtifactKind::Mi, impl_, n, m)?;
        self.get_or_compile(&meta)?;
        let padded = Self::pad(d, n, m, meta.rows, meta.cols);
        let d_lit = Self::matrix_literal(&padded, meta.rows, meta.cols)?;
        let n_lit = xla::Literal::vec1(&[n as f32]);
        let out = self.execute(&meta.name, &[d_lit, n_lit])?;
        let flat = Self::to_vec_f32(&out[0])?;
        // slice top-left m x m out of cols x cols
        let c = meta.cols;
        let mut mi = vec![0.0f64; m * m];
        for i in 0..m {
            for j in 0..m {
                mi[i * m + j] = flat[i * c + j] as f64;
            }
        }
        Ok(mi)
    }

    /// Partial Gram of one row chunk: returns (g11 [m x m], colsums [m])
    /// sliced to the true column count.
    pub fn run_gram(
        &self,
        impl_: Impl,
        d: &[f32],
        n: usize,
        m: usize,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let meta = self.bucket(ArtifactKind::Gram, impl_, n, m)?;
        self.get_or_compile(&meta)?;
        let padded = Self::pad(d, n, m, meta.rows, meta.cols);
        let d_lit = Self::matrix_literal(&padded, meta.rows, meta.cols)?;
        let out = self.execute(&meta.name, &[d_lit])?;
        let g_flat = Self::to_vec_f32(&out[0])?;
        let c_flat = Self::to_vec_f32(&out[1])?;
        let c = meta.cols;
        let mut g = vec![0.0f64; m * m];
        for i in 0..m {
            for j in 0..m {
                g[i * m + j] = g_flat[i * c + j] as f64;
            }
        }
        let colsums = c_flat[..m].iter().map(|&v| v as f64).collect();
        Ok((g, colsums))
    }

    /// Cross-block partial Gram: (g [ma x mb], ca [ma], cb [mb]).
    #[allow(clippy::too_many_arguments)]
    pub fn run_xgram(
        &self,
        impl_: Impl,
        da: &[f32],
        db: &[f32],
        n: usize,
        ma: usize,
        mb: usize,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
        let meta = self.bucket(ArtifactKind::Xgram, impl_, n, ma.max(mb))?;
        self.get_or_compile(&meta)?;
        let pa = Self::pad(da, n, ma, meta.rows, meta.cols);
        let pb = Self::pad(db, n, mb, meta.rows, meta.cols);
        let a_lit = Self::matrix_literal(&pa, meta.rows, meta.cols)?;
        let b_lit = Self::matrix_literal(&pb, meta.rows, meta.cols)?;
        let out = self.execute(&meta.name, &[a_lit, b_lit])?;
        let g_flat = Self::to_vec_f32(&out[0])?;
        let ca_flat = Self::to_vec_f32(&out[1])?;
        let cb_flat = Self::to_vec_f32(&out[2])?;
        let c = meta.cols;
        let mut g = vec![0.0f64; ma * mb];
        for i in 0..ma {
            for j in 0..mb {
                g[i * mb + j] = g_flat[i * c + j] as f64;
            }
        }
        Ok((
            g,
            ca_flat[..ma].iter().map(|&v| v as f64).collect(),
            cb_flat[..mb].iter().map(|&v| v as f64).collect(),
        ))
    }

    /// MI combine from accumulated counts: (g11 [m x m], ca, cb, n) → MI.
    pub fn run_combine(
        &self,
        impl_: Impl,
        g11: &[f64],
        ca: &[f64],
        cb: &[f64],
        n: f64,
        m: usize,
    ) -> Result<Vec<f64>> {
        let meta = self.bucket(ArtifactKind::Combine, impl_, 0, m)?;
        self.get_or_compile(&meta)?;
        let c = meta.cols;
        let g32: Vec<f32> = g11.iter().map(|&v| v as f32).collect();
        let g_pad = Self::pad(&g32, m, m, c, c);
        let mut ca_pad = vec![0.0f32; c];
        let mut cb_pad = vec![0.0f32; c];
        for i in 0..m {
            ca_pad[i] = ca[i] as f32;
            cb_pad[i] = cb[i] as f32;
        }
        let out = self.execute(
            &meta.name,
            &[
                Self::matrix_literal(&g_pad, c, c)?,
                xla::Literal::vec1(&ca_pad),
                xla::Literal::vec1(&cb_pad),
                xla::Literal::vec1(&[n as f32]),
            ],
        )?;
        let flat = Self::to_vec_f32(&out[0])?;
        let mut mi = vec![0.0f64; m * m];
        for i in 0..m {
            for j in 0..m {
                mi[i * m + j] = flat[i * c + j] as f64;
            }
        }
        Ok(mi)
    }

    /// Section-2 basic MI (ablation): no row-count arg; n must equal the
    /// bucket rows for exact results, so callers should only use this on
    /// exact bucket shapes.
    pub fn run_mi_basic(&self, d: &[f32], n: usize, m: usize) -> Result<Vec<f64>> {
        let meta = self.bucket(ArtifactKind::MiBasic, Impl::Xla, n, m)?;
        if meta.rows != n {
            return Err(Error::Shape(format!(
                "mi_basic artifact requires exact rows {} (got {n}); \
                 zero-padded rows are NOT exact for the Section-2 form",
                meta.rows
            )));
        }
        self.get_or_compile(&meta)?;
        let padded = Self::pad(d, n, m, meta.rows, meta.cols);
        let d_lit = Self::matrix_literal(&padded, meta.rows, meta.cols)?;
        let out = self.execute(&meta.name, &[d_lit])?;
        let flat = Self::to_vec_f32(&out[0])?;
        let c = meta.cols;
        let mut mi = vec![0.0f64; m * m];
        for i in 0..m {
            for j in 0..m {
                mi[i * m + j] = flat[i * c + j] as f64;
            }
        }
        Ok(mi)
    }
}
