//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client via
//! the `xla` crate. This is the only place the compiled Layer-1/Layer-2
//! code is touched at request time — Python never runs here.
//!
//! * [`artifacts`] — manifest parsing and shape-bucket selection.
//! * [`client`] — the client wrapper with a compile cache and typed
//!   entry points for each artifact kind.

pub mod artifacts;
pub mod client;

pub use artifacts::{ArtifactKind, ArtifactMeta, ArtifactRegistry, Impl};
pub use client::XlaRuntime;
