//! Non-binary (categorical) extension — the paper's stated future work
//! ("extensions to non-binary datasets").
//!
//! The bulk trick generalizes cleanly: one-hot encode each categorical
//! variable into its indicator columns; then the joint count
//! `#(X = a, Y = b)` for any category pair *is* a cell of the binary
//! Gram matrix `G11` between indicator columns. One Gram computation
//! (on any substrate — we use the bit-packed one) yields every joint
//! contingency table of every variable pair at once, and MI assembles
//! per pair from its block of `G11`:
//!
//! ```text
//! MI(X, Y) = Σ_{a ∈ X} Σ_{b ∈ Y} p_ab log2( p_ab / (p_a p_b) )
//! ```

use super::MiMatrix;
use crate::linalg::dense::Mat64;
use crate::util::error::{Error, Result};

/// A dataset of categorical variables (each cell a small category id).
#[derive(Clone, Debug)]
pub struct CategoricalDataset {
    n_rows: usize,
    n_vars: usize,
    /// Row-major category ids; `data[r * n_vars + v] < cardinality[v]`.
    data: Vec<u16>,
    cardinality: Vec<u16>,
}

impl CategoricalDataset {
    /// Build from row-major category ids; cardinalities are inferred
    /// (max id + 1 per variable).
    pub fn new(n_rows: usize, n_vars: usize, data: Vec<u16>) -> Result<Self> {
        if data.len() != n_rows * n_vars {
            return Err(Error::Shape(format!(
                "buffer length {} != {n_rows}x{n_vars}",
                data.len()
            )));
        }
        let mut cardinality = vec![0u16; n_vars];
        for r in 0..n_rows {
            for v in 0..n_vars {
                let c = data[r * n_vars + v];
                if c == u16::MAX {
                    return Err(Error::Parse("category id 65535 is reserved".into()));
                }
                cardinality[v] = cardinality[v].max(c + 1);
            }
        }
        Ok(CategoricalDataset { n_rows, n_vars, data, cardinality })
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_vars(&self) -> usize {
        self.n_vars
    }

    pub fn cardinality(&self) -> &[u16] {
        &self.cardinality
    }

    #[inline]
    pub fn get(&self, r: usize, v: usize) -> u16 {
        self.data[r * self.n_vars + v]
    }

    /// Total one-hot indicator columns.
    pub fn onehot_cols(&self) -> usize {
        self.cardinality.iter().map(|&c| c as usize).sum()
    }

    /// One-hot expansion to a binary dataset; returns the binary matrix
    /// and the starting indicator column of each variable.
    pub fn one_hot(&self) -> (crate::data::dataset::BinaryDataset, Vec<usize>) {
        let total = self.onehot_cols();
        let mut offsets = Vec::with_capacity(self.n_vars);
        let mut acc = 0usize;
        for &c in &self.cardinality {
            offsets.push(acc);
            acc += c as usize;
        }
        let mut bytes = vec![0u8; self.n_rows * total];
        for r in 0..self.n_rows {
            let base = r * total;
            for v in 0..self.n_vars {
                bytes[base + offsets[v] + self.get(r, v) as usize] = 1;
            }
        }
        (
            crate::data::dataset::BinaryDataset::new(self.n_rows, total, bytes)
                .expect("one-hot expansion is consistent"),
            offsets,
        )
    }
}

/// Bulk MI (bits) between all pairs of categorical variables: ONE binary
/// Gram over the one-hot expansion, then per-pair assembly from blocks.
pub fn mi_categorical(ds: &CategoricalDataset) -> Result<MiMatrix> {
    if ds.n_rows() == 0 || ds.n_vars() == 0 {
        return Err(Error::Shape("empty dataset".into()));
    }
    let (binary, offsets) = ds.one_hot();
    let bits = binary.to_bitmatrix();
    let g11 = bits.gram(); // every pairwise category contingency at once
    let counts = bits.col_counts();
    let n = ds.n_rows() as f64;
    let v = ds.n_vars();
    let mut out = Mat64::zeros(v, v);
    for x in 0..v {
        let (ox, cx) = (offsets[x], ds.cardinality[x] as usize);
        for y in x..v {
            let (oy, cy) = (offsets[y], ds.cardinality[y] as usize);
            let mut mi = 0.0;
            for a in 0..cx {
                let pa = counts[ox + a] as f64 / n;
                if pa == 0.0 {
                    continue;
                }
                for b in 0..cy {
                    let pb = counts[oy + b] as f64 / n;
                    let pab = g11.get(ox + a, oy + b) / n;
                    if pab > 0.0 && pb > 0.0 {
                        mi += pab * (pab / (pa * pb)).log2();
                    }
                }
            }
            // diagonal: MI(X, X) = H(X); the double loop already gives
            // exactly that (pab = pa when a == b, 0 otherwise)
            out.set(x, y, mi);
            out.set(y, x, mi);
        }
    }
    Ok(MiMatrix::from_mat(out))
}

/// Categorical entropy H(X_v) in bits per variable.
pub fn categorical_entropies(ds: &CategoricalDataset) -> Vec<f64> {
    let n = ds.n_rows() as f64;
    (0..ds.n_vars())
        .map(|v| {
            let card = ds.cardinality[v] as usize;
            let mut counts = vec![0u64; card];
            for r in 0..ds.n_rows() {
                counts[ds.get(r, v) as usize] += 1;
            }
            counts
                .iter()
                .filter(|&&c| c > 0)
                .map(|&c| {
                    let p = c as f64 / n;
                    -p * p.log2()
                })
                .sum()
        })
        .collect()
}

/// Reference per-pair categorical MI via an explicit contingency table
/// (oracle for tests).
pub fn mi_pair_categorical(ds: &CategoricalDataset, x: usize, y: usize) -> f64 {
    let (cx, cy) = (ds.cardinality[x] as usize, ds.cardinality[y] as usize);
    let mut joint = vec![0u64; cx * cy];
    for r in 0..ds.n_rows() {
        joint[ds.get(r, x) as usize * cy + ds.get(r, y) as usize] += 1;
    }
    let n = ds.n_rows() as f64;
    let mut px = vec![0.0; cx];
    let mut py = vec![0.0; cy];
    for a in 0..cx {
        for b in 0..cy {
            px[a] += joint[a * cy + b] as f64 / n;
            py[b] += joint[a * cy + b] as f64 / n;
        }
    }
    let mut mi = 0.0;
    for a in 0..cx {
        for b in 0..cy {
            let pab = joint[a * cy + b] as f64 / n;
            if pab > 0.0 {
                mi += pab * (pab / (px[a] * py[b])).log2();
            }
        }
    }
    mi
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mi::counts::entropy_bits;
    use crate::util::rng::Rng;

    fn random_cat(rng: &mut Rng, n: usize, cards: &[u16]) -> CategoricalDataset {
        let v = cards.len();
        let data = (0..n * v)
            .map(|i| rng.gen_range(cards[i % v] as usize) as u16)
            .collect();
        CategoricalDataset::new(n, v, data).unwrap()
    }

    #[test]
    fn construction_and_cardinality() {
        let ds = CategoricalDataset::new(3, 2, vec![0, 2, 1, 0, 2, 1]).unwrap();
        assert_eq!(ds.cardinality(), &[3, 3]);
        assert_eq!(ds.onehot_cols(), 6);
        assert!(CategoricalDataset::new(2, 2, vec![0, 1, 2]).is_err());
    }

    #[test]
    fn one_hot_round_trip() {
        let ds = CategoricalDataset::new(4, 2, vec![0, 1, 2, 0, 1, 1, 0, 0]).unwrap();
        let (bin, offsets) = ds.one_hot();
        assert_eq!(bin.n_cols(), ds.onehot_cols());
        for r in 0..4 {
            for v in 0..2 {
                for c in 0..ds.cardinality[v] as usize {
                    let want = (ds.get(r, v) as usize == c) as u8;
                    assert_eq!(bin.get(r, offsets[v] + c), want, "({r},{v},{c})");
                }
            }
        }
    }

    #[test]
    fn bulk_matches_pairwise_oracle() {
        let mut rng = Rng::new(1);
        let ds = random_cat(&mut rng, 300, &[2, 3, 4, 5, 2]);
        let bulk = mi_categorical(&ds).unwrap();
        for x in 0..5 {
            for y in 0..5 {
                let want = mi_pair_categorical(&ds, x, y);
                assert!(
                    (bulk.get(x, y) - want).abs() < 1e-10,
                    "({x},{y}): {} vs {want}",
                    bulk.get(x, y)
                );
            }
        }
    }

    #[test]
    fn binary_special_case_matches_binary_backend() {
        // cardinality-2 categorical MI == binary bulk MI
        let mut rng = Rng::new(2);
        let ds = random_cat(&mut rng, 200, &[2, 2, 2, 2]);
        let cat_mi = mi_categorical(&ds).unwrap();
        let bytes: Vec<u8> = (0..200 * 4).map(|i| ds.data[i] as u8).collect();
        let bin = crate::data::dataset::BinaryDataset::new(200, 4, bytes).unwrap();
        let bin_mi = crate::mi::bulk_opt::mi_bulk_opt(&bin);
        assert!(cat_mi.max_abs_diff(&bin_mi) < 1e-10);
    }

    #[test]
    fn diag_is_categorical_entropy() {
        let mut rng = Rng::new(3);
        let ds = random_cat(&mut rng, 500, &[3, 7]);
        let mi = mi_categorical(&ds).unwrap();
        let h = categorical_entropies(&ds);
        for v in 0..2 {
            assert!((mi.get(v, v) - h[v]).abs() < 1e-10);
        }
    }

    #[test]
    fn copied_variable_reaches_entropy() {
        let mut rng = Rng::new(4);
        let n = 400;
        let col: Vec<u16> = (0..n).map(|_| rng.gen_range(4) as u16).collect();
        let mut data = Vec::with_capacity(n * 2);
        for r in 0..n {
            data.push(col[r]);
            data.push(col[r]);
        }
        let ds = CategoricalDataset::new(n, 2, data).unwrap();
        let mi = mi_categorical(&ds).unwrap();
        assert!((mi.get(0, 1) - mi.get(0, 0)).abs() < 1e-10);
    }

    #[test]
    fn independent_uniform_near_zero() {
        let mut rng = Rng::new(5);
        let ds = random_cat(&mut rng, 50_000, &[3, 4]);
        let mi = mi_categorical(&ds).unwrap();
        assert!(mi.get(0, 1) < 5e-3, "MI {}", mi.get(0, 1));
    }

    #[test]
    fn entropy_bits_consistency() {
        // a balanced binary categorical has H = 1 bit
        let data: Vec<u16> = (0..100).map(|i| (i % 2) as u16).collect();
        let ds = CategoricalDataset::new(100, 1, data).unwrap();
        let h = categorical_entropies(&ds);
        assert!((h[0] - entropy_bits(0.5)).abs() < 1e-12);
    }
}
