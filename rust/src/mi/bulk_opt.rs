//! Section-3 optimized bulk algorithm (the paper's "Opt-NN" row):
//! ONE dense Gram matmul (`G11 = D^T D`), then every other Gram matrix
//! derived from the identities
//!
//! ```text
//! G00 = N - C - C^T + G11      G01 = C - G11      G10 = G01^T
//! ```
//!
//! so the element-wise combine needs only `(G11, colsums, n)`. The
//! combine here is the shared implementation reused by the sparse,
//! bit-packed and coordinator paths; the entry point itself is a
//! one-block plan through the blockwise engine
//! ([`crate::coordinator::executor::compute_source`]), so the
//! monolithic and blockwise paths are literally the same code.

use super::measure::{combine_block, CombineKind};
use super::MiMatrix;
use crate::coordinator::executor::{compute_source, NativeKind};
use crate::data::colstore::InMemorySource;
use crate::data::dataset::BinaryDataset;
use crate::linalg::dense::Mat64;

/// Element-wise eq. (3) from `(G11, colsums_a, colsums_b, n)`.
///
/// Works for rectangular cross-blocks: `g11[i][j]` counts co-occurring
/// ones between variable `i` of block a and variable `j` of block b.
/// This is the MI instance of the pluggable combine layer
/// ([`crate::mi::measure::combine_block`]); other measures use the
/// generic entry point with their [`CombineKind`].
pub fn combine(g11: &Mat64, ca: &[f64], cb: &[f64], n: f64) -> Mat64 {
    combine_block(CombineKind::Mi, g11, ca, cb, n)
}

/// Full optimized bulk MI for a dataset (dense f32 Gram substrate),
/// routed through the blockwise engine as a one-block plan.
pub fn mi_bulk_opt(ds: &BinaryDataset) -> MiMatrix {
    if ds.n_cols() == 0 {
        return MiMatrix::from_mat(Mat64::zeros(0, 0));
    }
    compute_source(&InMemorySource::new(ds), NativeKind::Dense, 1, CombineKind::Mi)
        .expect("one-block plan on non-empty columns")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::mi::pairwise::mi_pairwise;

    #[test]
    fn matches_pairwise_exactly() {
        for &(n, m, s) in &[(200usize, 10usize, 0.9f64), (97, 17, 0.5), (64, 33, 0.1)] {
            let ds = SynthSpec::new(n, m).sparsity(s).seed(n as u64).generate();
            let bulk = mi_bulk_opt(&ds);
            let pair = mi_pairwise(&ds);
            assert!(
                bulk.max_abs_diff(&pair) < 1e-12,
                "n={n} m={m} s={s}: diff {}",
                bulk.max_abs_diff(&pair)
            );
        }
    }

    #[test]
    fn symmetric_nonnegative() {
        let ds = SynthSpec::new(300, 20).sparsity(0.8).seed(9).generate();
        let mi = mi_bulk_opt(&ds);
        assert!(mi.max_asymmetry() < 1e-12);
        assert!(mi.min_value() > -1e-12);
    }

    #[test]
    fn constant_columns_are_zero() {
        // all-zero and all-one columns: MI must be exactly 0 everywhere
        let mut data = vec![0u8; 50 * 3];
        for r in 0..50 {
            data[r * 3 + 1] = 1; // constant one column
            data[r * 3 + 2] = (r % 2) as u8;
        }
        let ds = crate::data::dataset::BinaryDataset::new(50, 3, data).unwrap();
        let mi = mi_bulk_opt(&ds);
        assert_eq!(mi.get(0, 1), 0.0);
        assert_eq!(mi.get(0, 2), 0.0);
        assert_eq!(mi.get(1, 2), 0.0);
    }

    #[test]
    fn cross_block_combine_matches_full() {
        let ds = SynthSpec::new(150, 12).sparsity(0.6).seed(4).generate();
        let full = mi_bulk_opt(&ds);
        let a = ds.col_block(0, 5).unwrap().to_mat32();
        let b = ds.col_block(5, 7).unwrap().to_mat32();
        let g = crate::linalg::blas::gemm_at_b(&a, &b).unwrap();
        let cross = combine(&g, &a.col_sums(), &b.col_sums(), 150.0);
        for i in 0..5 {
            for j in 0..7 {
                assert!((cross.get(i, j) - full.get(i, 5 + j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn single_row_dataset() {
        let ds = crate::data::dataset::BinaryDataset::new(1, 4, vec![1, 0, 1, 0]).unwrap();
        let mi = mi_bulk_opt(&ds);
        // single observation: every variable is constant -> all MI zero
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(mi.get(i, j), 0.0);
            }
        }
    }
}
