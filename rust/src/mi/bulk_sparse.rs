//! Section-3 algorithm on the CSR substrate (the paper's "Opt-SS" row).
//!
//! Only `G11` is ever computed sparsely — the paper's key observation is
//! that ¬D of a sparse matrix is dense, so the optimized derivation is
//! what makes a sparse implementation possible at all. Cost of the Gram
//! is Σ_r nnz(r)², which loses to dense at ~90% sparsity and wins
//! decisively at ≥99% (reproduced by `benches/fig3_sparsity.rs`).

use super::MiMatrix;
use crate::coordinator::executor::{compute_source, NativeKind};
use crate::data::colstore::InMemorySource;
use crate::data::dataset::BinaryDataset;
use crate::linalg::dense::Mat64;
use crate::mi::measure::CombineKind;

/// Full optimized bulk MI with a sparse (CSR row-pair expansion) Gram,
/// routed through the blockwise engine as a one-block plan.
pub fn mi_bulk_sparse(ds: &BinaryDataset) -> MiMatrix {
    if ds.n_cols() == 0 {
        return MiMatrix::from_mat(Mat64::zeros(0, 0));
    }
    compute_source(&InMemorySource::new(ds), NativeKind::Sparse, 1, CombineKind::Mi)
        .expect("one-block plan on non-empty columns")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::mi::pairwise::mi_pairwise;

    #[test]
    fn matches_pairwise_across_sparsities() {
        for &s in &[0.5, 0.9, 0.99] {
            let ds = SynthSpec::new(400, 15).sparsity(s).seed((s * 100.0) as u64).generate();
            let sparse = mi_bulk_sparse(&ds);
            let pair = mi_pairwise(&ds);
            assert!(
                sparse.max_abs_diff(&pair) < 1e-12,
                "s={s}: diff {}",
                sparse.max_abs_diff(&pair)
            );
        }
    }

    #[test]
    fn all_zero_dataset() {
        let ds = crate::data::dataset::BinaryDataset::new(20, 4, vec![0; 80]).unwrap();
        let mi = mi_bulk_sparse(&ds);
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(mi.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn extremely_sparse_single_ones() {
        // one 1 per column, all in different rows
        let mut data = vec![0u8; 100 * 5];
        for c in 0..5 {
            data[(c * 13) * 5 + c] = 1;
        }
        let ds = crate::data::dataset::BinaryDataset::new(100, 5, data).unwrap();
        let sparse = mi_bulk_sparse(&ds);
        let pair = mi_pairwise(&ds);
        assert!(sparse.max_abs_diff(&pair) < 1e-12);
    }
}
