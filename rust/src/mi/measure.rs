//! Pluggable combine layer: every pairwise association measure the 2x2
//! contingency table determines, computed from the *same* single Gram.
//!
//! The paper's identity — `(G11, colsums, n)` determine the full 2x2
//! table `(n00, n01, n10, n11)` of every column pair — is not specific
//! to mutual information. Any measure that is a function of the four
//! joint counts rides the identical one-Gram pipeline for free: the
//! blockwise engine computes the Gram block once and only the final
//! element-wise combine differs. [`CombineKind`] names the measures the
//! crate ships; [`CombineKind::combine`] is the scalar core and
//! [`combine_block`] the block-level map every native backend routes
//! through ([`crate::coordinator::executor`]).
//!
//! # Formula table
//!
//! With marginals `r1 = n11 + n10` (X=1), `r0 = n01 + n00`,
//! `c1 = n11 + n01` (Y=1), `c0 = n10 + n00`, expected counts
//! `e_xy = n_x · n_y / n`, and entropies in bits:
//!
//! | kind | formula | range | zero ⇔ |
//! |------|---------|-------|--------|
//! | `mi` | `Σ (n_xy/n) log2(n_xy n / (n_x n_y))` | `[0, min(H(X), H(Y))]` | independence |
//! | `nmi` | `MI / min(H(X), H(Y))` (0 when a variable is constant) | `[0, 1]` | independence |
//! | `vi` | `H(X) + H(Y) - 2 MI` (a metric) | `[0, H(X)+H(Y)]` | X determines Y and vice versa |
//! | `gstat` | `G = 2 n ln2 · MI_bits = 2 Σ n_xy ln(n_xy/e_xy)` | `[0, 2n ln 2]` | independence |
//! | `chi2` | `Σ (n_xy - e_xy)² / e_xy` | `[0, n]` | independence |
//! | `phi` | `(n11 n00 - n10 n01) / sqrt(r1 r0 c1 c0)` | `[-1, 1]` | independence |
//! | `jaccard` | `n11 / (n11 + n10 + n01)` | `[0, 1]` | no co-occurrence |
//! | `ochiai` | `n11 / sqrt(r1 c1)` (cosine of the indicator vectors) | `[0, 1]` | no co-occurrence |
//!
//! Cells or denominators that vanish (constant columns, empty unions)
//! contribute exactly 0 — the same no-epsilon convention as
//! [`crate::mi::counts`]. Every formula is evaluated with a summation
//! tree that is bitwise invariant under the `(i, j) -> (j, i)` swap
//! (which exchanges `n10 <-> n01`, `r <-> c`), so blockwise
//! mirror-writes stay bit-identical to monolithic runs for every
//! measure, exactly as they do for MI.
//!
//! Both the scalar core and the block map are thin entry points over
//! [`crate::mi::combine_kernels`]: the scalar path runs the same
//! per-measure cell bodies in direct-`log2` mode, the block path runs
//! them monomorphized with marginal invariants hoisted and integer
//! logs served from a [`crate::mi::combine_kernels::LogTable`] — two
//! evaluation speeds, one expression tree, identical bits.
//!
//! Only `mi` and `gstat` carry the G-test χ²₁ asymptotic null
//! ([`crate::mi::significance`]); the `pvalue:` sink therefore accepts
//! exactly those two ([`CombineKind::supports_pvalue_sink`]) and
//! returns a clean error for the rest.
//!
//! ```
//! use bulkmi::data::synth::SynthSpec;
//! use bulkmi::mi::backend::{compute_measure, Backend};
//! use bulkmi::mi::measure::CombineKind;
//!
//! let ds = SynthSpec::new(512, 12).sparsity(0.8).seed(3).generate();
//! // one Gram per backend run, any measure from it
//! let jac = compute_measure(&ds, Backend::BulkBitpack, CombineKind::Jaccard).unwrap();
//! let nmi = compute_measure(&ds, Backend::BulkOpt, CombineKind::Nmi).unwrap();
//! for i in 0..12 {
//!     for j in 0..12 {
//!         assert!((0.0..=1.0).contains(&jac.get(i, j)));
//!         assert!((0.0..=1.0).contains(&nmi.get(i, j)));
//!     }
//! }
//! // a column co-occurs perfectly with itself (unless it is all-zero)
//! assert!((jac.get(0, 0) - 1.0).abs() < 1e-12 || ds.col_counts()[0] == 0);
//! // parse() round-trips the CLI names
//! assert_eq!(CombineKind::parse("ochiai"), Some(CombineKind::Ochiai));
//! assert_eq!(CombineKind::parse("bogus"), None);
//! ```

use super::combine_kernels::{combine_block_with, combine_cell, LogTable};
use super::MiMatrix;
use crate::data::dataset::BinaryDataset;
use crate::linalg::dense::Mat64;

/// Which association measure the element-wise combine computes from the
/// four 2x2 contingency counts. See the module-level formula table.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum CombineKind {
    /// Mutual information in bits (the paper's measure; the default).
    #[default]
    Mi,
    /// MI normalized by `min(H(X), H(Y))` — 1 when one variable
    /// determines the other (matches
    /// [`crate::mi::entropy::Normalization::Min`]).
    Nmi,
    /// Variation of information `H(X) + H(Y) - 2 MI`, in bits.
    Vi,
    /// The G-test statistic `2 n ln2 · MI_bits` (log-likelihood ratio).
    GStat,
    /// Pearson's χ² statistic against the independence null.
    Chi2,
    /// The φ coefficient (Pearson correlation of binary indicators).
    Phi,
    /// Jaccard similarity of the ones-sets, `n11 / |union|`.
    Jaccard,
    /// Ochiai / cosine similarity, `n11 / sqrt(n_x n_y)`.
    Ochiai,
}

impl CombineKind {
    /// Every measure, in the module table's order.
    pub const ALL: [CombineKind; 8] = [
        CombineKind::Mi,
        CombineKind::Nmi,
        CombineKind::Vi,
        CombineKind::GStat,
        CombineKind::Chi2,
        CombineKind::Phi,
        CombineKind::Jaccard,
        CombineKind::Ochiai,
    ];

    /// Stable identifier used by `--measure`, config and bench output.
    pub fn name(self) -> &'static str {
        match self {
            CombineKind::Mi => "mi",
            CombineKind::Nmi => "nmi",
            CombineKind::Vi => "vi",
            CombineKind::GStat => "gstat",
            CombineKind::Chi2 => "chi2",
            CombineKind::Phi => "phi",
            CombineKind::Jaccard => "jaccard",
            CombineKind::Ochiai => "ochiai",
        }
    }

    pub fn parse(s: &str) -> Option<CombineKind> {
        CombineKind::ALL.iter().copied().find(|k| k.name() == s)
    }

    /// Does this measure carry the G-test χ²₁ asymptotic null that the
    /// `pvalue:P` sink converts cutoffs through? Only `mi` (monotone in
    /// G) and `gstat` (G itself) do; measures without an asymptotic
    /// null make `pvalue:` a clean error.
    pub fn supports_pvalue_sink(self) -> bool {
        matches!(self, CombineKind::Mi | CombineKind::GStat)
    }

    /// The measure's value for one column pair, from the total `n` and
    /// the four joint counts (`c10` counts rows with X=1, Y=0, etc.).
    ///
    /// Counts arrive as f64 because they come off a Gram matrix; they
    /// are integral up to float rounding. Delegates to the shared
    /// kernel cell body ([`crate::mi::combine_kernels::combine_cell`])
    /// in direct-log mode, so the value is bit-identical to the
    /// table-driven block kernels and bitwise invariant under the
    /// `c10 <-> c01` (column swap) exchange — the blockwise engine's
    /// mirror-write exactness relies on it.
    #[inline]
    pub fn combine(self, n: f64, c00: f64, c01: f64, c10: f64, c11: f64) -> f64 {
        combine_cell(self, n, c00, c01, c10, c11)
    }
}

impl std::fmt::Display for CombineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Element-wise combine of a (possibly rectangular cross-) Gram block
/// into the selected measure — the generalization of the Section-3
/// eq. (3) map that [`crate::mi::bulk_opt::combine`] applies for MI.
///
/// `g11[i][j]` counts co-occurring ones between variable `i` of block a
/// and variable `j` of block b; `ca`/`cb` are the blocks' column sums.
///
/// Runs the monomorphized kernels with a per-call [`LogTable`] sized by
/// the block's cell count (small blocks stay on the bit-identical
/// direct-log path rather than paying an `O(n)` table build). Callers
/// that map many blocks per run — the executor, cluster workers — hold
/// one table and call
/// [`combine_block_with`](crate::mi::combine_kernels::combine_block_with)
/// instead.
pub fn combine_block(kind: CombineKind, g11: &Mat64, ca: &[f64], cb: &[f64], n: f64) -> Mat64 {
    let lt = LogTable::sized_for(n, g11.rows() * g11.cols());
    combine_block_with(kind, &lt, g11, ca, cb, n)
}

/// Sequential per-pair computation of any measure (the `pairwise`
/// backend generalized): a full row scan builds each pair's 2x2 table
/// ([`crate::mi::pairwise::pair_counts`], the same inner loop as
/// `mi_pairwise`), then the scalar combine applies. O(m² n) — the
/// comparator the bulk paths are validated against in
/// `rust/tests/measures.rs`.
pub fn measure_pairwise(ds: &BinaryDataset, kind: CombineKind) -> MiMatrix {
    let (n, m) = (ds.n_rows(), ds.n_cols());
    let mut out = Mat64::zeros(m, m);
    for i in 0..m {
        for j in i..m {
            let (n11, n10, n01, n00) = super::pairwise::pair_counts(ds, i, j);
            let v = kind.combine(n as f64, n00 as f64, n01 as f64, n10 as f64, n11 as f64);
            out.set(i, j, v);
            out.set(j, i, v);
        }
    }
    MiMatrix::from_mat(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for k in CombineKind::ALL {
            assert_eq!(CombineKind::parse(k.name()), Some(k));
        }
        assert_eq!(CombineKind::parse("warp"), None);
        assert_eq!(CombineKind::default(), CombineKind::Mi);
        assert_eq!(CombineKind::GStat.to_string(), "gstat");
    }

    #[test]
    fn pvalue_support_is_gtest_only() {
        for k in CombineKind::ALL {
            assert_eq!(
                k.supports_pvalue_sink(),
                matches!(k, CombineKind::Mi | CombineKind::GStat),
                "{k}"
            );
        }
    }

    #[test]
    fn perfect_dependence_extremes() {
        // X == Y, both balanced over n = 8: n11 = 4, n00 = 4
        let v = |k: CombineKind| k.combine(8.0, 4.0, 0.0, 0.0, 4.0);
        assert!((v(CombineKind::Mi) - 1.0).abs() < 1e-12);
        assert!((v(CombineKind::Nmi) - 1.0).abs() < 1e-12);
        assert_eq!(v(CombineKind::Vi), 0.0);
        assert!((v(CombineKind::GStat) - 16.0 * std::f64::consts::LN_2).abs() < 1e-12);
        assert!((v(CombineKind::Chi2) - 8.0).abs() < 1e-12); // n·φ² = n
        assert!((v(CombineKind::Phi) - 1.0).abs() < 1e-12);
        assert!((v(CombineKind::Jaccard) - 1.0).abs() < 1e-12);
        assert!((v(CombineKind::Ochiai) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn exact_independence_zeroes_dependence_measures() {
        // p(x) = 1/2, p(y) = 1/4, all cells exactly independent
        for k in [
            CombineKind::Mi,
            CombineKind::Nmi,
            CombineKind::GStat,
            CombineKind::Chi2,
            CombineKind::Phi,
        ] {
            assert!(
                k.combine(8.0, 3.0, 1.0, 3.0, 1.0).abs() < 1e-12,
                "{k} not zero on independent counts"
            );
        }
        // similarity measures are *not* zero under independence
        assert!(CombineKind::Jaccard.combine(8.0, 3.0, 1.0, 3.0, 1.0) > 0.0);
        assert!(CombineKind::Ochiai.combine(8.0, 3.0, 1.0, 3.0, 1.0) > 0.0);
    }

    #[test]
    fn constant_columns_are_safe_zeros() {
        for k in CombineKind::ALL {
            // X constant-zero (r1 = 0) against a balanced Y
            let v = k.combine(8.0, 4.0, 4.0, 0.0, 0.0);
            assert!(v.is_finite(), "{k} not finite on constant column");
            assert_eq!(v, 0.0, "{k} on constant column");
            // zero rows
            assert_eq!(k.combine(0.0, 0.0, 0.0, 0.0, 0.0), 0.0, "{k} on n = 0");
        }
    }

    #[test]
    fn swap_symmetry_is_bitwise() {
        // exchanging c10 <-> c01 (the (i,j) -> (j,i) swap) must be
        // bit-identical for every measure: the blockwise mirror-write
        // correctness condition.
        let tables: &[(f64, f64, f64, f64, f64)] = &[
            (10.0, 3.0, 2.0, 4.0, 1.0),
            (100.0, 50.0, 30.0, 15.0, 5.0),
            (7.0, 0.0, 3.0, 0.0, 4.0),
            (9.0, 1.0, 0.0, 8.0, 0.0),
        ];
        for &(n, c00, c01, c10, c11) in tables {
            for k in CombineKind::ALL {
                let a = k.combine(n, c00, c01, c10, c11);
                let b = k.combine(n, c00, c10, c01, c11);
                assert_eq!(a.to_bits(), b.to_bits(), "{k} on {n} {c00} {c01} {c10} {c11}");
            }
        }
    }

    #[test]
    fn phi_negative_on_anticorrelation() {
        // X = not Y: n10 = n01 = 4
        let v = CombineKind::Phi.combine(8.0, 0.0, 4.0, 4.0, 0.0);
        assert!((v + 1.0).abs() < 1e-12, "phi = {v}");
        // ...while the symmetric dependence measures max out
        assert!((CombineKind::Mi.combine(8.0, 0.0, 4.0, 4.0, 0.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn combine_block_matches_scalar() {
        use crate::data::synth::SynthSpec;
        let ds = SynthSpec::new(200, 9).sparsity(0.7).seed(5).generate();
        let bits = ds.to_bitmatrix();
        let g = bits.gram();
        let c: Vec<f64> = ds.col_counts().iter().map(|&v| v as f64).collect();
        for k in CombineKind::ALL {
            let block = combine_block(k, &g, &c, &c, 200.0);
            let pair = measure_pairwise(&ds, k);
            for i in 0..9 {
                for j in 0..9 {
                    assert!(
                        (block.get(i, j) - pair.get(i, j)).abs() < 1e-12,
                        "{k} ({i},{j}): {} vs {}",
                        block.get(i, j),
                        pair.get(i, j)
                    );
                }
            }
        }
    }

    #[test]
    fn mi_kind_matches_legacy_combine() {
        use crate::data::synth::SynthSpec;
        let ds = SynthSpec::new(150, 7).sparsity(0.5).seed(2).generate();
        let g = ds.to_bitmatrix().gram();
        let c: Vec<f64> = ds.col_counts().iter().map(|&v| v as f64).collect();
        let new = combine_block(CombineKind::Mi, &g, &c, &c, 150.0);
        let old = crate::mi::bulk_opt::combine(&g, &c, &c, 150.0);
        assert_eq!(new.max_abs_diff(&old), 0.0, "Mi combine must stay bit-identical");
    }
}
