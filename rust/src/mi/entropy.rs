//! Entropy-based analysis utilities over MI results: marginal/joint
//! entropies, normalized MI variants, and variation of information —
//! the quantities feature-selection and clustering applications
//! (paper §1) derive from the raw MI matrix.

use super::counts::entropy_bits;
use super::MiMatrix;
use crate::data::dataset::BinaryDataset;

/// Marginal entropy H(X_c) in bits for every column.
pub fn column_entropies(ds: &BinaryDataset) -> Vec<f64> {
    entropies_from_counts(&ds.col_counts(), ds.n_rows())
}

/// Marginal entropies from per-column ones counts — everything a
/// streaming [`crate::data::colstore::ColumnSource`] can supply without
/// materializing rows (a binary column's entropy is a function of its
/// count alone).
pub fn entropies_from_counts(counts: &[u64], n_rows: usize) -> Vec<f64> {
    let n = n_rows as f64;
    counts.iter().map(|&c| entropy_bits(c as f64 / n)).collect()
}

/// Joint entropy H(X_i, X_j) = H(X_i) + H(X_j) - MI(X_i, X_j).
pub fn joint_entropy(h: &[f64], mi: &MiMatrix, i: usize, j: usize) -> f64 {
    h[i] + h[j] - mi.get(i, j)
}

/// Normalized MI variants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Normalization {
    /// MI / min(H(X), H(Y)) — 1 when one variable determines the other.
    Min,
    /// MI / max(H(X), H(Y)).
    Max,
    /// 2·MI / (H(X) + H(Y)) — symmetric uncertainty.
    Mean,
    /// MI / H(X, Y) — the [0,1] "IQR" coefficient.
    Joint,
}

/// Normalized MI matrix; cells with a zero denominator (constant
/// variables) are defined as 0.
pub fn normalized_mi(ds: &BinaryDataset, mi: &MiMatrix, norm: Normalization) -> MiMatrix {
    normalized_mi_with(&column_entropies(ds), mi, norm)
}

/// [`normalized_mi`] from precomputed marginal entropies (the streaming
/// input path derives them via [`entropies_from_counts`]).
pub fn normalized_mi_with(h: &[f64], mi: &MiMatrix, norm: Normalization) -> MiMatrix {
    let m = mi.dim();
    let mut out = crate::linalg::dense::Mat64::zeros(m, m);
    for i in 0..m {
        for j in 0..m {
            let denom = match norm {
                Normalization::Min => h[i].min(h[j]),
                Normalization::Max => h[i].max(h[j]),
                Normalization::Mean => 0.5 * (h[i] + h[j]),
                Normalization::Joint => joint_entropy(h, mi, i, j),
            };
            let v = if denom > 0.0 { (mi.get(i, j) / denom).clamp(0.0, 1.0) } else { 0.0 };
            out.set(i, j, v);
        }
    }
    MiMatrix::from_mat(out)
}

/// Variation of information VI(X,Y) = H(X,Y) - MI(X,Y), a metric.
pub fn variation_of_information(ds: &BinaryDataset, mi: &MiMatrix) -> crate::linalg::dense::Mat64 {
    let h = column_entropies(ds);
    let m = mi.dim();
    let mut out = crate::linalg::dense::Mat64::zeros(m, m);
    for i in 0..m {
        for j in 0..m {
            out.set(i, j, (h[i] + h[j] - 2.0 * mi.get(i, j)).max(0.0));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::mi::pairwise::mi_pairwise;

    fn setup() -> (BinaryDataset, MiMatrix) {
        let ds = SynthSpec::new(800, 10).sparsity(0.6).seed(1).plant(0, 1, 0.0).generate();
        let mi = mi_pairwise(&ds);
        (ds, mi)
    }

    #[test]
    fn entropies_match_diag() {
        let (ds, mi) = setup();
        let h = column_entropies(&ds);
        for c in 0..10 {
            assert!((h[c] - mi.get(c, c)).abs() < 1e-12);
        }
    }

    #[test]
    fn normalized_copy_pair_is_one() {
        let (ds, mi) = setup();
        for norm in [
            Normalization::Min,
            Normalization::Max,
            Normalization::Mean,
            Normalization::Joint,
        ] {
            let nmi = normalized_mi(&ds, &mi, norm);
            assert!((nmi.get(0, 1) - 1.0).abs() < 1e-9, "{norm:?}: {}", nmi.get(0, 1));
        }
    }

    #[test]
    fn normalized_in_unit_interval() {
        let (ds, mi) = setup();
        let nmi = normalized_mi(&ds, &mi, Normalization::Min);
        for &v in nmi.data() {
            assert!((0.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn vi_is_metric_like() {
        let (ds, mi) = setup();
        let vi = variation_of_information(&ds, &mi);
        for i in 0..10 {
            assert!(vi.get(i, i).abs() < 1e-9, "VI(X,X) = 0");
            for j in 0..10 {
                assert!(vi.get(i, j) >= 0.0);
                assert!((vi.get(i, j) - vi.get(j, i)).abs() < 1e-12);
            }
        }
        // copy pair: VI = 0
        assert!(vi.get(0, 1).abs() < 1e-9);
    }

    #[test]
    fn joint_entropy_bounds() {
        let (ds, mi) = setup();
        let h = column_entropies(&ds);
        for i in 0..10 {
            for j in 0..10 {
                let hij = joint_entropy(&h, &mi, i, j);
                assert!(hij <= h[i] + h[j] + 1e-12);
                assert!(hij >= h[i].max(h[j]) - 1e-9);
            }
        }
    }
}
