//! Section-3 algorithm on the bit-packed substrate — the crate's
//! hardware-optimized hot path (the role PyTorch's fused CPU kernels play
//! in the paper's "Opt-T" row). The Gram inner product is
//! `popcount(a & b)` over 64-bit words: 64 multiply-adds per instruction,
//! integer-exact, cache-friendly column-major layout.
//!
//! Optionally parallel across output row-blocks via
//! [`crate::util::threadpool::parallel_for`].

use super::bulk_opt::combine;
use super::MiMatrix;
use crate::data::dataset::BinaryDataset;
use crate::linalg::bitmat::BitMatrix;
use crate::linalg::dense::Mat64;
use crate::util::threadpool::parallel_for;
use std::sync::Mutex;

/// Full optimized bulk MI on the bit-packed Gram, single-threaded.
pub fn mi_bulk_bitpack(ds: &BinaryDataset) -> MiMatrix {
    mi_bulk_bitpack_threads(ds, 1)
}

/// Same, with the Gram parallelized over `workers` threads (row blocks
/// of the output are independent).
pub fn mi_bulk_bitpack_threads(ds: &BinaryDataset, workers: usize) -> MiMatrix {
    let bm = ds.to_bitmatrix();
    let n = ds.n_rows() as f64;
    let c: Vec<f64> = bm.col_counts().iter().map(|&v| v as f64).collect();
    let g11 = if workers <= 1 { bm.gram() } else { gram_parallel(&bm, workers) };
    MiMatrix::from_mat(combine(&g11, &c, &c, n))
}

/// Parallel symmetric Gram: split output rows into bands; each band's
/// upper-triangle cells are computed independently, then mirrored.
fn gram_parallel(bm: &BitMatrix, workers: usize) -> Mat64 {
    let m = bm.cols();
    let out = Mutex::new(Mat64::zeros(m, m));
    // Band tasks sized so later (shorter) rows of the triangle balance:
    // use more tasks than workers and let work-stealing even it out.
    let bands = (workers * 8).min(m.max(1));
    let band_size = m.div_ceil(bands.max(1)).max(1);
    let n_tasks = m.div_ceil(band_size);
    parallel_for(n_tasks, workers, |t| {
        let lo = t * band_size;
        let hi = ((t + 1) * band_size).min(m);
        // compute locally, then write under the lock once per band
        let mut local: Vec<(usize, Vec<f64>)> = Vec::with_capacity(hi - lo);
        for i in lo..hi {
            let ci = bm.col(i);
            let mut row = vec![0.0f64; m - i];
            for j in i..m {
                row[j - i] = dot(ci, bm.col(j)) as f64;
            }
            local.push((i, row));
        }
        let mut guard = out.lock().unwrap();
        for (i, row) in local {
            for (off, v) in row.into_iter().enumerate() {
                let j = i + off;
                guard.set(i, j, v);
                guard.set(j, i, v);
            }
        }
    });
    out.into_inner().unwrap()
}

#[inline]
fn dot(a: &[u64], b: &[u64]) -> u64 {
    a.iter().zip(b).map(|(&x, &y)| (x & y).count_ones() as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::mi::bulk_opt::mi_bulk_opt;
    use crate::mi::pairwise::mi_pairwise;

    #[test]
    fn matches_pairwise() {
        for &(n, m, s) in &[(333usize, 11usize, 0.9f64), (64, 20, 0.3), (1000, 8, 0.99)] {
            let ds = SynthSpec::new(n, m).sparsity(s).seed(n as u64 + 7).generate();
            let bit = mi_bulk_bitpack(&ds);
            let pair = mi_pairwise(&ds);
            assert!(bit.max_abs_diff(&pair) < 1e-12, "n={n} m={m} s={s}");
        }
    }

    #[test]
    fn matches_dense_opt() {
        let ds = SynthSpec::new(500, 25).sparsity(0.8).seed(5).generate();
        assert!(mi_bulk_bitpack(&ds).max_abs_diff(&mi_bulk_opt(&ds)) < 1e-12);
    }

    #[test]
    fn parallel_matches_serial() {
        let ds = SynthSpec::new(400, 37).sparsity(0.7).seed(6).generate();
        let serial = mi_bulk_bitpack_threads(&ds, 1);
        for workers in [2, 4, 7] {
            let par = mi_bulk_bitpack_threads(&ds, workers);
            assert_eq!(par.max_abs_diff(&serial), 0.0, "workers={workers}");
        }
    }

    #[test]
    fn tiny_datasets() {
        for (n, m) in [(1usize, 1usize), (1, 5), (5, 1), (2, 2)] {
            let ds = SynthSpec::new(n, m).sparsity(0.5).seed(8).generate();
            let bit = mi_bulk_bitpack(&ds);
            let pair = mi_pairwise(&ds);
            assert!(bit.max_abs_diff(&pair) < 1e-12, "n={n} m={m}");
        }
    }
}
