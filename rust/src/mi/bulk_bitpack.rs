//! Section-3 algorithm on the bit-packed substrate — the crate's
//! hardware-optimized hot path (the role PyTorch's fused CPU kernels play
//! in the paper's "Opt-T" row). The Gram inner product is
//! `popcount(a & b)` over 64-bit words: 64 multiply-adds per instruction,
//! integer-exact, cache-friendly column-major layout.
//!
//! Both entry points are thin wrappers over the blockwise engine
//! ([`crate::coordinator::executor::compute_source`]): serial runs are a
//! one-block plan, parallel runs over-decompose into block tasks whose
//! results are channeled to a single collector — there is no shared
//! output lock anywhere on this path.

use super::MiMatrix;
use crate::coordinator::executor::{compute_source, NativeKind};
use crate::data::colstore::InMemorySource;
use crate::data::dataset::BinaryDataset;
use crate::linalg::dense::Mat64;
use crate::mi::measure::CombineKind;

/// Full optimized bulk MI on the bit-packed Gram, single-threaded.
pub fn mi_bulk_bitpack(ds: &BinaryDataset) -> MiMatrix {
    mi_bulk_bitpack_threads(ds, 1)
}

/// Same, parallelized over `workers` threads (independent column-block
/// tasks through the blockwise engine; bit-identical to serial).
pub fn mi_bulk_bitpack_threads(ds: &BinaryDataset, workers: usize) -> MiMatrix {
    if ds.n_cols() == 0 {
        return MiMatrix::from_mat(Mat64::zeros(0, 0));
    }
    compute_source(&InMemorySource::new(ds), NativeKind::Bitpack, workers, CombineKind::Mi)
        .expect("block plan on non-empty columns")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::mi::bulk_opt::mi_bulk_opt;
    use crate::mi::pairwise::mi_pairwise;

    #[test]
    fn matches_pairwise() {
        for &(n, m, s) in &[(333usize, 11usize, 0.9f64), (64, 20, 0.3), (1000, 8, 0.99)] {
            let ds = SynthSpec::new(n, m).sparsity(s).seed(n as u64 + 7).generate();
            let bit = mi_bulk_bitpack(&ds);
            let pair = mi_pairwise(&ds);
            assert!(bit.max_abs_diff(&pair) < 1e-12, "n={n} m={m} s={s}");
        }
    }

    #[test]
    fn matches_dense_opt() {
        let ds = SynthSpec::new(500, 25).sparsity(0.8).seed(5).generate();
        assert!(mi_bulk_bitpack(&ds).max_abs_diff(&mi_bulk_opt(&ds)) < 1e-12);
    }

    #[test]
    fn parallel_matches_serial() {
        let ds = SynthSpec::new(400, 37).sparsity(0.7).seed(6).generate();
        let serial = mi_bulk_bitpack_threads(&ds, 1);
        for workers in [2, 4, 7] {
            let par = mi_bulk_bitpack_threads(&ds, workers);
            assert_eq!(par.max_abs_diff(&serial), 0.0, "workers={workers}");
        }
    }

    #[test]
    fn tiny_datasets() {
        for (n, m) in [(1usize, 1usize), (1, 5), (5, 1), (2, 2)] {
            let ds = SynthSpec::new(n, m).sparsity(0.5).seed(8).generate();
            let bit = mi_bulk_bitpack(&ds);
            let pair = mi_pairwise(&ds);
            assert!(bit.max_abs_diff(&pair) < 1e-12, "n={n} m={m}");
        }
    }
}
