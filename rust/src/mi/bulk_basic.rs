//! Section-2 basic bulk algorithm (the paper's "Bas-NN" row), implemented
//! *literally*: materialize the complementary matrix ¬D, compute all four
//! Gram matrices with dense matmuls, form joint/marginal probability
//! matrices and the independence expectations, and sum the four masked
//! `P log2(P/E)` terms. Deliberately unoptimized relative to
//! [`super::bulk_opt`] — the pair is the paper's basic-vs-optimized
//! ablation (expected ~3-4x gap from the 4-vs-1 matmul count).

use super::MiMatrix;
use crate::data::dataset::BinaryDataset;
use crate::linalg::blas;
use crate::linalg::dense::Mat64;

/// `p * log2(p / e)` with the `0 log 0 := 0` convention.
#[inline]
fn term(p: f64, e: f64) -> f64 {
    if p > 0.0 {
        p * (p / e).log2()
    } else {
        0.0
    }
}

/// Full basic bulk MI (paper Section 2, verbatim).
pub fn mi_bulk_basic(ds: &BinaryDataset) -> MiMatrix {
    let n = ds.n_rows() as f64;
    let m = ds.n_cols();
    let d = ds.to_mat32();
    let nd = d.complement(); // the dense ¬D the optimized path avoids

    // Step 2: the four Gram matrices (joint counts).
    let g11 = blas::gram(&d);
    let g00 = blas::gram(&nd);
    let g01 = blas::gemm_at_b(&nd, &d).expect("same rows");
    let g10 = blas::gemm_at_b(&d, &nd).expect("same rows");

    // Step 3: marginals from the diagonals.
    let p1: Vec<f64> = g11.diag().iter().map(|&v| v / n).collect();
    let p0: Vec<f64> = g00.diag().iter().map(|&v| v / n).collect();

    // Steps 4-5: expectations via outer products + the eq. (3) combine.
    let mut out = Mat64::zeros(m, m);
    for i in 0..m {
        for j in 0..m {
            let p11 = g11.get(i, j) / n;
            let p00 = g00.get(i, j) / n;
            let p01 = g01.get(i, j) / n; // X_i = 0, X_j = 1
            let p10 = g10.get(i, j) / n;
            let mi = term(p11, p1[i] * p1[j])
                + term(p10, p1[i] * p0[j])
                + term(p01, p0[i] * p1[j])
                + term(p00, p0[i] * p0[j]);
            out.set(i, j, mi);
        }
    }
    MiMatrix::from_mat(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::mi::bulk_opt::mi_bulk_opt;
    use crate::mi::pairwise::mi_pairwise;

    #[test]
    fn matches_pairwise() {
        for &(n, m, s) in &[(150usize, 9usize, 0.9f64), (80, 21, 0.4)] {
            let ds = SynthSpec::new(n, m).sparsity(s).seed(m as u64).generate();
            let bulk = mi_bulk_basic(&ds);
            let pair = mi_pairwise(&ds);
            assert!(bulk.max_abs_diff(&pair) < 1e-10, "diff {}", bulk.max_abs_diff(&pair));
        }
    }

    #[test]
    fn matches_optimized() {
        let ds = SynthSpec::new(256, 30).sparsity(0.85).seed(2).generate();
        let basic = mi_bulk_basic(&ds);
        let opt = mi_bulk_opt(&ds);
        assert!(basic.max_abs_diff(&opt) < 1e-10);
    }

    #[test]
    fn gram_identities_hold() {
        // The Section-3 derivation must agree with the literal Section-2
        // Grams: G01 = C - G11 where C[i][j] = c[j].
        let ds = SynthSpec::new(90, 7).sparsity(0.5).seed(3).generate();
        let d = ds.to_mat32();
        let nd = d.complement();
        let g11 = blas::gram(&d);
        let g01 = blas::gemm_at_b(&nd, &d).unwrap();
        let c = d.col_sums();
        for i in 0..7 {
            for j in 0..7 {
                assert_eq!(g01.get(i, j), c[j] - g11.get(i, j), "({i},{j})");
            }
        }
    }
}
