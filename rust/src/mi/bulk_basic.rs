//! Section-2 basic bulk algorithm (the paper's "Bas-NN" row): materialize
//! the complementary matrix ¬D and compute all four Gram matrices with
//! dense matmuls — deliberately 4x the matmul work of [`super::bulk_opt`];
//! the pair is the paper's basic-vs-optimized ablation. The element-wise
//! MI combine is the one shared exact core ([`super::bulk_opt::combine`]):
//! the Section-3 identities guarantee `(G11, colsums)` determine the other
//! three Grams, which the debug assertions below cross-check cell by cell.

use super::measure::{combine_block, CombineKind};
use super::MiMatrix;
use crate::data::dataset::BinaryDataset;
use crate::linalg::blas;

/// Full basic bulk MI (paper Section 2: four Gram matmuls).
pub fn mi_bulk_basic(ds: &BinaryDataset) -> MiMatrix {
    measure_bulk_basic(ds, CombineKind::Mi)
}

/// The Section-2 ablation with any combine measure: still pays the
/// deliberate 4x matmul cost, then applies the selected measure's
/// element-wise combine to the same `(G11, colsums, n)`.
pub fn measure_bulk_basic(ds: &BinaryDataset, measure: CombineKind) -> MiMatrix {
    let n = ds.n_rows() as f64;
    let m = ds.n_cols();
    let d = ds.to_mat32();
    let nd = d.complement(); // the dense ¬D the optimized path avoids

    // Step 2: the four Gram matrices (joint counts) — the ablation's cost.
    let g11 = blas::gram(&d);
    let g00 = blas::gram(&nd);
    let g01 = blas::gemm_at_b(&nd, &d).expect("same rows");
    let g10 = blas::gemm_at_b(&d, &nd).expect("same rows");

    // Step 3: marginal counts from the G11 diagonal.
    let c = g11.diag();

    // The literal Grams must satisfy the Section-3 identities the shared
    // combine relies on (G01 = C - G11 etc.) — checked in debug builds.
    for i in 0..m {
        for j in 0..m {
            debug_assert!((g01.get(i, j) - (c[j] - g11.get(i, j))).abs() < 1e-6, "G01({i},{j})");
            debug_assert!((g10.get(i, j) - (c[i] - g11.get(i, j))).abs() < 1e-6, "G10({i},{j})");
            debug_assert!(
                (g00.get(i, j) - (n - c[i] - c[j] + g11.get(i, j))).abs() < 1e-6,
                "G00({i},{j})"
            );
        }
    }

    // Steps 4-5: the shared exact combine on (G11, colsums, n).
    MiMatrix::from_mat(combine_block(measure, &g11, &c, &c, n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::mi::bulk_opt::mi_bulk_opt;
    use crate::mi::pairwise::mi_pairwise;

    #[test]
    fn matches_pairwise() {
        for &(n, m, s) in &[(150usize, 9usize, 0.9f64), (80, 21, 0.4)] {
            let ds = SynthSpec::new(n, m).sparsity(s).seed(m as u64).generate();
            let bulk = mi_bulk_basic(&ds);
            let pair = mi_pairwise(&ds);
            assert!(bulk.max_abs_diff(&pair) < 1e-10, "diff {}", bulk.max_abs_diff(&pair));
        }
    }

    #[test]
    fn matches_optimized() {
        let ds = SynthSpec::new(256, 30).sparsity(0.85).seed(2).generate();
        let basic = mi_bulk_basic(&ds);
        let opt = mi_bulk_opt(&ds);
        assert!(basic.max_abs_diff(&opt) < 1e-10);
    }

    #[test]
    fn gram_identities_hold() {
        // The Section-3 derivation must agree with the literal Section-2
        // Grams: G01 = C - G11 where C[i][j] = c[j].
        let ds = SynthSpec::new(90, 7).sparsity(0.5).seed(3).generate();
        let d = ds.to_mat32();
        let nd = d.complement();
        let g11 = blas::gram(&d);
        let g01 = blas::gemm_at_b(&nd, &d).unwrap();
        let c = d.col_sums();
        for i in 0..7 {
            for j in 0..7 {
                assert_eq!(g01.get(i, j), c[j] - g11.get(i, j), "({i},{j})");
            }
        }
    }
}
