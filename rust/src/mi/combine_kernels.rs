//! Table-driven, monomorphized combine kernels: the element-wise
//! measure map at Gram speed.
//!
//! The blockwise engine spends its inner loop mapping each Gram cell
//! `n11` (plus the block colsums) to a measure value. Before this
//! module that map was a scalar call per cell — an enum `match` plus up
//! to four transcendental `log2` evaluations (`CombineKind::combine`
//! via `mi_from_counts_f64`), ~2·m² of them per run: exactly the
//! per-element cost profile the paper's Section-3 bulk formulation
//! eliminates for the Gram itself. Two observations fix it:
//!
//! 1. **Every `log2` argument is an integral count in `[0, n]`.**
//!    Decompose each MI/entropy term into integer-argument logs,
//!    `(nxy/n)·log2(nxy·n/(nx·ny))
//!       = (nxy/n)·((log2 nxy + log2 n) − (log2 nx + log2 ny))`,
//!    and serve them from a once-per-job [`LogTable`] of `log2 k` for
//!    `k = 0..=n` (~8·(n+1) bytes, capped — see
//!    [`LogTable::MAX_ENTRIES`] — with a direct-`log2` fallback for
//!    huge `n` or non-integral arguments). The table is built once per
//!    run ([`crate::coordinator::executor`]) or per cluster job
//!    ([`crate::cluster::worker`]) and shared read-only across thread
//!    lanes.
//! 2. **The measure is loop-invariant.** A per-measure kernel struct
//!    ([`BlockKernel`]) lifts the `match` out of the inner loop and
//!    hoists every per-row/per-column invariant — the marginal, its
//!    log, the Nmi/Vi marginal-entropy values, Chi2's constant-column
//!    precheck — so the column loop is a branch-light map over `n11`.
//!
//! # The bit-identity contract
//!
//! All counts and marginals off a Gram are exact integers in f64
//! (`< 2^53`), so any algebraically-equal *integer* derivation of them
//! is bitwise equal; only divisions by `n`, `log2`, and the final
//! sums/products round. The kernels therefore evaluate the *same*
//! expression tree as the scalar core — [`CombineKind::combine`]
//! delegates to [`combine_cell`], which runs the identical kernel cell
//! in direct-log mode — and `table[k] = (k as f64).log2()` at build
//! time is bit-identical to evaluating `x.log2()` at `x == k as f64`,
//! so table mode ≡ direct mode. Consequence: scalar ≡ block ≡ streamed
//! for every measure, bitwise, and the swap-invariant summation tree
//! `(t11 + t00) + (t10 + t01)` (see [`crate::mi::counts`]) survives
//! unchanged, preserving the engine's mirror-write exactness.
//!
//! The one number this decomposition moves: exactly-independent counts
//! no longer cancel to ±0.0 inside each term (the old
//! `log2(nxy·n/(nx·ny)) = log2(1) = 0` cancellation), so MI at exact
//! independence is ~1e-15 instead of 0.0 — still far inside the 1e-12
//! oracle tolerance every measure is validated against.

use super::measure::CombineKind;
use crate::linalg::dense::Mat64;
use std::f64::consts::LN_2;

/// Precomputed `log2 k` for integral counts `k = 0..=n`, the shared
/// lookup the combine kernels replace transcendental calls with.
///
/// `table[0]` is `-inf`, exactly like `(0.0).log2()`; every use is
/// behind the `nxy > 0` / `0 < c < n` guards the measures already
/// carry, so no infinity ever reaches a result. An empty table
/// ([`LogTable::direct`]) makes every lookup fall through to
/// `x.log2()` — bit-identical by construction, just slower — which is
/// also the capacity fallback for `n` past [`LogTable::MAX_ENTRIES`].
pub struct LogTable {
    table: Vec<f64>,
}

impl LogTable {
    /// Capacity cap: 2²² entries = 32 MiB. Datasets with more rows than
    /// this fall back to direct `log2` (the table would stop fitting in
    /// cache long before, so nothing of value is lost).
    pub const MAX_ENTRIES: usize = 1 << 22;

    /// Build the table covering every count a run over `n_rows` rows
    /// can produce (`0..=n_rows`), or the direct fallback when that
    /// would exceed [`LogTable::MAX_ENTRIES`].
    pub fn new(n_rows: usize) -> LogTable {
        if n_rows >= Self::MAX_ENTRIES {
            return LogTable::direct();
        }
        LogTable { table: (0..=n_rows).map(|k| (k as f64).log2()).collect() }
    }

    /// The no-allocation fallback: every lookup computes `x.log2()`
    /// directly. Bit-identical to table mode for integral arguments.
    pub fn direct() -> LogTable {
        LogTable { table: Vec::new() }
    }

    /// Build a table only when the block is large enough to amortize
    /// it: constructing `n+1` logs to serve fewer than `n` cells is a
    /// net loss, so small one-shot maps (streaming snapshots of a few
    /// columns, tiny blocks) stay on the direct path. Either choice
    /// yields identical bits.
    pub fn sized_for(n: f64, cells: usize) -> LogTable {
        if !(n.is_finite() && n >= 0.0) {
            return LogTable::direct();
        }
        let k = n as usize;
        if cells >= k { LogTable::new(k) } else { LogTable::direct() }
    }

    pub fn is_direct(&self) -> bool {
        self.table.is_empty()
    }

    /// Table memory in bytes (0 for the direct fallback) — the
    /// `~8·(n+1)` term the planner's `task_bytes` model footnotes.
    pub fn bytes(&self) -> usize {
        self.table.len() * std::mem::size_of::<f64>()
    }

    /// `log2 x`, from the table when `x` is an in-range integer, else
    /// computed directly. (The float→int cast saturates, so negative,
    /// NaN and huge inputs all take the `x.log2()` branch or fail the
    /// round-trip check — never an out-of-bounds read.)
    #[inline]
    pub fn log2(&self, x: f64) -> f64 {
        let i = x as usize;
        if i < self.table.len() && i as f64 == x {
            self.table[i]
        } else {
            x.log2()
        }
    }
}

/// Per-marginal logs hoisted once per row/column: `l1 = log2 c`,
/// `l0 = log2 (n − c)`.
#[derive(Clone, Copy)]
struct MargLogs {
    l1: f64,
    l0: f64,
}

#[inline]
fn marg_logs(lt: &LogTable, n: f64, c1: f64) -> MargLogs {
    MargLogs { l1: lt.log2(c1), l0: lt.log2(n - c1) }
}

/// The decomposed MI sum (bits). `ln = log2 n`; `r`/`c` carry the
/// marginal logs. The summation tree `(t11 + t00) + (t10 + t01)` and
/// the commutative `(lx + ly)` pairing keep the result bitwise
/// invariant under the `(i, j) -> (j, i)` swap, exactly like the
/// pre-decomposition form in [`crate::mi::counts`].
#[inline]
fn mi_bits(
    lt: &LogTable,
    n: f64,
    ln: f64,
    r: MargLogs,
    c: MargLogs,
    n11: f64,
    n10: f64,
    n01: f64,
    n00: f64,
) -> f64 {
    let term = |nxy: f64, lx: f64, ly: f64| -> f64 {
        if nxy > 0.0 {
            (nxy / n) * ((lt.log2(nxy) + ln) - (lx + ly))
        } else {
            0.0
        }
    };
    (term(n11, r.l1, c.l1) + term(n00, r.l0, c.l0)) + (term(n10, r.l1, c.l0) + term(n01, r.l0, c.l1))
}

/// Marginal entropy in bits from the *count* `c1` (not the
/// probability): `H = (c1/n)·(log2 n − log2 c1) + (c0/n)·(log2 n −
/// log2 c0)` — the same integer-argument decomposition as [`mi_bits`],
/// so Nmi/Vi stay on table lookups. Constant columns (`c1 <= 0` or
/// `c1 >= n`) contribute exactly 0, matching
/// [`crate::mi::counts::entropy_bits`]'s convention.
#[inline]
fn entropy_from_count(lt: &LogTable, n: f64, ln: f64, c1: f64) -> f64 {
    if c1 <= 0.0 || c1 >= n {
        return 0.0;
    }
    let c0 = n - c1;
    (c1 / n) * (ln - lt.log2(c1)) + (c0 / n) * (ln - lt.log2(c0))
}

/// One measure's block kernel: `row`/`col` hoist per-marginal
/// invariants, `cell` is the branch-light inner-loop body. Kernels are
/// monomorphized through [`map_block`], so the measure `match` runs
/// once per block, not once per cell.
trait BlockKernel {
    type Row: Copy;
    type Col: Copy;
    fn row(&self, c1: f64) -> Self::Row;
    fn col(&self, c1: f64) -> Self::Col;
    fn cell(&self, r: Self::Row, c: Self::Col, n11: f64, n10: f64, n01: f64, n00: f64) -> f64;
}

struct MiKernel<'a> {
    lt: &'a LogTable,
    n: f64,
    ln: f64,
}

impl<'a> MiKernel<'a> {
    fn new(lt: &'a LogTable, n: f64) -> MiKernel<'a> {
        MiKernel { lt, n, ln: lt.log2(n) }
    }
}

impl BlockKernel for MiKernel<'_> {
    type Row = MargLogs;
    type Col = MargLogs;
    fn row(&self, c1: f64) -> MargLogs {
        marg_logs(self.lt, self.n, c1)
    }
    fn col(&self, c1: f64) -> MargLogs {
        marg_logs(self.lt, self.n, c1)
    }
    #[inline]
    fn cell(&self, r: MargLogs, c: MargLogs, n11: f64, n10: f64, n01: f64, n00: f64) -> f64 {
        mi_bits(self.lt, self.n, self.ln, r, c, n11, n10, n01, n00)
    }
}

/// Marginal logs plus the marginal entropy — the Nmi/Vi row/col state.
#[derive(Clone, Copy)]
struct EntMarg {
    logs: MargLogs,
    h: f64,
}

struct NmiKernel<'a> {
    lt: &'a LogTable,
    n: f64,
    ln: f64,
}

impl<'a> NmiKernel<'a> {
    fn new(lt: &'a LogTable, n: f64) -> NmiKernel<'a> {
        NmiKernel { lt, n, ln: lt.log2(n) }
    }
    fn marg(&self, c1: f64) -> EntMarg {
        EntMarg {
            logs: marg_logs(self.lt, self.n, c1),
            h: entropy_from_count(self.lt, self.n, self.ln, c1),
        }
    }
}

impl BlockKernel for NmiKernel<'_> {
    type Row = EntMarg;
    type Col = EntMarg;
    fn row(&self, c1: f64) -> EntMarg {
        self.marg(c1)
    }
    fn col(&self, c1: f64) -> EntMarg {
        self.marg(c1)
    }
    #[inline]
    fn cell(&self, r: EntMarg, c: EntMarg, n11: f64, n10: f64, n01: f64, n00: f64) -> f64 {
        let mi = mi_bits(self.lt, self.n, self.ln, r.logs, c.logs, n11, n10, n01, n00);
        // min of non-negative entropies: symmetric bitwise (no NaN, no -0.0)
        let denom = r.h.min(c.h);
        if denom > 0.0 { (mi / denom).clamp(0.0, 1.0) } else { 0.0 }
    }
}

struct ViKernel<'a> {
    inner: NmiKernel<'a>,
}

impl BlockKernel for ViKernel<'_> {
    type Row = EntMarg;
    type Col = EntMarg;
    fn row(&self, c1: f64) -> EntMarg {
        self.inner.marg(c1)
    }
    fn col(&self, c1: f64) -> EntMarg {
        self.inner.marg(c1)
    }
    #[inline]
    fn cell(&self, r: EntMarg, c: EntMarg, n11: f64, n10: f64, n01: f64, n00: f64) -> f64 {
        let k = &self.inner;
        let mi = mi_bits(k.lt, k.n, k.ln, r.logs, c.logs, n11, n10, n01, n00);
        // hx + hy is a commutative add: swap-invariant
        (r.h + c.h - 2.0 * mi).max(0.0)
    }
}

struct GStatKernel<'a> {
    inner: MiKernel<'a>,
    scale: f64,
}

impl<'a> GStatKernel<'a> {
    fn new(lt: &'a LogTable, n: f64) -> GStatKernel<'a> {
        // same tree as the scalar `2.0 * n * LN_2 * mi`: ((2·n)·ln2)·mi
        GStatKernel { inner: MiKernel::new(lt, n), scale: 2.0 * n * LN_2 }
    }
}

impl BlockKernel for GStatKernel<'_> {
    type Row = MargLogs;
    type Col = MargLogs;
    fn row(&self, c1: f64) -> MargLogs {
        self.inner.row(c1)
    }
    fn col(&self, c1: f64) -> MargLogs {
        self.inner.col(c1)
    }
    #[inline]
    fn cell(&self, r: MargLogs, c: MargLogs, n11: f64, n10: f64, n01: f64, n00: f64) -> f64 {
        self.scale * self.inner.cell(r, c, n11, n10, n01, n00)
    }
}

/// Chi2/Phi marginal state: both counts plus the constant-column flag,
/// checked once per row/column instead of once per cell.
#[derive(Clone, Copy)]
struct ChiMarg {
    m1: f64,
    m0: f64,
    ok: bool,
}

struct Chi2Kernel {
    n: f64,
}

impl Chi2Kernel {
    fn marg(&self, c1: f64) -> ChiMarg {
        let m0 = self.n - c1;
        ChiMarg { m1: c1, m0, ok: c1 > 0.0 && m0 > 0.0 }
    }
}

impl BlockKernel for Chi2Kernel {
    type Row = ChiMarg;
    type Col = ChiMarg;
    fn row(&self, c1: f64) -> ChiMarg {
        self.marg(c1)
    }
    fn col(&self, c1: f64) -> ChiMarg {
        self.marg(c1)
    }
    #[inline]
    fn cell(&self, r: ChiMarg, c: ChiMarg, n11: f64, n10: f64, n01: f64, n00: f64) -> f64 {
        if !(r.ok && c.ok) {
            return 0.0; // a constant column: no deviation possible
        }
        let n = self.n;
        let term = |obs: f64, nx: f64, ny: f64| -> f64 {
            let e = nx * ny / n;
            let d = obs - e;
            d * d / e
        };
        // swap-invariant tree, mirroring mi_bits
        (term(n11, r.m1, c.m1) + term(n00, r.m0, c.m0))
            + (term(n10, r.m1, c.m0) + term(n01, r.m0, c.m1))
    }
}

struct PhiKernel {
    n: f64,
}

impl BlockKernel for PhiKernel {
    /// `r1 · r0`, the row half of the denominator product.
    type Row = f64;
    type Col = f64;
    fn row(&self, c1: f64) -> f64 {
        c1 * (self.n - c1)
    }
    fn col(&self, c1: f64) -> f64 {
        c1 * (self.n - c1)
    }
    #[inline]
    fn cell(&self, rr: f64, kk: f64, n11: f64, n10: f64, n01: f64, n00: f64) -> f64 {
        let denom = (rr * kk).sqrt();
        if denom > 0.0 { (n11 * n00 - n10 * n01) / denom } else { 0.0 }
    }
}

struct JaccardKernel;

impl BlockKernel for JaccardKernel {
    type Row = ();
    type Col = ();
    fn row(&self, _c1: f64) {}
    fn col(&self, _c1: f64) {}
    #[inline]
    fn cell(&self, _r: (), _c: (), n11: f64, n10: f64, n01: f64, _n00: f64) -> f64 {
        let union = n11 + (n10 + n01);
        if union > 0.0 { n11 / union } else { 0.0 }
    }
}

struct OchiaiKernel;

impl BlockKernel for OchiaiKernel {
    /// The ones-marginal itself.
    type Row = f64;
    type Col = f64;
    fn row(&self, c1: f64) -> f64 {
        c1
    }
    fn col(&self, c1: f64) -> f64 {
        c1
    }
    #[inline]
    fn cell(&self, r1: f64, k1: f64, n11: f64, _n10: f64, _n01: f64, _n00: f64) -> f64 {
        let denom = (r1 * k1).sqrt();
        if denom > 0.0 { n11 / denom } else { 0.0 }
    }
}

/// The monomorphized block loop: hoists the `n <= 0` guard, the row
/// marginal and `r0 = n − r1`, and the kernel's row/column state out of
/// the inner loop; the cell-count derivation keeps the exact expression
/// tree of the historical scalar loop (`n00 = ((n − ci) − cj) + n11`),
/// which is integer-exact anyway.
fn map_block<K: BlockKernel>(k: &K, g11: &Mat64, ca: &[f64], cb: &[f64], n: f64) -> Mat64 {
    let (ma, mb) = (g11.rows(), g11.cols());
    assert_eq!(ca.len(), ma, "colsums_a length");
    assert_eq!(cb.len(), mb, "colsums_b length");
    let mut out = Mat64::zeros(ma, mb);
    if n <= 0.0 {
        return out; // the scalar core's n <= 0 guard, hoisted
    }
    let cols: Vec<K::Col> = cb.iter().map(|&c| k.col(c)).collect();
    for i in 0..ma {
        let ci = ca[i];
        let r = k.row(ci);
        let r0 = n - ci;
        let grow = g11.row(i);
        let orow = &mut out.data_mut()[i * mb..(i + 1) * mb];
        for j in 0..mb {
            let n11 = grow[j];
            let cj = cb[j];
            let n10 = ci - n11;
            let n01 = cj - n11;
            let n00 = (r0 - cj) + n11;
            orow[j] = k.cell(r, cols[j], n11, n10, n01, n00);
        }
    }
    out
}

/// Element-wise combine of a Gram block through the table-driven
/// kernels. The workhorse behind
/// [`crate::mi::measure::combine_block`]; callers that amortize one
/// [`LogTable`] across many blocks (the executor, cluster workers, the
/// autotune prober) invoke this directly.
pub fn combine_block_with(
    kind: CombineKind,
    lt: &LogTable,
    g11: &Mat64,
    ca: &[f64],
    cb: &[f64],
    n: f64,
) -> Mat64 {
    match kind {
        CombineKind::Mi => map_block(&MiKernel::new(lt, n), g11, ca, cb, n),
        CombineKind::Nmi => map_block(&NmiKernel::new(lt, n), g11, ca, cb, n),
        CombineKind::Vi => map_block(&ViKernel { inner: NmiKernel::new(lt, n) }, g11, ca, cb, n),
        CombineKind::GStat => map_block(&GStatKernel::new(lt, n), g11, ca, cb, n),
        CombineKind::Chi2 => map_block(&Chi2Kernel { n }, g11, ca, cb, n),
        CombineKind::Phi => map_block(&PhiKernel { n }, g11, ca, cb, n),
        CombineKind::Jaccard => map_block(&JaccardKernel, g11, ca, cb, n),
        CombineKind::Ochiai => map_block(&OchiaiKernel, g11, ca, cb, n),
    }
}

/// The shared scalar core: one cell of `kind` from the four joint
/// counts, evaluated through the same kernel `cell` bodies as the block
/// path, in direct-log mode — which is what makes scalar ≡ block
/// bit-identical. [`CombineKind::combine`] is a thin wrapper over this.
#[inline]
pub fn combine_cell(kind: CombineKind, n: f64, c00: f64, c01: f64, c10: f64, c11: f64) -> f64 {
    if n <= 0.0 {
        return 0.0;
    }
    let lt = LogTable::direct();
    let r1 = c11 + c10; // X = 1 marginal
    let k1 = c11 + c01; // Y = 1 marginal
    macro_rules! via {
        ($k:expr) => {{
            let k = $k;
            k.cell(k.row(r1), k.col(k1), c11, c10, c01, c00)
        }};
    }
    match kind {
        CombineKind::Mi => via!(MiKernel::new(&lt, n)),
        CombineKind::Nmi => via!(NmiKernel::new(&lt, n)),
        CombineKind::Vi => via!(ViKernel { inner: NmiKernel::new(&lt, n) }),
        CombineKind::GStat => via!(GStatKernel::new(&lt, n)),
        CombineKind::Chi2 => via!(Chi2Kernel { n }),
        CombineKind::Phi => via!(PhiKernel { n }),
        CombineKind::Jaccard => via!(JaccardKernel),
        CombineKind::Ochiai => via!(OchiaiKernel),
    }
}

/// The decomposed MI cell in direct-log mode — the single expression
/// every MI path in the crate now evaluates
/// ([`crate::mi::counts::mi_from_counts_f64`] and
/// [`crate::mi::counts::mi_from_counts_u64`] delegate here).
#[inline]
pub fn mi_cell_direct(n11: f64, n10: f64, n01: f64, n00: f64, n: f64) -> f64 {
    if n <= 0.0 {
        return 0.0;
    }
    let lt = LogTable::direct();
    let k = MiKernel::new(&lt, n);
    k.cell(k.row(n11 + n10), k.col(n11 + n01), n11, n10, n01, n00)
}

/// The pre-kernel combine shape — per-cell marginal derivation plus the
/// enum-dispatched scalar [`CombineKind::combine`] — kept as the
/// reference loop benches and tests measure the block kernels against.
/// Bit-identical to [`combine_block_with`] (same cell cores, direct-log
/// mode), just slower.
pub fn combine_block_scalar(
    kind: CombineKind,
    g11: &Mat64,
    ca: &[f64],
    cb: &[f64],
    n: f64,
) -> Mat64 {
    let (ma, mb) = (g11.rows(), g11.cols());
    assert_eq!(ca.len(), ma, "colsums_a length");
    assert_eq!(cb.len(), mb, "colsums_b length");
    let mut out = Mat64::zeros(ma, mb);
    for i in 0..ma {
        let ci = ca[i];
        let grow = g11.row(i);
        let orow = &mut out.data_mut()[i * mb..(i + 1) * mb];
        for j in 0..mb {
            let n11 = grow[j];
            let n10 = ci - n11;
            let n01 = cb[j] - n11;
            let n00 = n - ci - cb[j] + n11;
            orow[j] = kind.combine(n, n00, n01, n10, n11);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn table_lookup_is_bit_identical_to_direct() {
        let lt = LogTable::new(1000);
        assert!(!lt.is_direct());
        assert_eq!(lt.bytes(), 1001 * 8);
        for k in 0..=1000usize {
            let x = k as f64;
            assert_eq!(lt.log2(x).to_bits(), x.log2().to_bits(), "k = {k}");
        }
        // out-of-range, non-integral, negative, NaN: all fall through
        for x in [1001.0, 1e9, 2.5, -3.0, -0.0, f64::NAN, f64::INFINITY] {
            assert_eq!(lt.log2(x).to_bits(), x.log2().to_bits(), "x = {x}");
        }
        assert_eq!(lt.log2(0.0), f64::NEG_INFINITY);
    }

    #[test]
    fn capacity_cap_falls_back_to_direct() {
        let lt = LogTable::new(LogTable::MAX_ENTRIES);
        assert!(lt.is_direct());
        assert_eq!(lt.bytes(), 0);
        // direct mode still answers everything
        assert_eq!(lt.log2(8.0), 3.0);
        // sized_for: too few cells to amortize -> direct; enough -> table
        assert!(LogTable::sized_for(1000.0, 99).is_direct());
        assert!(!LogTable::sized_for(1000.0, 10_000).is_direct());
        assert!(LogTable::sized_for(f64::NAN, 10_000).is_direct());
        assert!(LogTable::sized_for(-5.0, 10_000).is_direct());
    }

    /// The tentpole property: for every measure, the table-driven block
    /// kernel, the direct-mode block kernel and the per-cell scalar
    /// loop produce the same bits — on a square Gram with edge-case
    /// columns (all-zero, all-one) baked in.
    #[test]
    fn block_kernels_bit_match_scalar_on_edge_columns() {
        // hand-built 97x10 dataset: col 0 all-zero (c = 0), col 1
        // all-one (c = n), the rest pseudo-random
        let (n_rows, n_cols) = (97usize, 10usize);
        let mut data = vec![0u8; n_rows * n_cols];
        let mut state = 0xD1CEu64;
        for r in 0..n_rows {
            for c in 2..n_cols {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                data[r * n_cols + c] = ((state >> 60) & 1) as u8;
            }
            data[r * n_cols + 1] = 1;
        }
        let ds = crate::data::dataset::BinaryDataset::new(n_rows, n_cols, data).unwrap();
        let g = ds.to_bitmatrix().gram();
        let c: Vec<f64> = ds.col_counts().iter().map(|&v| v as f64).collect();
        let n = 97.0;
        let table = LogTable::new(97);
        let direct = LogTable::direct();
        for kind in CombineKind::ALL {
            let fast = combine_block_with(kind, &table, &g, &c, &c, n);
            let fallback = combine_block_with(kind, &direct, &g, &c, &c, n);
            let scalar = combine_block_scalar(kind, &g, &c, &c, n);
            assert_eq!(fast.max_abs_diff(&fallback), 0.0, "{kind}: table vs direct");
            assert_eq!(fast.max_abs_diff(&scalar), 0.0, "{kind}: block vs scalar");
        }
    }

    /// Same property on a rectangular cross-block (distinct row/col
    /// column sets, distinct marginals on each axis).
    #[test]
    fn rectangular_cross_blocks_bit_match_scalar() {
        let ds = SynthSpec::new(64, 12).sparsity(0.4).seed(23).generate();
        let bits = ds.to_bitmatrix();
        let a = bits.col_block(0, 5).unwrap();
        let b = bits.col_block(5, 7).unwrap();
        let g = a.gram_cross(&b).unwrap();
        let c: Vec<f64> = ds.col_counts().iter().map(|&v| v as f64).collect();
        let (ca, cb) = (&c[0..5], &c[5..12]);
        let lt = LogTable::new(64);
        for kind in CombineKind::ALL {
            let fast = combine_block_with(kind, &lt, &g, ca, cb, 64.0);
            let scalar = combine_block_scalar(kind, &g, ca, cb, 64.0);
            assert_eq!(fast.max_abs_diff(&scalar), 0.0, "{kind}");
            assert_eq!(fast.rows(), 5);
            assert_eq!(fast.cols(), 7);
        }
    }

    /// Random integral 2x2 tables, including degenerate totals
    /// `n ∈ {0, 1}`: the scalar wrapper and the 1x1-block kernel agree
    /// bitwise cell by cell.
    #[test]
    fn random_tables_and_tiny_n_bit_match() {
        let mut state = 0x9E3779B97F4A7C15u64;
        let mut next = move |m: u64| {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) % (m + 1)
        };
        let mut tables: Vec<[f64; 5]> = vec![
            [0.0, 0.0, 0.0, 0.0, 0.0], // n = 0
            [1.0, 1.0, 0.0, 0.0, 0.0], // n = 1, the single row is (0,0)
            [1.0, 0.0, 0.0, 0.0, 1.0], // n = 1, the single row is (1,1)
        ];
        for _ in 0..200 {
            let n11 = next(40);
            let n10 = next(40);
            let n01 = next(40);
            let n00 = next(40);
            let n = n11 + n10 + n01 + n00;
            tables.push([n as f64, n00 as f64, n01 as f64, n10 as f64, n11 as f64]);
        }
        for &[n, c00, c01, c10, c11] in &tables {
            let lt = LogTable::new(n as usize);
            let mut g = Mat64::zeros(1, 1);
            g.set(0, 0, c11);
            let ca = [c11 + c10];
            let cb = [c11 + c01];
            for kind in CombineKind::ALL {
                let scalar = kind.combine(n, c00, c01, c10, c11);
                let block = combine_block_with(kind, &lt, &g, &ca, &cb, n).get(0, 0);
                assert_eq!(
                    scalar.to_bits(),
                    block.to_bits(),
                    "{kind} on n={n} ({c00},{c01},{c10},{c11})"
                );
                assert!(scalar.is_finite(), "{kind} not finite on n={n}");
            }
        }
    }

    #[test]
    fn entropy_from_count_matches_probability_form() {
        use crate::mi::counts::entropy_bits;
        let lt = LogTable::new(64);
        let n = 64.0;
        let ln = lt.log2(n);
        for c in 0..=64 {
            let c = c as f64;
            let got = entropy_from_count(&lt, n, ln, c);
            let want = entropy_bits(c / n);
            assert!((got - want).abs() < 1e-12, "c = {c}: {got} vs {want}");
            assert!(got >= 0.0);
        }
    }
}
