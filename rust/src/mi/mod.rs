//! Mutual-information computation: the paper's contribution.
//!
//! * [`counts`] — the scalar core: MI (bits) from a 2x2 contingency.
//! * [`pairwise`] — the sequential per-pair baseline (SKL-pairwise row).
//! * [`bulk_basic`] — Section 2: four dense Gram matrices (Bas-NN row).
//! * [`bulk_opt`] — Section 3: one Gram + N/C derivation (Opt-NN row).
//! * [`bulk_sparse`] — Section 3 on CSR (Opt-SS row).
//! * [`bulk_bitpack`] — Section 3 on AND+popcount (hardware-optimized).
//! * [`xla`] — Section 3 through the AOT Pallas/XLA artifacts (Opt-T row).
//! * [`backend`] — the `MiBackend` trait and dispatch.
//! * [`autotune`] — the `--backend auto` micro-prober: picks the
//!   fastest native substrate for this machine and dataset, caching
//!   verdicts per dataset shape within the process.
//! * [`measure`] — the pluggable combine layer: every association
//!   measure the 2x2 table determines (MI, normalized MI, variation of
//!   information, G-statistic, χ², φ, Jaccard, Ochiai) from the same
//!   single Gram.
//! * [`combine_kernels`] — the table-driven, monomorphized block
//!   kernels behind that combine layer: integer-argument log
//!   decomposition served from a once-per-job `LogTable`, bit-identical
//!   to the scalar core.
//! * [`sink`] — streaming consumers of MI blocks (dense / top-k /
//!   threshold / disk-spill); what decouples computing all pairs from
//!   storing all pairs.
//! * [`significance`] — bias correction, permutation tests, and the
//!   G-test χ²₁ asymptotics converting p-value cutoffs to MI
//!   thresholds.
//! * [`entropy`], [`topk`] — analysis utilities on MI matrices.
//!
//! A contributor-level walkthrough of how these fit together — from
//! CSV/stream ingestion through packing, kernel dispatch, the
//! blockwise engine, and the sinks — lives in `docs/ARCHITECTURE.md`
//! at the repository root.

pub mod autotune;
pub mod backend;
pub mod bulk_basic;
pub mod categorical;
pub mod bulk_bitpack;
pub mod bulk_opt;
pub mod bulk_sparse;
pub mod combine_kernels;
pub mod counts;
pub mod entropy;
pub mod measure;
pub mod pairwise;
pub mod significance;
pub mod sink;
pub mod topk;
pub mod xla;

use crate::linalg::dense::Mat64;

/// A symmetric m x m mutual-information matrix in bits.
#[derive(Clone, Debug)]
pub struct MiMatrix {
    mat: Mat64,
}

impl MiMatrix {
    pub fn from_mat(mat: Mat64) -> Self {
        debug_assert_eq!(mat.rows(), mat.cols());
        MiMatrix { mat }
    }

    /// Number of variables (columns of the source dataset).
    pub fn dim(&self) -> usize {
        self.mat.rows()
    }

    /// MI between variables i and j, in bits.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.mat.get(i, j)
    }

    pub fn as_mat(&self) -> &Mat64 {
        &self.mat
    }

    pub fn data(&self) -> &[f64] {
        self.mat.data()
    }

    /// Largest |self - other| cell difference.
    pub fn max_abs_diff(&self, other: &MiMatrix) -> f64 {
        self.mat.max_abs_diff(&other.mat)
    }

    /// Largest asymmetry |M[i][j] - M[j][i]|.
    pub fn max_asymmetry(&self) -> f64 {
        let m = self.dim();
        let mut worst = 0.0f64;
        for i in 0..m {
            for j in (i + 1)..m {
                worst = worst.max((self.get(i, j) - self.get(j, i)).abs());
            }
        }
        worst
    }

    /// Smallest cell value (MI is non-negative up to rounding).
    pub fn min_value(&self) -> f64 {
        self.mat.data().iter().copied().fold(f64::INFINITY, f64::min)
    }
}
