//! Streaming MI sinks: where combined MI blocks go.
//!
//! The blockwise engine ([`crate::coordinator::executor`]) produces the
//! exact MI values of one column-block pair at a time. A [`MiSink`]
//! decides what to *keep* from that stream, which decouples the cost of
//! computing all-pairs MI (cheap, the paper's contribution) from the
//! cost of storing all pairs (the m x m dense matrix that caps m on
//! real hardware: m = 100k already needs ~80 GB).
//!
//! Shipped sinks:
//!
//! | sink | keeps | memory | use case |
//! |------|-------|--------|----------|
//! | [`DenseSink`] | every cell | m² x 8 B | full matrix (legacy behaviour) |
//! | [`TopKSink`] | k strongest pairs | O(k) | feature selection, screening |
//! | [`ThresholdSink`] | pairs ≥ cutoff | O(nnz) | MI networks, p-value screens |
//! | [`TileSpillSink`] | every cell, on disk | O(block²) | out-of-core m |
//!
//! `DenseSink` is bit-identical to the historical `MiMatrix` assembly;
//! `TopKSink`/`ThresholdSink` agree exactly with post-hoc extraction
//! from the full matrix (property-tested in `rust/tests/sinks.rs`).

use super::measure::CombineKind;
use super::topk::MiPair;
use super::MiMatrix;
use crate::coordinator::planner::BlockTask;
use crate::linalg::dense::Mat64;
use crate::util::error::{Error, Result};
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::path::{Path, PathBuf};

/// A consumer of combined MI blocks.
///
/// `consume_block` receives the exact MI values for the task's
/// `(a, b)` column-block pair; for off-diagonal tasks the mirrored
/// `(b, a)` region is implied and must be materialized by the sink if
/// it keeps dense state. Blocks arrive in arbitrary order (the parallel
/// executor consumes them on a single collector thread, so `&mut self`
/// is safe), and every (i, j) cell is delivered exactly once per run —
/// the planner's coverage invariant.
///
/// `finish` is called once, after every block was consumed.
pub trait MiSink: Send {
    /// Short identifier for logs and bench output.
    fn name(&self) -> &'static str {
        "sink"
    }

    /// Consume the combined MI block for `task` (shape `a_len x b_len`).
    fn consume_block(&mut self, task: &BlockTask, block: &Mat64) -> Result<()>;

    /// Fold another sink's *finished* state into this one, before this
    /// sink's own [`MiSink::finish`]. This is the distributed-run
    /// contract ([`crate::cluster`]): the coordinator retains one shard
    /// sink per worker connection and merges them into the primary, so
    /// correctness rests on the planner's exactly-once coverage — two
    /// shards never retain the same `(i, j)` cell, and every sink's
    /// retained state is a pure function of the cell set it saw (the
    /// top-k rank order is partition-independent, threshold/COO
    /// concatenates and sorts at finish, dense regions are disjoint).
    /// The default refuses: a sink that cannot merge must not silently
    /// drop a shard's results.
    fn merge(&mut self, other: SinkData) -> Result<()> {
        Err(Error::Coordinator(format!(
            "sink {} cannot merge {} shard state",
            self.name(),
            other.kind_name()
        )))
    }

    /// Finalize and return whatever the sink retained.
    fn finish(&mut self) -> Result<SinkOutput>;
}

/// What a sink retained (the payload half of a [`SinkOutput`]).
#[derive(Clone, Debug)]
pub enum SinkData {
    /// The full dense matrix.
    Dense(MiMatrix),
    /// The k strongest pairs, best first.
    TopK(Vec<MiPair>),
    /// Per-column strongest pairs, best first within each column.
    TopKPerColumn(Vec<Vec<MiPair>>),
    /// Sparse COO of above-threshold pairs.
    Sparse(SparsePairs),
    /// Tiles written to disk.
    Spilled(SpillInfo),
}

impl SinkData {
    /// Stable identifier of the output shape.
    pub fn kind_name(&self) -> &'static str {
        match self {
            SinkData::Dense(_) => "dense",
            SinkData::TopK(_) => "topk",
            SinkData::TopKPerColumn(_) => "topk-per-col",
            SinkData::Sparse(_) => "sparse",
            SinkData::Spilled(_) => "spill",
        }
    }

    /// The dense matrix, when this output holds one.
    pub fn into_dense(self) -> Option<MiMatrix> {
        match self {
            SinkData::Dense(mi) => Some(mi),
            _ => None,
        }
    }

    /// Bytes of in-memory result state this output holds (disk bytes of
    /// a spilled run are reported in its [`SpillInfo`] instead).
    pub fn state_bytes(&self) -> usize {
        const PAIR: usize = std::mem::size_of::<MiPair>();
        match self {
            SinkData::Dense(mi) => mi.dim() * mi.dim() * 8,
            SinkData::TopK(pairs) => pairs.len() * PAIR,
            SinkData::TopKPerColumn(cols) => {
                cols.iter().map(|c| c.len() * PAIR).sum()
            }
            SinkData::Sparse(sp) => sp.pairs.len() * PAIR,
            SinkData::Spilled(_) => 0,
        }
    }

    /// One-line human summary (job service / CLI reporting).
    pub fn summary(&self) -> String {
        match self {
            SinkData::Dense(mi) => format!("dense {0} x {0} matrix", mi.dim()),
            SinkData::TopK(pairs) => format!("top-{} pairs", pairs.len()),
            SinkData::TopKPerColumn(cols) => {
                format!("per-column top pairs over {} columns", cols.len())
            }
            SinkData::Sparse(sp) => {
                format!("{} pairs >= MI {:.6}", sp.pairs.len(), sp.threshold)
            }
            SinkData::Spilled(info) => format!(
                "{} tiles / {} bytes spilled to {}",
                info.tiles,
                info.bytes,
                info.dir.display()
            ),
        }
    }
}

/// How a run was executed: filled in by whoever drives the engine (the
/// job service, the CLI sink path) after `finish()`. Sinks themselves
/// know nothing about backends, so a bare `SinkOutput` built from
/// [`SinkData`] carries an empty meta.
#[derive(Clone, Debug, Default)]
pub struct SinkMeta {
    /// Backend the Gram blocks were actually computed with.
    pub backend: Option<String>,
    /// Backend the caller asked for (`"auto"` when the autotuner chose
    /// [`Self::backend`]).
    pub requested_backend: Option<String>,
    /// The process-wide AND-popcount kernel
    /// ([`crate::linalg::kernels::active`]).
    pub kernel: Option<String>,
    /// The association measure the run's combine stage computed
    /// ([`crate::mi::measure::CombineKind::name`]); `None` on legacy
    /// paths that never set it, which always means MI.
    pub measure: Option<String>,
    /// The autotuner's probe report, when the run was `--backend auto`
    /// (its [`cached`](crate::mi::autotune::ProbeReport::cached) flag
    /// records whether the verdict came from the probe cache).
    pub probe: Option<crate::mi::autotune::ProbeReport>,
    /// How the executed plan's column-block width was decided, when the
    /// driving layer planned blockwise.
    pub sizing: Option<BlockSizing>,
    /// Read-side I/O of the run, when the source is instrumented
    /// (file-backed sources; `None` for in-memory runs).
    pub io: Option<IoReport>,
    /// Block-substrate cache behaviour, when a cache was attached.
    pub cache: Option<CacheReport>,
    /// Gram-tile result-cache behaviour, when the run consulted the
    /// content-addressed tile cache
    /// (`crate::coordinator::tilecache`).
    pub tiles: Option<TileCacheReport>,
    /// Task-ordering policy of the executed plan
    /// ([`crate::coordinator::scheduler::Schedule::name`]).
    pub schedule: Option<&'static str>,
    /// How the job service's byte gate priced and queued the run
    /// (`None` outside the service; see
    /// `crate::coordinator::admission`).
    pub admission: Option<AdmissionReport>,
    /// How a distributed run was sharded across workers and recovered
    /// from worker deaths (`None` for single-process runs; see
    /// `crate::cluster`).
    pub cluster: Option<ClusterReport>,
}

/// Shard-and-retry audit trail of one distributed run, recorded in
/// [`SinkMeta`] by the cluster coordinator (`crate::cluster`).
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterReport {
    /// Worker connections the coordinator opened.
    pub workers: usize,
    /// Unique block tasks dispatched (not attempts).
    pub tasks: usize,
    /// Task attempts re-queued after a worker died or timed out —
    /// idempotence makes every retry bit-exact, so this is an audit
    /// number, not a correctness concern.
    pub retried: u64,
    /// Worker connections lost before the run finished.
    pub worker_failures: u64,
}

/// Admission audit trail for one service job, recorded in [`SinkMeta`]:
/// what the byte gate charged, how long the job queued behind the
/// aggregate cap, and the class it queued in.
#[derive(Clone, Debug, PartialEq)]
pub struct AdmissionReport {
    /// The gate's price for the job
    /// (`crate::coordinator::admission::estimate_job_bytes`).
    pub estimated_bytes: usize,
    /// Wall time between entering the byte gate and being admitted.
    pub queued_secs: f64,
    /// Admission class name (`"interactive"` / `"batch"`).
    pub priority: &'static str,
}

/// Read-side I/O of one run against an instrumented
/// [`crate::data::colstore::ColumnSource`] (deltas over the run, not
/// process totals), recorded in [`SinkMeta`] so the streaming path's
/// read amplification is auditable per run.
#[derive(Clone, Debug, PartialEq)]
pub struct IoReport {
    /// Payload bytes read from storage during the run.
    pub bytes_read: u64,
    /// Read calls issued during the run.
    pub reads: u64,
    /// Wall time spent inside read calls.
    pub read_secs: f64,
    /// The source's total payload size (the read-amplification
    /// denominator).
    pub payload_bytes: u64,
    /// `bytes_read / payload_bytes` — 1.0 means every block was read
    /// exactly once (the block cache's floor); an uncached blockwise
    /// run over `nb` blocks reads ~`nb/2 + 1/2` times the payload.
    pub read_amplification: f64,
}

/// Block-substrate cache behaviour over one run (deltas, not process
/// totals), recorded in [`SinkMeta`]. See
/// `crate::coordinator::blockcache`.
#[derive(Clone, Debug, PartialEq)]
pub struct CacheReport {
    /// Substrate requests served from cache.
    pub hits: u64,
    /// Substrate requests that fetched + built.
    pub misses: u64,
    /// Entries evicted to stay under the byte budget.
    pub evictions: u64,
    /// Misses filled by the readahead stage rather than a stalled
    /// worker.
    pub prefetched: u64,
    /// Wall time demand misses spent fetching + building — the I/O
    /// stall the cache and prefetch exist to hide.
    pub stall_secs: f64,
    /// The cache's byte budget for the run.
    pub budget_bytes: usize,
}

/// Content-addressed Gram-tile cache behaviour over one run (deltas,
/// not process totals), recorded in [`SinkMeta`]. A hit means the
/// task's Gram tile was served verified from disk and only the measure
/// combine ran. See `crate::coordinator::tilecache`.
#[derive(Clone, Debug, PartialEq)]
pub struct TileCacheReport {
    /// Tasks whose Gram tile was served from the cache.
    pub hits: u64,
    /// Tasks that computed their Gram (including dropped corrupt
    /// tiles).
    pub misses: u64,
    /// Tiles deleted to stay under the byte budget.
    pub evictions: u64,
    /// Bytes of tile files written during the run.
    pub inserted_bytes: u64,
    /// The cache's byte budget.
    pub budget_bytes: usize,
}

/// The planner's block-sizing decision for one run, recorded in
/// [`SinkMeta`] so auto runs are auditable end to end.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockSizing {
    /// Column-block width of the executed plan.
    pub block_cols: usize,
    /// What determined it: `"explicit"` (caller-fixed block size),
    /// `"monolithic"` (no block size requested, single-task plan),
    /// `"budget"` (memory-budget rule), or `"probe-throughput"`
    /// (autotuner cells/sec folded into the latency target via
    /// [`crate::coordinator::planner::throughput_block`]).
    pub source: &'static str,
    /// The per-task Gram latency target (seconds) the sizing ran under
    /// (`--task-latency` / `run.task_latency_secs` /
    /// `JobSpec::task_latency_secs`; only binding when `source` is
    /// `"probe-throughput"`, recorded always so runs are comparable).
    pub task_latency_secs: f64,
    /// The probed combine-stage throughput (output cells/sec) for the
    /// run's measure that was folded into the latency model alongside
    /// the Gram throughput
    /// ([`crate::mi::autotune::ProbeReport::combine_throughput`]).
    /// `None` when the sizing ignored combine cost: no probe ran, the
    /// width was explicit, or the probe report carried no entry for the
    /// measure.
    pub combine_cells_per_sec: Option<f64>,
}

/// What a sink retained plus how the run was executed, returned by
/// [`MiSink::finish`].
#[derive(Clone, Debug)]
pub struct SinkOutput {
    pub data: SinkData,
    pub meta: SinkMeta,
}

impl From<SinkData> for SinkOutput {
    fn from(data: SinkData) -> Self {
        SinkOutput { data, meta: SinkMeta::default() }
    }
}

impl SinkOutput {
    /// Stable identifier of the output shape.
    pub fn kind_name(&self) -> &'static str {
        self.data.kind_name()
    }

    /// The dense matrix, when this output holds one.
    pub fn into_dense(self) -> Option<MiMatrix> {
        self.data.into_dense()
    }

    /// Bytes of in-memory result state this output holds.
    pub fn state_bytes(&self) -> usize {
        self.data.state_bytes()
    }

    /// One-line human summary; names the backend when the meta knows it
    /// (e.g. `"top-10 pairs (via bulk-bitpack)"`).
    pub fn summary(&self) -> String {
        match &self.meta.backend {
            Some(b) => format!("{} (via {b})", self.data.summary()),
            None => self.data.summary(),
        }
    }
}

/// Sparse COO view of the retained pairs (each with `i < j`), sorted by
/// `(i, j)` — the same order `mi::topk::edges_above` produces.
#[derive(Clone, Debug)]
pub struct SparsePairs {
    /// The MI cutoff that was applied.
    pub threshold: f64,
    /// The p-value the cutoff was derived from, when any.
    pub pvalue: Option<f64>,
    pub pairs: Vec<MiPair>,
}

impl SparsePairs {
    pub fn nnz(&self) -> usize {
        self.pairs.len()
    }
}

/// Where and how much a [`TileSpillSink`] wrote.
#[derive(Clone, Debug)]
pub struct SpillInfo {
    pub dir: PathBuf,
    /// Number of variables (manifest `m`).
    pub m: usize,
    /// Tiles written.
    pub tiles: usize,
    /// Total tile bytes on disk (manifest excluded).
    pub bytes: u64,
}

// ---------------------------------------------------------------------
// shared helpers
// ---------------------------------------------------------------------

/// Visit every strict-upper-triangle cell `(i, j, mi)` with global
/// `i < j` that this block contributes.
fn for_each_upper(t: &BlockTask, block: &Mat64, mut f: impl FnMut(usize, usize, f64)) {
    for bi in 0..t.a_len {
        let i = t.a_start + bi;
        for bj in 0..t.b_len {
            let j = t.b_start + bj;
            if j > i {
                f(i, j, block.get(bi, bj));
            }
        }
    }
}

fn check_block_shape(t: &BlockTask, block: &Mat64) -> Result<()> {
    if (block.rows(), block.cols()) != (t.a_len, t.b_len) {
        return Err(Error::Shape(format!(
            "sink received {}x{} block for task {t:?}",
            block.rows(),
            block.cols()
        )));
    }
    Ok(())
}

/// Total order on pairs: higher MI ranks first, ties broken by `(i, j)`
/// ascending — exactly the order `mi::topk::top_k_pairs` sorts by.
/// `Greater` means `a` outranks `b`.
fn rank_cmp(a: &MiPair, b: &MiPair) -> Ordering {
    a.mi
        .partial_cmp(&b.mi)
        .unwrap_or(Ordering::Equal)
        .then_with(|| (b.i, b.j).cmp(&(a.i, a.j)))
}

/// Heap entry ordered so the *worst-ranked* pair is at the top, turning
/// `BinaryHeap` (a max-heap) into the bounded min-heap top-k needs.
#[derive(Clone, Copy, Debug)]
struct WorstFirst(MiPair);

impl PartialEq for WorstFirst {
    fn eq(&self, other: &Self) -> bool {
        rank_cmp(&self.0, &other.0) == Ordering::Equal
    }
}

impl Eq for WorstFirst {}

impl PartialOrd for WorstFirst {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for WorstFirst {
    fn cmp(&self, other: &Self) -> Ordering {
        rank_cmp(&other.0, &self.0)
    }
}

/// A bounded "keep the k best" heap: O(k) memory, O(log k) per offer.
#[derive(Debug, Default)]
struct BoundedRank {
    k: usize,
    heap: BinaryHeap<WorstFirst>,
}

impl BoundedRank {
    fn new(k: usize) -> Self {
        BoundedRank { k, heap: BinaryHeap::with_capacity(k.min(1 << 20) + 1) }
    }

    #[inline]
    fn offer(&mut self, p: MiPair) {
        if self.k == 0 {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push(WorstFirst(p));
        } else if let Some(worst) = self.heap.peek() {
            if rank_cmp(&p, &worst.0) == Ordering::Greater {
                self.heap.pop();
                self.heap.push(WorstFirst(p));
            }
        }
    }

    /// Drain into a best-first sorted vec.
    fn into_sorted(self) -> Vec<MiPair> {
        let mut pairs: Vec<MiPair> = self.heap.into_iter().map(|w| w.0).collect();
        pairs.sort_by(|a, b| rank_cmp(b, a));
        pairs
    }
}

// ---------------------------------------------------------------------
// DenseSink
// ---------------------------------------------------------------------

/// Materializes the full m x m matrix — bit-identical to the historical
/// monolithic assembly (same combine, same mirror writes).
#[derive(Debug)]
pub struct DenseSink {
    m: usize,
    mat: Option<Mat64>,
}

impl DenseSink {
    pub fn new(m: usize) -> Self {
        DenseSink { m, mat: Some(Mat64::zeros(m, m)) }
    }
}

impl MiSink for DenseSink {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn consume_block(&mut self, t: &BlockTask, block: &Mat64) -> Result<()> {
        check_block_shape(t, block)?;
        if t.a_start + t.a_len > self.m || t.b_start + t.b_len > self.m {
            return Err(Error::Shape(format!(
                "task {t:?} out of bounds for m = {}",
                self.m
            )));
        }
        let mat = self
            .mat
            .as_mut()
            .ok_or_else(|| Error::Coordinator("DenseSink already finished".into()))?;
        for i in 0..t.a_len {
            for j in 0..t.b_len {
                let v = block.get(i, j);
                mat.set(t.a_start + i, t.b_start + j, v);
                if !t.is_diagonal() {
                    mat.set(t.b_start + j, t.a_start + i, v);
                }
            }
        }
        Ok(())
    }

    fn merge(&mut self, other: SinkData) -> Result<()> {
        let SinkData::Dense(shard) = other else {
            return Err(Error::Coordinator(format!(
                "dense sink cannot merge {} shard state",
                other.kind_name()
            )));
        };
        if shard.dim() != self.m {
            return Err(Error::Shape(format!(
                "dense merge: shard is {0} x {0} but the run is {1} x {1}",
                shard.dim(),
                self.m
            )));
        }
        let mat = self
            .mat
            .as_mut()
            .ok_or_else(|| Error::Coordinator("DenseSink already finished".into()))?;
        // Shards cover disjoint cell sets (planner exactly-once), so a
        // cell is either untouched in `shard` (still +0.0) or the final
        // value. Copying only bit-nonzero cells keeps the merge
        // bit-exact: a *computed* +0.0 is skipped, but the destination
        // already holds +0.0, and a computed -0.0 has nonzero bits and
        // is copied.
        for i in 0..self.m {
            for j in 0..self.m {
                let v = shard.get(i, j);
                if v.to_bits() != 0 {
                    mat.set(i, j, v);
                }
            }
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<SinkOutput> {
        let mat = self
            .mat
            .take()
            .ok_or_else(|| Error::Coordinator("DenseSink already finished".into()))?;
        Ok(SinkData::Dense(MiMatrix::from_mat(mat)).into())
    }
}

// ---------------------------------------------------------------------
// TopKSink
// ---------------------------------------------------------------------

enum TopKState {
    Global(BoundedRank),
    PerColumn(Vec<BoundedRank>),
}

/// Keeps the k largest off-diagonal pairs — globally, or per column —
/// in bounded heaps. Never allocates anything proportional to m²: the
/// matrix-free path for screening workloads.
pub struct TopKSink {
    state: TopKState,
}

impl TopKSink {
    /// Global top-k over all pairs `(i < j)`.
    pub fn global(k: usize) -> Self {
        TopKSink { state: TopKState::Global(BoundedRank::new(k)) }
    }

    /// The k strongest partners of *each* of the `m` columns.
    pub fn per_column(m: usize, k: usize) -> Self {
        TopKSink {
            state: TopKState::PerColumn((0..m).map(|_| BoundedRank::new(k)).collect()),
        }
    }
}

impl MiSink for TopKSink {
    fn name(&self) -> &'static str {
        match self.state {
            TopKState::Global(_) => "topk",
            TopKState::PerColumn(_) => "topk-per-col",
        }
    }

    fn consume_block(&mut self, t: &BlockTask, block: &Mat64) -> Result<()> {
        check_block_shape(t, block)?;
        match &mut self.state {
            TopKState::Global(heap) => {
                for_each_upper(t, block, |i, j, mi| heap.offer(MiPair { i, j, mi }));
            }
            TopKState::PerColumn(heaps) => {
                let m = heaps.len();
                if t.a_start + t.a_len > m || t.b_start + t.b_len > m {
                    return Err(Error::Shape(format!(
                        "task {t:?} out of bounds for m = {m}"
                    )));
                }
                for bi in 0..t.a_len {
                    let i = t.a_start + bi;
                    for bj in 0..t.b_len {
                        let j = t.b_start + bj;
                        if j > i {
                            let p = MiPair { i, j, mi: block.get(bi, bj) };
                            heaps[i].offer(p);
                            heaps[j].offer(p);
                        }
                    }
                }
            }
        }
        Ok(())
    }

    fn merge(&mut self, other: SinkData) -> Result<()> {
        // rank_cmp is a total order and offer() is insertion-order
        // independent for distinct (mi, i, j), so merging shard top-k
        // lists reproduces the single-process result exactly
        let other_kind = other.kind_name();
        match (&mut self.state, other) {
            (TopKState::Global(heap), SinkData::TopK(pairs)) => {
                for p in pairs {
                    heap.offer(p);
                }
                Ok(())
            }
            (TopKState::PerColumn(heaps), SinkData::TopKPerColumn(cols)) => {
                if cols.len() != heaps.len() {
                    return Err(Error::Shape(format!(
                        "per-column merge: shard has {} columns, run has {}",
                        cols.len(),
                        heaps.len()
                    )));
                }
                // each pair already appears under both endpoint columns
                // in the shard, so column i's list feeds heap i only
                for (heap, col) in heaps.iter_mut().zip(cols) {
                    for p in col {
                        heap.offer(p);
                    }
                }
                Ok(())
            }
            _ => Err(Error::Coordinator(format!(
                "top-k sink cannot merge {other_kind} shard state"
            ))),
        }
    }

    fn finish(&mut self) -> Result<SinkOutput> {
        match std::mem::replace(&mut self.state, TopKState::Global(BoundedRank::new(0))) {
            TopKState::Global(heap) => Ok(SinkData::TopK(heap.into_sorted()).into()),
            TopKState::PerColumn(heaps) => Ok(SinkData::TopKPerColumn(
                heaps.into_iter().map(|h| h.into_sorted()).collect(),
            )
            .into()),
        }
    }
}

// ---------------------------------------------------------------------
// ThresholdSink
// ---------------------------------------------------------------------

/// Keeps every pair with MI at or above a cutoff as sparse COO. The
/// cutoff may be given directly in bits, or derived from an asymptotic
/// p-value (the G-test chi-square tail; see
/// [`crate::mi::significance::mi_threshold_for_pvalue`]).
pub struct ThresholdSink {
    threshold: f64,
    pvalue: Option<f64>,
    pairs: Vec<MiPair>,
}

impl ThresholdSink {
    /// Keep pairs with `MI >= threshold` (bits).
    pub fn by_mi(threshold: f64) -> Self {
        ThresholdSink { threshold, pvalue: None, pairs: Vec::new() }
    }

    /// Keep pairs whose asymptotic independence p-value is `<= pvalue`
    /// for a dataset with `n_rows` observations (MI-bits cutoff).
    pub fn by_pvalue(pvalue: f64, n_rows: usize) -> Result<Self> {
        Self::by_pvalue_for(pvalue, n_rows, CombineKind::Mi)
    }

    /// [`Self::by_pvalue`] for a run whose combine stage computes
    /// `measure`: the χ²₁ cutoff converts to MI bits for
    /// [`CombineKind::Mi`] and applies directly for
    /// [`CombineKind::GStat`] (the statistic *is* G). Every other
    /// measure has no G-test asymptotic null, so the conversion is a
    /// clean error rather than a silently wrong threshold.
    pub fn by_pvalue_for(pvalue: f64, n_rows: usize, measure: CombineKind) -> Result<Self> {
        let threshold = match measure {
            CombineKind::Mi => super::significance::mi_threshold_for_pvalue(pvalue, n_rows)?,
            CombineKind::GStat => {
                if n_rows == 0 {
                    return Err(Error::Shape("p-value threshold needs n_rows >= 1".into()));
                }
                super::significance::gstat_threshold_for_pvalue(pvalue)?
            }
            other => {
                return Err(Error::Parse(format!(
                    "sink pvalue: measure '{other}' has no G-test asymptotic null \
                     (supported: mi, gstat); use threshold:T instead"
                )))
            }
        };
        Ok(ThresholdSink { threshold, pvalue: Some(pvalue), pairs: Vec::new() })
    }

    /// The effective MI cutoff in bits.
    pub fn threshold(&self) -> f64 {
        self.threshold
    }
}

impl MiSink for ThresholdSink {
    fn name(&self) -> &'static str {
        "threshold"
    }

    fn consume_block(&mut self, t: &BlockTask, block: &Mat64) -> Result<()> {
        check_block_shape(t, block)?;
        let threshold = self.threshold;
        let pairs = &mut self.pairs;
        for_each_upper(t, block, |i, j, mi| {
            if mi >= threshold {
                pairs.push(MiPair { i, j, mi });
            }
        });
        Ok(())
    }

    fn merge(&mut self, other: SinkData) -> Result<()> {
        let SinkData::Sparse(sp) = other else {
            return Err(Error::Coordinator(format!(
                "threshold sink cannot merge {} shard state",
                other.kind_name()
            )));
        };
        if sp.threshold != self.threshold {
            return Err(Error::Coordinator(format!(
                "threshold merge: shard cutoff {} != run cutoff {}",
                sp.threshold, self.threshold
            )));
        }
        // order is irrelevant here: finish() sorts by (i, j)
        self.pairs.extend(sp.pairs);
        Ok(())
    }

    fn finish(&mut self) -> Result<SinkOutput> {
        let mut pairs = std::mem::take(&mut self.pairs);
        pairs.sort_by_key(|p| (p.i, p.j));
        Ok(SinkData::Sparse(SparsePairs {
            threshold: self.threshold,
            pvalue: self.pvalue,
            pairs,
        })
        .into())
    }
}

// ---------------------------------------------------------------------
// TileSpillSink
// ---------------------------------------------------------------------

/// First line of a resumable (v2) spill manifest.
const SPILL_MANIFEST_V2: &str = "bulkmi-spill,v2";
/// v2 per-tile row header.
const SPILL_HEADER_V2: &str = "a_start,a_len,b_start,b_len,bytes,checksum,file";
/// Trailer line a finished run appends; its absence means a crash.
const SPILL_COMPLETE: &str = "complete,1";

/// Writes each combined block to disk as a raw little-endian f64 tile
/// plus an *incremental* `manifest.csv`: the version + `m` headers go
/// out at construction, and each tile's row — byte length, FNV-1a
/// checksum, file name — is appended and flushed right after the tile
/// file lands. A crash therefore leaves a manifest that lists exactly
/// the durable tiles (a torn final row is tolerated by the parser);
/// only a clean [`MiSink::finish`] appends the `complete,1` trailer.
/// That is what makes spilled runs resumable: [`TileSpillSink::resume`]
/// replays the manifest, verifies the surviving tiles, and reports
/// which tasks are already done. Keeps only O(block²) bytes in memory —
/// the out-of-core path for m far beyond RAM. Reassemble (for m that
/// fits) with [`assemble_spilled`].
pub struct TileSpillSink {
    dir: PathBuf,
    m: usize,
    manifest: std::io::BufWriter<std::fs::File>,
    tiles: usize,
    bytes: u64,
}

impl TileSpillSink {
    pub fn new(dir: impl Into<PathBuf>, m: usize) -> Result<Self> {
        use std::io::Write;
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        let mut manifest =
            std::io::BufWriter::new(std::fs::File::create(dir.join("manifest.csv"))?);
        writeln!(manifest, "{SPILL_MANIFEST_V2}")?;
        writeln!(manifest, "m,{m}")?;
        writeln!(manifest, "{SPILL_HEADER_V2}")?;
        manifest.flush()?;
        Ok(TileSpillSink { dir, m, manifest, tiles: 0, bytes: 0 })
    }

    /// Reopen a crashed (or finished) spill directory: parse its v2
    /// manifest, verify every listed tile's length and checksum
    /// (corruption is a clean [`Error::Parse`] naming the tile), and
    /// return the sink in append mode plus the tasks whose tiles are
    /// already durable — the caller schedules only the rest and calls
    /// `finish()` as usual.
    pub fn resume(dir: impl Into<PathBuf>) -> Result<(Self, Vec<BlockTask>)> {
        let dir = dir.into();
        let man = read_spill_manifest(&dir)?;
        let mut done = Vec::with_capacity(man.tiles.len());
        let mut bytes = 0u64;
        for tile in &man.tiles {
            verify_spill_tile(&dir, tile)?;
            done.push(tile.task);
            bytes += tile.bytes;
        }
        let file = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.join("manifest.csv"))?;
        let manifest = std::io::BufWriter::new(file);
        let sink = TileSpillSink { dir, m: man.m, manifest, tiles: done.len(), bytes };
        Ok((sink, done))
    }
}

impl MiSink for TileSpillSink {
    fn name(&self) -> &'static str {
        "spill"
    }

    fn consume_block(&mut self, t: &BlockTask, block: &Mat64) -> Result<()> {
        use std::io::Write;
        check_block_shape(t, block)?;
        let file = format!("tile_{}_{}.f64", t.a_start, t.b_start);
        let mut buf = Vec::with_capacity(block.data().len() * 8);
        for v in block.data() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(self.dir.join(&file), &buf)?;
        // the tile is durable before its row is: a crash in between
        // leaves an unlisted file that resume simply overwrites
        let ck = crate::coordinator::tilecache::fnv1a(&buf);
        writeln!(
            self.manifest,
            "{},{},{},{},{},{ck:016x},{file}",
            t.a_start,
            t.a_len,
            t.b_start,
            t.b_len,
            buf.len()
        )?;
        self.manifest.flush()?;
        self.bytes += buf.len() as u64;
        self.tiles += 1;
        Ok(())
    }

    fn merge(&mut self, other: SinkData) -> Result<()> {
        use std::io::Write;
        let SinkData::Spilled(info) = other else {
            return Err(Error::Coordinator(format!(
                "spill sink cannot merge {} shard state",
                other.kind_name()
            )));
        };
        if info.m != self.m {
            return Err(Error::Shape(format!(
                "spill merge: shard manifest has m = {}, run has m = {}",
                info.m, self.m
            )));
        }
        // adopt the shard directory's verified tiles: each file moves
        // into this sink's directory and its manifest row is appended
        // only after the moved tile is durable — the same
        // crash-ordering discipline consume_block keeps
        let man = read_spill_manifest(&info.dir)?;
        for tile in &man.tiles {
            let raw = verify_spill_tile(&info.dir, tile)?;
            let file = tile.file();
            std::fs::write(self.dir.join(&file), &raw)?;
            let t = &tile.task;
            writeln!(
                self.manifest,
                "{},{},{},{},{},{:016x},{file}",
                t.a_start, t.a_len, t.b_start, t.b_len, tile.bytes, tile.checksum
            )?;
            self.manifest.flush()?;
            self.bytes += tile.bytes;
            self.tiles += 1;
        }
        let _ = std::fs::remove_dir_all(&info.dir);
        Ok(())
    }

    fn finish(&mut self) -> Result<SinkOutput> {
        use std::io::Write;
        writeln!(self.manifest, "{SPILL_COMPLETE}")?;
        self.manifest.flush()?;
        Ok(SinkData::Spilled(SpillInfo {
            dir: self.dir.clone(),
            m: self.m,
            tiles: self.tiles,
            bytes: self.bytes,
        })
        .into())
    }
}

/// One tile row of a v2 spill manifest.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpillTile {
    pub task: BlockTask,
    /// Tile file length in bytes (must equal `a_len * b_len * 8`).
    pub bytes: u64,
    /// FNV-1a checksum of the tile file's bytes.
    pub checksum: u64,
}

impl SpillTile {
    /// The tile's file name inside the spill directory.
    pub fn file(&self) -> String {
        format!("tile_{}_{}.f64", self.task.a_start, self.task.b_start)
    }
}

/// A parsed v2 spill manifest.
#[derive(Clone, Debug)]
pub struct SpillManifest {
    pub m: usize,
    /// Whether the run's `finish()` appended the completion trailer.
    pub complete: bool,
    pub tiles: Vec<SpillTile>,
}

/// Parse a spill directory's v2 `manifest.csv`. Legacy v1 manifests
/// (no version line, no checksums) are a clean error — they predate
/// resumability. An incomplete manifest may end in one torn row (a
/// crash mid-append), which is dropped; any other malformed line is an
/// [`Error::Parse`].
pub fn read_spill_manifest(dir: &Path) -> Result<SpillManifest> {
    let path = dir.join("manifest.csv");
    let text = std::fs::read_to_string(&path)?;
    parse_spill_manifest(&text)
        .map_err(|e| Error::Parse(format!("{}: {e}", path.display())))
}

fn parse_spill_manifest(text: &str) -> std::result::Result<SpillManifest, String> {
    let lines: Vec<&str> = text.lines().collect();
    if lines.first().copied() != Some(SPILL_MANIFEST_V2) {
        return Err(format!(
            "not a resumable v2 spill manifest (first line is {:?})",
            lines.first().copied().unwrap_or("")
        ));
    }
    let m: usize = lines
        .get(1)
        .and_then(|l| l.strip_prefix("m,"))
        .and_then(|v| v.parse().ok())
        .ok_or("missing m header")?;
    if lines.get(2).copied() != Some(SPILL_HEADER_V2) {
        return Err(format!("bad header '{}'", lines.get(2).copied().unwrap_or("")));
    }
    let complete = lines.iter().any(|l| *l == SPILL_COMPLETE);
    let mut tiles = Vec::new();
    let rows = &lines[3..];
    let last_row = rows.iter().rposition(|l| !l.trim().is_empty());
    for (idx, line) in rows.iter().enumerate() {
        if line.trim().is_empty() || *line == SPILL_COMPLETE {
            continue;
        }
        match parse_spill_row(line, m) {
            Some(tile) => tiles.push(tile),
            // a torn final row is the expected residue of a crash
            // mid-append; anywhere else it is corruption
            None if !complete && Some(idx) == last_row => break,
            None => return Err(format!("bad row '{line}'")),
        }
    }
    Ok(SpillManifest { m, complete, tiles })
}

fn parse_spill_row(line: &str, m: usize) -> Option<SpillTile> {
    let parts: Vec<&str> = line.split(',').collect();
    if parts.len() != 7 {
        return None;
    }
    let nums: Vec<usize> = parts[..4].iter().map(|s| s.parse().ok()).collect::<Option<_>>()?;
    let (a_start, a_len, b_start, b_len) = (nums[0], nums[1], nums[2], nums[3]);
    if a_start.checked_add(a_len)? > m || b_start.checked_add(b_len)? > m {
        return None;
    }
    let bytes: u64 = parts[4].parse().ok()?;
    let checksum = u64::from_str_radix(parts[5], 16).ok()?;
    let tile = SpillTile {
        task: BlockTask { a_start, a_len, b_start, b_len },
        bytes,
        checksum,
    };
    if parts[6] != tile.file() {
        return None;
    }
    Some(tile)
}

/// Read and verify one spilled tile against its manifest row: the file
/// must exist, match the recorded byte length (which must itself match
/// the tile's shape), and match the recorded checksum. Every failure is
/// an [`Error::Parse`] naming the tile — a corrupt spill can never
/// silently assemble into a wrong matrix.
pub fn verify_spill_tile(dir: &Path, tile: &SpillTile) -> Result<Vec<u8>> {
    let file = tile.file();
    let want = (tile.task.a_len as u64) * (tile.task.b_len as u64) * 8;
    if tile.bytes != want {
        return Err(Error::Parse(format!(
            "tile {file}: manifest says {} bytes but the tile shape implies {want}",
            tile.bytes
        )));
    }
    let raw = std::fs::read(dir.join(&file))
        .map_err(|e| Error::Parse(format!("tile {file}: {e}")))?;
    if raw.len() as u64 != want {
        return Err(Error::Parse(format!(
            "tile {file}: {} bytes, expected {want} (truncated?)",
            raw.len()
        )));
    }
    let ck = crate::coordinator::tilecache::fnv1a(&raw);
    if ck != tile.checksum {
        return Err(Error::Parse(format!(
            "tile {file}: checksum {ck:016x} != manifest {:016x} (corrupt tile)",
            tile.checksum
        )));
    }
    Ok(raw)
}

/// Load a spilled run back into a dense matrix (requires m² x 8 bytes
/// of RAM — intended for tests and for tiles small enough to revisit).
/// v2 manifests get every tile length- and checksum-verified
/// ([`verify_spill_tile`]), and an incomplete manifest (crashed run) is
/// a clean error pointing at `bulkmi resume`; legacy v1 manifests
/// assemble with the historical length-only check.
pub fn assemble_spilled(dir: &Path) -> Result<MiMatrix> {
    let manifest = std::fs::read_to_string(dir.join("manifest.csv"))?;
    if manifest.starts_with(SPILL_MANIFEST_V2) {
        let man = parse_spill_manifest(&manifest)
            .map_err(|e| Error::Parse(format!("{}: {e}", dir.join("manifest.csv").display())))?;
        if !man.complete {
            return Err(Error::Parse(format!(
                "{}: manifest has no completion marker (crashed run?) — finish it \
                 with `bulkmi resume {}`",
                dir.join("manifest.csv").display(),
                dir.display()
            )));
        }
        let mut mat = Mat64::zeros(man.m, man.m);
        for tile in &man.tiles {
            let raw = verify_spill_tile(dir, tile)?;
            fill_tile(&mut mat, &tile.task, &raw);
        }
        return Ok(MiMatrix::from_mat(mat));
    }
    assemble_spilled_v1(dir, &manifest)
}

fn fill_tile(mat: &mut Mat64, t: &BlockTask, raw: &[u8]) {
    let diagonal = t.a_start == t.b_start && t.a_len == t.b_len;
    for (idx, chunk) in raw.chunks_exact(8).enumerate() {
        let v = f64::from_le_bytes(chunk.try_into().expect("8-byte chunk"));
        let (i, j) = (t.a_start + idx / t.b_len, t.b_start + idx % t.b_len);
        mat.set(i, j, v);
        if !diagonal {
            mat.set(j, i, v);
        }
    }
}

/// The pre-resume (v1) assembly path: no checksums, length check only.
fn assemble_spilled_v1(dir: &Path, manifest: &str) -> Result<MiMatrix> {
    let mut lines = manifest.lines();
    let m: usize = lines
        .next()
        .and_then(|l| l.strip_prefix("m,"))
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| Error::Parse("manifest.csv: missing m header".into()))?;
    let header = lines.next().unwrap_or("");
    if header != "a_start,a_len,b_start,b_len,file" {
        return Err(Error::Parse(format!("manifest.csv: bad header '{header}'")));
    }
    let mut mat = Mat64::zeros(m, m);
    for line in lines {
        if line.trim().is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.split(',').collect();
        if parts.len() != 5 {
            return Err(Error::Parse(format!("manifest.csv: bad row '{line}'")));
        }
        let nums: Vec<usize> = parts[..4]
            .iter()
            .map(|s| s.parse().map_err(|_| Error::Parse(format!("bad number in '{line}'"))))
            .collect::<Result<_>>()?;
        let (a_start, a_len, b_start, b_len) = (nums[0], nums[1], nums[2], nums[3]);
        if a_start + a_len > m || b_start + b_len > m {
            return Err(Error::Parse(format!("manifest.csv: tile out of bounds '{line}'")));
        }
        let raw = std::fs::read(dir.join(parts[4]))?;
        if raw.len() != a_len * b_len * 8 {
            return Err(Error::Parse(format!(
                "tile {}: {} bytes, expected {}",
                parts[4],
                raw.len(),
                a_len * b_len * 8
            )));
        }
        let t = BlockTask { a_start, a_len, b_start, b_len };
        fill_tile(&mut mat, &t, &raw);
    }
    Ok(MiMatrix::from_mat(mat))
}

// ---------------------------------------------------------------------
// SinkSpec: parse / build (CLI, config, job service)
// ---------------------------------------------------------------------

/// Declarative sink choice, parseable from `--sink` syntax:
/// `dense | topk:K | topk-per-col:K | threshold:T | pvalue:P | spill:DIR`.
///
/// ```
/// use bulkmi::mi::sink::SinkSpec;
///
/// let spec = SinkSpec::parse("topk:8").unwrap();
/// assert_eq!(spec, SinkSpec::TopK { k: 8, per_column: false });
/// assert!(!spec.is_dense());
///
/// // build() instantiates the sink for an m-column, n-row dataset
/// let sink = spec.build(100, 5_000).unwrap();
/// assert_eq!(sink.name(), "topk");
///
/// // malformed specs are parse errors, not fallbacks
/// assert!(SinkSpec::parse("topk").is_err());
/// assert!(SinkSpec::parse("warp:1").is_err());
/// ```
#[derive(Clone, Debug, PartialEq, Default)]
pub enum SinkSpec {
    #[default]
    Dense,
    TopK { k: usize, per_column: bool },
    ThresholdMi { threshold: f64 },
    ThresholdPvalue { pvalue: f64 },
    Spill { dir: PathBuf },
}

impl SinkSpec {
    pub fn parse(s: &str) -> Result<SinkSpec> {
        if s == "dense" {
            return Ok(SinkSpec::Dense);
        }
        let (kind, arg) = s.split_once(':').ok_or_else(|| {
            Error::Parse(format!(
                "bad sink '{s}' (expected dense | topk:K | topk-per-col:K | \
                 threshold:T | pvalue:P | spill:DIR)"
            ))
        })?;
        match kind {
            "topk" | "topk-per-col" => {
                let k = arg
                    .parse()
                    .map_err(|_| Error::Parse(format!("sink {kind}: bad k '{arg}'")))?;
                Ok(SinkSpec::TopK { k, per_column: kind == "topk-per-col" })
            }
            "threshold" => {
                let threshold = arg
                    .parse()
                    .map_err(|_| Error::Parse(format!("sink threshold: bad value '{arg}'")))?;
                Ok(SinkSpec::ThresholdMi { threshold })
            }
            "pvalue" => {
                let pvalue: f64 = arg
                    .parse()
                    .map_err(|_| Error::Parse(format!("sink pvalue: bad value '{arg}'")))?;
                Ok(SinkSpec::ThresholdPvalue { pvalue })
            }
            "spill" => Ok(SinkSpec::Spill { dir: PathBuf::from(arg) }),
            other => Err(Error::Parse(format!("unknown sink kind '{other}'"))),
        }
    }

    pub fn is_dense(&self) -> bool {
        matches!(self, SinkSpec::Dense)
    }

    /// Instantiate for a dataset with `m` columns and `n_rows` rows
    /// (MI combine; see [`Self::build_for`] for other measures).
    pub fn build(&self, m: usize, n_rows: usize) -> Result<Box<dyn MiSink>> {
        self.build_for(m, n_rows, CombineKind::Mi)
    }

    /// Instantiate for a run whose combine stage computes `measure`.
    /// Sinks rank/threshold whatever values the measure produces; only
    /// `pvalue:` is measure-sensitive (its χ²₁ conversion exists for
    /// `mi` and `gstat` alone and errors cleanly otherwise).
    pub fn build_for(
        &self,
        m: usize,
        n_rows: usize,
        measure: CombineKind,
    ) -> Result<Box<dyn MiSink>> {
        Ok(match self {
            SinkSpec::Dense => Box::new(DenseSink::new(m)),
            SinkSpec::TopK { k, per_column: false } => Box::new(TopKSink::global(*k)),
            SinkSpec::TopK { k, per_column: true } => Box::new(TopKSink::per_column(m, *k)),
            SinkSpec::ThresholdMi { threshold } => Box::new(ThresholdSink::by_mi(*threshold)),
            SinkSpec::ThresholdPvalue { pvalue } => {
                Box::new(ThresholdSink::by_pvalue_for(*pvalue, n_rows, measure)?)
            }
            SinkSpec::Spill { dir } => Box::new(TileSpillSink::new(dir.clone(), m)?),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block(t: &BlockTask, f: impl Fn(usize, usize) -> f64) -> Mat64 {
        let mut out = Mat64::zeros(t.a_len, t.b_len);
        for i in 0..t.a_len {
            for j in 0..t.b_len {
                out.set(i, j, f(t.a_start + i, t.b_start + j));
            }
        }
        out
    }

    /// 4 columns tiled as 2x2 blocks; cell value = i * 10 + j (i <= j).
    fn feed(sink: &mut dyn MiSink) {
        let tasks = [
            BlockTask { a_start: 0, a_len: 2, b_start: 0, b_len: 2 },
            BlockTask { a_start: 0, a_len: 2, b_start: 2, b_len: 2 },
            BlockTask { a_start: 2, a_len: 2, b_start: 2, b_len: 2 },
        ];
        for t in &tasks {
            let b = block(t, |i, j| (i.min(j) * 10 + i.max(j)) as f64);
            sink.consume_block(t, &b).unwrap();
        }
    }

    #[test]
    fn dense_sink_mirrors_off_diagonal() {
        let mut sink = DenseSink::new(4);
        feed(&mut sink);
        let SinkData::Dense(mi) = sink.finish().unwrap().data else { panic!() };
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(mi.get(i, j), (i.min(j) * 10 + i.max(j)) as f64, "({i},{j})");
            }
        }
        assert!(sink.finish().is_err(), "double finish must error");
    }

    #[test]
    fn topk_keeps_the_best_pairs() {
        let mut sink = TopKSink::global(2);
        feed(&mut sink);
        let SinkData::TopK(pairs) = sink.finish().unwrap().data else { panic!() };
        // values: (0,1)=1 (0,2)=2 (0,3)=3 (1,2)=12 (1,3)=13 (2,3)=23
        assert_eq!(pairs.len(), 2);
        assert_eq!((pairs[0].i, pairs[0].j, pairs[0].mi), (2, 3, 23.0));
        assert_eq!((pairs[1].i, pairs[1].j, pairs[1].mi), (1, 3, 13.0));
    }

    #[test]
    fn topk_zero_and_oversized_k() {
        let mut empty = TopKSink::global(0);
        feed(&mut empty);
        let SinkData::TopK(pairs) = empty.finish().unwrap().data else { panic!() };
        assert!(pairs.is_empty());

        let mut all = TopKSink::global(100);
        feed(&mut all);
        let SinkData::TopK(pairs) = all.finish().unwrap().data else { panic!() };
        assert_eq!(pairs.len(), 6); // only 6 pairs exist
        for w in pairs.windows(2) {
            assert!(w[0].mi >= w[1].mi);
        }
    }

    #[test]
    fn topk_ties_break_by_index_like_posthoc() {
        let t = BlockTask { a_start: 0, a_len: 3, b_start: 0, b_len: 3 };
        let b = block(&t, |_, _| 1.0); // all pairs tie
        let mut sink = TopKSink::global(2);
        sink.consume_block(&t, &b).unwrap();
        let SinkData::TopK(pairs) = sink.finish().unwrap().data else { panic!() };
        assert_eq!((pairs[0].i, pairs[0].j), (0, 1));
        assert_eq!((pairs[1].i, pairs[1].j), (0, 2));
    }

    #[test]
    fn per_column_topk_covers_both_endpoints() {
        let mut sink = TopKSink::per_column(4, 1);
        feed(&mut sink);
        let SinkData::TopKPerColumn(cols) = sink.finish().unwrap().data else { panic!() };
        assert_eq!(cols.len(), 4);
        // column 0's best partner is 3 (value 3), column 3's is 2 (23)
        assert_eq!((cols[0][0].i, cols[0][0].j), (0, 3));
        assert_eq!((cols[3][0].i, cols[3][0].j), (2, 3));
        for c in &cols {
            assert_eq!(c.len(), 1);
        }
    }

    #[test]
    fn threshold_sink_filters_and_sorts() {
        let mut sink = ThresholdSink::by_mi(12.0);
        feed(&mut sink);
        let SinkData::Sparse(sp) = sink.finish().unwrap().data else { panic!() };
        let got: Vec<(usize, usize)> = sp.pairs.iter().map(|p| (p.i, p.j)).collect();
        assert_eq!(got, vec![(1, 2), (1, 3), (2, 3)]);
        assert_eq!(sp.nnz(), 3);
        assert_eq!(sp.pvalue, None);
    }

    #[test]
    fn spill_sink_round_trips() {
        let dir = std::env::temp_dir()
            .join(format!("bulkmi-spill-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut sink = TileSpillSink::new(&dir, 4).unwrap();
        feed(&mut sink);
        let SinkData::Spilled(info) = sink.finish().unwrap().data else { panic!() };
        assert_eq!(info.tiles, 3);
        assert_eq!(info.bytes, 3 * 4 * 8);
        let mi = assemble_spilled(&dir).unwrap();
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(mi.get(i, j), (i.min(j) * 10 + i.max(j)) as f64);
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_manifest_is_incremental_and_resumable() {
        let dir = std::env::temp_dir()
            .join(format!("bulkmi-spill-resume-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let tasks = [
            BlockTask { a_start: 0, a_len: 2, b_start: 0, b_len: 2 },
            BlockTask { a_start: 0, a_len: 2, b_start: 2, b_len: 2 },
            BlockTask { a_start: 2, a_len: 2, b_start: 2, b_len: 2 },
        ];
        let value = |i: usize, j: usize| (i.min(j) * 10 + i.max(j)) as f64;
        // write only the first two tiles, then "crash" (drop the sink
        // without finish): the manifest must already list both
        {
            let mut sink = TileSpillSink::new(&dir, 4).unwrap();
            for t in &tasks[..2] {
                sink.consume_block(t, &block(t, value)).unwrap();
            }
        }
        let man = read_spill_manifest(&dir).unwrap();
        assert_eq!((man.m, man.complete, man.tiles.len()), (4, false, 2));
        // assembling a crashed run must refuse, pointing at resume
        let err = assemble_spilled(&dir).unwrap_err().to_string();
        assert!(err.contains("resume"), "{err}");
        // resume: the done tiles verify and come back; finish the rest
        let (mut sink, done) = TileSpillSink::resume(&dir).unwrap();
        assert_eq!(done, tasks[..2]);
        sink.consume_block(&tasks[2], &block(&tasks[2], value)).unwrap();
        let SinkData::Spilled(info) = sink.finish().unwrap().data else { panic!() };
        assert_eq!((info.tiles, info.bytes), (3, 3 * 4 * 8));
        let mi = assemble_spilled(&dir).unwrap();
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(mi.get(i, j), value(i, j));
            }
        }
        // a torn final row (crash mid-append) is tolerated when the
        // manifest is incomplete
        let manifest = std::fs::read_to_string(dir.join("manifest.csv")).unwrap();
        let torn = manifest.replace(&format!("{SPILL_COMPLETE}\n"), "") + "2,2,0";
        std::fs::write(dir.join("manifest.csv"), torn).unwrap();
        let man = read_spill_manifest(&dir).unwrap();
        assert_eq!((man.complete, man.tiles.len()), (false, 3));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_spill_tiles_are_named_not_assembled() {
        let dir = std::env::temp_dir()
            .join(format!("bulkmi-spill-corrupt-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut sink = TileSpillSink::new(&dir, 4).unwrap();
        feed(&mut sink);
        sink.finish().unwrap();
        // truncate one tile
        let t0 = dir.join("tile_0_0.f64");
        let raw = std::fs::read(&t0).unwrap();
        std::fs::write(&t0, &raw[..raw.len() - 8]).unwrap();
        let err = assemble_spilled(&dir).unwrap_err().to_string();
        assert!(err.contains("tile_0_0.f64"), "{err}");
        std::fs::write(&t0, &raw).unwrap();
        // flip one byte in another: the length check passes, the
        // checksum must catch it
        let t1 = dir.join("tile_0_2.f64");
        let mut raw = std::fs::read(&t1).unwrap();
        raw[3] ^= 0x01;
        std::fs::write(&t1, &raw).unwrap();
        let err = assemble_spilled(&dir).unwrap_err().to_string();
        assert!(err.contains("tile_0_2.f64") && err.contains("checksum"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_v1_spill_manifests_still_assemble() {
        let dir = std::env::temp_dir()
            .join(format!("bulkmi-spill-v1-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let t = BlockTask { a_start: 0, a_len: 2, b_start: 0, b_len: 2 };
        let b = block(&t, |i, j| (i * 10 + j) as f64);
        let mut buf = Vec::new();
        for v in b.data() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(dir.join("tile_0_0.f64"), &buf).unwrap();
        std::fs::write(
            dir.join("manifest.csv"),
            "m,2\na_start,a_len,b_start,b_len,file\n0,2,0,2,tile_0_0.f64\n",
        )
        .unwrap();
        let mi = assemble_spilled(&dir).unwrap();
        assert_eq!(mi.get(1, 1), 11.0);
        // v1 dirs predate resumability: a clean error, not a panic
        assert!(read_spill_manifest(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let t = BlockTask { a_start: 0, a_len: 2, b_start: 0, b_len: 2 };
        let wrong = Mat64::zeros(3, 2);
        assert!(DenseSink::new(4).consume_block(&t, &wrong).is_err());
        assert!(TopKSink::global(3).consume_block(&t, &wrong).is_err());
        assert!(ThresholdSink::by_mi(0.0).consume_block(&t, &wrong).is_err());
    }

    #[test]
    fn spec_parse_round_trip() {
        assert_eq!(SinkSpec::parse("dense").unwrap(), SinkSpec::Dense);
        assert_eq!(
            SinkSpec::parse("topk:100").unwrap(),
            SinkSpec::TopK { k: 100, per_column: false }
        );
        assert_eq!(
            SinkSpec::parse("topk-per-col:5").unwrap(),
            SinkSpec::TopK { k: 5, per_column: true }
        );
        assert_eq!(
            SinkSpec::parse("threshold:0.25").unwrap(),
            SinkSpec::ThresholdMi { threshold: 0.25 }
        );
        assert_eq!(
            SinkSpec::parse("pvalue:0.01").unwrap(),
            SinkSpec::ThresholdPvalue { pvalue: 0.01 }
        );
        assert_eq!(
            SinkSpec::parse("spill:/tmp/x").unwrap(),
            SinkSpec::Spill { dir: PathBuf::from("/tmp/x") }
        );
        assert!(SinkSpec::parse("topk").is_err());
        assert!(SinkSpec::parse("topk:ten").is_err());
        assert!(SinkSpec::parse("bogus:1").is_err());
    }

    #[test]
    fn pvalue_sink_is_measure_aware() {
        // mi: cutoff in MI bits
        let mi = ThresholdSink::by_pvalue_for(0.01, 10_000, CombineKind::Mi).unwrap();
        let want = crate::mi::significance::mi_threshold_for_pvalue(0.01, 10_000).unwrap();
        assert_eq!(mi.threshold(), want);
        // gstat: the chi²₁ critical value itself (≈ 6.635 at P = 0.01)
        let g = ThresholdSink::by_pvalue_for(0.01, 10_000, CombineKind::GStat).unwrap();
        assert!((g.threshold() - 6.635).abs() < 0.01, "{}", g.threshold());
        // measures without an asymptotic null: clean Err, not a panic
        for k in CombineKind::ALL {
            let built = SinkSpec::ThresholdPvalue { pvalue: 0.01 }.build_for(4, 100, k);
            assert_eq!(built.is_ok(), k.supports_pvalue_sink(), "{k}");
        }
        // non-pvalue sinks build under every measure
        for k in CombineKind::ALL {
            for s in ["dense", "topk:2", "topk-per-col:1", "threshold:0.5"] {
                SinkSpec::parse(s).unwrap().build_for(4, 100, k).unwrap();
            }
        }
    }

    #[test]
    fn merge_matches_single_process_for_every_sink_kind() {
        let tasks = [
            BlockTask { a_start: 0, a_len: 2, b_start: 0, b_len: 2 },
            BlockTask { a_start: 0, a_len: 2, b_start: 2, b_len: 2 },
            BlockTask { a_start: 2, a_len: 2, b_start: 2, b_len: 2 },
        ];
        let value = |i: usize, j: usize| (i.min(j) * 10 + i.max(j)) as f64;
        let feed_some = |sink: &mut dyn MiSink, idxs: &[usize]| {
            for &k in idxs {
                let t = &tasks[k];
                sink.consume_block(t, &block(t, value)).unwrap();
            }
        };
        for s in ["dense", "topk:3", "topk-per-col:1", "threshold:2.0"] {
            let spec = SinkSpec::parse(s).unwrap();
            let mut whole = spec.build(4, 100).unwrap();
            feed_some(whole.as_mut(), &[0, 1, 2]);
            let want = format!("{:?}", whole.finish().unwrap().data);

            // shard the same cell set over three sinks and merge
            let mut primary = spec.build(4, 100).unwrap();
            feed_some(primary.as_mut(), &[0]);
            for shard_tasks in [&[1usize][..], &[2][..]] {
                let mut shard = spec.build(4, 100).unwrap();
                feed_some(shard.as_mut(), shard_tasks);
                primary.merge(shard.finish().unwrap().data).unwrap();
            }
            let got = format!("{:?}", primary.finish().unwrap().data);
            assert_eq!(got, want, "{s}");
        }
    }

    #[test]
    fn spill_merge_adopts_shard_tiles() {
        let base = std::env::temp_dir()
            .join(format!("bulkmi-spill-merge-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let tasks = [
            BlockTask { a_start: 0, a_len: 2, b_start: 0, b_len: 2 },
            BlockTask { a_start: 0, a_len: 2, b_start: 2, b_len: 2 },
            BlockTask { a_start: 2, a_len: 2, b_start: 2, b_len: 2 },
        ];
        let value = |i: usize, j: usize| (i.min(j) * 10 + i.max(j)) as f64;
        let mut primary = TileSpillSink::new(base.join("run"), 4).unwrap();
        primary.consume_block(&tasks[0], &block(&tasks[0], value)).unwrap();
        let shard_dir = base.join("shard-0");
        let mut shard = TileSpillSink::new(&shard_dir, 4).unwrap();
        for t in &tasks[1..] {
            shard.consume_block(t, &block(t, value)).unwrap();
        }
        primary.merge(shard.finish().unwrap().data).unwrap();
        let SinkData::Spilled(info) = primary.finish().unwrap().data else { panic!() };
        assert_eq!(info.tiles, 3);
        assert!(!shard_dir.exists(), "adopted shard dir must be removed");
        let mi = assemble_spilled(&base.join("run")).unwrap();
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(mi.get(i, j), value(i, j));
            }
        }
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn merge_kind_mismatch_is_a_clean_error() {
        assert!(DenseSink::new(4).merge(SinkData::TopK(Vec::new())).is_err());
        assert!(TopKSink::global(2).merge(SinkData::TopKPerColumn(Vec::new())).is_err());
        assert!(TopKSink::per_column(3, 1).merge(SinkData::TopK(Vec::new())).is_err());
        assert!(ThresholdSink::by_mi(1.0).merge(SinkData::TopK(Vec::new())).is_err());
        // shard/run cutoff mismatch is refused, not silently mixed
        let sp = SparsePairs { threshold: 0.5, pvalue: None, pairs: Vec::new() };
        assert!(ThresholdSink::by_mi(1.0).merge(SinkData::Sparse(sp)).is_err());
    }

    #[test]
    fn spec_builds_every_sink() {
        for s in ["dense", "topk:3", "topk-per-col:2", "threshold:0.1", "pvalue:0.05"] {
            let spec = SinkSpec::parse(s).unwrap();
            let mut sink = spec.build(4, 100).unwrap();
            feed(sink.as_mut());
            sink.finish().unwrap();
        }
        assert!(SinkSpec::parse("pvalue:2.0").unwrap().build(4, 100).is_err());
    }
}
