//! Backend selection and dispatch: one enum covering every
//! implementation the paper compares (Table 1's five columns, plus the
//! bit-packed extra), a parser for CLI/config use, and a uniform
//! `compute_mi` entry point.

use super::autotune::{autotune, autotune_source, ProbeReport};
use super::bulk_basic::measure_bulk_basic;
use super::measure::{measure_pairwise, CombineKind};
use super::xla::XlaMi;
use super::MiMatrix;
use crate::coordinator::executor::{compute_source, NativeKind};
use crate::data::colstore::{ColumnSource, InMemorySource};
use crate::data::dataset::BinaryDataset;
use crate::util::error::{Error, Result};

/// Every MI implementation the crate ships.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Sequential per-pair baseline (paper: "SKL Pairwise").
    Pairwise,
    /// Section-2 basic bulk, four dense Grams (paper: "Bas-NN").
    BulkBasic,
    /// Section-3 optimized bulk, one dense Gram (paper: "Opt-NN").
    BulkOpt,
    /// Section-3 on CSR sparse (paper: "Opt-SS").
    BulkSparse,
    /// Section-3 on bit-packed popcount (hardware-optimized native).
    BulkBitpack,
    /// Micro-probe the optimized native substrates on a sampled block
    /// and commit to the fastest ([`crate::mi::autotune`]).
    Auto,
    /// Section-3 through AOT XLA artifacts (paper: "Opt-T").
    Xla,
    /// Same, routed through the interpret-mode Pallas kernels.
    XlaPallas,
}

impl Backend {
    /// All backends, in the paper's Table-1 column order (+ extras).
    pub const ALL: [Backend; 8] = [
        Backend::Pairwise,
        Backend::BulkBasic,
        Backend::BulkOpt,
        Backend::BulkSparse,
        Backend::BulkBitpack,
        Backend::Auto,
        Backend::Xla,
        Backend::XlaPallas,
    ];

    /// Stable identifier used by the CLI, config and bench output.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Pairwise => "pairwise",
            Backend::BulkBasic => "bulk-basic",
            Backend::BulkOpt => "bulk-opt",
            Backend::BulkSparse => "bulk-sparse",
            Backend::BulkBitpack => "bulk-bitpack",
            Backend::Auto => "auto",
            Backend::Xla => "xla",
            Backend::XlaPallas => "xla-pallas",
        }
    }

    /// The paper's label for this implementation (where one exists).
    pub fn paper_label(self) -> &'static str {
        match self {
            Backend::Pairwise => "SKL Pairwise",
            Backend::BulkBasic => "Bas-NN",
            Backend::BulkOpt => "Opt-NN",
            Backend::BulkSparse => "Opt-SS",
            Backend::BulkBitpack => "Opt-bitpack (ours)",
            Backend::Auto => "Opt-auto (probed)",
            Backend::Xla => "Opt-T",
            Backend::XlaPallas => "Opt-T (pallas)",
        }
    }

    pub fn parse(s: &str) -> Option<Backend> {
        Backend::ALL.iter().copied().find(|b| b.name() == s)
    }

    /// Backends that need no XLA artifacts (always available).
    pub fn is_native(self) -> bool {
        !matches!(self, Backend::Xla | Backend::XlaPallas)
    }

    /// The blockwise-engine Gram substrate this backend maps to (the
    /// coordinator / sink paths use it for blockwise plans). `Pairwise`
    /// and `BulkBasic` have no block provider of their own and map to
    /// the substrate that matches their cost profile best. `Auto` must
    /// be [`Self::resolve`]d first; unresolved it maps to the bitpack
    /// default.
    pub fn native_kind(self) -> NativeKind {
        match self {
            Backend::BulkSparse => NativeKind::Sparse,
            Backend::BulkBasic | Backend::BulkOpt => NativeKind::Dense,
            _ => NativeKind::Bitpack,
        }
    }

    /// Resolve `Auto` to a concrete fixed backend by micro-probing the
    /// dataset ([`crate::mi::autotune`]; identically-shaped datasets
    /// hit the process-wide probe cache); every other backend resolves
    /// to itself with no probe.
    ///
    /// ```
    /// use bulkmi::data::synth::SynthSpec;
    /// use bulkmi::mi::backend::Backend;
    ///
    /// let ds = SynthSpec::new(256, 16).sparsity(0.8).seed(1).generate();
    ///
    /// // Auto probes and commits to one of the optimized substrates
    /// let (fixed, probe) = Backend::Auto.resolve(&ds).unwrap();
    /// assert_ne!(fixed, Backend::Auto);
    /// assert!(fixed.is_native());
    /// let report = probe.expect("auto always attaches its probe report");
    /// assert_eq!(report.chosen, fixed);
    ///
    /// // fixed backends resolve to themselves without probing
    /// let (same, none) = Backend::BulkOpt.resolve(&ds).unwrap();
    /// assert_eq!(same, Backend::BulkOpt);
    /// assert!(none.is_none());
    /// ```
    pub fn resolve(self, ds: &BinaryDataset) -> Result<(Backend, Option<ProbeReport>)> {
        match self {
            Backend::Auto => {
                let report = autotune(ds)?;
                Ok((report.chosen, Some(report)))
            }
            fixed => Ok((fixed, None)),
        }
    }

    /// [`Self::resolve`] over any [`ColumnSource`]: `Auto` probes
    /// through block fetches ([`crate::mi::autotune::autotune_source`])
    /// so streaming inputs resolve without materializing the dataset;
    /// fixed backends resolve to themselves with no probe. Shares the
    /// probe cache with [`Self::resolve`].
    pub fn resolve_source(self, src: &dyn ColumnSource) -> Result<(Backend, Option<ProbeReport>)> {
        match self {
            Backend::Auto => {
                let report = autotune_source(src)?;
                Ok((report.chosen, Some(report)))
            }
            fixed => Ok((fixed, None)),
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Compute the full MI matrix with the chosen backend.
///
/// XLA backends construct a fresh runtime per call; callers doing many
/// computations should hold an [`XlaMi`] instead (executable caching).
pub fn compute_mi(ds: &BinaryDataset, backend: Backend) -> Result<MiMatrix> {
    compute_mi_with(ds, backend, 1)
}

/// Like [`compute_mi`] with an explicit worker count for backends that
/// parallelize.
pub fn compute_mi_with(ds: &BinaryDataset, backend: Backend, workers: usize) -> Result<MiMatrix> {
    compute_measure_with(ds, backend, workers, CombineKind::Mi)
}

/// Compute the full matrix of any association measure
/// ([`crate::mi::measure::CombineKind`]) with the chosen backend —
/// same Gram work as MI, different element-wise combine.
pub fn compute_measure(
    ds: &BinaryDataset,
    backend: Backend,
    measure: CombineKind,
) -> Result<MiMatrix> {
    compute_measure_with(ds, backend, 1, measure)
}

/// [`compute_measure`] with an explicit worker count. The XLA backends
/// fuse the *MI* combine into their AOT artifact graphs, so they accept
/// only [`CombineKind::Mi`]; every native backend accepts every
/// measure.
pub fn compute_measure_with(
    ds: &BinaryDataset,
    backend: Backend,
    workers: usize,
    measure: CombineKind,
) -> Result<MiMatrix> {
    if ds.n_rows() == 0 || ds.n_cols() == 0 {
        return Err(Error::Shape("empty dataset".into()));
    }
    if !backend.is_native() && measure != CombineKind::Mi {
        return Err(Error::Parse(format!(
            "measure '{measure}' needs a native backend: '{backend}' combines MI inside \
             its AOT artifact graph"
        )));
    }
    match backend {
        Backend::Pairwise => Ok(measure_pairwise(ds, measure)),
        // the deliberate Section-2 ablation baseline (4 Gram matmuls)
        Backend::BulkBasic => Ok(measure_bulk_basic(ds, measure)),
        // all optimized native backends are one engine, three substrates
        Backend::BulkOpt => {
            compute_source(&InMemorySource::new(ds), NativeKind::Dense, workers, measure)
        }
        Backend::BulkSparse => {
            compute_source(&InMemorySource::new(ds), NativeKind::Sparse, workers, measure)
        }
        Backend::BulkBitpack => {
            compute_source(&InMemorySource::new(ds), NativeKind::Bitpack, workers, measure)
        }
        Backend::Auto => {
            let (chosen, report) = backend.resolve(ds)?;
            if let Some(r) = &report {
                crate::info!("{}", r.summary());
            }
            compute_source(&InMemorySource::new(ds), chosen.native_kind(), workers, measure)
        }
        Backend::Xla => XlaMi::load_default()?.compute(ds),
        Backend::XlaPallas => XlaMi::load_default_pallas()?.compute(ds),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn names_round_trip() {
        for b in Backend::ALL {
            assert_eq!(Backend::parse(b.name()), Some(b));
        }
        assert_eq!(Backend::parse("nope"), None);
    }

    #[test]
    fn native_backends_agree() {
        let ds = SynthSpec::new(120, 14).sparsity(0.8).seed(1).generate();
        let reference = compute_mi(&ds, Backend::Pairwise).unwrap();
        for b in Backend::ALL.iter().copied().filter(|b| b.is_native()) {
            let got = compute_mi(&ds, b).unwrap();
            assert!(
                got.max_abs_diff(&reference) < 1e-10,
                "{b}: diff {}",
                got.max_abs_diff(&reference)
            );
        }
    }

    #[test]
    fn empty_dataset_rejected() {
        let ds = BinaryDataset::new(0, 0, vec![]).unwrap();
        assert!(compute_mi(&ds, Backend::BulkOpt).is_err());
        assert!(compute_measure(&ds, Backend::BulkOpt, CombineKind::Phi).is_err());
    }

    #[test]
    fn non_mi_measure_rejected_on_xla_backends() {
        let ds = SynthSpec::new(64, 5).sparsity(0.5).seed(3).generate();
        for backend in [Backend::Xla, Backend::XlaPallas] {
            let err = compute_measure(&ds, backend, CombineKind::Jaccard).unwrap_err();
            assert!(err.to_string().contains("native"), "{err}");
        }
    }

    #[test]
    fn mi_measure_is_the_mi_path() {
        let ds = SynthSpec::new(100, 8).sparsity(0.6).seed(4).generate();
        let a = compute_mi(&ds, Backend::BulkBitpack).unwrap();
        let b = compute_measure(&ds, Backend::BulkBitpack, CombineKind::Mi).unwrap();
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn display_matches_name() {
        assert_eq!(Backend::BulkOpt.to_string(), "bulk-opt");
    }
}
