//! Backend autotuner: the `--backend auto` implementation.
//!
//! The three optimized native backends (`bulk-opt` / `bulk-sparse` /
//! `bulk-bitpack`) are one algorithm on three Gram substrates, so the
//! right choice is purely a hardware + data-shape question: bitpack wins
//! almost everywhere, CSR wins at extreme sparsity, dense f32 can win
//! on tiny row counts where packing overhead dominates. Rather than
//! encode fragile closed-form rules, the autotuner **micro-probes**: it
//! carves a small deterministic probe block out of the dataset (evenly
//! strided columns so planted structure or column ordering cannot skew
//! it, a bounded row prefix), measures each eligible backend's Gram
//! throughput on that block with warmup + best-of-k, records the
//! density estimate alongside, and commits the whole block plan to the
//! winner. The probed winner is by construction never slower *on the
//! probe block* than any fixed choice — the acceptance invariant
//! checked in `rust/tests/autotune.rs`.
//!
//! All native backends are exact and bit-identical, so an imperfect
//! probe can only ever cost time, never correctness.
//!
//! **Probe cache.** A probe verdict is a property of the hardware and
//! the dataset's *shape* — the same machine probing another dataset of
//! the same `(n_rows, n_cols, density bucket)` will reach the same
//! conclusion, so `serve` workloads that submit many identically-shaped
//! jobs should not pay the probe (a few milliseconds of warmup + timing
//! per job) more than once. [`autotune`] therefore consults a
//! process-wide cache keyed by [`ProbeKey`]; a hit returns the stored
//! report with [`ProbeReport::cached`] set and skips all timing.
//! [`autotune_uncached`] bypasses the cache (the bench harness uses it
//! so `backend-auto` entries always time a real probe).
//!
//! **Persistent probe cache.** When the `BULKMI_CACHE_DIR` environment
//! variable names a directory, probe verdicts also persist across
//! *processes*: a RAM miss consults `probe-cache.v1` under that root
//! before timing anything, and a fresh probe rewrites it (merged with
//! the valid entries already on disk). Because a verdict is a hardware
//! property, the file is guarded by `hardware.fpr` — a fingerprint of
//! the CPU brand string, the CPU feature flags, and the active SIMD
//! kernel — and the whole cache is ignored (then rewritten) when the
//! fingerprint changes. A corrupt cache file is ignored with a warning,
//! never an error: the worst case is one redundant probe.

use super::backend::Backend;
use super::combine_kernels::{combine_block_with, LogTable};
use super::measure::CombineKind;
use crate::coordinator::executor::NativeKind;
use crate::data::colstore::ColumnSource;
use crate::data::dataset::BinaryDataset;
use crate::util::error::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Columns in the probe block (fewer when the dataset is narrower).
pub const PROBE_MAX_COLS: usize = 48;
/// Rows in the probe block (fewer when the dataset is shorter).
pub const PROBE_MAX_ROWS: usize = 8192;
/// Timed repetitions per candidate (after one warmup rep).
const PROBE_REPS: usize = 3;

/// One candidate's probe result.
#[derive(Clone, Debug)]
pub struct ProbeMeasurement {
    pub backend: Backend,
    /// Best-of-k seconds for one Gram of the probe block.
    pub secs: f64,
    /// Gram throughput on the probe block: output cells × rows / secs
    /// (comparable across candidates because the block is shared).
    pub throughput: f64,
}

/// One measure's combine-stage probe result: how long the element-wise
/// combine of the probe block's Gram takes for that [`CombineKind`].
/// The combine is substrate-independent (it maps an f64 Gram block), so
/// one timing per measure covers every backend.
#[derive(Clone, Debug)]
pub struct CombineMeasurement {
    pub measure: CombineKind,
    /// Best-of-k seconds for one combine of the probe block's Gram.
    pub secs: f64,
    /// Combine throughput: output cells / secs.
    pub cells_per_sec: f64,
}

/// What the autotuner saw and decided; recorded in
/// [`crate::mi::sink::SinkMeta`] so every auto run is auditable.
#[derive(Clone, Debug)]
pub struct ProbeReport {
    /// The winning fixed backend the run was committed to.
    pub chosen: Backend,
    /// Fraction of ones in the probe block (1 - sparsity).
    pub density: f64,
    pub probe_rows: usize,
    pub probe_cols: usize,
    /// All candidates, in probe order.
    pub candidates: Vec<ProbeMeasurement>,
    /// Combine-stage timing for every [`CombineKind`], on the probe
    /// block's Gram (one entry per measure, [`CombineKind::ALL`]
    /// order). Lets callers see how much of a run each measure's
    /// combine will cost relative to the Gram itself.
    pub combine: Vec<CombineMeasurement>,
    /// Did this report come from the process-wide probe cache (true)
    /// or from freshly timed measurements (false)? Cached reports carry
    /// the *original* run's timings.
    pub cached: bool,
}

impl ProbeReport {
    /// One-line human summary for logs and the CLI.
    pub fn summary(&self) -> String {
        let detail: Vec<String> = self
            .candidates
            .iter()
            .map(|c| format!("{} {:.2}ms", c.backend, c.secs * 1e3))
            .collect();
        format!(
            "auto probe{} ({}x{} block, density {:.4}): chose {} ({})",
            if self.cached { " [cached]" } else { "" },
            self.probe_rows,
            self.probe_cols,
            self.density,
            self.chosen,
            detail.join(", ")
        )
    }

    /// Probed Gram throughput (cell-rows/sec) of the chosen backend —
    /// what the planner folds into block sizing.
    pub fn chosen_throughput(&self) -> f64 {
        self.candidates
            .iter()
            .find(|c| c.backend == self.chosen)
            .map(|c| c.throughput)
            .unwrap_or(0.0)
    }

    /// The probed combine-stage time for `measure`, when the probe
    /// recorded one (always present on freshly probed reports).
    pub fn combine_secs(&self, measure: CombineKind) -> Option<f64> {
        self.combine.iter().find(|c| c.measure == measure).map(|c| c.secs)
    }

    /// The probed combine throughput (output cells/sec) for `measure` —
    /// what [`crate::coordinator::planner::block_policy`] folds into
    /// the latency model alongside [`Self::chosen_throughput`], so
    /// entropy-heavy measures size blocks against Gram + combine.
    /// `None` when the report carries no entry for the measure (e.g. a
    /// persisted report from before combine probing existed).
    pub fn combine_throughput(&self, measure: CombineKind) -> Option<f64> {
        self.combine.iter().find(|c| c.measure == measure).map(|c| c.cells_per_sec)
    }
}

/// Cache key for a probe verdict: dataset shape plus a coarse density
/// bucket. Shape is exact; density is bucketed because the probe's own
/// density estimate is what is available, and the backend choice only
/// flips across coarse density regimes (CSR wins at extreme sparsity,
/// bitpack nearly everywhere else).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ProbeKey {
    pub n_rows: usize,
    pub n_cols: usize,
    pub density_bucket: u16,
}

/// Bucket a density estimate for [`ProbeKey`]: 0.001-wide buckets below
/// 5% ones (where the sparse substrate's viability changes quickly),
/// 0.05-wide buckets above (where the choice is insensitive). The two
/// ranges cannot collide: the fine range tops out at bucket 50 and the
/// coarse range starts at 51.
pub fn density_bucket(density: f64) -> u16 {
    let d = density.clamp(0.0, 1.0);
    if d < 0.05 {
        (d * 1000.0).round() as u16
    } else {
        50 + (d * 20.0).round() as u16
    }
}

fn probe_cache() -> &'static Mutex<HashMap<ProbeKey, ProbeReport>> {
    static CACHE: OnceLock<Mutex<HashMap<ProbeKey, ProbeReport>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Drop every cached probe verdict (tests; long-lived services that
/// want to re-probe after, say, CPU-affinity changes).
pub fn clear_probe_cache() {
    probe_cache().lock().unwrap().clear();
}

/// The backends `--backend auto` chooses between: the optimized native
/// substrates with a block Gram provider. (`pairwise` and `bulk-basic`
/// are deliberate ablation baselines, `xla*` needs artifacts — none is
/// ever auto-selected.)
pub fn eligible() -> [Backend; 3] {
    [Backend::BulkBitpack, Backend::BulkOpt, Backend::BulkSparse]
}

/// Probe every eligible backend on a sampled block of `ds` and return
/// the full report, consulting the process-wide probe cache first: a
/// dataset matching a previously probed `(n_rows, n_cols, density
/// bucket)` gets the stored verdict back (with
/// [`ProbeReport::cached`] = true) without re-timing anything.
/// Deterministic in everything except the timings themselves.
pub fn autotune(ds: &BinaryDataset) -> Result<ProbeReport> {
    if ds.n_rows() == 0 || ds.n_cols() == 0 {
        return Err(Error::Shape("cannot autotune an empty dataset".into()));
    }
    autotune_probe_cached(probe_block(ds)?, ds.n_rows(), ds.n_cols())
}

/// [`autotune`] over any [`ColumnSource`]: the probe block is gathered
/// through `col_block` fetches (same evenly strided columns, same row
/// cap — byte-identical to the in-memory gather for the same data), so
/// streaming inputs like
/// [`crate::data::colstore::PackedFileSource`] probe without ever
/// materializing the dataset. Cache behavior is shared with
/// [`autotune`]: an in-memory job and a packed-file job of the same
/// shape and density hit the same verdict.
pub fn autotune_source(src: &dyn ColumnSource) -> Result<ProbeReport> {
    if src.n_rows() == 0 || src.n_cols() == 0 {
        return Err(Error::Shape("cannot autotune an empty source".into()));
    }
    autotune_probe_cached(probe_block_source(src)?, src.n_rows(), src.n_cols())
}

/// Shared cache-consulting tail of [`autotune`] / [`autotune_source`]:
/// RAM cache first, then (when `BULKMI_CACHE_DIR` is set) the on-disk
/// cache, then a fresh probe that populates both layers.
fn autotune_probe_cached(
    probe: BinaryDataset,
    n_rows: usize,
    n_cols: usize,
) -> Result<ProbeReport> {
    let density = 1.0 - probe.sparsity();
    let key = ProbeKey { n_rows, n_cols, density_bucket: density_bucket(density) };
    if let Some(hit) = probe_cache().lock().unwrap().get(&key) {
        let mut report = hit.clone();
        report.cached = true;
        return Ok(report);
    }
    let dir = persistent_cache_dir();
    let mut disk_entries = None;
    if let Some(d) = &dir {
        disk_entries = load_probe_cache(d);
        if let Some(hit) = disk_entries.as_ref().and_then(|m| m.get(&key)) {
            // Promote to RAM so later probes in this process skip the
            // disk read; the file itself is left untouched (a byte-
            // identical cache file is how tests prove no re-probe and
            // no rewrite happened).
            probe_cache().lock().unwrap().insert(key, hit.clone());
            let mut report = hit.clone();
            report.cached = true;
            return Ok(report);
        }
    }
    let report = probe_candidates(&probe, density)?;
    probe_cache().lock().unwrap().insert(key, report.clone());
    if let Some(d) = &dir {
        let mut entries = disk_entries.unwrap_or_default();
        entries.insert(key, report.clone());
        save_probe_cache(d, &entries);
    }
    Ok(report)
}

/// Environment variable naming the persistent cache root shared by the
/// probe cache (`probe-cache.v1` + `hardware.fpr`) and, by convention,
/// the tile cache. Unset (the default, and the state every in-process
/// test runs under) means the probe cache is RAM-only.
pub const CACHE_DIR_ENV: &str = "BULKMI_CACHE_DIR";

const PROBE_CACHE_FILE: &str = "probe-cache.v1";
const PROBE_CACHE_MAGIC: &str = "bulkmi-probe-cache,v1";
const FINGERPRINT_FILE: &str = "hardware.fpr";

fn persistent_cache_dir() -> Option<PathBuf> {
    std::env::var_os(CACHE_DIR_ENV)
        .filter(|v| !v.is_empty())
        .map(PathBuf::from)
}

/// The hardware identity a probe verdict is valid for: CPU brand
/// string, an FNV digest of the CPU feature flags, the active SIMD
/// kernel's name, and the arch/OS pair. Any component changing (new
/// machine, kernel dispatch picking a different path after a binary
/// upgrade) must invalidate persisted verdicts — timings from other
/// hardware are not merely stale, they are misleading.
pub fn hardware_fingerprint() -> String {
    format!(
        "{}|flags:{}|kernel:{}|{}-{}",
        cpu_brand(),
        cpu_flags_digest(),
        crate::linalg::kernels::active().name(),
        std::env::consts::ARCH,
        std::env::consts::OS
    )
}

fn cpu_brand() -> String {
    if let Ok(text) = std::fs::read_to_string("/proc/cpuinfo") {
        for line in text.lines() {
            // x86 calls it "model name"; some aarch64 kernels expose
            // "Hardware" or nothing useful — fall through in that case.
            if line.starts_with("model name") || line.starts_with("Hardware") {
                if let Some((_, v)) = line.split_once(':') {
                    return v.trim().to_string();
                }
            }
        }
    }
    "unknown-cpu".to_string()
}

fn cpu_flags_digest() -> String {
    if let Ok(text) = std::fs::read_to_string("/proc/cpuinfo") {
        for line in text.lines() {
            if line.starts_with("flags") || line.starts_with("Features") {
                if let Some((_, v)) = line.split_once(':') {
                    let fp = crate::coordinator::tilecache::fnv1a(v.trim().as_bytes());
                    return format!("{fp:016x}");
                }
            }
        }
    }
    "none".to_string()
}

/// Load the persisted probe cache under `dir`, returning `None` when
/// there is nothing usable: no fingerprint file yet, a fingerprint that
/// does not match this hardware (silent invalidation — the next save
/// rewrites both files), or a cache file that fails to parse (warned,
/// because it indicates corruption rather than a hardware change).
pub fn load_probe_cache(dir: &Path) -> Option<HashMap<ProbeKey, ProbeReport>> {
    let stored = std::fs::read_to_string(dir.join(FINGERPRINT_FILE)).ok()?;
    if stored.trim_end() != hardware_fingerprint() {
        return None;
    }
    let text = match std::fs::read_to_string(dir.join(PROBE_CACHE_FILE)) {
        Ok(t) => t,
        // fingerprint present but no cache yet: valid, empty
        Err(_) => return Some(HashMap::new()),
    };
    match parse_probe_cache(&text) {
        Some(map) => Some(map),
        None => {
            eprintln!(
                "warning: ignoring corrupt probe cache at {} (will be rewritten by the next probe)",
                dir.join(PROBE_CACHE_FILE).display()
            );
            None
        }
    }
}

/// Persist `entries` (plus the current hardware fingerprint) under
/// `dir`, creating it if needed. Failures warn and return — a machine
/// with a read-only or missing cache root just re-probes next time.
pub fn save_probe_cache(dir: &Path, entries: &HashMap<ProbeKey, ProbeReport>) {
    if let Err(e) = try_save_probe_cache(dir, entries) {
        eprintln!("warning: could not persist probe cache to {}: {e}", dir.display());
    }
}

fn try_save_probe_cache(
    dir: &Path,
    entries: &HashMap<ProbeKey, ProbeReport>,
) -> std::io::Result<()> {
    use std::io::Write;
    std::fs::create_dir_all(dir)?;
    let stamp = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0);
    let mut out = String::new();
    out.push_str(PROBE_CACHE_MAGIC);
    out.push('\n');
    out.push_str(&format!("stamp,{stamp}\n"));
    // deterministic entry order so diffs between saves are meaningful
    let mut keys: Vec<&ProbeKey> = entries.keys().collect();
    keys.sort_by_key(|k| (k.n_rows, k.n_cols, k.density_bucket));
    for key in keys {
        let r = &entries[key];
        out.push_str(&format!(
            "entry,{},{},{},{},{},{},{}\n",
            key.n_rows,
            key.n_cols,
            key.density_bucket,
            r.chosen.name(),
            r.density,
            r.probe_rows,
            r.probe_cols
        ));
        for c in &r.candidates {
            out.push_str(&format!("cand,{},{},{}\n", c.backend.name(), c.secs, c.throughput));
        }
        for c in &r.combine {
            out.push_str(&format!("comb,{},{},{}\n", c.measure.name(), c.secs, c.cells_per_sec));
        }
        out.push_str("end\n");
    }
    // tmp + rename so a crash mid-write never leaves a torn cache file
    let write_atomic = |name: &str, body: &str| -> std::io::Result<()> {
        let tmp = dir.join(format!("{name}.tmp"));
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(body.as_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, dir.join(name))
    };
    write_atomic(PROBE_CACHE_FILE, &out)?;
    write_atomic(FINGERPRINT_FILE, &format!("{}\n", hardware_fingerprint()))
}

/// Parse a `probe-cache.v1` body; `None` on any structural defect
/// (wrong magic, torn entry, malformed number) — the caller treats the
/// whole file as corrupt rather than trusting a readable prefix.
fn parse_probe_cache(text: &str) -> Option<HashMap<ProbeKey, ProbeReport>> {
    let mut lines = text.lines();
    if lines.next()? != PROBE_CACHE_MAGIC {
        return None;
    }
    if !lines.next()?.starts_with("stamp,") {
        return None;
    }
    let mut map = HashMap::new();
    let mut cur: Option<(ProbeKey, ProbeReport)> = None;
    for line in lines {
        let mut f = line.split(',');
        match f.next()? {
            "entry" => {
                if cur.is_some() {
                    return None; // previous entry never reached "end"
                }
                let key = ProbeKey {
                    n_rows: f.next()?.parse().ok()?,
                    n_cols: f.next()?.parse().ok()?,
                    density_bucket: f.next()?.parse().ok()?,
                };
                let report = ProbeReport {
                    chosen: Backend::parse(f.next()?)?,
                    density: f.next()?.parse().ok()?,
                    probe_rows: f.next()?.parse().ok()?,
                    probe_cols: f.next()?.parse().ok()?,
                    candidates: Vec::new(),
                    combine: Vec::new(),
                    cached: false,
                };
                cur = Some((key, report));
            }
            "cand" => {
                cur.as_mut()?.1.candidates.push(ProbeMeasurement {
                    backend: Backend::parse(f.next()?)?,
                    secs: f.next()?.parse().ok()?,
                    throughput: f.next()?.parse().ok()?,
                });
            }
            "comb" => {
                cur.as_mut()?.1.combine.push(CombineMeasurement {
                    measure: CombineKind::parse(f.next()?)?,
                    secs: f.next()?.parse().ok()?,
                    cells_per_sec: f.next()?.parse().ok()?,
                });
            }
            "end" => {
                let (key, report) = cur.take()?;
                map.insert(key, report);
            }
            _ => return None,
        }
    }
    if cur.is_some() {
        return None; // truncated mid-entry
    }
    Some(map)
}

/// [`autotune`] bypassing the probe cache: always times a fresh probe
/// and never stores the result. The bench harness uses this so its
/// `backend-auto` entries measure the probe itself, not a cache hit.
pub fn autotune_uncached(ds: &BinaryDataset) -> Result<ProbeReport> {
    if ds.n_rows() == 0 || ds.n_cols() == 0 {
        return Err(Error::Shape("cannot autotune an empty dataset".into()));
    }
    let probe = probe_block(ds)?;
    let density = 1.0 - probe.sparsity();
    probe_candidates(&probe, density)
}

/// Time every eligible backend on the prepared probe block.
fn probe_candidates(probe: &BinaryDataset, density: f64) -> Result<ProbeReport> {
    let cells = (probe.n_cols() * probe.n_cols()) as f64 * probe.n_rows() as f64;
    let mut candidates = Vec::with_capacity(3);
    for backend in eligible() {
        let secs = gram_secs(probe, backend.native_kind());
        candidates.push(ProbeMeasurement {
            backend,
            secs,
            throughput: cells / secs.max(1e-12),
        });
    }
    let chosen = candidates
        .iter()
        .max_by(|a, b| {
            a.throughput
                .partial_cmp(&b.throughput)
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .expect("three candidates")
        .backend;
    Ok(ProbeReport {
        chosen,
        density,
        probe_rows: probe.n_rows(),
        probe_cols: probe.n_cols(),
        candidates,
        combine: probe_combine(probe),
        cached: false,
    })
}

/// Time every measure's element-wise combine on the probe block's Gram
/// (the combine is substrate-independent, so the bit-packed Gram serves
/// as the shared input). Cells are tiny (≤ 48x48), so this adds
/// microseconds to the probe while making the per-measure combine cost
/// auditable in the report.
///
/// Times the table-driven block kernels
/// ([`crate::mi::combine_kernels::combine_block_with`]) — the exact
/// code the executor runs per task — with the [`LogTable`] built once
/// *outside* the timed region, matching production where the table is
/// amortized across a whole run rather than paid per block.
fn probe_combine(probe: &BinaryDataset) -> Vec<CombineMeasurement> {
    let g11 = probe.to_bitmatrix().gram();
    let colsums: Vec<f64> = probe.col_counts().iter().map(|&v| v as f64).collect();
    let n = probe.n_rows() as f64;
    let lt = LogTable::new(probe.n_rows());
    let cells = (probe.n_cols() * probe.n_cols()) as f64;
    CombineKind::ALL
        .iter()
        .map(|&measure| {
            let secs = best_of(|| {
                std::hint::black_box(combine_block_with(measure, &lt, &g11, &colsums, &colsums, n));
            });
            CombineMeasurement { measure, secs, cells_per_sec: cells / secs.max(1e-12) }
        })
        .collect()
}

/// The probe's column choice: every column when the dataset is narrow
/// enough, else [`PROBE_MAX_COLS`] evenly strided columns (so planted
/// structure or column ordering cannot skew the sample).
fn probe_cols(m: usize) -> Vec<usize> {
    if m <= PROBE_MAX_COLS {
        (0..m).collect()
    } else {
        (0..PROBE_MAX_COLS).map(|k| k * m / PROBE_MAX_COLS).collect()
    }
}

/// The deterministic probe block: the [`probe_cols`] columns over the
/// first [`PROBE_MAX_ROWS`] rows, gathered directly so the copy is
/// O(probe_rows × probe_cols) — never a row-height or column-width pass
/// over the full dataset.
fn probe_block(ds: &BinaryDataset) -> Result<BinaryDataset> {
    let m = ds.n_cols();
    let rows = ds.n_rows().min(PROBE_MAX_ROWS);
    if m <= PROBE_MAX_COLS {
        return ds.row_chunk(0, rows);
    }
    let idx = probe_cols(m);
    let mut data = Vec::with_capacity(rows * idx.len());
    for r in 0..rows {
        let row = ds.row(r);
        data.extend(idx.iter().map(|&c| row[c]));
    }
    BinaryDataset::new(rows, idx.len(), data)
}

/// [`probe_block`] through a [`ColumnSource`]: fetches each probe
/// column's packed words (one small read per column for a file-backed
/// source) and unpacks the first `rows` bits. Produces byte-identical
/// probe data to [`probe_block`] for the same underlying dataset.
fn probe_block_source(src: &dyn ColumnSource) -> Result<BinaryDataset> {
    let rows = src.n_rows().min(PROBE_MAX_ROWS);
    let idx = probe_cols(src.n_cols());
    let mut data = vec![0u8; rows * idx.len()];
    for (pc, &c) in idx.iter().enumerate() {
        let col = src.col_block(c, 1)?;
        for r in 0..rows {
            if col.get(r, 0) {
                data[r * idx.len() + pc] = 1;
            }
        }
    }
    BinaryDataset::new(rows, idx.len(), data)
}

/// Best-of-k time of one substrate's *per-task* cost on the probe
/// block: substrate construction from a bit-packed block plus its
/// Gram — exactly what `NativeProvider::block_gram` pays per task now
/// that substrates are built per block from a
/// [`crate::data::colstore::ColumnSource`]. The bit-pack itself is
/// excluded from every candidate equally: sources hand blocks out
/// already packed (memcpy or disk read), so it is not a
/// substrate-differentiating cost.
fn gram_secs(probe: &BinaryDataset, kind: NativeKind) -> f64 {
    let bits = probe.to_bitmatrix();
    match kind {
        NativeKind::Bitpack => best_of(|| {
            std::hint::black_box(bits.gram());
        }),
        NativeKind::Dense => best_of(|| {
            std::hint::black_box(crate::linalg::blas::gram(&bits.to_mat32()));
        }),
        NativeKind::Sparse => best_of(|| {
            std::hint::black_box(crate::linalg::csr::CsrMatrix::from_bitmatrix(&bits).gram());
        }),
    }
}

fn best_of(mut f: impl FnMut()) -> f64 {
    f(); // warmup
    let mut best = f64::INFINITY;
    for _ in 0..PROBE_REPS {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn probe_block_is_bounded_and_deterministic() {
        let ds = SynthSpec::new(20_000, 300).sparsity(0.9).seed(3).generate();
        let a = probe_block(&ds).unwrap();
        let b = probe_block(&ds).unwrap();
        assert_eq!(a.n_rows(), PROBE_MAX_ROWS);
        assert_eq!(a.n_cols(), PROBE_MAX_COLS);
        assert_eq!(a.bytes(), b.bytes(), "probe sampling must be deterministic");
    }

    #[test]
    fn small_datasets_probe_whole() {
        let ds = SynthSpec::new(50, 7).sparsity(0.5).seed(1).generate();
        let p = probe_block(&ds).unwrap();
        assert_eq!((p.n_rows(), p.n_cols()), (50, 7));
    }

    #[test]
    fn report_chooses_the_fastest_candidate() {
        let ds = SynthSpec::new(2000, 40).sparsity(0.9).seed(5).generate();
        let report = autotune(&ds).unwrap();
        assert!(eligible().contains(&report.chosen));
        let best = report
            .candidates
            .iter()
            .map(|c| c.throughput)
            .fold(f64::NEG_INFINITY, f64::max);
        let chosen = report
            .candidates
            .iter()
            .find(|c| c.backend == report.chosen)
            .unwrap();
        assert_eq!(chosen.throughput, best, "{}", report.summary());
        assert!((0.0..=1.0).contains(&report.density));
        assert!(!report.summary().is_empty());
    }

    #[test]
    fn empty_dataset_rejected() {
        let ds = BinaryDataset::new(0, 0, vec![]).unwrap();
        assert!(autotune(&ds).is_err());
        assert!(autotune_uncached(&ds).is_err());
        assert!(autotune_source(&crate::data::colstore::InMemorySource::new(&ds)).is_err());
    }

    #[test]
    fn source_probe_matches_in_memory_probe() {
        use crate::data::colstore::InMemorySource;
        // narrow case: the whole width is the probe
        let ds = SynthSpec::new(1733, 29).sparsity(0.75).seed(23).generate();
        let a = probe_block(&ds).unwrap();
        let b = probe_block_source(&InMemorySource::new(&ds)).unwrap();
        assert_eq!(a.bytes(), b.bytes(), "probe gathers must be byte-identical");
        // wide case: strided column sample
        let wide = SynthSpec::new(900, 150).sparsity(0.6).seed(24).generate();
        let aw = probe_block(&wide).unwrap();
        let bw = probe_block_source(&InMemorySource::new(&wide)).unwrap();
        assert_eq!(aw.bytes(), bw.bytes());
        // ...so the probe cache is shared across the two gather paths
        // (unique shape: no other test probes 1733x29)
        let first = autotune(&ds).unwrap();
        let second = autotune_source(&InMemorySource::new(&ds)).unwrap();
        assert!(second.cached, "source probe must hit the in-memory probe's cache entry");
        assert_eq!(second.chosen, first.chosen);
    }

    #[test]
    fn density_buckets_are_disjoint_and_monotone_regimes() {
        // fine range (< 5% ones) never collides with the coarse range
        let fine_max = density_bucket(0.0499999);
        let coarse_min = density_bucket(0.05);
        assert!(fine_max < coarse_min, "{fine_max} vs {coarse_min}");
        // neighbours in different regimes land in different buckets
        assert_ne!(density_bucket(0.001), density_bucket(0.002));
        assert_ne!(density_bucket(0.1), density_bucket(0.5));
        // same regime, same bucket
        assert_eq!(density_bucket(0.50), density_bucket(0.51));
        // clamped at the extremes
        assert_eq!(density_bucket(-1.0), density_bucket(0.0));
        assert_eq!(density_bucket(2.0), density_bucket(1.0));
    }

    #[test]
    fn probe_cache_hits_on_matching_shape_and_density() {
        // unique shape so parallel tests cannot collide on the key
        let ds = SynthSpec::new(1501, 37).sparsity(0.7).seed(101).generate();
        clear_probe_cache();
        let first = autotune(&ds).unwrap();
        assert!(!first.cached, "first probe must be fresh");
        let second = autotune(&ds).unwrap();
        assert!(second.cached, "second probe must hit the cache");
        assert_eq!(second.chosen, first.chosen);
        // bit-identical stored timings prove nothing was re-timed
        for (a, b) in first.candidates.iter().zip(&second.candidates) {
            assert_eq!(a.backend, b.backend);
            assert_eq!(a.secs, b.secs);
            assert_eq!(a.throughput, b.throughput);
        }
        assert!(second.summary().contains("[cached]"));
        // a different shape misses
        let other = SynthSpec::new(1502, 37).sparsity(0.7).seed(101).generate();
        assert!(!autotune(&other).unwrap().cached);
        // uncached always re-times and never populates from the hit path
        assert!(!autotune_uncached(&ds).unwrap().cached);
    }

    #[test]
    fn probe_records_combine_timing_per_measure() {
        let ds = SynthSpec::new(1200, 24).sparsity(0.7).seed(17).generate();
        let report = autotune_uncached(&ds).unwrap();
        assert_eq!(report.combine.len(), CombineKind::ALL.len());
        for (m, c) in CombineKind::ALL.iter().zip(&report.combine) {
            assert_eq!(c.measure, *m, "ALL order preserved");
            assert!(c.secs > 0.0, "{m}: non-positive combine time");
            assert!(c.cells_per_sec > 0.0, "{m}");
            assert_eq!(report.combine_secs(*m), Some(c.secs));
            assert_eq!(report.combine_throughput(*m), Some(c.cells_per_sec));
        }
    }

    fn tmp_cache_dir(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("bulkmi-probecache-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn hardware_fingerprint_is_stable_and_structured() {
        let a = hardware_fingerprint();
        let b = hardware_fingerprint();
        assert_eq!(a, b, "fingerprint must be deterministic within a process");
        assert!(a.contains("|kernel:"), "{a}");
        assert!(a.contains("|flags:"), "{a}");
        assert!(a.contains(std::env::consts::ARCH), "{a}");
        assert!(!a.contains('\n'));
    }

    #[test]
    fn probe_cache_round_trips_through_disk_exactly() {
        let dir = tmp_cache_dir("roundtrip");
        let ds = SynthSpec::new(800, 16).sparsity(0.6).seed(41).generate();
        let report = autotune_uncached(&ds).unwrap();
        let key = ProbeKey {
            n_rows: ds.n_rows(),
            n_cols: ds.n_cols(),
            density_bucket: density_bucket(report.density),
        };
        let mut entries = HashMap::new();
        entries.insert(key, report.clone());
        save_probe_cache(&dir, &entries);
        let loaded = load_probe_cache(&dir).expect("matching fingerprint must load");
        let got = loaded.get(&key).expect("saved entry present");
        assert_eq!(got.chosen, report.chosen);
        assert_eq!(got.density, report.density, "f64 Display must round-trip exactly");
        assert_eq!(got.probe_rows, report.probe_rows);
        assert_eq!(got.probe_cols, report.probe_cols);
        assert!(!got.cached, "loaded entries start uncached");
        assert_eq!(got.candidates.len(), report.candidates.len());
        for (a, b) in report.candidates.iter().zip(&got.candidates) {
            assert_eq!(a.backend, b.backend);
            assert_eq!(a.secs, b.secs);
            assert_eq!(a.throughput, b.throughput);
        }
        assert_eq!(got.combine.len(), CombineKind::ALL.len());
        for (a, b) in report.combine.iter().zip(&got.combine) {
            assert_eq!(a.measure, b.measure);
            assert_eq!(a.secs, b.secs);
            assert_eq!(a.cells_per_sec, b.cells_per_sec);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fingerprint_mismatch_invalidates_disk_cache() {
        let dir = tmp_cache_dir("fpr-mismatch");
        save_probe_cache(&dir, &HashMap::new());
        assert!(load_probe_cache(&dir).is_some(), "fresh save must load");
        std::fs::write(dir.join("hardware.fpr"), "some-other-machine\n").unwrap();
        assert!(
            load_probe_cache(&dir).is_none(),
            "a foreign fingerprint must invalidate every entry"
        );
        // the next save restores the real fingerprint
        save_probe_cache(&dir, &HashMap::new());
        assert!(load_probe_cache(&dir).is_some());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_or_missing_disk_cache_never_panics() {
        let dir = tmp_cache_dir("corrupt");
        // no directory at all
        assert!(load_probe_cache(&dir).is_none());
        save_probe_cache(&dir, &HashMap::new());
        // garbage body
        std::fs::write(dir.join("probe-cache.v1"), "not a cache file\n").unwrap();
        assert!(load_probe_cache(&dir).is_none(), "garbage must be ignored");
        // right magic, torn entry (no "end")
        std::fs::write(
            dir.join("probe-cache.v1"),
            "bulkmi-probe-cache,v1\nstamp,0\nentry,10,10,5,bulk-bitpack,0.5,10,10\n",
        )
        .unwrap();
        assert!(load_probe_cache(&dir).is_none(), "torn entries must be ignored");
        // bad backend name inside an otherwise well-formed entry
        std::fs::write(
            dir.join("probe-cache.v1"),
            "bulkmi-probe-cache,v1\nstamp,0\nentry,10,10,5,no-such-backend,0.5,10,10\nend\n",
        )
        .unwrap();
        assert!(load_probe_cache(&dir).is_none());
        // fingerprint present but cache file absent: valid empty cache
        std::fs::remove_file(dir.join("probe-cache.v1")).unwrap();
        let empty = load_probe_cache(&dir).expect("fingerprint alone is a valid empty cache");
        assert!(empty.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn disk_cache_merges_entries_across_saves() {
        let dir = tmp_cache_dir("merge");
        let ds = SynthSpec::new(700, 12).sparsity(0.5).seed(42).generate();
        let report = autotune_uncached(&ds).unwrap();
        let k1 = ProbeKey { n_rows: 700, n_cols: 12, density_bucket: density_bucket(0.5) };
        let k2 = ProbeKey { n_rows: 900, n_cols: 31, density_bucket: density_bucket(0.1) };
        let mut first = HashMap::new();
        first.insert(k1, report.clone());
        save_probe_cache(&dir, &first);
        // a second process would load, add its entry, and save the union
        let mut merged = load_probe_cache(&dir).unwrap();
        merged.insert(k2, report.clone());
        save_probe_cache(&dir, &merged);
        let last = load_probe_cache(&dir).unwrap();
        assert_eq!(last.len(), 2);
        assert!(last.contains_key(&k1) && last.contains_key(&k2));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn chosen_throughput_matches_winner() {
        let ds = SynthSpec::new(900, 20).sparsity(0.6).seed(8).generate();
        let report = autotune_uncached(&ds).unwrap();
        let want = report
            .candidates
            .iter()
            .find(|c| c.backend == report.chosen)
            .unwrap()
            .throughput;
        assert_eq!(report.chosen_throughput(), want);
        assert!(report.chosen_throughput() > 0.0);
    }
}
