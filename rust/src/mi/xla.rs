//! The XLA/PJRT backend (the paper's "Opt-T" optimized-framework row):
//! Section-3 bulk MI executed through the AOT artifacts compiled from
//! the Layer-2 JAX graphs (and, in `Impl::Pallas` mode, through the
//! Layer-1 Pallas kernels).
//!
//! Serving strategy for an arbitrary (n, m):
//!
//! 1. **Fused**: if some `mi_{R}x{C}` bucket fits, zero-pad and run it
//!    (exact: the true n is an input, see DESIGN.md §2).
//! 2. **Row-chunked**: if n exceeds every bucket, stream row chunks
//!    through the largest fitting `gram` bucket, accumulate
//!    `(G11, colsums)` in f64, then combine — through the `combine`
//!    artifact when one fits, natively otherwise.
//! 3. **Column-blocked**: if m exceeds every gram bucket, delegate to
//!    the coordinator's blockwise plan (`crate::coordinator`), which
//!    handles arbitrary shapes over the `xgram` artifacts.

use super::bulk_opt::combine;
use super::MiMatrix;
use crate::data::dataset::BinaryDataset;
use crate::linalg::dense::Mat64;
use crate::runtime::{ArtifactKind, Impl, XlaRuntime};
use crate::util::error::{Error, Result};

/// XLA-backed MI computation.
pub struct XlaMi {
    runtime: XlaRuntime,
    impl_: Impl,
}

impl XlaMi {
    pub fn new(runtime: XlaRuntime, impl_: Impl) -> Self {
        XlaMi { runtime, impl_ }
    }

    /// Construct over the default artifact directory, XLA-native dots.
    pub fn load_default() -> Result<Self> {
        Ok(XlaMi::new(XlaRuntime::load_default()?, Impl::Xla))
    }

    /// Construct with the interpret-mode Pallas artifacts.
    pub fn load_default_pallas() -> Result<Self> {
        Ok(XlaMi::new(XlaRuntime::load_default()?, Impl::Pallas))
    }

    pub fn runtime(&self) -> &XlaRuntime {
        &self.runtime
    }

    /// Compute the full MI matrix for a dataset.
    pub fn compute(&self, ds: &BinaryDataset) -> Result<MiMatrix> {
        let (n, m) = (ds.n_rows(), ds.n_cols());
        let d: Vec<f32> = ds.bytes().iter().map(|&b| b as f32).collect();

        // 1. fused bucket
        if self.runtime.registry().find_bucket(ArtifactKind::Mi, self.impl_, n, m).is_some() {
            let flat = self.runtime.run_mi_fused(self.impl_, &d, n, m)?;
            return Ok(MiMatrix::from_mat(Mat64::from_vec(m, m, flat)?));
        }

        // 2. row-chunked through gram buckets
        let chunk_rows = self
            .runtime
            .registry()
            .max_rows_for_cols(ArtifactKind::Gram, self.impl_, m)
            .ok_or_else(|| {
                Error::NoArtifact(format!(
                    "no gram bucket with >= {m} cols; use the coordinator's \
                     column-blocked plan for this width"
                ))
            })?;
        let (g11, colsums) = self.gram_chunked(&d, n, m, chunk_rows)?;
        self.combine_counts(&g11, &colsums, &colsums, n as f64, m)
    }

    /// Accumulate (G11, colsums) over row chunks of size `chunk_rows`.
    fn gram_chunked(
        &self,
        d: &[f32],
        n: usize,
        m: usize,
        chunk_rows: usize,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let mut g_acc = vec![0.0f64; m * m];
        let mut c_acc = vec![0.0f64; m];
        let mut start = 0usize;
        while start < n {
            let len = chunk_rows.min(n - start);
            let chunk = &d[start * m..(start + len) * m];
            let (g, c) = self.runtime.run_gram(self.impl_, chunk, len, m)?;
            for (acc, v) in g_acc.iter_mut().zip(&g) {
                *acc += v;
            }
            for (acc, v) in c_acc.iter_mut().zip(&c) {
                *acc += v;
            }
            start += len;
        }
        Ok((g_acc, c_acc))
    }

    /// Combine counts into MI — through the artifact if a bucket fits,
    /// natively otherwise (identical math, see `mi::bulk_opt::combine`).
    fn combine_counts(
        &self,
        g11: &[f64],
        ca: &[f64],
        cb: &[f64],
        n: f64,
        m: usize,
    ) -> Result<MiMatrix> {
        let flat = if self
            .runtime
            .registry()
            .find_bucket(ArtifactKind::Combine, self.impl_, 0, m)
            .is_some()
        {
            self.runtime.run_combine(self.impl_, g11, ca, cb, n, m)?
        } else {
            let g = Mat64::from_vec(m, m, g11.to_vec())?;
            return Ok(MiMatrix::from_mat(combine(&g, ca, cb, n)));
        };
        Ok(MiMatrix::from_mat(Mat64::from_vec(m, m, flat)?))
    }
}
