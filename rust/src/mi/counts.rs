//! Scalar core: mutual information (bits) of a 2x2 contingency table.
//!
//! Convention (shared with `python/compile/kernels/ref.py`): a zero
//! joint count contributes exactly 0 — `0 * log(0/e) := 0` — so results
//! are exact, with no epsilon bias. `log2` identities:
//! `MI = Σ p_xy * (log2 n_xy + log2 n - log2 n_x - log2 n_y)` evaluated
//! in f64 from integer counts.
//!
//! Both entry points delegate to the single decomposed MI expression in
//! [`crate::mi::combine_kernels`] — the same cell body the table-driven
//! block kernels run — so the scalar, blockwise and streamed paths all
//! produce identical bits. The summation tree
//! `(t11 + t00) + (t10 + t01)` with commutative `(log2 n_x + log2 n_y)`
//! pairing is bitwise invariant under the `(i, j) -> (j, i)` swap
//! (which exchanges `n10 <-> n01`): IEEE addition/multiplication are
//! commutative, so MI(i,j) is bit-identical to MI(j,i) — the
//! coordinator's mirror-write relies on this for blockwise ==
//! monolithic exactness.

/// MI (bits) from the four joint counts and the total `n = Σ n_xy`.
///
/// `n11` counts rows where both are 1, `n10` X=1,Y=0, etc. Counts below
/// 2^53 are exact in f64, so the cast loses nothing for any realistic
/// dataset.
#[inline]
pub fn mi_from_counts_u64(n11: u64, n10: u64, n01: u64, n00: u64, n: u64) -> f64 {
    debug_assert_eq!(n11 + n10 + n01 + n00, n);
    super::combine_kernels::mi_cell_direct(
        n11 as f64,
        n10 as f64,
        n01 as f64,
        n00 as f64,
        n as f64,
    )
}

/// MI (bits) from *real-valued* counts (used when counts arrive as f32/f64
/// sums from a Gram matrix; values are integral up to float rounding).
#[inline]
pub fn mi_from_counts_f64(n11: f64, n10: f64, n01: f64, n00: f64, n: f64) -> f64 {
    super::combine_kernels::mi_cell_direct(n11, n10, n01, n00, n)
}

/// Binary entropy H(p) in bits.
#[inline]
pub fn entropy_bits(p: f64) -> f64 {
    if p <= 0.0 || p >= 1.0 {
        return 0.0;
    }
    -p * p.log2() - (1.0 - p) * (1.0 - p).log2()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_n_is_zero() {
        assert_eq!(mi_from_counts_u64(0, 0, 0, 0, 0), 0.0);
    }

    #[test]
    fn identical_variables_give_entropy() {
        // X == Y with 3 ones of 8: n11=3, n00=5
        let mi = mi_from_counts_u64(3, 0, 0, 5, 8);
        assert!((mi - entropy_bits(3.0 / 8.0)).abs() < 1e-12);
    }

    #[test]
    fn complementary_variables_give_entropy() {
        let mi = mi_from_counts_u64(0, 3, 5, 0, 8);
        assert!((mi - entropy_bits(3.0 / 8.0)).abs() < 1e-12);
    }

    #[test]
    fn exact_independence_is_zero() {
        // The decomposed form (log2 nxy + log2 n) - (log2 nx + log2 ny)
        // no longer cancels to exactly 0.0 at independence the way
        // log2(nxy*n/(nx*ny)) = log2(1) did, so the bound is ~1e-15 per
        // term rather than exact — still far inside the 1e-12 oracle
        // tolerance every measure is gated on.
        // balanced 2x2: all four cells equal
        assert!(mi_from_counts_u64(2, 2, 2, 2, 8).abs() < 1e-12);
        // unbalanced but independent: p(x)=1/2, p(y)=1/4
        assert!(mi_from_counts_u64(1, 3, 1, 3, 8).abs() < 1e-12);
    }

    #[test]
    fn constant_variable_is_zero() {
        assert_eq!(mi_from_counts_u64(0, 0, 4, 4, 8), 0.0); // X always 0
        assert_eq!(mi_from_counts_u64(4, 4, 0, 0, 8), 0.0); // X always 1
    }

    #[test]
    fn perfect_one_bit() {
        // X == Y, both balanced: MI = 1 bit
        assert!((mi_from_counts_u64(4, 0, 0, 4, 8) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn f64_matches_u64() {
        for &(a, b, c, d) in &[(3u64, 2u64, 1u64, 4u64), (0, 5, 5, 0), (7, 0, 1, 2)] {
            let n = a + b + c + d;
            let exact = mi_from_counts_u64(a, b, c, d, n);
            let float =
                mi_from_counts_f64(a as f64, b as f64, c as f64, d as f64, n as f64);
            assert!((exact - float).abs() < 1e-12);
        }
    }

    #[test]
    fn nonnegative_exhaustive_small() {
        // exhaustive over all 2x2 tables with n <= 12
        for n in 1u64..=12 {
            for n11 in 0..=n {
                for n10 in 0..=(n - n11) {
                    for n01 in 0..=(n - n11 - n10) {
                        let n00 = n - n11 - n10 - n01;
                        let mi = mi_from_counts_u64(n11, n10, n01, n00, n);
                        assert!(mi > -1e-12, "negative MI for {n11},{n10},{n01},{n00}");
                        // bounded by min marginal entropy
                        let hx = entropy_bits((n11 + n10) as f64 / n as f64);
                        let hy = entropy_bits((n11 + n01) as f64 / n as f64);
                        assert!(mi <= hx.min(hy) + 1e-9);
                    }
                }
            }
        }
    }

    #[test]
    fn entropy_edges() {
        assert_eq!(entropy_bits(0.0), 0.0);
        assert_eq!(entropy_bits(1.0), 0.0);
        assert!((entropy_bits(0.5) - 1.0).abs() < 1e-15);
    }
}
