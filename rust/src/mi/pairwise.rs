//! The sequential pairwise baseline (the paper's "SKL Pairwise" row):
//! for every column pair, scan all n rows building the 2x2 contingency,
//! then apply the scalar MI core. O(m² n) with the full per-pair pass —
//! exactly the cost model of a scikit-learn `mutual_info_score` loop.
//!
//! This is the comparator every bulk backend is validated against and
//! the denominator of the paper's headline speedup.

use super::counts::mi_from_counts_u64;
use super::MiMatrix;
use crate::data::dataset::BinaryDataset;
use crate::linalg::dense::Mat64;

/// Compute the full m x m MI matrix pair by pair.
pub fn mi_pairwise(ds: &BinaryDataset) -> MiMatrix {
    let (n, m) = (ds.n_rows(), ds.n_cols());
    let mut out = Mat64::zeros(m, m);
    for i in 0..m {
        for j in i..m {
            let mi = mi_pair(ds, i, j, n);
            out.set(i, j, mi);
            out.set(j, i, mi);
        }
    }
    MiMatrix::from_mat(out)
}

/// 2x2 contingency counts `(n11, n10, n01, n00)` of one column pair
/// via a full row scan — the shared per-pair inner loop of this module
/// and [`crate::mi::measure::measure_pairwise`].
pub fn pair_counts(ds: &BinaryDataset, i: usize, j: usize) -> (u64, u64, u64, u64) {
    let n = ds.n_rows();
    let mut n11 = 0u64;
    let mut n10 = 0u64;
    let mut n01 = 0u64;
    for r in 0..n {
        let row = ds.row(r);
        match (row[i], row[j]) {
            (1, 1) => n11 += 1,
            (1, 0) => n10 += 1,
            (0, 1) => n01 += 1,
            _ => {}
        }
    }
    (n11, n10, n01, n as u64 - n11 - n10 - n01)
}

/// MI between two columns via a row scan (the per-pair inner loop).
fn mi_pair(ds: &BinaryDataset, i: usize, j: usize, n: usize) -> f64 {
    let (n11, n10, n01, n00) = pair_counts(ds, i, j);
    mi_from_counts_u64(n11, n10, n01, n00, n as u64)
}

/// MI between two explicit binary vectors (public convenience).
pub fn mi_between(x: &[u8], y: &[u8]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut n11 = 0u64;
    let mut n10 = 0u64;
    let mut n01 = 0u64;
    for (&a, &b) in x.iter().zip(y) {
        match (a, b) {
            (1, 1) => n11 += 1,
            (1, 0) => n10 += 1,
            (0, 1) => n01 += 1,
            _ => {}
        }
    }
    let n = x.len() as u64;
    mi_from_counts_u64(n11, n10, n01, n - n11 - n10 - n01, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::mi::counts::entropy_bits;

    #[test]
    fn diag_is_entropy() {
        let ds = SynthSpec::new(500, 8).sparsity(0.7).seed(1).generate();
        let mi = mi_pairwise(&ds);
        for c in 0..8 {
            let p = ds.col_counts()[c] as f64 / 500.0;
            assert!((mi.get(c, c) - entropy_bits(p)).abs() < 1e-12);
        }
    }

    #[test]
    fn symmetric_nonnegative() {
        let ds = SynthSpec::new(300, 12).sparsity(0.5).seed(2).generate();
        let mi = mi_pairwise(&ds);
        assert_eq!(mi.max_asymmetry(), 0.0);
        assert!(mi.min_value() > -1e-12);
    }

    #[test]
    fn planted_copy_has_full_entropy_mi() {
        let ds = SynthSpec::new(2000, 4).sparsity(0.6).seed(3).plant(0, 3, 0.0).generate();
        let mi = mi_pairwise(&ds);
        let h = mi.get(0, 0);
        assert!((mi.get(0, 3) - h).abs() < 1e-12, "copy pair should reach H(X)");
    }

    #[test]
    fn independent_columns_near_zero() {
        let ds = SynthSpec::new(50_000, 3).sparsity(0.5).seed(4).generate();
        let mi = mi_pairwise(&ds);
        assert!(mi.get(0, 1) < 1e-3);
        assert!(mi.get(1, 2) < 1e-3);
    }

    #[test]
    fn mi_between_matches_matrix() {
        let ds = SynthSpec::new(128, 5).sparsity(0.4).seed(5).generate();
        let mi = mi_pairwise(&ds);
        let x: Vec<u8> = (0..128).map(|r| ds.get(r, 1)).collect();
        let y: Vec<u8> = (0..128).map(|r| ds.get(r, 4)).collect();
        assert!((mi_between(&x, &y) - mi.get(1, 4)).abs() < 1e-15);
    }
}
