//! Statistical post-processing of MI estimates: small-sample bias
//! correction, permutation significance, and the asymptotic
//! p-value ↔ MI conversion behind the `pvalue:P` sink — what
//! downstream feature selection (paper refs [12], [17]) needs before
//! trusting a raw MI value from finite data.
//!
//! # The G-test χ²₁ derivation behind `--sink pvalue:P`
//!
//! For two binary variables observed over `n` rows, the log-likelihood
//! ratio (G-test) statistic against the independence null is
//!
//! ```text
//! G = 2 Σ_{x,y ∈ {0,1}} n_xy · ln( n_xy / e_xy )
//! ```
//!
//! where `n_xy` are the 2x2 contingency counts and
//! `e_xy = n_x· n_·y / n` the counts expected under independence. That
//! sum is exactly `2 n` times the plug-in mutual information *in nats*;
//! this crate reports MI in bits, so
//!
//! ```text
//! G = 2 · n · ln(2) · MI_bits
//! ```
//!
//! By Wilks' theorem, `G` is asymptotically chi-square distributed
//! under the null with degrees of freedom
//! `(|X| - 1)(|Y| - 1) = 1` for binary variables. The p-value of an
//! observed MI is therefore the χ²₁ survival function at `G`
//! ([`mi_pvalue_asymptotic`], using
//! `P(χ²₁ ≥ x) = erfc(√(x/2))`), and inverting the (monotone) survival
//! turns a p-value cutoff into an MI threshold
//! ([`mi_threshold_for_pvalue`]) — which is what lets
//! [`crate::mi::sink::ThresholdSink::by_pvalue`] screen all pairs in
//! one streaming pass with zero per-pair permutation tests.
//!
//! **Validity regime** (the Mori–Kawamura asymptotics,
//! arXiv:2308.14735): Wilks' theorem is an `n → ∞` statement taken at
//! *fixed* distribution, so the χ²₁ tail is trustworthy when every
//! expected cell count `e_xy` is large (the usual rule of thumb:
//! ≥ ~5). For very sparse columns (marginal probability ~`1/n`) or
//! p-values so extreme that `G` sits far in the tail, the χ²
//! approximation degrades and the conversion is conservative at best —
//! confirm borderline survivors with [`permutation_test`], which is
//! exact under the permutation null at any `n`. Conversely, at large
//! `n` the threshold shrinks like `1/n` (fixed evidence quantile), so
//! significance does **not** imply effect size: an MI passing
//! `pvalue:0.01` at `n = 10^6` can be far too small to matter for
//! feature selection.
//!
//! Converting a screening p-value into an MI cutoff:
//!
//! ```
//! use bulkmi::mi::significance::{mi_pvalue_asymptotic, mi_threshold_for_pvalue};
//!
//! // P = 0.01 over n = 10_000 rows -> the smallest MI (bits) that is
//! // significant at the 1% level...
//! let threshold = mi_threshold_for_pvalue(0.01, 10_000).unwrap();
//!
//! // ...which is exactly the chi-square 1% critical value 6.635
//! // mapped back through G = 2 n ln(2) MI:
//! let g = 2.0 * 10_000.0 * std::f64::consts::LN_2 * threshold;
//! assert!((g - 6.635).abs() < 0.01);
//!
//! // and the forward conversion round-trips the p-value
//! let p = mi_pvalue_asymptotic(threshold, 10_000);
//! assert!((p - 0.01).abs() < 1e-3);
//! ```

use super::counts::mi_from_counts_u64;
use super::MiMatrix;
use crate::data::dataset::BinaryDataset;
use crate::linalg::dense::Mat64;
use crate::util::error::Error;
use crate::util::rng::Rng;

/// Miller–Madow bias-corrected MI matrix.
///
/// The plug-in MI estimator is biased upward by ≈ (K_xy - K_x - K_y + 1)
/// / (2 n ln 2) bits where K are the numbers of non-empty cells of the
/// joint/marginal distributions. For binary variables K ≤ 4/2/2, so the
/// correction is at most 1/(2 n ln2); constant columns contribute 0.
pub fn miller_madow(ds: &BinaryDataset, mi: &MiMatrix) -> MiMatrix {
    let n = ds.n_rows() as f64;
    let m = mi.dim();
    let counts = ds.col_counts();
    let bits = ds.to_bitmatrix();
    let mut out = Mat64::zeros(m, m);
    for i in 0..m {
        for j in 0..m {
            let n11 = bits.and_count(i, j);
            let ci = counts[i];
            let cj = counts[j];
            let n10 = ci - n11;
            let n01 = cj - n11;
            let n00 = ds.n_rows() as u64 - n11 - n10 - n01;
            let k_xy = [n11, n10, n01, n00].iter().filter(|&&c| c > 0).count() as f64;
            let k_x = [ci, ds.n_rows() as u64 - ci].iter().filter(|&&c| c > 0).count() as f64;
            let k_y = [cj, ds.n_rows() as u64 - cj].iter().filter(|&&c| c > 0).count() as f64;
            let correction = (k_xy - k_x - k_y + 1.0) / (2.0 * n * std::f64::consts::LN_2);
            out.set(i, j, (mi.get(i, j) - correction).max(0.0));
        }
    }
    MiMatrix::from_mat(out)
}

/// Permutation significance for one pair: p-value of observing MI(x, y)
/// at least as large under independence (shuffling y breaks any
/// dependency while preserving both marginals).
///
/// Returns (observed_mi, p_value) with the standard +1 correction.
pub fn permutation_test(
    ds: &BinaryDataset,
    x: usize,
    y: usize,
    permutations: usize,
    seed: u64,
) -> (f64, f64) {
    let n = ds.n_rows();
    let xv: Vec<u8> = (0..n).map(|r| ds.get(r, x)).collect();
    let mut yv: Vec<u8> = (0..n).map(|r| ds.get(r, y)).collect();
    let observed = pair_mi(&xv, &yv);
    let mut rng = Rng::new(seed);
    let mut exceed = 0usize;
    for _ in 0..permutations {
        rng.shuffle(&mut yv);
        if pair_mi(&xv, &yv) >= observed {
            exceed += 1;
        }
    }
    let p = (exceed + 1) as f64 / (permutations + 1) as f64;
    (observed, p)
}

/// p-values for the top-k strongest pairs of a computed MI matrix.
pub fn top_pairs_significance(
    ds: &BinaryDataset,
    mi: &MiMatrix,
    k: usize,
    permutations: usize,
    seed: u64,
) -> Vec<(usize, usize, f64, f64)> {
    super::topk::top_k_pairs(mi, k)
        .into_iter()
        .enumerate()
        .map(|(idx, p)| {
            let (obs, pval) =
                permutation_test(ds, p.i, p.j, permutations, seed ^ (idx as u64) << 17);
            (p.i, p.j, obs, pval)
        })
        .collect()
}

/// Complementary error function (Abramowitz & Stegun 7.1.26 rational
/// approximation; |error| <= 1.5e-7 — ample for screening cutoffs).
fn erfc(x: f64) -> f64 {
    if x < 0.0 {
        return 2.0 - erfc(-x);
    }
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    poly * (-x * x).exp()
}

/// Survival function of the chi-square distribution with 1 degree of
/// freedom: `P(X >= x) = erfc(sqrt(x / 2))`.
pub fn chi2_sf_1df(x: f64) -> f64 {
    if x <= 0.0 {
        1.0
    } else {
        erfc((x / 2.0).sqrt())
    }
}

/// Asymptotic independence p-value for an observed MI (bits) over
/// `n_rows` observations: under H0, the G statistic
/// `2 n ln(2) MI_bits` is chi-square with 1 dof for binary variables
/// (the standard G-test / MI asymptotics behind p-value screening).
pub fn mi_pvalue_asymptotic(mi_bits: f64, n_rows: usize) -> f64 {
    chi2_sf_1df(2.0 * n_rows as f64 * std::f64::consts::LN_2 * mi_bits)
}

/// Smallest MI (bits) whose asymptotic p-value is `<= pvalue` for
/// `n_rows` observations — the conversion [`crate::mi::sink::ThresholdSink`]
/// uses so `--sink pvalue:P` can screen pairs without per-pair
/// permutation tests.
pub fn mi_threshold_for_pvalue(pvalue: f64, n_rows: usize) -> Result<f64, Error> {
    if n_rows == 0 {
        return Err(Error::Shape("p-value threshold needs n_rows >= 1".into()));
    }
    Ok(gstat_threshold_for_pvalue(pvalue)? / (2.0 * n_rows as f64 * std::f64::consts::LN_2))
}

/// The χ²₁ critical value at `pvalue` — the smallest G statistic whose
/// asymptotic independence p-value is `<= pvalue`. This is the cutoff
/// `--sink pvalue:P` applies directly when the run's combine measure is
/// [`crate::mi::measure::CombineKind::GStat`] (G needs no `n` scaling:
/// the statistic already carries it); the MI-bits conversion
/// [`mi_threshold_for_pvalue`] divides it by `2 n ln 2`.
pub fn gstat_threshold_for_pvalue(pvalue: f64) -> Result<f64, Error> {
    if !(pvalue > 0.0 && pvalue < 1.0) {
        return Err(Error::Parse(format!("p-value cutoff {pvalue} not in (0, 1)")));
    }
    // invert the (monotone decreasing) chi-square survival by bisection
    let mut hi = 1.0f64;
    while chi2_sf_1df(hi) > pvalue {
        hi *= 2.0;
        if hi > 1e9 {
            break;
        }
    }
    let mut lo = 0.0f64;
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if chi2_sf_1df(mid) > pvalue {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Ok(hi)
}

fn pair_mi(x: &[u8], y: &[u8]) -> f64 {
    let mut n11 = 0u64;
    let mut n10 = 0u64;
    let mut n01 = 0u64;
    for (&a, &b) in x.iter().zip(y) {
        match (a, b) {
            (1, 1) => n11 += 1,
            (1, 0) => n10 += 1,
            (0, 1) => n01 += 1,
            _ => {}
        }
    }
    let n = x.len() as u64;
    mi_from_counts_u64(n11, n10, n01, n - n11 - n10 - n01, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::mi::backend::{compute_mi, Backend};

    fn planted() -> BinaryDataset {
        SynthSpec::new(600, 8).sparsity(0.6).seed(1).plant(0, 1, 0.05).generate()
    }

    #[test]
    fn miller_madow_bounded_and_preserves_signal() {
        let ds = planted();
        let raw = compute_mi(&ds, Backend::BulkBitpack).unwrap();
        let corrected = miller_madow(&ds, &raw);
        let max_corr = 1.0 / (600.0 * std::f64::consts::LN_2); // |K terms| <= 2
        for i in 0..8 {
            for j in 0..8 {
                assert!(corrected.get(i, j) >= 0.0);
                assert!(
                    (corrected.get(i, j) - raw.get(i, j)).abs() <= max_corr + 1e-12,
                    "({i},{j}) correction too large"
                );
            }
        }
        // a generic pair (all four joint cells occupied) shrinks...
        assert!(corrected.get(2, 3) <= raw.get(2, 3) + 1e-15);
        // ...and the strong planted pair survives the correction
        assert!(corrected.get(0, 1) > 0.5);
    }

    #[test]
    fn permutation_detects_dependence() {
        let ds = planted();
        let (obs, p) = permutation_test(&ds, 0, 1, 200, 42);
        assert!(obs > 0.5);
        assert!(p <= 1.0 / 100.0, "planted pair p = {p}");
    }

    #[test]
    fn permutation_accepts_independence() {
        let ds = SynthSpec::new(500, 4).sparsity(0.5).seed(9).generate();
        let (_, p) = permutation_test(&ds, 0, 1, 200, 7);
        assert!(p > 0.05, "independent pair p = {p}");
    }

    #[test]
    fn top_pairs_significance_ranks_planted_first() {
        let ds = planted();
        let mi = compute_mi(&ds, Backend::BulkBitpack).unwrap();
        let sig = top_pairs_significance(&ds, &mi, 3, 100, 3);
        assert_eq!(sig.len(), 3);
        assert_eq!((sig[0].0, sig[0].1), (0, 1));
        assert!(sig[0].3 < 0.05);
    }

    #[test]
    fn chi2_survival_matches_known_quantiles() {
        assert_eq!(chi2_sf_1df(0.0), 1.0);
        // classical 1-dof critical values
        assert!((chi2_sf_1df(3.841) - 0.05).abs() < 2e-3);
        assert!((chi2_sf_1df(6.635) - 0.01).abs() < 1e-3);
        // monotone decreasing
        assert!(chi2_sf_1df(1.0) > chi2_sf_1df(2.0));
    }

    #[test]
    fn gstat_threshold_is_the_chi2_critical_value() {
        // the documented P = 0.01 example: chi²₁ critical value 6.635
        let g = gstat_threshold_for_pvalue(0.01).unwrap();
        assert!((g - 6.635).abs() < 0.01, "g = {g}");
        // the MI conversion is exactly the G cutoff rescaled by 2 n ln2
        let t = mi_threshold_for_pvalue(0.01, 10_000).unwrap();
        assert!((t * 2.0 * 10_000.0 * std::f64::consts::LN_2 - g).abs() < 1e-12);
        assert!(gstat_threshold_for_pvalue(0.0).is_err());
        assert!(gstat_threshold_for_pvalue(1.0).is_err());
    }

    #[test]
    fn pvalue_threshold_round_trips() {
        for &(p, n) in &[(0.05f64, 1000usize), (0.01, 500), (1e-6, 20_000)] {
            let t = mi_threshold_for_pvalue(p, n).unwrap();
            assert!(t > 0.0);
            let back = mi_pvalue_asymptotic(t, n);
            assert!((back - p).abs() <= p * 0.05 + 1e-7, "p={p} back={back}");
        }
        // larger n -> smaller MI needed for the same significance
        let t_small = mi_threshold_for_pvalue(0.01, 100).unwrap();
        let t_big = mi_threshold_for_pvalue(0.01, 10_000).unwrap();
        assert!(t_big < t_small);
        assert!(mi_threshold_for_pvalue(0.0, 100).is_err());
        assert!(mi_threshold_for_pvalue(1.5, 100).is_err());
        assert!(mi_threshold_for_pvalue(0.05, 0).is_err());
    }

    #[test]
    fn asymptotic_pvalue_tracks_permutation() {
        // the planted strong pair is significant under both tests
        let ds = planted();
        let mi = compute_mi(&ds, Backend::BulkBitpack).unwrap();
        let p_asym = mi_pvalue_asymptotic(mi.get(0, 1), ds.n_rows());
        assert!(p_asym < 1e-6, "planted pair asymptotic p = {p_asym}");
        // an independent pair is not
        let p_indep = mi_pvalue_asymptotic(mi.get(5, 6), ds.n_rows());
        assert!(p_indep > 1e-4, "independent pair asymptotic p = {p_indep}");
    }

    #[test]
    fn pvalue_bounds() {
        let ds = planted();
        for &(x, y) in &[(0usize, 1usize), (2, 3)] {
            let (_, p) = permutation_test(&ds, x, y, 50, 1);
            assert!(p > 0.0 && p <= 1.0);
        }
    }
}
