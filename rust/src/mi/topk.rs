//! Extracting structure from an MI matrix: top-k strongest pairs,
//! threshold edge lists, and per-variable relevance ranking — the
//! feature-selection / network-construction consumers from the paper's
//! introduction.

use super::MiMatrix;

/// An (i, j, mi) pair with i < j.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MiPair {
    pub i: usize,
    pub j: usize,
    pub mi: f64,
}

/// The k strongest off-diagonal pairs, descending by MI (stable order:
/// ties broken by (i, j)).
pub fn top_k_pairs(mi: &MiMatrix, k: usize) -> Vec<MiPair> {
    let m = mi.dim();
    let mut pairs = Vec::with_capacity(m * (m.saturating_sub(1)) / 2);
    for i in 0..m {
        for j in (i + 1)..m {
            pairs.push(MiPair { i, j, mi: mi.get(i, j) });
        }
    }
    pairs.sort_by(|a, b| {
        b.mi.partial_cmp(&a.mi).unwrap().then(a.i.cmp(&b.i)).then(a.j.cmp(&b.j))
    });
    pairs.truncate(k);
    pairs
}

/// All off-diagonal pairs with MI >= threshold (an "MI network" edge list).
pub fn edges_above(mi: &MiMatrix, threshold: f64) -> Vec<MiPair> {
    let m = mi.dim();
    let mut edges = Vec::new();
    for i in 0..m {
        for j in (i + 1)..m {
            let v = mi.get(i, j);
            if v >= threshold {
                edges.push(MiPair { i, j, mi: v });
            }
        }
    }
    edges
}

/// Sum of MI to all other variables — a max-relevance score per column.
pub fn relevance_scores(mi: &MiMatrix) -> Vec<f64> {
    let m = mi.dim();
    (0..m)
        .map(|i| (0..m).filter(|&j| j != i).map(|j| mi.get(i, j)).sum())
        .collect()
}

/// Greedy mRMR-style selection: repeatedly pick the variable maximizing
/// `relevance(target) - mean MI to already-selected` (paper ref [12]).
/// `target_mi[i]` is MI(X_i; label); returns selected column indices.
pub fn mrmr_select(mi: &MiMatrix, target_mi: &[f64], k: usize) -> Vec<usize> {
    let m = mi.dim();
    assert_eq!(target_mi.len(), m);
    let mut selected: Vec<usize> = Vec::new();
    let mut remaining: Vec<usize> = (0..m).collect();
    while selected.len() < k && !remaining.is_empty() {
        let (best_pos, _) = remaining
            .iter()
            .enumerate()
            .map(|(pos, &c)| {
                let redundancy = if selected.is_empty() {
                    0.0
                } else {
                    selected.iter().map(|&s| mi.get(c, s)).sum::<f64>() / selected.len() as f64
                };
                (pos, target_mi[c] - redundancy)
            })
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        selected.push(remaining.swap_remove(best_pos));
    }
    selected
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::mi::pairwise::mi_pairwise;

    fn planted_mi() -> MiMatrix {
        let ds = SynthSpec::new(3000, 8)
            .sparsity(0.5)
            .seed(1)
            .plant(0, 1, 0.05)
            .plant(2, 3, 0.2)
            .generate();
        mi_pairwise(&ds)
    }

    #[test]
    fn top_k_finds_planted_pairs() {
        let mi = planted_mi();
        let top = top_k_pairs(&mi, 2);
        assert_eq!((top[0].i, top[0].j), (0, 1));
        assert_eq!((top[1].i, top[1].j), (2, 3));
        assert!(top[0].mi > top[1].mi);
    }

    #[test]
    fn top_k_truncates_and_orders() {
        let mi = planted_mi();
        let all = top_k_pairs(&mi, usize::MAX);
        assert_eq!(all.len(), 8 * 7 / 2);
        for w in all.windows(2) {
            assert!(w[0].mi >= w[1].mi);
        }
        assert_eq!(top_k_pairs(&mi, 3).len(), 3);
    }

    #[test]
    fn edges_above_threshold() {
        let mi = planted_mi();
        let strong = edges_above(&mi, 0.5);
        assert!(strong.iter().any(|e| (e.i, e.j) == (0, 1)));
        assert!(!strong.iter().any(|e| (e.i, e.j) == (5, 6)));
        let all = edges_above(&mi, 0.0);
        assert_eq!(all.len(), 28);
    }

    #[test]
    fn relevance_ranks_planted_columns() {
        let mi = planted_mi();
        let rel = relevance_scores(&mi);
        // planted columns participate in a high-MI pair: highest relevance
        let mut order: Vec<usize> = (0..8).collect();
        order.sort_by(|&a, &b| rel[b].partial_cmp(&rel[a]).unwrap());
        assert!(order[..4].contains(&0) && order[..4].contains(&1));
    }

    #[test]
    fn mrmr_avoids_redundant_picks() {
        let mi = planted_mi();
        // target highly informed by both 0 and 1 (which are near-copies):
        // after picking one of them, mRMR should prefer a non-redundant
        // column over the other one.
        let target = vec![1.0, 0.98, 0.3, 0.3, 0.29, 0.28, 0.27, 0.26];
        let sel = mrmr_select(&mi, &target, 3);
        assert_eq!(sel[0], 0);
        assert_ne!(sel[1], 1, "second pick should avoid the redundant copy");
        assert_eq!(sel.len(), 3);
    }
}
