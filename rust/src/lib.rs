//! # bulkmi — fast bulk mutual information for large binary datasets
//!
//! Production-quality reproduction of *"Fast Mutual Information Computation
//! for Large Binary Datasets"* (A. O. Falcao, 2024) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: blockwise planning,
//!   scheduling, the job service, all five native CPU backends the paper
//!   evaluates, and the PJRT runtime that executes AOT-compiled XLA
//!   artifacts. Python never runs on the request path.
//! * **Layer 2** — JAX compute graphs (`python/compile/model.py`),
//!   AOT-lowered once to HLO text artifacts.
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/mi_pallas.py`)
//!   implementing the tiled Gram matmul and the element-wise MI combine.
//!
//! ## The algorithm in one paragraph
//!
//! For an `n x m` binary matrix `D`, all `m^2` pairwise mutual informations
//! are a function of just `(G11, c, n)` where `G11 = D^T D` and
//! `c = colsums(D)`: the paper's Section-3 identities give
//! `G00 = N - C - C^T + G11`, `G01 = C - G11`, `G10 = G01^T`, so a single
//! Gram computation replaces the `O(m^2)` per-pair 2x2 contingency scans.
//! Every backend in [`mi`] is a different substrate for that one Gram:
//! dense blocked f32 ([`linalg::blas`]), bit-packed AND+popcount
//! ([`linalg::bitmat`]), CSR sparse ([`linalg::csr`]), or the XLA/PJRT
//! executable compiled from the Pallas kernel.
//!
//! ## Quickstart
//!
//! ```no_run
//! use bulkmi::data::synth::SynthSpec;
//! use bulkmi::mi::backend::{Backend, compute_mi};
//!
//! let ds = SynthSpec::new(10_000, 200).sparsity(0.9).seed(7).generate();
//! let mi = compute_mi(&ds, Backend::BulkBitpack).unwrap();
//! println!("MI(0,1) = {:.4} bits", mi.get(0, 1));
//! ```
//!
//! See `examples/` for end-to-end drivers and `rust/benches/` for the
//! reproduction of every table and figure in the paper.

// The numeric kernels deliberately index by (row, col) to mirror the
// paper's pseudocode; iterator rewrites would obscure the math.
#![allow(clippy::needless_range_loop)]

pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod metrics;
pub mod mi;
pub mod runtime;
pub mod server;
pub mod util;

pub use util::error::{Error, Result};
