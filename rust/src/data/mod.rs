//! Datasets: the in-memory binary dataset type, synthetic workload
//! generators matching the paper's experimental setup (sparsity-controlled
//! Bernoulli data) and the application domains its introduction motivates
//! (genomics marker panels, text bag-of-words, network adjacency), plus
//! CSV / `.bmat` IO.

pub mod dataset;
pub mod genomics;
pub mod graph;
pub mod io;
pub mod synth;
pub mod text;
