//! Datasets: the in-memory binary dataset type, the [`colstore`]
//! column-source abstraction that streams bit-packed column blocks from
//! memory or disk (out-of-core input), synthetic workload generators
//! matching the paper's experimental setup (sparsity-controlled
//! Bernoulli data) and the application domains its introduction motivates
//! (genomics marker panels, text bag-of-words, network adjacency), plus
//! CSV / `.bmat` (v1 row-major bits, v2 column-major packed words) IO.

pub mod colstore;
pub mod dataset;
pub mod genomics;
pub mod graph;
pub mod io;
pub mod synth;
pub mod text;

pub use colstore::{ColumnSource, InMemorySource, PackedFileSource};
