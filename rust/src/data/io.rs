//! Dataset IO: CSV (with optional header) and `.bmat`, a compact binary
//! format (magic + dims + bit-packed payload) for large panels.

use super::dataset::BinaryDataset;
use crate::util::error::{Error, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Magic bytes for the .bmat format, version 1.
const BMAT_MAGIC: &[u8; 8] = b"BULKMI\x01\0";

/// Write CSV. `header` controls whether column names are emitted.
pub fn write_csv(ds: &BinaryDataset, path: &Path, header: bool) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    if header {
        let names: Vec<String> = (0..ds.n_cols()).map(|c| ds.col_name(c)).collect();
        writeln!(w, "{}", names.join(","))?;
    }
    let mut line = String::with_capacity(ds.n_cols() * 2);
    for r in 0..ds.n_rows() {
        line.clear();
        for (c, &v) in ds.row(r).iter().enumerate() {
            if c > 0 {
                line.push(',');
            }
            line.push(if v == 1 { '1' } else { '0' });
        }
        writeln!(w, "{line}")?;
    }
    Ok(())
}

/// Read CSV of 0/1 cells. If the first row contains any non-numeric
/// token it is treated as a header of column names.
pub fn read_csv(path: &Path) -> Result<BinaryDataset> {
    let f = std::fs::File::open(path)?;
    let reader = BufReader::new(f);
    let mut names: Option<Vec<String>> = None;
    let mut data: Vec<u8> = Vec::new();
    let mut n_cols = 0usize;
    let mut n_rows = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(|s| s.trim()).collect();
        if lineno == 0 && fields.iter().any(|f| f.parse::<u8>().is_err()) {
            names = Some(fields.iter().map(|s| s.to_string()).collect());
            n_cols = fields.len();
            continue;
        }
        if n_cols == 0 {
            n_cols = fields.len();
        } else if fields.len() != n_cols {
            return Err(Error::Parse(format!(
                "line {}: {} fields, expected {n_cols}",
                lineno + 1,
                fields.len()
            )));
        }
        for f in &fields {
            match *f {
                "0" => data.push(0),
                "1" => data.push(1),
                other => {
                    return Err(Error::Parse(format!(
                        "line {}: non-binary value '{other}'",
                        lineno + 1
                    )))
                }
            }
        }
        n_rows += 1;
    }
    let ds = BinaryDataset::new(n_rows, n_cols, data)?;
    match names {
        Some(ns) => ds.with_names(ns),
        None => Ok(ds),
    }
}

/// Write the compact bit-packed `.bmat` format.
///
/// Layout: magic(8) | n_rows(u64 LE) | n_cols(u64 LE) | payload where the
/// payload packs cells row-major, 8 cells per byte, LSB first.
pub fn write_bmat(ds: &BinaryDataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(BMAT_MAGIC)?;
    w.write_all(&(ds.n_rows() as u64).to_le_bytes())?;
    w.write_all(&(ds.n_cols() as u64).to_le_bytes())?;
    let total = ds.n_rows() * ds.n_cols();
    let bytes = ds.bytes();
    let mut packed = vec![0u8; total.div_ceil(8)];
    for (i, &v) in bytes.iter().enumerate() {
        if v != 0 {
            packed[i / 8] |= 1 << (i % 8);
        }
    }
    w.write_all(&packed)?;
    Ok(())
}

/// Read `.bmat`.
pub fn read_bmat(path: &Path) -> Result<BinaryDataset> {
    let mut f = std::fs::File::open(path)?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != BMAT_MAGIC {
        return Err(Error::Parse("not a .bmat file (bad magic)".into()));
    }
    let mut dims = [0u8; 16];
    f.read_exact(&mut dims)?;
    let n_rows = u64::from_le_bytes(dims[..8].try_into().unwrap()) as usize;
    let n_cols = u64::from_le_bytes(dims[8..].try_into().unwrap()) as usize;
    let total = n_rows
        .checked_mul(n_cols)
        .ok_or_else(|| Error::Parse("dimension overflow".into()))?;
    let mut packed = vec![0u8; total.div_ceil(8)];
    f.read_exact(&mut packed)?;
    let mut data = vec![0u8; total];
    for (i, cell) in data.iter_mut().enumerate() {
        *cell = (packed[i / 8] >> (i % 8)) & 1;
    }
    BinaryDataset::new(n_rows, n_cols, data)
}

/// Load by extension: `.csv` or `.bmat`.
pub fn load(path: &Path) -> Result<BinaryDataset> {
    match path.extension().and_then(|e| e.to_str()) {
        Some("csv") => read_csv(path),
        Some("bmat") => read_bmat(path),
        other => Err(Error::Parse(format!("unsupported dataset extension {other:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    fn tmpdir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("bulkmi-io-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn csv_round_trip_no_header() {
        let ds = SynthSpec::new(20, 7).sparsity(0.6).seed(1).generate();
        let path = tmpdir().join("nh.csv");
        write_csv(&ds, &path, false).unwrap();
        let back = read_csv(&path).unwrap();
        assert_eq!(back.bytes(), ds.bytes());
        assert_eq!((back.n_rows(), back.n_cols()), (20, 7));
    }

    #[test]
    fn csv_round_trip_with_header() {
        let ds = SynthSpec::new(5, 3)
            .seed(2)
            .generate()
            .with_names(vec!["alpha".into(), "beta".into(), "gamma".into()])
            .unwrap();
        let path = tmpdir().join("h.csv");
        write_csv(&ds, &path, true).unwrap();
        let back = read_csv(&path).unwrap();
        assert_eq!(back.names().unwrap(), ds.names().unwrap());
        assert_eq!(back.bytes(), ds.bytes());
    }

    #[test]
    fn csv_rejects_garbage() {
        let path = tmpdir().join("bad.csv");
        std::fs::write(&path, "0,1\n1,2\n").unwrap();
        assert!(read_csv(&path).is_err());
        std::fs::write(&path, "0,1\n1\n").unwrap();
        assert!(read_csv(&path).is_err());
    }

    #[test]
    fn bmat_round_trip() {
        let ds = SynthSpec::new(100, 33).sparsity(0.9).seed(3).generate();
        let path = tmpdir().join("x.bmat");
        write_bmat(&ds, &path).unwrap();
        let back = read_bmat(&path).unwrap();
        assert_eq!(back.bytes(), ds.bytes());
    }

    #[test]
    fn bmat_rejects_bad_magic() {
        let path = tmpdir().join("bad.bmat");
        std::fs::write(&path, b"NOTBMAT!aaaaaaaaaaaaaaaa").unwrap();
        assert!(read_bmat(&path).is_err());
    }

    #[test]
    fn load_dispatches_on_extension() {
        let ds = SynthSpec::new(4, 4).seed(4).generate();
        let dir = tmpdir();
        let c = dir.join("d.csv");
        let b = dir.join("d.bmat");
        write_csv(&ds, &c, false).unwrap();
        write_bmat(&ds, &b).unwrap();
        assert_eq!(load(&c).unwrap().bytes(), ds.bytes());
        assert_eq!(load(&b).unwrap().bytes(), ds.bytes());
        assert!(load(&dir.join("d.xyz")).is_err());
    }
}
