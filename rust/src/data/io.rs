//! Dataset IO: CSV (with optional header) and the `.bmat` binary
//! format, in two versions:
//!
//! * **v1** — row-major bit stream, 8 cells per byte. Compact, but a
//!   column block read has to touch every row's bytes, so it only ever
//!   loads whole datasets.
//! * **v2** — **column-major** bit-packed 64-bit words, one
//!   `⌈n_rows/64⌉`-word run per column (exactly the
//!   [`crate::linalg::bitmat::BitMatrix`] layout). 8x smaller than the
//!   one-byte-per-cell in-memory form, and a column block is one
//!   contiguous byte range — which is what lets
//!   [`crate::data::colstore::PackedFileSource`] stream blocks straight
//!   off disk without materializing the dataset.
//!
//! v2 layout (all integers little-endian):
//!
//! ```text
//! magic      8 B   b"BULKMI\x02\0"
//! n_rows     8 B   u64
//! n_cols     8 B   u64
//! names_len  8 B   u64 — 0 when the columns are unnamed
//! names      names_len B of UTF-8, the n_cols names '\n'-joined
//! payload    n_cols x ⌈n_rows/64⌉ x 8 B — column-major packed words,
//!            bit r%64 of word r/64 in column c's run = cell (r, c)
//! ```
//!
//! [`pack`] converts CSV / v1 to v2 one row chunk at a time (seek-writes
//! into each column's word run), so the conversion itself never holds
//! more than a chunk of rows; [`write_bmat_v2`] is the in-memory
//! convenience writer over the same code path.

use super::colstore::PackedFileSource;
use super::dataset::BinaryDataset;
use crate::util::error::{Error, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

/// Magic bytes for the .bmat format, version 1 (row-major bits).
const BMAT_MAGIC: &[u8; 8] = b"BULKMI\x01\0";
/// Magic bytes for the .bmat format, version 2 (column-major words).
const BMAT2_MAGIC: &[u8; 8] = b"BULKMI\x02\0";

/// Rows per chunk for the streaming [`pack`] conversion (a multiple of
/// 64 so chunk boundaries never straddle a packed word).
pub const PACK_CHUNK_ROWS: usize = 8192;

/// Write CSV. `header` controls whether column names are emitted.
pub fn write_csv(ds: &BinaryDataset, path: &Path, header: bool) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    if header {
        let names: Vec<String> = (0..ds.n_cols()).map(|c| ds.col_name(c)).collect();
        writeln!(w, "{}", names.join(","))?;
    }
    let mut line = String::with_capacity(ds.n_cols() * 2);
    for r in 0..ds.n_rows() {
        line.clear();
        for (c, &v) in ds.row(r).iter().enumerate() {
            if c > 0 {
                line.push(',');
            }
            line.push(if v == 1 { '1' } else { '0' });
        }
        writeln!(w, "{line}")?;
    }
    Ok(())
}

/// Read CSV of 0/1 cells. If the first row contains any non-numeric
/// token it is treated as a header of column names.
pub fn read_csv(path: &Path) -> Result<BinaryDataset> {
    let f = std::fs::File::open(path)?;
    let reader = BufReader::new(f);
    let mut names: Option<Vec<String>> = None;
    let mut data: Vec<u8> = Vec::new();
    let mut n_cols = 0usize;
    let mut n_rows = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(|s| s.trim()).collect();
        if lineno == 0 && fields.iter().any(|f| f.parse::<u8>().is_err()) {
            names = Some(fields.iter().map(|s| s.to_string()).collect());
            n_cols = fields.len();
            continue;
        }
        if n_cols == 0 {
            n_cols = fields.len();
        } else if fields.len() != n_cols {
            return Err(Error::Parse(format!(
                "line {}: {} fields, expected {n_cols}",
                lineno + 1,
                fields.len()
            )));
        }
        for f in &fields {
            match *f {
                "0" => data.push(0),
                "1" => data.push(1),
                other => {
                    return Err(Error::Parse(format!(
                        "line {}: non-binary value '{other}'",
                        lineno + 1
                    )))
                }
            }
        }
        n_rows += 1;
    }
    let ds = BinaryDataset::new(n_rows, n_cols, data)?;
    match names {
        Some(ns) => ds.with_names(ns),
        None => Ok(ds),
    }
}

/// Write the row-major bit-packed `.bmat` **v1** format (kept for
/// interchange with older tooling; new datasets should use
/// [`write_bmat_v2`], which column blocks can be streamed from).
///
/// Layout: magic(8) | n_rows(u64 LE) | n_cols(u64 LE) | payload where the
/// payload packs cells row-major, 8 cells per byte, LSB first.
pub fn write_bmat(ds: &BinaryDataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(BMAT_MAGIC)?;
    w.write_all(&(ds.n_rows() as u64).to_le_bytes())?;
    w.write_all(&(ds.n_cols() as u64).to_le_bytes())?;
    let total = ds.n_rows() * ds.n_cols();
    let bytes = ds.bytes();
    let mut packed = vec![0u8; total.div_ceil(8)];
    for (i, &v) in bytes.iter().enumerate() {
        if v != 0 {
            packed[i / 8] |= 1 << (i % 8);
        }
    }
    w.write_all(&packed)?;
    Ok(())
}

/// Read `.bmat`, either version (the magic selects the decoder).
///
/// The v1 payload length is validated against `n_rows x n_cols`
/// (checked multiply; truncated files and trailing bytes are clean
/// [`Error::Parse`]s, never a short read into a wrong-shaped dataset).
pub fn read_bmat(path: &Path) -> Result<BinaryDataset> {
    let mut f = std::fs::File::open(path)?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic == BMAT2_MAGIC {
        drop(f);
        return PackedFileSource::open(path)?.to_dataset();
    }
    if &magic != BMAT_MAGIC {
        return Err(Error::Parse("not a .bmat file (bad magic)".into()));
    }
    let mut dims = [0u8; 16];
    f.read_exact(&mut dims)?;
    let n_rows = u64::from_le_bytes(dims[..8].try_into().expect("8 bytes")) as usize;
    let n_cols = u64::from_le_bytes(dims[8..].try_into().expect("8 bytes")) as usize;
    let total = n_rows
        .checked_mul(n_cols)
        .ok_or_else(|| Error::Parse("dimension overflow".into()))?;
    let want = total.div_ceil(8);
    let mut packed = Vec::new();
    f.read_to_end(&mut packed)?;
    if packed.len() != want {
        return Err(Error::Parse(format!(
            "v1 payload is {} bytes but {n_rows}x{n_cols} needs {want} \
             (truncated or trailing bytes)",
            packed.len()
        )));
    }
    let mut data = vec![0u8; total];
    for (i, cell) in data.iter_mut().enumerate() {
        *cell = (packed[i / 8] >> (i % 8)) & 1;
    }
    BinaryDataset::new(n_rows, n_cols, data)
}

/// Does `path` look like a `.bmat` v2 file (extension + magic)? Used by
/// the CLI to pick the streaming input path; `Ok(false)` for anything
/// the ordinary in-memory loaders should handle (including files too
/// short to carry a magic — the loader reports those properly).
pub fn is_bmat_v2(path: &Path) -> Result<bool> {
    if path.extension().and_then(|e| e.to_str()) != Some("bmat") {
        return Ok(false);
    }
    let mut f = std::fs::File::open(path)?;
    let mut magic = [0u8; 8];
    match f.read_exact(&mut magic) {
        Ok(()) => Ok(&magic == BMAT2_MAGIC),
        Err(_) => Ok(false),
    }
}

/// Parsed v2 header (everything before the packed payload).
pub(crate) struct Bmat2Header {
    pub n_rows: usize,
    pub n_cols: usize,
    pub names: Option<Vec<String>>,
    /// Absolute byte offset of the packed payload.
    pub payload_off: u64,
}

/// Read and validate a v2 header from the start of `f`.
pub(crate) fn read_bmat2_header(f: &mut std::fs::File, path: &Path) -> Result<Bmat2Header> {
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != BMAT2_MAGIC {
        return Err(Error::Parse(format!(
            "{} is not a .bmat v2 file (convert with `bulkmi pack`)",
            path.display()
        )));
    }
    let mut head = [0u8; 24];
    f.read_exact(&mut head)?;
    let n_rows = usize::try_from(u64::from_le_bytes(head[..8].try_into().expect("8 bytes")))
        .map_err(|_| Error::Parse("v2 header: n_rows overflows usize".into()))?;
    let n_cols = usize::try_from(u64::from_le_bytes(head[8..16].try_into().expect("8 bytes")))
        .map_err(|_| Error::Parse("v2 header: n_cols overflows usize".into()))?;
    let names_len = u64::from_le_bytes(head[16..24].try_into().expect("8 bytes"));
    let names = if names_len == 0 {
        None
    } else {
        // guard the allocation against a corrupt header: the name blob
        // cannot be larger than the file it came from
        if names_len > f.metadata()?.len() {
            return Err(Error::Parse(format!(
                "v2 header: names length {names_len} exceeds the file size"
            )));
        }
        let len = usize::try_from(names_len)
            .map_err(|_| Error::Parse("v2 header: names length overflows usize".into()))?;
        let mut blob = vec![0u8; len];
        f.read_exact(&mut blob)?;
        let text = String::from_utf8(blob)
            .map_err(|_| Error::Parse("v2 header: column names are not UTF-8".into()))?;
        let ns: Vec<String> = text.split('\n').map(str::to_string).collect();
        if ns.len() != n_cols {
            return Err(Error::Parse(format!(
                "v2 header: {} names for {n_cols} columns",
                ns.len()
            )));
        }
        Some(ns)
    };
    Ok(Bmat2Header { n_rows, n_cols, names, payload_off: 32 + names_len })
}

/// Incremental v2 writer: fixes the dimensions up front, then accepts
/// row chunks and seek-writes each chunk's words into every column's
/// run. Every chunk except the last must be a multiple of 64 rows so
/// no packed word straddles two chunks.
struct Bmat2Writer {
    f: std::fs::File,
    payload_off: u64,
    words_per_col: usize,
    n_rows: usize,
    n_cols: usize,
    next_row: usize,
    colbuf: Vec<u64>,
}

impl Bmat2Writer {
    fn create(
        path: &Path,
        n_rows: usize,
        n_cols: usize,
        names: Option<&[String]>,
    ) -> Result<Self> {
        if let Some(ns) = names {
            if ns.len() != n_cols {
                return Err(Error::Shape(format!(
                    "{} names for {n_cols} columns",
                    ns.len()
                )));
            }
            if ns.iter().any(|n| n.contains('\n')) {
                return Err(Error::Parse(
                    "column names must not contain newlines (.bmat v2 stores them \
                     '\\n'-joined)"
                        .into(),
                ));
            }
        }
        let words_per_col = n_rows.div_ceil(64);
        let payload_words = words_per_col
            .checked_mul(n_cols)
            .ok_or_else(|| Error::Parse(format!("{n_rows}x{n_cols} overflows")))?;
        let name_blob = match names {
            Some(ns) if !ns.is_empty() => ns.join("\n"),
            _ => String::new(),
        };
        let mut f = std::fs::File::create(path)?;
        f.write_all(BMAT2_MAGIC)?;
        f.write_all(&(n_rows as u64).to_le_bytes())?;
        f.write_all(&(n_cols as u64).to_le_bytes())?;
        f.write_all(&(name_blob.len() as u64).to_le_bytes())?;
        f.write_all(name_blob.as_bytes())?;
        let payload_off = 32 + name_blob.len() as u64;
        f.set_len(payload_off + payload_words as u64 * 8)?;
        Ok(Bmat2Writer {
            f,
            payload_off,
            words_per_col,
            n_rows,
            n_cols,
            next_row: 0,
            colbuf: Vec::new(),
        })
    }

    /// Append `k` rows given as row-major 0/1 bytes (any nonzero byte
    /// counts as a one).
    fn push_rows(&mut self, rows: &[u8], k: usize) -> Result<()> {
        if rows.len() != k * self.n_cols {
            return Err(Error::Shape(format!(
                "chunk buffer has {} bytes, {k} rows x {} cols needs {}",
                rows.len(),
                self.n_cols,
                k * self.n_cols
            )));
        }
        if self.next_row % 64 != 0 {
            return Err(Error::Shape(
                "only the final chunk may have a non-multiple-of-64 row count".into(),
            ));
        }
        if self.next_row + k > self.n_rows {
            return Err(Error::Shape(format!(
                "chunk overruns the declared {} rows",
                self.n_rows
            )));
        }
        let kw = k.div_ceil(64);
        self.colbuf.clear();
        self.colbuf.resize(self.n_cols * kw, 0);
        for r in 0..k {
            let row = &rows[r * self.n_cols..(r + 1) * self.n_cols];
            let (word, bit) = (r / 64, r % 64);
            for (c, &v) in row.iter().enumerate() {
                if v != 0 {
                    self.colbuf[c * kw + word] |= 1u64 << bit;
                }
            }
        }
        let word0 = (self.next_row / 64) as u64;
        let mut bytes = Vec::with_capacity(kw * 8);
        for c in 0..self.n_cols {
            bytes.clear();
            for w in &self.colbuf[c * kw..(c + 1) * kw] {
                bytes.extend_from_slice(&w.to_le_bytes());
            }
            let off = self.payload_off + (c as u64 * self.words_per_col as u64 + word0) * 8;
            self.f.seek(SeekFrom::Start(off))?;
            self.f.write_all(&bytes)?;
        }
        self.next_row += k;
        Ok(())
    }

    /// Verify every declared row arrived and return the total file size.
    fn finish(mut self) -> Result<u64> {
        if self.next_row != self.n_rows {
            return Err(Error::Shape(format!(
                "wrote {} of {} declared rows",
                self.next_row, self.n_rows
            )));
        }
        self.f.flush()?;
        Ok(self.payload_off + (self.words_per_col * self.n_cols) as u64 * 8)
    }
}

/// Write the column-major bit-packed `.bmat` **v2** format (the
/// streaming-readable layout — see the module docs for the byte
/// layout). Column names, when present, are stored in the header.
pub fn write_bmat_v2(ds: &BinaryDataset, path: &Path) -> Result<()> {
    let mut w = Bmat2Writer::create(path, ds.n_rows(), ds.n_cols(), ds.names())?;
    let mut start = 0;
    while start < ds.n_rows() {
        let k = PACK_CHUNK_ROWS.min(ds.n_rows() - start);
        let rows = &ds.bytes()[start * ds.n_cols()..(start + k) * ds.n_cols()];
        w.push_rows(rows, k)?;
        start += k;
    }
    w.finish()?;
    Ok(())
}

/// What [`pack`] produced.
#[derive(Clone, Copy, Debug)]
pub struct PackStats {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Input file size in bytes.
    pub in_bytes: u64,
    /// Output (v2) file size in bytes.
    pub out_bytes: u64,
}

/// Convert a CSV or `.bmat` v1 dataset to `.bmat` v2, streaming one
/// `chunk_rows` row chunk at a time — the dataset is **never**
/// materialized, so arbitrarily large inputs convert in bounded memory
/// (one chunk of cells plus one chunk of packed words).
///
/// `chunk_rows` is rounded up to a multiple of 64 (packed-word
/// alignment); pass [`PACK_CHUNK_ROWS`] when in doubt.
pub fn pack(input: &Path, out: &Path, chunk_rows: usize) -> Result<PackStats> {
    let chunk_rows = chunk_rows.max(1).div_ceil(64) * 64;
    // refuse in-place conversion: creating the output truncates the
    // inode the input read fd points at, destroying the dataset
    // (canonicalize on `out` only succeeds when it already exists —
    // and a non-existent output cannot be the input)
    if let (Ok(ci), Ok(co)) = (input.canonicalize(), out.canonicalize()) {
        if ci == co {
            return Err(Error::Parse(
                "pack: --out must differ from --input (in-place conversion would \
                 destroy the input)"
                    .into(),
            ));
        }
    }
    let in_bytes = std::fs::metadata(input)?.len();
    let (n_rows, n_cols, out_bytes) = match input.extension().and_then(|e| e.to_str()) {
        Some("csv") => pack_csv(input, out, chunk_rows)?,
        Some("bmat") => pack_bmat_v1(input, out, chunk_rows)?,
        other => {
            return Err(Error::Parse(format!(
                "pack: unsupported input extension {other:?} (expected .csv or .bmat)"
            )))
        }
    };
    Ok(PackStats { n_rows, n_cols, in_bytes, out_bytes })
}

/// Remove a partially-written v2 output after a mid-conversion error: a
/// header-valid, zero-payload stub must not be left for `compute` to
/// load silently. Only called once the writer has created the file —
/// errors *before* creation (bad input, corrupt header) must not
/// delete whatever the caller's `--out` path already held.
fn cleanup_partial<T>(out: &Path, result: Result<T>) -> Result<T> {
    if result.is_err() {
        let _ = std::fs::remove_file(out);
    }
    result
}

/// Pass 1 of the CSV pack: dimensions + header names, no cell storage.
fn scan_csv(path: &Path) -> Result<(usize, usize, Option<Vec<String>>)> {
    let reader = BufReader::new(std::fs::File::open(path)?);
    let mut names: Option<Vec<String>> = None;
    let mut n_cols = 0usize;
    let mut n_rows = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(|s| s.trim()).collect();
        if lineno == 0 && fields.iter().any(|f| f.parse::<u8>().is_err()) {
            names = Some(fields.iter().map(|s| s.to_string()).collect());
            n_cols = fields.len();
            continue;
        }
        if n_cols == 0 {
            n_cols = fields.len();
        }
        n_rows += 1;
    }
    Ok((n_rows, n_cols, names))
}

fn pack_csv(input: &Path, out: &Path, chunk_rows: usize) -> Result<(usize, usize, u64)> {
    let (n_rows, n_cols, names) = scan_csv(input)?;
    let w = Bmat2Writer::create(out, n_rows, n_cols, names.as_deref())?;
    cleanup_partial(out, fill_from_csv(w, input, chunk_rows, names.is_some()))
}

fn fill_from_csv(
    mut w: Bmat2Writer,
    input: &Path,
    chunk_rows: usize,
    has_header: bool,
) -> Result<(usize, usize, u64)> {
    let (n_rows, n_cols) = (w.n_rows, w.n_cols);
    let reader = BufReader::new(std::fs::File::open(input)?);
    let mut buf: Vec<u8> = Vec::with_capacity(chunk_rows.min(n_rows.max(1)) * n_cols);
    let mut buffered = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        if lineno == 0 && has_header {
            continue; // header consumed in pass 1
        }
        let mut count = 0usize;
        for f in t.split(',') {
            match f.trim() {
                "0" => buf.push(0),
                "1" => buf.push(1),
                other => {
                    return Err(Error::Parse(format!(
                        "line {}: non-binary value '{other}'",
                        lineno + 1
                    )))
                }
            }
            count += 1;
        }
        if count != n_cols {
            return Err(Error::Parse(format!(
                "line {}: {count} fields, expected {n_cols}",
                lineno + 1
            )));
        }
        buffered += 1;
        if buffered == chunk_rows {
            w.push_rows(&buf, buffered)?;
            buf.clear();
            buffered = 0;
        }
    }
    if buffered > 0 {
        w.push_rows(&buf, buffered)?;
    }
    let out_bytes = w.finish()?;
    Ok((n_rows, n_cols, out_bytes))
}

fn pack_bmat_v1(input: &Path, out: &Path, chunk_rows: usize) -> Result<(usize, usize, u64)> {
    let mut f = std::fs::File::open(input)?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic == BMAT2_MAGIC {
        return Err(Error::Parse("pack: input is already a .bmat v2 file".into()));
    }
    if &magic != BMAT_MAGIC {
        return Err(Error::Parse("pack: not a .bmat file (bad magic)".into()));
    }
    let mut dims = [0u8; 16];
    f.read_exact(&mut dims)?;
    let n_rows = u64::from_le_bytes(dims[..8].try_into().expect("8 bytes")) as usize;
    let n_cols = u64::from_le_bytes(dims[8..].try_into().expect("8 bytes")) as usize;
    let total = n_rows
        .checked_mul(n_cols)
        .ok_or_else(|| Error::Parse("dimension overflow".into()))?;
    // validate the header against the input's actual size *before*
    // creating (and pre-sizing) the output: a corrupt header must not
    // provoke a huge set_len, and no stub file should be left behind
    let want_payload = total.div_ceil(8) as u64;
    let expect_file = want_payload
        .checked_add(24)
        .ok_or_else(|| Error::Parse("dimension overflow".into()))?;
    let actual_file = f.metadata()?.len();
    if actual_file != expect_file {
        return Err(Error::Parse(format!(
            "v1 payload is {} bytes but {n_rows}x{n_cols} needs {want_payload} \
             (truncated or trailing bytes)",
            actual_file.saturating_sub(24)
        )));
    }
    let w = Bmat2Writer::create(out, n_rows, n_cols, None)?;
    cleanup_partial(out, fill_from_v1(w, f, chunk_rows, total))
}

/// Stream the v1 row-major bit payload into a v2 writer: rows do not
/// align to byte boundaries, so walk a global cell cursor across
/// fixed-size reads. `f` is positioned just past the v1 header.
fn fill_from_v1(
    mut w: Bmat2Writer,
    f: std::fs::File,
    chunk_rows: usize,
    total: usize,
) -> Result<(usize, usize, u64)> {
    let (n_rows, n_cols) = (w.n_rows, w.n_cols);
    let chunk_cells = chunk_rows * n_cols.max(1);
    let mut chunk: Vec<u8> = Vec::with_capacity(chunk_cells.min(total.max(1)));
    let mut reader = BufReader::new(f);
    let mut io_buf = vec![0u8; 64 * 1024];
    let mut cells = 0usize;
    let mut payload_bytes = 0usize;
    loop {
        let got = reader.read(&mut io_buf)?;
        if got == 0 {
            break;
        }
        payload_bytes += got;
        for &b in &io_buf[..got] {
            for bit in 0..8 {
                if cells >= total {
                    break; // padding bits of the final byte
                }
                chunk.push((b >> bit) & 1);
                cells += 1;
                if chunk.len() == chunk_cells {
                    w.push_rows(&chunk, chunk_rows)?;
                    chunk.clear();
                }
            }
        }
    }
    let want = total.div_ceil(8);
    if payload_bytes != want {
        return Err(Error::Parse(format!(
            "v1 payload is {payload_bytes} bytes but {n_rows}x{n_cols} needs {want} \
             (truncated or trailing bytes)"
        )));
    }
    if n_cols == 0 {
        // zero-column datasets carry no cells; declare the rows directly
        w.push_rows(&[], n_rows)?;
    } else if !chunk.is_empty() {
        let k = chunk.len() / n_cols;
        w.push_rows(&chunk, k)?;
    }
    let out_bytes = w.finish()?;
    Ok((n_rows, n_cols, out_bytes))
}

/// Load a whole dataset into memory by extension: `.csv` or `.bmat`
/// (either version). For out-of-core runs over v2 files, open a
/// [`PackedFileSource`] instead — it streams blocks without this
/// function's full materialization.
pub fn load(path: &Path) -> Result<BinaryDataset> {
    match path.extension().and_then(|e| e.to_str()) {
        Some("csv") => read_csv(path),
        Some("bmat") => read_bmat(path),
        other => Err(Error::Parse(format!("unsupported dataset extension {other:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::colstore::ColumnSource;
    use crate::data::synth::SynthSpec;

    fn tmpdir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("bulkmi-io-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn csv_round_trip_no_header() {
        let ds = SynthSpec::new(20, 7).sparsity(0.6).seed(1).generate();
        let path = tmpdir().join("nh.csv");
        write_csv(&ds, &path, false).unwrap();
        let back = read_csv(&path).unwrap();
        assert_eq!(back.bytes(), ds.bytes());
        assert_eq!((back.n_rows(), back.n_cols()), (20, 7));
    }

    #[test]
    fn csv_round_trip_with_header() {
        let ds = SynthSpec::new(5, 3)
            .seed(2)
            .generate()
            .with_names(vec!["alpha".into(), "beta".into(), "gamma".into()])
            .unwrap();
        let path = tmpdir().join("h.csv");
        write_csv(&ds, &path, true).unwrap();
        let back = read_csv(&path).unwrap();
        assert_eq!(back.names().unwrap(), ds.names().unwrap());
        assert_eq!(back.bytes(), ds.bytes());
    }

    #[test]
    fn csv_rejects_garbage() {
        let path = tmpdir().join("bad.csv");
        std::fs::write(&path, "0,1\n1,2\n").unwrap();
        assert!(read_csv(&path).is_err());
        std::fs::write(&path, "0,1\n1\n").unwrap();
        assert!(read_csv(&path).is_err());
    }

    #[test]
    fn bmat_round_trip() {
        let ds = SynthSpec::new(100, 33).sparsity(0.9).seed(3).generate();
        let path = tmpdir().join("x.bmat");
        write_bmat(&ds, &path).unwrap();
        let back = read_bmat(&path).unwrap();
        assert_eq!(back.bytes(), ds.bytes());
    }

    #[test]
    fn bmat_rejects_bad_magic() {
        let path = tmpdir().join("bad.bmat");
        std::fs::write(&path, b"NOTBMAT!aaaaaaaaaaaaaaaa").unwrap();
        assert!(read_bmat(&path).is_err());
    }

    #[test]
    fn bmat_v1_payload_length_is_validated() {
        let ds = SynthSpec::new(50, 9).sparsity(0.5).seed(4).generate();
        let path = tmpdir().join("len.bmat");
        write_bmat(&ds, &path).unwrap();
        let good = std::fs::read(&path).unwrap();

        // truncated payload
        std::fs::write(&path, &good[..good.len() - 1]).unwrap();
        let err = read_bmat(&path).unwrap_err();
        assert!(matches!(err, Error::Parse(_)), "truncated: {err}");

        // trailing bytes
        let mut long = good.clone();
        long.push(0);
        std::fs::write(&path, &long).unwrap();
        let err = read_bmat(&path).unwrap_err();
        assert!(matches!(err, Error::Parse(_)), "trailing: {err}");

        // absurd dimensions overflow the checked multiply
        let mut evil = good;
        evil[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        evil[16..24].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &evil).unwrap();
        let err = read_bmat(&path).unwrap_err();
        assert!(matches!(err, Error::Parse(_)), "overflow: {err}");
    }

    #[test]
    fn bmat_v2_round_trip_with_names() {
        let ds = SynthSpec::new(131, 9)
            .sparsity(0.7)
            .seed(5)
            .generate()
            .with_names((0..9).map(|c| format!("m{c}")).collect())
            .unwrap();
        let path = tmpdir().join("v2.bmat");
        write_bmat_v2(&ds, &path).unwrap();
        assert!(is_bmat_v2(&path).unwrap());
        let back = read_bmat(&path).unwrap();
        assert_eq!(back.bytes(), ds.bytes());
        assert_eq!(back.names().unwrap(), ds.names().unwrap());
        // v1 files are not v2
        let v1 = tmpdir().join("v1notv2.bmat");
        write_bmat(&ds, &v1).unwrap();
        assert!(!is_bmat_v2(&v1).unwrap());
    }

    #[test]
    fn bmat_v2_validates_file_length() {
        let ds = SynthSpec::new(70, 5).sparsity(0.5).seed(6).generate();
        let path = tmpdir().join("v2len.bmat");
        write_bmat_v2(&ds, &path).unwrap();
        let good = std::fs::read(&path).unwrap();
        std::fs::write(&path, &good[..good.len() - 8]).unwrap();
        assert!(read_bmat(&path).is_err(), "truncated v2 must not load");
        let mut long = good;
        long.push(7);
        std::fs::write(&path, &long).unwrap();
        assert!(read_bmat(&path).is_err(), "trailing v2 bytes must not load");
    }

    #[test]
    fn pack_csv_to_v2_streams() {
        let ds = SynthSpec::new(300, 17)
            .sparsity(0.8)
            .seed(7)
            .generate()
            .with_names((0..17).map(|c| format!("w{c}")).collect())
            .unwrap();
        let csv = tmpdir().join("p.csv");
        let v2 = tmpdir().join("p.bmat");
        write_csv(&ds, &csv, true).unwrap();
        // a tiny chunk size forces many chunk flushes (rounded to 64)
        let stats = pack(&csv, &v2, 1).unwrap();
        assert_eq!((stats.n_rows, stats.n_cols), (300, 17));
        assert!(stats.out_bytes > 0 && stats.in_bytes > 0);
        let back = read_bmat(&v2).unwrap();
        assert_eq!(back.bytes(), ds.bytes());
        assert_eq!(back.names().unwrap(), ds.names().unwrap());
    }

    #[test]
    fn pack_v1_to_v2_streams() {
        // 13 cols: rows do not align to v1 byte boundaries
        let ds = SynthSpec::new(257, 13).sparsity(0.6).seed(8).generate();
        let v1 = tmpdir().join("q1.bmat");
        let v2 = tmpdir().join("q2.bmat");
        write_bmat(&ds, &v1).unwrap();
        let stats = pack(&v1, &v2, 64).unwrap();
        assert_eq!((stats.n_rows, stats.n_cols), (257, 13));
        let back = read_bmat(&v2).unwrap();
        assert_eq!(back.bytes(), ds.bytes());
        // packing an already-v2 file is a clean error
        assert!(pack(&v2, &tmpdir().join("q3.bmat"), 64).is_err());
        // unsupported extensions are rejected
        assert!(pack(&tmpdir().join("nope.xyz"), &v2, 64).is_err());
        // in-place conversion is refused and leaves the input intact
        assert!(pack(&v1, &v1, 64).is_err());
        assert_eq!(read_bmat(&v1).unwrap().bytes(), ds.bytes(), "input untouched");
    }

    #[test]
    fn failed_csv_pack_leaves_no_output_stub() {
        let dir = tmpdir();
        let csv = dir.join("badcell.csv");
        std::fs::write(&csv, "0,1\n1,2\n").unwrap(); // non-binary '2'
        let out = dir.join("badcell.bmat");
        assert!(pack(&csv, &out, 64).is_err());
        assert!(!out.exists(), "failed pack must remove its partial output");
        // short row past line 1 likewise
        std::fs::write(&csv, "0,1\n1\n").unwrap();
        assert!(pack(&csv, &out, 64).is_err());
        assert!(!out.exists());
    }

    #[test]
    fn pack_rejects_corrupt_v1_header_without_touching_output() {
        let ds = SynthSpec::new(40, 8).sparsity(0.5).seed(10).generate();
        let v1 = tmpdir().join("corrupt.bmat");
        write_bmat(&ds, &v1).unwrap();
        let mut bytes = std::fs::read(&v1).unwrap();
        // absurd n_rows: the header now implies a gigabyte payload
        bytes[8..16].copy_from_slice(&(1u64 << 30).to_le_bytes());
        std::fs::write(&v1, &bytes).unwrap();
        let out = tmpdir().join("corrupt-out.bmat");
        assert!(pack(&v1, &out, 64).is_err());
        assert!(!out.exists(), "corrupt header must not leave an output stub behind");
    }

    #[test]
    fn pack_empty_and_tiny_edges() {
        let dir = tmpdir();
        // 0 rows, 3 named columns
        let ds = BinaryDataset::new(0, 3, vec![])
            .unwrap()
            .with_names(vec!["a".into(), "b".into(), "c".into()])
            .unwrap();
        let csv = dir.join("e0.csv");
        let v2 = dir.join("e0.bmat");
        write_csv(&ds, &csv, true).unwrap();
        let stats = pack(&csv, &v2, 64).unwrap();
        assert_eq!((stats.n_rows, stats.n_cols), (0, 3));
        let back = read_bmat(&v2).unwrap();
        assert_eq!((back.n_rows(), back.n_cols()), (0, 3));
        assert_eq!(back.names().unwrap(), ds.names().unwrap());

        // 0 columns via direct v2 write
        let none = BinaryDataset::new(4, 0, vec![]).unwrap();
        let v2z = dir.join("e1.bmat");
        write_bmat_v2(&none, &v2z).unwrap();
        let back = read_bmat(&v2z).unwrap();
        assert_eq!((back.n_rows(), back.n_cols()), (4, 0));

        // 1x1
        let one = BinaryDataset::new(1, 1, vec![1]).unwrap();
        let v2o = dir.join("e2.bmat");
        write_bmat_v2(&one, &v2o).unwrap();
        let back = read_bmat(&v2o).unwrap();
        assert_eq!(back.bytes(), &[1]);
    }

    #[test]
    fn v2_col_counts_match_dataset() {
        let ds = SynthSpec::new(200, 21).sparsity(0.85).seed(9).generate();
        let path = tmpdir().join("cnt.bmat");
        write_bmat_v2(&ds, &path).unwrap();
        let src = PackedFileSource::open(&path).unwrap();
        assert_eq!(src.all_col_counts(5).unwrap(), ds.col_counts());
    }

    #[test]
    fn load_dispatches_on_extension() {
        let ds = SynthSpec::new(4, 4).seed(4).generate();
        let dir = tmpdir();
        let c = dir.join("d.csv");
        let b = dir.join("d.bmat");
        write_csv(&ds, &c, false).unwrap();
        write_bmat(&ds, &b).unwrap();
        assert_eq!(load(&c).unwrap().bytes(), ds.bytes());
        assert_eq!(load(&b).unwrap().bytes(), ds.bytes());
        let b2 = dir.join("d2.bmat");
        write_bmat_v2(&ds, &b2).unwrap();
        assert_eq!(load(&b2).unwrap().bytes(), ds.bytes());
        assert!(load(&dir.join("d.xyz")).is_err());
    }
}
