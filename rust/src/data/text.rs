//! Bag-of-words binarization: documents x vocabulary presence matrix —
//! the NLP workload the paper's introduction cites. Includes a tiny
//! built-in corpus so examples run without external data.

use super::dataset::BinaryDataset;
use std::collections::BTreeMap;

/// Tokenize: lowercase alphanumeric words, length >= `min_len`.
pub fn tokenize(text: &str, min_len: usize) -> Vec<String> {
    text.split(|c: char| !c.is_alphanumeric())
        .filter(|w| w.len() >= min_len)
        .map(|w| w.to_ascii_lowercase())
        .collect()
}

/// Build a documents x vocabulary binary presence dataset.
///
/// Vocabulary keeps words appearing in at least `min_df` documents,
/// ordered by (descending document frequency, then lexicographic),
/// truncated to `max_vocab`.
pub fn binarize(docs: &[&str], min_df: usize, max_vocab: usize) -> BinaryDataset {
    let tokenized: Vec<Vec<String>> = docs.iter().map(|d| tokenize(d, 2)).collect();
    let mut df: BTreeMap<String, usize> = BTreeMap::new();
    for toks in &tokenized {
        let mut seen: Vec<&String> = toks.iter().collect();
        seen.sort();
        seen.dedup();
        for w in seen {
            *df.entry(w.clone()).or_insert(0) += 1;
        }
    }
    let mut vocab: Vec<(String, usize)> =
        df.into_iter().filter(|&(_, c)| c >= min_df).collect();
    vocab.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    vocab.truncate(max_vocab);
    let index: BTreeMap<&str, usize> =
        vocab.iter().enumerate().map(|(i, (w, _))| (w.as_str(), i)).collect();

    let (n, m) = (docs.len(), vocab.len());
    let mut data = vec![0u8; n * m];
    for (r, toks) in tokenized.iter().enumerate() {
        for w in toks {
            if let Some(&c) = index.get(w.as_str()) {
                data[r * m + c] = 1;
            }
        }
    }
    BinaryDataset::new(n, m, data)
        .expect("generator is valid")
        .with_names(vocab.into_iter().map(|(w, _)| w).collect())
        .expect("names sized")
}

/// A tiny built-in corpus (news-style snippets across three topics) so
/// the text example runs self-contained.
pub fn builtin_corpus() -> Vec<&'static str> {
    vec![
        "the central bank raised interest rates to fight inflation in the economy",
        "stock market investors worried about rising interest rates and inflation",
        "the bank announced new lending rates as inflation pressure continued",
        "economy shrank last quarter as markets reacted to central bank policy",
        "investors moved money from stocks to bonds as rates climbed higher",
        "the genome study identified gene variants linked to disease risk",
        "researchers sequenced the genome to find mutations causing the disease",
        "gene expression analysis revealed markers associated with cancer risk",
        "the mutation in this gene raises disease risk according to the study",
        "scientists mapped genetic variants across the genome in a large cohort",
        "the team won the championship game with a late goal in extra time",
        "players celebrated the victory after the final game of the season",
        "the coach praised the team defense after winning the championship",
        "a record crowd watched the game as the home team scored the winning goal",
        "the season ended with the team lifting the championship trophy",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizer_basics() {
        assert_eq!(tokenize("Hello, World! a", 2), vec!["hello", "world"]);
        assert_eq!(tokenize("", 2), Vec::<String>::new());
        assert_eq!(tokenize("x1 y2", 2), vec!["x1", "y2"]);
    }

    #[test]
    fn binarize_shapes_and_presence() {
        let docs = vec!["cat dog", "dog bird", "cat bird dog"];
        let ds = binarize(&docs, 1, 10);
        assert_eq!(ds.n_rows(), 3);
        assert_eq!(ds.n_cols(), 3); // cat, dog, bird
        let names = ds.names().unwrap().to_vec();
        let dog = names.iter().position(|w| w == "dog").unwrap();
        assert_eq!(ds.get(0, dog), 1);
        assert_eq!(ds.get(1, dog), 1);
        assert_eq!(ds.get(2, dog), 1);
        let cat = names.iter().position(|w| w == "cat").unwrap();
        assert_eq!(ds.get(1, cat), 0);
    }

    #[test]
    fn min_df_filters_rare_words() {
        let docs = vec!["common rare1", "common rare2", "common rare3"];
        let ds = binarize(&docs, 2, 10);
        assert_eq!(ds.n_cols(), 1);
        assert_eq!(ds.names().unwrap()[0], "common");
    }

    #[test]
    fn max_vocab_truncates() {
        let docs = vec!["aa bb cc dd", "aa bb cc dd", "aa bb cc dd"];
        let ds = binarize(&docs, 1, 2);
        assert_eq!(ds.n_cols(), 2);
    }

    #[test]
    fn builtin_corpus_binarizes() {
        let docs = builtin_corpus();
        let ds = binarize(&docs, 2, 100);
        assert_eq!(ds.n_rows(), 15);
        assert!(ds.n_cols() >= 10);
        assert!(ds.sparsity() > 0.5);
    }
}
