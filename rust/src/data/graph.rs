//! Network-science workload: binary adjacency matrices — the link-
//! prediction scenario the paper's introduction cites (MI between
//! adjacency columns measures neighborhood overlap between nodes).

use super::dataset::BinaryDataset;
use crate::util::rng::Rng;

/// A planted-partition (stochastic block model) random graph.
///
/// `k` communities of equal size; edge probability `p_in` within a
/// community, `p_out` across. Columns of the adjacency matrix belonging
/// to the same community share neighborhoods, so their pairwise MI is
/// high — ground truth the network example recovers.
#[derive(Clone, Debug)]
pub struct SbmSpec {
    pub n_nodes: usize,
    pub k: usize,
    pub p_in: f64,
    pub p_out: f64,
    pub seed: u64,
}

impl Default for SbmSpec {
    fn default() -> Self {
        SbmSpec { n_nodes: 120, k: 3, p_in: 0.4, p_out: 0.02, seed: 0 }
    }
}

/// Generated graph: adjacency as a dataset (rows = columns = nodes) and
/// the community of each node.
#[derive(Clone, Debug)]
pub struct SbmGraph {
    pub adjacency: BinaryDataset,
    pub community: Vec<usize>,
}

impl SbmSpec {
    pub fn generate(&self) -> SbmGraph {
        let n = self.n_nodes;
        let mut rng = Rng::new(self.seed);
        let community: Vec<usize> = (0..n).map(|i| i * self.k / n).collect();
        let mut data = vec![0u8; n * n];
        for i in 0..n {
            for j in (i + 1)..n {
                let p = if community[i] == community[j] { self.p_in } else { self.p_out };
                let edge = rng.bernoulli(p) as u8;
                data[i * n + j] = edge;
                data[j * n + i] = edge; // undirected: symmetric adjacency
            }
        }
        let adjacency = BinaryDataset::new(n, n, data)
            .expect("generator is valid")
            .with_names((0..n).map(|i| format!("node{i}")).collect())
            .expect("names sized");
        SbmGraph { adjacency, community }
    }
}

/// Erdos-Renyi random graph adjacency (no structure; null model).
pub fn erdos_renyi(n_nodes: usize, p: f64, seed: u64) -> BinaryDataset {
    let mut rng = Rng::new(seed);
    let mut data = vec![0u8; n_nodes * n_nodes];
    for i in 0..n_nodes {
        for j in (i + 1)..n_nodes {
            let edge = rng.bernoulli(p) as u8;
            data[i * n_nodes + j] = edge;
            data[j * n_nodes + i] = edge;
        }
    }
    BinaryDataset::new(n_nodes, n_nodes, data).expect("generator is valid")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sbm_is_symmetric_no_self_loops() {
        let g = SbmSpec::default().generate();
        let a = &g.adjacency;
        for i in 0..a.n_rows() {
            assert_eq!(a.get(i, i), 0);
            for j in 0..a.n_cols() {
                assert_eq!(a.get(i, j), a.get(j, i));
            }
        }
    }

    #[test]
    fn sbm_communities_are_balanced() {
        let g = SbmSpec { n_nodes: 90, k: 3, ..Default::default() }.generate();
        for c in 0..3 {
            let size = g.community.iter().filter(|&&x| x == c).count();
            assert_eq!(size, 30);
        }
    }

    #[test]
    fn sbm_in_density_exceeds_out_density() {
        let g = SbmSpec { n_nodes: 150, seed: 3, ..Default::default() }.generate();
        let a = &g.adjacency;
        let (mut ein, mut nin, mut eout, mut nout) = (0f64, 0f64, 0f64, 0f64);
        for i in 0..a.n_rows() {
            for j in (i + 1)..a.n_cols() {
                if g.community[i] == g.community[j] {
                    ein += a.get(i, j) as f64;
                    nin += 1.0;
                } else {
                    eout += a.get(i, j) as f64;
                    nout += 1.0;
                }
            }
        }
        assert!(ein / nin > 5.0 * (eout / nout));
    }

    #[test]
    fn erdos_renyi_density() {
        let a = erdos_renyi(200, 0.1, 1);
        let ones: usize = a.bytes().iter().map(|&b| b as usize).sum();
        let expected = 0.1 * (200.0 * 199.0); // directed cell count of undirected edges
        assert!((ones as f64 - expected).abs() / expected < 0.15);
    }
}
