//! Synthetic genomics workload: SNP/mutation presence-absence panels with
//! disease-associated marker groups — the feature-selection scenario the
//! paper's introduction motivates ("selecting genetic markers associated
//! with diseases").
//!
//! Model: each sample has a latent disease status; a small set of causal
//! markers is enriched in cases (presence probability `p_case` vs the
//! background `p_bg`), and each causal marker drags along a few linked
//! markers (linkage disequilibrium), giving the MI matrix a known block
//! structure the examples can recover.

use super::dataset::BinaryDataset;
use crate::util::rng::Rng;

/// Specification for a synthetic SNP panel.
#[derive(Clone, Debug)]
pub struct GenomicsSpec {
    pub n_samples: usize,
    pub n_markers: usize,
    /// Number of causal markers (placed at the start of the panel).
    pub n_causal: usize,
    /// Linked (LD) markers per causal marker, placed right after it.
    pub ld_per_causal: usize,
    /// Disease prevalence among samples.
    pub prevalence: f64,
    /// Marker presence probability in cases / background.
    pub p_case: f64,
    pub p_bg: f64,
    /// Probability an LD marker copies its causal partner (else background).
    pub ld_strength: f64,
    pub seed: u64,
}

impl Default for GenomicsSpec {
    fn default() -> Self {
        GenomicsSpec {
            n_samples: 2000,
            n_markers: 200,
            n_causal: 5,
            ld_per_causal: 3,
            prevalence: 0.3,
            p_case: 0.6,
            p_bg: 0.05,
            ld_strength: 0.9,
            seed: 0,
        }
    }
}

/// A generated panel plus its ground truth.
#[derive(Clone, Debug)]
pub struct GenomicsPanel {
    pub dataset: BinaryDataset,
    /// Disease status per sample (not part of the marker matrix).
    pub disease: Vec<u8>,
    /// Indices of causal markers.
    pub causal: Vec<usize>,
    /// (causal, linked) pairs that should show high MI.
    pub ld_pairs: Vec<(usize, usize)>,
}

impl GenomicsSpec {
    pub fn generate(&self) -> GenomicsPanel {
        assert!(
            self.n_causal * (1 + self.ld_per_causal) <= self.n_markers,
            "causal+LD markers exceed panel size"
        );
        let mut rng = Rng::new(self.seed);
        let n = self.n_samples;
        let m = self.n_markers;
        let disease: Vec<u8> = (0..n).map(|_| rng.bernoulli(self.prevalence) as u8).collect();
        let mut data = vec![0u8; n * m];
        let mut causal = Vec::new();
        let mut ld_pairs = Vec::new();

        let block = 1 + self.ld_per_causal;
        for cidx in 0..self.n_causal {
            let c_col = cidx * block;
            causal.push(c_col);
            for r in 0..n {
                let p = if disease[r] == 1 { self.p_case } else { self.p_bg };
                data[r * m + c_col] = rng.bernoulli(p) as u8;
            }
            for l in 1..=self.ld_per_causal {
                let l_col = c_col + l;
                ld_pairs.push((c_col, l_col));
                for r in 0..n {
                    data[r * m + l_col] = if rng.bernoulli(self.ld_strength) {
                        data[r * m + c_col]
                    } else {
                        rng.bernoulli(self.p_bg) as u8
                    };
                }
            }
        }
        // background markers
        for col in self.n_causal * block..m {
            for r in 0..n {
                data[r * m + col] = rng.bernoulli(self.p_bg) as u8;
            }
        }
        let names = (0..m)
            .map(|c| {
                if causal.contains(&c) {
                    format!("rsC{c}")
                } else if ld_pairs.iter().any(|&(_, l)| l == c) {
                    format!("rsL{c}")
                } else {
                    format!("rs{c}")
                }
            })
            .collect();
        let dataset = BinaryDataset::new(n, m, data)
            .expect("generator is valid")
            .with_names(names)
            .expect("names sized");
        GenomicsPanel { dataset, disease, causal, ld_pairs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mi::counts::mi_from_counts_u64;

    fn pair_mi(ds: &BinaryDataset, a: usize, b: usize) -> f64 {
        let n = ds.n_rows() as u64;
        let mut n11 = 0u64;
        let mut n10 = 0u64;
        let mut n01 = 0u64;
        for r in 0..ds.n_rows() {
            match (ds.get(r, a), ds.get(r, b)) {
                (1, 1) => n11 += 1,
                (1, 0) => n10 += 1,
                (0, 1) => n01 += 1,
                _ => {}
            }
        }
        mi_from_counts_u64(n11, n10, n01, n - n11 - n10 - n01, n)
    }

    #[test]
    fn panel_shape_and_truth() {
        let panel = GenomicsSpec::default().generate();
        assert_eq!(panel.dataset.n_rows(), 2000);
        assert_eq!(panel.dataset.n_cols(), 200);
        assert_eq!(panel.causal.len(), 5);
        assert_eq!(panel.ld_pairs.len(), 15);
        assert_eq!(panel.disease.len(), 2000);
    }

    #[test]
    fn ld_pairs_have_high_mi_vs_background() {
        let panel = GenomicsSpec { seed: 11, ..Default::default() }.generate();
        let (c, l) = panel.ld_pairs[0];
        let signal = pair_mi(&panel.dataset, c, l);
        // background pair: two far-apart background columns
        let bg = pair_mi(&panel.dataset, 150, 199);
        assert!(
            signal > 10.0 * bg.max(1e-6),
            "signal {signal} not >> background {bg}"
        );
    }

    #[test]
    fn causal_markers_enriched_in_cases() {
        let panel = GenomicsSpec { seed: 5, ..Default::default() }.generate();
        let c = panel.causal[0];
        let (mut case_hits, mut case_n, mut ctrl_hits, mut ctrl_n) = (0f64, 0f64, 0f64, 0f64);
        for r in 0..panel.dataset.n_rows() {
            if panel.disease[r] == 1 {
                case_hits += panel.dataset.get(r, c) as f64;
                case_n += 1.0;
            } else {
                ctrl_hits += panel.dataset.get(r, c) as f64;
                ctrl_n += 1.0;
            }
        }
        assert!(case_hits / case_n > 3.0 * (ctrl_hits / ctrl_n));
    }
}
