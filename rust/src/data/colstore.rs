//! Column sources: the ingestion abstraction the blockwise engine
//! consumes, so the *input* side of a run no longer has to be resident
//! in RAM.
//!
//! Since PR 1 the output side is matrix-free (`MiSink` keeps peak RAM
//! at `budget + sink state` for any m), but every execution path still
//! began by materializing the whole dataset as a `Vec<u8>` with one
//! byte per cell — ~100 GB for a 1M x 100k panel before a single Gram.
//! A [`ColumnSource`] closes that gap: it serves bit-packed *column
//! blocks* on demand, so a block task only ever touches the two blocks
//! it is computing, wherever the bits actually live:
//!
//! * [`InMemorySource`] — wraps a packed [`BinaryDataset`] (one up-front
//!   pack, block fetches are column-range memcpys). Identical behavior
//!   and cost profile to the historical whole-dataset path.
//! * [`PackedFileSource`] — positioned-reads blocks out of a
//!   column-major bit-packed `.bmat` v2 file (see `crate::data::io`),
//!   8x smaller than v1's byte cells; a block read touches only the
//!   requested columns' words, so peak RAM is `task_bytes(n, b)`
//!   regardless of how large the file is. Reads carry no shared file
//!   cursor (`pread`-style), so workers fetch concurrently, and
//!   per-source [`IoStats`] feed the engine's read-amplification
//!   reporting.
//! * [`BinaryDataset`] itself implements the trait (packing the
//!   requested block per fetch) so existing `&BinaryDataset` call sites
//!   coerce to `&dyn ColumnSource` unchanged — convenient for tests and
//!   one-shot monolithic plans; repeated-fetch paths should prefer
//!   [`InMemorySource`] (one up-front pack) or run behind the
//!   substrate cache (`crate::coordinator::blockcache`), which
//!   memoizes each block's constructed substrate.
//!
//! Every implementation serves *identical bits* for identical inputs —
//! the round-trip property tested in `rust/tests/colstore.rs` — so the
//! engine's exactness guarantee is untouched by where the data lives.

use super::dataset::BinaryDataset;
use super::io;
use crate::linalg::bitmat::BitMatrix;
use crate::util::error::{Error, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Cumulative read-side counters of an instrumented source (see
/// [`ColumnSource::io_stats`]). Take a snapshot before a run and
/// [`IoStats::since`] after it for per-run numbers; dividing
/// `bytes_read` by the source's payload size gives the run's
/// *read-amplification factor* — 1.0 means each block was read exactly
/// once, the floor the block cache aims for.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct IoStats {
    /// Payload bytes read from storage.
    pub bytes_read: u64,
    /// Read calls issued.
    pub reads: u64,
    /// Wall time spent inside read calls.
    pub read_secs: f64,
}

impl IoStats {
    /// Counters accumulated since the `earlier` snapshot.
    pub fn since(&self, earlier: &IoStats) -> IoStats {
        IoStats {
            bytes_read: self.bytes_read.saturating_sub(earlier.bytes_read),
            reads: self.reads.saturating_sub(earlier.reads),
            read_secs: (self.read_secs - earlier.read_secs).max(0.0),
        }
    }
}

/// A provider of bit-packed column blocks — the blockwise engine's
/// input abstraction ([`crate::coordinator::executor::NativeProvider`]
/// builds each task's Gram substrate from these blocks on demand).
pub trait ColumnSource: Send + Sync {
    fn n_rows(&self) -> usize;

    fn n_cols(&self) -> usize;

    /// Column names, when the source carries them.
    fn names(&self) -> Option<&[String]>;

    /// Name of column `c` (falls back to `col{c}`).
    fn col_name(&self, c: usize) -> String {
        match self.names() {
            Some(ns) => ns[c].clone(),
            None => format!("col{c}"),
        }
    }

    /// The contiguous column block `[start, start + len)` as a
    /// bit-packed matrix of all `n_rows` rows.
    fn col_block(&self, start: usize, len: usize) -> Result<BitMatrix>;

    /// Ones count per column of the block `[start, start + len)` —
    /// cheap relative to a Gram (one pass over the block's words).
    fn col_counts_block(&self, start: usize, len: usize) -> Result<Vec<u64>> {
        Ok(self.col_block(start, len)?.col_counts())
    }

    /// Does this source serve blocks from beyond-RAM storage? When
    /// true, planners must prefer bounded blockwise plans over the
    /// historical monolithic single-task plan — a monolithic task's one
    /// `col_block(0, n_cols)` fetch would materialize the entire
    /// source, defeating the point of streaming it. Default false
    /// (in-memory sources, where monolithic is cheapest).
    fn out_of_core(&self) -> bool {
        false
    }

    /// Cumulative read counters, when the source is instrumented.
    /// `None` (the default) means reads are free or untracked —
    /// in-memory sources. [`PackedFileSource`] reports real disk
    /// traffic here, which is what the executor's read-amplification
    /// reporting is built on.
    fn io_stats(&self) -> Option<IoStats> {
        None
    }

    /// Total payload bytes the source holds (the denominator of the
    /// read-amplification factor), when known. `None` for sources
    /// without a meaningful on-storage payload.
    fn payload_bytes_hint(&self) -> Option<u64> {
        None
    }

    /// Content fingerprint of the block `[start, start + len)` — the
    /// tile-cache key half for this block
    /// ([`crate::coordinator::tilecache`]). Defined as FNV-1a over the
    /// block's packed words with the shape mixed in, so every source
    /// serving identical bits (the `colstore.rs` round-trip property)
    /// reports identical fingerprints. The default fetches the block
    /// and hashes it; sources with real I/O should memoize
    /// ([`PackedFileSource`] does), keeping the cost one extra read
    /// per block per process.
    fn block_fingerprint(&self, start: usize, len: usize) -> Result<u64> {
        let block = self.col_block(start, len)?;
        Ok(crate::coordinator::tilecache::fingerprint_words(
            self.n_rows(),
            len,
            block.words(),
        ))
    }

    /// All column counts, fetched in `chunk_cols`-sized blocks so no
    /// more than one block of columns is ever resident (`0` = one fetch
    /// for everything).
    fn all_col_counts(&self, chunk_cols: usize) -> Result<Vec<u64>> {
        let m = self.n_cols();
        let chunk = if chunk_cols == 0 { m.max(1) } else { chunk_cols };
        let mut out = Vec::with_capacity(m);
        let mut start = 0;
        while start < m {
            let len = chunk.min(m - start);
            out.extend(self.col_counts_block(start, len)?);
            start += len;
        }
        Ok(out)
    }
}

fn block_bounds(start: usize, len: usize, n_cols: usize) -> Result<()> {
    match start.checked_add(len) {
        Some(end) if end <= n_cols => Ok(()),
        _ => Err(Error::Shape(format!(
            "col_block [{start}, {start}+{len}) out of {n_cols} cols"
        ))),
    }
}

/// In-memory column source: packs the dataset into a [`BitMatrix`] once
/// at construction, after which block fetches are column-range memcpys
/// — the same cost profile the whole-dataset execution path always had
/// (zero behavior change, property-tested against [`PackedFileSource`]
/// in `rust/tests/colstore.rs`).
pub struct InMemorySource {
    bits: BitMatrix,
    names: Option<Vec<String>>,
}

impl InMemorySource {
    pub fn new(ds: &BinaryDataset) -> Self {
        InMemorySource {
            bits: ds.to_bitmatrix(),
            names: ds.names().map(<[String]>::to_vec),
        }
    }
}

impl ColumnSource for InMemorySource {
    fn n_rows(&self) -> usize {
        self.bits.rows()
    }

    fn n_cols(&self) -> usize {
        self.bits.cols()
    }

    fn names(&self) -> Option<&[String]> {
        self.names.as_deref()
    }

    fn col_block(&self, start: usize, len: usize) -> Result<BitMatrix> {
        block_bounds(start, len, self.bits.cols())?;
        self.bits.col_block(start, len)
    }
}

/// `BinaryDataset` as a column source: packs the requested block from
/// the row-major bytes on every fetch — an `O(n·b)` bit-twiddling pass
/// per fetch, not a memcpy. Fine for tests and one-shot monolithic
/// plans; blockwise runs that fetch each block `O(n_blocks)` times
/// must wrap the dataset in [`InMemorySource`] instead (one up-front
/// pack — `compute_measure_with` and the job service both do) or
/// attach the substrate cache (`crate::coordinator::blockcache`),
/// which memoizes the packed block after the first fetch. Note the
/// *inherent* `BinaryDataset::col_block` returns a `BinaryDataset` and
/// takes precedence under method syntax; this trait impl is reached
/// through `&dyn ColumnSource`.
impl ColumnSource for BinaryDataset {
    fn n_rows(&self) -> usize {
        BinaryDataset::n_rows(self)
    }

    fn n_cols(&self) -> usize {
        BinaryDataset::n_cols(self)
    }

    fn names(&self) -> Option<&[String]> {
        BinaryDataset::names(self)
    }

    fn col_block(&self, start: usize, len: usize) -> Result<BitMatrix> {
        let m = BinaryDataset::n_cols(self);
        block_bounds(start, len, m)?;
        let rows = BinaryDataset::n_rows(self);
        let wpc = rows.div_ceil(64);
        let mut data = vec![0u64; wpc * len];
        let bytes = self.bytes();
        for r in 0..rows {
            let row = &bytes[r * m + start..r * m + start + len];
            let (word, bit) = (r / 64, r % 64);
            for (c, &v) in row.iter().enumerate() {
                if v != 0 {
                    data[c * wpc + word] |= 1u64 << bit;
                }
            }
        }
        BitMatrix::from_packed_cols(rows, len, data)
    }

    fn col_counts_block(&self, start: usize, len: usize) -> Result<Vec<u64>> {
        let m = BinaryDataset::n_cols(self);
        block_bounds(start, len, m)?;
        let mut counts = vec![0u64; len];
        let bytes = self.bytes();
        for r in 0..BinaryDataset::n_rows(self) {
            let row = &bytes[r * m + start..r * m + start + len];
            for (cnt, &v) in counts.iter_mut().zip(row) {
                *cnt += v as u64;
            }
        }
        Ok(counts)
    }
}

/// A file read at explicit offsets with no shared cursor, so
/// concurrent block reads never serialize on a seek lock: `pread` on
/// Unix, `seek_read` on Windows, and a `Mutex` + seek fallback
/// elsewhere. The shared-cursor `Mutex<File>` this replaces was the
/// scaling limit of multi-worker streaming runs — every worker's read
/// queued behind one file position.
struct PositionedFile {
    #[cfg(any(unix, windows))]
    file: std::fs::File,
    #[cfg(not(any(unix, windows)))]
    file: std::sync::Mutex<std::fs::File>,
}

impl PositionedFile {
    fn new(file: std::fs::File) -> Self {
        #[cfg(any(unix, windows))]
        {
            PositionedFile { file }
        }
        #[cfg(not(any(unix, windows)))]
        {
            PositionedFile { file: std::sync::Mutex::new(file) }
        }
    }

    /// Fill `buf` from `offset`; does not touch any file cursor on
    /// unix/windows, so it is safe to call from many threads at once.
    fn read_exact_at(&self, buf: &mut [u8], offset: u64) -> std::io::Result<()> {
        #[cfg(unix)]
        {
            std::os::unix::fs::FileExt::read_exact_at(&self.file, buf, offset)
        }
        #[cfg(windows)]
        {
            use std::os::windows::fs::FileExt;
            let mut pos = 0usize;
            while pos < buf.len() {
                match self.file.seek_read(&mut buf[pos..], offset + pos as u64) {
                    Ok(0) => {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::UnexpectedEof,
                            "failed to fill whole buffer",
                        ))
                    }
                    Ok(n) => pos += n,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => return Err(e),
                }
            }
            Ok(())
        }
        #[cfg(not(any(unix, windows)))]
        {
            use std::io::{Read, Seek, SeekFrom};
            let mut f = self.file.lock().unwrap();
            f.seek(SeekFrom::Start(offset))?;
            f.read_exact(buf)
        }
    }
}

/// Streaming column source over a `.bmat` v2 file: column-major
/// bit-packed 64-bit words, so a block fetch is one contiguous
/// positioned read of exactly the requested columns' words — no
/// row-height pass, no unpack/repack. Peak RAM for a fetch is
/// `len * ⌈n/64⌉ * 8` bytes, independent of the file's total size.
///
/// Reads use positioned I/O ([`PositionedFile`]) with no shared file
/// cursor, so concurrent workers and the prefetch stage read in
/// parallel; per-source counters ([`ColumnSource::io_stats`]) track
/// bytes, read calls, and read wall time for the engine's
/// read-amplification reporting.
pub struct PackedFileSource {
    file: PositionedFile,
    path: PathBuf,
    n_rows: usize,
    n_cols: usize,
    words_per_col: usize,
    payload_off: u64,
    names: Option<Vec<String>>,
    bytes_read: AtomicU64,
    reads: AtomicU64,
    read_nanos: AtomicU64,
    /// Memoized block fingerprints, so tile-cache keying costs one
    /// extra read per block per process, not one per task.
    fingerprints: std::sync::Mutex<std::collections::HashMap<(usize, usize), u64>>,
}

impl PackedFileSource {
    /// Open and validate a `.bmat` v2 file (magic, header arithmetic,
    /// exact payload length). The payload itself stays on disk.
    pub fn open(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)?;
        let header = io::read_bmat2_header(&mut f, path)?;
        let words_per_col = header.n_rows.div_ceil(64);
        let payload_words = words_per_col
            .checked_mul(header.n_cols)
            .ok_or_else(|| Error::Parse("v2 header: dimension overflow".into()))?;
        let expect = (payload_words as u64)
            .checked_mul(8)
            .and_then(|b| b.checked_add(header.payload_off))
            .ok_or_else(|| Error::Parse("v2 header: payload size overflow".into()))?;
        let file_len = f.metadata()?.len();
        if file_len != expect {
            return Err(Error::Parse(format!(
                "{}: file is {file_len} bytes but the v2 header implies {expect} \
                 (truncated or trailing bytes)",
                path.display()
            )));
        }
        Ok(PackedFileSource {
            file: PositionedFile::new(f),
            path: path.to_path_buf(),
            n_rows: header.n_rows,
            n_cols: header.n_cols,
            words_per_col,
            payload_off: header.payload_off,
            names: header.names,
            bytes_read: AtomicU64::new(0),
            reads: AtomicU64::new(0),
            read_nanos: AtomicU64::new(0),
            fingerprints: std::sync::Mutex::new(std::collections::HashMap::new()),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Bytes of packed payload on disk (`n_cols * ⌈n_rows/64⌉ * 8`).
    pub fn payload_bytes(&self) -> u64 {
        (self.words_per_col * self.n_cols) as u64 * 8
    }

    /// Fully materialize as an in-memory [`BinaryDataset`] (the
    /// backward-compatible `io::load` path for v2 files).
    pub fn to_dataset(&self) -> Result<BinaryDataset> {
        let bits = self.col_block(0, self.n_cols)?;
        let ds = BinaryDataset::new(self.n_rows, self.n_cols, bits.to_row_major_bytes())?;
        match &self.names {
            Some(ns) => ds.with_names(ns.clone()),
            None => Ok(ds),
        }
    }
}

impl ColumnSource for PackedFileSource {
    fn n_rows(&self) -> usize {
        self.n_rows
    }

    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn names(&self) -> Option<&[String]> {
        self.names.as_deref()
    }

    fn out_of_core(&self) -> bool {
        true
    }

    fn col_block(&self, start: usize, len: usize) -> Result<BitMatrix> {
        block_bounds(start, len, self.n_cols)?;
        let words = len * self.words_per_col;
        let mut data = vec![0u64; words];
        // read straight into the u64 buffer's byte view — no
        // intermediate Vec<u8>, no second copy. Viewing u64 storage as
        // bytes is always alignment-safe (u64 align >= u8), and for
        // words == 0 the dangling pointer is valid for a length of 0.
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr().cast::<u8>(), words * 8)
        };
        let off = self.payload_off + (start * self.words_per_col) as u64 * 8;
        let t0 = Instant::now();
        self.file.read_exact_at(bytes, off)?;
        self.read_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.bytes_read.fetch_add((words * 8) as u64, Ordering::Relaxed);
        self.reads.fetch_add(1, Ordering::Relaxed);
        // the file stores little-endian words; on LE hosts the bytes
        // already are the words, elsewhere fix them up in place
        if cfg!(target_endian = "big") {
            for w in data.iter_mut() {
                *w = u64::from_le(*w);
            }
        }
        BitMatrix::from_packed_cols(self.n_rows, len, data)
    }

    fn io_stats(&self) -> Option<IoStats> {
        Some(IoStats {
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            reads: self.reads.load(Ordering::Relaxed),
            read_secs: self.read_nanos.load(Ordering::Relaxed) as f64 / 1e9,
        })
    }

    fn payload_bytes_hint(&self) -> Option<u64> {
        Some(self.payload_bytes())
    }

    fn block_fingerprint(&self, start: usize, len: usize) -> Result<u64> {
        if let Some(&fp) = self.fingerprints.lock().unwrap().get(&(start, len)) {
            return Ok(fp);
        }
        let block = self.col_block(start, len)?;
        let fp =
            crate::coordinator::tilecache::fingerprint_words(self.n_rows, len, block.words());
        self.fingerprints.lock().unwrap().insert((start, len), fp);
        Ok(fp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    fn tmpdir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("bulkmi-colstore-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn in_memory_source_matches_dataset_blocks() {
        let ds = SynthSpec::new(133, 11)
            .sparsity(0.7)
            .seed(5)
            .generate()
            .with_names((0..11).map(|c| format!("v{c}")).collect())
            .unwrap();
        let src = InMemorySource::new(&ds);
        assert_eq!((src.n_rows(), src.n_cols()), (133, 11));
        assert_eq!(src.names().unwrap()[3], "v3");
        assert_eq!(src.col_name(3), "v3");
        for (start, len) in [(0usize, 11usize), (3, 4), (10, 1), (5, 0)] {
            let a = src.col_block(start, len).unwrap();
            let b = ColumnSource::col_block(&ds, start, len).unwrap();
            assert_eq!(a.words(), b.words(), "[{start}, {start}+{len})");
        }
        assert_eq!(src.all_col_counts(4).unwrap(), ds.col_counts());
        assert!(src.col_block(8, 4).is_err());
        assert!(ColumnSource::col_block(&ds, 8, 4).is_err());
    }

    #[test]
    fn dataset_source_counts_match() {
        let ds = SynthSpec::new(200, 9).sparsity(0.5).seed(7).generate();
        let counts = ColumnSource::col_counts_block(&ds, 2, 5).unwrap();
        assert_eq!(counts, ds.col_counts()[2..7]);
        assert_eq!(ds.all_col_counts(0).unwrap(), ds.col_counts());
    }

    #[test]
    fn packed_file_source_round_trips() {
        let ds = SynthSpec::new(517, 13).sparsity(0.8).seed(9).generate();
        let path = tmpdir().join("src.bmat");
        io::write_bmat_v2(&ds, &path).unwrap();
        let src = PackedFileSource::open(&path).unwrap();
        assert_eq!((src.n_rows(), src.n_cols()), (517, 13));
        assert!(src.names().is_none());
        let mem = InMemorySource::new(&ds);
        assert!(src.out_of_core(), "file-backed sources must ask for blockwise plans");
        assert!(!mem.out_of_core());
        for (start, len) in [(0usize, 13usize), (0, 5), (9, 4), (12, 1)] {
            assert_eq!(
                src.col_block(start, len).unwrap().words(),
                mem.col_block(start, len).unwrap().words(),
                "[{start}, {start}+{len})"
            );
        }
        assert_eq!(src.all_col_counts(3).unwrap(), ds.col_counts());
        assert_eq!(src.to_dataset().unwrap().bytes(), ds.bytes());
        assert!(src.col_block(13, 1).is_err());
    }

    #[test]
    fn block_fingerprints_agree_across_sources_and_memoize() {
        let ds = SynthSpec::new(201, 9).sparsity(0.6).seed(13).generate();
        let path = tmpdir().join("fps.bmat");
        io::write_bmat_v2(&ds, &path).unwrap();
        let file = PackedFileSource::open(&path).unwrap();
        let mem = InMemorySource::new(&ds);
        for (start, len) in [(0usize, 9usize), (0, 4), (4, 4), (8, 1)] {
            let a = file.block_fingerprint(start, len).unwrap();
            let b = mem.block_fingerprint(start, len).unwrap();
            let c = ColumnSource::block_fingerprint(&ds, start, len).unwrap();
            assert_eq!(a, b, "[{start}, {start}+{len})");
            assert_eq!(a, c, "[{start}, {start}+{len})");
        }
        assert_ne!(
            file.block_fingerprint(0, 4).unwrap(),
            file.block_fingerprint(4, 4).unwrap(),
            "distinct content must fingerprint differently"
        );
        // memoized: repeating a fingerprint issues no new read
        let before = file.io_stats().unwrap();
        file.block_fingerprint(0, 4).unwrap();
        assert_eq!(file.io_stats().unwrap().since(&before).reads, 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn packed_file_source_accounts_bytes_read() {
        let ds = SynthSpec::new(130, 8).sparsity(0.5).seed(11).generate();
        let path = tmpdir().join("iostats.bmat");
        io::write_bmat_v2(&ds, &path).unwrap();
        let src = PackedFileSource::open(&path).unwrap();
        assert!(InMemorySource::new(&ds).io_stats().is_none());
        let before = src.io_stats().unwrap();
        assert_eq!(before, IoStats::default());
        // 130 rows -> 3 words per column, 8 bytes each
        src.col_block(2, 4).unwrap();
        src.col_block(0, 8).unwrap();
        let d = src.io_stats().unwrap().since(&before);
        assert_eq!(d.reads, 2);
        assert_eq!(d.bytes_read, (4 + 8) * 3 * 8);
        assert_eq!(src.payload_bytes_hint(), Some(8 * 3 * 8));
        std::fs::remove_file(&path).ok();
    }
}
