//! Synthetic dataset generation matching the paper's experimental setup:
//! Bernoulli binary matrices with controlled sparsity, optionally with
//! *planted* dependent column pairs so that correctness checks and the
//! examples have known signal to find.

use super::dataset::BinaryDataset;
use crate::util::rng::Rng;

/// Builder for sparsity-controlled random binary datasets.
///
/// `sparsity` is the fraction of ZEROS, matching the paper's usage
/// ("datasets of identical sparsity (90%)"): density = 1 - sparsity.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    n_rows: usize,
    n_cols: usize,
    sparsity: f64,
    seed: u64,
    planted_pairs: Vec<PlantedPair>,
}

/// A planted dependency: column `b` copies column `a` and then each cell
/// is flipped with probability `noise` — MI(a, b) decreases smoothly with
/// noise and is ~H(a) at noise = 0.
#[derive(Clone, Copy, Debug)]
pub struct PlantedPair {
    pub a: usize,
    pub b: usize,
    pub noise: f64,
}

impl SynthSpec {
    pub fn new(n_rows: usize, n_cols: usize) -> Self {
        SynthSpec { n_rows, n_cols, sparsity: 0.9, seed: 0, planted_pairs: Vec::new() }
    }

    /// Fraction of zeros (paper default: 0.9).
    pub fn sparsity(mut self, s: f64) -> Self {
        assert!((0.0..=1.0).contains(&s), "sparsity must be in [0,1]");
        self.sparsity = s;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Plant a dependent pair (b becomes a noisy copy of a).
    pub fn plant(mut self, a: usize, b: usize, noise: f64) -> Self {
        assert!(a < self.n_cols && b < self.n_cols && a != b);
        self.planted_pairs.push(PlantedPair { a, b, noise });
        self
    }

    /// Generate the dataset.
    pub fn generate(&self) -> BinaryDataset {
        let mut rng = Rng::new(self.seed);
        let density = 1.0 - self.sparsity;
        let mut data = vec![0u8; self.n_rows * self.n_cols];
        for cell in data.iter_mut() {
            *cell = rng.bernoulli(density) as u8;
        }
        for pp in &self.planted_pairs {
            for r in 0..self.n_rows {
                let src = data[r * self.n_cols + pp.a];
                let flip = rng.bernoulli(pp.noise) as u8;
                data[r * self.n_cols + pp.b] = src ^ flip;
            }
        }
        BinaryDataset::new(self.n_rows, self.n_cols, data).expect("generator is valid")
    }
}

/// The paper's Table-1 dataset shapes: (rows, cols) at 90% sparsity.
pub const TABLE1_SHAPES: [(usize, usize); 3] = [(1000, 100), (100_000, 100), (100_000, 1000)];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_binary() {
        let ds = SynthSpec::new(100, 20).seed(1).generate();
        assert_eq!((ds.n_rows(), ds.n_cols()), (100, 20));
        assert!(ds.bytes().iter().all(|&b| b <= 1));
    }

    #[test]
    fn sparsity_is_controlled() {
        for &s in &[0.5, 0.9, 0.99] {
            let ds = SynthSpec::new(20_000, 10).sparsity(s).seed(2).generate();
            assert!(
                (ds.sparsity() - s).abs() < 0.01,
                "requested {s}, got {}",
                ds.sparsity()
            );
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = SynthSpec::new(50, 5).seed(7).generate();
        let b = SynthSpec::new(50, 5).seed(7).generate();
        assert_eq!(a.bytes(), b.bytes());
        let c = SynthSpec::new(50, 5).seed(8).generate();
        assert_ne!(a.bytes(), c.bytes());
    }

    #[test]
    fn planted_pair_is_correlated() {
        let ds = SynthSpec::new(5000, 6).sparsity(0.5).seed(3).plant(0, 5, 0.0).generate();
        // zero noise: exact copy
        for r in 0..ds.n_rows() {
            assert_eq!(ds.get(r, 0), ds.get(r, 5));
        }
        let noisy = SynthSpec::new(5000, 6).sparsity(0.5).seed(3).plant(0, 5, 0.2).generate();
        let agree = (0..noisy.n_rows())
            .filter(|&r| noisy.get(r, 0) == noisy.get(r, 5))
            .count() as f64
            / noisy.n_rows() as f64;
        assert!(agree > 0.75 && agree < 0.85, "agreement {agree}");
    }
}
