//! The core in-memory dataset type shared by every backend.

use crate::linalg::bitmat::BitMatrix;
use crate::linalg::csr::CsrMatrix;
use crate::linalg::dense::Mat32;
use crate::util::error::{Error, Result};

/// An n_rows x n_cols binary dataset, row-major, one byte per cell
/// (0 or 1). Columns may carry names (genomics markers, vocabulary...).
#[derive(Clone, Debug)]
pub struct BinaryDataset {
    n_rows: usize,
    n_cols: usize,
    data: Vec<u8>,
    names: Option<Vec<String>>,
}

impl BinaryDataset {
    /// Build from a row-major buffer of 0/1 bytes.
    pub fn new(n_rows: usize, n_cols: usize, data: Vec<u8>) -> Result<Self> {
        if data.len() != n_rows * n_cols {
            return Err(Error::Shape(format!(
                "buffer length {} != {n_rows}x{n_cols}",
                data.len()
            )));
        }
        if let Some(bad) = data.iter().position(|&b| b > 1) {
            return Err(Error::Parse(format!(
                "non-binary value {} at cell {bad}",
                data[bad]
            )));
        }
        Ok(BinaryDataset { n_rows, n_cols, data, names: None })
    }

    /// Attach column names (length must equal n_cols).
    pub fn with_names(mut self, names: Vec<String>) -> Result<Self> {
        if names.len() != self.n_cols {
            return Err(Error::Shape(format!(
                "{} names for {} columns",
                names.len(),
                self.n_cols
            )));
        }
        self.names = Some(names);
        Ok(self)
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    pub fn names(&self) -> Option<&[String]> {
        self.names.as_deref()
    }

    /// Name of column `c` (falls back to "col{c}").
    pub fn col_name(&self, c: usize) -> String {
        match &self.names {
            Some(ns) => ns[c].clone(),
            None => format!("col{c}"),
        }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> u8 {
        debug_assert!(r < self.n_rows && c < self.n_cols);
        self.data[r * self.n_cols + c]
    }

    /// Raw row-major bytes.
    pub fn bytes(&self) -> &[u8] {
        &self.data
    }

    /// One row as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[u8] {
        &self.data[r * self.n_cols..(r + 1) * self.n_cols]
    }

    /// Fraction of zero cells.
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let ones: usize = self.data.iter().map(|&b| b as usize).sum();
        1.0 - ones as f64 / self.data.len() as f64
    }

    /// Count of ones per column.
    pub fn col_counts(&self) -> Vec<u64> {
        let mut counts = vec![0u64; self.n_cols];
        for r in 0..self.n_rows {
            for (cnt, &v) in counts.iter_mut().zip(self.row(r)) {
                *cnt += v as u64;
            }
        }
        counts
    }

    /// Dense f32 view (what the NumPy/XLA-style backends consume).
    pub fn to_mat32(&self) -> Mat32 {
        let data = self.data.iter().map(|&b| b as f32).collect();
        Mat32::from_vec(self.n_rows, self.n_cols, data).expect("shape consistent")
    }

    /// Bit-packed view.
    pub fn to_bitmatrix(&self) -> BitMatrix {
        BitMatrix::from_row_major(self.n_rows, self.n_cols, &self.data)
            .expect("shape consistent")
    }

    /// CSR sparse view.
    pub fn to_csr(&self) -> CsrMatrix {
        CsrMatrix::from_row_major(self.n_rows, self.n_cols, &self.data)
            .expect("shape consistent")
    }

    /// Contiguous column block `[start, start+len)` as a new dataset.
    pub fn col_block(&self, start: usize, len: usize) -> Result<BinaryDataset> {
        if start + len > self.n_cols {
            return Err(Error::Shape(format!(
                "col_block [{start}, {}) out of {} cols",
                start + len,
                self.n_cols
            )));
        }
        let mut data = Vec::with_capacity(self.n_rows * len);
        for r in 0..self.n_rows {
            data.extend_from_slice(&self.row(r)[start..start + len]);
        }
        let names = self.names.as_ref().map(|ns| ns[start..start + len].to_vec());
        Ok(BinaryDataset { n_rows: self.n_rows, n_cols: len, data, names })
    }

    /// Gather of arbitrary columns (in `idx` order) as a new dataset
    /// (column subsetting for feature selection and sampling; the
    /// backend autotuner's probe uses the same stride-gather, fused
    /// with its row cap).
    pub fn select_cols(&self, idx: &[usize]) -> Result<BinaryDataset> {
        if let Some(&bad) = idx.iter().find(|&&c| c >= self.n_cols) {
            return Err(Error::Shape(format!(
                "select_cols: column {bad} out of {} cols",
                self.n_cols
            )));
        }
        let mut data = Vec::with_capacity(self.n_rows * idx.len());
        for r in 0..self.n_rows {
            let row = self.row(r);
            data.extend(idx.iter().map(|&c| row[c]));
        }
        let names = self
            .names
            .as_ref()
            .map(|ns| idx.iter().map(|&c| ns[c].clone()).collect());
        Ok(BinaryDataset { n_rows: self.n_rows, n_cols: idx.len(), data, names })
    }

    /// Contiguous row chunk `[start, start+len)` as a new dataset
    /// (used by the streaming/row-chunked ingestion path).
    pub fn row_chunk(&self, start: usize, len: usize) -> Result<BinaryDataset> {
        if start + len > self.n_rows {
            return Err(Error::Shape(format!(
                "row_chunk [{start}, {}) out of {} rows",
                start + len,
                self.n_rows
            )));
        }
        let data = self.data[start * self.n_cols..(start + len) * self.n_cols].to_vec();
        Ok(BinaryDataset {
            n_rows: len,
            n_cols: self.n_cols,
            data,
            names: self.names.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> BinaryDataset {
        BinaryDataset::new(3, 2, vec![1, 0, 0, 1, 1, 1]).unwrap()
    }

    #[test]
    fn construction_validates() {
        assert!(BinaryDataset::new(2, 2, vec![0, 1, 2, 0]).is_err()); // non-binary
        assert!(BinaryDataset::new(2, 2, vec![0, 1, 1]).is_err()); // wrong length
    }

    #[test]
    fn accessors() {
        let ds = small();
        assert_eq!(ds.get(0, 0), 1);
        assert_eq!(ds.get(1, 1), 1);
        assert_eq!(ds.row(2), &[1, 1]);
        assert_eq!(ds.col_counts(), vec![2, 2]);
        assert!((ds.sparsity() - (2.0 / 6.0)).abs() < 1e-12);
    }

    #[test]
    fn names_round_trip() {
        let ds = small().with_names(vec!["a".into(), "b".into()]).unwrap();
        assert_eq!(ds.col_name(1), "b");
        assert!(small().with_names(vec!["x".into()]).is_err());
        assert_eq!(small().col_name(0), "col0");
    }

    #[test]
    fn views_agree() {
        let ds = small();
        let dense = ds.to_mat32();
        let bits = ds.to_bitmatrix();
        let csr = ds.to_csr();
        for r in 0..3 {
            for c in 0..2 {
                let v = ds.get(r, c);
                assert_eq!(dense.get(r, c), v as f32);
                assert_eq!(bits.get(r, c), v == 1);
            }
        }
        assert_eq!(csr.nnz(), 4);
    }

    #[test]
    fn col_block_and_row_chunk() {
        let ds = BinaryDataset::new(4, 3, vec![1, 0, 1, 0, 1, 0, 1, 1, 0, 0, 0, 1]).unwrap();
        let blk = ds.col_block(1, 2).unwrap();
        assert_eq!(blk.n_cols(), 2);
        assert_eq!(blk.get(1, 0), 1);
        assert_eq!(blk.get(3, 1), 1);
        let chunk = ds.row_chunk(2, 2).unwrap();
        assert_eq!(chunk.n_rows(), 2);
        assert_eq!(chunk.row(0), ds.row(2));
        assert!(ds.col_block(2, 2).is_err());
        assert!(ds.row_chunk(3, 2).is_err());
    }

    #[test]
    fn select_cols_gathers_and_validates() {
        let ds = BinaryDataset::new(3, 4, vec![1, 0, 0, 1, 0, 1, 0, 0, 1, 1, 1, 0])
            .unwrap()
            .with_names(vec!["a".into(), "b".into(), "c".into(), "d".into()])
            .unwrap();
        let sub = ds.select_cols(&[3, 1]).unwrap();
        assert_eq!(sub.n_cols(), 2);
        assert_eq!(sub.names(), Some(&["d".to_string(), "b".to_string()][..]));
        for r in 0..3 {
            assert_eq!(sub.get(r, 0), ds.get(r, 3));
            assert_eq!(sub.get(r, 1), ds.get(r, 1));
        }
        assert!(ds.select_cols(&[0, 4]).is_err());
    }
}
