//! Minimal JSON parser (the offline registry has no `serde`).
//!
//! Supports the full JSON value grammar — objects, arrays, strings with
//! the standard escapes, numbers, booleans, null — which is all the
//! bench baseline files ([`crate::cli`]'s `pallas-bench --baseline`)
//! and any future machine-readable artifact need. Writing stays
//! hand-formatted at the call sites; only parsing needs structure.

use crate::util::error::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key-value pairs in document order (duplicate keys keep last).
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(err(pos, "trailing content after JSON value"));
        }
        Ok(value)
    }

    /// Object field lookup (last occurrence wins).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Escape a string for embedding in hand-written JSON output.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn err(pos: usize, msg: &str) -> Error {
    Error::Parse(format!("json at byte {pos}: {msg}"))
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, ch: u8) -> Result<()> {
    if *pos < b.len() && b[*pos] == ch {
        *pos += 1;
        Ok(())
    } else {
        Err(err(*pos, &format!("expected '{}'", ch as char)))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err(err(*pos, "unexpected end of input")),
        Some(b'{') => parse_obj(b, pos),
        Some(b'[') => parse_arr(b, pos),
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(_) => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(err(*pos, &format!("expected '{lit}'")))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).expect("ascii slice");
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| err(start, &format!("bad number '{text}'")))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err(err(*pos, "unterminated string")),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .ok_or_else(|| err(*pos, "bad \\u escape"))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| err(*pos, "bad \\u escape"))?;
                        // lone surrogates map to the replacement char;
                        // our own artifacts never emit them
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err(err(*pos, "bad escape")),
                }
                *pos += 1;
            }
            Some(_) => {
                // consume one UTF-8 scalar (multi-byte safe)
                let rest = std::str::from_utf8(&b[*pos..])
                    .map_err(|_| err(*pos, "invalid utf-8"))?;
                let c = rest.chars().next().expect("non-empty");
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(err(*pos, "expected ',' or ']'")),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'{')?;
    let mut fields = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(fields));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        expect(b, pos, b':')?;
        let value = parse_value(b, pos)?;
        fields.push((key, value));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            _ => return Err(err(*pos, "expected ',' or '}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(Json::parse("-1e3").unwrap(), Json::Num(-1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structure() {
        let doc = r#"{"schema": 1, "host": "ci-runner",
            "results": [{"name": "a/b", "rel": 0.5}, {"name": "c", "rel": 2}]}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("schema").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("host").unwrap().as_str(), Some("ci-runner"));
        let results = v.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("a/b"));
        assert_eq!(results[1].get("rel").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn string_escapes_round_trip() {
        let original = "a\"b\\c\nd\te";
        let doc = format!("\"{}\"", escape(original));
        assert_eq!(Json::parse(&doc).unwrap(), Json::Str(original.into()));
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "\"unterminated"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} must fail");
        }
    }

    #[test]
    fn empty_containers_and_dup_keys() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
        let v = Json::parse(r#"{"k": 1, "k": 2}"#).unwrap();
        assert_eq!(v.get("k").unwrap().as_f64(), Some(2.0));
    }
}
