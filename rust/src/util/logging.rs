//! Minimal leveled stderr logger (the offline registry has no `log`
//! backend). Level comes from `BULKMI_LOG` (error|warn|info|debug|trace),
//! default `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Log severity, ordered.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized
static INIT: OnceLock<()> = OnceLock::new();

fn init_from_env() {
    INIT.get_or_init(|| {
        let lvl = std::env::var("BULKMI_LOG")
            .ok()
            .and_then(|s| Level::parse(&s))
            .unwrap_or(Level::Info);
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
}

/// Current level (initializes from env on first call).
pub fn level() -> Level {
    init_from_env();
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically (tests, CLI --verbose).
pub fn set_level(lvl: Level) {
    init_from_env();
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

/// True if a message at `lvl` would be emitted.
pub fn enabled(lvl: Level) -> bool {
    lvl <= level()
}

#[doc(hidden)]
pub fn log_at(lvl: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if enabled(lvl) {
        eprintln!("[{:5} {}] {}", lvl.as_str(), module, args);
    }
}

/// Log at an explicit level: `log!(Level::Info, "x = {}", 3)`.
#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)*) => {
        $crate::util::logging::log_at($lvl, module_path!(), format_args!($($arg)*))
    };
}

/// Convenience macros.
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::log!($crate::util::logging::Level::Info, $($arg)*) };
}
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::log!($crate::util::logging::Level::Warn, $($arg)*) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::log!($crate::util::logging::Level::Debug, $($arg)*) };
}
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::log!($crate::util::logging::Level::Error, $($arg)*) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn ordering() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn set_and_check() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info);
    }
}
