//! Wall-clock timing helpers used by benches, metrics and the CLI.

use std::time::{Duration, Instant};

/// A simple resumable stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Option<Instant>,
    accumulated: Duration,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    /// Create stopped, at zero.
    pub fn new() -> Self {
        Stopwatch { start: None, accumulated: Duration::ZERO }
    }

    /// Create and start.
    pub fn started() -> Self {
        Stopwatch { start: Some(Instant::now()), accumulated: Duration::ZERO }
    }

    pub fn start(&mut self) {
        if self.start.is_none() {
            self.start = Some(Instant::now());
        }
    }

    pub fn stop(&mut self) {
        if let Some(s) = self.start.take() {
            self.accumulated += s.elapsed();
        }
    }

    /// Total accumulated time (including the running span, if any).
    pub fn elapsed(&self) -> Duration {
        self.accumulated + self.start.map(|s| s.elapsed()).unwrap_or(Duration::ZERO)
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Human format for durations: "1.23 s", "45.6 ms", "789 µs".
pub fn fmt_secs(secs: f64) -> String {
    if secs >= 100.0 {
        format!("{secs:.0} s")
    } else if secs >= 1.0 {
        format!("{secs:.2} s")
    } else if secs >= 1e-3 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.1} µs", secs * 1e6)
    } else {
        format!("{:.0} ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        assert_eq!(sw.elapsed(), Duration::ZERO);
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        let first = sw.elapsed();
        assert!(first >= Duration::from_millis(4));
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        assert!(sw.elapsed() > first);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 40 + 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_secs(120.0), "120 s");
        assert_eq!(fmt_secs(1.234), "1.23 s");
        assert_eq!(fmt_secs(0.01234), "12.34 ms");
        assert!(fmt_secs(1e-5).contains("µs"));
        assert!(fmt_secs(1e-8).contains("ns"));
    }
}
