//! Crate-wide error type. Kept dependency-free (no `thiserror` macro
//! expansion needed for a handful of variants).

use std::fmt;

/// Errors produced by the bulkmi library.
#[derive(Debug)]
pub enum Error {
    /// Input shapes/sizes are inconsistent or unsupported.
    Shape(String),
    /// Dataset parsing / IO failures.
    Io(std::io::Error),
    /// Malformed file contents (CSV, .bmat, manifest, config).
    Parse(String),
    /// XLA / PJRT runtime failures.
    Runtime(String),
    /// No artifact bucket can serve the requested shape.
    NoArtifact(String),
    /// Coordinator-level failures (cancelled jobs, worker panics...).
    Coordinator(String),
    /// Configuration errors.
    Config(String),
    /// A job was cancelled (taking its result yields this, not a value).
    JobCancelled(String),
    /// A job failed; the payload is the underlying failure message.
    JobFailed(String),
    /// An operation needed a live job but the job is already terminal.
    JobTerminal(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(s) => write!(f, "shape error: {s}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Parse(s) => write!(f, "parse error: {s}"),
            Error::Runtime(s) => write!(f, "runtime error: {s}"),
            Error::NoArtifact(s) => write!(f, "no artifact: {s}"),
            Error::Coordinator(s) => write!(f, "coordinator error: {s}"),
            Error::Config(s) => write!(f, "config error: {s}"),
            Error::JobCancelled(s) => write!(f, "job cancelled: {s}"),
            Error::JobFailed(s) => write!(f, "job failed: {s}"),
            Error::JobTerminal(s) => write!(f, "job already terminal: {s}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        assert!(Error::Shape("bad".into()).to_string().contains("shape"));
        assert!(Error::Parse("x".into()).to_string().contains("parse"));
        assert!(Error::Runtime("x".into()).to_string().contains("runtime"));
    }

    #[test]
    fn job_variants_format() {
        assert!(Error::JobCancelled("7".into()).to_string().contains("cancelled"));
        assert!(Error::JobFailed("7".into()).to_string().contains("failed"));
        assert!(Error::JobTerminal("7".into()).to_string().contains("terminal"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
