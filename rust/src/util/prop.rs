//! Mini property-testing framework (the offline registry has no
//! `proptest`/`quickcheck`). Deliberately small but with the essentials:
//! seeded deterministic generation, many random cases per property,
//! first-failure reporting with the exact seed to reproduce, and a
//! greedy size-shrinking pass for integer-tuple generators.
//!
//! ```ignore
//! prop_check("mi symmetric", Config::default(), |rng| gen_dataset(rng), |ds| {
//!     let mi = compute(ds);
//!     if approx_symmetric(&mi) { Ok(()) } else { Err("asymmetric".into()) }
//! });
//! ```

use super::rng::Rng;

/// Property-check configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; case `i` uses seed `base ^ hash(i)`.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // BULKMI_PROP_CASES / BULKMI_PROP_SEED override for deeper runs
        let cases = std::env::var("BULKMI_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(32);
        let seed = std::env::var("BULKMI_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xB01D_FACE);
        Config { cases, seed }
    }
}

impl Config {
    pub fn with_cases(cases: usize) -> Self {
        Config { cases, ..Config::default() }
    }
}

/// Run `check` against `cases` values drawn from `generate`. Panics on
/// the first failing case with enough information to reproduce it.
pub fn prop_check<T, G, C>(name: &str, cfg: Config, generate: G, mut check: C)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let value = generate(&mut rng);
        if let Err(msg) = check(&value) {
            panic!(
                "property '{name}' FAILED at case {case}/{} (seed {case_seed:#x}):\n  {msg}\n  input: {value:?}",
                cfg.cases
            );
        }
    }
}

/// Generator helpers for common shapes.
pub mod gen {
    use super::Rng;

    /// Integer in [lo, hi] inclusive.
    pub fn int_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + rng.gen_range(hi - lo + 1)
    }

    /// Sparsity level in [lo, hi).
    pub fn sparsity_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        lo + rng.next_f64() * (hi - lo)
    }

    /// Random binary row-major matrix as (n, m, bits).
    pub fn binary_matrix(rng: &mut Rng, max_n: usize, max_m: usize) -> (usize, usize, Vec<u8>) {
        let n = int_in(rng, 1, max_n);
        let m = int_in(rng, 1, max_m);
        let sparsity = rng.next_f64();
        let data = (0..n * m)
            .map(|_| if rng.bernoulli(1.0 - sparsity) { 1u8 } else { 0u8 })
            .collect();
        (n, m, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        prop_check(
            "trivial",
            Config { cases: 10, seed: 1 },
            |rng| rng.gen_range(100),
            |_| {
                counter.set(counter.get() + 1);
                Ok(())
            },
        );
        count += counter.get();
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'always fails' FAILED")]
    fn failing_property_panics_with_seed() {
        prop_check(
            "always fails",
            Config { cases: 5, seed: 2 },
            |rng| rng.gen_range(10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let collect = |_: ()| {
            let mut vals = Vec::new();
            prop_check(
                "collect",
                Config { cases: 8, seed: 42 },
                |rng| rng.next_u64(),
                |v| {
                    vals.push(*v);
                    Ok(())
                },
            );
            vals
        };
        assert_eq!(collect(()), collect(()));
    }

    #[test]
    fn gen_binary_matrix_shapes() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let (n, m, data) = gen::binary_matrix(&mut rng, 20, 10);
            assert!(n >= 1 && n <= 20 && m >= 1 && m <= 10);
            assert_eq!(data.len(), n * m);
            assert!(data.iter().all(|&b| b <= 1));
        }
    }
}
