//! Shared bench harness (criterion is unavailable offline; every
//! `rust/benches/*.rs` target is `harness = false` and uses this).
//!
//! Conventions:
//! * Each bench prints a human table mirroring the paper's rows/series
//!   AND one machine-readable `JSON:` line per cell.
//! * `BULKMI_BENCH_FULL=1` runs the paper-exact sizes; the default
//!   applies documented caps so a full `cargo bench` stays tractable on
//!   this single-vCPU container (see EXPERIMENTS.md).
//! * Cells skipped by a cap print `--` and a `"skipped"` JSON marker.
//! * The pairwise baseline beyond its cap is *estimated* from a column
//!   subsample (cost is exactly quadratic in columns), marked `est`.

use crate::data::dataset::BinaryDataset;
use crate::mi::pairwise::mi_pairwise;
use std::time::Instant;

/// True when the paper-exact sizes were requested.
pub fn full_mode() -> bool {
    std::env::var("BULKMI_BENCH_FULL").map(|v| v == "1" || v == "true").unwrap_or(false)
}

/// Optional global scale factor on dataset rows (default 1.0).
pub fn row_scale() -> f64 {
    std::env::var("BULKMI_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1.0)
}

/// Apply the row scale.
pub fn scaled_rows(rows: usize) -> usize {
    ((rows as f64 * row_scale()) as usize).max(64)
}

/// Measure one invocation (datasets here are big enough that a single
/// shot is stable; small cells are repeated until >= 100 ms or 5 reps
/// and the minimum is reported).
pub fn measure<T>(mut f: impl FnMut() -> T) -> f64 {
    let t0 = Instant::now();
    let _keep = f();
    let first = t0.elapsed().as_secs_f64();
    if first >= 0.1 {
        return first;
    }
    let mut best = first;
    for _ in 0..4 {
        let t0 = Instant::now();
        let _keep = f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

/// Time a single fallible invocation: `Secs` on success, `Skipped` (with
/// a stderr note) on error. Used for the XLA cells, which are expensive
/// enough that one shot is stable and a pre-flight check would double
/// the bench wall time.
pub fn measure_result<T, E: std::fmt::Display>(
    label: &str,
    f: impl FnOnce() -> Result<T, E>,
) -> Cell {
    let t0 = Instant::now();
    match f() {
        Ok(_) => Cell::Secs(t0.elapsed().as_secs_f64()),
        Err(e) => {
            eprintln!("{label} unavailable: {e}");
            Cell::Skipped
        }
    }
}

/// A measured (or skipped/estimated) cell.
#[derive(Clone, Copy, Debug)]
pub enum Cell {
    Secs(f64),
    Estimated(f64),
    Skipped,
}

impl Cell {
    pub fn text(&self) -> String {
        match self {
            Cell::Secs(s) => format!("{s:.3}"),
            Cell::Estimated(s) => format!("{s:.1}*"),
            Cell::Skipped => "--".to_string(),
        }
    }

    pub fn json_value(&self) -> String {
        match self {
            Cell::Secs(s) => format!("{s:.6}"),
            Cell::Estimated(s) => format!("{s:.6}"),
            Cell::Skipped => "null".to_string(),
        }
    }

    pub fn marker(&self) -> &'static str {
        match self {
            Cell::Secs(_) => "measured",
            Cell::Estimated(_) => "estimated",
            Cell::Skipped => "skipped",
        }
    }
}

/// Emit the machine-readable line for one cell.
pub fn emit_json(bench: &str, labels: &[(&str, String)], cell: &Cell) {
    let mut body = format!("\"bench\":\"{bench}\"");
    for (k, v) in labels {
        let quoted = v.parse::<f64>().map(|_| v.clone()).unwrap_or(format!("\"{v}\""));
        body.push_str(&format!(",\"{k}\":{quoted}"));
    }
    body.push_str(&format!(
        ",\"secs\":{},\"status\":\"{}\"",
        cell.json_value(),
        cell.marker()
    ));
    println!("JSON: {{{body}}}");
}

/// Estimate the full pairwise time from a `sample_cols`-column subsample
/// (pair count scales quadratically, per-pair cost is constant).
pub fn estimate_pairwise(ds: &BinaryDataset, sample_cols: usize) -> f64 {
    let m = ds.n_cols();
    let k = sample_cols.min(m);
    let sub = ds.col_block(0, k).expect("subsample in range");
    let secs = measure(|| mi_pairwise(&sub));
    let pairs_full = (m * (m + 1)) as f64 / 2.0;
    let pairs_sub = (k * (k + 1)) as f64 / 2.0;
    secs * pairs_full / pairs_sub
}

/// Print a header row: first column label + per-impl column names.
pub fn print_header(first: &str, cols: &[&str]) {
    print!("{first:<18}");
    for c in cols {
        print!(" {c:>14}");
    }
    println!();
    println!("{}", "-".repeat(18 + cols.len() * 15));
}

/// Print one row of cells.
pub fn print_row(label: &str, cells: &[Cell]) {
    print!("{label:<18}");
    for c in cells {
        print!(" {:>14}", c.text());
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn measure_returns_positive() {
        let secs = measure(|| std::hint::black_box((0..1000).sum::<usize>()));
        assert!(secs > 0.0);
    }

    #[test]
    fn cell_formatting() {
        assert_eq!(Cell::Skipped.text(), "--");
        assert!(Cell::Secs(1.25).text().starts_with("1.250"));
        assert!(Cell::Estimated(3.0).text().ends_with('*'));
        assert_eq!(Cell::Skipped.json_value(), "null");
    }

    #[test]
    fn pairwise_estimate_close_on_small_data() {
        let ds = SynthSpec::new(2000, 30).sparsity(0.8).seed(1).generate();
        let est = estimate_pairwise(&ds, 15);
        let real = measure(|| mi_pairwise(&ds));
        let ratio = est / real;
        assert!(
            (0.3..3.0).contains(&ratio),
            "estimate {est} vs real {real} (ratio {ratio})"
        );
    }

    #[test]
    fn scaled_rows_applies_floor() {
        assert!(scaled_rows(10) >= 64);
    }
}
