//! Infrastructure substrates built in-crate (the offline registry lacks
//! `rand`, `rayon`, `proptest`, `log`-backends, `clap`): PRNG, logging,
//! errors, timers, a scoped thread pool, and a mini property-testing
//! framework.

pub mod bench;
pub mod error;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod threadpool;
pub mod timer;
