//! Scoped data-parallel helpers on `std::thread` (no `rayon`/`tokio` in
//! the offline registry). Two primitives:
//!
//! * [`parallel_for`] — run `n_tasks` index-addressed tasks across
//!   `n_workers` threads with atomic work-stealing; blocks until done.
//! * [`WorkerPool`] — a persistent pool consuming boxed jobs from a
//!   channel, used by the coordinator service for long-lived workers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Number of workers to default to on this machine.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(i)` for every `i in 0..n_tasks` across up to `n_workers`
/// threads. Tasks are claimed from a shared atomic counter, so uneven
/// task costs balance automatically. Panics in tasks propagate.
pub fn parallel_for<F>(n_tasks: usize, n_workers: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let n_workers = n_workers.max(1).min(n_tasks.max(1));
    if n_workers <= 1 || n_tasks <= 1 {
        for i in 0..n_tasks {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..n_workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_tasks {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Like [`parallel_for`] but each task produces a value; results are
/// returned in task order.
pub fn parallel_map<T, F>(n_tasks: usize, n_workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n_tasks).map(|_| None).collect();
    {
        let slots = Mutex::new(&mut out);
        let next = AtomicUsize::new(0);
        let n_workers = n_workers.max(1).min(n_tasks.max(1));
        std::thread::scope(|scope| {
            for _ in 0..n_workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n_tasks {
                        break;
                    }
                    let v = f(i);
                    let mut guard = slots.lock().unwrap();
                    guard[i] = Some(v);
                });
            }
        });
    }
    out.into_iter().map(|v| v.expect("task did not complete")).collect()
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A persistent pool of worker threads consuming boxed jobs.
pub struct WorkerPool {
    tx: Option<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl WorkerPool {
    /// Spawn a pool with `n` workers (at least 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::with_capacity(n);
        for k in 0..n {
            let rx = Arc::clone(&rx);
            let queued = Arc::clone(&queued);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("bulkmi-worker-{k}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                queued.fetch_sub(1, Ordering::Relaxed);
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        WorkerPool { tx: Some(tx), handles, queued }
    }

    /// Enqueue a job. Returns an error after shutdown.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) -> Result<(), ()> {
        match &self.tx {
            Some(tx) => {
                self.queued.fetch_add(1, Ordering::Relaxed);
                tx.send(Box::new(job)).map_err(|_| ())
            }
            None => Err(()),
        }
    }

    /// Jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers drain and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices() {
        let hits = AtomicU64::new(0);
        parallel_for(1000, 4, |i| {
            hits.fetch_add(i as u64 + 1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 500_500);
    }

    #[test]
    fn parallel_for_zero_tasks() {
        parallel_for(0, 4, |_| panic!("should not run"));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn worker_pool_runs_jobs() {
        let pool = WorkerPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn worker_pool_min_one_worker() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 1);
    }
}
