//! xoshiro256++ PRNG (no `rand` crate in the offline registry).
//!
//! Deterministic, seedable, fast; used by the synthetic data generators,
//! the property-testing framework, and the benches. Not cryptographic.

/// xoshiro256++ by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw: true with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Uniform integer in [0, bound) (bound > 0). Unbiased via rejection.
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range bound must be > 0");
        let bound = bound as u64;
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % bound) as usize;
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher-Yates: the first k entries become the sample
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bernoulli_mean_close() {
        let mut r = Rng::new(11);
        for &p in &[0.1, 0.5, 0.9] {
            let hits = (0..50_000).filter(|_| r.bernoulli(p)).count() as f64 / 50_000.0;
            assert!((hits - p).abs() < 0.01, "p={p} hits={hits}");
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut uniq = s.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 30);
    }
}
