//! `bulkmi bench` (alias `pallas-bench`): the deterministic perf-smoke
//! harness behind CI's perf gate.
//!
//! Fixed-seed synthetic datasets, warmup + median-of-k timing, and one
//! machine-readable `BENCH_<host>.json` per run. Measured surfaces:
//!
//! * `gram-kernel/<name>@dX` — the bit-packed Gram on every dispatchable
//!   AND-popcount kernel ([`crate::linalg::kernels`]);
//! * `backend-gram/<backend>@dX` — the three native Gram substrates the
//!   autotuner chooses between;
//! * `combine-scalar@dX` / `combine/<measure>@dX` — the element-wise
//!   combine stage: a reference row timing the per-cell scalar
//!   `CombineKind::combine` loop, then one row per association measure
//!   ([`crate::mi::measure::CombineKind`]) timing the table-driven
//!   block kernels ([`crate::mi::combine_kernels`]) the executor runs;
//!   the measure is part of the entry id so per-measure rows can never
//!   alias each other in the baseline gate;
//! * `backend-auto@dX` — the autotuner probe itself (wall time + what
//!   it chose);
//! * `oocgram/{uncached,cached}@dX` — the out-of-core streaming path
//!   over a real `.bmat` v2 file split into >= 8 column blocks, run
//!   once without the block cache (largest-first order) and once with
//!   it (panel order + prefetch). These rows carry `bytes_read`, and
//!   the cached row's `rel` is the uncached/cached bytes-read ratio —
//!   the read-amplification win the cache exists to deliver (expected
//!   well above 2x), gated like any other `rel`;
//! * `tile-cache/{cold,warm}@dX` — the content-addressed Gram-tile
//!   result cache ([`crate::coordinator::tilecache`]), one run that
//!   computes and persists every tile and one that must be served
//!   entirely from disk. The warm row's `rel` is the hit *fraction*
//!   (exactly 1.0 when the cache works), a deterministic number where
//!   wall time on temp-file tiles would be flaky; the cold row carries
//!   the tile bytes written in `bytes_read`.
//!
//! A separate subcommand, `bulkmi cluster bench` ([`cluster_bench`]),
//! measures the distributed path: one dataset, a single-process
//! reference, then 1/2/4 in-process workers served over real TCP
//! loopback through the cluster wire protocol. Its `cluster/...` rows
//! merge into the same `BENCH_<host>.json` but carry no `rel` — a
//! `--baseline` gate warns-and-skips them instead of failing a run on
//! loopback scheduling noise — and each row is recorded only after the
//! sharded result proves bit-identical to the single-process
//! reference.
//!
//! Every entry carries both absolute throughput (`cells_per_sec`, Gram
//! output cells per second) and `rel`, the throughput normalized by the
//! same-dataset scalar-kernel run (combine rows normalize by the
//! same-dataset `combine-scalar` reference instead — so their `rel` is
//! the table-driven kernel's speedup over the per-cell scalar combine
//! loop). `rel` is what `--baseline` gates on: machine speed
//! cancels out of the ratio, so a checked-in baseline catches code
//! regressions ("bitpack got 2x slower than scalar") without being
//! flaky across runner generations. Absolute numbers stay in the JSON
//! for trend tracking.

use super::args::Args;
use crate::data::synth::SynthSpec;
use crate::linalg::kernels;
use crate::mi::autotune;
use crate::mi::measure::CombineKind;
use crate::util::error::{Error, Result};
use crate::util::json::{escape, Json};
use std::path::{Path, PathBuf};
use std::time::Instant;

/// One measured cell of the bench matrix.
struct BenchEntry {
    name: String,
    rows: usize,
    cols: usize,
    density: f64,
    secs: f64,
    cells_per_sec: f64,
    /// Throughput relative to the scalar kernel on the same dataset
    /// (None for entries that are not Gram measurements).
    rel: Option<f64>,
    /// The autotuner's choice, for `backend-auto` entries.
    chosen: Option<String>,
    /// Bytes read from storage, for the out-of-core `oocgram` entries
    /// (None for in-memory measurements).
    bytes_read: Option<u64>,
}

pub fn bench(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let quick = args.flag("quick");
    let out = args.get("out").map(PathBuf::from);
    let baseline = args.get("baseline").map(PathBuf::from);
    let tolerance = args.get_f64("tolerance", 0.30)?;
    let seed = args.get_u64("seed", 42)?;
    let reps = args.get_usize("reps", if quick { 3 } else { 5 })?;
    let measure_args = args.get_all("measure");
    args.reject_unknown()?;
    let measures: Vec<CombineKind> = if measure_args.is_empty() {
        CombineKind::ALL.to_vec()
    } else {
        measure_args
            .iter()
            .map(|m| {
                CombineKind::parse(m)
                    .ok_or_else(|| Error::Parse(format!("unknown measure '{m}'")))
            })
            .collect::<Result<_>>()?
    };
    if !(0.0..1.0).contains(&tolerance) {
        return Err(Error::Parse(format!(
            "--tolerance must be in [0, 1), got {tolerance}"
        )));
    }
    if reps == 0 {
        return Err(Error::Parse("--reps must be >= 1".into()));
    }

    let (rows, cols) = if quick { (8_192, 160) } else { (32_768, 384) };
    let densities: &[f64] = if quick { &[0.5, 0.01] } else { &[0.5, 0.1, 0.01] };
    let mode = if quick { "quick" } else { "full" };
    println!(
        "pallas-bench ({mode}): {rows}x{cols}, densities {densities:?}, \
         seed {seed}, median of {reps}"
    );
    println!("{}", kernels::KernelDispatch::global().summary());

    let mut entries: Vec<BenchEntry> = Vec::new();
    for &density in densities {
        let ds = SynthSpec::new(rows, cols).sparsity(1.0 - density).seed(seed).generate();
        let bits = ds.to_bitmatrix();
        let cells = (cols * cols) as f64;
        let tag = format!("@d{density:.2}");

        // --- per-kernel bit-packed Gram ---------------------------------
        let mut scalar_cps = f64::NAN;
        for kernel in kernels::available() {
            let secs = timed_median(reps, || {
                std::hint::black_box(bits.gram_with(kernel));
            });
            let cps = cells / secs;
            if kernel.name() == "scalar" {
                scalar_cps = cps;
            }
            entries.push(BenchEntry {
                name: format!("gram-kernel/{}{tag}", kernel.name()),
                rows,
                cols,
                density,
                secs,
                cells_per_sec: cps,
                rel: Some(cps / scalar_cps),
                chosen: None,
                bytes_read: None,
            });
        }

        // --- per-backend Gram substrates --------------------------------
        let dense = ds.to_mat32();
        let csr = ds.to_csr();
        for name in ["bulk-bitpack", "bulk-opt", "bulk-sparse"] {
            let secs = match name {
                "bulk-bitpack" => timed_median(reps, || {
                    std::hint::black_box(bits.gram());
                }),
                "bulk-opt" => timed_median(reps, || {
                    std::hint::black_box(crate::linalg::blas::gram(&dense));
                }),
                _ => timed_median(reps, || {
                    std::hint::black_box(csr.gram());
                }),
            };
            let cps = cells / secs;
            entries.push(BenchEntry {
                name: format!("backend-gram/{name}{tag}"),
                rows,
                cols,
                density,
                secs,
                cells_per_sec: cps,
                rel: Some(cps / scalar_cps),
                chosen: None,
                bytes_read: None,
            });
        }

        // --- per-measure combine stage ----------------------------------
        entries.extend(bench_combine(&ds, density, reps, &measures));

        // --- the autotuner probe itself ---------------------------------
        // uncached: the entry times a real probe, not a cache hit
        let t0 = Instant::now();
        let report = autotune::autotune_uncached(&ds)?;
        let probe_secs = t0.elapsed().as_secs_f64();
        entries.push(BenchEntry {
            name: format!("backend-auto{tag}"),
            rows,
            cols,
            density,
            secs: probe_secs,
            cells_per_sec: 0.0,
            rel: None,
            chosen: Some(report.chosen.name().to_string()),
            bytes_read: None,
        });
    }

    // --- out-of-core streaming path (cached vs uncached) ----------------
    // sized down from the in-memory grid: the interesting number here is
    // bytes read, not raw throughput, and 8k rows already gives >= 8
    // column blocks with real positioned-read I/O
    entries.extend(bench_ooc(rows.min(8_192), cols, 0.5, seed)?);

    // --- Gram-tile result cache (cold write vs warm read) ---------------
    entries.extend(bench_tilecache(rows.min(8_192), cols, 0.5, seed)?);

    print_table(&entries);
    let path = out.unwrap_or_else(|| PathBuf::from(format!("BENCH_{}.json", host_id())));
    write_json(&entries, mode, seed, reps, &path)?;
    println!("wrote {}", path.display());

    if let Some(base) = baseline {
        check_baseline(&entries, &base, tolerance)?;
    }
    Ok(())
}

/// Warmup + calibration, then the median of `reps` samples. Short
/// workloads are repeated within a sample until each sample spans
/// >= 50 ms, so CI-grade timer noise stays well under the gate's
/// tolerance.
fn timed_median(reps: usize, mut f: impl FnMut()) -> f64 {
    let t0 = Instant::now();
    f(); // warmup; also calibrates the inner repeat count
    let first = t0.elapsed().as_secs_f64();
    let iters = if first >= 0.05 {
        1
    } else {
        (((0.05 / first.max(1e-9)).ceil()) as usize).clamp(1, 200)
    };
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    samples[samples.len() / 2]
}

/// The element-wise combine stage: one `combine-scalar@dX` reference
/// row timing the per-cell scalar `CombineKind::combine` loop
/// ([`crate::mi::combine_kernels::combine_block_scalar`], the
/// pre-kernel code shape — per-cell marginal re-derivation and direct
/// `log2` calls), then one `combine/<measure>@dX` row per requested
/// measure timing the table-driven block kernels
/// ([`crate::mi::combine_kernels::combine_block_with`]) the executor
/// actually runs. The [`crate::mi::combine_kernels::LogTable`] is
/// built once *outside* the timed
/// region, matching production where one table is amortized across a
/// whole run. Every kernel row's `rel` is its throughput over the
/// scalar reference — the kernel speedup the perf gate holds floors
/// on — and the reference row itself carries `rel` 1.0 by definition.
fn bench_combine(
    ds: &crate::data::dataset::BinaryDataset,
    density: f64,
    reps: usize,
    measures: &[CombineKind],
) -> Vec<BenchEntry> {
    use crate::mi::combine_kernels::{combine_block_scalar, combine_block_with, LogTable};

    let (rows, cols) = (ds.n_rows(), ds.n_cols());
    let g11 = ds.to_bitmatrix().gram();
    let colsums: Vec<f64> = ds.col_counts().iter().map(|&v| v as f64).collect();
    let nf = rows as f64;
    let cells = (cols * cols) as f64;
    let tag = format!("@d{density:.2}");

    let scalar_secs = timed_median(reps, || {
        std::hint::black_box(combine_block_scalar(
            CombineKind::Mi,
            &g11,
            &colsums,
            &colsums,
            nf,
        ));
    });
    let scalar_cps = cells / scalar_secs;
    let mut entries = vec![BenchEntry {
        name: format!("combine-scalar{tag}"),
        rows,
        cols,
        density,
        secs: scalar_secs,
        cells_per_sec: scalar_cps,
        rel: Some(1.0),
        chosen: None,
        bytes_read: None,
    }];

    let lt = LogTable::new(rows);
    for &measure in measures {
        let secs = timed_median(reps, || {
            std::hint::black_box(combine_block_with(measure, &lt, &g11, &colsums, &colsums, nf));
        });
        let cps = cells / secs;
        entries.push(BenchEntry {
            name: format!("combine/{}{tag}", measure.name()),
            rows,
            cols,
            density,
            secs,
            cells_per_sec: cps,
            rel: Some(cps / scalar_cps),
            chosen: None,
            bytes_read: None,
        });
    }
    entries
}

/// The out-of-core streaming path, measured end to end over a real
/// `.bmat` v2 file: plan >= 8 column blocks, run the top-k sink once
/// uncached (largest-first, every off-diagonal task re-reads both
/// blocks) and once through the block cache (panel order + one task of
/// readahead). Timed once — the entries exist for their `bytes_read`
/// counters and the cached row's `rel` (uncached/cached bytes-read
/// ratio), which are deterministic; wall time on temp-file I/O is not.
fn bench_ooc(rows: usize, cols: usize, density: f64, seed: u64) -> Result<Vec<BenchEntry>> {
    use crate::coordinator::blockcache::{BlockCache, CacheHandle};
    use crate::coordinator::executor::{run_plan, NativeKind, NativeProvider};
    use crate::coordinator::planner::plan_blocks;
    use crate::coordinator::progress::Progress;
    use crate::coordinator::scheduler::{order_tasks, Schedule};
    use crate::data::colstore::{ColumnSource, PackedFileSource};
    use crate::data::io::write_bmat_v2;
    use crate::mi::sink::TopKSink;
    use std::sync::Arc;

    let ds = SynthSpec::new(rows, cols).sparsity(1.0 - density).seed(seed).generate();
    let path = std::env::temp_dir()
        .join(format!("bulkmi-bench-ooc-{}-{rows}x{cols}.bmat", std::process::id()));
    write_bmat_v2(&ds, &path)?;
    let block = cols.div_ceil(8).max(1);
    let cells = (cols * cols) as f64;
    let tag = format!("@d{density:.2}");
    let mut entries = Vec::new();
    let mut uncached_bytes = 0u64;
    for cached in [false, true] {
        let src = PackedFileSource::open(&path)?;
        let before = src.io_stats().unwrap_or_default();
        let mut plan = plan_blocks(cols, block)?;
        order_tasks(
            &mut plan.tasks,
            if cached { Schedule::Panel } else { Schedule::LargestFirst },
        );
        let handle = CacheHandle::fresh(Arc::new(BlockCache::new(64 << 20)));
        let provider = if cached {
            NativeProvider::with_cache(&src, NativeKind::Bitpack, handle, 1)
        } else {
            NativeProvider::new(&src, NativeKind::Bitpack)
        };
        let mut sink = TopKSink::global(8);
        let progress = Progress::new(plan.tasks.len());
        let t0 = Instant::now();
        run_plan(&src, &plan, &provider, 2, &progress, &mut sink, CombineKind::Mi)?;
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        let delta = src.io_stats().unwrap_or_default().since(&before);
        let rel = if cached && delta.bytes_read > 0 {
            Some(uncached_bytes as f64 / delta.bytes_read as f64)
        } else {
            uncached_bytes = delta.bytes_read;
            None
        };
        entries.push(BenchEntry {
            name: format!("oocgram/{}{tag}", if cached { "cached" } else { "uncached" }),
            rows,
            cols,
            density,
            secs,
            cells_per_sec: cells / secs,
            rel,
            chosen: None,
            bytes_read: Some(delta.bytes_read),
        });
    }
    let _ = std::fs::remove_file(&path);
    Ok(entries)
}

/// The content-addressed Gram-tile result cache, measured end to end
/// through `run_plan_tiled`: a cold run that computes every tile and
/// writes it to a fresh cache directory, then a warm run over the same
/// plan that must be served entirely from disk. Wall time on temp-file
/// tiles is not deterministic, so the gateable number is the warm
/// row's `rel` — the hit fraction, exactly 1.0 when every lookup hits
/// — and the cold row reports the tile bytes it wrote in `bytes_read`
/// (the warm row reports 0 there: a pure-hit run writes nothing).
fn bench_tilecache(rows: usize, cols: usize, density: f64, seed: u64) -> Result<Vec<BenchEntry>> {
    use crate::coordinator::executor::{run_plan_tiled, NativeKind, NativeProvider};
    use crate::coordinator::planner::plan_blocks;
    use crate::coordinator::progress::Progress;
    use crate::coordinator::tilecache::TileCache;
    use crate::data::colstore::InMemorySource;
    use crate::mi::sink::TopKSink;

    let ds = SynthSpec::new(rows, cols).sparsity(1.0 - density).seed(seed).generate();
    let src = InMemorySource::new(&ds);
    let root = std::env::temp_dir()
        .join(format!("bulkmi-bench-tiles-{}-{rows}x{cols}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let cache = TileCache::open(root.clone(), 1 << 30);
    let block = cols.div_ceil(8).max(1);
    let cells = (cols * cols) as f64;
    let tag = format!("@d{density:.2}");
    let mut entries = Vec::new();
    for warm in [false, true] {
        let plan = plan_blocks(cols, block)?;
        let provider = NativeProvider::new(&src, NativeKind::Bitpack);
        let mut sink = TopKSink::global(8);
        let progress = Progress::new(plan.tasks.len());
        let before = cache.stats();
        let tiles = Some(&cache);
        let t0 = Instant::now();
        run_plan_tiled(&src, &plan, &provider, 2, &progress, &mut sink, CombineKind::Mi, tiles)?;
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        let delta = cache.stats().since(&before);
        let looked_up = (delta.hits + delta.misses).max(1);
        entries.push(BenchEntry {
            name: format!("tile-cache/{}{tag}", if warm { "warm" } else { "cold" }),
            rows,
            cols,
            density,
            secs,
            cells_per_sec: cells / secs,
            rel: warm.then(|| delta.hits as f64 / looked_up as f64),
            chosen: None,
            bytes_read: Some(delta.inserted_bytes),
        });
    }
    let _ = std::fs::remove_dir_all(&root);
    Ok(entries)
}

/// `bulkmi cluster bench`: the local-loopback scaling suite. One
/// dataset, a single-process reference row, then 1/2/4 in-process
/// workers served over real TCP loopback through the cluster wire
/// protocol — the cheapest honest answer to "does sharding this
/// workload scale" before renting machines. Rows merge into the same
/// `BENCH_<host>.json` the main bench writes (prior `cluster/` rows
/// are replaced, everything else survives) and carry no `rel`, so a
/// `--baseline` gate warns-and-skips them instead of failing a run on
/// loopback scheduling noise.
pub fn cluster_bench(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let rows = args.get_usize("rows", 4_096)?;
    let cols = args.get_usize("cols", 256)?;
    let sparsity = args.get_f64("sparsity", 0.9)?;
    let seed = args.get_u64("seed", 42)?;
    let out = args.get("out").map(PathBuf::from);
    let baseline = args.get("baseline").map(PathBuf::from);
    args.reject_unknown()?;
    if !(0.0..=1.0).contains(&sparsity) {
        return Err(Error::Parse(format!(
            "--sparsity must be in [0, 1], got {sparsity}"
        )));
    }
    if rows == 0 || cols < 2 {
        return Err(Error::Parse(format!(
            "need --rows >= 1 and --cols >= 2, got {rows}x{cols}"
        )));
    }
    let density = 1.0 - sparsity;
    println!(
        "cluster-bench: {rows}x{cols} @ density {density:.2}, seed {seed}, \
         single-process reference + 1/2/4 loopback workers"
    );
    let entries = bench_cluster(rows, cols, density, seed)?;
    print_table(&entries);
    let path = out.unwrap_or_else(|| PathBuf::from(format!("BENCH_{}.json", host_id())));
    let merged = merge_entries(entries, &path)?;
    write_json(&merged, "cluster", seed, 1, &path)?;
    println!("wrote {}", path.display());
    if let Some(base) = baseline {
        check_baseline(&merged, &base, 0.30)?;
    }
    Ok(())
}

/// Measure the distributed path on loopback: a single-process
/// reference over the same backend, then `run_cluster` against 1, 2,
/// and 4 workers running [`crate::cluster::worker::serve_conn`] on
/// in-process threads behind real `127.0.0.1` sockets — the full wire
/// protocol (framing, heartbeats, f64 round-trip), none of the
/// network. Every workers-K row is verified cell-for-cell bit-exact
/// against the reference before it is recorded: a scaling number for
/// a wrong answer is worse than no number.
fn bench_cluster(rows: usize, cols: usize, density: f64, seed: u64) -> Result<Vec<BenchEntry>> {
    use crate::cluster::worker::serve_conn;
    use crate::cluster::{run_cluster, ClusterRun};
    use crate::coordinator::executor::{compute_source, NativeKind};
    use crate::coordinator::planner::plan_blocks;
    use crate::coordinator::scheduler::{order_tasks, Schedule};
    use crate::data::colstore::InMemorySource;
    use crate::mi::backend::Backend;
    use crate::mi::sink::{SinkData, SinkSpec};
    use std::net::{TcpListener, TcpStream};

    let ds = SynthSpec::new(rows, cols).sparsity(1.0 - density).seed(seed).generate();
    let src = InMemorySource::new(&ds);
    let cells = (cols * cols) as f64;
    let tag = format!("@d{density:.2}");
    let mut entries = Vec::new();

    // the reference: same bitpack substrate, one compute thread — the
    // denominator a reader scales the workers-K rows against
    let t0 = Instant::now();
    let reference = compute_source(&src, NativeKind::Bitpack, 1, CombineKind::Mi)?;
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    entries.push(BenchEntry {
        name: format!("cluster/single-process{tag}"),
        rows,
        cols,
        density,
        secs,
        cells_per_sec: cells / secs,
        rel: None,
        chosen: None,
        bytes_read: None,
    });

    let block = cols.div_ceil(8).max(1);
    for workers in [1usize, 2, 4] {
        let mut plan = plan_blocks(cols, block)?;
        order_tasks(&mut plan.tasks, Schedule::LargestFirst);
        let sink = SinkSpec::Dense;
        // bind every listener before the scope: an address in hand is
        // what lets the coordinator dial, and a bind failure here must
        // not strand acceptor threads
        let mut listeners = Vec::with_capacity(workers);
        let mut addrs = Vec::with_capacity(workers);
        for _ in 0..workers {
            let l = TcpListener::bind("127.0.0.1:0")?;
            addrs.push(l.local_addr()?.to_string());
            listeners.push(l);
        }
        let t0 = Instant::now();
        let out = std::thread::scope(|s| {
            for l in listeners {
                let src = &src;
                s.spawn(move || {
                    if let Ok((stream, _)) = l.accept() {
                        let _ = serve_conn(stream, src);
                    }
                });
            }
            let result = run_cluster(&ClusterRun {
                workers: &addrs,
                backend: Backend::BulkBitpack,
                measure: CombineKind::Mi,
                plan: &plan,
                n_rows: rows,
                sink: &sink,
            });
            if result.is_err() {
                // unblock any acceptor the coordinator never dialed,
                // so the scope can join instead of hanging
                for addr in &addrs {
                    drop(TcpStream::connect(addr));
                }
            }
            result
        })?;
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        let SinkData::Dense(mi) = out.data else {
            return Err(Error::Runtime("cluster bench expected a dense result".into()));
        };
        let exact = mi
            .data()
            .iter()
            .zip(reference.data())
            .all(|(a, b)| a.to_bits() == b.to_bits());
        if !exact {
            return Err(Error::Runtime(format!(
                "{workers}-worker loopback result diverged from the single-process \
                 reference — refusing to record a scaling row for a wrong answer"
            )));
        }
        entries.push(BenchEntry {
            name: format!("cluster/workers-{workers}{tag}"),
            rows,
            cols,
            density,
            secs,
            cells_per_sec: cells / secs,
            rel: None,
            chosen: None,
            bytes_read: None,
        });
    }
    Ok(entries)
}

/// Fold freshly measured rows into whatever bench JSON `path` already
/// holds: existing rows survive untouched, except prior `cluster/`
/// rows, which the new measurements replace. A missing file starts
/// fresh; a file that exists but does not parse is a hard error —
/// silently clobbering a bench history is how baselines get lost.
fn merge_entries(new: Vec<BenchEntry>, path: &Path) -> Result<Vec<BenchEntry>> {
    let text = match std::fs::read_to_string(path) {
        Ok(text) => text,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(new),
        Err(e) => return Err(e.into()),
    };
    let doc = Json::parse(&text).map_err(|e| {
        Error::Parse(format!(
            "{}: existing bench file unreadable, not overwriting: {e}",
            path.display()
        ))
    })?;
    let mut merged = Vec::new();
    for row in doc.get("results").and_then(|r| r.as_arr()).unwrap_or(&[]) {
        let Some(name) = row.get("name").and_then(|n| n.as_str()) else {
            continue;
        };
        if name.starts_with("cluster/") {
            continue; // replaced by this run's measurements
        }
        let f = |key: &str| row.get(key).and_then(|v| v.as_f64());
        merged.push(BenchEntry {
            name: name.to_string(),
            rows: f("rows").unwrap_or(0.0) as usize,
            cols: f("cols").unwrap_or(0.0) as usize,
            density: f("density").unwrap_or(0.0),
            secs: f("secs").unwrap_or(0.0),
            cells_per_sec: f("cells_per_sec").unwrap_or(0.0),
            rel: f("rel"),
            chosen: row.get("chosen").and_then(|v| v.as_str()).map(str::to_string),
            bytes_read: f("bytes_read").map(|b| b as u64),
        });
    }
    merged.extend(new);
    Ok(merged)
}

fn print_table(entries: &[BenchEntry]) {
    println!(
        "\n{:<36} {:>10} {:>14} {:>8}  {}",
        "entry", "secs", "cells/s", "rel", "chosen"
    );
    println!("{}", "-".repeat(80));
    for e in entries {
        println!(
            "{:<36} {:>10.4} {:>14.3e} {:>8}  {}",
            e.name,
            e.secs,
            e.cells_per_sec,
            e.rel.map(|r| format!("{r:.2}")).unwrap_or_else(|| "--".into()),
            e.chosen.as_deref().unwrap_or("")
        );
    }
}

fn write_json(
    entries: &[BenchEntry],
    mode: &str,
    seed: u64,
    reps: usize,
    path: &Path,
) -> Result<()> {
    use std::io::Write;
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(w, "{{")?;
    writeln!(w, "  \"schema\": 1,")?;
    writeln!(w, "  \"bench\": \"pallas-bench\",")?;
    writeln!(w, "  \"mode\": \"{}\",", escape(mode))?;
    writeln!(w, "  \"host\": \"{}\",", escape(&host_id()))?;
    writeln!(w, "  \"seed\": {seed},")?;
    writeln!(w, "  \"reps\": {reps},")?;
    writeln!(
        w,
        "  \"kernel\": \"{}\",",
        escape(kernels::active().name())
    )?;
    writeln!(w, "  \"results\": [")?;
    for (i, e) in entries.iter().enumerate() {
        let rel = e.rel.map(|r| format!("{r:.6}")).unwrap_or_else(|| "null".into());
        let chosen = e
            .chosen
            .as_ref()
            .map(|c| format!("\"{}\"", escape(c)))
            .unwrap_or_else(|| "null".into());
        let bytes = e.bytes_read.map(|b| b.to_string()).unwrap_or_else(|| "null".into());
        let comma = if i + 1 == entries.len() { "" } else { "," };
        writeln!(
            w,
            "    {{\"name\": \"{}\", \"rows\": {}, \"cols\": {}, \"density\": {}, \
             \"secs\": {:.6e}, \"cells_per_sec\": {:.6e}, \"rel\": {}, \"chosen\": {}, \
             \"bytes_read\": {}}}{}",
            escape(&e.name), e.rows, e.cols, e.density, e.secs, e.cells_per_sec, rel, chosen,
            bytes, comma
        )?;
    }
    writeln!(w, "  ]")?;
    writeln!(w, "}}")?;
    w.flush()?;
    Ok(())
}

/// Compare this run against a checked-in baseline on two axes:
///
/// * `rel` — scalar-normalized throughput; fails when it fell more
///   than `tolerance` below the baseline value. Machine speed cancels
///   out of the ratio, so this catches one implementation regressing
///   relative to the others.
/// * `min_cells_per_sec` (optional per baseline entry) — an absolute
///   floor, checked as-is. The rel gate is structurally blind to a
///   slowdown that hits *every* kernel equally (including the scalar
///   denominator), so the scalar rows carry a deliberately loose
///   absolute floor to catch shared-path catastrophes.
///
/// Baseline entries absent from this run are **warn-and-skip**, never
/// silent: a per-entry `warning:` line names the entry and says *why*
/// it is absent — "kernel not eligible on this host" for a known ISA
/// kernel the CPU lacks (e.g. the `avx512` rows on an ARM runner,
/// expected) versus "no such measurement in this bench build" for a
/// stale or mistyped baseline name (suspicious) — and a summary line
/// reports the skip count next to the pass verdict, so a gate that
/// checked nothing it was supposed to can be seen in the CI log.
fn check_baseline(entries: &[BenchEntry], path: &Path, tolerance: f64) -> Result<()> {
    let doc = Json::parse(&std::fs::read_to_string(path)?)?;
    let results = doc
        .get("results")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| Error::Parse(format!("{}: no results array", path.display())))?;
    let mut regressions = Vec::new();
    let mut checked = 0usize;
    let mut skipped: Vec<String> = Vec::new();
    for base in results {
        let Some(name) = base.get("name").and_then(|n| n.as_str()) else {
            continue;
        };
        let base_rel = base.get("rel").and_then(|r| r.as_f64());
        let abs_floor = base.get("min_cells_per_sec").and_then(|v| v.as_f64());
        if base_rel.is_none() && abs_floor.is_none() {
            continue; // auto entries and other ungated rows
        }
        let Some(current) = entries.iter().find(|e| e.name == name) else {
            eprintln!(
                "warning: baseline entry '{name}' skipped: {}",
                skip_reason(name, entries)
            );
            skipped.push(name.to_string());
            continue;
        };
        checked += 1;
        if let (Some(base_rel), Some(cur_rel)) = (base_rel, current.rel) {
            let floor = base_rel * (1.0 - tolerance);
            if cur_rel < floor {
                regressions.push(format!(
                    "{name}: rel {cur_rel:.3} < {floor:.3} (baseline {base_rel:.3} minus {:.0}%)",
                    tolerance * 100.0
                ));
            } else {
                println!("baseline OK: {name} rel {cur_rel:.3} (>= {floor:.3})");
            }
        }
        if let Some(abs_floor) = abs_floor {
            if current.cells_per_sec < abs_floor {
                regressions.push(format!(
                    "{name}: {:.3e} cells/s below absolute floor {abs_floor:.3e}",
                    current.cells_per_sec
                ));
            } else {
                println!(
                    "baseline OK: {name} {:.3e} cells/s (abs floor {abs_floor:.3e})",
                    current.cells_per_sec
                );
            }
        }
    }
    if checked == 0 {
        return Err(Error::Parse(format!(
            "{}: baseline contained no comparable entries",
            path.display()
        )));
    }
    if !regressions.is_empty() {
        return Err(Error::Coordinator(format!(
            "perf gate failed, {} regression(s):\n  {}",
            regressions.len(),
            regressions.join("\n  ")
        )));
    }
    if skipped.is_empty() {
        println!("perf gate passed: {checked} entries within {:.0}%", tolerance * 100.0);
    } else {
        println!(
            "perf gate passed: {checked} entries within {:.0}%; {} skipped ({})",
            tolerance * 100.0,
            skipped.len(),
            skipped.join(", ")
        );
    }
    Ok(())
}

/// Why a baseline entry has no matching measurement in this run — the
/// warn-and-skip diagnostic for [`check_baseline`]. Entry ids carry
/// their full identity (kernel name, or `combine/<measure>`, plus the
/// `@dX` density tag), so two per-measure rows can never alias each
/// other here. `measured` is this run's entry set: a baseline row
/// whose prefix *was* measured, just at different densities, is a
/// run-mode mismatch (`--quick` vs full), not an eligibility problem.
fn skip_reason(name: &str, measured: &[BenchEntry]) -> String {
    if let Some((prefix, density)) = name.split_once('@') {
        let same_prefix_other_density = measured
            .iter()
            .any(|e| e.name.split_once('@').is_some_and(|(p, d)| p == prefix && d != density));
        if same_prefix_other_density {
            return format!(
                "density '@{density}' not exercised by this run (baseline from a \
                 different bench mode? --quick and full use different density sets)"
            );
        }
    }
    if let Some(kernel) = name
        .strip_prefix("gram-kernel/")
        .and_then(|rest| rest.split('@').next())
    {
        if kernels::by_name(kernel).is_some() {
            // eligible kernels are always measured; reaching here
            // means the bench section itself did not run
            return format!("kernel '{kernel}' eligible but not measured (partial run?)");
        }
        if kernels::known_names().contains(&kernel) {
            return format!("kernel '{kernel}' not eligible on this host (expected on other ISAs)");
        }
        return format!("kernel '{kernel}' unknown to this bench build (stale baseline?)");
    }
    if let Some(measure) = name
        .strip_prefix("combine/")
        .and_then(|rest| rest.split('@').next())
    {
        if CombineKind::parse(measure).is_some() {
            // every known measure is measured unless --measure narrowed
            // the run
            return format!("measure '{measure}' not in this run's --measure set");
        }
        return format!("measure '{measure}' unknown to this bench build (stale baseline?)");
    }
    "no such measurement in this bench build (stale baseline?)".into()
}

/// Stable-ish host identifier for the output filename:
/// `BULKMI_BENCH_HOST` override, `/etc/hostname`, `$HOSTNAME`, or a
/// fallback — sanitized to filename-safe characters.
fn host_id() -> String {
    let raw = std::env::var("BULKMI_BENCH_HOST")
        .ok()
        .or_else(|| {
            std::fs::read_to_string("/etc/hostname")
                .ok()
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
        })
        .or_else(|| std::env::var("HOSTNAME").ok().filter(|s| !s.is_empty()))
        .unwrap_or_else(|| "unknown-host".to_string());
    let safe: String = raw
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') { c } else { '-' })
        .collect();
    if safe.is_empty() {
        "unknown-host".into()
    } else {
        safe
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("bulkmi-bench-{}-{name}", std::process::id()))
    }

    #[test]
    fn host_id_is_filename_safe() {
        let id = host_id();
        assert!(!id.is_empty());
        assert!(id
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-')));
    }

    #[test]
    fn timed_median_is_positive_and_ordered() {
        let secs = timed_median(3, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(secs > 0.0);
    }

    #[test]
    fn json_round_trips_and_gate_passes_against_itself() {
        let entries = vec![
            BenchEntry {
                name: "gram-kernel/scalar@d0.50".into(),
                rows: 64,
                cols: 8,
                density: 0.5,
                secs: 0.5,
                cells_per_sec: 128.0,
                rel: Some(1.0),
                chosen: None,
                bytes_read: None,
            },
            BenchEntry {
                name: "backend-auto@d0.50".into(),
                rows: 64,
                cols: 8,
                density: 0.5,
                secs: 0.1,
                cells_per_sec: 0.0,
                rel: None,
                chosen: Some("bulk-bitpack".into()),
                bytes_read: Some(4096),
            },
        ];
        let path = tmp("roundtrip.json");
        write_json(&entries, "quick", 1, 3, &path).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_f64(), Some(1.0));
        let results = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(
            results[1].get("chosen").unwrap().as_str(),
            Some("bulk-bitpack")
        );
        // bytes_read survives the round trip: null when absent, the
        // raw counter when present
        assert!(results[0].get("bytes_read").unwrap().as_f64().is_none());
        assert_eq!(results[1].get("bytes_read").unwrap().as_f64(), Some(4096.0));
        // a run always passes a gate against its own numbers
        check_baseline(&entries, &path, 0.30).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn gate_catches_regressions() {
        let good = vec![BenchEntry {
            name: "gram-kernel/portable@d0.50".into(),
            rows: 64,
            cols: 8,
            density: 0.5,
            secs: 0.5,
            cells_per_sec: 128.0,
            rel: Some(2.0),
            chosen: None,
            bytes_read: None,
        }];
        let path = tmp("gate.json");
        write_json(&good, "quick", 1, 3, &path).unwrap();
        let regressed = vec![BenchEntry { rel: Some(1.0), ..gate_entry() }];
        assert!(check_baseline(&regressed, &path, 0.30).is_err());
        // within tolerance passes
        let ok = vec![BenchEntry { rel: Some(1.5), ..gate_entry() }];
        check_baseline(&ok, &path, 0.30).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn gate_enforces_absolute_floor() {
        let path = tmp("abs-gate.json");
        std::fs::write(
            &path,
            r#"{"results": [
                {"name": "gram-kernel/portable@d0.50", "min_cells_per_sec": 1000.0}
            ]}"#,
        )
        .unwrap();
        // cells_per_sec 128 < floor 1000: shared-path catastrophe caught
        // even though no `rel` is gated
        assert!(check_baseline(&[gate_entry()], &path, 0.30).is_err());
        let fast = vec![BenchEntry { cells_per_sec: 5000.0, ..gate_entry() }];
        check_baseline(&fast, &path, 0.30).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn gate_warns_and_skips_unmatched_entries() {
        let path = tmp("skip-gate.json");
        std::fs::write(
            &path,
            r#"{"results": [
                {"name": "gram-kernel/portable@d0.50", "rel": 1.0},
                {"name": "gram-kernel/neon@d0.50", "rel": 1.0},
                {"name": "gram-kernel/warp@d0.50", "rel": 1.0}
            ]}"#,
        )
        .unwrap();
        // the unmatched rows are skipped (with a warning), not failed,
        // and the matched row still gates
        check_baseline(&[gate_entry()], &path, 0.30).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn skip_reasons_distinguish_ineligible_from_stale() {
        // a kernel the crate ships for another architecture
        let foreign = if cfg!(target_arch = "x86_64") { "neon" } else { "avx2" };
        let reason = skip_reason(&format!("gram-kernel/{foreign}@d0.50"), &[]);
        assert!(reason.contains("not eligible"), "{reason}");
        // a name no build of this bench ever produces
        assert!(skip_reason("gram-kernel/warp@d0.50", &[]).contains("stale"), "warp");
        assert!(skip_reason("backend-gram/bogus@d0.50", &[]).contains("stale"), "bogus");
    }

    #[test]
    fn combine_skip_reasons_carry_the_measure_id() {
        // a known measure missing from the run: named, not aliased
        let known = skip_reason("combine/jaccard@d0.50", &[]);
        assert!(known.contains("jaccard"), "{known}");
        assert!(!known.contains("stale"), "{known}");
        // an unknown measure name is flagged as stale
        let stale = skip_reason("combine/pearson@d0.50", &[]);
        assert!(stale.contains("stale"), "{stale}");
        assert!(stale.contains("pearson"), "{stale}");
    }

    #[test]
    fn skip_reasons_detect_density_mode_mismatch() {
        // the same prefix was measured, just at other densities: a
        // --quick run checked against a full-mode baseline row
        let run = vec![gate_entry()]; // measured: gram-kernel/portable@d0.50
        let reason = skip_reason("gram-kernel/portable@d0.10", &run);
        assert!(reason.contains("@d0.10"), "{reason}");
        assert!(reason.contains("bench mode"), "{reason}");
        // a genuinely foreign prefix still falls through to the
        // eligibility / staleness diagnosis
        assert!(skip_reason("combine/pearson@d0.10", &run).contains("stale"));
    }

    #[test]
    fn bad_measure_arg_rejected() {
        assert!(bench(&sv(&["--measure", "pearson"])).is_err());
    }

    fn gate_entry() -> BenchEntry {
        BenchEntry {
            name: "gram-kernel/portable@d0.50".into(),
            rows: 64,
            cols: 8,
            density: 0.5,
            secs: 0.5,
            cells_per_sec: 128.0,
            rel: Some(1.0),
            chosen: None,
            bytes_read: None,
        }
    }

    #[test]
    fn table_kernels_beat_the_scalar_combine_loop() {
        // the quick-bench Gram block (8192x160 at density 0.5): the
        // table-driven block kernels must map it at >= 3x the per-cell
        // scalar-`combine` loop for mi and nmi — the speedup the
        // monomorphized-kernel rewrite exists to deliver
        let ds = SynthSpec::new(8_192, 160).sparsity(0.5).seed(42).generate();
        let entries =
            bench_combine(&ds, 0.5, 3, &[CombineKind::Mi, CombineKind::Nmi]);
        assert_eq!(entries.len(), 3);
        assert_eq!(entries[0].name, "combine-scalar@d0.50");
        assert_eq!(entries[0].rel, Some(1.0));
        assert!(entries[0].cells_per_sec > 0.0);
        for want in ["combine/mi@d0.50", "combine/nmi@d0.50"] {
            let e = entries.iter().find(|e| e.name == want).unwrap();
            let rel = e.rel.unwrap();
            assert!(
                rel >= 3.0,
                "{want}: table-driven kernel is only {rel:.2}x the scalar loop"
            );
        }
    }

    #[test]
    fn ooc_entries_report_bytes_and_ratio() {
        // small but real: 64 cols in 8 blocks off a temp .bmat v2 file
        let entries = bench_ooc(256, 64, 0.5, 7).unwrap();
        assert_eq!(entries.len(), 2);
        let uncached = &entries[0];
        let cached = &entries[1];
        assert_eq!(uncached.name, "oocgram/uncached@d0.50");
        assert_eq!(cached.name, "oocgram/cached@d0.50");
        let ub = uncached.bytes_read.unwrap();
        let cb = cached.bytes_read.unwrap();
        assert!(ub > 0 && cb > 0);
        // the whole point of the cache: the panel schedule re-reads
        // nothing, so the uncached run moves at least 2x the bytes
        assert!(ub >= 2 * cb, "uncached {ub} vs cached {cb}");
        assert_eq!(cached.rel, Some(ub as f64 / cb as f64));
        assert_eq!(uncached.rel, None);
    }

    #[test]
    fn tilecache_entries_report_hit_fraction() {
        // 64 cols in 8 blocks: 36 tiles, cold writes all of them, warm
        // serves every one from disk
        let entries = bench_tilecache(256, 64, 0.5, 7).unwrap();
        assert_eq!(entries.len(), 2);
        let cold = &entries[0];
        let warm = &entries[1];
        assert_eq!(cold.name, "tile-cache/cold@d0.50");
        assert_eq!(warm.name, "tile-cache/warm@d0.50");
        assert_eq!(cold.rel, None, "the cold row is a reference, never gated");
        assert_eq!(warm.rel, Some(1.0), "a warm run must be pure hits");
        assert!(cold.bytes_read.unwrap() > 0, "the cold run writes tiles");
        assert_eq!(warm.bytes_read, Some(0), "a pure-hit run writes nothing");
    }

    #[test]
    fn cluster_bench_rows_are_exact_and_ungated() {
        // small but real: 36 tasks over loopback TCP, 1/2/4 workers,
        // each row recorded only after bit-exact verification inside
        // bench_cluster itself
        let entries = bench_cluster(256, 64, 0.5, 7).unwrap();
        let names: Vec<&str> = entries.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(
            names,
            [
                "cluster/single-process@d0.50",
                "cluster/workers-1@d0.50",
                "cluster/workers-2@d0.50",
                "cluster/workers-4@d0.50",
            ]
        );
        // warn-only by construction: no rel means check_baseline never
        // gates on a scaling row
        assert!(entries.iter().all(|e| e.rel.is_none()));
        assert!(entries.iter().all(|e| e.secs > 0.0 && e.cells_per_sec > 0.0));
    }

    #[test]
    fn merge_entries_replaces_cluster_rows_and_keeps_the_rest() {
        let path = tmp("merge.json");
        let old = vec![
            gate_entry(),
            BenchEntry {
                name: "cluster/workers-2@d0.50".into(),
                cells_per_sec: 1.0,
                ..gate_entry()
            },
        ];
        write_json(&old, "quick", 1, 3, &path).unwrap();
        let fresh = vec![BenchEntry {
            name: "cluster/workers-2@d0.50".into(),
            cells_per_sec: 999.0,
            rel: None,
            ..gate_entry()
        }];
        let merged = merge_entries(fresh, &path).unwrap();
        assert_eq!(merged.len(), 2);
        // the non-cluster row survives with its fields intact
        assert_eq!(merged[0].name, "gram-kernel/portable@d0.50");
        assert_eq!(merged[0].rel, Some(1.0));
        assert_eq!(merged[0].rows, 64);
        // the stale cluster row is replaced, not duplicated
        assert_eq!(merged[1].name, "cluster/workers-2@d0.50");
        assert_eq!(merged[1].cells_per_sec, 999.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn merge_entries_starts_fresh_without_a_file_and_rejects_garbage() {
        let missing = tmp("merge-missing.json");
        let _ = std::fs::remove_file(&missing);
        let merged = merge_entries(vec![gate_entry()], &missing).unwrap();
        assert_eq!(merged.len(), 1);
        let garbage = tmp("merge-garbage.json");
        std::fs::write(&garbage, "not json {").unwrap();
        assert!(merge_entries(vec![gate_entry()], &garbage).is_err());
        let _ = std::fs::remove_file(&garbage);
    }

    #[test]
    fn cluster_bench_rejects_bad_args() {
        assert!(cluster_bench(&sv(&["--sparsity", "1.5"])).is_err());
        assert!(cluster_bench(&sv(&["--cols", "1"])).is_err());
        assert!(cluster_bench(&sv(&["--bogus", "1"])).is_err());
    }

    #[test]
    fn quick_bench_end_to_end_writes_json() {
        // tiny end-to-end through the real plumbing is covered by the
        // cheaper unit tests above; the full run is exercised by CI's
        // perf-smoke job (`bulkmi bench --quick`). Here we only verify
        // argument validation.
        assert!(bench(&sv(&["--tolerance", "2.0"])).is_err());
        assert!(bench(&sv(&["--reps", "0"])).is_err());
        assert!(bench(&sv(&["--bogus", "1"])).is_err());
    }
}
