//! Command-line interface (own arg parser — no `clap` offline).
//!
//! Subcommands: `generate`, `pack`, `compute`, `analyze`, `info`,
//! `selftest`, `serve`, `bench`. Run `bulkmi help` for usage.

pub mod args;
pub mod benchcmd;
pub mod commands;

use crate::util::error::Result;

pub const USAGE: &str = "\
bulkmi — fast bulk mutual information for large binary datasets
(reproduction of Falcao 2024; three-layer Rust + JAX + Pallas stack)

USAGE:
    bulkmi <command> [options]

COMMANDS:
    generate    Generate a synthetic binary dataset
        --rows N --cols M [--sparsity S=0.9] [--seed K=0]
        [--plant A:B:NOISE ...] --out FILE.{csv,bmat}
        (.bmat output is the v2 column-major packed format, which
        compute/serve stream blockwise without loading the dataset)
    pack        Convert CSV / .bmat v1 to the streaming .bmat v2 format
        --input FILE.{csv,bmat} --out FILE.bmat [--chunk-rows N=8192]
        converts one row chunk at a time — the dataset is never
        materialized, so inputs of any size pack in bounded memory
    compute     Compute MI (or any measure) for a dataset
        --input FILE.{csv,bmat} [--backend NAME=bulk-bitpack]
        [--measure mi|nmi|vi|gstat|chi2|phi|jaccard|ochiai]
        [--workers N | --workers HOST:PORT,...] [--block-cols B=0]
        [--memory-budget BYTES=0]
        [--task-latency SECS=2] [--top K=10]
        [--cache-budget BYTES] [--readahead N=1] [--tiles]
        [--sink dense|topk:K|topk-per-col:K|threshold:T|pvalue:P|spill:DIR]
        [--normalize min|max|mean|joint] [--out FILE.csv]
        [--config FILE.toml]
        non-dense sinks run matrix-free: memory stays O(block^2) no
        matter how many columns the dataset has; a .bmat v2 input
        additionally streams the *input* side — column blocks are
        positioned-read off disk, so a run never holds more than
        task_bytes(n, b) of the dataset; streamed runs get a block
        substrate cache (auto-sized from half the memory budget;
        --cache-budget overrides, 0 disables) with a cache-aware panel
        schedule and --readahead tasks of prefetch, so each block is
        read once instead of once per task; --backend auto micro-probes
        the native substrates and commits to the fastest; every
        measure rides the same single Gram (sinks rank/threshold in
        the measure's units; pvalue: composes with mi and gstat only);
        --tiles caches finished Gram tiles content-addressed under
        BULKMI_CACHE_DIR (or a temp dir), so re-runs over the same
        data skip the Gram stage entirely; --workers HOST:PORT,...
        runs distributed instead: start a `bulkmi worker` per address
        over the same input file, the coordinator resolves the run
        once, shards the task schedule, merges sink states, and
        retries tasks whose worker dies — output stays bit-identical
        to the single-process run
    resume      Resume an interrupted spill-sink run
        bulkmi resume DIR
        DIR is a spill:DIR directory from an interrupted compute run:
        the incremental manifest is replayed, every completed tile is
        verified (length + checksum), and only the missing tiles are
        recomputed — zero finished work is repeated. Exits 0
        immediately when the run is already complete.
    analyze     MI with statistical post-processing + edge-list export
        --input FILE [--backend NAME] [--top K=10]
        [--bias-correction miller-madow] [--permutations P=0]
        [--threshold T=0] [--edges-out FILE.csv]
    info        Show artifact registry and backend availability
        [--artifacts DIR]
    selftest    Cross-check every available backend on random data
        [--rows N=500] [--cols M=40] [--with-xla]
    serve       Run the job server (HTTP, stdin wire, or local demo)
        HTTP mode:  --listen ADDR:PORT [--dataset NAME=PATH ...]
            [--workers N=2] [--max-queued Q=64] [--memory-budget BYTES]
            [--config FILE.toml]   ([serve] section: listen, workers,
            max_queued, memory_budget; flags override)
            JSON/HTTP job API over the v1 wire schema: POST /v1/jobs
            {\"v\":1,\"dataset\":NAME,...}, GET /v1/jobs/ID,
            GET /v1/jobs/ID/result, POST /v1/jobs/ID/cancel,
            GET /metrics, POST /v1/admin/drain; --memory-budget caps
            aggregate resident bytes across concurrent jobs (over-
            budget jobs queue; interactive sinks jump batch); port 0
            picks a free port (printed as `serving on http://...`);
            SIGINT/SIGTERM drain in-flight jobs, then exit 0
        stdin mode: --stdin [--dataset NAME=PATH ...] [same sizing]
            one v1 JSON job request per stdin line, one result
            envelope per stdout line
        demo mode (no --listen/--stdin/--config):
            [--workers N] [--max-queued Q=4] [--jobs J=8] [--block-cols B]
            [--backend NAME=bulk-bitpack] [--measure NAME=mi]
            [--sink dense|topk:K|topk-per-col:K|threshold:T|pvalue:P|spill:DIR]
            [--input FILE.{csv,bmat}]
            with --input every job runs over that file (a .bmat v2 file
            is streamed blockwise off disk); without it, demo datasets
            are generated per job
    worker      Serve block tasks to a cluster coordinator, then exit
        --connect ADDR:PORT --input FILE.{csv,bmat}
        binds ADDR:PORT (port 0 picks a free port, logged on bind),
        accepts one coordinator connection, computes each dispatched
        (col-block, col-block) task with the single-process core, and
        streams only its own blocks from FILE — point every worker
        and the coordinator at the same dataset
    cluster     Cluster tooling
        bench [--rows N=4096] [--cols M=256] [--sparsity S=0.9]
            [--seed K=42] [--out FILE.json] [--baseline FILE.json]
            local-loopback scaling suite: one dataset, single-process
            baseline plus 1/2/4 in-process workers; appends
            cluster/workers-K rows to the bench JSON (warn-only: rows
            carry no rel value, so --baseline never gates on them)
    bench       Deterministic Gram/kernel perf suite (alias: pallas-bench)
        [--quick] [--seed K=42] [--reps R] [--out FILE.json]
        [--baseline FILE.json] [--tolerance F=0.30] [--measure NAME ...]
        writes BENCH_<host>.json; with --baseline, fails when any Gram
        entry's scalar-normalized throughput regresses past tolerance;
        combine/<measure> rows time the combine stage per measure
        (--measure repeatable; default: all)
    help        Show this message

BACKENDS:
    pairwise bulk-basic bulk-opt bulk-sparse bulk-bitpack auto xla xla-pallas
    (auto = probe bulk-opt / bulk-sparse / bulk-bitpack on a sampled
    block, then run everything on the winner)

MEASURES (--measure, all from the same one-Gram pipeline):
    mi nmi vi gstat chi2 phi jaccard ochiai

ENVIRONMENT:
    BULKMI_LOG=error|warn|info|debug|trace    log level (default info)
    BULKMI_ARTIFACTS=DIR                      artifact directory
    BULKMI_CACHE_DIR=DIR                      persistent cache root: Gram
                                              tiles (DIR/tiles) and autotune
                                              probe verdicts (guarded by a
                                              hardware fingerprint) survive
                                              across processes
    BULKMI_KERNEL=scalar|portable|avx2|avx512|neon
                                              force the Gram kernel (a name
                                              not eligible on this CPU is a
                                              hard error)
    BULKMI_BENCH_HOST=NAME                    override bench host tag
";

/// CLI entry point; returns the process exit code.
pub fn run(argv: &[String]) -> i32 {
    match dispatch(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    // fail fast on a bad BULKMI_KERNEL before any work starts, with a
    // clean CLI error instead of the dispatch table's late hard error
    crate::linalg::kernels::validate_env_override()?;
    let rest = &argv[1..];
    match cmd.as_str() {
        "generate" => commands::generate(rest),
        "pack" => commands::pack(rest),
        "compute" => commands::compute(rest),
        "resume" => commands::resume(rest),
        "analyze" => commands::analyze(rest),
        "info" => commands::info(rest),
        "selftest" => commands::selftest(rest),
        "serve" => commands::serve(rest),
        "worker" => commands::worker(rest),
        "cluster" => commands::cluster(rest),
        "bench" | "pallas-bench" => benchcmd::bench(rest),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(crate::util::error::Error::Parse(format!(
            "unknown command '{other}' (try `bulkmi help`)"
        ))),
    }
}
