//! Subcommand implementations.

use super::args::Args;
use crate::config::{RawConfig, RunConfig, ServeConfig};
use crate::coordinator::blockcache::{cache_plan, run_reports, BlockCache, CacheHandle};
use crate::coordinator::planner::{
    block_policy, matrix_free_block, plan_blocks, plan_with_config, PlannerConfig,
};
use crate::coordinator::progress::Progress;
use crate::coordinator::scheduler::{order_tasks, Schedule};
use crate::coordinator::service::{JobService, JobSpec, JobStatus};
use crate::coordinator::tilecache::{
    default_tile_root, tile_report, TileCache, DEFAULT_TILE_BUDGET,
};
use crate::coordinator::{run_plan, run_plan_dense, run_plan_tiled, NativeProvider};
use crate::data::colstore::{ColumnSource, InMemorySource, PackedFileSource};
use crate::data::dataset::BinaryDataset;
use crate::data::io;
use crate::data::synth::SynthSpec;
use crate::mi::backend::{compute_measure_with, compute_mi_with, Backend};
use crate::mi::entropy::{entropies_from_counts, normalized_mi_with, Normalization};
use crate::mi::measure::CombineKind;
use crate::mi::sink::{BlockSizing, SinkData, SinkSpec};
use crate::mi::topk::{top_k_pairs, MiPair};
use crate::mi::MiMatrix;
use crate::runtime::ArtifactRegistry;
use crate::server::{signal, wire, Server, ServerConfig};
use crate::util::error::{Error, Result};
use crate::util::timer::{fmt_secs, time_it};
use std::path::{Path, PathBuf};
use std::sync::Arc;

pub fn generate(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let rows = args.req("rows")?.parse::<usize>().map_err(|_| bad("rows"))?;
    let cols = args.req("cols")?.parse::<usize>().map_err(|_| bad("cols"))?;
    let sparsity = args.get_f64("sparsity", 0.9)?;
    let seed = args.get_u64("seed", 0)?;
    let out = PathBuf::from(args.req("out")?);
    let mut spec = SynthSpec::new(rows, cols).sparsity(sparsity).seed(seed);
    for p in args.get_all("plant") {
        let parts: Vec<&str> = p.split(':').collect();
        if parts.len() != 3 {
            return Err(Error::Parse(format!("--plant expects A:B:NOISE, got '{p}'")));
        }
        let a = parts[0].parse().map_err(|_| bad("plant"))?;
        let b = parts[1].parse().map_err(|_| bad("plant"))?;
        let noise = parts[2].parse().map_err(|_| bad("plant"))?;
        spec = spec.plant(a, b, noise);
    }
    args.reject_unknown()?;
    let (ds, secs) = time_it(|| spec.generate());
    save_dataset(&ds, &out)?;
    crate::info!(
        "generated {}x{} (sparsity {:.3}) in {} -> {}",
        ds.n_rows(),
        ds.n_cols(),
        ds.sparsity(),
        fmt_secs(secs),
        out.display()
    );
    Ok(())
}

pub fn compute(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    // config file gives defaults; explicit options override
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::load(Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(b) = args.get("backend") {
        cfg.backend = wire::parse_backend(b)?;
    }
    if let Some(m) = args.get("measure") {
        cfg.measure = wire::parse_measure(m)?;
    }
    // --workers is overloaded: a plain number is the local thread
    // count, a comma-separated host:port list is a distributed run
    // against `bulkmi worker` processes (crate::cluster)
    let cluster_workers: Vec<String> = match args.get("workers") {
        Some(v) if v.contains(':') => {
            v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
        }
        _ => {
            cfg.workers = args.get_usize("workers", cfg.workers)?;
            Vec::new()
        }
    };
    cfg.block_cols = args.get_usize("block-cols", cfg.block_cols)?;
    cfg.memory_budget = args.get_usize("memory-budget", cfg.memory_budget)?;
    cfg.task_latency_secs = args.get_f64("task-latency", cfg.task_latency_secs)?;
    if !cfg.task_latency_secs.is_finite() || cfg.task_latency_secs <= 0.0 {
        return Err(Error::Parse(
            "--task-latency must be a positive number of seconds".into(),
        ));
    }
    if let Some(v) = args.get("cache-budget") {
        cfg.cache_bytes = Some(v.parse().map_err(|_| {
            Error::Parse(format!("--cache-budget expects bytes, got '{v}' (0 disables)"))
        })?);
    }
    cfg.readahead = args.get_usize("readahead", cfg.readahead)?;
    cfg.tiles = cfg.tiles || args.flag("tiles");
    let input = PathBuf::from(args.req("input")?);
    let top = args.get_usize("top", 10)?;
    let normalize = args.get("normalize").map(|s| s.to_string());
    let out = args.get("out").map(PathBuf::from);
    let sink = SinkSpec::parse(args.get("sink").unwrap_or("dense"))?;
    args.reject_unknown()?;

    if normalize.is_some() && cfg.measure != CombineKind::Mi {
        return Err(Error::Parse(format!(
            "--normalize applies to raw MI only, not measure '{}' (nmi is itself \
             --measure nmi)",
            cfg.measure
        )));
    }
    if !sink.is_dense() && normalize.is_some() {
        return Err(Error::Parse("--normalize requires --sink dense".into()));
    }

    if !cluster_workers.is_empty() {
        return compute_cluster(
            &input,
            &cfg,
            &cluster_workers,
            &sink,
            top,
            normalize.as_deref(),
            out.as_deref(),
        );
    }

    if io::is_bmat_v2(&input)? && cfg.backend.is_native() {
        // streaming input: column blocks come straight off disk, so
        // peak input RAM is one task's working set — never the dataset.
        // Non-native (XLA) backends fall through to the in-memory load
        // below, which reads v2 too — slower, but the capability stays.
        return compute_packed(&input, &cfg, &sink, top, normalize.as_deref(), out.as_deref());
    }

    let ds = io::load(&input)?;
    crate::info!(
        "loaded {}x{} (sparsity {:.3}) from {}",
        ds.n_rows(),
        ds.n_cols(),
        ds.sparsity(),
        input.display()
    );

    if !sink.is_dense() {
        // matrix-free / out-of-core path: never builds the m x m matrix
        let src = InMemorySource::new(&ds);
        return compute_into_sink(&src, &input, &cfg, &sink, top, out.as_deref());
    }

    let (mi, secs) = compute_with_plan(&ds, &cfg)?;
    println!(
        "computed {}x{} {} matrix with {} in {}",
        mi.dim(),
        mi.dim(),
        cfg.measure,
        cfg.backend,
        fmt_secs(secs)
    );
    finish_dense(mi, &ds, normalize.as_deref(), 0, top, out.as_deref())
}

fn parse_normalization(norm: &str) -> Result<Normalization> {
    match norm {
        "min" => Ok(Normalization::Min),
        "max" => Ok(Normalization::Max),
        "mean" => Ok(Normalization::Mean),
        "joint" => Ok(Normalization::Joint),
        other => Err(Error::Parse(format!("unknown normalization '{other}'"))),
    }
}

/// Shared tail of the dense-matrix paths (in-memory and streamed):
/// optional normalization — marginal entropies come from the source's
/// column counts, fetched in `counts_chunk`-col blocks (0 = one fetch;
/// one extra chunked pass over a streamed payload, noise next to the
/// n_blocks passes the m² Gram work just made) — then the top-pair
/// listing and the matrix CSV export.
fn finish_dense(
    mi: MiMatrix,
    src: &dyn ColumnSource,
    normalize: Option<&str>,
    counts_chunk: usize,
    top: usize,
    out: Option<&Path>,
) -> Result<()> {
    let display = match normalize {
        None => mi,
        Some(norm) => {
            let h = entropies_from_counts(&src.all_col_counts(counts_chunk)?, src.n_rows());
            normalized_mi_with(&h, &mi, parse_normalization(norm)?)
        }
    };
    if top > 0 {
        println!("top {top} pairs:");
        for p in top_k_pairs(&display, top) {
            println!(
                "  {:<20} {:<20} {:.6}",
                src.col_name(p.i),
                src.col_name(p.j),
                p.mi
            );
        }
    }
    if let Some(path) = out {
        write_mi_csv(&display, src, path)?;
        crate::info!("wrote MI matrix to {}", path.display());
    }
    Ok(())
}

/// `compute` over a `.bmat` v2 file: column blocks stream off disk
/// through a [`PackedFileSource`], so the input side never loads more
/// than one task's working set (`task_bytes(n, b)`). Matrix-free sinks
/// keep the whole run out-of-core; the dense sink still materializes
/// the m x m *result* (that is what it is for).
fn compute_packed(
    input: &Path,
    cfg: &RunConfig,
    sink: &SinkSpec,
    top: usize,
    normalize: Option<&str>,
    out: Option<&Path>,
) -> Result<()> {
    if !cfg.backend.is_native() {
        // `compute` routes non-native backends to the in-memory load
        // instead; this guard only protects direct callers
        return Err(Error::Parse(format!(
            "streaming .bmat v2 input needs a native backend, not '{}'",
            cfg.backend
        )));
    }
    let src = PackedFileSource::open(input)?;
    if src.n_rows() == 0 || src.n_cols() == 0 {
        return Err(Error::Shape("empty dataset".into()));
    }
    crate::info!(
        "streaming {}x{} column source from {} ({} packed payload bytes on disk)",
        src.n_rows(),
        src.n_cols(),
        input.display(),
        src.payload_bytes()
    );
    if !sink.is_dense() {
        return compute_into_sink(&src, input, cfg, sink, top, out);
    }
    // dense sink: blockwise through the source into the full matrix
    let (backend, probe) = cfg.backend.resolve_source(&src)?;
    if let Some(report) = &probe {
        crate::info!("{}", report.summary());
    }
    let (cache, task_budget) = cache_setup(cfg, &src);
    let (block, sizing_source) = block_policy(
        cfg.block_cols,
        probe.as_ref().map(|r| r.chosen_throughput()),
        probe.as_ref().and_then(|r| r.combine_throughput(cfg.measure)),
        src.n_rows(),
        src.n_cols(),
        task_budget,
        cfg.task_latency_secs,
        (matrix_free_block(src.n_rows(), src.n_cols(), task_budget), "budget"),
    );
    let mut plan = plan_blocks(src.n_cols(), block)?;
    let schedule = pick_schedule(&cache, &src);
    order_tasks(&mut plan.tasks, schedule);
    crate::info!(
        "streaming dense plan: {} tasks, block {} cols ({sizing_source}), {} order",
        plan.tasks.len(),
        plan.block,
        schedule.name()
    );
    let provider = match &cache {
        Some(c) => NativeProvider::with_cache(
            &src,
            backend.native_kind(),
            CacheHandle::fresh(Arc::clone(c)),
            cfg.readahead,
        ),
        None => NativeProvider::new(&src, backend.native_kind()),
    };
    let io0 = src.io_stats();
    let cache0 = cache.as_ref().map(|c| c.stats());
    let progress = Progress::new(plan.tasks.len());
    let t0 = std::time::Instant::now();
    let mi = run_plan_dense(&src, &plan, &provider, cfg.workers, &progress, cfg.measure)?;
    println!(
        "computed {}x{} {} matrix with {} in {}",
        mi.dim(),
        mi.dim(),
        cfg.measure,
        backend,
        fmt_secs(t0.elapsed().as_secs_f64())
    );
    report_io(&src, io0, cache.as_deref().zip(cache0));
    finish_dense(mi, &src, normalize, plan.block, top, out)
}

/// The CLI mirror of the job service's cache decision: resolve the
/// cache budget (and the task budget left after the carve) from the
/// run config, building the cache when one is called for.
fn cache_setup(cfg: &RunConfig, src: &dyn ColumnSource) -> (Option<Arc<BlockCache>>, usize) {
    let (cache_budget, task_budget) =
        cache_plan(cfg.cache_bytes, src.out_of_core(), cfg.memory_budget);
    (cache_budget.map(|b| Arc::new(BlockCache::new(b))), task_budget)
}

/// Schedule resolution: cache-friendly panel order for cached
/// out-of-core runs, the tail-friendly largest-first otherwise.
fn pick_schedule(cache: &Option<Arc<BlockCache>>, src: &dyn ColumnSource) -> Schedule {
    if cache.is_some() && src.out_of_core() {
        Schedule::Panel
    } else {
        Schedule::LargestFirst
    }
}

/// Log the run's read traffic and cache behaviour (the CLI equivalent
/// of the `SinkMeta` io/cache fields), and return the reports for
/// callers that do have a meta to fill.
fn report_io(
    src: &dyn ColumnSource,
    io_before: Option<crate::data::colstore::IoStats>,
    cache: Option<(&BlockCache, crate::coordinator::blockcache::CacheStats)>,
) -> (
    Option<crate::mi::sink::IoReport>,
    Option<crate::mi::sink::CacheReport>,
) {
    let (io, cache_report) = run_reports(src, io_before, cache);
    if let Some(io) = &io {
        crate::info!(
            "io: {} bytes in {} reads ({:.2}x read amplification, {} in reads)",
            io.bytes_read,
            io.reads,
            io.read_amplification,
            fmt_secs(io.read_secs)
        );
    }
    if let Some(c) = &cache_report {
        crate::info!(
            "cache: {} hits / {} misses ({} prefetched, {} evictions, {} stalled)",
            c.hits,
            c.misses,
            c.prefetched,
            c.evictions,
            fmt_secs(c.stall_secs)
        );
    }
    (io, cache_report)
}

/// Compute respecting block/budget settings (blockwise plans go through
/// the coordinator; monolithic through the plain backend).
pub fn compute_with_plan(ds: &BinaryDataset, cfg: &RunConfig) -> Result<(MiMatrix, f64)> {
    let planner = PlannerConfig {
        block_cols: cfg.block_cols,
        memory_budget: cfg.memory_budget,
        n_rows: ds.n_rows(),
    };
    let needs_plan = cfg.block_cols > 0 || cfg.memory_budget > 0;
    if needs_plan && cfg.backend.is_native() {
        let (backend, probe) = cfg.backend.resolve(ds)?;
        if let Some(report) = &probe {
            crate::info!("{}", report.summary());
        }
        let kind = backend.native_kind();
        let plan = plan_with_config(ds.n_cols(), &planner)?;
        crate::info!(
            "blockwise plan: {} tasks, block {} cols",
            plan.tasks.len(),
            plan.block
        );
        let src = InMemorySource::new(ds);
        let provider = NativeProvider::new(&src, kind);
        let progress = Progress::new(plan.tasks.len());
        let t0 = std::time::Instant::now();
        let mi = run_plan_dense(&src, &plan, &provider, cfg.workers, &progress, cfg.measure)?;
        Ok((mi, t0.elapsed().as_secs_f64()))
    } else {
        let t0 = std::time::Instant::now();
        let mi = compute_measure_with(ds, cfg.backend, cfg.workers, cfg.measure)?;
        Ok((mi, t0.elapsed().as_secs_f64()))
    }
}

/// Matrix-free `compute`: blockwise plan + any non-dense sink, over
/// any [`ColumnSource`] — in-memory and streaming inputs share this
/// path verbatim. The block size defaults to the planner's matrix-free
/// budget rule, so memory stays bounded no matter how many columns
/// (or, with a [`PackedFileSource`], how many bytes) the input has.
fn compute_into_sink(
    src: &dyn ColumnSource,
    input: &Path,
    cfg: &RunConfig,
    spec: &SinkSpec,
    top: usize,
    out: Option<&Path>,
) -> Result<()> {
    if !cfg.backend.is_native() {
        return Err(Error::Parse(format!(
            "--sink needs a native backend, not '{}'",
            cfg.backend
        )));
    }
    if matches!(spec, SinkSpec::Spill { .. }) && out.is_some() {
        return Err(Error::Parse(
            "--out is not supported with --sink spill (tiles + manifest.csv go to DIR)".into(),
        ));
    }
    let (backend, probe) = cfg.backend.resolve_source(src)?;
    if let Some(report) = &probe {
        crate::info!("{}", report.summary());
    }
    // Explicit block size wins; otherwise an auto run folds the
    // probe's throughput into the width (faster substrates afford
    // larger blocks under the same latency target) and fixed backends
    // use the memory-budget rule (shrunk by the cache carve on
    // out-of-core runs, so cache + task working set share the budget).
    let (cache, task_budget) = cache_setup(cfg, src);
    let combine_tput = probe.as_ref().and_then(|r| r.combine_throughput(cfg.measure));
    let (block, sizing_source) = block_policy(
        cfg.block_cols,
        probe.as_ref().map(|r| r.chosen_throughput()),
        combine_tput,
        src.n_rows(),
        src.n_cols(),
        task_budget,
        cfg.task_latency_secs,
        (matrix_free_block(src.n_rows(), src.n_cols(), task_budget), "budget"),
    );
    let mut plan = plan_blocks(src.n_cols(), block)?;
    let schedule = pick_schedule(&cache, src);
    order_tasks(&mut plan.tasks, schedule);
    crate::info!(
        "matrix-free plan: {} tasks, block {} cols ({sizing_source}), {} order",
        plan.tasks.len(),
        plan.block,
        schedule.name()
    );
    let mut sink = spec.build_for(src.n_cols(), src.n_rows(), cfg.measure)?;
    if let SinkSpec::Spill { dir } = spec {
        // leave a resume descriptor next to the manifest so an
        // interrupted run can be finished by `bulkmi resume DIR` with
        // the exact same plan (same resolved backend and block size
        // keep the remaining tiles bit-identical to an uninterrupted run)
        write_resume_descriptor(dir, input, backend, cfg.measure, plan.block, cfg.workers)?;
    }
    let tiles = cfg.tiles.then(|| TileCache::open(default_tile_root(), DEFAULT_TILE_BUDGET));
    let tiles0 = tiles.as_ref().map(|c| c.stats());
    let provider = match &cache {
        Some(c) => NativeProvider::with_cache(
            src,
            backend.native_kind(),
            CacheHandle::fresh(Arc::clone(c)),
            cfg.readahead,
        ),
        None => NativeProvider::new(src, backend.native_kind()),
    };
    let io0 = src.io_stats();
    let cache0 = cache.as_ref().map(|c| c.stats());
    let progress = Progress::new(plan.tasks.len());
    let t0 = std::time::Instant::now();
    run_plan_tiled(
        src,
        &plan,
        &provider,
        cfg.workers,
        &progress,
        sink.as_mut(),
        cfg.measure,
        tiles.as_ref(),
    )?;
    let mut output = sink.finish()?;
    output.meta.backend = Some(backend.name().to_string());
    output.meta.requested_backend = Some(cfg.backend.name().to_string());
    output.meta.kernel = Some(crate::linalg::kernels::active().name().to_string());
    output.meta.measure = Some(cfg.measure.name().to_string());
    output.meta.probe = probe;
    output.meta.sizing = Some(BlockSizing {
        block_cols: plan.block,
        source: sizing_source,
        task_latency_secs: cfg.task_latency_secs,
        combine_cells_per_sec: if sizing_source == "probe-throughput" {
            combine_tput
        } else {
            None
        },
    });
    output.meta.schedule = Some(schedule.name());
    let (io, cache_report) = report_io(src, io0, cache.as_deref().zip(cache0));
    output.meta.io = io;
    output.meta.cache = cache_report;
    if let (Some(tc), Some(before)) = (tiles.as_ref(), tiles0) {
        let report = tile_report(tc, &before);
        crate::info!(
            "tiles: {} hits / {} misses ({} evictions, {} bytes written) in {}",
            report.hits,
            report.misses,
            report.evictions,
            report.inserted_bytes,
            tc.root().display()
        );
        output.meta.tiles = Some(report);
    }
    println!(
        "computed {} ({}) over {} columns in {}",
        output.summary(),
        cfg.measure,
        src.n_cols(),
        fmt_secs(t0.elapsed().as_secs_f64())
    );

    print_sink_results(&output.data, src, cfg.measure, top, out)
}

/// Shared tail of the matrix-free paths (local and cluster): the
/// per-sink-kind console listing and CSV export.
fn print_sink_results(
    data: &SinkData,
    src: &dyn ColumnSource,
    measure: CombineKind,
    top: usize,
    out: Option<&Path>,
) -> Result<()> {
    let print_pairs = |pairs: &[MiPair], limit: usize| {
        for p in pairs.iter().take(limit) {
            println!("  {:<20} {:<20} {:.6}", src.col_name(p.i), src.col_name(p.j), p.mi);
        }
    };
    match data {
        SinkData::TopK(pairs) => {
            print_pairs(pairs, top);
            if let Some(path) = out {
                write_pairs_csv(pairs, src, path)?;
                crate::info!("wrote {} pairs to {}", pairs.len(), path.display());
            }
        }
        SinkData::TopKPerColumn(cols) => {
            for (c, pairs) in cols.iter().enumerate().take(top.max(1)) {
                if let Some(best) = pairs.first() {
                    let partner = if best.i == c { best.j } else { best.i };
                    println!(
                        "  {:<20} best partner {:<20} {:.6}",
                        src.col_name(c),
                        src.col_name(partner),
                        best.mi
                    );
                }
            }
            if let Some(path) = out {
                let flat: Vec<MiPair> = cols.iter().flatten().copied().collect();
                write_pairs_csv(&flat, src, path)?;
                crate::info!("wrote {} pairs to {}", flat.len(), path.display());
            }
        }
        SinkData::Sparse(sp) => {
            println!(
                "{} pairs at or above {} {:.6}{}",
                sp.nnz(),
                measure,
                sp.threshold,
                sp.pvalue.map(|p| format!(" (p <= {p})")).unwrap_or_default()
            );
            print_pairs(&sp.pairs, top);
            if let Some(path) = out {
                write_pairs_csv(&sp.pairs, src, path)?;
                crate::info!("wrote {} edges to {}", sp.nnz(), path.display());
            }
        }
        SinkData::Spilled(info) => {
            println!(
                "spilled {} tiles ({} bytes) for m = {} to {}",
                info.tiles,
                info.bytes,
                info.m,
                info.dir.display()
            );
        }
        // both callers route dense output through finish_dense instead
        SinkData::Dense(_) => unreachable!("dense results print via finish_dense"),
    }
    Ok(())
}

/// `compute --workers a:p,b:p`: the distributed path. The coordinator
/// resolves the run exactly once (backend probe included), plans the
/// same blockwise task set the local path would execute, and drives
/// the `bulkmi worker` processes at the given addresses; it never
/// reads a column block itself. Every sink kind works — results merge
/// shard-by-shard through `MiSink::merge` — and the output is
/// bit-identical to the single-process run.
fn compute_cluster(
    input: &Path,
    cfg: &RunConfig,
    addrs: &[String],
    spec: &SinkSpec,
    top: usize,
    normalize: Option<&str>,
    out: Option<&Path>,
) -> Result<()> {
    use crate::cluster::ClusterRun;
    if !cfg.backend.is_native() {
        return Err(Error::Parse(format!(
            "--workers HOST:PORT,... needs a native backend, not '{}'",
            cfg.backend
        )));
    }
    if matches!(spec, SinkSpec::Spill { .. }) && out.is_some() {
        return Err(Error::Parse(
            "--out is not supported with --sink spill (tiles + manifest.csv go to DIR)".into(),
        ));
    }
    let src = crate::server::open_source(input)?;
    if src.n_rows() == 0 || src.n_cols() == 0 {
        return Err(Error::Shape("empty dataset".into()));
    }
    // resolve once at the coordinator: workers receive the winner and
    // never re-probe (per-worker probes could pick different backends)
    let (backend, probe) = cfg.backend.resolve_source(&*src)?;
    if let Some(report) = &probe {
        crate::info!("{}", report.summary());
    }
    let combine_tput = probe.as_ref().and_then(|r| r.combine_throughput(cfg.measure));
    let (block, sizing_source) = block_policy(
        cfg.block_cols,
        probe.as_ref().map(|r| r.chosen_throughput()),
        combine_tput,
        src.n_rows(),
        src.n_cols(),
        cfg.memory_budget,
        cfg.task_latency_secs,
        (matrix_free_block(src.n_rows(), src.n_cols(), cfg.memory_budget), "budget"),
    );
    let mut plan = plan_blocks(src.n_cols(), block)?;
    let schedule = Schedule::LargestFirst;
    order_tasks(&mut plan.tasks, schedule);
    crate::info!(
        "cluster plan: {} tasks, block {} cols ({sizing_source}), {} workers",
        plan.tasks.len(),
        plan.block,
        addrs.len()
    );
    let t0 = std::time::Instant::now();
    let mut output = crate::cluster::run_cluster(&ClusterRun {
        workers: addrs,
        backend,
        measure: cfg.measure,
        plan: &plan,
        n_rows: src.n_rows(),
        sink: spec,
    })?;
    output.meta.backend = Some(backend.name().to_string());
    output.meta.requested_backend = Some(cfg.backend.name().to_string());
    output.meta.measure = Some(cfg.measure.name().to_string());
    output.meta.probe = probe;
    output.meta.sizing = Some(BlockSizing {
        block_cols: plan.block,
        source: sizing_source,
        task_latency_secs: cfg.task_latency_secs,
        combine_cells_per_sec: if sizing_source == "probe-throughput" {
            combine_tput
        } else {
            None
        },
    });
    output.meta.schedule = Some(schedule.name());
    let report = output.meta.cluster.clone().expect("cluster runs fill their report");
    println!(
        "computed {} ({}) across {} workers in {} ({} tasks, {} retried, {} worker failures)",
        output.summary(),
        cfg.measure,
        report.workers,
        fmt_secs(t0.elapsed().as_secs_f64()),
        report.tasks,
        report.retried,
        report.worker_failures
    );
    match output.data {
        SinkData::Dense(mi) => finish_dense(mi, &*src, normalize, plan.block, top, out),
        other => print_sink_results(&other, &*src, cfg.measure, top, out),
    }
}

/// `bulkmi worker --connect ADDR --input FILE`: serve block tasks to
/// one cluster coordinator, then exit (see [`crate::cluster::worker`]).
pub fn worker(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let addr = args.req("connect")?.to_string();
    let input = PathBuf::from(args.req("input")?);
    args.reject_unknown()?;
    crate::cluster::worker::serve(&addr, &input)
}

/// `bulkmi cluster <sub>`: cluster tooling (currently `bench`).
pub fn cluster(argv: &[String]) -> Result<()> {
    match argv.first().map(String::as_str) {
        Some("bench") => super::benchcmd::cluster_bench(&argv[1..]),
        other => Err(Error::Parse(format!(
            "unknown cluster subcommand {:?} (try `bulkmi cluster bench`)",
            other.unwrap_or("<none>")
        ))),
    }
}

/// Write the `job.toml` resume descriptor a spill run leaves next to
/// its manifest: everything `bulkmi resume DIR` needs to rebuild the
/// exact plan — input path, *resolved* backend (an `auto` run must not
/// re-probe to a different winner mid-dataset), measure, resolved
/// block width, and worker count.
fn write_resume_descriptor(
    dir: &Path,
    input: &Path,
    backend: Backend,
    measure: CombineKind,
    block_cols: usize,
    workers: usize,
) -> Result<()> {
    use std::io::Write;
    // absolute path: resume may run from a different working directory
    let input = std::fs::canonicalize(input).unwrap_or_else(|_| input.to_path_buf());
    let mut f = std::fs::File::create(dir.join("job.toml"))?;
    writeln!(f, "# written by `bulkmi compute --sink spill:...`; read by `bulkmi resume`")?;
    writeln!(f, "[resume]")?;
    writeln!(f, "input = \"{}\"", input.display())?;
    writeln!(f, "backend = \"{}\"", backend.name())?;
    writeln!(f, "measure = \"{}\"", measure.name())?;
    writeln!(f, "block_cols = {block_cols}")?;
    writeln!(f, "workers = {workers}")?;
    f.sync_all()?;
    Ok(())
}

/// `bulkmi resume DIR`: finish an interrupted `--sink spill:DIR` run.
/// Every tile already in the manifest is verified (length + checksum)
/// and kept; only the missing tiles are computed, with the plan
/// rebuilt from the `job.toml` descriptor so the completed directory
/// is bit-identical to an uninterrupted run. A directory whose
/// manifest already carries the completion trailer is a no-op success.
pub fn resume(argv: &[String]) -> Result<()> {
    use crate::mi::sink::{read_spill_manifest, TileSpillSink};
    let args = Args::parse(argv)?;
    let workers_override = args.get_usize("workers", 0)?;
    args.reject_unknown()?;
    let dir = match args.positionals() {
        [d] => PathBuf::from(d),
        _ => {
            return Err(Error::Parse(
                "usage: bulkmi resume DIR [--workers N] (DIR is a --sink spill:DIR directory)"
                    .into(),
            ))
        }
    };
    let manifest = read_spill_manifest(&dir)?;
    if manifest.complete {
        println!(
            "{}: already complete ({} tiles, m = {}) — nothing to resume",
            dir.display(),
            manifest.tiles.len(),
            manifest.m
        );
        return Ok(());
    }
    let raw = RawConfig::load(&dir.join("job.toml")).map_err(|e| {
        Error::Parse(format!(
            "{}: interrupted spill run but no readable resume descriptor (job.toml): {e}",
            dir.display()
        ))
    })?;
    let missing = |key: &str| Error::Parse(format!("job.toml: missing resume.{key}"));
    let input = raw.get("resume.input").ok_or_else(|| missing("input"))?.to_string();
    let backend =
        wire::parse_native_backend(raw.get("resume.backend").ok_or_else(|| missing("backend"))?)?;
    let measure =
        wire::parse_measure(raw.get("resume.measure").ok_or_else(|| missing("measure"))?)?;
    let block_cols = raw.get_usize("resume.block_cols")?.ok_or_else(|| missing("block_cols"))?;
    let workers = match workers_override {
        0 => raw.get_usize("resume.workers")?.unwrap_or(1).max(1),
        n => n,
    };

    let src = crate::server::open_source(Path::new(&input))?;
    if src.n_cols() != manifest.m {
        return Err(Error::Shape(format!(
            "{input} has {} columns but the spill manifest says m = {} — wrong input?",
            src.n_cols(),
            manifest.m
        )));
    }
    // verifies every completed tile (length + checksum) before trusting it
    let (mut sink, done) = TileSpillSink::resume(&dir)?;
    let mut plan = plan_blocks(manifest.m, block_cols)?;
    let total = plan.tasks.len();
    plan.tasks.retain(|t| !done.contains(t));
    crate::info!(
        "resuming {}: {}/{total} tiles verified on disk, {} to compute",
        dir.display(),
        total - plan.tasks.len(),
        plan.tasks.len()
    );
    let t0 = std::time::Instant::now();
    if !plan.tasks.is_empty() {
        order_tasks(&mut plan.tasks, Schedule::LargestFirst);
        let provider = NativeProvider::new(&*src, backend.native_kind());
        let progress = Progress::new(plan.tasks.len());
        run_plan(&*src, &plan, &provider, workers, &progress, &mut sink, measure)?;
    }
    let output = sink.finish()?;
    println!(
        "resumed {} ({}) in {}: {}",
        dir.display(),
        measure,
        fmt_secs(t0.elapsed().as_secs_f64()),
        output.summary()
    );
    Ok(())
}

fn write_pairs_csv(pairs: &[MiPair], src: &dyn ColumnSource, path: &Path) -> Result<()> {
    use std::io::Write;
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(w, "source,target,mi")?;
    for p in pairs {
        writeln!(w, "{},{},{:.8}", src.col_name(p.i), src.col_name(p.j), p.mi)?;
    }
    Ok(())
}

/// Convert CSV / `.bmat` v1 to the streaming-readable `.bmat` v2
/// format, one row chunk at a time (the dataset is never materialized).
pub fn pack(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let input = PathBuf::from(args.req("input")?);
    let out = PathBuf::from(args.req("out")?);
    let chunk_rows = args.get_usize("chunk-rows", io::PACK_CHUNK_ROWS)?;
    args.reject_unknown()?;
    if out.extension().and_then(|e| e.to_str()) != Some("bmat") {
        return Err(Error::Parse("pack: --out must end in .bmat".into()));
    }
    let (stats, secs) = time_it(|| io::pack(&input, &out, chunk_rows));
    let stats = stats?;
    crate::info!(
        "packed {}x{} into {} ({} -> {} bytes, {:.1}x) in {}",
        stats.n_rows,
        stats.n_cols,
        out.display(),
        stats.in_bytes,
        stats.out_bytes,
        stats.in_bytes as f64 / stats.out_bytes.max(1) as f64,
        fmt_secs(secs)
    );
    Ok(())
}

pub fn analyze(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let input = PathBuf::from(args.req("input")?);
    let backend = match args.get("backend") {
        Some(b) => wire::parse_backend(b)?,
        None => Backend::BulkBitpack,
    };
    let top = args.get_usize("top", 10)?;
    let threshold = args.get_f64("threshold", 0.0)?;
    let permutations = args.get_usize("permutations", 0)?;
    let corrected = args.get("bias-correction").map(|s| s.to_string());
    let edges_out = args.get("edges-out").map(PathBuf::from);
    args.reject_unknown()?;

    let ds = io::load(&input)?;
    let (mi, secs) = time_it(|| compute_mi_with(&ds, backend, 1));
    let mut mi = mi?;
    println!(
        "analyzed {}x{} with {} in {}",
        ds.n_rows(),
        ds.n_cols(),
        backend,
        fmt_secs(secs)
    );
    match corrected.as_deref() {
        None | Some("none") => {}
        Some("miller-madow") => {
            mi = crate::mi::significance::miller_madow(&ds, &mi);
            println!("applied Miller-Madow bias correction");
        }
        Some(other) => {
            return Err(Error::Parse(format!("unknown bias correction '{other}'")))
        }
    }

    if top > 0 {
        println!("top {top} pairs:");
        if permutations > 0 {
            for (i, j, v, p) in crate::mi::significance::top_pairs_significance(
                &ds, &mi, top, permutations, 42,
            ) {
                println!(
                    "  {:<18} {:<18} MI={:.6}  p={:.4}",
                    ds.col_name(i),
                    ds.col_name(j),
                    v,
                    p
                );
            }
        } else {
            for p in top_k_pairs(&mi, top) {
                println!(
                    "  {:<18} {:<18} MI={:.6}",
                    ds.col_name(p.i),
                    ds.col_name(p.j),
                    p.mi
                );
            }
        }
    }

    if let Some(path) = edges_out {
        use std::io::Write;
        let edges = crate::mi::topk::edges_above(&mi, threshold);
        let mut w = std::io::BufWriter::new(std::fs::File::create(&path)?);
        writeln!(w, "source,target,mi")?;
        for e in &edges {
            writeln!(w, "{},{},{:.8}", ds.col_name(e.i), ds.col_name(e.j), e.mi)?;
        }
        println!("wrote {} edges (MI >= {threshold}) to {}", edges.len(), path.display());
    }
    Ok(())
}

pub fn info(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(crate::runtime::artifacts::default_dir);
    args.reject_unknown()?;
    println!("bulkmi {}", env!("CARGO_PKG_VERSION"));
    println!("{}", crate::linalg::kernels::KernelDispatch::global().summary());
    println!("native backends: always available");
    for b in Backend::ALL.iter().filter(|b| b.is_native()) {
        println!("  {:<14} {}", b.name(), b.paper_label());
    }
    match ArtifactRegistry::load(&dir) {
        Ok(reg) => {
            println!("artifacts ({}):", reg.dir().display());
            for a in reg.all() {
                println!(
                    "  {:<24} {:?}/{:?} {}x{}",
                    a.name, a.kind, a.impl_, a.rows, a.cols
                );
            }
        }
        Err(e) => println!("artifacts: unavailable ({e}); xla backends disabled"),
    }
    Ok(())
}

pub fn selftest(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let rows = args.get_usize("rows", 500)?;
    let cols = args.get_usize("cols", 40)?;
    let with_xla = args.flag("with-xla");
    args.reject_unknown()?;

    let ds = SynthSpec::new(rows, cols).sparsity(0.9).seed(42).generate();
    let (reference, ref_secs) = time_it(|| compute_mi_with(&ds, Backend::Pairwise, 1));
    let reference = reference?;
    println!("{:<14} {:>10}   (reference)", "pairwise", fmt_secs(ref_secs));
    let mut failures = 0;
    for b in Backend::ALL {
        if b == Backend::Pairwise || (!b.is_native() && !with_xla) {
            continue;
        }
        let (result, secs) = time_it(|| compute_mi_with(&ds, b, 1));
        match result {
            Ok(mi) => {
                let diff = mi.max_abs_diff(&reference);
                let tol = if b.is_native() { 1e-10 } else { 1e-4 };
                let verdict = if diff < tol { "OK" } else { "MISMATCH" };
                if diff >= tol {
                    failures += 1;
                }
                println!("{:<14} {:>10}   max diff {:.2e}  {}", b.name(), fmt_secs(secs), diff, verdict);
            }
            Err(e) => {
                failures += 1;
                println!("{:<14} FAILED: {e}", b.name());
            }
        }
    }
    if failures > 0 {
        return Err(Error::Coordinator(format!("{failures} backend(s) failed selftest")));
    }
    println!("selftest OK");
    Ok(())
}

pub fn serve(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    // Three modes: --listen (or --config with a [serve] section) runs
    // the HTTP job server, --stdin speaks the same v1 wire schema over
    // stdin/stdout lines, neither keeps the original local demo /
    // --input batch behavior.
    let stdin_mode = args.flag("stdin");
    let listen = args.get("listen").map(|s| s.to_string());
    let config_path = args.get("config").map(PathBuf::from);
    if stdin_mode || listen.is_some() || config_path.is_some() {
        serve_wire(&args, listen, config_path, stdin_mode)
    } else {
        serve_demo(&args)
    }
}

/// The serving modes: parse the `[serve]` config + flags, register
/// `--dataset NAME=PATH` mounts, install the SIGINT/SIGTERM latch, and
/// run either the HTTP accept loop or the stdin line loop.
fn serve_wire(
    args: &Args,
    listen: Option<String>,
    config_path: Option<PathBuf>,
    stdin_mode: bool,
) -> Result<()> {
    let mut cfg = match &config_path {
        Some(p) => ServeConfig::load(p)?,
        None => ServeConfig::default(),
    };
    if let Some(l) = listen {
        cfg.listen = l;
    }
    cfg.workers = args.get_usize("workers", cfg.workers)?.max(1);
    cfg.max_queued = args.get_usize("max-queued", cfg.max_queued)?.max(1);
    if let Some(v) = args.get("memory-budget") {
        let bytes: usize = v.parse().map_err(|_| {
            Error::Parse(format!("--memory-budget expects bytes, got '{v}' (0 = unbounded)"))
        })?;
        cfg.memory_budget = if bytes == 0 { None } else { Some(bytes) };
    }
    let mut datasets: Vec<(String, PathBuf)> = Vec::new();
    for spec in args.get_all("dataset") {
        let (name, path) = spec.split_once('=').ok_or_else(|| {
            Error::Parse(format!("--dataset expects NAME=PATH, got '{spec}'"))
        })?;
        datasets.push((name.to_string(), PathBuf::from(path)));
    }
    args.reject_unknown()?;
    signal::install();

    if stdin_mode {
        return serve_stdin(&cfg, &datasets);
    }
    let server = Server::bind(&ServerConfig {
        listen: cfg.listen.clone(),
        workers: cfg.workers,
        max_queued: cfg.max_queued,
        memory_budget: cfg.memory_budget,
    })?;
    for (name, path) in &datasets {
        let (rows, cols) = server.register_dataset(name, path)?;
        crate::info!("dataset '{name}': {rows}x{cols} from {}", path.display());
    }
    server.run()
}

/// Line protocol: each stdin line is a v1 [`wire::JobRequest`]; the
/// matching result envelope (or error envelope) is printed on stdout.
/// Jobs run to completion in submission order — this is the scripting
/// surface, the HTTP server is the concurrent one.
fn serve_stdin(cfg: &ServeConfig, datasets: &[(String, PathBuf)]) -> Result<()> {
    use std::collections::BTreeMap;
    use std::io::BufRead;

    let svc = match cfg.memory_budget {
        Some(b) => JobService::with_budget(cfg.workers, cfg.max_queued, b),
        None => JobService::new(cfg.workers, cfg.max_queued),
    };
    let mut sources: BTreeMap<String, Arc<dyn ColumnSource>> = BTreeMap::new();
    for (name, path) in datasets {
        let src = crate::server::open_source(path)?;
        crate::info!(
            "dataset '{name}': {}x{} from {}",
            src.n_rows(),
            src.n_cols(),
            path.display()
        );
        sources.insert(name.clone(), src);
    }
    for line in std::io::stdin().lock().lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if signal::requested() {
            break;
        }
        match run_wire_job(&svc, &sources, line) {
            Ok(json) => println!("{json}"),
            Err(err) => println!("{}", wire::error_json(&err.to_string())),
        }
    }
    svc.drain();
    Ok(())
}

fn run_wire_job(
    svc: &JobService,
    sources: &std::collections::BTreeMap<String, Arc<dyn ColumnSource>>,
    line: &str,
) -> Result<String> {
    let req = wire::JobRequest::parse(line)?;
    let src = sources.get(&req.dataset).cloned().ok_or_else(|| {
        Error::Parse(format!(
            "unknown dataset '{}' (registered: {})",
            req.dataset,
            if sources.is_empty() {
                "none".to_string()
            } else {
                sources.keys().cloned().collect::<Vec<_>>().join(" ")
            }
        ))
    })?;
    let handle = svc.submit_source(src, req.spec)?;
    svc.wait(handle)?;
    let out = svc.take(handle)?;
    Ok(wire::result_json(handle.id(), &out))
}

/// The original local batch demo (and `--input` batch mode): submit
/// `--jobs` jobs to an in-process service and wait for them all.
fn serve_demo(args: &Args) -> Result<()> {
    let workers = args.get_usize("workers", crate::util::threadpool::default_workers())?;
    let max_queued = args.get_usize("max-queued", 4)?;
    let jobs = args.get_usize("jobs", 8)?;
    let block_cols = args.get_usize("block-cols", 64)?;
    let sink = SinkSpec::parse(args.get("sink").unwrap_or("dense"))?;
    let input = args.get("input").map(PathBuf::from);
    let backend = match args.get("backend") {
        Some(b) => wire::parse_native_backend(b)?,
        None => Backend::BulkBitpack,
    };
    let measure = match args.get("measure") {
        Some(m) => wire::parse_measure(m)?,
        None => CombineKind::Mi,
    };
    args.reject_unknown()?;

    // With --input, every job runs over the same shared column source —
    // streamed off disk for a .bmat v2 file, packed once in memory
    // otherwise. Without it, each job generates its own demo dataset.
    let shared: Option<Arc<dyn ColumnSource>> = match &input {
        None => None,
        Some(p) => Some(crate::server::open_source(p)?),
    };

    let svc = JobService::new(workers, max_queued);
    println!("service up: {workers} workers, {max_queued} queue slots, {jobs} jobs");
    let mut handles = Vec::new();
    let mut rejected = 0usize;
    for k in 0..jobs {
        let src: Arc<dyn ColumnSource> = match &shared {
            Some(s) => Arc::clone(s),
            None => Arc::new(InMemorySource::new(
                &SynthSpec::new(2000 + 500 * (k % 4), 100 + 20 * (k % 3))
                    .sparsity(0.9)
                    .seed(k as u64)
                    .generate(),
            )),
        };
        // spill jobs each get their own subdirectory — concurrent jobs
        // writing tiles into one shared dir would corrupt each other
        let job_sink = match &sink {
            SinkSpec::Spill { dir } => SinkSpec::Spill { dir: dir.join(format!("job{k}")) },
            other => other.clone(),
        };
        let spec = JobSpec::builder()
            .backend(backend)
            .block_cols(block_cols)
            .sink(job_sink)
            .measure(measure)
            .build()?;
        loop {
            match svc.submit_source(Arc::clone(&src), spec.clone()) {
                Ok(h) => {
                    println!("job {k}: submitted ({}x{})", src.n_rows(), src.n_cols());
                    handles.push(h);
                    break;
                }
                Err(_) => {
                    rejected += 1; // backpressure: wait and retry
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
            }
        }
    }
    for (k, h) in handles.iter().enumerate() {
        match svc.wait(*h)? {
            JobStatus::Done(out) => println!("job {k}: done, {}", out.summary()),
            other => println!("job {k}: {other:?}"),
        }
    }
    println!("backpressure retries: {rejected}");
    print!("{}", svc.metrics().report());
    Ok(())
}

fn save_dataset(ds: &BinaryDataset, path: &Path) -> Result<()> {
    match path.extension().and_then(|e| e.to_str()) {
        Some("csv") => io::write_csv(ds, path, ds.names().is_some()),
        // v2 is the native format: generated .bmat files stream
        // blockwise through `compute`/`serve` without a full load
        Some("bmat") => io::write_bmat_v2(ds, path),
        other => Err(Error::Parse(format!("unsupported output extension {other:?}"))),
    }
}

fn write_mi_csv(mi: &MiMatrix, src: &dyn ColumnSource, path: &Path) -> Result<()> {
    use std::io::Write;
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    let names: Vec<String> = (0..mi.dim()).map(|c| src.col_name(c)).collect();
    writeln!(w, ",{}", names.join(","))?;
    for i in 0..mi.dim() {
        write!(w, "{}", names[i])?;
        for j in 0..mi.dim() {
            write!(w, ",{:.8}", mi.get(i, j))?;
        }
        writeln!(w)?;
    }
    Ok(())
}

fn bad(name: &str) -> Error {
    Error::Parse(format!("--{name}: invalid value"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("bulkmi-cli-{}-{name}", std::process::id()))
    }

    #[test]
    fn generate_then_compute_round_trip() {
        let data = tmp("ds.bmat");
        generate(&sv(&[
            "--rows", "200", "--cols", "12", "--sparsity", "0.8", "--seed", "7",
            "--plant", "0:3:0.05", "--out", data.to_str().unwrap(),
        ]))
        .unwrap();
        let out = tmp("mi.csv");
        compute(&sv(&[
            "--input", data.to_str().unwrap(), "--backend", "bulk-opt",
            "--top", "3", "--out", out.to_str().unwrap(),
        ]))
        .unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        assert_eq!(text.lines().count(), 13); // header + 12 rows
    }

    #[test]
    fn compute_blockwise_path() {
        let data = tmp("blk.csv");
        generate(&sv(&["--rows", "100", "--cols", "9", "--out", data.to_str().unwrap()]))
            .unwrap();
        compute(&sv(&[
            "--input", data.to_str().unwrap(), "--backend", "bulk-bitpack",
            "--block-cols", "4", "--top", "0",
        ]))
        .unwrap();
    }

    #[test]
    fn compute_sink_paths_end_to_end() {
        let data = tmp("sink.bmat");
        generate(&sv(&[
            "--rows", "300", "--cols", "10", "--sparsity", "0.7", "--seed", "3",
            "--plant", "1:7:0.02", "--out", data.to_str().unwrap(),
        ]))
        .unwrap();

        // topk sink writes a pair CSV
        let pairs = tmp("sink-topk.csv");
        compute(&sv(&[
            "--input", data.to_str().unwrap(), "--sink", "topk:3",
            "--block-cols", "4", "--out", pairs.to_str().unwrap(),
        ]))
        .unwrap();
        let text = std::fs::read_to_string(&pairs).unwrap();
        assert_eq!(text.lines().count(), 4, "header + 3 pairs: {text}");
        assert!(text.starts_with("source,target,mi"));

        // per-column topk
        compute(&sv(&[
            "--input", data.to_str().unwrap(), "--sink", "topk-per-col:2", "--top", "3",
        ]))
        .unwrap();

        // threshold + pvalue sinks
        compute(&sv(&[
            "--input", data.to_str().unwrap(), "--sink", "threshold:0.1",
        ]))
        .unwrap();
        compute(&sv(&[
            "--input", data.to_str().unwrap(), "--sink", "pvalue:0.001",
        ]))
        .unwrap();

        // spill sink produces tiles + manifest that reassemble exactly
        let spill = tmp("sink-spill-dir");
        let _ = std::fs::remove_dir_all(&spill);
        compute(&sv(&[
            "--input", data.to_str().unwrap(), "--sink",
            &format!("spill:{}", spill.display()), "--block-cols", "4",
        ]))
        .unwrap();
        assert!(spill.join("manifest.csv").exists());
        let assembled = crate::mi::sink::assemble_spilled(&spill).unwrap();
        assert_eq!(assembled.dim(), 10);
        let _ = std::fs::remove_dir_all(&spill);

        // invalid combinations are rejected
        assert!(compute(&sv(&[
            "--input", data.to_str().unwrap(), "--sink", "topk:3", "--normalize", "min",
        ]))
        .is_err());
        assert!(compute(&sv(&[
            "--input", data.to_str().unwrap(), "--sink", "warp:1",
        ]))
        .is_err());
        assert!(compute(&sv(&[
            "--input", data.to_str().unwrap(), "--sink", "topk:3", "--backend", "xla",
        ]))
        .is_err());
    }

    #[test]
    fn tiles_flag_warm_run_is_bit_identical_to_cold() {
        let data = tmp("tiles.bmat");
        generate(&sv(&[
            "--rows", "250", "--cols", "11", "--sparsity", "0.8", "--seed", "23",
            "--plant", "0:6:0.03", "--out", data.to_str().unwrap(),
        ]))
        .unwrap();
        // no BULKMI_CACHE_DIR in tests, so the cache root is the
        // per-process temp dir; content addressing keeps concurrent
        // tests in this process from ever serving each other bad tiles
        let cold = tmp("tiles-cold.csv");
        let warm = tmp("tiles-warm.csv");
        for out in [&cold, &warm] {
            compute(&sv(&[
                "--input", data.to_str().unwrap(), "--sink", "topk:5", "--tiles",
                "--block-cols", "4", "--out", out.to_str().unwrap(),
            ]))
            .unwrap();
        }
        assert_eq!(
            std::fs::read_to_string(&cold).unwrap(),
            std::fs::read_to_string(&warm).unwrap(),
            "tile-cache hits must not change any output bit"
        );
    }

    #[test]
    fn resume_command_finishes_an_interrupted_spill_run() {
        let data = tmp("res.bmat");
        generate(&sv(&[
            "--rows", "220", "--cols", "9", "--sparsity", "0.7", "--seed", "31",
            "--plant", "2:5:0.02", "--out", data.to_str().unwrap(),
        ]))
        .unwrap();
        let spill = tmp("res-spill-dir");
        let _ = std::fs::remove_dir_all(&spill);
        compute(&sv(&[
            "--input", data.to_str().unwrap(), "--sink",
            &format!("spill:{}", spill.display()), "--block-cols", "4",
        ]))
        .unwrap();
        let reference = crate::mi::sink::assemble_spilled(&spill).unwrap();

        // a complete directory resumes as a no-op success
        resume(&sv(&[spill.to_str().unwrap()])).unwrap();

        // simulate a crash: strip the completion trailer and the last
        // manifest row, and delete that row's tile file
        let manifest_path = spill.join("manifest.csv");
        let text = std::fs::read_to_string(&manifest_path).unwrap();
        let mut lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.pop(), Some("complete,1"));
        let lost = lines.pop().unwrap();
        let tile_file = lost.rsplit(',').next().unwrap();
        std::fs::remove_file(spill.join(tile_file)).unwrap();
        std::fs::write(&manifest_path, format!("{}\n", lines.join("\n"))).unwrap();
        assert!(crate::mi::sink::assemble_spilled(&spill).is_err(), "incomplete");

        resume(&sv(&[spill.to_str().unwrap()])).unwrap();
        let resumed = crate::mi::sink::assemble_spilled(&spill).unwrap();
        assert_eq!(resumed.max_abs_diff(&reference), 0.0, "resume must be bit-identical");
        let _ = std::fs::remove_dir_all(&spill);

        // operand errors: no DIR, and a DIR that is not a spill run
        assert!(resume(&sv(&[])).is_err());
        assert!(resume(&sv(&[tmp("res-not-a-dir").to_str().unwrap()])).is_err());
    }

    #[test]
    fn selftest_native_passes() {
        selftest(&sv(&["--rows", "120", "--cols", "10"])).unwrap();
    }

    #[test]
    fn pack_cli_round_trip() {
        let csv = tmp("pk.csv");
        generate(&sv(&[
            "--rows", "150", "--cols", "9", "--sparsity", "0.7", "--seed", "5",
            "--out", csv.to_str().unwrap(),
        ]))
        .unwrap();
        let v2 = tmp("pk.bmat");
        pack(&sv(&[
            "--input", csv.to_str().unwrap(), "--out", v2.to_str().unwrap(),
        ]))
        .unwrap();
        assert!(io::is_bmat_v2(&v2).unwrap());
        assert_eq!(io::load(&v2).unwrap().bytes(), io::load(&csv).unwrap().bytes());
        // --out must be a .bmat path
        assert!(pack(&sv(&[
            "--input", csv.to_str().unwrap(), "--out", tmp("pk.csv2").to_str().unwrap(),
        ]))
        .is_err());
    }

    #[test]
    fn streaming_v2_equals_in_memory_csv() {
        // same data through both input paths; identical top-k output
        let csv = tmp("strm.csv");
        generate(&sv(&[
            "--rows", "400", "--cols", "16", "--sparsity", "0.8", "--seed", "19",
            "--plant", "2:11:0.02", "--out", csv.to_str().unwrap(),
        ]))
        .unwrap();
        let v2 = tmp("strm.bmat");
        pack(&sv(&["--input", csv.to_str().unwrap(), "--out", v2.to_str().unwrap()]))
            .unwrap();
        let from_csv = tmp("strm-mem.csv");
        let from_v2 = tmp("strm-pk.csv");
        for (input, out) in [(&csv, &from_csv), (&v2, &from_v2)] {
            compute(&sv(&[
                "--input", input.to_str().unwrap(), "--sink", "topk:8",
                "--block-cols", "5", "--out", out.to_str().unwrap(),
            ]))
            .unwrap();
        }
        assert_eq!(
            std::fs::read_to_string(&from_csv).unwrap(),
            std::fs::read_to_string(&from_v2).unwrap(),
            "streaming and in-memory runs must be bit-identical"
        );
        // the streaming dense path also works, auto backend included
        compute(&sv(&[
            "--input", v2.to_str().unwrap(), "--backend", "auto", "--top", "2",
        ]))
        .unwrap();
        // xla backends fall back to the in-memory v2 load; with a sink
        // they still hit the native-backend sink error, deterministically
        assert!(compute(&sv(&[
            "--input", v2.to_str().unwrap(), "--backend", "xla", "--sink", "topk:3",
        ]))
        .is_err());
    }

    #[test]
    fn task_latency_option_validated() {
        let data = tmp("lat.csv");
        generate(&sv(&["--rows", "60", "--cols", "6", "--out", data.to_str().unwrap()]))
            .unwrap();
        for bad in ["0", "-2", "inf"] {
            assert!(
                compute(&sv(&[
                    "--input", data.to_str().unwrap(), "--task-latency", bad,
                ]))
                .is_err(),
                "--task-latency {bad} must be rejected"
            );
        }
        compute(&sv(&[
            "--input", data.to_str().unwrap(), "--task-latency", "0.5", "--top", "0",
        ]))
        .unwrap();
    }

    #[test]
    fn compute_measure_paths_end_to_end() {
        let data = tmp("meas.bmat");
        generate(&sv(&[
            "--rows", "200", "--cols", "8", "--sparsity", "0.7", "--seed", "11",
            "--plant", "0:5:0.02", "--out", data.to_str().unwrap(),
        ]))
        .unwrap();

        // dense matrix under a non-MI measure
        let out = tmp("meas-jac.csv");
        compute(&sv(&[
            "--input", data.to_str().unwrap(), "--measure", "jaccard",
            "--top", "3", "--out", out.to_str().unwrap(),
        ]))
        .unwrap();
        assert_eq!(std::fs::read_to_string(&out).unwrap().lines().count(), 9);

        // matrix-free sink ranks by the selected measure
        compute(&sv(&[
            "--input", data.to_str().unwrap(), "--measure", "ochiai",
            "--sink", "topk:3", "--block-cols", "4",
        ]))
        .unwrap();

        // pvalue sink composes with gstat (G-test native units)...
        compute(&sv(&[
            "--input", data.to_str().unwrap(), "--measure", "gstat",
            "--sink", "pvalue:0.01",
        ]))
        .unwrap();
        // ...but is a clean error for measures without an asymptotic null
        assert!(compute(&sv(&[
            "--input", data.to_str().unwrap(), "--measure", "phi",
            "--sink", "pvalue:0.01",
        ]))
        .is_err());

        // unknown measure, and normalize x non-MI measure, are rejected
        assert!(compute(&sv(&[
            "--input", data.to_str().unwrap(), "--measure", "pearson",
        ]))
        .is_err());
        assert!(compute(&sv(&[
            "--input", data.to_str().unwrap(), "--measure", "vi", "--normalize", "min",
        ]))
        .is_err());
    }

    #[test]
    fn bad_options_rejected() {
        assert!(generate(&sv(&["--rows", "10"])).is_err()); // missing cols/out
        assert!(compute(&sv(&["--input", "nope.csv", "--backend", "warp"])).is_err());
        assert!(generate(&sv(&[
            "--rows", "4", "--cols", "4", "--out", "/tmp/x.bmat", "--bogus", "1"
        ]))
        .is_err());
    }

    #[test]
    fn analyze_with_significance_and_edges() {
        let data = tmp("an.bmat");
        generate(&sv(&[
            "--rows", "300", "--cols", "8", "--sparsity", "0.6", "--seed", "1",
            "--plant", "0:4:0.05", "--out", data.to_str().unwrap(),
        ]))
        .unwrap();
        let edges = tmp("edges.csv");
        analyze(&sv(&[
            "--input", data.to_str().unwrap(), "--bias-correction", "miller-madow",
            "--permutations", "50", "--top", "2", "--threshold", "0.1",
            "--edges-out", edges.to_str().unwrap(),
        ]))
        .unwrap();
        let text = std::fs::read_to_string(&edges).unwrap();
        assert!(text.lines().count() >= 2, "planted edge above threshold: {text}");
        assert!(text.starts_with("source,target,mi"));
        // bad bias-correction rejected
        assert!(analyze(&sv(&[
            "--input", data.to_str().unwrap(), "--bias-correction", "nope",
        ]))
        .is_err());
    }

    #[test]
    fn normalize_option_validated() {
        let data = tmp("norm.csv");
        generate(&sv(&["--rows", "50", "--cols", "5", "--out", data.to_str().unwrap()]))
            .unwrap();
        assert!(compute(&sv(&[
            "--input", data.to_str().unwrap(), "--normalize", "bogus",
        ]))
        .is_err());
        compute(&sv(&[
            "--input", data.to_str().unwrap(), "--normalize", "min", "--top", "2",
        ]))
        .unwrap();
    }
}
