//! Minimal `--key value` / `--flag` argument parser.

use crate::util::error::{Error, Result};
use std::collections::BTreeMap;

/// Parsed arguments: repeatable options, flags, and positionals.
#[derive(Debug, Default)]
pub struct Args {
    options: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    positional: Vec<String>,
    /// Option names consumed so far (for unknown-option detection).
    known: std::cell::RefCell<Vec<String>>,
}

/// Names that take no value (everything else with `--` expects one).
const FLAG_NAMES: &[&str] =
    &["with-xla", "header", "verbose", "quiet", "quick", "stdin", "tiles"];

impl Args {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut args = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if FLAG_NAMES.contains(&name) {
                    args.flags.push(name.to_string());
                } else {
                    let value = argv
                        .get(i + 1)
                        .ok_or_else(|| Error::Parse(format!("--{name} needs a value")))?;
                    args.options.entry(name.to_string()).or_default().push(value.clone());
                    i += 1;
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    fn mark(&self, name: &str) {
        self.known.borrow_mut().push(name.to_string());
    }

    pub fn flag(&self, name: &str) -> bool {
        self.mark(name);
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.mark(name);
        self.options.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.mark(name);
        self.options.get(name).map(|v| v.iter().map(|s| s.as_str()).collect()).unwrap_or_default()
    }

    pub fn req(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| Error::Parse(format!("missing required --{name}")))
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Parse(format!("--{name}: expected integer, got '{s}'"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Parse(format!("--{name}: expected number, got '{s}'"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Parse(format!("--{name}: expected integer, got '{s}'"))),
        }
    }

    /// Bare (non `--`) arguments, in order — subcommand operands like
    /// `bulkmi resume DIR`.
    pub fn positionals(&self) -> &[String] {
        &self.positional
    }

    /// Error on options that were provided but never consumed (typos).
    pub fn reject_unknown(&self) -> Result<()> {
        let known = self.known.borrow();
        for name in self.options.keys() {
            if !known.iter().any(|k| k == name) {
                return Err(Error::Parse(format!("unknown option --{name}")));
            }
        }
        for name in &self.flags {
            if !known.iter().any(|k| k == name) {
                return Err(Error::Parse(format!("unknown flag --{name}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_options_flags_positionals() {
        let a = Args::parse(&sv(&["--rows", "10", "pos1", "--with-xla", "--cols", "5"])).unwrap();
        assert_eq!(a.get("rows"), Some("10"));
        assert_eq!(a.get_usize("cols", 0).unwrap(), 5);
        assert!(a.flag("with-xla"));
        assert!(!a.flag("header"));
        assert_eq!(a.positionals(), &["pos1".to_string()]);
    }

    #[test]
    fn repeated_options_collect() {
        let a = Args::parse(&sv(&["--plant", "0:1:0.1", "--plant", "2:3:0.0"])).unwrap();
        assert_eq!(a.get_all("plant"), vec!["0:1:0.1", "2:3:0.0"]);
        assert_eq!(a.get("plant"), Some("2:3:0.0")); // last wins for single get
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["--rows"])).is_err());
    }

    #[test]
    fn required_and_typed() {
        let a = Args::parse(&sv(&["--rows", "ten"])).unwrap();
        assert!(a.get_usize("rows", 0).is_err());
        assert!(a.req("cols").is_err());
        assert_eq!(a.get_f64("sparsity", 0.9).unwrap(), 0.9);
    }

    #[test]
    fn unknown_rejection() {
        let a = Args::parse(&sv(&["--rows", "1", "--bogus", "2"])).unwrap();
        let _ = a.get("rows");
        assert!(a.reject_unknown().is_err());
        let b = Args::parse(&sv(&["--rows", "1"])).unwrap();
        let _ = b.get("rows");
        assert!(b.reject_unknown().is_ok());
    }
}
