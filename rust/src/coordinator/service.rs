//! The job service: a long-lived coordinator accepting MI jobs, running
//! them on a worker pool with two layers of admission control (a job
//! *slot* queue plus a RAM-pricing byte gate — see
//! [`super::admission`]), and exposing submit / poll / wait / cancel /
//! drain — the crate's "serving" surface used by the `bulkmi serve` CLI
//! mode, the HTTP layer in [`crate::server`], and the e2e example.

use super::admission::{estimate_job_bytes, AdmissionController, Priority};
use super::backpressure::Semaphore;
use super::blockcache::{cache_plan, run_reports, BlockCache, CacheHandle};
use super::executor::{run_plan_tiled, NativeProvider};
use super::planner::{
    block_policy, carve_cache_budget, matrix_free_block, plan_blocks, BlockPlan,
    DEFAULT_TASK_LATENCY_SECS,
};
use super::progress::Progress;
use super::scheduler::{order_tasks, Schedule};
use super::tilecache::{tile_report, TileCache};
use crate::data::colstore::{ColumnSource, InMemorySource};
use crate::data::dataset::BinaryDataset;
use crate::metrics::Metrics;
use crate::mi::autotune::ProbeReport;
use crate::mi::backend::Backend;
use crate::mi::measure::CombineKind;
use crate::mi::sink::{AdmissionReport, BlockSizing, SinkOutput, SinkSpec};
use crate::util::error::{Error, Result};
use crate::util::threadpool::WorkerPool;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};


/// Observable job state.
#[derive(Clone, Debug)]
pub enum JobStatus {
    Queued,
    /// Fraction of block tasks completed.
    Running(f64),
    /// Whatever the job's sink retained (a dense matrix for the default
    /// [`SinkSpec::Dense`]; top-k pairs, sparse COO, or spill info for
    /// the matrix-free sinks).
    Done(SinkOutput),
    Failed(String),
    Cancelled,
}

impl JobStatus {
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobStatus::Done(_) | JobStatus::Failed(_) | JobStatus::Cancelled)
    }

    /// Stable lowercase state name (wire schema, error messages).
    pub fn name(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running(_) => "running",
            JobStatus::Done(_) => "done",
            JobStatus::Failed(_) => "failed",
            JobStatus::Cancelled => "cancelled",
        }
    }
}

/// Ticket for a submitted job.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct JobHandle(u64);

impl JobHandle {
    /// The numeric job id (the wire schema's `"job"` field).
    pub fn id(self) -> u64 {
        self.0
    }

    /// Rebuild a handle from a wire-level job id (HTTP layer).
    pub(crate) fn from_id(id: u64) -> JobHandle {
        JobHandle(id)
    }
}

/// Job specification. Construct through [`JobSpec::builder`]; the
/// struct is `#[non_exhaustive]` so fields can keep accruing across
/// releases without breaking downstream struct literals (they broke on
/// every field added in PRs 2–6).
#[derive(Clone, Debug)]
#[non_exhaustive]
pub struct JobSpec {
    /// Which native backend computes the Gram blocks. [`Backend::Auto`]
    /// micro-probes the dataset at job start (hitting the process-wide
    /// probe cache when an identically-shaped job already probed) and
    /// commits to the winner (recorded in the output's
    /// [`crate::mi::sink::SinkMeta`]).
    pub backend: Backend,
    /// Column-block size. 0 = let the service decide: monolithic for
    /// fixed backends, probe-throughput sized for [`Backend::Auto`]
    /// (faster substrates get larger blocks under the same latency
    /// target — see
    /// [`crate::coordinator::planner::throughput_block`]).
    pub block_cols: usize,
    /// Worker threads *within* the job's plan execution.
    pub inner_workers: usize,
    /// Task ordering. `None` = let the service decide: the
    /// cache-friendly [`Schedule::Panel`] for cached out-of-core jobs,
    /// [`Schedule::LargestFirst`] (best tail behaviour) otherwise.
    pub schedule: Option<Schedule>,
    /// Block-substrate cache budget in bytes. `None` = auto: enable
    /// the service's shared cache for out-of-core sources, skip it for
    /// in-memory ones (their fetches are memcpys). `Some(0)` disables
    /// the cache; any other value gives the job a private cache of
    /// that size.
    pub cache_bytes: Option<usize>,
    /// Tasks of readahead for the executor's prefetch stage (0
    /// disables; only active when a cache is attached). Default 1 —
    /// double-buffering: the next task's blocks load while the current
    /// Grams compute.
    pub readahead: usize,
    /// Where the combined blocks go (dense matrix by default).
    pub sink: SinkSpec,
    /// Which association measure the combine stage computes from the
    /// Gram blocks (MI by default; see [`crate::mi::measure`]). Sinks
    /// rank and threshold in the measure's own units.
    pub measure: CombineKind,
    /// Per-task Gram latency target (seconds) for probe-throughput
    /// block sizing
    /// ([`crate::coordinator::planner::throughput_block`]); recorded in
    /// the output's `BlockSizing`. Default
    /// [`DEFAULT_TASK_LATENCY_SECS`].
    pub task_latency_secs: f64,
    /// Admission class under the service's aggregate byte cap. `None`
    /// derives from the sink ([`Priority::for_sink`]): bounded-output
    /// sinks are interactive and jump queued batch (dense / spill)
    /// jobs.
    pub priority: Option<Priority>,
    /// Metrics namespace for multi-tenant serving: when set, the job's
    /// terminal counters, cache traffic, and probe-cache hits are
    /// mirrored under `tenant:<name>:*` in the service metrics.
    pub tenant: Option<String>,
    /// Consult the service's shared content-addressed Gram-tile cache
    /// ([`TileCache`]): finished tiles persist keyed by the input
    /// blocks' content fingerprints, so a later job over the same data
    /// (any backend, any measure, any sink) skips the Gram entirely and
    /// only re-runs the cheap combine. Off by default because a hit
    /// bypasses the block-substrate path — jobs auditing *that* cache's
    /// traffic should leave this off.
    pub tiles: bool,
    /// Worker addresses (`host:port`) for a distributed run. Non-empty
    /// turns the job into a cluster coordinator: the backend is
    /// resolved once here, the schedule-ordered plan is sharded across
    /// `bulkmi worker` processes over the wire protocol in
    /// [`crate::cluster`], and merged sink states come back
    /// bit-identical to a local run (the retry audit lands in the
    /// output meta's [`crate::mi::sink::ClusterReport`]). Every worker
    /// must serve the same dataset as this job's source. Empty
    /// (default) = run locally.
    pub cluster_workers: Vec<String>,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            backend: Backend::BulkBitpack,
            block_cols: 0,
            inner_workers: 1,
            schedule: None,
            cache_bytes: None,
            readahead: 1,
            sink: SinkSpec::Dense,
            measure: CombineKind::Mi,
            task_latency_secs: DEFAULT_TASK_LATENCY_SECS,
            priority: None,
            tenant: None,
            tiles: false,
            cluster_workers: Vec::new(),
        }
    }
}

impl JobSpec {
    /// Start a builder whose defaults equal [`JobSpec::default`].
    pub fn builder() -> JobSpecBuilder {
        JobSpecBuilder { spec: JobSpec::default() }
    }
}

/// Validating builder for [`JobSpec`]; the one construction path open
/// to external callers now that the struct is `#[non_exhaustive]`.
#[derive(Clone, Debug)]
pub struct JobSpecBuilder {
    spec: JobSpec,
}

impl JobSpecBuilder {
    pub fn backend(mut self, backend: Backend) -> Self {
        self.spec.backend = backend;
        self
    }

    pub fn block_cols(mut self, block_cols: usize) -> Self {
        self.spec.block_cols = block_cols;
        self
    }

    pub fn inner_workers(mut self, inner_workers: usize) -> Self {
        self.spec.inner_workers = inner_workers;
        self
    }

    pub fn schedule(mut self, schedule: Schedule) -> Self {
        self.spec.schedule = Some(schedule);
        self
    }

    pub fn cache_bytes(mut self, cache_bytes: Option<usize>) -> Self {
        self.spec.cache_bytes = cache_bytes;
        self
    }

    pub fn readahead(mut self, readahead: usize) -> Self {
        self.spec.readahead = readahead;
        self
    }

    pub fn sink(mut self, sink: SinkSpec) -> Self {
        self.spec.sink = sink;
        self
    }

    pub fn measure(mut self, measure: CombineKind) -> Self {
        self.spec.measure = measure;
        self
    }

    pub fn task_latency_secs(mut self, secs: f64) -> Self {
        self.spec.task_latency_secs = secs;
        self
    }

    pub fn priority(mut self, priority: Priority) -> Self {
        self.spec.priority = Some(priority);
        self
    }

    pub fn tenant(mut self, tenant: impl Into<String>) -> Self {
        self.spec.tenant = Some(tenant.into());
        self
    }

    pub fn tiles(mut self, tiles: bool) -> Self {
        self.spec.tiles = tiles;
        self
    }

    pub fn cluster_workers(mut self, workers: Vec<String>) -> Self {
        self.spec.cluster_workers = workers;
        self
    }

    /// Validate and produce the spec. Rejects non-native backends (the
    /// service cannot run XLA jobs) and non-finite / non-positive
    /// latency targets — the same checks `submit` would fail with
    /// later, moved to construction time.
    pub fn build(self) -> Result<JobSpec> {
        if !self.spec.backend.is_native() {
            return Err(Error::Coordinator(format!(
                "job backend must be native, not '{}'",
                self.spec.backend
            )));
        }
        if !self.spec.task_latency_secs.is_finite() || self.spec.task_latency_secs <= 0.0 {
            return Err(Error::Coordinator(format!(
                "task_latency_secs must be a positive finite number, got {}",
                self.spec.task_latency_secs
            )));
        }
        Ok(self.spec)
    }
}

struct JobEntry {
    status: JobStatus,
    progress: Progress,
    priority: Priority,
    estimated_bytes: usize,
}

/// Everything the status surface knows about one job.
#[derive(Clone, Debug)]
pub struct JobInfo {
    /// Same as [`JobService::poll`] (live progress for running jobs).
    pub status: JobStatus,
    /// Admission class the job was priced under.
    pub priority: Priority,
    /// The byte gate's price for the job (see
    /// [`super::admission::estimate_job_bytes`]).
    pub estimated_bytes: usize,
}

/// Plan a job's block structure. An explicit `block_cols` wins;
/// otherwise an auto job folds the probe's throughput into the block
/// width (faster substrates get larger blocks under the same latency
/// target) and fixed backends keep the historical monolithic plan.
/// The returned [`BlockSizing`] is recorded in the job's
/// [`crate::mi::sink::SinkMeta`].
fn plan_for_job(
    src: &dyn ColumnSource,
    spec: &JobSpec,
    probe: Option<&ProbeReport>,
    task_budget: usize,
) -> Result<(BlockPlan, BlockSizing)> {
    let (n_rows, m) = (src.n_rows(), src.n_cols());
    // In-memory sources keep the historical monolithic fallback (block
    // 0 = single-task plan). An out-of-core source must never plan
    // monolithically — that one task's col_block(0, m) fetch would
    // materialize the whole source — so its fallback is the bounded
    // matrix-free memory rule instead, sized by the budget left after
    // the cache carve so cache + task working set stay honest.
    let fallback = if src.out_of_core() {
        (matrix_free_block(n_rows, m, task_budget), "budget")
    } else {
        (0, "monolithic")
    };
    let combine = probe.and_then(|r| r.combine_throughput(spec.measure));
    let (block, source) = block_policy(
        spec.block_cols,
        probe.map(ProbeReport::chosen_throughput),
        combine,
        n_rows,
        m,
        task_budget,
        spec.task_latency_secs,
        fallback,
    );
    let plan = plan_blocks(m, block)?;
    Ok((plan, BlockSizing {
        block_cols: plan.block,
        source,
        task_latency_secs: spec.task_latency_secs,
        // record the combine figure only when it actually participated
        combine_cells_per_sec: if source == "probe-throughput" { combine } else { None },
    }))
}

/// The service. Dropping it drains in-flight jobs.
///
/// ```
/// use bulkmi::coordinator::service::{JobService, JobSpec, JobStatus};
/// use bulkmi::data::synth::SynthSpec;
///
/// let svc = JobService::new(1, 2);
/// let ds = SynthSpec::new(64, 6).sparsity(0.5).seed(1).generate();
/// let spec = JobSpec::builder().build().unwrap();
/// let handle = svc.submit(ds, spec).unwrap();
/// let JobStatus::Done(_) = svc.wait(handle).unwrap() else {
///     panic!("job failed");
/// };
/// let out = svc.take(handle).unwrap();
/// assert!(out.into_dense().is_some()); // default sink keeps the matrix
/// ```
pub struct JobService {
    pool: WorkerPool,
    jobs: Arc<Mutex<HashMap<u64, JobEntry>>>,
    /// Slot gate: bounds jobs that are queued-or-running (fail-fast
    /// backpressure at submit time).
    queue_slots: Semaphore,
    /// Byte gate: bounds the *aggregate* estimated resident bytes of
    /// concurrently running jobs; over-budget jobs wait inside their
    /// worker in priority order instead of OOMing the process.
    ram_gate: Arc<AdmissionController>,
    draining: Arc<AtomicBool>,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
    /// Shared block-substrate cache for auto-cached jobs: process-wide
    /// across the service's jobs, so repeated jobs over the same
    /// `Arc`'d source (the `serve --input` pattern) reuse each other's
    /// blocks. Sized by the default budget carve.
    cache: Arc<BlockCache>,
    /// Shared content-addressed Gram-tile cache for jobs submitted with
    /// [`JobSpec::tiles`]. Lazily opened on first use so services that
    /// never run a tiled job touch no disk; rooted under
    /// `$BULKMI_CACHE_DIR/tiles` when that is set (cross-process
    /// reuse), else a per-process temp directory.
    tile_cache: OnceLock<Arc<TileCache>>,
}

impl JobService {
    /// `workers`: pool threads executing jobs; `max_queued`: admission
    /// limit on jobs that are queued or running (backpressure). The
    /// aggregate byte cap is unbounded; serving deployments should use
    /// [`JobService::with_budget`].
    pub fn new(workers: usize, max_queued: usize) -> Self {
        Self::with_budget(workers, max_queued, 0)
    }

    /// Like [`JobService::new`] with an aggregate RAM cap:
    /// `budget_bytes` bounds the summed job prices
    /// ([`estimate_job_bytes`]) of everything running at once
    /// (0 = unbounded).
    pub fn with_budget(workers: usize, max_queued: usize, budget_bytes: usize) -> Self {
        JobService {
            pool: WorkerPool::new(workers),
            jobs: Arc::new(Mutex::new(HashMap::new())),
            queue_slots: Semaphore::new(max_queued.max(1)),
            ram_gate: Arc::new(AdmissionController::new(budget_bytes)),
            draining: Arc::new(AtomicBool::new(false)),
            next_id: AtomicU64::new(1),
            metrics: Arc::new(Metrics::new()),
            cache: Arc::new(BlockCache::new(carve_cache_budget(0).1)),
            tile_cache: OnceLock::new(),
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The aggregate byte gate (admission stats: inflight / peak /
    /// waiting).
    pub fn admission(&self) -> &Arc<AdmissionController> {
        &self.ram_gate
    }

    /// The service-wide shared substrate cache (metrics surface).
    pub fn shared_cache(&self) -> &BlockCache {
        &self.cache
    }

    /// The service-wide Gram-tile cache (metrics surface; populated by
    /// jobs submitted with [`JobSpec::tiles`]). Opened on first call.
    pub fn shared_tile_cache(&self) -> &Arc<TileCache> {
        self.tile_cache.get_or_init(|| {
            Arc::new(TileCache::open(
                super::tilecache::default_tile_root(),
                super::tilecache::DEFAULT_TILE_BUDGET,
            ))
        })
    }

    /// Submit a job over an in-memory dataset; fails fast with
    /// `Error::Coordinator` when the admission queue is full (callers
    /// should retry with backoff). Packs the dataset once into an
    /// [`InMemorySource`] and delegates to [`Self::submit_source`].
    pub fn submit(&self, ds: BinaryDataset, spec: JobSpec) -> Result<JobHandle> {
        self.submit_source(Arc::new(InMemorySource::new(&ds)), spec)
    }

    /// Submit a job over any [`ColumnSource`] — the streaming-input
    /// path: a [`crate::data::colstore::PackedFileSource`] job reads
    /// column blocks straight off disk, so the service's peak RAM per
    /// job is the plan's task working set plus sink state, independent
    /// of the dataset's size. Admission control, planning, autotuning
    /// (through block fetches) and sink handling are identical to
    /// [`Self::submit`].
    pub fn submit_source(&self, src: Arc<dyn ColumnSource>, spec: JobSpec) -> Result<JobHandle> {
        if self.draining.load(Ordering::SeqCst) {
            self.metrics.counter("jobs_rejected").inc();
            return Err(Error::Coordinator("service is draining".into()));
        }
        if !spec.backend.is_native() {
            return Err(Error::Coordinator(format!(
                "job backend must be native, not '{}'",
                spec.backend
            )));
        }
        // a bad BULKMI_KERNEL would otherwise panic the first worker
        // that touches the dispatch table, leaving the job non-terminal
        crate::linalg::kernels::validate_env_override()?;
        let Some(permit) = self.queue_slots.try_acquire() else {
            self.metrics.counter("jobs_rejected").inc();
            return Err(Error::Coordinator(format!(
                "admission queue full ({} jobs in flight)",
                self.queue_slots.capacity()
            )));
        };
        if src.n_cols() == 0 {
            return Err(Error::Shape("cannot plan over zero columns".into()));
        }
        let priority = spec.priority.unwrap_or_else(|| Priority::for_sink(&spec.sink));
        let estimated_bytes =
            estimate_job_bytes(src.n_rows(), src.n_cols(), src.out_of_core(), &spec);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        // Planning happens *inside* the worker: an auto job's block
        // size depends on the probe's throughput verdict, which is not
        // known until the job starts. The placeholder total keeps
        // `fraction()` at 0.0 until the real plan lands via
        // `Progress::set_total`.
        let progress = Progress::new(1);
        self.jobs.lock().unwrap().insert(id, JobEntry {
            status: JobStatus::Queued,
            progress: progress.clone(),
            priority,
            estimated_bytes,
        });
        self.metrics.counter("jobs_submitted").inc();

        let jobs = Arc::clone(&self.jobs);
        let metrics = Arc::clone(&self.metrics);
        let shared_cache = Arc::clone(&self.cache);
        let tile_cache = spec.tiles.then(|| Arc::clone(self.shared_tile_cache()));
        let ram_gate = Arc::clone(&self.ram_gate);
        let set_status = move |jobs: &Mutex<HashMap<u64, JobEntry>>, status: JobStatus| {
            // the entry may already be gone: take() on a
            // cancelled-while-queued job removes it before we run
            if let Some(e) = jobs.lock().unwrap().get_mut(&id) {
                e.status = status;
            }
        };
        self.pool
            .submit(move || {
                let _permit = permit; // released when the job finishes
                if progress.is_cancelled() {
                    metrics.counter("jobs_cancelled").inc();
                    set_status(&jobs, JobStatus::Cancelled);
                    return;
                }
                // RAM admission: wait (priority order) until the job's
                // price fits under the aggregate cap. The RAII permit
                // returns the bytes exactly once, however the job ends.
                let queued_at = Instant::now();
                let Some(ram_permit) =
                    ram_gate.admit(estimated_bytes, priority, &|| progress.is_cancelled())
                else {
                    metrics.counter("jobs_cancelled").inc();
                    set_status(&jobs, JobStatus::Cancelled);
                    return;
                };
                let queued_secs = queued_at.elapsed().as_secs_f64();
                metrics.histogram("admission_wait_secs").observe(queued_secs);
                metrics.counter("admission_est_bytes").add(estimated_bytes as u64);
                let _ram_permit = ram_permit;
                set_status(&jobs, JobStatus::Running(0.0));
                let result = spec.backend.resolve_source(&*src).and_then(|(resolved, probe)| {
                    if !spec.cluster_workers.is_empty() {
                        // distributed job: same resolve / plan /
                        // schedule as a local run, but the tasks ship
                        // to cluster workers instead of the local
                        // executor (no block cache or tile cache —
                        // workers stream their own blocks)
                        let (_, task_budget) =
                            cache_plan(spec.cache_bytes, src.out_of_core(), 0);
                        let (mut plan, sizing) =
                            plan_for_job(&*src, &spec, probe.as_ref(), task_budget)?;
                        let schedule = spec.schedule.unwrap_or(Schedule::LargestFirst);
                        order_tasks(&mut plan.tasks, schedule);
                        progress.set_total(plan.tasks.len());
                        let mut out = metrics.time("job_secs", || {
                            crate::cluster::run_cluster(&crate::cluster::ClusterRun {
                                workers: &spec.cluster_workers,
                                backend: resolved,
                                measure: spec.measure,
                                plan: &plan,
                                n_rows: src.n_rows(),
                                sink: &spec.sink,
                            })
                        })?;
                        if let Some(cr) = &out.meta.cluster {
                            metrics.counter("cluster_task_retries").add(cr.retried);
                            metrics
                                .counter("cluster_worker_failures")
                                .add(cr.worker_failures);
                        }
                        out.meta.backend = Some(resolved.name().to_string());
                        out.meta.requested_backend = Some(spec.backend.name().to_string());
                        out.meta.measure = Some(spec.measure.name().to_string());
                        out.meta.probe = probe;
                        out.meta.sizing = Some(sizing);
                        out.meta.schedule = Some(schedule.name());
                        out.meta.admission = Some(AdmissionReport {
                            estimated_bytes,
                            queued_secs,
                            priority: priority.name(),
                        });
                        return Ok(out);
                    }
                    // cache decision first: the carve shrinks the task
                    // budget the plan is sized under
                    let (cache_budget, task_budget) =
                        cache_plan(spec.cache_bytes, src.out_of_core(), 0);
                    let cache: Option<Arc<BlockCache>> = match (cache_budget, spec.cache_bytes) {
                        (None, _) => None,
                        // auto-enabled: the service's shared cache
                        (Some(_), None) => Some(Arc::clone(&shared_cache)),
                        // explicit budget: a private per-job cache
                        (Some(n), Some(_)) => Some(Arc::new(BlockCache::new(n))),
                    };
                    let (mut plan, sizing) =
                        plan_for_job(&*src, &spec, probe.as_ref(), task_budget)?;
                    let schedule = spec.schedule.unwrap_or(
                        if cache.is_some() && src.out_of_core() {
                            Schedule::Panel
                        } else {
                            Schedule::LargestFirst
                        },
                    );
                    order_tasks(&mut plan.tasks, schedule);
                    progress.set_total(plan.tasks.len());
                    let provider = match &cache {
                        Some(c) => NativeProvider::with_cache(
                            &*src,
                            resolved.native_kind(),
                            CacheHandle::for_source(Arc::clone(c), &src),
                            spec.readahead,
                        ),
                        None => NativeProvider::new(&*src, resolved.native_kind()),
                    };
                    let io0 = src.io_stats();
                    let cache0 = cache.as_ref().map(|c| c.stats());
                    let tiles0 = tile_cache.as_ref().map(|c| c.stats());
                    let mut sink = spec.sink.build_for(src.n_cols(), src.n_rows(), spec.measure)?;
                    metrics.time("job_secs", || {
                        run_plan_tiled(
                            &*src,
                            &plan,
                            &provider,
                            spec.inner_workers,
                            &progress,
                            sink.as_mut(),
                            spec.measure,
                            tile_cache.as_deref(),
                        )
                    })?;
                    let mut out = sink.finish()?;
                    out.meta.backend = Some(resolved.name().to_string());
                    out.meta.requested_backend = Some(spec.backend.name().to_string());
                    out.meta.kernel =
                        Some(crate::linalg::kernels::active().name().to_string());
                    out.meta.measure = Some(spec.measure.name().to_string());
                    out.meta.probe = probe;
                    out.meta.sizing = Some(sizing);
                    out.meta.schedule = Some(schedule.name());
                    out.meta.admission = Some(AdmissionReport {
                        estimated_bytes,
                        queued_secs,
                        priority: priority.name(),
                    });
                    let (io, cache_report) = run_reports(&*src, io0, cache.as_deref().zip(cache0));
                    if let Some(io) = &io {
                        metrics.counter("io_bytes_read").add(io.bytes_read);
                        metrics.counter("io_reads").add(io.reads);
                    }
                    if let Some(cr) = &cache_report {
                        metrics.counter("cache_hits").add(cr.hits);
                        metrics.counter("cache_misses").add(cr.misses);
                        metrics.counter("cache_evictions").add(cr.evictions);
                        metrics.counter("cache_prefetched").add(cr.prefetched);
                        metrics.histogram("cache_stall_secs").observe(cr.stall_secs);
                    }
                    out.meta.io = io;
                    out.meta.cache = cache_report;
                    if let (Some(tc), Some(t0)) = (tile_cache.as_ref(), tiles0) {
                        let report = tile_report(tc, &t0);
                        metrics.counter("tile_hits").add(report.hits);
                        metrics.counter("tile_misses").add(report.misses);
                        out.meta.tiles = Some(report);
                    }
                    Ok(out)
                });
                let status = match result {
                    Ok(out) => {
                        metrics.counter("jobs_done").inc();
                        JobStatus::Done(out)
                    }
                    Err(_) if progress.is_cancelled() => {
                        metrics.counter("jobs_cancelled").inc();
                        JobStatus::Cancelled
                    }
                    Err(e) => {
                        metrics.counter("jobs_failed").inc();
                        JobStatus::Failed(e.to_string())
                    }
                };
                // multi-tenant audit: mirror terminal counters + cache
                // traffic under the tenant's namespace
                if let Some(tenant) = spec.tenant.as_deref() {
                    let c = |name: &str| metrics.counter(&format!("tenant:{tenant}:{name}"));
                    c("admission_est_bytes").add(estimated_bytes as u64);
                    match &status {
                        JobStatus::Done(out) => {
                            c("jobs_done").inc();
                            if let Some(cr) = &out.meta.cache {
                                c("cache_hits").add(cr.hits);
                                c("cache_misses").add(cr.misses);
                            }
                            if out.meta.probe.as_ref().is_some_and(|p| p.cached) {
                                c("probe_cache_hits").inc();
                            }
                        }
                        JobStatus::Cancelled => c("jobs_cancelled").inc(),
                        JobStatus::Failed(_) => c("jobs_failed").inc(),
                        _ => {}
                    }
                }
                set_status(&jobs, status);
            })
            .map_err(|_| Error::Coordinator("service is shut down".into()))?;
        Ok(JobHandle(id))
    }

    /// Current status (progress is live for running jobs).
    pub fn poll(&self, handle: JobHandle) -> Result<JobStatus> {
        let jobs = self.jobs.lock().unwrap();
        let entry = jobs
            .get(&handle.0)
            .ok_or_else(|| Error::Coordinator(format!("unknown job {}", handle.0)))?;
        Ok(match &entry.status {
            JobStatus::Running(_) => JobStatus::Running(entry.progress.fraction()),
            other => other.clone(),
        })
    }

    /// Status plus the admission facts (priority, estimated bytes) —
    /// the HTTP status endpoint's view.
    pub fn info(&self, handle: JobHandle) -> Result<JobInfo> {
        let jobs = self.jobs.lock().unwrap();
        let entry = jobs
            .get(&handle.0)
            .ok_or_else(|| Error::Coordinator(format!("unknown job {}", handle.0)))?;
        let status = match &entry.status {
            JobStatus::Running(_) => JobStatus::Running(entry.progress.fraction()),
            other => other.clone(),
        };
        Ok(JobInfo { status, priority: entry.priority, estimated_bytes: entry.estimated_bytes })
    }

    /// Request cancellation (running tasks finish their current block).
    /// Errors with [`Error::JobTerminal`] when the job already reached
    /// a terminal state — a double cancel is a caller bug worth
    /// surfacing, not an idempotent no-op.
    pub fn cancel(&self, handle: JobHandle) -> Result<()> {
        let mut jobs = self.jobs.lock().unwrap();
        let entry = jobs
            .get_mut(&handle.0)
            .ok_or_else(|| Error::Coordinator(format!("unknown job {}", handle.0)))?;
        if entry.status.is_terminal() {
            return Err(Error::JobTerminal(format!(
                "job {} is already {}",
                handle.0,
                entry.status.name()
            )));
        }
        entry.progress.cancel();
        if matches!(entry.status, JobStatus::Queued) {
            entry.status = JobStatus::Cancelled;
        }
        Ok(())
    }

    /// Block until the job reaches a terminal state.
    pub fn wait(&self, handle: JobHandle) -> Result<JobStatus> {
        loop {
            let status = self.poll(handle)?;
            if status.is_terminal() {
                return Ok(status);
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    /// Remove a terminal job and return its sink output. Typed errors
    /// for the unhappy endings: [`Error::JobCancelled`] /
    /// [`Error::JobFailed`] consume the entry too (a second take sees
    /// an unknown job), while an in-flight job is left untouched.
    pub fn take(&self, handle: JobHandle) -> Result<SinkOutput> {
        let mut jobs = self.jobs.lock().unwrap();
        match jobs.get(&handle.0) {
            None => Err(Error::Coordinator(format!("unknown job {}", handle.0))),
            Some(e) if !e.status.is_terminal() => {
                Err(Error::Coordinator(format!("job {} still in flight", handle.0)))
            }
            Some(_) => match jobs.remove(&handle.0).unwrap().status {
                JobStatus::Done(out) => Ok(out),
                JobStatus::Failed(msg) => Err(Error::JobFailed(msg)),
                JobStatus::Cancelled => {
                    Err(Error::JobCancelled(format!("job {}", handle.0)))
                }
                JobStatus::Queued | JobStatus::Running(_) => unreachable!("filtered above"),
            },
        }
    }

    /// Graceful drain: stop admitting new submissions, then block until
    /// every tracked job is terminal (running tasks finish, sinks
    /// flush). Idempotent; the SIGINT/SIGTERM path of `bulkmi serve`.
    pub fn drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
        loop {
            let all_terminal =
                self.jobs.lock().unwrap().values().all(|e| e.status.is_terminal());
            if all_terminal {
                return;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// Has [`Self::drain`] been called?
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Jobs currently tracked (any state).
    pub fn job_count(&self) -> usize {
        self.jobs.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::planner::{dense_output_bytes, task_bytes};
    use crate::data::synth::SynthSpec;
    use crate::mi::backend::{compute_mi, Backend};

    #[test]
    fn submit_wait_take_round_trip() {
        let svc = JobService::new(2, 8);
        let ds = SynthSpec::new(100, 10).sparsity(0.7).seed(1).generate();
        let want = compute_mi(&ds, Backend::Pairwise).unwrap();
        let spec = JobSpec::builder().block_cols(4).build().unwrap();
        let h = svc.submit(ds, spec).unwrap();
        let status = svc.wait(h).unwrap();
        let JobStatus::Done(_) = status else {
            panic!("expected Done, got {status:?}")
        };
        let mi = svc.take(h).unwrap().into_dense().unwrap();
        assert!(mi.max_abs_diff(&want) < 1e-12);
        assert_eq!(svc.job_count(), 0);
    }

    #[test]
    fn builder_defaults_match_default() {
        let built = JobSpec::builder().build().unwrap();
        let def = JobSpec::default();
        assert_eq!(built.backend, def.backend);
        assert_eq!(built.block_cols, def.block_cols);
        assert_eq!(built.inner_workers, def.inner_workers);
        assert_eq!(built.schedule, def.schedule);
        assert_eq!(built.cache_bytes, def.cache_bytes);
        assert_eq!(built.readahead, def.readahead);
        assert_eq!(built.sink, def.sink);
        assert_eq!(built.measure, def.measure);
        assert_eq!(built.task_latency_secs, def.task_latency_secs);
        assert_eq!(built.priority, def.priority);
        assert_eq!(built.tenant, def.tenant);
        assert_eq!(built.tiles, def.tiles);
        assert!(!def.tiles, "tile cache is opt-in per job");
        assert_eq!(built.cluster_workers, def.cluster_workers);
        assert!(def.cluster_workers.is_empty(), "jobs run locally by default");
    }

    #[test]
    fn cluster_job_through_the_service_matches_local() {
        use crate::data::colstore::InMemorySource;

        let ds = SynthSpec::new(300, 16).sparsity(0.7).seed(21).plant(1, 5, 0.05).generate();
        let want = compute_mi(&ds, Backend::BulkBitpack).unwrap();
        // two loopback workers over the same dataset; leaked source so
        // plain spawned threads can serve it
        let src: &'static InMemorySource = Box::leak(Box::new(InMemorySource::new(&ds)));
        let mut addrs = Vec::new();
        for _ in 0..2 {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            addrs.push(l.local_addr().unwrap().to_string());
            std::thread::spawn(move || {
                if let Ok((stream, _)) = l.accept() {
                    let _ = crate::cluster::worker::serve_conn(stream, src);
                }
            });
        }
        let svc = JobService::new(1, 2);
        let spec = JobSpec::builder()
            .block_cols(4)
            .cluster_workers(addrs)
            .build()
            .unwrap();
        let h = svc.submit(ds, spec).unwrap();
        let JobStatus::Done(out) = svc.wait(h).unwrap() else {
            panic!("cluster job failed");
        };
        let report = out.meta.cluster.clone().expect("cluster jobs report their run");
        assert_eq!(report.workers, 2);
        assert_eq!(report.retried, 0);
        let mi = svc.take(h).unwrap().into_dense().expect("dense sink");
        for i in 0..want.dim() {
            for j in 0..want.dim() {
                assert_eq!(
                    mi.get(i, j).to_bits(),
                    want.get(i, j).to_bits(),
                    "cell ({i},{j}) must be bit-identical to the local run"
                );
            }
        }
    }

    #[test]
    fn builder_validates() {
        assert!(JobSpec::builder().backend(Backend::Xla).build().is_err());
        assert!(JobSpec::builder().task_latency_secs(0.0).build().is_err());
        assert!(JobSpec::builder().task_latency_secs(f64::NAN).build().is_err());
        assert!(JobSpec::builder().task_latency_secs(0.5).build().is_ok());
    }

    #[test]
    fn topk_sink_job_round_trip() {
        let svc = JobService::new(2, 4);
        let ds = SynthSpec::new(400, 12).sparsity(0.6).seed(9).plant(0, 3, 0.02).generate();
        let full = compute_mi(&ds, Backend::BulkBitpack).unwrap();
        let want = crate::mi::topk::top_k_pairs(&full, 5);
        let spec = JobSpec::builder()
            .block_cols(5)
            .sink(SinkSpec::TopK { k: 5, per_column: false })
            .build()
            .unwrap();
        let h = svc.submit(ds, spec).unwrap();
        let status = svc.wait(h).unwrap();
        let JobStatus::Done(out) = status else {
            panic!("expected top-k output, got {status:?}")
        };
        assert_eq!(out.meta.backend.as_deref(), Some("bulk-bitpack"));
        let crate::mi::sink::SinkData::TopK(pairs) = out.data else {
            panic!("expected top-k output")
        };
        assert_eq!(pairs.len(), 5);
        assert_eq!((pairs[0].i, pairs[0].j), (0, 3));
        for (got, exp) in pairs.iter().zip(&want) {
            assert_eq!((got.i, got.j), (exp.i, exp.j));
            assert_eq!(got.mi, exp.mi);
        }
    }

    #[test]
    fn measure_job_round_trip() {
        use crate::mi::backend::compute_measure;
        let svc = JobService::new(2, 4);
        let ds = SynthSpec::new(300, 10).sparsity(0.6).seed(31).plant(2, 5, 0.02).generate();
        let full = compute_measure(&ds, Backend::BulkBitpack, CombineKind::Jaccard).unwrap();
        let want = crate::mi::topk::top_k_pairs(&full, 3);
        let spec = JobSpec::builder()
            .block_cols(4)
            .sink(SinkSpec::TopK { k: 3, per_column: false })
            .measure(CombineKind::Jaccard)
            .build()
            .unwrap();
        let h = svc.submit(ds, spec).unwrap();
        let JobStatus::Done(out) = svc.wait(h).unwrap() else { panic!() };
        assert_eq!(out.meta.measure.as_deref(), Some("jaccard"));
        let crate::mi::sink::SinkData::TopK(pairs) = out.data else { panic!() };
        for (got, exp) in pairs.iter().zip(&want) {
            assert_eq!((got.i, got.j), (exp.i, exp.j));
            assert_eq!(got.mi, exp.mi, "sink ranks by the selected measure");
        }
    }

    #[test]
    fn pvalue_sink_with_incompatible_measure_fails_cleanly() {
        let svc = JobService::new(1, 2);
        let ds = SynthSpec::new(100, 6).sparsity(0.5).seed(32).generate();
        let spec = JobSpec::builder()
            .sink(SinkSpec::ThresholdPvalue { pvalue: 0.01 })
            .measure(CombineKind::Phi)
            .build()
            .unwrap();
        let h = svc.submit(ds, spec).unwrap();
        let JobStatus::Failed(msg) = svc.wait(h).unwrap() else {
            panic!("expected a clean failure")
        };
        assert!(msg.contains("asymptotic null"), "{msg}");
        // taking a failed job surfaces the same message, typed
        let Err(Error::JobFailed(taken)) = svc.take(h) else {
            panic!("take on a failed job must be JobFailed")
        };
        assert_eq!(taken, msg);
    }

    #[test]
    fn sizing_decision_recorded_in_meta() {
        let svc = JobService::new(2, 4);
        let ds = SynthSpec::new(300, 16).sparsity(0.8).seed(21).generate();

        // explicit block size
        let spec = JobSpec::builder().block_cols(4).build().unwrap();
        let h = svc.submit(ds.clone(), spec).unwrap();
        let JobStatus::Done(out) = svc.wait(h).unwrap() else { panic!() };
        assert_eq!(
            out.meta.sizing,
            Some(BlockSizing {
                block_cols: 4,
                source: "explicit",
                task_latency_secs: DEFAULT_TASK_LATENCY_SECS,
                combine_cells_per_sec: None,
            })
        );

        // fixed backend without a block size: the historical monolithic plan
        let h = svc.submit(ds.clone(), JobSpec::default()).unwrap();
        let JobStatus::Done(out) = svc.wait(h).unwrap() else { panic!() };
        let sizing = out.meta.sizing.expect("sizing recorded");
        assert_eq!(sizing.source, "monolithic");
        assert_eq!(sizing.block_cols, 16);

        // auto without a block size: probe throughput drives the width
        let spec = JobSpec::builder().backend(Backend::Auto).build().unwrap();
        let h = svc.submit(ds, spec).unwrap();
        let JobStatus::Done(out) = svc.wait(h).unwrap() else { panic!() };
        let sizing = out.meta.sizing.expect("sizing recorded");
        assert_eq!(sizing.source, "probe-throughput");
        assert_eq!(sizing.task_latency_secs, DEFAULT_TASK_LATENCY_SECS);
        assert!(sizing.block_cols >= 1 && sizing.block_cols <= 16);
        assert!(out.meta.probe.is_some(), "auto jobs carry the probe report");
        // the probe recorded a combine timing for the measure, so the
        // sizing must have folded it in
        assert!(
            sizing.combine_cells_per_sec.is_some_and(|c| c > 0.0),
            "probe-sized jobs record the combine throughput they used"
        );
    }

    #[test]
    fn submit_source_matches_submit() {
        let svc = JobService::new(2, 4);
        let ds = SynthSpec::new(250, 14).sparsity(0.7).seed(41).plant(1, 9, 0.03).generate();
        let want = compute_mi(&ds, Backend::BulkBitpack).unwrap();
        let src: Arc<dyn ColumnSource> = Arc::new(InMemorySource::new(&ds));
        let spec = JobSpec::builder().block_cols(5).build().unwrap();
        let h = svc.submit_source(Arc::clone(&src), spec).unwrap();
        let JobStatus::Done(out) = svc.wait(h).unwrap() else { panic!() };
        let got = out.into_dense().unwrap();
        assert_eq!(got.max_abs_diff(&want), 0.0, "source job == in-memory job");
    }

    #[test]
    fn packed_source_job_never_plans_monolithically() {
        use crate::data::colstore::PackedFileSource;
        use crate::data::io;
        let svc = JobService::new(1, 2);
        let ds = SynthSpec::new(180, 11).sparsity(0.7).seed(47).generate();
        let want = compute_mi(&ds, Backend::BulkBitpack).unwrap();
        let path =
            std::env::temp_dir().join(format!("bulkmi-svc-ooc-{}.bmat", std::process::id()));
        io::write_bmat_v2(&ds, &path).unwrap();
        let src: Arc<dyn ColumnSource> = Arc::new(PackedFileSource::open(&path).unwrap());
        // default spec (fixed backend, no block size): the fallback for
        // an out-of-core source must be the bounded budget rule — a
        // monolithic plan would fetch the whole file in one col_block
        let h = svc.submit_source(src, JobSpec::default()).unwrap();
        let JobStatus::Done(out) = svc.wait(h).unwrap() else { panic!() };
        let sizing = out.meta.sizing.clone().expect("sizing recorded");
        assert_eq!(sizing.source, "budget");
        let got = out.into_dense().unwrap();
        assert_eq!(got.max_abs_diff(&want), 0.0, "streamed job == in-memory result");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn packed_job_cache_cuts_bytes_read_and_stays_bit_identical() {
        use crate::data::colstore::PackedFileSource;
        use crate::data::io;
        let svc = JobService::new(1, 4);
        let ds = SynthSpec::new(256, 64).sparsity(0.6).seed(53).generate();
        let want = compute_mi(&ds, Backend::BulkBitpack).unwrap();
        let path =
            std::env::temp_dir().join(format!("bulkmi-svc-cache-{}.bmat", std::process::id()));
        io::write_bmat_v2(&ds, &path).unwrap();

        // block_cols 8 -> 8 column blocks, 36 tasks: the acceptance
        // scenario from ISSUE 6. Run uncached first, then cached, each
        // against its own source so the io_stats deltas are per-run.
        let mut bytes = Vec::new();
        for cache_bytes in [Some(0), None] {
            let src: Arc<dyn ColumnSource> = Arc::new(PackedFileSource::open(&path).unwrap());
            let spec = JobSpec::builder()
                .block_cols(8)
                .inner_workers(2)
                .cache_bytes(cache_bytes)
                .build()
                .unwrap();
            let h = svc.submit_source(Arc::clone(&src), spec).unwrap();
            let JobStatus::Done(out) = svc.wait(h).unwrap() else { panic!() };
            let io = out.meta.io.clone().expect("packed jobs report io");
            assert_eq!(io.payload_bytes, 64 * 4 * 8, "64 cols x 4 words x 8 bytes");
            assert!(io.read_amplification > 0.0);
            if cache_bytes.is_none() {
                assert_eq!(out.meta.schedule, Some("panel"));
                let cr = out.meta.cache.clone().expect("cached jobs report the cache");
                assert!(cr.hits > 0, "panel schedule must produce hits: {cr:?}");
            } else {
                assert_eq!(out.meta.schedule, Some("largest-first"));
                assert!(out.meta.cache.is_none(), "cache_bytes=0 disables the cache");
            }
            let got = out.into_dense().unwrap();
            assert_eq!(got.max_abs_diff(&want), 0.0, "cached == uncached == monolithic");
            bytes.push(io.bytes_read);
        }
        let (uncached, cached) = (bytes[0], bytes[1]);
        assert!(
            uncached >= 2 * cached,
            "cache + panel schedule must cut bytes read >= 2x: uncached {uncached}, cached {cached}"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn custom_task_latency_recorded() {
        let svc = JobService::new(1, 2);
        let ds = SynthSpec::new(200, 12).sparsity(0.8).seed(43).generate();
        let spec = JobSpec::builder()
            .backend(Backend::Auto)
            .task_latency_secs(0.25)
            .build()
            .unwrap();
        let h = svc.submit(ds, spec).unwrap();
        let JobStatus::Done(out) = svc.wait(h).unwrap() else { panic!() };
        let sizing = out.meta.sizing.expect("sizing recorded");
        assert_eq!(sizing.task_latency_secs, 0.25);
        assert_eq!(sizing.source, "probe-throughput");
    }

    #[test]
    fn multiple_jobs_complete() {
        let svc = JobService::new(3, 16);
        let mut handles = Vec::new();
        for seed in 0..6 {
            let ds = SynthSpec::new(60, 8).sparsity(0.5).seed(seed).generate();
            handles.push(svc.submit(ds, JobSpec::default()).unwrap());
        }
        for h in handles {
            assert!(matches!(svc.wait(h).unwrap(), JobStatus::Done(_)));
        }
        assert_eq!(svc.metrics().counter("jobs_done").get(), 6);
    }

    #[test]
    fn admission_control_rejects_when_full() {
        let svc = JobService::new(1, 1);
        // first job occupies the only permit (big enough to still be running)
        let big = SynthSpec::new(4000, 64).sparsity(0.5).seed(2).generate();
        let spec = JobSpec::builder().block_cols(8).build().unwrap();
        let h1 = svc.submit(big, spec).unwrap();
        // immediate second submit: queue full
        let ds = SynthSpec::new(10, 4).seed(3).generate();
        let err = svc.submit(ds.clone(), JobSpec::default());
        assert!(err.is_err() || svc.wait(h1).is_ok());
        let _ = svc.wait(h1);
        // after completion a permit is free again
        let h2 = svc.submit(ds, JobSpec::default()).unwrap();
        assert!(matches!(svc.wait(h2).unwrap(), JobStatus::Done(_)));
    }

    #[test]
    fn byte_budget_serializes_concurrent_jobs() {
        // each dense job prices at task_bytes(256, 8) + dense_output_bytes(32);
        // cap the service so only one fits at a time, run three at once
        let per_job = task_bytes(256, 8) + dense_output_bytes(32);
        let svc = JobService::with_budget(3, 8, per_job + per_job / 2);
        let want = {
            let ds = SynthSpec::new(256, 32).sparsity(0.6).seed(71).generate();
            compute_mi(&ds, Backend::BulkBitpack).unwrap()
        };
        let mut handles = Vec::new();
        for _ in 0..3 {
            let ds = SynthSpec::new(256, 32).sparsity(0.6).seed(71).generate();
            let spec = JobSpec::builder().block_cols(8).build().unwrap();
            handles.push(svc.submit(ds, spec).unwrap());
        }
        for h in handles {
            let JobStatus::Done(out) = svc.wait(h).unwrap() else { panic!() };
            let adm = out.meta.admission.clone().expect("admission recorded");
            assert_eq!(adm.estimated_bytes, per_job);
            assert_eq!(adm.priority, "batch");
            assert!(adm.queued_secs >= 0.0);
            let got = out.into_dense().unwrap();
            assert_eq!(got.max_abs_diff(&want), 0.0, "capped run == uncapped result");
        }
        let gate = svc.admission();
        assert!(
            gate.peak_bytes() <= per_job + per_job / 2,
            "aggregate cap violated: peak {} > {}",
            gate.peak_bytes(),
            per_job + per_job / 2
        );
        assert_eq!(gate.admitted(), 3);
        assert_eq!(gate.inflight_bytes(), 0, "all permits returned");
        assert!(svc.metrics().histogram("admission_wait_secs").count() >= 3);
    }

    #[test]
    fn interactive_priority_recorded_for_topk() {
        let svc = JobService::new(1, 2);
        let ds = SynthSpec::new(120, 10).sparsity(0.6).seed(83).generate();
        let spec = JobSpec::builder()
            .block_cols(4)
            .sink(SinkSpec::TopK { k: 3, per_column: false })
            .build()
            .unwrap();
        let h = svc.submit(ds, spec).unwrap();
        let JobStatus::Done(out) = svc.wait(h).unwrap() else { panic!() };
        assert_eq!(out.meta.admission.unwrap().priority, "interactive");
        let info_err = svc.info(JobHandle(999));
        assert!(info_err.is_err());
    }

    #[test]
    fn info_exposes_admission_facts() {
        let svc = JobService::new(1, 2);
        let ds = SynthSpec::new(100, 8).sparsity(0.6).seed(88).generate();
        let spec = JobSpec::builder().priority(Priority::Interactive).build().unwrap();
        let h = svc.submit(ds, spec).unwrap();
        let info = svc.info(h).unwrap();
        assert_eq!(info.priority, Priority::Interactive);
        assert!(info.estimated_bytes > 0);
        let _ = svc.wait(h);
    }

    #[test]
    fn tenant_counters_are_namespaced() {
        let svc = JobService::new(1, 2);
        let ds = SynthSpec::new(80, 8).sparsity(0.6).seed(91).generate();
        let spec = JobSpec::builder().tenant("acme").build().unwrap();
        let h = svc.submit(ds, spec).unwrap();
        assert!(matches!(svc.wait(h).unwrap(), JobStatus::Done(_)));
        assert_eq!(svc.metrics().counter("tenant:acme:jobs_done").get(), 1);
        assert!(svc.metrics().counter("tenant:acme:admission_est_bytes").get() > 0);
    }

    #[test]
    fn drain_stops_admission_and_waits_for_jobs() {
        let svc = JobService::new(2, 8);
        let ds = SynthSpec::new(2000, 48).sparsity(0.5).seed(97).generate();
        let spec = JobSpec::builder().block_cols(8).build().unwrap();
        let h = svc.submit(ds.clone(), spec).unwrap();
        svc.drain();
        assert!(svc.is_draining());
        // drained: the submitted job is terminal, new submissions bounce
        assert!(matches!(svc.poll(h).unwrap(), JobStatus::Done(_)));
        let err = svc.submit(ds, JobSpec::default()).unwrap_err();
        assert!(err.to_string().contains("draining"), "{err}");
    }

    #[test]
    fn cancel_running_job() {
        let svc = JobService::new(1, 4);
        let ds = SynthSpec::new(5000, 128).sparsity(0.5).seed(4).generate();
        let spec = JobSpec::builder().block_cols(4).build().unwrap();
        let h = svc.submit(ds, spec).unwrap();
        svc.cancel(h).unwrap();
        let status = svc.wait(h).unwrap();
        assert!(
            matches!(status, JobStatus::Cancelled) || matches!(status, JobStatus::Done(_)),
            "cancelled or already finished, got {status:?}"
        );
    }

    #[test]
    fn double_cancel_and_take_after_cancel_are_typed() {
        let svc = JobService::new(1, 4);
        // occupy the single worker so the second job stays queued
        let big = SynthSpec::new(4000, 96).sparsity(0.5).seed(5).generate();
        let spec = JobSpec::builder().block_cols(8).build().unwrap();
        let h1 = svc.submit(big, spec).unwrap();
        let small = SynthSpec::new(50, 6).sparsity(0.5).seed(6).generate();
        let h2 = svc.submit(small, JobSpec::default()).unwrap();

        svc.cancel(h2).unwrap();
        assert!(matches!(svc.wait(h2).unwrap(), JobStatus::Cancelled));
        // second cancel: typed terminal error
        let Err(Error::JobTerminal(msg)) = svc.cancel(h2) else {
            panic!("double cancel must be JobTerminal")
        };
        assert!(msg.contains("cancelled"), "{msg}");
        // take after cancel: typed cancelled error, entry consumed
        let Err(Error::JobCancelled(_)) = svc.take(h2) else {
            panic!("take after cancel must be JobCancelled")
        };
        let Err(Error::Coordinator(msg)) = svc.take(h2) else {
            panic!("second take must see an unknown job")
        };
        assert!(msg.contains("unknown job"), "{msg}");

        let _ = svc.wait(h1);
        let gate = svc.admission();
        // the cancelled-while-queued job never admitted bytes; the big
        // job's permit was returned exactly once
        assert_eq!(gate.inflight_bytes(), 0);
        assert_eq!(gate.inflight_jobs(), 0);
        assert_eq!(gate.admitted(), 1);
    }

    #[test]
    fn zero_column_submit_rejected() {
        let svc = JobService::new(1, 2);
        let ds = BinaryDataset::new(5, 0, vec![]).unwrap();
        assert!(svc.submit(ds, JobSpec::default()).is_err());
    }

    #[test]
    fn unknown_handles_error() {
        let svc = JobService::new(1, 2);
        assert!(svc.poll(JobHandle(999)).is_err());
        assert!(svc.cancel(JobHandle(999)).is_err());
        assert!(svc.take(JobHandle(999)).is_err());
    }

    #[test]
    fn take_in_flight_errors() {
        let svc = JobService::new(1, 2);
        let ds = SynthSpec::new(3000, 64).sparsity(0.5).seed(5).generate();
        let spec = JobSpec::builder().block_cols(8).build().unwrap();
        let h = svc.submit(ds, spec).unwrap();
        // likely still running
        let r = svc.take(h);
        if let Ok(out) = r {
            // raced to completion; fine
            assert!(out.into_dense().is_some());
        }
        let _ = svc.wait(h);
    }
}
