//! RAM-bounded admission control for the job service.
//!
//! Every job is priced *before* it runs, from the same sizing model the
//! planner uses ([`task_bytes`]), so the service can bound the
//! **aggregate** resident bytes of all concurrently running jobs
//! instead of discovering an over-commit as an OOM kill:
//!
//! ```text
//! job_bytes = task_bytes(n, block) * inner_workers   (Gram working set)
//!           + sink_state_bytes(sink, m)              (accumulated output)
//!           + private cache budget                   (explicit --cache-budget)
//! ```
//!
//! The shared auto-carved substrate cache is deliberately *not* part of
//! a job's price: it is one server-wide allocation, accounted once by
//! whoever constructs the [`super::service::JobService`].
//!
//! Admission is strict priority order ([`Priority::Interactive`] jumps
//! [`Priority::Batch`]), FIFO within a class. A job whose price exceeds
//! the whole budget is still admitted — but only once the server is
//! idle, so the cap degrades to "one oversized job at a time" instead
//! of deadlocking. Permits are RAII: the reserved bytes are returned
//! exactly once when the [`AdmissionPermit`] drops, however the job
//! ends (done, failed, cancelled, panicked worker).

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::blockcache::cache_plan;
use super::planner::{dense_output_bytes, matrix_free_block, task_bytes};
use super::service::JobSpec;
use crate::mi::sink::SinkSpec;
use crate::mi::topk::MiPair;

/// Scheduling class for admission: interactive jobs overtake queued
/// batch jobs when bytes free up.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Priority {
    /// Latency-sensitive queries (top-k, thresholded screens).
    Interactive,
    /// Throughput work (dense all-pairs, spill runs).
    Batch,
}

impl Priority {
    /// Stable lowercase name for metrics / the wire schema.
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }

    /// Inverse of [`Priority::name`].
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "interactive" => Some(Priority::Interactive),
            "batch" => Some(Priority::Batch),
            _ => None,
        }
    }

    /// Default class for a sink when the submitter does not say:
    /// bounded-output sinks are interactive, full-matrix ones batch.
    pub fn for_sink(sink: &SinkSpec) -> Priority {
        match sink {
            SinkSpec::Dense | SinkSpec::Spill { .. } => Priority::Batch,
            SinkSpec::TopK { .. }
            | SinkSpec::ThresholdMi { .. }
            | SinkSpec::ThresholdPvalue { .. } => Priority::Interactive,
        }
    }

    fn rank(self) -> u8 {
        match self {
            Priority::Interactive => 0,
            Priority::Batch => 1,
        }
    }
}

/// Resident bytes a sink accumulates while a job runs.
///
/// Dense holds the full `m x m` output; top-k holds `k` (or `m*k`
/// per-column) heap entries; threshold sinks are priced at one retained
/// pair per column — a documented heuristic, since true retention
/// depends on the data. Spill keeps nothing resident beyond the block
/// in flight, which the working-set term already covers.
pub fn sink_state_bytes(sink: &SinkSpec, m: usize) -> usize {
    const PAIR: usize = std::mem::size_of::<MiPair>();
    match sink {
        SinkSpec::Dense => dense_output_bytes(m),
        SinkSpec::TopK { k, per_column: false } => k.saturating_mul(PAIR),
        SinkSpec::TopK { k, per_column: true } => m.saturating_mul(*k).saturating_mul(PAIR),
        SinkSpec::ThresholdMi { .. } | SinkSpec::ThresholdPvalue { .. } => {
            m.saturating_mul(PAIR)
        }
        SinkSpec::Spill { .. } => 0,
    }
}

/// Price a job: the peak resident bytes it is expected to pin while
/// running (see the module docs for the model).
pub fn estimate_job_bytes(
    n_rows: usize,
    n_cols: usize,
    out_of_core: bool,
    spec: &JobSpec,
) -> usize {
    let (cache_budget, task_budget) = cache_plan(spec.cache_bytes, out_of_core, 0);
    let block = if spec.block_cols > 0 {
        spec.block_cols.min(n_cols.max(1))
    } else if out_of_core {
        matrix_free_block(n_rows, n_cols, task_budget)
    } else {
        // monolithic worst case: probe-throughput sizing only shrinks it
        n_cols.max(1)
    };
    let lanes = spec.inner_workers.max(1);
    let working = task_bytes(n_rows, block).saturating_mul(lanes);
    // only an *explicit* cache budget is private to the job; the
    // auto-carved cache is the shared server-wide one (priced once)
    let private_cache = match (cache_budget, spec.cache_bytes) {
        (Some(n), Some(_)) => n,
        _ => 0,
    };
    working
        .saturating_add(sink_state_bytes(&spec.sink, n_cols))
        .saturating_add(private_cache)
}

#[derive(Debug)]
struct Ticket {
    seq: u64,
    rank: u8,
    bytes: usize,
}

#[derive(Debug, Default)]
struct AdmState {
    inflight_bytes: usize,
    inflight_jobs: usize,
    peak_bytes: usize,
    admitted: u64,
    next_seq: u64,
    waiting: Vec<Ticket>,
}

/// Aggregate-byte admission gate shared by every job of a service.
#[derive(Debug)]
pub struct AdmissionController {
    /// `usize::MAX` means unbounded.
    budget: usize,
    state: Mutex<AdmState>,
    cv: Condvar,
}

impl AdmissionController {
    /// `budget_bytes == 0` means unbounded (every job admits at once).
    pub fn new(budget_bytes: usize) -> Self {
        AdmissionController {
            budget: if budget_bytes == 0 { usize::MAX } else { budget_bytes },
            state: Mutex::new(AdmState::default()),
            cv: Condvar::new(),
        }
    }

    pub fn unbounded() -> Self {
        Self::new(0)
    }

    /// The configured cap; `None` when unbounded.
    pub fn budget_bytes(&self) -> Option<usize> {
        if self.budget == usize::MAX {
            None
        } else {
            Some(self.budget)
        }
    }

    /// Estimated bytes currently admitted (running jobs).
    pub fn inflight_bytes(&self) -> usize {
        self.state.lock().unwrap().inflight_bytes
    }

    /// Number of currently admitted jobs.
    pub fn inflight_jobs(&self) -> usize {
        self.state.lock().unwrap().inflight_jobs
    }

    /// High-water mark of admitted bytes since construction.
    pub fn peak_bytes(&self) -> usize {
        self.state.lock().unwrap().peak_bytes
    }

    /// Total jobs ever admitted.
    pub fn admitted(&self) -> u64 {
        self.state.lock().unwrap().admitted
    }

    /// Jobs currently queued behind the byte cap.
    pub fn waiting(&self) -> usize {
        self.state.lock().unwrap().waiting.len()
    }

    /// Block until `bytes` fit under the aggregate cap (strict
    /// priority-then-FIFO order), or `cancelled()` turns true. Returns
    /// `None` only on cancellation. A request larger than the whole
    /// budget waits for the server to go idle, then runs alone.
    pub fn admit(
        self: &Arc<Self>,
        bytes: usize,
        priority: Priority,
        cancelled: &dyn Fn() -> bool,
    ) -> Option<AdmissionPermit> {
        let mut st = self.state.lock().unwrap();
        let seq = st.next_seq;
        st.next_seq += 1;
        st.waiting.push(Ticket { seq, rank: priority.rank(), bytes });
        loop {
            let head = st
                .waiting
                .iter()
                .map(|t| (t.rank, t.seq))
                .min()
                .expect("own ticket is registered");
            let fits = st.inflight_bytes == 0
                || st.inflight_bytes.saturating_add(bytes) <= self.budget;
            if head == (priority.rank(), seq) && fits {
                st.waiting.retain(|t| t.seq != seq);
                st.inflight_bytes = st.inflight_bytes.saturating_add(bytes);
                st.inflight_jobs += 1;
                st.peak_bytes = st.peak_bytes.max(st.inflight_bytes);
                st.admitted += 1;
                drop(st);
                // the head changed: let the next-best waiter re-evaluate
                self.cv.notify_all();
                return Some(AdmissionPermit { ctrl: Arc::clone(self), bytes });
            }
            let (guard, _) = self.cv.wait_timeout(st, Duration::from_millis(25)).unwrap();
            st = guard;
            if cancelled() {
                st.waiting.retain(|t| t.seq != seq);
                drop(st);
                self.cv.notify_all();
                return None;
            }
        }
    }
}

/// RAII receipt for admitted bytes; dropping it returns them exactly
/// once and wakes the queue.
#[derive(Debug)]
pub struct AdmissionPermit {
    ctrl: Arc<AdmissionController>,
    bytes: usize,
}

impl AdmissionPermit {
    /// The bytes this permit reserved.
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

impl Drop for AdmissionPermit {
    fn drop(&mut self) {
        let mut st = self.ctrl.state.lock().unwrap();
        st.inflight_bytes = st.inflight_bytes.saturating_sub(self.bytes);
        st.inflight_jobs = st.inflight_jobs.saturating_sub(1);
        drop(st);
        self.ctrl.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;
    use std::time::Instant;

    fn never() -> bool {
        false
    }

    #[test]
    fn unbounded_admits_everything_at_once() {
        let ctrl = Arc::new(AdmissionController::unbounded());
        let a = ctrl.admit(usize::MAX / 2, Priority::Batch, &never).unwrap();
        let b = ctrl.admit(usize::MAX / 2, Priority::Batch, &never).unwrap();
        assert_eq!(ctrl.inflight_jobs(), 2);
        assert!(ctrl.budget_bytes().is_none());
        drop((a, b));
        assert_eq!(ctrl.inflight_bytes(), 0);
    }

    #[test]
    fn over_budget_jobs_serialize_and_peak_stays_under_cap() {
        let ctrl = Arc::new(AdmissionController::new(100));
        let first = ctrl.admit(80, Priority::Batch, &never).unwrap();
        let c2 = Arc::clone(&ctrl);
        let (tx, rx) = mpsc::channel();
        let waiter = thread::spawn(move || {
            let p = c2.admit(80, Priority::Batch, &never).unwrap();
            tx.send(()).unwrap();
            drop(p);
        });
        // the second 80 does not fit next to the first
        assert!(rx.recv_timeout(Duration::from_millis(100)).is_err());
        assert_eq!(ctrl.waiting(), 1);
        drop(first);
        rx.recv_timeout(Duration::from_secs(5)).expect("waiter admitted after release");
        waiter.join().unwrap();
        assert!(ctrl.peak_bytes() <= 100, "peak {} > cap", ctrl.peak_bytes());
        assert_eq!(ctrl.admitted(), 2);
    }

    #[test]
    fn interactive_overtakes_queued_batch() {
        let ctrl = Arc::new(AdmissionController::new(100));
        let holder = ctrl.admit(100, Priority::Batch, &never).unwrap();
        let order = Arc::new(Mutex::new(Vec::new()));

        let (c, o) = (Arc::clone(&ctrl), Arc::clone(&order));
        let batch = thread::spawn(move || {
            let p = c.admit(60, Priority::Batch, &never).unwrap();
            o.lock().unwrap().push("batch");
            drop(p);
        });
        thread::sleep(Duration::from_millis(60)); // batch queues first
        let (c, o) = (Arc::clone(&ctrl), Arc::clone(&order));
        let inter = thread::spawn(move || {
            let p = c.admit(60, Priority::Interactive, &never).unwrap();
            o.lock().unwrap().push("interactive");
            // hold so batch cannot slip in concurrently (60+60 > 100)
            thread::sleep(Duration::from_millis(60));
            drop(p);
        });
        thread::sleep(Duration::from_millis(60)); // interactive queued too
        assert_eq!(ctrl.waiting(), 2);
        drop(holder);
        batch.join().unwrap();
        inter.join().unwrap();
        assert_eq!(*order.lock().unwrap(), vec!["interactive", "batch"]);
        assert!(ctrl.peak_bytes() <= 100);
    }

    #[test]
    fn oversized_job_runs_alone() {
        let ctrl = Arc::new(AdmissionController::new(10));
        let big = ctrl.admit(1000, Priority::Batch, &never).unwrap();
        let c2 = Arc::clone(&ctrl);
        let t = thread::spawn(move || {
            let t0 = Instant::now();
            drop(c2.admit(5, Priority::Interactive, &never).unwrap());
            t0.elapsed()
        });
        thread::sleep(Duration::from_millis(80));
        drop(big);
        let waited = t.join().unwrap();
        assert!(waited >= Duration::from_millis(50), "small job ran beside oversized one");
    }

    #[test]
    fn cancelled_waiter_unregisters() {
        let ctrl = Arc::new(AdmissionController::new(10));
        let hold = ctrl.admit(10, Priority::Batch, &never).unwrap();
        let c2 = Arc::clone(&ctrl);
        let t = thread::spawn(move || c2.admit(10, Priority::Batch, &|| true));
        assert!(t.join().unwrap().is_none());
        assert_eq!(ctrl.waiting(), 0);
        drop(hold);
        assert_eq!(ctrl.inflight_bytes(), 0);
    }

    #[test]
    fn priority_names_round_trip() {
        for p in [Priority::Interactive, Priority::Batch] {
            assert_eq!(Priority::parse(p.name()), Some(p));
        }
        assert_eq!(Priority::parse("turbo"), None);
        assert_eq!(Priority::for_sink(&SinkSpec::Dense), Priority::Batch);
        assert_eq!(
            Priority::for_sink(&SinkSpec::TopK { k: 4, per_column: false }),
            Priority::Interactive
        );
    }

    #[test]
    fn sink_pricing_model() {
        const PAIR: usize = std::mem::size_of::<MiPair>();
        assert_eq!(sink_state_bytes(&SinkSpec::Dense, 100), 100 * 100 * 8);
        assert_eq!(sink_state_bytes(&SinkSpec::TopK { k: 8, per_column: false }, 100), 8 * PAIR);
        assert_eq!(
            sink_state_bytes(&SinkSpec::TopK { k: 8, per_column: true }, 100),
            100 * 8 * PAIR
        );
        assert_eq!(sink_state_bytes(&SinkSpec::ThresholdMi { threshold: 0.1 }, 100), 100 * PAIR);
        assert_eq!(
            sink_state_bytes(&SinkSpec::Spill { dir: std::path::PathBuf::from("/tmp/x") }, 100),
            0
        );
    }

    #[test]
    fn job_pricing_covers_working_set_sink_and_private_cache() {
        let base = JobSpec::builder().block_cols(8).build().unwrap();
        let dense = estimate_job_bytes(1000, 64, false, &base);
        assert_eq!(dense, task_bytes(1000, 8) + dense_output_bytes(64));

        let topk = JobSpec::builder()
            .block_cols(8)
            .sink(SinkSpec::TopK { k: 4, per_column: false })
            .build()
            .unwrap();
        assert!(estimate_job_bytes(1000, 64, false, &topk) < dense);

        let cached = JobSpec::builder()
            .block_cols(8)
            .cache_bytes(Some(1 << 20))
            .build()
            .unwrap();
        assert_eq!(estimate_job_bytes(1000, 64, false, &cached), dense + (1 << 20));

        // more lanes pin more concurrent task working sets
        let wide = JobSpec::builder().block_cols(8).inner_workers(4).build().unwrap();
        assert_eq!(
            estimate_job_bytes(1000, 64, false, &wide),
            4 * task_bytes(1000, 8) + dense_output_bytes(64)
        );
    }
}
