//! Block-substrate cache: the block-reuse layer for out-of-core runs.
//!
//! A blockwise plan over `nb` column blocks has `nb(nb+1)/2` tasks, and
//! an uncached [`super::executor::NativeProvider`] fetches and rebuilds
//! both of a task's substrates from the [`ColumnSource`] on every task —
//! `nb²` block fetches where `nb` would do. For an in-memory source the
//! refetch is a memcpy; for a [`crate::data::colstore::PackedFileSource`]
//! it is a disk read plus a CSR build or `to_mat32` conversion, which
//! makes the streaming path I/O-bound instead of matmul-bound. The
//! [`BlockCache`] closes that gap: a bounded, process-wide LRU keyed by
//! `(source id, start, len, kind)` holding the *constructed* per-block
//! substrate (packed bits, CSR, or dense f32), so fetch + build happen
//! once per block per run. Combined with the panel task order
//! ([`crate::coordinator::scheduler::Schedule::Panel`]) the fetch count
//! drops from `O(nb²)` to `O(nb)` whenever the cache holds a panel's
//! working set.
//!
//! Concurrency model: the cache never holds its lock across a build.
//! `get_or_build` is lock → probe → unlock → build → lock → insert; two
//! workers racing on the same missing block may both build it (correct,
//! occasionally wasteful), and the second one adopts the first's entry
//! so both tasks share one allocation. Values are `Arc<Substrate>`, so
//! eviction never invalidates a block a task is still computing with.
//!
//! Budget honesty: the cache's byte budget is carved out of the run's
//! memory budget ([`crate::coordinator::planner::carve_cache_budget`]),
//! so `task_bytes` block sizing and the cache together stay within what
//! the caller asked for. An entry larger than the whole budget is
//! served but never retained.

use super::executor::NativeKind;
use crate::data::colstore::{ColumnSource, IoStats};
use crate::linalg::bitmat::BitMatrix;
use crate::linalg::csr::CsrMatrix;
use crate::linalg::dense::{Mat32, Mat64};
use crate::mi::sink::{CacheReport, IoReport};
use crate::util::error::{Error, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::Instant;

/// A constructed per-block Gram substrate — what a
/// [`super::executor::NativeProvider`] builds from a fetched column
/// block, and what the cache retains so the build happens once per
/// block instead of once per task.
pub enum Substrate {
    Bits(BitMatrix),
    Csr(CsrMatrix),
    Dense(Mat32),
}

impl Substrate {
    /// Build the substrate `kind` from a fetched bit-packed block.
    pub fn build(bits: BitMatrix, kind: NativeKind) -> Substrate {
        match kind {
            NativeKind::Bitpack => Substrate::Bits(bits),
            NativeKind::Sparse => Substrate::Csr(CsrMatrix::from_bitmatrix(&bits)),
            NativeKind::Dense => Substrate::Dense(bits.to_mat32()),
        }
    }

    /// Resident bytes, the cache's cost model (CSR: indices + indptr).
    pub fn bytes(&self) -> usize {
        match self {
            Substrate::Bits(b) => b.words().len() * 8,
            Substrate::Csr(c) => c.nnz() * 4 + (c.rows() + 1) * 8,
            Substrate::Dense(d) => d.rows() * d.cols() * 4,
        }
    }

    /// Diagonal Gram — the same per-substrate routine the uncached
    /// provider always used, so cached runs stay bit-identical.
    pub fn gram(&self) -> Mat64 {
        match self {
            Substrate::Bits(b) => b.gram(),
            Substrate::Csr(c) => c.gram(),
            Substrate::Dense(d) => crate::linalg::blas::gram(d),
        }
    }

    /// Cross Gram against a substrate of the same kind.
    pub fn gram_cross(&self, other: &Substrate) -> Result<Mat64> {
        match (self, other) {
            (Substrate::Bits(a), Substrate::Bits(b)) => a.gram_cross(b),
            (Substrate::Csr(a), Substrate::Csr(b)) => a.gram_cross(b),
            (Substrate::Dense(a), Substrate::Dense(b)) => crate::linalg::blas::gemm_at_b(a, b),
            _ => Err(Error::Coordinator(
                "gram_cross over mismatched substrate kinds".into(),
            )),
        }
    }
}

/// Cache key: which block of which source, built for which substrate.
/// The source id comes from [`BlockCache::source_id`] /
/// [`BlockCache::fresh_source_id`] — never from the source's address
/// alone, so a recycled allocation can never serve stale blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BlockKey {
    pub source: u64,
    pub start: usize,
    pub len: usize,
    pub kind: NativeKind,
}

/// A snapshot of the cache's counters. Take one before a run and
/// [`CacheStats::since`] after it to get per-run numbers (the cache is
/// process-wide, so absolute counters span runs).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    /// Requests served from a resident entry.
    pub hits: u64,
    /// Requests that had to build the substrate.
    pub misses: u64,
    /// Entries dropped to stay under the byte budget.
    pub evictions: u64,
    /// Misses filled by the readahead stage (`demand = false`) rather
    /// than by a stalled worker.
    pub prefetched: u64,
    /// Bytes of substrate inserted (lifetime, not resident).
    pub inserted_bytes: u64,
    /// Wall time demand-path misses spent in fetch + build — the I/O
    /// stall the cache and prefetch exist to hide.
    pub stall_secs: f64,
}

impl CacheStats {
    /// Counters accumulated since the `earlier` snapshot.
    pub fn since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits.saturating_sub(earlier.hits),
            misses: self.misses.saturating_sub(earlier.misses),
            evictions: self.evictions.saturating_sub(earlier.evictions),
            prefetched: self.prefetched.saturating_sub(earlier.prefetched),
            inserted_bytes: self.inserted_bytes.saturating_sub(earlier.inserted_bytes),
            stall_secs: (self.stall_secs - earlier.stall_secs).max(0.0),
        }
    }
}

struct Entry {
    value: Arc<Substrate>,
    bytes: usize,
    last_use: u64,
}

struct Inner {
    map: HashMap<BlockKey, Entry>,
    total_bytes: usize,
    /// Monotone access clock; unique per touch, so LRU has no ties.
    tick: u64,
}

/// Bounded LRU over constructed block substrates. Thread-safe; see the
/// module docs for the concurrency and budget model.
pub struct BlockCache {
    budget: usize,
    inner: Mutex<Inner>,
    /// Source identity registry: allocation address -> (id, liveness
    /// witness). A dead witness at a reused address purges the old id's
    /// entries before a new id is handed out.
    sources: Mutex<HashMap<usize, (u64, Weak<dyn ColumnSource>)>>,
    next_source: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    prefetched: AtomicU64,
    inserted_bytes: AtomicU64,
    stall_nanos: AtomicU64,
}

impl BlockCache {
    pub fn new(budget_bytes: usize) -> Self {
        BlockCache {
            budget: budget_bytes,
            inner: Mutex::new(Inner { map: HashMap::new(), total_bytes: 0, tick: 0 }),
            sources: Mutex::new(HashMap::new()),
            next_source: AtomicU64::new(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            prefetched: AtomicU64::new(0),
            inserted_bytes: AtomicU64::new(0),
            stall_nanos: AtomicU64::new(0),
        }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// Bytes currently resident.
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().total_bytes
    }

    /// Entries currently resident.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            prefetched: self.prefetched.load(Ordering::Relaxed),
            inserted_bytes: self.inserted_bytes.load(Ordering::Relaxed),
            stall_secs: self.stall_nanos.load(Ordering::Relaxed) as f64 / 1e9,
        }
    }

    /// Stable id for a shared source: the same live `Arc` always maps
    /// to the same id (so jobs over one `serve --input` file share
    /// blocks), and an address recycled after the source died gets a
    /// fresh id with the dead id's entries purged first.
    pub fn source_id(&self, src: &Arc<dyn ColumnSource>) -> u64 {
        let ptr = Arc::as_ptr(src) as *const () as usize;
        let mut sources = self.sources.lock().unwrap();
        let existing = sources.get(&ptr).map(|(id, weak)| (*id, weak.upgrade().is_some()));
        match existing {
            Some((id, true)) => return id,
            Some((id, false)) => {
                sources.remove(&ptr);
                self.purge_source(id);
            }
            None => {}
        }
        let id = self.next_source.fetch_add(1, Ordering::Relaxed);
        sources.insert(ptr, (id, Arc::downgrade(src)));
        id
    }

    /// A never-before-used id for a non-shared (borrowed) source — its
    /// entries can only ever be hit through the handle that owns it.
    pub fn fresh_source_id(&self) -> u64 {
        self.next_source.fetch_add(1, Ordering::Relaxed)
    }

    /// Drop every entry of one source id.
    pub fn purge_source(&self, source: u64) {
        let mut inner = self.inner.lock().unwrap();
        let keys: Vec<BlockKey> =
            inner.map.keys().filter(|k| k.source == source).copied().collect();
        for k in keys {
            let e = inner.map.remove(&k).unwrap();
            inner.total_bytes -= e.bytes;
        }
    }

    /// Serve `key` from the cache or build it with `build`, retaining
    /// the result when it fits the budget. `demand` distinguishes a
    /// worker that is stalled on the block (counted into `stall_secs`)
    /// from the readahead stage (counted into `prefetched`).
    pub fn get_or_build(
        &self,
        key: BlockKey,
        demand: bool,
        build: impl FnOnce() -> Result<Substrate>,
    ) -> Result<Arc<Substrate>> {
        {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(e) = inner.map.get_mut(&key) {
                e.last_use = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(Arc::clone(&e.value));
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let built = Arc::new(build()?);
        if demand {
            self.stall_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        } else {
            self.prefetched.fetch_add(1, Ordering::Relaxed);
        }
        let bytes = built.bytes();
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(e) = inner.map.get_mut(&key) {
            // a racing worker built and inserted it first: adopt that
            // copy so both tasks share one allocation
            e.last_use = tick;
            return Ok(Arc::clone(&e.value));
        }
        if bytes <= self.budget {
            inner.total_bytes += bytes;
            inner
                .map
                .insert(key, Entry { value: Arc::clone(&built), bytes, last_use: tick });
            self.inserted_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
            // evict LRU down to budget; the fresh entry carries the
            // newest tick, so it is only ever the last one standing
            while inner.total_bytes > self.budget {
                let victim = inner.map.iter().min_by_key(|(_, e)| e.last_use).map(|(k, _)| *k);
                match victim {
                    Some(k) => {
                        let e = inner.map.remove(&k).unwrap();
                        inner.total_bytes -= e.bytes;
                        self.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                    None => break,
                }
            }
        }
        Ok(built)
    }
}

/// A cache plus the source id requests are keyed under — what a
/// [`super::executor::NativeProvider`] carries.
#[derive(Clone)]
pub struct CacheHandle {
    cache: Arc<BlockCache>,
    source: u64,
}

impl CacheHandle {
    /// Handle for a shared (`Arc`) source: stable id, so later jobs
    /// over the same source hit this run's blocks.
    pub fn for_source(cache: Arc<BlockCache>, src: &Arc<dyn ColumnSource>) -> Self {
        let source = cache.source_id(src);
        CacheHandle { cache, source }
    }

    /// Handle with a fresh id (borrowed / single-run sources).
    pub fn fresh(cache: Arc<BlockCache>) -> Self {
        let source = cache.fresh_source_id();
        CacheHandle { cache, source }
    }

    pub fn cache(&self) -> &Arc<BlockCache> {
        &self.cache
    }

    pub fn source(&self) -> u64 {
        self.source
    }

    /// [`BlockCache::get_or_build`] under this handle's source id.
    pub fn get_or_build(
        &self,
        start: usize,
        len: usize,
        kind: NativeKind,
        demand: bool,
        build: impl FnOnce() -> Result<Substrate>,
    ) -> Result<Arc<Substrate>> {
        self.cache
            .get_or_build(BlockKey { source: self.source, start, len, kind }, demand, build)
    }
}

/// Resolve a run's cache decision from its knobs. An explicit
/// `cache_bytes` wins (`Some(0)` disables the cache); `None`
/// auto-enables it for out-of-core sources only, carving the budget
/// out of `memory_budget` via
/// [`crate::coordinator::planner::carve_cache_budget`]. Returns
/// `(cache budget when enabled, task memory budget)` — block sizing
/// must use the second value so the combined footprint stays within
/// what the caller asked for.
pub fn cache_plan(
    cache_bytes: Option<usize>,
    out_of_core: bool,
    memory_budget: usize,
) -> (Option<usize>, usize) {
    match cache_bytes {
        Some(0) => (None, memory_budget),
        Some(n) => (Some(n), memory_budget),
        None if out_of_core => {
            let (task, cache) = super::planner::carve_cache_budget(memory_budget);
            (Some(cache), task)
        }
        None => (None, memory_budget),
    }
}

/// Build a run's [`IoReport`] / [`CacheReport`] from start-of-run
/// snapshots — the shared tail of the job service and the CLI drivers.
/// `None` io when the source is not instrumented (in-memory).
pub fn run_reports(
    src: &dyn ColumnSource,
    io_before: Option<IoStats>,
    cache: Option<(&BlockCache, CacheStats)>,
) -> (Option<IoReport>, Option<CacheReport>) {
    let io = match (io_before, src.io_stats()) {
        (Some(before), Some(now)) => {
            let d = now.since(&before);
            let payload = src.payload_bytes_hint().unwrap_or(0);
            Some(IoReport {
                bytes_read: d.bytes_read,
                reads: d.reads,
                read_secs: d.read_secs,
                payload_bytes: payload,
                read_amplification: if payload > 0 {
                    d.bytes_read as f64 / payload as f64
                } else {
                    0.0
                },
            })
        }
        _ => None,
    };
    let cache = cache.map(|(c, before)| {
        let d = c.stats().since(&before);
        CacheReport {
            hits: d.hits,
            misses: d.misses,
            evictions: d.evictions,
            prefetched: d.prefetched,
            stall_secs: d.stall_secs,
            budget_bytes: c.budget_bytes(),
        }
    });
    (io, cache)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::colstore::InMemorySource;
    use crate::data::synth::SynthSpec;

    fn bits(seed: u64) -> BitMatrix {
        SynthSpec::new(128, 4).sparsity(0.5).seed(seed).generate().to_bitmatrix()
    }

    fn key(source: u64, start: usize) -> BlockKey {
        BlockKey { source, start, len: 4, kind: NativeKind::Bitpack }
    }

    #[test]
    fn hit_after_miss_shares_the_entry() {
        let cache = BlockCache::new(1 << 20);
        let a = cache
            .get_or_build(key(1, 0), true, || Ok(Substrate::build(bits(1), NativeKind::Bitpack)))
            .unwrap();
        let b = cache
            .get_or_build(key(1, 0), true, || panic!("must not rebuild on a hit"))
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.resident_bytes(), a.bytes());
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        // each substrate is 128 rows x 4 cols bitpack = 2 words * 4 cols
        // * 8 bytes = 64 bytes; budget fits exactly two
        let one = Substrate::build(bits(1), NativeKind::Bitpack).bytes();
        let cache = BlockCache::new(2 * one);
        let build = |seed| move || Ok(Substrate::build(bits(seed), NativeKind::Bitpack));
        cache.get_or_build(key(1, 0), true, build(1)).unwrap();
        cache.get_or_build(key(1, 4), true, build(2)).unwrap();
        cache.get_or_build(key(1, 0), true, build(1)).unwrap(); // 0 is now MRU
        cache.get_or_build(key(1, 8), true, build(3)).unwrap(); // evicts 4
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 1);
        // 4 must rebuild; 0 must still be resident
        cache.get_or_build(key(1, 0), true, || panic!("0 was evicted")).unwrap();
        let mut rebuilt = false;
        cache
            .get_or_build(key(1, 4), true, || {
                rebuilt = true;
                Ok(Substrate::build(bits(2), NativeKind::Bitpack))
            })
            .unwrap();
        assert!(rebuilt, "the LRU victim must have been 4");
    }

    #[test]
    fn oversized_entries_are_served_but_not_retained() {
        let cache = BlockCache::new(8); // smaller than any substrate
        let v = cache
            .get_or_build(key(1, 0), true, || Ok(Substrate::build(bits(1), NativeKind::Bitpack)))
            .unwrap();
        assert!(v.bytes() > 8);
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.resident_bytes(), 0);
    }

    #[test]
    fn prefetch_misses_counted_separately() {
        let cache = BlockCache::new(1 << 20);
        cache
            .get_or_build(key(1, 0), false, || Ok(Substrate::build(bits(1), NativeKind::Bitpack)))
            .unwrap();
        let s = cache.stats();
        assert_eq!((s.misses, s.prefetched), (1, 1));
        assert_eq!(s.stall_secs, 0.0, "prefetch builds are not worker stalls");
    }

    #[test]
    fn substrate_kinds_never_alias() {
        let cache = BlockCache::new(1 << 20);
        cache
            .get_or_build(key(1, 0), true, || Ok(Substrate::build(bits(1), NativeKind::Bitpack)))
            .unwrap();
        let mut built = false;
        cache
            .get_or_build(
                BlockKey { source: 1, start: 0, len: 4, kind: NativeKind::Dense },
                true,
                || {
                    built = true;
                    Ok(Substrate::build(bits(1), NativeKind::Dense))
                },
            )
            .unwrap();
        assert!(built, "a different substrate kind is a different entry");
    }

    #[test]
    fn source_ids_stable_for_live_arcs_and_purged_for_dead() {
        let cache = BlockCache::new(1 << 20);
        let ds = SynthSpec::new(64, 4).sparsity(0.5).seed(1).generate();
        let s1: Arc<dyn ColumnSource> = Arc::new(InMemorySource::new(&ds));
        let s2: Arc<dyn ColumnSource> = Arc::new(InMemorySource::new(&ds));
        let id1 = cache.source_id(&s1);
        assert_eq!(cache.source_id(&s1), id1, "same live Arc, same id");
        assert_ne!(cache.source_id(&s2), id1, "distinct sources, distinct ids");
        cache
            .get_or_build(key(id1, 0), true, || Ok(Substrate::build(bits(1), NativeKind::Bitpack)))
            .unwrap();
        assert_eq!(cache.len(), 1);
        cache.purge_source(id1);
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.resident_bytes(), 0);
    }

    #[test]
    fn substrate_grams_match_uncached_routines() {
        let a = bits(1);
        let b = bits(2);
        for kind in [NativeKind::Bitpack, NativeKind::Dense, NativeKind::Sparse] {
            let sa = Substrate::build(a.clone(), kind);
            let sb = Substrate::build(b.clone(), kind);
            assert_eq!(sa.gram().max_abs_diff(&a.gram()), 0.0, "{kind:?} diag");
            assert_eq!(
                sa.gram_cross(&sb).unwrap().max_abs_diff(&a.gram_cross(&b).unwrap()),
                0.0,
                "{kind:?} cross"
            );
        }
        let sa = Substrate::build(a, NativeKind::Bitpack);
        let sb = Substrate::build(b, NativeKind::Dense);
        assert!(sa.gram_cross(&sb).is_err(), "mixed kinds must be rejected");
    }

    #[test]
    fn cache_plan_resolution() {
        // explicit budget wins, task budget untouched
        assert_eq!(cache_plan(Some(64), true, 1000), (Some(64), 1000));
        assert_eq!(cache_plan(Some(64), false, 0), (Some(64), 0));
        // Some(0) disables
        assert_eq!(cache_plan(Some(0), true, 1000), (None, 1000));
        // auto: carve for out-of-core, off for in-memory
        let (cache, task) = cache_plan(None, true, 1000);
        assert_eq!(cache, Some(500));
        assert_eq!(task, 500);
        assert_eq!(cache_plan(None, false, 1000), (None, 1000));
    }

    #[test]
    fn concurrent_get_or_build_is_consistent() {
        let cache = Arc::new(BlockCache::new(1 << 20));
        std::thread::scope(|s| {
            for _ in 0..8 {
                let cache = Arc::clone(&cache);
                s.spawn(move || {
                    for i in 0..50 {
                        let start = (i % 4) * 4;
                        let v = cache
                            .get_or_build(key(1, start), true, || {
                                Ok(Substrate::build(bits(start as u64), NativeKind::Bitpack))
                            })
                            .unwrap();
                        let want = bits(start as u64);
                        let Substrate::Bits(got) = &*v else { panic!() };
                        assert_eq!(got.words(), want.words());
                    }
                });
            }
        });
        let s = cache.stats();
        assert_eq!(s.hits + s.misses, 8 * 50);
        assert_eq!(cache.len(), 4);
    }
}
