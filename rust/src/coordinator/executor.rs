//! Plan execution: run block tasks on a Gram provider, combine each
//! block's counts into the selected association measure
//! ([`CombineKind`], MI by default), and stream the combined blocks
//! into a [`MiSink`] — the crate's *single* execution engine. The
//! monolithic backends are one-block plans over the same code path, so
//! a blockwise run is bit-identical to a monolithic one by
//! construction, for every measure (each measure's combine is
//! swap-invariant; see [`crate::mi::measure`]).
//!
//! Parallel runs have no shared output lock: workers send finished
//! blocks over a channel and one collector thread feeds the sink, so
//! high worker counts never contend on a global `Mutex<Mat64>`.

use super::blockcache::{CacheHandle, Substrate};
use super::planner::{matrix_free_block, plan_blocks, BlockPlan, BlockTask};
use super::progress::Progress;
use super::tilecache::{TileCache, TileKey};
use crate::data::colstore::ColumnSource;
use crate::data::dataset::BinaryDataset;
use crate::linalg::dense::Mat64;
use crate::mi::combine_kernels::{combine_block_with, LogTable};
use crate::mi::measure::CombineKind;
use crate::mi::sink::{DenseSink, MiSink, SinkData};
use crate::mi::xla::XlaMi;
use crate::mi::MiMatrix;
use crate::runtime::Impl;
use crate::util::error::{Error, Result};
use crate::util::threadpool::parallel_for;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, Mutex};

/// Computes the ones-co-occurrence Gram block for a column-block pair.
pub trait GramProvider {
    fn name(&self) -> &'static str;
    /// G11 block of shape (t.a_len, t.b_len).
    fn block_gram(&self, t: &BlockTask) -> Result<Mat64>;

    /// How many tasks ahead of the workers the executor may warm via
    /// [`GramProvider::prefetch`]. 0 (the default) disables the
    /// readahead stage entirely — right for providers whose fetches
    /// are cheap or uncacheable.
    fn readahead(&self) -> usize {
        0
    }

    /// Warm whatever state `block_gram(t)` will need, without
    /// computing the Gram. Called from the executor's readahead thread
    /// while earlier Grams compute, so fetch latency overlaps compute;
    /// must be cheap to call redundantly and must swallow errors (the
    /// demand path will surface them). Default: no-op.
    fn prefetch(&self, _t: &BlockTask) {}
}

/// Which native substrate a [`NativeProvider`] uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NativeKind {
    Bitpack,
    Dense,
    Sparse,
}

/// Gram provider over the in-process substrates, fed block by block
/// from a [`ColumnSource`]. Nothing is converted up front: each task
/// fetches its two bit-packed column blocks from the source and builds
/// the substrate (bit-packed, CSR, or dense f32) for just those
/// columns, so peak memory per task is the task's own working set —
/// `task_bytes(n, b)` — no matter how large the source is. With an
/// [`InMemorySource`] the fetch is a column-range memcpy (the
/// historical whole-dataset cost profile); with a
/// [`crate::data::colstore::PackedFileSource`] it is one contiguous
/// positioned read, which is what makes the input side out-of-core.
///
/// Attach a [`CacheHandle`] ([`NativeProvider::with_cache`]) and the
/// provider serves substrates through the block cache instead of
/// rebuilding them per task — with the panel schedule this takes a
/// streaming run from `O(nb²)` block fetches down to `O(nb)` — and a
/// non-zero `readahead` lets the executor's prefetch stage pull the
/// next tasks' blocks while the current Grams compute. Cached and
/// uncached providers produce bit-identical Grams: the cache stores
/// exactly the substrate the uncached path would have built.
pub struct NativeProvider<'a> {
    kind: NativeKind,
    src: &'a dyn ColumnSource,
    cache: Option<CacheHandle>,
    readahead: usize,
}

impl<'a> NativeProvider<'a> {
    pub fn new(src: &'a dyn ColumnSource, kind: NativeKind) -> Self {
        NativeProvider { kind, src, cache: None, readahead: 0 }
    }

    /// A provider that serves substrates through `cache` and asks the
    /// executor for `readahead` tasks of prefetch.
    pub fn with_cache(
        src: &'a dyn ColumnSource,
        kind: NativeKind,
        cache: CacheHandle,
        readahead: usize,
    ) -> Self {
        NativeProvider { kind, src, cache: Some(cache), readahead }
    }

    /// The substrate for one column block — through the cache when one
    /// is attached, built fresh otherwise. `demand` is false only on
    /// the prefetch path (it routes into the cache's stall/prefetch
    /// accounting).
    fn substrate(&self, start: usize, len: usize, demand: bool) -> Result<Arc<Substrate>> {
        let build = || Ok(Substrate::build(self.src.col_block(start, len)?, self.kind));
        match &self.cache {
            Some(handle) => handle.get_or_build(start, len, self.kind, demand, build),
            None => Ok(Arc::new(build()?)),
        }
    }
}

impl GramProvider for NativeProvider<'_> {
    fn name(&self) -> &'static str {
        match self.kind {
            NativeKind::Bitpack => "native-bitpack",
            NativeKind::Dense => "native-dense",
            NativeKind::Sparse => "native-sparse",
        }
    }

    fn block_gram(&self, t: &BlockTask) -> Result<Mat64> {
        // one structural fetch path for every substrate kind: a
        // diagonal task touches exactly one block, an off-diagonal
        // task exactly two
        let a = self.substrate(t.a_start, t.a_len, true)?;
        if t.is_diagonal() {
            Ok(a.gram())
        } else {
            let b = self.substrate(t.b_start, t.b_len, true)?;
            a.gram_cross(&b)
        }
    }

    fn readahead(&self) -> usize {
        if self.cache.is_some() {
            self.readahead
        } else {
            0 // nowhere to park a prefetched block without a cache
        }
    }

    fn prefetch(&self, t: &BlockTask) {
        // errors are swallowed by design: the demand path will hit the
        // same failure and surface it with full context
        let _ = self.substrate(t.a_start, t.a_len, false);
        if !t.is_diagonal() {
            let _ = self.substrate(t.b_start, t.b_len, false);
        }
    }
}

/// Gram provider over the AOT XLA artifacts (`xgram` buckets). Not
/// `Sync` (PJRT executable cache is thread-affine): use
/// [`run_plan_serial`] / [`run_plan_dense_serial`].
pub struct XlaProvider {
    xla: XlaMi,
    impl_: Impl,
    ds: BinaryDataset,
}

impl XlaProvider {
    pub fn new(xla: XlaMi, impl_: Impl, ds: &BinaryDataset) -> Self {
        XlaProvider { xla, impl_, ds: ds.clone() }
    }

    fn block_f32(&self, start: usize, len: usize) -> Result<Vec<f32>> {
        let blk = self.ds.col_block(start, len)?;
        Ok(blk.bytes().iter().map(|&b| b as f32).collect())
    }
}

impl GramProvider for XlaProvider {
    fn name(&self) -> &'static str {
        "xla-xgram"
    }

    fn block_gram(&self, t: &BlockTask) -> Result<Mat64> {
        let n = self.ds.n_rows();
        // Row-chunk through the xgram bucket rows so arbitrary n works.
        let meta = self.xla.runtime().bucket(
            crate::runtime::ArtifactKind::Xgram,
            self.impl_,
            n.min(usize::MAX),
            t.a_len.max(t.b_len),
        );
        let chunk_rows = match meta {
            Ok(m) => m.rows,
            Err(_) => self
                .xla
                .runtime()
                .registry()
                .max_rows_for_cols(
                    crate::runtime::ArtifactKind::Xgram,
                    self.impl_,
                    t.a_len.max(t.b_len),
                )
                .ok_or_else(|| {
                    Error::NoArtifact(format!(
                        "no xgram bucket with >= {} cols",
                        t.a_len.max(t.b_len)
                    ))
                })?,
        };
        let da = self.block_f32(t.a_start, t.a_len)?;
        let db = self.block_f32(t.b_start, t.b_len)?;
        let mut g_acc = vec![0.0f64; t.a_len * t.b_len];
        let mut start = 0usize;
        while start < n {
            let len = chunk_rows.min(n - start);
            let (g, _, _) = self.xla.runtime().run_xgram(
                self.impl_,
                &da[start * t.a_len..(start + len) * t.a_len],
                &db[start * t.b_len..(start + len) * t.b_len],
                len,
                t.a_len,
                t.b_len,
            )?;
            for (acc, v) in g_acc.iter_mut().zip(&g) {
                *acc += v;
            }
            start += len;
        }
        Mat64::from_vec(t.a_len, t.b_len, g_acc)
    }
}

/// Execute a plan in parallel, streaming combined blocks of `measure`
/// values into `sink` — **the** canonical engine entry point; every
/// driver (CLI `compute`, the job service, the HTTP handlers, benches)
/// funnels here. Workers compute Gram + combine per task and send the
/// result over a channel; the calling thread is the single consumer
/// feeding the sink (no global output lock, and sinks need no `Sync`).
///
/// Respects cancellation through `progress`; the first provider or
/// sink error aborts the remaining tasks and is returned.
pub fn run_plan<P: GramProvider + Sync>(
    src: &dyn ColumnSource,
    plan: &BlockPlan,
    provider: &P,
    workers: usize,
    progress: &Progress,
    sink: &mut dyn MiSink,
    measure: CombineKind,
) -> Result<()> {
    run_plan_tiled(src, plan, provider, workers, progress, sink, measure, None)
}

/// [`run_plan`] with an optional content-addressed Gram-tile cache
/// ([`crate::coordinator::tilecache`]). Per task the worker derives the
/// tile key from the two input blocks' content fingerprints
/// ([`ColumnSource::block_fingerprint`]) and consults the cache first:
/// a verified hit skips `block_gram` entirely and only the measure
/// combine runs (the Gram is backend- and measure-independent, so one
/// cached tile serves every configuration, bit-exactly). On a miss the
/// freshly computed Gram rides the result channel to the collector,
/// which inserts it only *after* the sink confirmed the block — a tile
/// the sink rejected is never cached.
#[allow(clippy::too_many_arguments)]
pub fn run_plan_tiled<P: GramProvider + Sync>(
    src: &dyn ColumnSource,
    plan: &BlockPlan,
    provider: &P,
    workers: usize,
    progress: &Progress,
    sink: &mut dyn MiSink,
    measure: CombineKind,
    tiles: Option<&TileCache>,
) -> Result<()> {
    let (n, colsums) = plan_inputs(src, plan)?;
    // One log table for the whole run, shared read-only by every worker
    // lane: the combine kernels replace their per-cell log2 calls with
    // lookups into it (see crate::mi::combine_kernels).
    let lt = LogTable::new(src.n_rows());
    let n_tasks = plan.tasks.len();
    let abort = AtomicBool::new(false);
    // Bounded channel: workers block when the collector falls behind,
    // so at most ~2 blocks per worker are ever in flight — the engine's
    // peak memory stays O(workers * block²) by construction. The sender
    // sits behind a Mutex so the shared `Fn` closure can send; the lock
    // covers one send per *task*, not per cell. Alongside each combined
    // block rides the Gram to insert on a tile-cache miss (`None` on a
    // hit or when no cache is attached).
    type TaskResult = Result<(Mat64, Option<(TileKey, Mat64)>)>;
    let (tx, rx) = sync_channel::<(usize, TaskResult)>(workers.max(1) * 2);
    let tx = Mutex::new(tx);
    let first_err = std::thread::scope(|scope| {
        let tasks = &plan.tasks;
        let abort = &abort;
        // Readahead stage: one thread walking the schedule ahead of
        // the workers, warming each upcoming task's blocks (the
        // provider parks them in its cache) so fetch latency overlaps
        // Gram compute instead of stalling a worker. The window is
        // bounded by worker count + the provider's readahead, so the
        // cache working set stays small; progress.done() only ever
        // grows, so the wait loop always terminates, and abort /
        // cancellation stop the stage early.
        let readahead = provider.readahead();
        if readahead > 0 {
            let window = workers.max(1) + readahead;
            scope.spawn(move || {
                for (idx, t) in tasks.iter().enumerate() {
                    while idx >= progress.done() + window {
                        if abort.load(Ordering::Relaxed) || progress.is_cancelled() {
                            return;
                        }
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                    if abort.load(Ordering::Relaxed) || progress.is_cancelled() {
                        return;
                    }
                    provider.prefetch(t);
                }
            });
        }
        let consumer = scope.spawn(move || {
            let mut first_err: Option<Error> = None;
            for (idx, res) in rx.iter() {
                match res {
                    Ok((block, fresh)) if first_err.is_none() => {
                        match sink.consume_block(&tasks[idx], &block) {
                            Ok(()) => {
                                // insert only after the sink confirmed
                                // the block — a rejected tile is never
                                // cached
                                if let (Some(cache), Some((key, gram))) = (tiles, fresh) {
                                    cache.insert(key, &gram);
                                }
                                progress.task_done();
                            }
                            Err(e) => {
                                first_err = Some(e);
                                abort.store(true, Ordering::Relaxed);
                            }
                        }
                    }
                    Ok(_) => {}
                    Err(e) => {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                        abort.store(true, Ordering::Relaxed);
                    }
                }
            }
            first_err
        });
        parallel_for(n_tasks, workers, |idx| {
            if progress.is_cancelled() || abort.load(Ordering::Relaxed) {
                return;
            }
            let res = compute_block_tiled(
                src,
                provider,
                &plan.tasks[idx],
                &colsums,
                n,
                measure,
                &lt,
                tiles,
            );
            // a send can only fail if the consumer died; nothing to do
            let _ = tx.lock().unwrap().send((idx, res));
        });
        drop(tx); // close the channel so the consumer drains and exits
        consumer.join().expect("sink consumer thread panicked")
    });
    if let Some(e) = first_err {
        return Err(e);
    }
    if progress.is_cancelled() {
        return Err(Error::Coordinator("job cancelled".into()));
    }
    Ok(())
}

/// Serial variant of [`run_plan`] for providers that are not `Sync`
/// (e.g. [`XlaProvider`]).
pub fn run_plan_serial<P: GramProvider>(
    src: &dyn ColumnSource,
    plan: &BlockPlan,
    provider: &P,
    progress: &Progress,
    sink: &mut dyn MiSink,
    measure: CombineKind,
) -> Result<()> {
    let (n, colsums) = plan_inputs(src, plan)?;
    let lt = LogTable::new(src.n_rows());
    for t in &plan.tasks {
        if progress.is_cancelled() {
            return Err(Error::Coordinator("job cancelled".into()));
        }
        let block = compute_block(provider, t, &colsums, n, measure, &lt)?;
        sink.consume_block(t, &block)?;
        progress.task_done();
    }
    Ok(())
}

/// Execute a plan into a full dense matrix of `measure` values (a
/// [`DenseSink`] run over [`run_plan`]).
pub fn run_plan_dense<P: GramProvider + Sync>(
    src: &dyn ColumnSource,
    plan: &BlockPlan,
    provider: &P,
    workers: usize,
    progress: &Progress,
    measure: CombineKind,
) -> Result<MiMatrix> {
    let mut sink = DenseSink::new(plan.m);
    run_plan(src, plan, provider, workers, progress, &mut sink, measure)?;
    dense_result(&mut sink)
}

/// Serial dense-matrix execution (for providers that are not `Sync`).
pub fn run_plan_dense_serial<P: GramProvider>(
    src: &dyn ColumnSource,
    plan: &BlockPlan,
    provider: &P,
    progress: &Progress,
    measure: CombineKind,
) -> Result<MiMatrix> {
    let mut sink = DenseSink::new(plan.m);
    run_plan_serial(src, plan, provider, progress, &mut sink, measure)?;
    dense_result(&mut sink)
}

/// Whole-dataset computation over any [`ColumnSource`] through the
/// blockwise engine — the source-generic successor to the
/// `compute_native*` wrapper pile. A one-block plan for serial
/// in-memory runs, enough blocks to keep `workers` busy otherwise; an
/// out-of-core source gets the bounded matrix-free block width instead
/// (a monolithic plan would materialize the whole file in one fetch).
/// This is what `mi::backend::compute_measure_with` dispatches the
/// `bulk-opt` / `bulk-sparse` / `bulk-bitpack` backends to — one
/// Gram -> combine core for every substrate.
pub fn compute_source(
    src: &dyn ColumnSource,
    kind: NativeKind,
    workers: usize,
    measure: CombineKind,
) -> Result<MiMatrix> {
    let m = src.n_cols();
    let block = if src.out_of_core() {
        matrix_free_block(src.n_rows(), m, 0)
    } else if workers <= 1 {
        0 // monolithic single task
    } else {
        // over-decompose 4x per worker so work-stealing balances the
        // triangle's uneven task sizes
        m.div_ceil(workers * 4).max(1)
    };
    let plan = plan_blocks(m, block)?;
    let provider = NativeProvider::new(src, kind);
    let progress = Progress::new(plan.tasks.len());
    run_plan_dense(src, &plan, &provider, workers, &progress, measure)
}

fn dense_result(sink: &mut DenseSink) -> Result<MiMatrix> {
    match sink.finish()?.data {
        SinkData::Dense(mi) => Ok(mi),
        other => Err(Error::Coordinator(format!(
            "dense sink returned {} output",
            other.kind_name()
        ))),
    }
}

/// Shared validation + sufficient statistics for a plan execution. The
/// column sums are fetched through the source in plan-block-sized
/// chunks, so even this pass never holds more than one block of
/// columns. Public for the cluster worker (`crate::cluster`), which
/// resolves the same inputs once per job before running tasks.
pub fn plan_inputs(src: &dyn ColumnSource, plan: &BlockPlan) -> Result<(f64, Vec<f64>)> {
    if src.n_cols() != plan.m {
        return Err(Error::Shape(format!(
            "plan is over {} columns but the source has {}",
            plan.m,
            src.n_cols()
        )));
    }
    let n = src.n_rows() as f64;
    let colsums = src
        .all_col_counts(plan.block)?
        .iter()
        .map(|&v| v as f64)
        .collect();
    Ok((n, colsums))
}

/// Gram + combine for one task. Public for the cluster worker
/// (`crate::cluster`), which runs exactly this per dispatched task —
/// the distributed path shares the single-process compute core, which
/// is what makes sharded runs bit-identical by construction. `lt` is
/// the run's shared [`LogTable`]; callers build it once per run/job
/// (table and direct modes produce identical bits, so a caller may
/// also pass [`LogTable::direct`]).
pub fn compute_block<P: GramProvider + ?Sized>(
    provider: &P,
    t: &BlockTask,
    colsums: &[f64],
    n: f64,
    measure: CombineKind,
    lt: &LogTable,
) -> Result<Mat64> {
    let g = provider.block_gram(t)?;
    if (g.rows(), g.cols()) != (t.a_len, t.b_len) {
        return Err(Error::Shape(format!(
            "provider {} returned {}x{} block for task {t:?}",
            provider.name(),
            g.rows(),
            g.cols()
        )));
    }
    let ca = &colsums[t.a_start..t.a_start + t.a_len];
    let cb = &colsums[t.b_start..t.b_start + t.b_len];
    Ok(combine_block_with(measure, lt, &g, ca, cb, n))
}

/// [`compute_block`] with a tile-cache fast path: serve the Gram from
/// the cache when a verified tile exists, compute it otherwise and
/// hand it back for post-confirmation insertion. Fingerprinting uses
/// the source directly (memoized by file-backed sources), so the key
/// is identical whichever provider computes the Gram.
#[allow(clippy::too_many_arguments)]
fn compute_block_tiled<P: GramProvider + ?Sized>(
    src: &dyn ColumnSource,
    provider: &P,
    t: &BlockTask,
    colsums: &[f64],
    n: f64,
    measure: CombineKind,
    lt: &LogTable,
    tiles: Option<&TileCache>,
) -> Result<(Mat64, Option<(TileKey, Mat64)>)> {
    let Some(cache) = tiles else {
        return Ok((compute_block(provider, t, colsums, n, measure, lt)?, None));
    };
    let key = TileKey {
        fp_a: src.block_fingerprint(t.a_start, t.a_len)?,
        fp_b: src.block_fingerprint(t.b_start, t.b_len)?,
    };
    let ca = &colsums[t.a_start..t.a_start + t.a_len];
    let cb = &colsums[t.b_start..t.b_start + t.b_len];
    if let Some(g) = cache.get(key, t.a_len, t.b_len) {
        return Ok((combine_block_with(measure, lt, &g, ca, cb, n), None));
    }
    let g = provider.block_gram(t)?;
    if (g.rows(), g.cols()) != (t.a_len, t.b_len) {
        return Err(Error::Shape(format!(
            "provider {} returned {}x{} block for task {t:?}",
            provider.name(),
            g.rows(),
            g.cols()
        )));
    }
    let block = combine_block_with(measure, lt, &g, ca, cb, n);
    Ok((block, Some((key, g))))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::planner::plan_blocks;
    use crate::data::synth::SynthSpec;
    use crate::mi::backend::{compute_mi, Backend};
    use crate::mi::sink::{SinkOutput, TopKSink};

    fn check_blockwise_matches(kind: NativeKind, workers: usize) {
        let ds = SynthSpec::new(200, 23).sparsity(0.8).seed(kind as u64).generate();
        let want = compute_mi(&ds, Backend::Pairwise).unwrap();
        let provider = NativeProvider::new(&ds, kind);
        for block in [1usize, 5, 8, 23, 100] {
            let plan = plan_blocks(23, block).unwrap();
            let progress = Progress::new(plan.tasks.len());
            let got =
                run_plan_dense(&ds, &plan, &provider, workers, &progress, CombineKind::Mi)
                    .unwrap();
            assert!(
                got.max_abs_diff(&want) < 1e-12,
                "{kind:?} block={block}: diff {}",
                got.max_abs_diff(&want)
            );
            assert_eq!(progress.done(), plan.tasks.len());
        }
    }

    #[test]
    fn bitpack_blockwise_matches_monolithic() {
        check_blockwise_matches(NativeKind::Bitpack, 1);
        check_blockwise_matches(NativeKind::Bitpack, 4);
    }

    #[test]
    fn dense_blockwise_matches_monolithic() {
        check_blockwise_matches(NativeKind::Dense, 2);
    }

    #[test]
    fn sparse_blockwise_matches_monolithic() {
        check_blockwise_matches(NativeKind::Sparse, 3);
    }

    #[test]
    fn serial_equals_parallel() {
        let ds = SynthSpec::new(150, 17).sparsity(0.6).seed(9).generate();
        let provider = NativeProvider::new(&ds, NativeKind::Bitpack);
        let plan = plan_blocks(17, 4).unwrap();
        let par = run_plan_dense(
            &ds,
            &plan,
            &provider,
            4,
            &Progress::new(plan.tasks.len()),
            CombineKind::Mi,
        )
        .unwrap();
        let ser = run_plan_dense_serial(
            &ds,
            &plan,
            &provider,
            &Progress::new(plan.tasks.len()),
            CombineKind::Mi,
        )
        .unwrap();
        assert_eq!(par.max_abs_diff(&ser), 0.0);
    }

    #[test]
    fn compute_source_matches_across_workers() {
        let ds = SynthSpec::new(300, 29).sparsity(0.7).seed(11).generate();
        let serial = compute_source(&ds, NativeKind::Bitpack, 1, CombineKind::Mi).unwrap();
        for workers in [2, 4, 7] {
            for kind in [NativeKind::Bitpack, NativeKind::Dense, NativeKind::Sparse] {
                let got = compute_source(&ds, kind, workers, CombineKind::Mi).unwrap();
                assert_eq!(got.max_abs_diff(&serial), 0.0, "{kind:?} workers={workers}");
            }
        }
    }

    #[test]
    fn blockwise_measure_matches_monolithic() {
        use crate::mi::measure::{measure_pairwise, CombineKind};
        let ds = SynthSpec::new(180, 19).sparsity(0.7).seed(13).generate();
        for measure in CombineKind::ALL {
            let want = measure_pairwise(&ds, measure);
            for kind in [NativeKind::Bitpack, NativeKind::Dense, NativeKind::Sparse] {
                let provider = NativeProvider::new(&ds, kind);
                let plan = plan_blocks(19, 6).unwrap();
                let progress = Progress::new(plan.tasks.len());
                let got =
                    run_plan_dense(&ds, &plan, &provider, 2, &progress, measure).unwrap();
                assert!(
                    got.max_abs_diff(&want) < 1e-12,
                    "{measure} on {kind:?}: diff {}",
                    got.max_abs_diff(&want)
                );
            }
        }
    }

    #[test]
    fn cancellation_aborts() {
        let ds = SynthSpec::new(50, 12).sparsity(0.5).seed(1).generate();
        let provider = NativeProvider::new(&ds, NativeKind::Bitpack);
        let plan = plan_blocks(12, 3).unwrap();
        let progress = Progress::new(plan.tasks.len());
        progress.cancel();
        let err =
            run_plan_dense(&ds, &plan, &provider, 2, &progress, CombineKind::Mi).unwrap_err();
        assert!(matches!(err, Error::Coordinator(_)));
    }

    #[test]
    fn plan_dataset_mismatch_rejected() {
        let ds = SynthSpec::new(50, 12).seed(2).generate();
        let provider = NativeProvider::new(&ds, NativeKind::Bitpack);
        let plan = plan_blocks(13, 4).unwrap();
        assert!(
            run_plan_dense(&ds, &plan, &provider, 1, &Progress::new(1), CombineKind::Mi)
                .is_err()
        );
    }

    /// A sink that errors on its nth block: the executor must surface
    /// the error and stop issuing work.
    struct FailingSink {
        after: usize,
        seen: usize,
    }

    impl MiSink for FailingSink {
        fn consume_block(&mut self, _t: &BlockTask, _block: &Mat64) -> Result<()> {
            self.seen += 1;
            if self.seen > self.after {
                return Err(Error::Coordinator("sink full".into()));
            }
            Ok(())
        }

        fn finish(&mut self) -> Result<SinkOutput> {
            Ok(SinkData::TopK(Vec::new()).into())
        }
    }

    #[test]
    fn sink_errors_abort_the_run() {
        let ds = SynthSpec::new(60, 20).sparsity(0.5).seed(3).generate();
        let provider = NativeProvider::new(&ds, NativeKind::Bitpack);
        let plan = plan_blocks(20, 4).unwrap();
        let mut sink = FailingSink { after: 2, seen: 0 };
        let progress = Progress::new(plan.tasks.len());
        let err =
            run_plan(&ds, &plan, &provider, 2, &progress, &mut sink, CombineKind::Mi)
                .unwrap_err();
        assert!(matches!(err, Error::Coordinator(_)), "got {err}");
    }

    #[test]
    fn tiled_runs_hit_across_backends_and_stay_bit_identical() {
        let dir = std::env::temp_dir()
            .join(format!("bulkmi-executor-tiles-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = TileCache::open(&dir, 1 << 20);
        let ds = SynthSpec::new(220, 13).sparsity(0.7).seed(21).generate();
        let plan = plan_blocks(13, 4).unwrap();
        let n_tasks = plan.tasks.len() as u64;
        let cold_provider = NativeProvider::new(&ds, NativeKind::Bitpack);
        let mut cold = DenseSink::new(13);
        run_plan_tiled(
            &ds,
            &plan,
            &cold_provider,
            2,
            &Progress::new(plan.tasks.len()),
            &mut cold,
            CombineKind::Mi,
            Some(&cache),
        )
        .unwrap();
        let want = dense_result(&mut cold).unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (0, n_tasks));
        // warm runs hit every tile from *any* backend — the Gram is
        // NativeKind-independent — and stay bit-identical
        for kind in [NativeKind::Bitpack, NativeKind::Dense, NativeKind::Sparse] {
            let before = cache.stats();
            let provider = NativeProvider::new(&ds, kind);
            let mut sink = DenseSink::new(13);
            run_plan_tiled(
                &ds,
                &plan,
                &provider,
                2,
                &Progress::new(plan.tasks.len()),
                &mut sink,
                CombineKind::Mi,
                Some(&cache),
            )
            .unwrap();
            let got = dense_result(&mut sink).unwrap();
            assert_eq!(got.max_abs_diff(&want), 0.0, "{kind:?}");
            let d = cache.stats().since(&before);
            assert_eq!((d.hits, d.misses), (n_tasks, 0), "{kind:?}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn topk_sink_through_parallel_engine() {
        let ds = SynthSpec::new(500, 18).sparsity(0.6).seed(5).plant(2, 9, 0.02).generate();
        let full = compute_mi(&ds, Backend::BulkBitpack).unwrap();
        let want = crate::mi::topk::top_k_pairs(&full, 4);
        let provider = NativeProvider::new(&ds, NativeKind::Bitpack);
        let plan = plan_blocks(18, 5).unwrap();
        let mut sink = TopKSink::global(4);
        let progress = Progress::new(plan.tasks.len());
        run_plan(&ds, &plan, &provider, 3, &progress, &mut sink, CombineKind::Mi).unwrap();
        let SinkData::TopK(got) = sink.finish().unwrap().data else { panic!() };
        assert_eq!(got.len(), 4);
        assert_eq!((got[0].i, got[0].j), (2, 9));
        for (g, w) in got.iter().zip(&want) {
            assert_eq!((g.i, g.j), (w.i, w.j));
            assert_eq!(g.mi, w.mi);
        }
    }
}
