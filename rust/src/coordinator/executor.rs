//! Plan execution: run block tasks on a Gram provider, combine each
//! block's counts into MI, and assemble the full matrix.
//!
//! Providers abstract the Gram substrate; the combine is always the
//! shared exact implementation (`mi::bulk_opt::combine`), so a blockwise
//! run is bit-identical to the monolithic one.

use super::planner::{BlockPlan, BlockTask};
use super::progress::Progress;
use crate::data::dataset::BinaryDataset;
use crate::linalg::bitmat::BitMatrix;
use crate::linalg::csr::CsrMatrix;
use crate::linalg::dense::Mat64;
use crate::mi::bulk_opt::combine;
use crate::mi::xla::XlaMi;
use crate::mi::MiMatrix;
use crate::runtime::Impl;
use crate::util::error::{Error, Result};
use crate::util::threadpool::parallel_for;
use std::sync::Mutex;

/// Computes the ones-co-occurrence Gram block for a column-block pair.
pub trait GramProvider {
    fn name(&self) -> &'static str;
    /// G11 block of shape (t.a_len, t.b_len).
    fn block_gram(&self, t: &BlockTask) -> Result<Mat64>;
}

/// Which native substrate a [`NativeProvider`] uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NativeKind {
    Bitpack,
    Dense,
    Sparse,
}

/// Gram provider over the in-process substrates. Cheap block extraction:
/// the bit-packed/CSR forms are built once up front.
pub struct NativeProvider {
    kind: NativeKind,
    ds: BinaryDataset,
    bit: Option<BitMatrix>,
    csr: Option<CsrMatrix>,
}

impl NativeProvider {
    pub fn new(ds: &BinaryDataset, kind: NativeKind) -> Self {
        let bit = matches!(kind, NativeKind::Bitpack).then(|| ds.to_bitmatrix());
        let csr = matches!(kind, NativeKind::Sparse).then(|| ds.to_csr());
        NativeProvider { kind, ds: ds.clone(), bit, csr }
    }
}

impl GramProvider for NativeProvider {
    fn name(&self) -> &'static str {
        match self.kind {
            NativeKind::Bitpack => "native-bitpack",
            NativeKind::Dense => "native-dense",
            NativeKind::Sparse => "native-sparse",
        }
    }

    fn block_gram(&self, t: &BlockTask) -> Result<Mat64> {
        match self.kind {
            NativeKind::Bitpack => {
                let bit = self.bit.as_ref().expect("built in new");
                let a = bit.col_block(t.a_start, t.a_len)?;
                if t.is_diagonal() {
                    Ok(a.gram())
                } else {
                    let b = bit.col_block(t.b_start, t.b_len)?;
                    a.gram_cross(&b)
                }
            }
            NativeKind::Dense => {
                let a = self.ds.col_block(t.a_start, t.a_len)?.to_mat32();
                if t.is_diagonal() {
                    Ok(crate::linalg::blas::gram(&a))
                } else {
                    let b = self.ds.col_block(t.b_start, t.b_len)?.to_mat32();
                    crate::linalg::blas::gemm_at_b(&a, &b)
                }
            }
            NativeKind::Sparse => {
                let csr = self.csr.as_ref().expect("built in new");
                let a = csr.col_block(t.a_start, t.a_len)?;
                if t.is_diagonal() {
                    Ok(a.gram())
                } else {
                    let b = csr.col_block(t.b_start, t.b_len)?;
                    a.gram_cross(&b)
                }
            }
        }
    }
}

/// Gram provider over the AOT XLA artifacts (`xgram` buckets). Not
/// `Sync` (PJRT executable cache is thread-affine): use
/// [`execute_plan_serial`].
pub struct XlaProvider {
    xla: XlaMi,
    impl_: Impl,
    ds: BinaryDataset,
}

impl XlaProvider {
    pub fn new(xla: XlaMi, impl_: Impl, ds: &BinaryDataset) -> Self {
        XlaProvider { xla, impl_, ds: ds.clone() }
    }

    fn block_f32(&self, start: usize, len: usize) -> Result<Vec<f32>> {
        let blk = self.ds.col_block(start, len)?;
        Ok(blk.bytes().iter().map(|&b| b as f32).collect())
    }
}

impl GramProvider for XlaProvider {
    fn name(&self) -> &'static str {
        "xla-xgram"
    }

    fn block_gram(&self, t: &BlockTask) -> Result<Mat64> {
        let n = self.ds.n_rows();
        // Row-chunk through the xgram bucket rows so arbitrary n works.
        let meta = self.xla.runtime().bucket(
            crate::runtime::ArtifactKind::Xgram,
            self.impl_,
            n.min(usize::MAX),
            t.a_len.max(t.b_len),
        );
        let chunk_rows = match meta {
            Ok(m) => m.rows,
            Err(_) => self
                .xla
                .runtime()
                .registry()
                .max_rows_for_cols(
                    crate::runtime::ArtifactKind::Xgram,
                    self.impl_,
                    t.a_len.max(t.b_len),
                )
                .ok_or_else(|| {
                    Error::NoArtifact(format!(
                        "no xgram bucket with >= {} cols",
                        t.a_len.max(t.b_len)
                    ))
                })?,
        };
        let da = self.block_f32(t.a_start, t.a_len)?;
        let db = self.block_f32(t.b_start, t.b_len)?;
        let mut g_acc = vec![0.0f64; t.a_len * t.b_len];
        let mut start = 0usize;
        while start < n {
            let len = chunk_rows.min(n - start);
            let (g, _, _) = self.xla.runtime().run_xgram(
                self.impl_,
                &da[start * t.a_len..(start + len) * t.a_len],
                &db[start * t.b_len..(start + len) * t.b_len],
                len,
                t.a_len,
                t.b_len,
            )?;
            for (acc, v) in g_acc.iter_mut().zip(&g) {
                *acc += v;
            }
            start += len;
        }
        Mat64::from_vec(t.a_len, t.b_len, g_acc)
    }
}

/// Execute a plan in parallel over `workers` threads (provider must be
/// shareable). Returns the assembled MI matrix; respects cancellation
/// through `progress`.
pub fn execute_plan<P: GramProvider + Sync>(
    ds: &BinaryDataset,
    plan: &BlockPlan,
    provider: &P,
    workers: usize,
    progress: &Progress,
) -> Result<MiMatrix> {
    run_tasks(ds, plan, provider, workers, progress)
}

/// Execute a plan serially (for providers that are not `Sync`, e.g.
/// [`XlaProvider`]).
pub fn execute_plan_serial<P: GramProvider>(
    ds: &BinaryDataset,
    plan: &BlockPlan,
    provider: &P,
    progress: &Progress,
) -> Result<MiMatrix> {
    let m = plan.m;
    let n = ds.n_rows() as f64;
    let colsums: Vec<f64> = ds.col_counts().iter().map(|&v| v as f64).collect();
    let mut out = Mat64::zeros(m, m);
    for t in &plan.tasks {
        if progress.is_cancelled() {
            return Err(Error::Coordinator("job cancelled".into()));
        }
        let block = compute_block(provider, t, &colsums, n)?;
        write_block(&mut out, t, &block, m);
        progress.task_done();
    }
    Ok(MiMatrix::from_mat(out))
}

fn run_tasks<P: GramProvider + Sync>(
    ds: &BinaryDataset,
    plan: &BlockPlan,
    provider: &P,
    workers: usize,
    progress: &Progress,
) -> Result<MiMatrix> {
    let m = plan.m;
    if ds.n_cols() != m {
        return Err(Error::Shape(format!(
            "plan is over {m} columns but dataset has {}",
            ds.n_cols()
        )));
    }
    let n = ds.n_rows() as f64;
    let colsums: Vec<f64> = ds.col_counts().iter().map(|&v| v as f64).collect();
    let out = Mutex::new(Mat64::zeros(m, m));
    let first_err: Mutex<Option<Error>> = Mutex::new(None);
    parallel_for(plan.tasks.len(), workers, |idx| {
        if progress.is_cancelled() || first_err.lock().unwrap().is_some() {
            return;
        }
        let t = &plan.tasks[idx];
        match compute_block(provider, t, &colsums, n) {
            Ok(block) => {
                let mut guard = out.lock().unwrap();
                write_block(&mut guard, t, &block, m);
                progress.task_done();
            }
            Err(e) => {
                let mut guard = first_err.lock().unwrap();
                if guard.is_none() {
                    *guard = Some(e);
                }
            }
        }
    });
    if let Some(e) = first_err.into_inner().unwrap() {
        return Err(e);
    }
    if progress.is_cancelled() {
        return Err(Error::Coordinator("job cancelled".into()));
    }
    Ok(MiMatrix::from_mat(out.into_inner().unwrap()))
}

/// Gram + combine for one task.
fn compute_block<P: GramProvider + ?Sized>(
    provider: &P,
    t: &BlockTask,
    colsums: &[f64],
    n: f64,
) -> Result<Mat64> {
    let g = provider.block_gram(t)?;
    if (g.rows(), g.cols()) != (t.a_len, t.b_len) {
        return Err(Error::Shape(format!(
            "provider {} returned {}x{} block for task {t:?}",
            provider.name(),
            g.rows(),
            g.cols()
        )));
    }
    let ca = &colsums[t.a_start..t.a_start + t.a_len];
    let cb = &colsums[t.b_start..t.b_start + t.b_len];
    Ok(combine(&g, ca, cb, n))
}

/// Write a combined block (and its mirror for off-diagonal tasks).
fn write_block(out: &mut Mat64, t: &BlockTask, block: &Mat64, m: usize) {
    let _ = m;
    for i in 0..t.a_len {
        for j in 0..t.b_len {
            let v = block.get(i, j);
            out.set(t.a_start + i, t.b_start + j, v);
            if !t.is_diagonal() {
                out.set(t.b_start + j, t.a_start + i, v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::planner::plan_blocks;
    use crate::data::synth::SynthSpec;
    use crate::mi::backend::{compute_mi, Backend};

    fn check_blockwise_matches(kind: NativeKind, workers: usize) {
        let ds = SynthSpec::new(200, 23).sparsity(0.8).seed(kind as u64).generate();
        let want = compute_mi(&ds, Backend::Pairwise).unwrap();
        let provider = NativeProvider::new(&ds, kind);
        for block in [1usize, 5, 8, 23, 100] {
            let plan = plan_blocks(23, block).unwrap();
            let progress = Progress::new(plan.tasks.len());
            let got = execute_plan(&ds, &plan, &provider, workers, &progress).unwrap();
            assert!(
                got.max_abs_diff(&want) < 1e-12,
                "{kind:?} block={block}: diff {}",
                got.max_abs_diff(&want)
            );
            assert_eq!(progress.done(), plan.tasks.len());
        }
    }

    #[test]
    fn bitpack_blockwise_matches_monolithic() {
        check_blockwise_matches(NativeKind::Bitpack, 1);
        check_blockwise_matches(NativeKind::Bitpack, 4);
    }

    #[test]
    fn dense_blockwise_matches_monolithic() {
        check_blockwise_matches(NativeKind::Dense, 2);
    }

    #[test]
    fn sparse_blockwise_matches_monolithic() {
        check_blockwise_matches(NativeKind::Sparse, 3);
    }

    #[test]
    fn serial_equals_parallel() {
        let ds = SynthSpec::new(150, 17).sparsity(0.6).seed(9).generate();
        let provider = NativeProvider::new(&ds, NativeKind::Bitpack);
        let plan = plan_blocks(17, 4).unwrap();
        let par =
            execute_plan(&ds, &plan, &provider, 4, &Progress::new(plan.tasks.len())).unwrap();
        let ser =
            execute_plan_serial(&ds, &plan, &provider, &Progress::new(plan.tasks.len()))
                .unwrap();
        assert_eq!(par.max_abs_diff(&ser), 0.0);
    }

    #[test]
    fn cancellation_aborts() {
        let ds = SynthSpec::new(50, 12).sparsity(0.5).seed(1).generate();
        let provider = NativeProvider::new(&ds, NativeKind::Bitpack);
        let plan = plan_blocks(12, 3).unwrap();
        let progress = Progress::new(plan.tasks.len());
        progress.cancel();
        let err = execute_plan(&ds, &plan, &provider, 2, &progress).unwrap_err();
        assert!(matches!(err, Error::Coordinator(_)));
    }

    #[test]
    fn plan_dataset_mismatch_rejected() {
        let ds = SynthSpec::new(50, 12).seed(2).generate();
        let provider = NativeProvider::new(&ds, NativeKind::Bitpack);
        let plan = plan_blocks(13, 4).unwrap();
        assert!(execute_plan(&ds, &plan, &provider, 1, &Progress::new(1)).is_err());
    }
}
