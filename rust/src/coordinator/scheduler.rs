//! Task ordering policies for a block plan. With work-stealing workers
//! the schedule mostly affects tail latency: issuing the most expensive
//! tasks first avoids a single large task straggling at the end. For
//! out-of-core runs the schedule also controls block *reuse*: the
//! [`Schedule::Panel`] order keeps consecutive tasks sharing a block so
//! the substrate cache (`super::blockcache`) turns `O(nb²)` fetches
//! into `O(nb)`.

use super::planner::BlockTask;

/// Ordering policy for block tasks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// Plan order (row-major over block pairs).
    Sequential,
    /// Most output cells first (default: best tail behaviour).
    LargestFirst,
    /// Diagonal blocks first (warms per-column state, useful for
    /// providers that cache per-block packing).
    DiagonalFirst,
    /// Cache-aware panel order: fix block `a`, sweep `b` — and sweep
    /// in *serpentine* direction (alternate panels reversed), so the
    /// block at a panel's turn is reused immediately by the next
    /// panel's first task. With a substrate cache that holds one
    /// panel's pinned block plus the sweeping block, every task after
    /// the first in a panel needs exactly one new fetch; this is the
    /// order that realizes the cache's `O(nb)`-fetch floor.
    Panel,
}

impl Schedule {
    /// Stable lowercase name, for `SinkMeta` / logs.
    pub fn name(self) -> &'static str {
        match self {
            Schedule::Sequential => "sequential",
            Schedule::LargestFirst => "largest-first",
            Schedule::DiagonalFirst => "diagonal-first",
            Schedule::Panel => "panel",
        }
    }

    /// Inverse of [`Schedule::name`]: parse a schedule from its stable
    /// name (the wire schema and the config layer share this).
    pub fn parse(s: &str) -> Option<Schedule> {
        match s {
            "sequential" => Some(Schedule::Sequential),
            "largest-first" => Some(Schedule::LargestFirst),
            "diagonal-first" => Some(Schedule::DiagonalFirst),
            "panel" => Some(Schedule::Panel),
            _ => None,
        }
    }
}

/// Order `tasks` in place according to `policy` (stable).
pub fn order_tasks(tasks: &mut [BlockTask], policy: Schedule) {
    match policy {
        Schedule::Sequential => {}
        Schedule::LargestFirst => {
            tasks.sort_by_key(|t| std::cmp::Reverse(t.cells()));
        }
        Schedule::DiagonalFirst => {
            tasks.sort_by_key(|t| !t.is_diagonal());
        }
        Schedule::Panel => {
            tasks.sort_by(|x, y| (x.a_start, x.b_start).cmp(&(y.a_start, y.b_start)));
            // reverse the b-sweep of every other panel (serpentine)
            let mut i = 0;
            let mut flip = false;
            while i < tasks.len() {
                let a = tasks[i].a_start;
                let mut j = i;
                while j < tasks.len() && tasks[j].a_start == a {
                    j += 1;
                }
                if flip {
                    tasks[i..j].reverse();
                }
                flip = !flip;
                i = j;
            }
        }
    }
}

/// Partition schedule-ordered tasks into `shards` affinity queues for
/// distributed dispatch (`crate::cluster`): contiguous runs of
/// near-equal total output cells, so each worker's preferred queue
/// keeps the locality the policy established (a panel-ordered shard
/// still sweeps panels) while the cut points balance work, not task
/// counts — the triangle's diagonal tasks are half the size of
/// off-diagonal ones. Workers steal across shards when their own runs
/// dry, so the split biases locality without fencing work in.
pub fn shard_tasks(tasks: &[BlockTask], shards: usize) -> Vec<Vec<BlockTask>> {
    let shards = shards.max(1);
    let total: u128 = tasks.iter().map(|t| t.cells() as u128).sum();
    let mut out: Vec<Vec<BlockTask>> = vec![Vec::new(); shards];
    let mut acc: u128 = 0;
    for (idx, t) in tasks.iter().enumerate() {
        // cells consumed *before* this task decide its shard, so every
        // shard gets a contiguous, non-empty-when-possible run
        let s = ((acc * shards as u128) / total.max(1)) as usize;
        let s = s.min(shards - 1).min(idx);
        out[s].push(*t);
        acc += t.cells() as u128;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::planner::plan_blocks;

    fn sample() -> Vec<BlockTask> {
        plan_blocks(10, 4).unwrap().tasks // blocks of 4,4,2 -> 6 tasks
    }

    #[test]
    fn sequential_is_identity() {
        let mut t = sample();
        let orig = t.clone();
        order_tasks(&mut t, Schedule::Sequential);
        assert_eq!(t, orig);
    }

    #[test]
    fn largest_first_descends() {
        let mut t = sample();
        order_tasks(&mut t, Schedule::LargestFirst);
        for w in t.windows(2) {
            assert!(w[0].cells() >= w[1].cells());
        }
    }

    #[test]
    fn diagonal_first_puts_diagonals_up_front() {
        let mut t = sample();
        order_tasks(&mut t, Schedule::DiagonalFirst);
        let first_off = t.iter().position(|x| !x.is_diagonal()).unwrap();
        assert!(t[..first_off].iter().all(|x| x.is_diagonal()));
        assert!(t[first_off..].iter().all(|x| !x.is_diagonal()));
        assert_eq!(t[..first_off].len(), 3);
    }

    #[test]
    fn panel_order_is_serpentine() {
        let mut t = plan_blocks(16, 4).unwrap().tasks; // 4 blocks, 10 tasks
        order_tasks(&mut t, Schedule::Panel);
        let starts: Vec<(usize, usize)> = t.iter().map(|x| (x.a_start, x.b_start)).collect();
        assert_eq!(
            starts,
            vec![
                (0, 0),
                (0, 4),
                (0, 8),
                (0, 12),
                (4, 12), // panel 1 reversed: reuses block 12 at the turn
                (4, 8),
                (4, 4),
                (8, 8), // panel 2 forward again: reuses block 8
                (8, 12),
                (12, 12),
            ]
        );
    }

    #[test]
    fn schedule_names_are_stable() {
        assert_eq!(Schedule::Sequential.name(), "sequential");
        assert_eq!(Schedule::LargestFirst.name(), "largest-first");
        assert_eq!(Schedule::DiagonalFirst.name(), "diagonal-first");
        assert_eq!(Schedule::Panel.name(), "panel");
    }

    #[test]
    fn parse_round_trips_every_name() {
        for s in [
            Schedule::Sequential,
            Schedule::LargestFirst,
            Schedule::DiagonalFirst,
            Schedule::Panel,
        ] {
            assert_eq!(Schedule::parse(s.name()), Some(s));
        }
        assert_eq!(Schedule::parse("zigzag"), None);
    }

    #[test]
    fn shard_tasks_partitions_in_schedule_order() {
        let mut t = plan_blocks(16, 4).unwrap().tasks; // 10 equal-cell tasks
        order_tasks(&mut t, Schedule::Panel);
        let shards = shard_tasks(&t, 3);
        let flat: Vec<BlockTask> = shards.iter().flatten().copied().collect();
        assert_eq!(flat, t, "concatenated shards must be the schedule order");
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert_eq!(sizes, vec![4, 3, 3], "equal-cell tasks split near-evenly");
        assert_eq!(shard_tasks(&t, 1), vec![t.clone()]);
        // more shards than tasks: nothing lost, some shards empty
        assert_eq!(shard_tasks(&t, 100).iter().flatten().count(), t.len());
        assert!(shard_tasks(&[], 4).iter().all(|s| s.is_empty()));
    }

    #[test]
    fn ordering_preserves_the_task_set() {
        for policy in [
            Schedule::Sequential,
            Schedule::LargestFirst,
            Schedule::DiagonalFirst,
            Schedule::Panel,
        ] {
            let mut t = sample();
            order_tasks(&mut t, policy);
            let mut a = t;
            let mut b = sample();
            let key = |x: &BlockTask| (x.a_start, x.b_start);
            a.sort_by_key(key);
            b.sort_by_key(key);
            assert_eq!(a, b);
        }
    }
}
