//! Task ordering policies for a block plan. With work-stealing workers
//! the schedule mostly affects tail latency: issuing the most expensive
//! tasks first avoids a single large task straggling at the end.

use super::planner::BlockTask;

/// Ordering policy for block tasks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// Plan order (row-major over block pairs).
    Sequential,
    /// Most output cells first (default: best tail behaviour).
    LargestFirst,
    /// Diagonal blocks first (warms per-column state, useful for
    /// providers that cache per-block packing).
    DiagonalFirst,
}

/// Order `tasks` in place according to `policy` (stable).
pub fn order_tasks(tasks: &mut [BlockTask], policy: Schedule) {
    match policy {
        Schedule::Sequential => {}
        Schedule::LargestFirst => {
            tasks.sort_by_key(|t| std::cmp::Reverse(t.cells()));
        }
        Schedule::DiagonalFirst => {
            tasks.sort_by_key(|t| !t.is_diagonal());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::planner::plan_blocks;

    fn sample() -> Vec<BlockTask> {
        plan_blocks(10, 4).unwrap().tasks // blocks of 4,4,2 -> 6 tasks
    }

    #[test]
    fn sequential_is_identity() {
        let mut t = sample();
        let orig = t.clone();
        order_tasks(&mut t, Schedule::Sequential);
        assert_eq!(t, orig);
    }

    #[test]
    fn largest_first_descends() {
        let mut t = sample();
        order_tasks(&mut t, Schedule::LargestFirst);
        for w in t.windows(2) {
            assert!(w[0].cells() >= w[1].cells());
        }
    }

    #[test]
    fn diagonal_first_puts_diagonals_up_front() {
        let mut t = sample();
        order_tasks(&mut t, Schedule::DiagonalFirst);
        let first_off = t.iter().position(|x| !x.is_diagonal()).unwrap();
        assert!(t[..first_off].iter().all(|x| x.is_diagonal()));
        assert!(t[first_off..].iter().all(|x| !x.is_diagonal()));
        assert_eq!(t[..first_off].len(), 3);
    }

    #[test]
    fn ordering_preserves_the_task_set() {
        for policy in [Schedule::Sequential, Schedule::LargestFirst, Schedule::DiagonalFirst] {
            let mut t = sample();
            order_tasks(&mut t, policy);
            let mut a = t;
            let mut b = sample();
            let key = |x: &BlockTask| (x.a_start, x.b_start);
            a.sort_by_key(key);
            b.sort_by_key(key);
            assert_eq!(a, b);
        }
    }
}
