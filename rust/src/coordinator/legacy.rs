//! Deprecated wrapper pile, collected in one place. PRs 4–5 grew a
//! family of Mi-defaulting and dataset-specific entry points
//! (`execute_plan*`, `compute_native*`); the canonical surface is now
//! the source-generic quartet in [`super::executor`]:
//! [`run_plan`](super::executor::run_plan) /
//! [`run_plan_serial`](super::executor::run_plan_serial) /
//! [`run_plan_dense`](super::executor::run_plan_dense) /
//! [`run_plan_dense_serial`](super::executor::run_plan_dense_serial),
//! plus [`compute_source`](super::executor::compute_source) for
//! whole-dataset runs. These aliases delegate verbatim (same plans,
//! bit-identical results) and will be removed once downstream callers
//! migrate; nothing inside the crate calls them.

use super::executor::{
    compute_source, run_plan, run_plan_dense, run_plan_dense_serial, run_plan_serial,
    GramProvider, NativeKind,
};
use super::planner::BlockPlan;
use super::progress::Progress;
use crate::data::colstore::{ColumnSource, InMemorySource};
use crate::data::dataset::BinaryDataset;
use crate::mi::measure::CombineKind;
use crate::mi::sink::MiSink;
use crate::mi::MiMatrix;
use crate::util::error::Result;

#[deprecated(note = "use `coordinator::run_plan` with `CombineKind::Mi`")]
pub fn execute_plan_sink<P: GramProvider + Sync>(
    src: &dyn ColumnSource,
    plan: &BlockPlan,
    provider: &P,
    workers: usize,
    progress: &Progress,
    sink: &mut dyn MiSink,
) -> Result<()> {
    run_plan(src, plan, provider, workers, progress, sink, CombineKind::Mi)
}

#[deprecated(note = "renamed to `coordinator::run_plan`")]
pub fn execute_plan_sink_measure<P: GramProvider + Sync>(
    src: &dyn ColumnSource,
    plan: &BlockPlan,
    provider: &P,
    workers: usize,
    progress: &Progress,
    sink: &mut dyn MiSink,
    measure: CombineKind,
) -> Result<()> {
    run_plan(src, plan, provider, workers, progress, sink, measure)
}

#[deprecated(note = "use `coordinator::run_plan_serial` with `CombineKind::Mi`")]
pub fn execute_plan_sink_serial<P: GramProvider>(
    src: &dyn ColumnSource,
    plan: &BlockPlan,
    provider: &P,
    progress: &Progress,
    sink: &mut dyn MiSink,
) -> Result<()> {
    run_plan_serial(src, plan, provider, progress, sink, CombineKind::Mi)
}

#[deprecated(note = "renamed to `coordinator::run_plan_serial`")]
pub fn execute_plan_sink_serial_measure<P: GramProvider>(
    src: &dyn ColumnSource,
    plan: &BlockPlan,
    provider: &P,
    progress: &Progress,
    sink: &mut dyn MiSink,
    measure: CombineKind,
) -> Result<()> {
    run_plan_serial(src, plan, provider, progress, sink, measure)
}

#[deprecated(note = "use `coordinator::run_plan_dense` with `CombineKind::Mi`")]
pub fn execute_plan<P: GramProvider + Sync>(
    src: &dyn ColumnSource,
    plan: &BlockPlan,
    provider: &P,
    workers: usize,
    progress: &Progress,
) -> Result<MiMatrix> {
    run_plan_dense(src, plan, provider, workers, progress, CombineKind::Mi)
}

#[deprecated(note = "renamed to `coordinator::run_plan_dense`")]
pub fn execute_plan_measure<P: GramProvider + Sync>(
    src: &dyn ColumnSource,
    plan: &BlockPlan,
    provider: &P,
    workers: usize,
    progress: &Progress,
    measure: CombineKind,
) -> Result<MiMatrix> {
    run_plan_dense(src, plan, provider, workers, progress, measure)
}

#[deprecated(note = "use `coordinator::run_plan_dense_serial` with `CombineKind::Mi`")]
pub fn execute_plan_serial<P: GramProvider>(
    src: &dyn ColumnSource,
    plan: &BlockPlan,
    provider: &P,
    progress: &Progress,
) -> Result<MiMatrix> {
    run_plan_dense_serial(src, plan, provider, progress, CombineKind::Mi)
}

#[deprecated(note = "use `coordinator::compute_source` with `CombineKind::Mi`")]
pub fn compute_native(ds: &BinaryDataset, kind: NativeKind, workers: usize) -> Result<MiMatrix> {
    compute_source(&InMemorySource::new(ds), kind, workers, CombineKind::Mi)
}

#[deprecated(note = "use `coordinator::compute_source`")]
pub fn compute_native_measure(
    ds: &BinaryDataset,
    kind: NativeKind,
    workers: usize,
    measure: CombineKind,
) -> Result<MiMatrix> {
    compute_source(&InMemorySource::new(ds), kind, workers, measure)
}

#[cfg(test)]
mod tests {
    // the aliases must stay call-compatible and bit-identical until
    // they are removed
    #![allow(deprecated)]
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn aliases_match_canonical_entry_points() {
        let ds = SynthSpec::new(120, 9).sparsity(0.6).seed(17).generate();
        let want = compute_source(
            &InMemorySource::new(&ds),
            NativeKind::Bitpack,
            2,
            CombineKind::Mi,
        )
        .unwrap();
        let via_native = compute_native(&ds, NativeKind::Bitpack, 2).unwrap();
        assert_eq!(via_native.max_abs_diff(&want), 0.0);
        let via_measure =
            compute_native_measure(&ds, NativeKind::Bitpack, 2, CombineKind::Mi).unwrap();
        assert_eq!(via_measure.max_abs_diff(&want), 0.0);
    }
}
