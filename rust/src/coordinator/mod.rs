//! The Layer-3 coordinator: blockwise bulk-MI over arbitrary (n, m) —
//! the paper's stated future work ("blockwise computation for situations
//! when the number of columns is too large ... might exhaust the
//! machine's memory") built as a first-class feature.
//!
//! Pipeline:
//!
//! 1. [`planner`] — split the m x m MI matrix into column-block pair
//!   tasks under a memory budget (carving a slice of it for the block
//!   cache on streaming runs).
//! 2. [`scheduler`] — order tasks and track their lifecycle (the
//!   `Panel` order maximizes block reuse for cached streaming runs).
//! 3. [`executor`] — run tasks on any Gram provider (bit-packed, dense,
//!   sparse, or the XLA/PJRT artifacts) and stream the combined MI
//!   blocks into a [`crate::mi::sink::MiSink`] (dense matrix, top-k,
//!   threshold COO, or disk spill). This is the *single* execution
//!   engine: the monolithic backends are one-block plans over it.
//! 4. [`blockcache`] — a bounded LRU over constructed block substrates
//!   plus prefetch support, so out-of-core runs fetch each block
//!   `O(1)` times instead of `O(n_blocks)` and reads overlap compute.
//! 5. [`service`] — a long-lived job API (submit / poll / cancel /
//!   drain) with worker pool, progress reporting and two admission
//!   gates: a job-slot queue ([`backpressure`]) and an aggregate RAM
//!   cap that prices every job up front ([`admission`]).
//!
//! The key exactness property (tested in `rust/tests/coordinator.rs`
//! and `rust/tests/sinks.rs`): a blockwise run equals the monolithic
//! computation *bit for bit*, because every block combines the same
//! integer counts.

pub mod admission;
pub mod backpressure;
pub mod blockcache;
pub mod executor;
pub mod legacy;
pub mod planner;
pub mod progress;
pub mod scheduler;
pub mod service;
pub mod streaming;
pub mod tilecache;

pub use admission::{AdmissionController, AdmissionPermit, Priority};
pub use blockcache::{cache_plan, BlockCache, BlockKey, CacheHandle, CacheStats, Substrate};
pub use executor::{
    compute_source, run_plan, run_plan_dense, run_plan_dense_serial, run_plan_serial,
    run_plan_tiled, GramProvider, NativeProvider, XlaProvider,
};
// the deprecated wrapper pile re-exported from its one home, so
// downstream `use bulkmi::coordinator::execute_plan` keeps resolving
// (with a deprecation warning) until callers migrate
#[allow(deprecated)]
pub use legacy::{
    compute_native, compute_native_measure, execute_plan, execute_plan_measure,
    execute_plan_serial, execute_plan_sink, execute_plan_sink_measure,
    execute_plan_sink_serial, execute_plan_sink_serial_measure,
};
pub use planner::{plan_blocks, BlockPlan, BlockTask, PlannerConfig};
pub use service::{JobHandle, JobInfo, JobService, JobSpec, JobSpecBuilder, JobStatus};
pub use tilecache::{fingerprint_words, fnv1a, TileCache, TileCacheStats, TileKey};
