//! Content-addressed Gram-tile cache: the never-compute-a-tile-twice
//! layer (ROADMAP direction 5).
//!
//! The paper's identity makes a Gram tile `G11[a, b] = Dᵀ_a D_b` a pure
//! function of its two input column blocks — and since every native
//! substrate (bit-packed, CSR, dense f32) produces the *bit-identical*
//! integer-count Gram, the tile is also independent of the
//! [`super::executor::NativeKind`] that computed it. That makes the
//! Gram the perfect cache grain: one cached tile serves every backend
//! and every measure (the measure combine runs fresh on top, so cached
//! runs stay bit-exact, which is what the `pvalue:` sinks require).
//!
//! Keying is by *content*, not position: each column block is
//! fingerprinted over its packed words ([`fingerprint_words`], an
//! FNV-1a over the `u64` payload with the block shape mixed in), so a
//! tile computed for one dataset file is hit by any other source whose
//! blocks carry the same bits — including the same file re-registered
//! under a new name, or a re-packed copy. A tile's key is the ordered
//! pair `(fp_a, fp_b)`.
//!
//! On-disk format (versioned; the version is in both the file name and
//! the header, so a format bump simply misses old tiles):
//!
//! ```text
//! tile-v1-{fp_a:016x}-{fp_b:016x}.gram
//!   8 B  magic  b"bmtile1\0"
//!   8 B  rows   (u64 LE)
//!   8 B  cols   (u64 LE)
//!   rows*cols*8 B  payload (f64 LE, row-major)
//!   8 B  FNV-1a checksum over the payload bytes (u64 LE)
//! ```
//!
//! Every read re-verifies the dimensions and the checksum; a tile that
//! fails either is deleted and reported as a miss, never served. The
//! cache is therefore safe against truncation, bit-flips, and foreign
//! files in the cache root.
//!
//! Retention is a byte-budget LRU in the style of
//! [`super::blockcache::BlockCache`]: an in-RAM index (rebuilt by
//! scanning the root on [`TileCache::open`]) tracks per-entry bytes and
//! a monotone access clock; inserts evict least-recently-used tiles
//! (removing their files) until the total fits the budget, and an
//! entry larger than the whole budget is never retained. All
//! operations are best-effort: an unwritable root yields a disabled
//! cache with a warning, not an error — caching is an optimization,
//! never a correctness dependency.

use crate::linalg::dense::Mat64;
use crate::mi::sink::TileCacheReport;
use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// File-format magic for tile files; bump together with the `v1` in
/// the file name when the layout changes.
const TILE_MAGIC: &[u8; 8] = b"bmtile1\0";
/// Bytes of header + trailer around the payload.
const TILE_OVERHEAD: usize = 8 + 8 + 8 + 8;

/// Default byte budget for the shared tile caches opened by the CLI
/// and the job service.
pub const DEFAULT_TILE_BUDGET: usize = 256 << 20;

/// The conventional shared cache root: `{BULKMI_CACHE_DIR}/tiles` when
/// the persistent cache root is configured (so tiles are reused across
/// processes, next to the autotune probe cache), else a per-process
/// directory under the system temp dir.
pub fn default_tile_root() -> PathBuf {
    std::env::var_os(crate::mi::autotune::CACHE_DIR_ENV)
        .filter(|v| !v.is_empty())
        .map(|v| PathBuf::from(v).join("tiles"))
        .unwrap_or_else(|| {
            std::env::temp_dir().join(format!("bulkmi-tiles-{}", std::process::id()))
        })
}

/// 64-bit FNV-1a over a byte slice — the crate's dependency-free
/// content hash, used for block fingerprints, tile checksums, and the
/// spill manifest's per-tile checksums.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Content fingerprint of a packed column block: FNV-1a over the
/// block's `u64` words with the shape (`n_rows`, `n_cols`) mixed in
/// first, so two blocks with equal padding words but different logical
/// shapes never collide by construction.
pub fn fingerprint_words(n_rows: usize, n_cols: usize, words: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |w: u64| {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    mix(n_rows as u64);
    mix(n_cols as u64);
    for &w in words {
        mix(w);
    }
    h
}

/// A tile's identity: the ordered content fingerprints of its two
/// input column blocks. Backend- and measure-independent (see the
/// module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TileKey {
    pub fp_a: u64,
    pub fp_b: u64,
}

/// Snapshot of the cache's counters; the cache is process-wide, so
/// take one before a run and [`TileCacheStats::since`] after it for
/// per-run numbers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TileCacheStats {
    /// Lookups served from a verified on-disk tile.
    pub hits: u64,
    /// Lookups that had to compute the tile (including corrupt or
    /// missing files, which are dropped and recomputed).
    pub misses: u64,
    /// Tiles deleted to stay under the byte budget.
    pub evictions: u64,
    /// Bytes of tile files written (lifetime, not resident).
    pub inserted_bytes: u64,
}

impl TileCacheStats {
    /// Counters accumulated since the `earlier` snapshot.
    pub fn since(&self, earlier: &TileCacheStats) -> TileCacheStats {
        TileCacheStats {
            hits: self.hits.saturating_sub(earlier.hits),
            misses: self.misses.saturating_sub(earlier.misses),
            evictions: self.evictions.saturating_sub(earlier.evictions),
            inserted_bytes: self.inserted_bytes.saturating_sub(earlier.inserted_bytes),
        }
    }
}

struct Entry {
    bytes: usize,
    last_use: u64,
}

struct Inner {
    map: HashMap<TileKey, Entry>,
    /// Recency index mirroring `map`: one `(last_use, key)` entry per
    /// tile, so the LRU victim is always the first key — eviction is
    /// `O(log n)` instead of a full min-scan per evicted tile.
    order: BTreeMap<(u64, TileKey), ()>,
    total_bytes: usize,
    /// Monotone access clock; unique per touch, so LRU has no ties.
    tick: u64,
}

impl Inner {
    fn empty() -> Inner {
        Inner { map: HashMap::new(), order: BTreeMap::new(), total_bytes: 0, tick: 0 }
    }

    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Refresh `key`'s clock position; `false` when absent.
    fn touch(&mut self, key: TileKey, tick: u64) -> bool {
        match self.map.get_mut(&key) {
            Some(e) => {
                self.order.remove(&(e.last_use, key));
                e.last_use = tick;
                self.order.insert((tick, key), ());
                true
            }
            None => false,
        }
    }

    /// Insert `key` at clock position `tick`, replacing any stale
    /// entry, keeping `order` and `total_bytes` in step with `map`.
    fn add(&mut self, key: TileKey, bytes: usize, tick: u64) {
        if let Some(old) = self.map.insert(key, Entry { bytes, last_use: tick }) {
            self.order.remove(&(old.last_use, key));
            self.total_bytes -= old.bytes;
        }
        self.order.insert((tick, key), ());
        self.total_bytes += bytes;
    }

    /// Remove `key` from both indexes; `None` when absent.
    fn remove(&mut self, key: TileKey) -> Option<usize> {
        let e = self.map.remove(&key)?;
        self.order.remove(&(e.last_use, key));
        self.total_bytes -= e.bytes;
        Some(e.bytes)
    }
}

/// Byte-budget LRU over on-disk Gram tiles. Thread-safe; see the
/// module docs for the format, verification, and retention model.
pub struct TileCache {
    root: PathBuf,
    budget: usize,
    enabled: bool,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    inserted_bytes: AtomicU64,
}

impl TileCache {
    /// Open (or create) a cache rooted at `root`, scanning it to
    /// rebuild the retention index — this is what makes tiles survive
    /// across processes. Best-effort: an unusable root yields a
    /// disabled cache (every `get` misses, every `insert` is a no-op)
    /// with a warning on stderr.
    pub fn open(root: impl Into<PathBuf>, budget_bytes: usize) -> TileCache {
        let root = root.into();
        if let Err(e) = std::fs::create_dir_all(&root) {
            eprintln!("warning: tile cache disabled: cannot create {}: {e}", root.display());
            return TileCache::disabled();
        }
        let cache = TileCache {
            root,
            budget: budget_bytes,
            enabled: true,
            inner: Mutex::new(Inner::empty()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            inserted_bytes: AtomicU64::new(0),
        };
        cache.rescan();
        cache
    }

    /// A cache that serves nothing and retains nothing.
    pub fn disabled() -> TileCache {
        TileCache {
            root: PathBuf::new(),
            budget: 0,
            enabled: false,
            inner: Mutex::new(Inner::empty()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            inserted_bytes: AtomicU64::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// Tiles currently indexed.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes currently resident on disk (per the index).
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().total_bytes
    }

    pub fn stats(&self) -> TileCacheStats {
        TileCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            inserted_bytes: self.inserted_bytes.load(Ordering::Relaxed),
        }
    }

    /// On-disk size of a `rows x cols` tile file — for sizing test
    /// budgets to an exact tile count.
    pub fn file_bytes(rows: usize, cols: usize) -> usize {
        TILE_OVERHEAD + rows * cols * 8
    }

    fn path_for(&self, key: TileKey) -> PathBuf {
        self.root.join(format!("tile-v1-{:016x}-{:016x}.gram", key.fp_a, key.fp_b))
    }

    /// Rebuild the index from the files present in the root, then
    /// evict down to budget. `last_use` is seeded from file mtime
    /// (ties broken by name), so the post-restart eviction pass drops
    /// the genuinely least-recently-used tiles, not arbitrary ones —
    /// `read_dir` order carries no recency information. Orphaned
    /// `*.gram.tmp` files (a crash between the tmp write and the
    /// rename in [`TileCache::insert`]) are swept here: nothing else
    /// ever indexes or deletes them, so they would otherwise
    /// accumulate outside the budget forever.
    fn rescan(&self) {
        let entries = match std::fs::read_dir(&self.root) {
            Ok(e) => e,
            Err(_) => return,
        };
        let mut found: Vec<(std::time::SystemTime, String, TileKey, usize)> = Vec::new();
        for ent in entries.flatten() {
            let name = ent.file_name();
            let Some(name) = name.to_str() else { continue };
            if name.ends_with(".gram.tmp") {
                let _ = std::fs::remove_file(ent.path());
                continue;
            }
            let Some(key) = parse_tile_name(name) else { continue };
            let Ok(meta) = ent.metadata() else { continue };
            let mtime = meta.modified().unwrap_or(std::time::SystemTime::UNIX_EPOCH);
            found.push((mtime, name.to_string(), key, meta.len() as usize));
        }
        found.sort();
        let mut inner = self.inner.lock().unwrap();
        for (_, _, key, bytes) in found {
            let tick = inner.next_tick();
            inner.add(key, bytes, tick);
        }
        self.evict_to_budget(&mut inner);
    }

    /// Fetch and verify the tile for `key`, expecting a `rows x cols`
    /// Gram. A missing, truncated, corrupt, or wrong-shape file is
    /// removed and counted as a miss — the caller recomputes.
    pub fn get(&self, key: TileKey, rows: usize, cols: usize) -> Option<Mat64> {
        if !self.enabled {
            return None;
        }
        {
            let mut inner = self.inner.lock().unwrap();
            let tick = inner.next_tick();
            if !inner.touch(key, tick) {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        }
        // read + verify outside the lock; tiles are small and
        // immutable once written, so a racing evict at worst turns
        // this hit into a miss
        let verified = std::fs::read(self.path_for(key))
            .ok()
            .and_then(|raw| decode_tile(&raw, rows, cols));
        match verified {
            Some(gram) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(gram)
            }
            None => {
                self.drop_entry(key);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Write the tile for `key` and retain it under the budget,
    /// evicting LRU tiles as needed. A tile larger than the whole
    /// budget is not written. Best-effort: I/O failures warn and skip.
    pub fn insert(&self, key: TileKey, gram: &Mat64) {
        if !self.enabled {
            return;
        }
        let buf = encode_tile(gram);
        let bytes = buf.len();
        if bytes > self.budget {
            return;
        }
        let path = self.path_for(key);
        let tmp = path.with_extension("gram.tmp");
        let written = std::fs::write(&tmp, &buf).and_then(|_| std::fs::rename(&tmp, &path));
        if let Err(e) = written {
            eprintln!("warning: tile cache write failed for {}: {e}", path.display());
            let _ = std::fs::remove_file(&tmp);
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let tick = inner.next_tick();
        if inner.touch(key, tick) {
            // racing insert of the same content: the rename above
            // replaced the file with identical bytes
            return;
        }
        inner.add(key, bytes, tick);
        self.inserted_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.evict_to_budget(&mut inner);
    }

    /// Drop one entry (index + file) without counting an eviction —
    /// used when verification fails.
    fn drop_entry(&self, key: TileKey) {
        let mut inner = self.inner.lock().unwrap();
        inner.remove(key);
        drop(inner);
        let _ = std::fs::remove_file(self.path_for(key));
    }

    fn evict_to_budget(&self, inner: &mut Inner) {
        while inner.total_bytes > self.budget {
            // the recency index makes the LRU victim its first key
            let Some(&(_, k)) = inner.order.keys().next() else { break };
            inner.remove(k);
            self.evictions.fetch_add(1, Ordering::Relaxed);
            let _ = std::fs::remove_file(self.path_for(k));
        }
    }
}

/// Build a run's [`TileCacheReport`] from a start-of-run snapshot —
/// the tile-cache analogue of [`super::blockcache::run_reports`].
pub fn tile_report(cache: &TileCache, before: &TileCacheStats) -> TileCacheReport {
    let d = cache.stats().since(before);
    TileCacheReport {
        hits: d.hits,
        misses: d.misses,
        evictions: d.evictions,
        inserted_bytes: d.inserted_bytes,
        budget_bytes: cache.budget_bytes(),
    }
}

fn parse_tile_name(name: &str) -> Option<TileKey> {
    let hex = name.strip_prefix("tile-v1-")?.strip_suffix(".gram")?;
    let (a, b) = hex.split_once('-')?;
    if a.len() != 16 || b.len() != 16 {
        return None;
    }
    Some(TileKey {
        fp_a: u64::from_str_radix(a, 16).ok()?,
        fp_b: u64::from_str_radix(b, 16).ok()?,
    })
}

fn encode_tile(gram: &Mat64) -> Vec<u8> {
    let payload_len = gram.data().len() * 8;
    let mut buf = Vec::with_capacity(TILE_OVERHEAD + payload_len);
    buf.extend_from_slice(TILE_MAGIC);
    buf.extend_from_slice(&(gram.rows() as u64).to_le_bytes());
    buf.extend_from_slice(&(gram.cols() as u64).to_le_bytes());
    for v in gram.data() {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    let ck = fnv1a(&buf[24..]);
    buf.extend_from_slice(&ck.to_le_bytes());
    buf
}

fn decode_tile(raw: &[u8], rows: usize, cols: usize) -> Option<Mat64> {
    let payload_len = rows.checked_mul(cols)?.checked_mul(8)?;
    if raw.len() != TILE_OVERHEAD + payload_len || &raw[..8] != TILE_MAGIC {
        return None;
    }
    let u64_at = |off: usize| u64::from_le_bytes(raw[off..off + 8].try_into().unwrap());
    if u64_at(8) != rows as u64 || u64_at(16) != cols as u64 {
        return None;
    }
    let payload = &raw[24..24 + payload_len];
    if fnv1a(payload) != u64_at(24 + payload_len) {
        return None;
    }
    let data: Vec<f64> = payload
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Mat64::from_vec(rows, cols, data).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("bulkmi-tilecache-unit-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn gram(seed: u64, rows: usize, cols: usize) -> Mat64 {
        let data = (0..rows * cols).map(|i| (seed * 31 + i as u64) as f64).collect();
        Mat64::from_vec(rows, cols, data).unwrap()
    }

    fn key(a: u64, b: u64) -> TileKey {
        TileKey { fp_a: a, fp_b: b }
    }

    #[test]
    fn fnv1a_is_the_reference_function() {
        // reference vectors for 64-bit FNV-1a
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn fingerprints_separate_content_and_shape() {
        let w1 = [1u64, 2, 3];
        let w2 = [1u64, 2, 4];
        assert_eq!(fingerprint_words(64, 3, &w1), fingerprint_words(64, 3, &w1));
        assert_ne!(fingerprint_words(64, 3, &w1), fingerprint_words(64, 3, &w2));
        assert_ne!(fingerprint_words(64, 3, &w1), fingerprint_words(128, 3, &w1));
        assert_ne!(fingerprint_words(64, 3, &w1), fingerprint_words(64, 2, &w1));
    }

    #[test]
    fn insert_then_get_round_trips_bit_identically() {
        let cache = TileCache::open(tmp("roundtrip"), 1 << 20);
        let g = gram(7, 3, 5);
        cache.insert(key(1, 2), &g);
        let back = cache.get(key(1, 2), 3, 5).expect("tile must be served");
        assert_eq!(back.data(), g.data());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 0));
        assert_eq!(s.inserted_bytes, TileCache::file_bytes(3, 5) as u64);
        assert!(cache.get(key(9, 9), 3, 5).is_none());
        assert_eq!(cache.stats().misses, 1);
    }

    #[test]
    fn wrong_shape_is_dropped_not_served() {
        let cache = TileCache::open(tmp("shape"), 1 << 20);
        cache.insert(key(1, 2), &gram(7, 3, 5));
        assert!(cache.get(key(1, 2), 5, 3).is_none(), "shape mismatch must miss");
        assert_eq!(cache.len(), 0, "bad entry must be dropped");
        assert!(cache.get(key(1, 2), 3, 5).is_none(), "the file is gone");
    }

    #[test]
    fn corrupt_payload_is_dropped_not_served() {
        let root = tmp("corrupt");
        let cache = TileCache::open(&root, 1 << 20);
        cache.insert(key(1, 2), &gram(7, 3, 5));
        // flip one payload byte on disk
        let path = root.join(format!("tile-v1-{:016x}-{:016x}.gram", 1, 2));
        let mut raw = std::fs::read(&path).unwrap();
        raw[30] ^= 0x40;
        std::fs::write(&path, &raw).unwrap();
        assert!(cache.get(key(1, 2), 3, 5).is_none(), "checksum must catch the flip");
        assert_eq!(cache.stats().misses, 1);
        assert!(!path.exists(), "corrupt tile must be deleted");
    }

    #[test]
    fn lru_evicts_by_last_use_and_removes_files() {
        let one = TileCache::file_bytes(2, 2);
        let root = tmp("lru");
        let cache = TileCache::open(&root, 2 * one);
        cache.insert(key(0, 0), &gram(1, 2, 2));
        cache.insert(key(0, 1), &gram(2, 2, 2));
        cache.get(key(0, 0), 2, 2).unwrap(); // 0 is now MRU
        cache.insert(key(0, 2), &gram(3, 2, 2)); // evicts (0, 1)
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.get(key(0, 0), 2, 2).is_some());
        assert!(cache.get(key(0, 1), 2, 2).is_none(), "LRU victim must be (0,1)");
        assert_eq!(cache.resident_bytes(), 2 * one);
        let files = std::fs::read_dir(&root).unwrap().count();
        assert_eq!(files, 2, "evicted tile file must be removed");
    }

    #[test]
    fn oversized_tiles_are_not_retained() {
        let root = tmp("oversized");
        let cache = TileCache::open(&root, 8);
        cache.insert(key(1, 1), &gram(1, 4, 4));
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.resident_bytes(), 0);
        assert_eq!(std::fs::read_dir(&root).unwrap().count(), 0);
    }

    #[test]
    fn open_rescans_tiles_from_a_prior_instance() {
        let root = tmp("rescan");
        let g = gram(5, 3, 3);
        {
            let cache = TileCache::open(&root, 1 << 20);
            cache.insert(key(10, 20), &g);
        }
        let cache = TileCache::open(&root, 1 << 20);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.resident_bytes(), TileCache::file_bytes(3, 3));
        let back = cache.get(key(10, 20), 3, 3).expect("persisted tile must be served");
        assert_eq!(back.data(), g.data());
        // foreign files in the root are ignored by the scan
        std::fs::write(root.join("notes.txt"), b"x").unwrap();
        let cache = TileCache::open(&root, 1 << 20);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn disabled_cache_is_inert() {
        let cache = TileCache::disabled();
        assert!(!cache.enabled());
        cache.insert(key(1, 2), &gram(1, 2, 2));
        assert!(cache.get(key(1, 2), 2, 2).is_none());
        assert_eq!(cache.stats(), TileCacheStats::default());
    }

    fn set_mtime(path: &Path, secs: u64) {
        let t = std::time::SystemTime::UNIX_EPOCH + std::time::Duration::from_secs(secs);
        let f = std::fs::OpenOptions::new().write(true).open(path).unwrap();
        f.set_times(std::fs::FileTimes::new().set_modified(t)).unwrap();
    }

    #[test]
    fn rescan_seeds_lru_from_mtime_not_scan_order() {
        let one = TileCache::file_bytes(2, 2);
        let root = tmp("rescan-mtime");
        {
            let cache = TileCache::open(&root, 1 << 20);
            cache.insert(key(0, 0), &gram(1, 2, 2));
            cache.insert(key(0, 1), &gram(2, 2, 2));
            cache.insert(key(0, 2), &gram(3, 2, 2));
        }
        // on-disk recency says (0,1) is coldest regardless of what
        // order the directory scan yields
        let p = |a: u64, b: u64| root.join(format!("tile-v1-{a:016x}-{b:016x}.gram"));
        set_mtime(&p(0, 1), 1_000);
        set_mtime(&p(0, 0), 2_000);
        set_mtime(&p(0, 2), 3_000);
        let cache = TileCache::open(&root, 2 * one);
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.get(key(0, 1), 2, 2).is_none(), "coldest tile must be the victim");
        assert!(cache.get(key(0, 0), 2, 2).is_some());
        assert!(cache.get(key(0, 2), 2, 2).is_some());
        // equal mtimes fall back to name order for determinism: the
        // lexicographically smaller file name counts as older
        let root = tmp("rescan-mtime-tie");
        {
            let cache = TileCache::open(&root, 1 << 20);
            cache.insert(key(0, 1), &gram(2, 2, 2));
            cache.insert(key(0, 2), &gram(3, 2, 2));
        }
        let p = |a: u64, b: u64| root.join(format!("tile-v1-{a:016x}-{b:016x}.gram"));
        set_mtime(&p(0, 1), 5_000);
        set_mtime(&p(0, 2), 5_000);
        let cache = TileCache::open(&root, one);
        assert!(cache.get(key(0, 1), 2, 2).is_none(), "name tie-break: (0,1) is older");
        assert!(cache.get(key(0, 2), 2, 2).is_some());
    }

    #[test]
    fn rescan_sweeps_stale_tmp_files() {
        let root = tmp("rescan-tmp");
        let g = gram(5, 2, 2);
        {
            let cache = TileCache::open(&root, 1 << 20);
            cache.insert(key(1, 2), &g);
        }
        // simulate a crash between the tmp write and the rename
        let stale = root.join(format!("tile-v1-{:016x}-{:016x}.gram.tmp", 7u64, 8u64));
        std::fs::write(&stale, b"half-written").unwrap();
        let cache = TileCache::open(&root, 1 << 20);
        assert!(!stale.exists(), "orphaned tmp file must be swept");
        assert_eq!(cache.len(), 1, "tmp files never become index entries");
        assert_eq!(cache.get(key(1, 2), 2, 2).unwrap().data(), g.data());
    }

    #[test]
    fn ordered_index_keeps_eviction_counts_and_victims() {
        // many small tiles over budget: the BTreeMap-backed eviction
        // must evict exactly the same count and the same victims as
        // the min-scan it replaced
        let one = TileCache::file_bytes(2, 2);
        let cache = TileCache::open(tmp("ordered-index"), 3 * one);
        for s in 0..6u64 {
            cache.insert(key(0, s), &gram(s, 2, 2));
        }
        assert_eq!(cache.stats().evictions, 3, "6 inserts into a 3-tile budget evict 3");
        assert_eq!(cache.len(), 3);
        for s in 0..3u64 {
            assert!(cache.get(key(0, s), 2, 2).is_none(), "oldest three evicted");
        }
        // re-touch the now-coldest survivor so it outlives a new insert
        assert!(cache.get(key(0, 3), 2, 2).is_some());
        cache.insert(key(0, 6), &gram(6, 2, 2));
        assert_eq!(cache.stats().evictions, 4);
        assert!(cache.get(key(0, 4), 2, 2).is_none(), "untouched LRU tile is the victim");
        assert!(cache.get(key(0, 3), 2, 2).is_some());
        assert!(cache.get(key(0, 5), 2, 2).is_some());
        assert!(cache.get(key(0, 6), 2, 2).is_some());
        assert_eq!(cache.resident_bytes(), 3 * one);
    }

    #[test]
    fn stats_since_subtracts() {
        let cache = TileCache::open(tmp("since"), 1 << 20);
        cache.insert(key(1, 1), &gram(1, 2, 2));
        cache.get(key(1, 1), 2, 2).unwrap();
        let before = cache.stats();
        cache.get(key(1, 1), 2, 2).unwrap();
        cache.get(key(2, 2), 2, 2);
        let d = cache.stats().since(&before);
        assert_eq!((d.hits, d.misses, d.inserted_bytes), (1, 1, 0));
    }
}
