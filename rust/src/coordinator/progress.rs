//! Shared progress + cancellation state for a running job.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// Cheap cloneable handle tracking task completion and cancellation.
#[derive(Clone, Debug, Default)]
pub struct Progress {
    inner: Arc<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    done: AtomicUsize,
    total: AtomicUsize,
    cancelled: AtomicBool,
}

impl Progress {
    pub fn new(total: usize) -> Self {
        let p = Progress::default();
        p.inner.total.store(total, Ordering::Relaxed);
        p
    }

    pub fn task_done(&self) {
        self.inner.done.fetch_add(1, Ordering::Relaxed);
    }

    pub fn done(&self) -> usize {
        self.inner.done.load(Ordering::Relaxed)
    }

    pub fn total(&self) -> usize {
        self.inner.total.load(Ordering::Relaxed)
    }

    /// Reset the task total once the real plan is known. The job
    /// service plans *inside* the worker when block sizing depends on
    /// the autotuner's probe, so the handle starts with a placeholder
    /// total; cancellation state is untouched.
    pub fn set_total(&self, total: usize) {
        self.inner.total.store(total, Ordering::Relaxed);
    }

    /// Completion in [0, 1] (1.0 for empty plans).
    pub fn fraction(&self) -> f64 {
        let total = self.total();
        if total == 0 {
            1.0
        } else {
            self.done() as f64 / total as f64
        }
    }

    pub fn cancel(&self) {
        self.inner.cancelled.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.inner.cancelled.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_fraction() {
        let p = Progress::new(4);
        assert_eq!(p.fraction(), 0.0);
        p.task_done();
        p.task_done();
        assert!((p.fraction() - 0.5).abs() < 1e-12);
        assert_eq!(p.done(), 2);
        assert_eq!(p.total(), 4);
    }

    #[test]
    fn empty_plan_is_complete() {
        assert_eq!(Progress::new(0).fraction(), 1.0);
    }

    #[test]
    fn cancellation_is_shared_across_clones() {
        let p = Progress::new(1);
        let q = p.clone();
        assert!(!q.is_cancelled());
        p.cancel();
        assert!(q.is_cancelled());
    }
}
