//! Blockwise planning: tile the symmetric m x m MI matrix into
//! column-block pair tasks, sized under a memory budget.
//!
//! For block size B the plan has one task per unordered block pair
//! (including diagonal blocks); task (a, b) with a <= b computes the
//! cross Gram of column blocks a and b and fills both the (a, b) and
//! (b, a) regions of the output. Every column pair is covered exactly
//! once — the invariant property-tested in `rust/tests/coordinator.rs`.

use crate::util::error::{Error, Result};

/// One unit of work: the cross-block Gram + combine for column ranges
/// `[a_start, a_start + a_len)` x `[b_start, b_start + b_len)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockTask {
    pub a_start: usize,
    pub a_len: usize,
    pub b_start: usize,
    pub b_len: usize,
}

impl BlockTask {
    /// Is this a diagonal task (same block on both sides)?
    pub fn is_diagonal(&self) -> bool {
        self.a_start == self.b_start && self.a_len == self.b_len
    }

    /// Number of output cells this task fills (counting both mirror
    /// halves for off-diagonal tasks).
    pub fn cells(&self) -> usize {
        if self.is_diagonal() {
            self.a_len * self.a_len
        } else {
            2 * self.a_len * self.b_len
        }
    }
}

/// A full plan over the dataset's columns.
#[derive(Clone, Debug)]
pub struct BlockPlan {
    pub m: usize,
    pub block: usize,
    pub tasks: Vec<BlockTask>,
}

impl BlockPlan {
    /// Total output cells across tasks (must equal m²; see tests).
    pub fn total_cells(&self) -> usize {
        self.tasks.iter().map(|t| t.cells()).sum()
    }

    pub fn n_blocks(&self) -> usize {
        self.m.div_ceil(self.block.max(1))
    }
}

/// Planner inputs.
#[derive(Clone, Copy, Debug, Default)]
pub struct PlannerConfig {
    /// Requested block size in columns; 0 = derive from `memory_budget`
    /// (or monolithic when that is also 0).
    pub block_cols: usize,
    /// Peak extra bytes a worker may use; 0 = unlimited.
    pub memory_budget: usize,
    /// Bytes per matrix cell of the Gram substrate (8 for f64 output
    /// blocks; used in the budget model).
    pub n_rows: usize,
}

/// Build a plan for `m` columns with explicit block size.
pub fn plan_blocks(m: usize, block_cols: usize) -> Result<BlockPlan> {
    if m == 0 {
        return Err(Error::Shape("cannot plan over zero columns".into()));
    }
    let block = if block_cols == 0 { m } else { block_cols.min(m) };
    let n_blocks = m.div_ceil(block);
    let mut tasks = Vec::with_capacity(n_blocks * (n_blocks + 1) / 2);
    for a in 0..n_blocks {
        let a_start = a * block;
        let a_len = block.min(m - a_start);
        for b in a..n_blocks {
            let b_start = b * block;
            let b_len = block.min(m - b_start);
            tasks.push(BlockTask { a_start, a_len, b_start, b_len });
        }
    }
    Ok(BlockPlan { m, block, tasks })
}

/// Estimate the peak working-set bytes of one block task for block size
/// `b` and `n` rows: two dense f32 column blocks streamed (2·n·b·4), one
/// f64 Gram/count block (b²·8), one f64 MI block (b²·8).
pub fn task_bytes(n: usize, b: usize) -> usize {
    2 * n * b * 4 + 2 * b * b * 8
}

/// Largest block size whose task working set fits `budget` bytes
/// (minimum 1 column). Solves the quadratic 16 b² + 8 n b <= budget.
pub fn block_for_budget(n: usize, m: usize, budget: usize) -> usize {
    if budget == 0 {
        return m;
    }
    let mut lo = 1usize;
    let mut hi = m.max(1);
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        if task_bytes(n, mid) <= budget {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

/// Bytes of the dense m x m f64 output a `DenseSink` materializes —
/// the term matrix-free sinks delete from the memory model.
pub fn dense_output_bytes(m: usize) -> usize {
    m * m * 8
}

/// The memory budget assumed when the caller passes 0 ("no budget"):
/// 256 MiB, the historical [`matrix_free_block`] default.
pub const DEFAULT_MEMORY_BUDGET: usize = 256 << 20;

/// Block size for matrix-free sink runs (top-k / threshold / spill)
/// when none is requested: the largest block whose *task* working set
/// fits `budget` bytes (default [`DEFAULT_MEMORY_BUDGET`] when 0).
/// Unlike the dense path there is no m x m term, so this stays bounded
/// for any m — the out-of-core sizing rule documented in ROADMAP.md.
pub fn matrix_free_block(n: usize, m: usize, budget: usize) -> usize {
    let budget = if budget == 0 { DEFAULT_MEMORY_BUDGET } else { budget };
    block_for_budget(n, m, budget)
}

/// Split a run's memory budget between task working sets and the block
/// substrate cache (`super::blockcache`), half each: returns
/// `(task_budget, cache_budget)`. `0` means "no budget" and carves
/// from [`DEFAULT_MEMORY_BUDGET`]. Keeping the carve inside the
/// planner keeps `task_bytes` accounting honest — block sizing and the
/// cache together stay within what the caller asked for, rather than
/// the cache silently doubling the footprint.
pub fn carve_cache_budget(budget: usize) -> (usize, usize) {
    let budget = if budget == 0 { DEFAULT_MEMORY_BUDGET } else { budget };
    let cache = budget / 2;
    (budget - cache, cache)
}

/// Default per-task Gram latency target for
/// [`throughput_block`]: long enough that per-task overheads
/// (block extraction, channel send) stay negligible, short enough that
/// progress reporting and cancellation stay responsive.
pub const DEFAULT_TASK_LATENCY_SECS: f64 = 2.0;

/// Fold probed Gram (and optionally combine) throughput into block
/// sizing: the largest block whose estimated single-task latency stays
/// under `target_secs`, additionally capped by the
/// [`matrix_free_block`] memory rule for `budget` (0 = its 256 MiB
/// default).
///
/// `cell_rows_per_sec` is the autotuner's Gram throughput measure
/// ([`crate::mi::autotune::ProbeReport::chosen_throughput`]): Gram
/// output cells x rows per second. A diagonal block task computes
/// ~`b² · n` cell-rows of Gram plus `b²` element-wise combine cells,
/// so with a probed combine throughput `T_c`
/// ([`crate::mi::autotune::ProbeReport::combine_throughput`], cells
/// per second) the latency model is
/// `b² · (n / T_gram + 1 / T_c) <= target` — entropy-heavy measures
/// (`nmi`, `vi`) size blocks against Gram **+** combine rather than
/// Gram alone. Without a combine figure the historical pure-Gram cap
/// `b = sqrt(T_gram · target / n)` applies unchanged. **Faster
/// substrates get larger blocks under the same latency budget**, which
/// amortizes per-task overhead exactly where the hardware can afford
/// it. A non-finite or non-positive Gram throughput falls back to the
/// memory rule alone; a non-finite or non-positive combine throughput
/// is ignored.
pub fn throughput_block(
    n: usize,
    m: usize,
    budget: usize,
    cell_rows_per_sec: f64,
    combine_cells_per_sec: Option<f64>,
    target_secs: f64,
) -> usize {
    let mem_cap = matrix_free_block(n, m, budget);
    if !cell_rows_per_sec.is_finite()
        || cell_rows_per_sec <= 0.0
        || !target_secs.is_finite()
        || target_secs <= 0.0
    {
        return mem_cap;
    }
    let combine = combine_cells_per_sec.filter(|c| c.is_finite() && *c > 0.0);
    let cells = match combine {
        // b² · (n/T_gram + 1/T_combine) <= target
        Some(tc) => target_secs / (n.max(1) as f64 / cell_rows_per_sec + 1.0 / tc),
        // pure-Gram model: b² · n / T_gram <= target
        None => cell_rows_per_sec * target_secs / n.max(1) as f64,
    };
    let latency_cap = cells.sqrt().floor() as usize;
    latency_cap.clamp(1, m.max(1)).min(mem_cap)
}

/// The block-width policy shared by the job service and the CLI sink
/// path: an explicit caller width always wins, then a probed
/// throughput (via [`throughput_block`] under the caller's
/// `target_secs` latency target — `--task-latency` /
/// `run.task_latency_secs`, default [`DEFAULT_TASK_LATENCY_SECS`]),
/// then the caller's `fallback` rule — the service's monolithic plan,
/// or the CLI's memory-budget rule. Returns the width together with
/// its `BlockSizing::source` tag (`"explicit"` / `"probe-throughput"`
/// / the fallback's own tag).
///
/// `combine_cells_per_sec` is the probed per-measure combine-stage
/// throughput ([`crate::mi::autotune::ProbeReport::combine_throughput`]);
/// when present it is folded into the latency model alongside the Gram
/// throughput, so entropy-heavy measures get smaller blocks under the
/// same latency target. It only participates when a Gram throughput is
/// also present (the combine probe never sizes blocks on its own).
pub fn block_policy(
    explicit_cols: usize,
    probe_cell_rows_per_sec: Option<f64>,
    combine_cells_per_sec: Option<f64>,
    n: usize,
    m: usize,
    budget: usize,
    target_secs: f64,
    fallback: (usize, &'static str),
) -> (usize, &'static str) {
    if explicit_cols > 0 {
        return (explicit_cols, "explicit");
    }
    if let Some(tput) = probe_cell_rows_per_sec {
        return (
            throughput_block(n, m, budget, tput, combine_cells_per_sec, target_secs),
            "probe-throughput",
        );
    }
    fallback
}

/// Plan from a [`PlannerConfig`] (block size override wins over budget).
pub fn plan_with_config(m: usize, cfg: &PlannerConfig) -> Result<BlockPlan> {
    let block = if cfg.block_cols > 0 {
        cfg.block_cols
    } else if cfg.memory_budget > 0 {
        block_for_budget(cfg.n_rows, m, cfg.memory_budget)
    } else {
        0
    };
    plan_blocks(m, block)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monolithic_plan_is_one_task() {
        let plan = plan_blocks(100, 0).unwrap();
        assert_eq!(plan.tasks.len(), 1);
        assert!(plan.tasks[0].is_diagonal());
        assert_eq!(plan.total_cells(), 100 * 100);
    }

    #[test]
    fn block_plan_covers_all_cells() {
        for (m, b) in [(10usize, 3usize), (100, 7), (64, 64), (65, 64), (5, 1)] {
            let plan = plan_blocks(m, b).unwrap();
            assert_eq!(plan.total_cells(), m * m, "m={m} b={b}");
            let nb = m.div_ceil(b);
            assert_eq!(plan.tasks.len(), nb * (nb + 1) / 2);
        }
    }

    #[test]
    fn every_column_pair_covered_exactly_once() {
        let m = 23;
        let plan = plan_blocks(m, 5).unwrap();
        let mut covered = vec![0u32; m * m];
        for t in &plan.tasks {
            for i in t.a_start..t.a_start + t.a_len {
                for j in t.b_start..t.b_start + t.b_len {
                    covered[i * m + j] += 1;
                    if !t.is_diagonal() {
                        covered[j * m + i] += 1;
                    }
                }
            }
        }
        assert!(covered.iter().all(|&c| c == 1), "coverage map: {covered:?}");
    }

    #[test]
    fn zero_columns_rejected() {
        assert!(plan_blocks(0, 4).is_err());
    }

    #[test]
    fn budget_block_sizing() {
        // generous budget: monolithic
        assert_eq!(block_for_budget(1000, 500, usize::MAX), 500);
        // tiny budget: still at least 1
        assert_eq!(block_for_budget(1_000_000, 500, 1), 1);
        // budget respected
        for &budget in &[1 << 20, 16 << 20, 256 << 20] {
            let b = block_for_budget(100_000, 10_000, budget);
            assert!(task_bytes(100_000, b) <= budget || b == 1);
            if b < 10_000 {
                // maximality: next size up must exceed the budget
                assert!(task_bytes(100_000, b + 1) > budget);
            }
        }
    }

    #[test]
    fn cache_carve_preserves_the_budget() {
        for budget in [0usize, 1, 7, 1 << 20, 256 << 20, usize::MAX - 1] {
            let (task, cache) = carve_cache_budget(budget);
            let want = if budget == 0 { DEFAULT_MEMORY_BUDGET } else { budget };
            assert_eq!(task + cache, want, "budget {budget}");
            assert!(task >= cache, "task side gets the rounding byte");
        }
        assert_eq!(carve_cache_budget(0), (128 << 20, 128 << 20));
    }

    #[test]
    fn matrix_free_block_is_bounded_for_huge_m() {
        // 1M columns: the dense output would need 8 TB...
        assert_eq!(dense_output_bytes(1_000_000), 8_000_000_000_000);
        // ...but the matrix-free task working set stays under budget
        let b = matrix_free_block(100_000, 1_000_000, 0);
        assert!(b >= 1);
        assert!(task_bytes(100_000, b) <= 256 << 20 || b == 1);
        // small m still planned monolithically under a huge budget
        assert_eq!(matrix_free_block(100, 50, usize::MAX), 50);
    }

    #[test]
    fn throughput_block_scales_with_substrate_speed() {
        let (n, m) = (10_000usize, 5_000usize);
        // faster probed substrates get blocks at least as large
        let slow = throughput_block(n, m, 0, 1e6, None, DEFAULT_TASK_LATENCY_SECS);
        let fast = throughput_block(n, m, 0, 1e9, None, DEFAULT_TASK_LATENCY_SECS);
        assert!(fast >= slow, "fast {fast} < slow {slow}");
        assert!(slow >= 1);
        // the latency model itself: b^2 * n / throughput <= target
        // (when the latency cap, not the memory cap, binds)
        let b = throughput_block(n, m, usize::MAX, 1e8, None, 1.0);
        if b < m {
            assert!((b * b) as f64 * n as f64 / 1e8 <= 1.0 + 1e-9, "b={b}");
            assert!(((b + 1) * (b + 1)) as f64 * n as f64 / 1e8 > 1.0, "b={b} not maximal");
        }
        // the memory rule still caps an arbitrarily fast substrate
        let capped = throughput_block(100_000, 1_000_000, 0, f64::MAX, None, 1e9);
        assert!(task_bytes(100_000, capped) <= 256 << 20 || capped == 1);
        // degenerate throughput falls back to the memory rule
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            assert_eq!(
                throughput_block(n, m, 0, bad, None, DEFAULT_TASK_LATENCY_SECS),
                matrix_free_block(n, m, 0),
                "throughput={bad}"
            );
        }
    }

    #[test]
    fn combine_throughput_shrinks_blocks() {
        let (n, m) = (10_000usize, 5_000usize);
        // a slow combine stage shrinks the block against Gram-only sizing
        let gram_only = throughput_block(n, m, usize::MAX, 1e8, None, 1.0);
        let with_combine = throughput_block(n, m, usize::MAX, 1e8, Some(1e6), 1.0);
        assert!(with_combine <= gram_only, "{with_combine} > {gram_only}");
        // the combined latency model: b^2 * (n/Tg + 1/Tc) <= target
        let b = with_combine;
        let per_cell = n as f64 / 1e8 + 1.0 / 1e6;
        if b < m {
            assert!((b * b) as f64 * per_cell <= 1.0 + 1e-9, "b={b}");
            assert!(((b + 1) * (b + 1)) as f64 * per_cell > 1.0, "b={b} not maximal");
        }
        // an arbitrarily fast combine stage converges to Gram-only sizing
        assert_eq!(throughput_block(n, m, usize::MAX, 1e8, Some(f64::MAX), 1.0), gram_only);
        // degenerate combine figures are ignored, not fatal
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            assert_eq!(
                throughput_block(n, m, usize::MAX, 1e8, Some(bad), 1.0),
                gram_only,
                "combine={bad}"
            );
        }
        assert!(throughput_block(n, m, usize::MAX, 1e8, Some(1e6), 1.0) >= 1);
    }

    #[test]
    fn block_policy_precedence() {
        let t = DEFAULT_TASK_LATENCY_SECS;
        // explicit width wins over everything
        assert_eq!(
            block_policy(7, Some(1e9), Some(1e7), 1000, 100, 0, t, (3, "budget")),
            (7, "explicit")
        );
        // probed throughput next
        let (b, src) = block_policy(0, Some(1e9), None, 1000, 100, 0, t, (3, "budget"));
        assert_eq!(src, "probe-throughput");
        assert_eq!(b, throughput_block(1000, 100, 0, 1e9, None, t));
        // a combine figure folds into the throughput rule, same tag
        let (bc, src) = block_policy(0, Some(1e9), Some(1e6), 1000, 100, 0, t, (3, "budget"));
        assert_eq!(src, "probe-throughput");
        assert_eq!(bc, throughput_block(1000, 100, 0, 1e9, Some(1e6), t));
        assert!(bc <= b);
        // ...but never sizes on its own: no Gram figure -> fallback
        assert_eq!(
            block_policy(0, None, Some(1e6), 1000, 100, 0, t, (3, "budget")),
            (3, "budget")
        );
        // the caller's fallback last
        assert_eq!(block_policy(0, None, None, 1000, 100, 0, t, (3, "budget")), (3, "budget"));
    }

    #[test]
    fn block_policy_honors_the_latency_target() {
        // a longer target affords blocks at least as large
        let (short, _) = block_policy(0, Some(1e8), None, 10_000, 5_000, 0, 0.5, (1, "budget"));
        let (long, _) = block_policy(0, Some(1e8), None, 10_000, 5_000, 0, 8.0, (1, "budget"));
        assert!(long >= short, "long {long} < short {short}");
        // a degenerate target falls back to the memory rule
        let (b, src) = block_policy(0, Some(1e8), None, 10_000, 5_000, 0, 0.0, (1, "budget"));
        assert_eq!(src, "probe-throughput");
        assert_eq!(b, matrix_free_block(10_000, 5_000, 0));
    }

    #[test]
    fn config_plan_modes() {
        let explicit = plan_with_config(100, &PlannerConfig {
            block_cols: 10,
            memory_budget: 1,
            n_rows: 50,
        })
        .unwrap();
        assert_eq!(explicit.block, 10); // explicit wins over budget

        let budgeted = plan_with_config(100, &PlannerConfig {
            block_cols: 0,
            memory_budget: task_bytes(50, 25),
            n_rows: 50,
        })
        .unwrap();
        assert!(budgeted.block >= 25);

        let mono = plan_with_config(100, &PlannerConfig::default()).unwrap();
        assert_eq!(mono.tasks.len(), 1);
    }
}
