//! Streaming ingestion: compute bulk MI over a dataset that arrives as
//! row chunks (a log stream, a sequencing run, a crawler) without ever
//! materializing all rows.
//!
//! Works because the optimized algorithm's sufficient statistics —
//! `(G11, colsums, n)` — are sums over rows: each chunk contributes its
//! partial Gram and counts, and the combine runs once at the end.
//! Peak memory is one chunk + the m x m accumulator, independent of the
//! total row count.

use crate::data::dataset::BinaryDataset;
use crate::linalg::dense::Mat64;
use crate::mi::bulk_opt::combine;
use crate::mi::MiMatrix;
use crate::util::error::{Error, Result};

/// Which substrate computes each chunk's partial Gram.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkGram {
    /// Bit-packed AND+popcount (default; fastest at typical sparsity).
    Bitpack,
    /// CSR row-pair expansion (fastest at very high sparsity).
    Sparse,
}

/// Accumulates sufficient statistics chunk by chunk.
#[derive(Debug)]
pub struct StreamingAccumulator {
    m: usize,
    kind: ChunkGram,
    g11: Mat64,
    colsums: Vec<f64>,
    n_rows: u64,
    n_chunks: u64,
}

impl StreamingAccumulator {
    /// `m`: number of variables every chunk must have.
    pub fn new(m: usize, kind: ChunkGram) -> Result<Self> {
        if m == 0 {
            return Err(Error::Shape("zero columns".into()));
        }
        Ok(StreamingAccumulator {
            m,
            kind,
            g11: Mat64::zeros(m, m),
            colsums: vec![0.0; m],
            n_rows: 0,
            n_chunks: 0,
        })
    }

    pub fn n_rows(&self) -> u64 {
        self.n_rows
    }

    pub fn n_chunks(&self) -> u64 {
        self.n_chunks
    }

    /// Ingest one chunk of rows (any chunk size, including 1).
    pub fn push_chunk(&mut self, chunk: &BinaryDataset) -> Result<()> {
        if chunk.n_cols() != self.m {
            return Err(Error::Shape(format!(
                "chunk has {} columns, accumulator expects {}",
                chunk.n_cols(),
                self.m
            )));
        }
        let (g, counts) = match self.kind {
            ChunkGram::Bitpack => {
                let bits = chunk.to_bitmatrix();
                (bits.gram(), bits.col_counts())
            }
            ChunkGram::Sparse => {
                let csr = chunk.to_csr();
                (csr.gram(), csr.col_counts())
            }
        };
        for (acc, v) in self.g11.data_mut().iter_mut().zip(g.data()) {
            *acc += v;
        }
        for (acc, &c) in self.colsums.iter_mut().zip(&counts) {
            *acc += c as f64;
        }
        self.n_rows += chunk.n_rows() as u64;
        self.n_chunks += 1;
        Ok(())
    }

    /// Current MI estimate over everything ingested so far (can be
    /// called repeatedly; does not consume the accumulator).
    pub fn snapshot(&self) -> Result<MiMatrix> {
        if self.n_rows == 0 {
            return Err(Error::Shape("no rows ingested".into()));
        }
        Ok(MiMatrix::from_mat(combine(
            &self.g11,
            &self.colsums,
            &self.colsums,
            self.n_rows as f64,
        )))
    }

    /// Final MI matrix.
    pub fn finalize(self) -> Result<MiMatrix> {
        self.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::mi::backend::{compute_mi, Backend};

    #[test]
    fn chunked_equals_monolithic_bit_for_bit() {
        let ds = SynthSpec::new(1000, 25).sparsity(0.85).seed(1).generate();
        let want = compute_mi(&ds, Backend::BulkBitpack).unwrap();
        for kind in [ChunkGram::Bitpack, ChunkGram::Sparse] {
            let mut acc = StreamingAccumulator::new(25, kind).unwrap();
            for start in (0..1000).step_by(137) {
                let len = 137.min(1000 - start);
                acc.push_chunk(&ds.row_chunk(start, len).unwrap()).unwrap();
            }
            assert_eq!(acc.n_rows(), 1000);
            let got = acc.finalize().unwrap();
            assert_eq!(got.max_abs_diff(&want), 0.0, "{kind:?}");
        }
    }

    #[test]
    fn single_row_chunks_work() {
        let ds = SynthSpec::new(60, 8).sparsity(0.5).seed(2).generate();
        let want = compute_mi(&ds, Backend::BulkBitpack).unwrap();
        let mut acc = StreamingAccumulator::new(8, ChunkGram::Bitpack).unwrap();
        for r in 0..60 {
            acc.push_chunk(&ds.row_chunk(r, 1).unwrap()).unwrap();
        }
        assert_eq!(acc.n_chunks(), 60);
        assert_eq!(acc.finalize().unwrap().max_abs_diff(&want), 0.0);
    }

    #[test]
    fn snapshot_is_progressive() {
        let ds = SynthSpec::new(400, 6).sparsity(0.6).seed(3).plant(0, 5, 0.0).generate();
        let mut acc = StreamingAccumulator::new(6, ChunkGram::Bitpack).unwrap();
        acc.push_chunk(&ds.row_chunk(0, 200).unwrap()).unwrap();
        let early = acc.snapshot().unwrap();
        acc.push_chunk(&ds.row_chunk(200, 200).unwrap()).unwrap();
        let late = acc.snapshot().unwrap();
        // the planted copy is visible in both snapshots
        assert!(early.get(0, 5) > 0.5);
        assert!(late.get(0, 5) > 0.5);
        // final equals monolithic
        let want = compute_mi(&ds, Backend::BulkBitpack).unwrap();
        assert_eq!(late.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn shape_errors() {
        assert!(StreamingAccumulator::new(0, ChunkGram::Bitpack).is_err());
        let mut acc = StreamingAccumulator::new(5, ChunkGram::Bitpack).unwrap();
        let bad = SynthSpec::new(10, 4).seed(4).generate();
        assert!(acc.push_chunk(&bad).is_err());
        assert!(acc.snapshot().is_err()); // nothing ingested yet
    }
}
