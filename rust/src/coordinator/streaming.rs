//! Streaming ingestion: compute bulk MI over a dataset that arrives as
//! row chunks (a log stream, a sequencing run, a crawler) without ever
//! materializing all rows.
//!
//! Works because the optimized algorithm's sufficient statistics —
//! `(G11, colsums, n)` — are sums over rows: each chunk contributes its
//! partial Gram and counts, and the combine runs once at the end.
//! Peak memory is one chunk + the m x m accumulator, independent of the
//! total row count.

use super::planner::plan_blocks;
use crate::data::dataset::BinaryDataset;
use crate::linalg::dense::Mat64;
use crate::mi::sink::MiSink;
use crate::mi::MiMatrix;
use crate::util::error::{Error, Result};

/// Which substrate computes each chunk's partial Gram.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkGram {
    /// Bit-packed AND+popcount (default; fastest at typical sparsity).
    Bitpack,
    /// CSR row-pair expansion (fastest at very high sparsity).
    Sparse,
}

/// Accumulates sufficient statistics chunk by chunk.
#[derive(Debug)]
pub struct StreamingAccumulator {
    m: usize,
    kind: ChunkGram,
    g11: Mat64,
    colsums: Vec<f64>,
    n_rows: u64,
    n_chunks: u64,
}

impl StreamingAccumulator {
    /// `m`: number of variables every chunk must have.
    pub fn new(m: usize, kind: ChunkGram) -> Result<Self> {
        if m == 0 {
            return Err(Error::Shape("zero columns".into()));
        }
        Ok(StreamingAccumulator {
            m,
            kind,
            g11: Mat64::zeros(m, m),
            colsums: vec![0.0; m],
            n_rows: 0,
            n_chunks: 0,
        })
    }

    pub fn n_rows(&self) -> u64 {
        self.n_rows
    }

    pub fn n_chunks(&self) -> u64 {
        self.n_chunks
    }

    /// Ingest one chunk of rows (any chunk size, including 1).
    pub fn push_chunk(&mut self, chunk: &BinaryDataset) -> Result<()> {
        if chunk.n_cols() != self.m {
            return Err(Error::Shape(format!(
                "chunk has {} columns, accumulator expects {}",
                chunk.n_cols(),
                self.m
            )));
        }
        let (g, counts) = match self.kind {
            ChunkGram::Bitpack => {
                let bits = chunk.to_bitmatrix();
                (bits.gram(), bits.col_counts())
            }
            ChunkGram::Sparse => {
                let csr = chunk.to_csr();
                (csr.gram(), csr.col_counts())
            }
        };
        for (acc, v) in self.g11.data_mut().iter_mut().zip(g.data()) {
            *acc += v;
        }
        for (acc, &c) in self.colsums.iter_mut().zip(&counts) {
            *acc += c as f64;
        }
        self.n_rows += chunk.n_rows() as u64;
        self.n_chunks += 1;
        Ok(())
    }

    /// Current MI estimate over everything ingested so far (can be
    /// called repeatedly; does not consume the accumulator).
    pub fn snapshot(&self) -> Result<MiMatrix> {
        self.snapshot_measure(crate::mi::measure::CombineKind::Mi)
    }

    /// [`Self::snapshot`] under any association measure: the streamed
    /// sufficient statistics `(G11, colsums, n)` determine every 2x2
    /// measure, so a stream can end in φ or Jaccard as cheaply as MI.
    pub fn snapshot_measure(&self, measure: crate::mi::measure::CombineKind) -> Result<MiMatrix> {
        if self.n_rows == 0 {
            return Err(Error::Shape("no rows ingested".into()));
        }
        Ok(MiMatrix::from_mat(crate::mi::measure::combine_block(
            measure,
            &self.g11,
            &self.colsums,
            &self.colsums,
            self.n_rows as f64,
        )))
    }

    /// Final MI matrix.
    pub fn finalize(self) -> Result<MiMatrix> {
        self.snapshot()
    }

    /// Stream the accumulated statistics through a [`MiSink`] in
    /// `block_cols`-sized tiles (0 = one block) *without* materializing
    /// the m x m MI matrix: each tile is combined from the `(G11,
    /// colsums, n)` sufficient statistics and handed to the sink — so a
    /// stream can end in a top-k list or a sparse edge set directly.
    /// Bit-identical to extracting from [`Self::snapshot`].
    ///
    /// The caller still invokes `sink.finish()` (sinks may be fed from
    /// several accumulators before finishing).
    pub fn drain_into(&self, sink: &mut dyn MiSink, block_cols: usize) -> Result<()> {
        self.drain_into_measure(sink, block_cols, crate::mi::measure::CombineKind::Mi)
    }

    /// [`Self::drain_into`] under any association measure: the sink
    /// ranks/thresholds in the measure's units, still without ever
    /// materializing the m x m matrix.
    pub fn drain_into_measure(
        &self,
        sink: &mut dyn MiSink,
        block_cols: usize,
        measure: crate::mi::measure::CombineKind,
    ) -> Result<()> {
        if self.n_rows == 0 {
            return Err(Error::Shape("no rows ingested".into()));
        }
        let plan = plan_blocks(self.m, block_cols)?;
        let n = self.n_rows as f64;
        for t in &plan.tasks {
            let mut g = Mat64::zeros(t.a_len, t.b_len);
            for i in 0..t.a_len {
                for j in 0..t.b_len {
                    g.set(i, j, self.g11.get(t.a_start + i, t.b_start + j));
                }
            }
            let ca = &self.colsums[t.a_start..t.a_start + t.a_len];
            let cb = &self.colsums[t.b_start..t.b_start + t.b_len];
            let block = crate::mi::measure::combine_block(measure, &g, ca, cb, n);
            sink.consume_block(t, &block)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::mi::backend::{compute_mi, Backend};

    #[test]
    fn chunked_equals_monolithic_bit_for_bit() {
        let ds = SynthSpec::new(1000, 25).sparsity(0.85).seed(1).generate();
        let want = compute_mi(&ds, Backend::BulkBitpack).unwrap();
        for kind in [ChunkGram::Bitpack, ChunkGram::Sparse] {
            let mut acc = StreamingAccumulator::new(25, kind).unwrap();
            for start in (0..1000).step_by(137) {
                let len = 137.min(1000 - start);
                acc.push_chunk(&ds.row_chunk(start, len).unwrap()).unwrap();
            }
            assert_eq!(acc.n_rows(), 1000);
            let got = acc.finalize().unwrap();
            assert_eq!(got.max_abs_diff(&want), 0.0, "{kind:?}");
        }
    }

    #[test]
    fn single_row_chunks_work() {
        let ds = SynthSpec::new(60, 8).sparsity(0.5).seed(2).generate();
        let want = compute_mi(&ds, Backend::BulkBitpack).unwrap();
        let mut acc = StreamingAccumulator::new(8, ChunkGram::Bitpack).unwrap();
        for r in 0..60 {
            acc.push_chunk(&ds.row_chunk(r, 1).unwrap()).unwrap();
        }
        assert_eq!(acc.n_chunks(), 60);
        assert_eq!(acc.finalize().unwrap().max_abs_diff(&want), 0.0);
    }

    #[test]
    fn snapshot_is_progressive() {
        let ds = SynthSpec::new(400, 6).sparsity(0.6).seed(3).plant(0, 5, 0.0).generate();
        let mut acc = StreamingAccumulator::new(6, ChunkGram::Bitpack).unwrap();
        acc.push_chunk(&ds.row_chunk(0, 200).unwrap()).unwrap();
        let early = acc.snapshot().unwrap();
        acc.push_chunk(&ds.row_chunk(200, 200).unwrap()).unwrap();
        let late = acc.snapshot().unwrap();
        // the planted copy is visible in both snapshots
        assert!(early.get(0, 5) > 0.5);
        assert!(late.get(0, 5) > 0.5);
        // final equals monolithic
        let want = compute_mi(&ds, Backend::BulkBitpack).unwrap();
        assert_eq!(late.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn drain_into_sinks_matches_snapshot() {
        use crate::mi::sink::{MiSink, SinkData, ThresholdSink, TopKSink};
        use crate::mi::topk::{edges_above, top_k_pairs};

        let ds = SynthSpec::new(600, 14).sparsity(0.7).seed(5).plant(1, 8, 0.05).generate();
        let mut acc = StreamingAccumulator::new(14, ChunkGram::Bitpack).unwrap();
        for start in (0..600).step_by(101) {
            let len = 101.min(600 - start);
            acc.push_chunk(&ds.row_chunk(start, len).unwrap()).unwrap();
        }
        let full = acc.snapshot().unwrap();

        let mut topk = TopKSink::global(3);
        acc.drain_into(&mut topk, 4).unwrap();
        let SinkData::TopK(pairs) = topk.finish().unwrap().data else { panic!() };
        for (got, exp) in pairs.iter().zip(&top_k_pairs(&full, 3)) {
            assert_eq!((got.i, got.j), (exp.i, exp.j));
            assert_eq!(got.mi, exp.mi);
        }

        let mut thresh = ThresholdSink::by_mi(0.1);
        acc.drain_into(&mut thresh, 5).unwrap();
        let SinkData::Sparse(sp) = thresh.finish().unwrap().data else { panic!() };
        let want = edges_above(&full, 0.1);
        assert_eq!(sp.pairs.len(), want.len());
        for (got, exp) in sp.pairs.iter().zip(&want) {
            assert_eq!((got.i, got.j, got.mi), (exp.i, exp.j, exp.mi));
        }
    }

    #[test]
    fn snapshot_measure_matches_monolithic() {
        use crate::mi::backend::compute_measure;
        use crate::mi::measure::CombineKind;
        let ds = SynthSpec::new(500, 9).sparsity(0.8).seed(7).generate();
        let mut acc = StreamingAccumulator::new(9, ChunkGram::Bitpack).unwrap();
        for start in (0..500).step_by(173) {
            let len = 173.min(500 - start);
            acc.push_chunk(&ds.row_chunk(start, len).unwrap()).unwrap();
        }
        for measure in CombineKind::ALL {
            let got = acc.snapshot_measure(measure).unwrap();
            let want = compute_measure(&ds, Backend::BulkBitpack, measure).unwrap();
            assert_eq!(got.max_abs_diff(&want), 0.0, "{measure}");
        }
    }

    #[test]
    fn drained_blocks_bit_match_snapshot_for_every_measure() {
        use crate::mi::measure::CombineKind;
        use crate::mi::sink::{DenseSink, MiSink, SinkData};
        // snapshot (one monolithic combine) and drain (block-tiled
        // combines through a sink) must agree to the bit for every
        // measure — both run the same table-driven kernels over the
        // same streamed sufficient statistics
        let ds = SynthSpec::new(350, 13).sparsity(0.65).seed(11).plant(0, 9, 0.04).generate();
        let mut acc = StreamingAccumulator::new(13, ChunkGram::Bitpack).unwrap();
        for start in (0..350).step_by(97) {
            let len = 97.min(350 - start);
            acc.push_chunk(&ds.row_chunk(start, len).unwrap()).unwrap();
        }
        for measure in CombineKind::ALL {
            let want = acc.snapshot_measure(measure).unwrap();
            let mut dense = DenseSink::new(13);
            acc.drain_into_measure(&mut dense, 4, measure).unwrap();
            let SinkData::Dense(got) = dense.finish().unwrap().data else { panic!() };
            assert_eq!(got.max_abs_diff(&want), 0.0, "{measure}");
        }
    }

    #[test]
    fn drain_into_measure_ranks_by_the_selected_measure() {
        use crate::mi::measure::CombineKind;
        use crate::mi::sink::{SinkData, TopKSink};
        use crate::mi::topk::top_k_pairs;
        let ds = SynthSpec::new(400, 10).sparsity(0.6).seed(8).plant(2, 7, 0.03).generate();
        let mut acc = StreamingAccumulator::new(10, ChunkGram::Bitpack).unwrap();
        acc.push_chunk(&ds).unwrap();
        let full = acc.snapshot_measure(CombineKind::Jaccard).unwrap();
        let mut topk = TopKSink::global(3);
        acc.drain_into_measure(&mut topk, 4, CombineKind::Jaccard).unwrap();
        let SinkData::TopK(pairs) = topk.finish().unwrap().data else { panic!() };
        for (got, exp) in pairs.iter().zip(&top_k_pairs(&full, 3)) {
            assert_eq!((got.i, got.j), (exp.i, exp.j));
            assert_eq!(got.mi, exp.mi, "sink fed jaccard, not MI");
        }
    }

    #[test]
    fn shape_errors() {
        assert!(StreamingAccumulator::new(0, ChunkGram::Bitpack).is_err());
        let mut acc = StreamingAccumulator::new(5, ChunkGram::Bitpack).unwrap();
        let bad = SynthSpec::new(10, 4).seed(4).generate();
        assert!(acc.push_chunk(&bad).is_err());
        assert!(acc.snapshot().is_err()); // nothing ingested yet
    }
}
