//! Admission control for the job service: a counting semaphore built on
//! Mutex + Condvar (no `tokio` offline). `acquire` blocks, `try_acquire`
//! fails fast — the service uses the latter to shed load when the queue
//! is full, mirroring a serving router's backpressure behaviour.

use std::sync::{Arc, Condvar, Mutex};

/// Counting semaphore with RAII permits.
#[derive(Clone)]
pub struct Semaphore {
    inner: Arc<Inner>,
}

struct Inner {
    state: Mutex<usize>,
    cv: Condvar,
    capacity: usize,
}

/// RAII permit; releases on drop.
pub struct Permit {
    inner: Arc<Inner>,
}

impl Semaphore {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "semaphore capacity must be > 0");
        Semaphore {
            inner: Arc::new(Inner { state: Mutex::new(capacity), cv: Condvar::new(), capacity }),
        }
    }

    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    /// Currently available permits.
    pub fn available(&self) -> usize {
        *self.inner.state.lock().unwrap()
    }

    /// Block until a permit is available.
    pub fn acquire(&self) -> Permit {
        let mut avail = self.inner.state.lock().unwrap();
        while *avail == 0 {
            avail = self.inner.cv.wait(avail).unwrap();
        }
        *avail -= 1;
        Permit { inner: Arc::clone(&self.inner) }
    }

    /// Take a permit without blocking; `None` when saturated.
    pub fn try_acquire(&self) -> Option<Permit> {
        let mut avail = self.inner.state.lock().unwrap();
        if *avail == 0 {
            None
        } else {
            *avail -= 1;
            Some(Permit { inner: Arc::clone(&self.inner) })
        }
    }
}

impl Drop for Permit {
    fn drop(&mut self) {
        let mut avail = self.inner.state.lock().unwrap();
        *avail += 1;
        self.inner.cv.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn permits_count_down_and_restore() {
        let s = Semaphore::new(2);
        assert_eq!(s.available(), 2);
        let p1 = s.acquire();
        let p2 = s.try_acquire().unwrap();
        assert_eq!(s.available(), 0);
        assert!(s.try_acquire().is_none());
        drop(p1);
        assert_eq!(s.available(), 1);
        drop(p2);
        assert_eq!(s.available(), 2);
    }

    #[test]
    fn acquire_blocks_until_release() {
        let s = Semaphore::new(1);
        let p = s.acquire();
        let s2 = s.clone();
        let handle = std::thread::spawn(move || {
            let _p = s2.acquire(); // blocks until main drops
            true
        });
        std::thread::sleep(Duration::from_millis(20));
        assert!(!handle.is_finished(), "acquire should still be blocked");
        drop(p);
        assert!(handle.join().unwrap());
    }

    #[test]
    #[should_panic(expected = "capacity must be > 0")]
    fn zero_capacity_panics() {
        let _ = Semaphore::new(0);
    }
}
