//! The cluster coordinator: shard a block plan across workers, merge
//! sink states, retry on death.
//!
//! The coordinator never reads the dataset itself — it resolves the
//! run exactly once (backend, measure, block width), connects to each
//! worker, and drives one in-flight task per connection. Tasks come
//! from [`shard_tasks`] affinity queues cut over the schedule order,
//! so each worker's preferred run keeps whatever locality the policy
//! established; a worker whose queue runs dry steals from the deepest
//! remaining queue, and a worker that dies (dropped connection, or
//! [`DEATH_TIMEOUT`](super::messages::DEATH_TIMEOUT) with neither
//! result nor heartbeat) has its in-flight task re-queued for the
//! survivors. Gram blocks are pure functions of the input, so a retry
//! recomputes the identical cells — the audit trail lands in
//! [`ClusterReport`], correctness never depends on it.
//!
//! Each connection thread feeds results into its *own* shard sink
//! (built from the same [`SinkSpec`] as the run); finished shard
//! states fold into the primary through [`MiSink::merge`] after every
//! thread joins. Exactly-once cell coverage (each task completes on
//! exactly one worker) plus partition-independent sink state is what
//! makes the merged output bit-identical to a single-process run.

use super::messages::{
    read_frame, write_frame, FromWorker, JobDesc, ToWorker, DEATH_TIMEOUT,
};
use crate::coordinator::planner::{BlockPlan, BlockTask};
use crate::coordinator::scheduler::shard_tasks;
use crate::linalg::dense::Mat64;
use crate::mi::backend::Backend;
use crate::mi::measure::CombineKind;
use crate::mi::sink::{ClusterReport, SinkData, SinkOutput, SinkSpec};
use crate::util::error::{Error, Result};
use std::collections::VecDeque;
use std::net::TcpStream;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// One distributed run, fully resolved. The caller owns resolution
/// (`auto` must already be probed down to a native backend) and task
/// ordering (`plan.tasks` is dispatched in the order given).
pub struct ClusterRun<'a> {
    /// Worker addresses (`host:port`), one connection each.
    pub workers: &'a [String],
    /// Resolved native backend every worker computes with.
    pub backend: Backend,
    pub measure: CombineKind,
    /// The shared plan; workers rebuild it from `plan.block`.
    pub plan: &'a BlockPlan,
    /// Row count of the dataset (sink construction + hello check).
    pub n_rows: usize,
    pub sink: &'a SinkSpec,
}

/// Shared dispatch state: affinity queues, the retry pool, and the
/// run's completion / failure accounting.
struct Dispatch {
    shards: Vec<VecDeque<BlockTask>>,
    retry: VecDeque<BlockTask>,
    /// Tasks not yet completed anywhere (in a queue, or in flight).
    remaining: usize,
    retried: u64,
    failures: u64,
    /// A worker reported a systematic error: abort, don't retry.
    fatal: Option<Error>,
}

impl Dispatch {
    /// Next task for worker `me`: retries first (they are the oldest
    /// work), then the own affinity queue, then steal from the deepest
    /// other queue — from its *back*, where the locality loss is
    /// smallest.
    fn next_task(&mut self, me: usize) -> Option<BlockTask> {
        if let Some(t) = self.retry.pop_front() {
            return Some(t);
        }
        if let Some(t) = self.shards[me].pop_front() {
            return Some(t);
        }
        let victim = (0..self.shards.len())
            .filter(|&i| i != me)
            .max_by_key(|&i| self.shards[i].len())
            .filter(|&i| !self.shards[i].is_empty())?;
        self.shards[victim].pop_back()
    }
}

/// Execute `run` across its workers and return the merged output with
/// [`ClusterReport`] filled in. Errors when a worker address cannot be
/// dialed or handshaken (a config problem, before any work starts),
/// when a worker reports a fatal error, or when every worker has died
/// with tasks unfinished.
pub fn run_cluster(run: &ClusterRun<'_>) -> Result<SinkOutput> {
    if run.workers.is_empty() {
        return Err(Error::Coordinator("cluster run needs at least one worker".into()));
    }
    if run.backend == Backend::Auto || !run.backend.is_native() {
        return Err(Error::Coordinator(format!(
            "cluster runs need a resolved native backend, not '{}'",
            run.backend
        )));
    }
    let m = run.plan.m;
    let job = JobDesc {
        backend: run.backend.name().to_string(),
        measure: run.measure.name().to_string(),
        block_cols: run.plan.block,
        n_rows: run.n_rows,
        n_cols: m,
    };
    // connect + handshake every worker up front: an unreachable or
    // mismatched worker is a configuration error, not a retry case
    let mut conns = Vec::with_capacity(run.workers.len());
    for addr in run.workers {
        conns.push(connect(addr, &job)?);
    }

    let total = run.plan.tasks.len();
    let state = Mutex::new(Dispatch {
        shards: shard_tasks(&run.plan.tasks, conns.len()).into_iter().map(Into::into).collect(),
        retry: VecDeque::new(),
        remaining: total,
        retried: 0,
        failures: 0,
        fatal: None,
    });
    let cv = Condvar::new();

    let shard_results: Vec<Result<SinkData>> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(conns.len());
        for (me, conn) in conns.into_iter().enumerate() {
            let state = &state;
            let cv = &cv;
            let spec = shard_spec(run.sink, me);
            let (n_rows, measure) = (run.n_rows, run.measure);
            handles.push(scope.spawn(move || {
                let mut sink = spec.build_for(m, n_rows, measure)?;
                shard_loop(me, conn, sink.as_mut(), state, cv);
                Ok(sink.finish()?.data)
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(Error::Coordinator("cluster connection thread panicked".into()))))
            .collect()
    });

    let mut st = state.into_inner().map_err(|_| Error::Coordinator("dispatch state poisoned".into()))?;
    if let Some(e) = st.fatal.take() {
        return Err(e);
    }
    if st.remaining > 0 {
        return Err(Error::Coordinator(format!(
            "all {} workers died with {} of {total} tasks unfinished ({} retried)",
            run.workers.len(),
            st.remaining,
            st.retried
        )));
    }
    let mut primary = run.sink.build_for(m, run.n_rows, run.measure)?;
    for data in shard_results {
        primary.merge(data?)?;
    }
    let mut out = primary.finish()?;
    out.meta.cluster = Some(ClusterReport {
        workers: run.workers.len(),
        tasks: total,
        retried: st.retried,
        worker_failures: st.failures,
    });
    Ok(out)
}

/// Shard sinks must not collide on shared resources: a spill run gives
/// each shard its own sub-directory (merge adopts the tiles and
/// removes it); every other sink kind is pure in-memory state.
fn shard_spec(spec: &SinkSpec, me: usize) -> SinkSpec {
    match spec {
        SinkSpec::Spill { dir } => SinkSpec::Spill { dir: dir.join(format!("shard-{me}")) },
        other => other.clone(),
    }
}

fn connect(addr: &str, job: &JobDesc) -> Result<TcpStream> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| Error::Coordinator(format!("cannot reach worker {addr}: {e}")))?;
    stream.set_nodelay(true).ok();
    // heartbeats arrive every second; silence for DEATH_TIMEOUT means
    // the worker is gone, not slow
    stream.set_read_timeout(Some(DEATH_TIMEOUT))?;
    match FromWorker::parse(&read_frame(&mut stream)?)? {
        FromWorker::Hello { n_rows, n_cols } => {
            if (n_rows, n_cols) != (job.n_rows, job.n_cols) {
                return Err(Error::Shape(format!(
                    "worker {addr} serves a {n_rows}x{n_cols} input but the run is \
                     {}x{} — point every worker at the same file",
                    job.n_rows, job.n_cols
                )));
            }
        }
        other => {
            return Err(Error::Coordinator(format!(
                "worker {addr} opened with {other:?} instead of hello"
            )))
        }
    }
    write_frame(&mut stream, &ToWorker::Job(job.clone()).to_json())?;
    Ok(stream)
}

/// Drive one worker connection until the run completes, a fatal error
/// aborts it, or this worker dies. The shard sink accumulates every
/// result this connection delivered; on death the in-flight task goes
/// back to the pool and the sink's completed state still merges.
fn shard_loop(
    me: usize,
    mut conn: TcpStream,
    sink: &mut dyn crate::mi::sink::MiSink,
    state: &Mutex<Dispatch>,
    cv: &Condvar,
) {
    let mut next_id: u64 = (me as u64) << 32;
    loop {
        // acquire a task (or learn the run is over)
        let task = {
            let mut st = match state.lock() {
                Ok(g) => g,
                Err(_) => return,
            };
            loop {
                if st.fatal.is_some() || st.remaining == 0 {
                    let _ = write_frame(&mut conn, &ToWorker::Shutdown.to_json());
                    return;
                }
                if let Some(t) = st.next_task(me) {
                    break t;
                }
                // every queue is empty but tasks are in flight on other
                // workers — one of them may die and re-queue, so wait
                let (g, _) = match cv.wait_timeout(st, Duration::from_millis(100)) {
                    Ok(r) => r,
                    Err(_) => return,
                };
                st = g;
            }
        };

        next_id += 1;
        match attempt(&mut conn, next_id, &task) {
            Ok(block) => {
                let consumed = sink.consume_block(&task, &block);
                let mut st = match state.lock() {
                    Ok(g) => g,
                    Err(_) => return,
                };
                match consumed {
                    Ok(()) => st.remaining -= 1,
                    Err(e) => {
                        st.fatal.get_or_insert(e);
                    }
                }
                cv.notify_all();
            }
            Err(Attempt::Fatal(e)) => {
                if let Ok(mut st) = state.lock() {
                    st.fatal.get_or_insert(e);
                    cv.notify_all();
                }
                return;
            }
            Err(Attempt::Dead(e)) => {
                // the worker is gone: re-queue the in-flight task for
                // the survivors and fold this shard's completed results
                crate::warn_!("cluster worker {me} died mid-run ({e}); re-queueing task");
                if let Ok(mut st) = state.lock() {
                    st.retry.push_back(task);
                    st.retried += 1;
                    st.failures += 1;
                    cv.notify_all();
                }
                return;
            }
        }
    }
}

enum Attempt {
    /// The connection failed or misbehaved: retry the task elsewhere.
    Dead(Error),
    /// The worker reported a systematic failure: abort the run.
    Fatal(Error),
}

fn attempt(conn: &mut TcpStream, id: u64, task: &BlockTask) -> std::result::Result<Mat64, Attempt> {
    write_frame(conn, &ToWorker::Task { id, task: *task }.to_json()).map_err(Attempt::Dead)?;
    loop {
        // a read error here is either death (EOF / reset) or silence
        // past DEATH_TIMEOUT (the socket's read timeout) — both Dead
        let frame = read_frame(conn).map_err(Attempt::Dead)?;
        match FromWorker::parse(&frame).map_err(Attempt::Dead)? {
            FromWorker::Heartbeat => continue,
            FromWorker::Result { id: got, rows, cols, data } => {
                if got != id || (rows, cols) != (task.a_len, task.b_len) {
                    return Err(Attempt::Dead(Error::Coordinator(format!(
                        "worker answered task {id} ({}x{}) with id {got} ({rows}x{cols})",
                        task.a_len, task.b_len
                    ))));
                }
                return Mat64::from_vec(rows, cols, data).map_err(Attempt::Dead);
            }
            FromWorker::Error { message } => {
                return Err(Attempt::Fatal(Error::Coordinator(format!(
                    "worker failed: {message}"
                ))))
            }
            FromWorker::Hello { .. } => {
                return Err(Attempt::Dead(Error::Coordinator(
                    "unexpected hello mid-run".into(),
                )))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::{compute_source, NativeKind};
    use crate::coordinator::planner::plan_blocks;
    use crate::coordinator::scheduler::{order_tasks, Schedule};
    use crate::data::colstore::InMemorySource;
    use crate::data::synth::SynthSpec;
    use crate::mi::sink::SinkData;
    use std::net::TcpListener;

    /// Spawn `k` in-process workers on loopback and return their
    /// addresses plus the serving threads.
    fn spawn_workers(
        scope_src: &'static InMemorySource,
        k: usize,
    ) -> (Vec<String>, Vec<std::thread::JoinHandle<Result<()>>>) {
        let mut addrs = Vec::new();
        let mut threads = Vec::new();
        for _ in 0..k {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            addrs.push(l.local_addr().unwrap().to_string());
            threads.push(std::thread::spawn(move || {
                let (stream, _) = l.accept().map_err(Error::Io)?;
                super::super::worker::serve_conn(stream, scope_src)
            }));
        }
        (addrs, threads)
    }

    fn leak_source(rows: usize, cols: usize, seed: u64) -> &'static InMemorySource {
        let ds = SynthSpec::new(rows, cols).sparsity(0.85).seed(seed).generate();
        Box::leak(Box::new(InMemorySource::new(&ds)))
    }

    #[test]
    fn two_workers_match_single_process_on_every_native_backend() {
        let src = leak_source(300, 24, 7);
        for (backend, kind) in [
            (Backend::BulkBitpack, NativeKind::Bitpack),
            (Backend::BulkOpt, NativeKind::Dense),
            (Backend::BulkSparse, NativeKind::Sparse),
        ] {
            let reference = compute_source(src, kind, 1, CombineKind::Mi).unwrap();
            let mut plan = plan_blocks(24, 8).unwrap();
            order_tasks(&mut plan.tasks, Schedule::LargestFirst);
            let (addrs, threads) = spawn_workers(src, 2);
            let out = run_cluster(&ClusterRun {
                workers: &addrs,
                backend,
                measure: CombineKind::Mi,
                plan: &plan,
                n_rows: 300,
                sink: &SinkSpec::Dense,
            })
            .unwrap();
            for t in threads {
                t.join().unwrap().unwrap();
            }
            let report = out.meta.cluster.clone().unwrap();
            assert_eq!(report.workers, 2);
            assert_eq!(report.tasks, plan.tasks.len());
            assert_eq!(report.retried, 0);
            let SinkData::Dense(mi) = out.data else { panic!("dense run") };
            for i in 0..24 {
                for j in 0..24 {
                    assert_eq!(
                        mi.get(i, j).to_bits(),
                        reference.get(i, j).to_bits(),
                        "{backend}: cell ({i},{j}) must be bit-identical"
                    );
                }
            }
        }
    }

    #[test]
    fn topk_sink_matches_single_process_exactly() {
        use crate::mi::topk::top_k_pairs;
        let src = leak_source(250, 20, 11);
        let reference = compute_source(src, NativeKind::Bitpack, 1, CombineKind::Mi).unwrap();
        let want = top_k_pairs(&reference, 6);
        let mut plan = plan_blocks(20, 6).unwrap();
        order_tasks(&mut plan.tasks, Schedule::LargestFirst);
        let (addrs, threads) = spawn_workers(src, 2);
        let out = run_cluster(&ClusterRun {
            workers: &addrs,
            backend: Backend::BulkBitpack,
            measure: CombineKind::Mi,
            plan: &plan,
            n_rows: 250,
            sink: &SinkSpec::TopK { k: 6, per_column: false },
        })
        .unwrap();
        for t in threads {
            t.join().unwrap().unwrap();
        }
        let SinkData::TopK(got) = out.data else { panic!("topk run") };
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!((g.i, g.j, g.mi.to_bits()), (w.i, w.j, w.mi.to_bits()));
        }
    }

    /// A worker that handshakes, accepts exactly one task, and drops
    /// the connection with it in flight — a deterministic stand-in for
    /// a SIGKILLed process (the e2e suite kills a real one).
    fn spawn_dying_worker(
        src: &'static InMemorySource,
    ) -> (String, std::thread::JoinHandle<()>) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        let t = std::thread::spawn(move || {
            let (mut stream, _) = l.accept().unwrap();
            let hello =
                FromWorker::Hello { n_rows: src.n_rows(), n_cols: src.n_cols() };
            write_frame(&mut stream, &hello.to_json()).unwrap();
            let _job = read_frame(&mut stream).unwrap();
            let _task = read_frame(&mut stream).unwrap();
            // die with the task accepted but unanswered
        });
        (addr, t)
    }

    #[test]
    fn dead_worker_task_is_retried_bit_identically() {
        let src = leak_source(280, 24, 13);
        let reference = compute_source(src, NativeKind::Bitpack, 1, CombineKind::Mi).unwrap();
        let mut plan = plan_blocks(24, 6).unwrap();
        order_tasks(&mut plan.tasks, Schedule::LargestFirst);
        let (mut addrs, threads) = spawn_workers(src, 1);
        let (dead_addr, dead_thread) = spawn_dying_worker(src);
        addrs.push(dead_addr);
        let out = run_cluster(&ClusterRun {
            workers: &addrs,
            backend: Backend::BulkBitpack,
            measure: CombineKind::Mi,
            plan: &plan,
            n_rows: 280,
            sink: &SinkSpec::Dense,
        })
        .unwrap();
        for t in threads {
            t.join().unwrap().unwrap();
        }
        dead_thread.join().unwrap();
        let report = out.meta.cluster.clone().unwrap();
        assert_eq!(report.worker_failures, 1, "exactly one worker died");
        assert!(report.retried >= 1, "the in-flight task must be re-queued");
        let SinkData::Dense(mi) = out.data else { panic!("dense run") };
        for i in 0..24 {
            for j in 0..24 {
                assert_eq!(
                    mi.get(i, j).to_bits(),
                    reference.get(i, j).to_bits(),
                    "retried cell ({i},{j}) must stay bit-identical"
                );
            }
        }
    }

    #[test]
    fn unreachable_worker_is_a_clean_config_error() {
        let plan = plan_blocks(8, 4).unwrap();
        let err = run_cluster(&ClusterRun {
            // reserved port on loopback nobody listens on
            workers: &["127.0.0.1:1".to_string()],
            backend: Backend::BulkBitpack,
            measure: CombineKind::Mi,
            plan: &plan,
            n_rows: 10,
            sink: &SinkSpec::Dense,
        })
        .unwrap_err();
        assert!(err.to_string().contains("cannot reach worker"), "{err}");
    }

    #[test]
    fn auto_backend_is_rejected() {
        let plan = plan_blocks(8, 4).unwrap();
        let err = run_cluster(&ClusterRun {
            workers: &["127.0.0.1:1".to_string()],
            backend: Backend::Auto,
            measure: CombineKind::Mi,
            plan: &plan,
            n_rows: 10,
            sink: &SinkSpec::Dense,
        })
        .unwrap_err();
        assert!(err.to_string().contains("resolved native backend"), "{err}");
    }
}
