//! Distributed block-plan execution: one coordinator, many workers.
//!
//! The paper's matrix formulation makes all-pairs MI a set of
//! independent Gram-block tasks, each a pure function of two column
//! blocks — embarrassingly parallel and idempotent. This module turns
//! that into a rack-scale path without giving up exactness: the
//! coordinator resolves the run once (backend, measure, block width —
//! the same descriptor `bulkmi resume` persists), shards the
//! schedule-ordered task list into per-worker affinity queues
//! ([`crate::coordinator::scheduler::shard_tasks`]), and drives one
//! in-flight task per `bulkmi worker` connection over the
//! length-prefixed JSON protocol in [`messages`]. Workers stream
//! their own column blocks from the shared input file (positioned
//! reads — no dataset broadcast), run the *single-process* compute
//! core ([`crate::coordinator::executor::compute_block`]) per task,
//! and ship the combined measure block back with every `f64`
//! round-tripping bit-exactly.
//!
//! Results land in per-connection shard sinks and fold into the
//! primary through [`crate::mi::sink::MiSink::merge`]; a worker that
//! dies (dropped connection or heartbeat silence) has its in-flight
//! task re-queued for the survivors. Because every task is
//! idempotent, sink state is partition-independent, and each cell
//! completes exactly once, the merged result is bit-identical to the
//! single-process run — retries are an audit number
//! ([`crate::mi::sink::ClusterReport`] in `SinkMeta`), not a
//! correctness concern.
//!
//! Entry points: `bulkmi worker --connect ADDR --input FILE` on each
//! machine, then `bulkmi compute --workers a:p,b:p ...` (or a job
//! request with a `"workers": "a:p,b:p"` string) at the coordinator.

pub mod coordinator;
pub mod messages;
pub mod worker;

pub use coordinator::{run_cluster, ClusterRun};
