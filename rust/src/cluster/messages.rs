//! The cluster wire protocol: length-prefixed JSON frames.
//!
//! Every message is one *frame*: a 4-byte little-endian `u32` byte
//! length followed by that many bytes of UTF-8 JSON, parsed with the
//! same serde-free [`crate::util::json`] reader the job server's wire
//! schema uses. JSON keeps the protocol debuggable (a frame body is
//! one readable object) and — because Rust's shortest `Display`
//! rendering of an `f64` parses back to the identical bits — lets
//! result blocks ship as plain number arrays without losing the
//! bit-exactness the distributed path promises. The rare non-finite
//! cell (a degenerate measure on a constant column) is not valid JSON
//! and travels as a `"bits:<hex>"` string instead.
//!
//! Direction and types (protocol `v1`):
//!
//! | direction | `type` | payload |
//! |-----------|--------|---------|
//! | worker → coordinator | `hello` | `n_rows`, `n_cols` of the worker's input |
//! | coordinator → worker | `job` | resolved `backend`, `measure`, `block_cols`, expected `n_rows`/`n_cols` |
//! | coordinator → worker | `task` | `id` plus the [`BlockTask`] coordinates |
//! | worker → coordinator | `result` | echoed `id`, block shape, row-major `data` |
//! | worker → coordinator | `heartbeat` | none (liveness while a task computes) |
//! | worker → coordinator | `error` | `message` (fatal: the run aborts, no retry) |
//! | coordinator → worker | `shutdown` | none (clean end of run) |
//!
//! The `job` frame is the same resolved descriptor `bulkmi resume`
//! persists in `job.toml`: backend and block width are fixed once at
//! the coordinator, so an `auto` run never re-probes per worker and
//! every worker rebuilds the exact same plan.

use crate::coordinator::planner::BlockTask;
use crate::util::error::{Error, Result};
use crate::util::json::{escape, Json};
use std::io::{Read, Write};
use std::time::Duration;

/// Cluster protocol version (the `"v"` field of every frame).
pub const PROTO_VERSION: u64 = 1;

/// How often a busy worker proves liveness.
pub const HEARTBEAT_INTERVAL: Duration = Duration::from_secs(1);

/// How long the coordinator waits without hearing *anything* (result
/// or heartbeat) before declaring a worker dead and re-queueing its
/// in-flight task. Ten missed heartbeats is unambiguous death, not a
/// long task.
pub const DEATH_TIMEOUT: Duration = Duration::from_secs(10);

/// Refuse frames above this size: the largest legitimate frame is a
/// result block, and a 256 MiB body is a 4M-cell f64 tile rendered at
/// maximum decimal width — far past any plan the block sizer emits.
const MAX_FRAME_BYTES: u32 = 256 << 20;

// ---------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------

/// Write one frame: `u32` little-endian length, then the JSON bytes.
pub fn write_frame(w: &mut impl Write, body: &str) -> Result<()> {
    let len = u32::try_from(body.len())
        .ok()
        .filter(|&l| l <= MAX_FRAME_BYTES)
        .ok_or_else(|| {
            Error::Coordinator(format!("cluster frame of {} bytes exceeds limit", body.len()))
        })?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(body.as_bytes())?;
    w.flush()?;
    Ok(())
}

/// Read one frame's JSON body. EOF mid-frame (a dead peer) surfaces as
/// the underlying [`Error::Io`].
pub fn read_frame(r: &mut impl Read) -> Result<String> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len);
    if len > MAX_FRAME_BYTES {
        return Err(Error::Parse(format!(
            "cluster frame announces {len} bytes (limit {MAX_FRAME_BYTES}) — corrupt stream?"
        )));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    String::from_utf8(body).map_err(|_| Error::Parse("cluster frame is not UTF-8".into()))
}

// ---------------------------------------------------------------------
// f64 encoding (bit-exact both ways)
// ---------------------------------------------------------------------

/// Render one cell: shortest round-trip decimal for finite values
/// (including `-0.0`, whose `"-0"` parses back to negative zero), a
/// quoted `bits:` hex bit pattern for the non-finite rest.
fn fmt_cell(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        format!("\"bits:{:016x}\"", v.to_bits())
    }
}

fn parse_cell(j: &Json) -> Result<f64> {
    match j {
        Json::Num(v) => Ok(*v),
        Json::Str(s) => {
            let hex = s
                .strip_prefix("bits:")
                .ok_or_else(|| Error::Parse(format!("bad cell encoding '{s}'")))?;
            let bits = u64::from_str_radix(hex, 16)
                .map_err(|_| Error::Parse(format!("bad cell bit pattern '{s}'")))?;
            Ok(f64::from_bits(bits))
        }
        _ => Err(Error::Parse("result cell must be a number or bits string".into())),
    }
}

// ---------------------------------------------------------------------
// typed messages
// ---------------------------------------------------------------------

/// The run descriptor the coordinator resolves exactly once and ships
/// to every worker — the wire twin of the `job.toml` resume descriptor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JobDesc {
    /// Resolved *native* backend name (never `auto`: the coordinator
    /// probes once; workers must not re-probe to different winners).
    pub backend: String,
    /// Measure name ([`crate::mi::measure::CombineKind::name`]).
    pub measure: String,
    /// Column-block width of the shared plan.
    pub block_cols: usize,
    /// Expected dataset shape — workers refuse a mismatched input file
    /// before any task runs.
    pub n_rows: usize,
    pub n_cols: usize,
}

/// Coordinator → worker messages.
#[derive(Clone, Debug, PartialEq)]
pub enum ToWorker {
    /// First frame after the worker's hello: the resolved run.
    Job(JobDesc),
    /// One block task to compute; `id` is echoed in the result.
    Task { id: u64, task: BlockTask },
    /// Clean end of run.
    Shutdown,
}

/// Worker → coordinator messages.
#[derive(Clone, Debug, PartialEq)]
pub enum FromWorker {
    /// First frame on connect: the shape of the worker's input file.
    Hello { n_rows: usize, n_cols: usize },
    /// The combined measure block for task `id`, row-major.
    Result { id: u64, rows: usize, cols: usize, data: Vec<f64> },
    /// Liveness while a long task computes.
    Heartbeat,
    /// Fatal worker-side failure: the coordinator aborts the run with
    /// this message instead of retrying (a systematic error would fail
    /// identically on every worker).
    Error { message: String },
}

fn field(doc: &Json, key: &str) -> Result<f64> {
    doc.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| Error::Parse(format!("cluster message needs numeric '{key}'")))
}

fn field_usize(doc: &Json, key: &str) -> Result<usize> {
    let v = field(doc, key)?;
    if !v.is_finite() || v < 0.0 || v.fract() != 0.0 || v > 9.0e15 {
        return Err(Error::Parse(format!(
            "cluster message key '{key}' must be a non-negative integer, got {v}"
        )));
    }
    Ok(v as usize)
}

fn field_str<'a>(doc: &'a Json, key: &str) -> Result<&'a str> {
    doc.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| Error::Parse(format!("cluster message needs string '{key}'")))
}

fn parse_envelope<'a>(doc: &'a Json) -> Result<&'a str> {
    let v = field(doc, "v")?;
    if v != PROTO_VERSION as f64 {
        return Err(Error::Parse(format!(
            "unsupported cluster protocol version {v} (this build speaks v{PROTO_VERSION})"
        )));
    }
    field_str(doc, "type")
}

impl ToWorker {
    pub fn to_json(&self) -> String {
        match self {
            ToWorker::Job(job) => format!(
                "{{\"v\":{PROTO_VERSION},\"type\":\"job\",\"backend\":\"{}\",\
                 \"measure\":\"{}\",\"block_cols\":{},\"n_rows\":{},\"n_cols\":{}}}",
                escape(&job.backend),
                escape(&job.measure),
                job.block_cols,
                job.n_rows,
                job.n_cols
            ),
            ToWorker::Task { id, task } => format!(
                "{{\"v\":{PROTO_VERSION},\"type\":\"task\",\"id\":{id},\
                 \"a_start\":{},\"a_len\":{},\"b_start\":{},\"b_len\":{}}}",
                task.a_start, task.a_len, task.b_start, task.b_len
            ),
            ToWorker::Shutdown => {
                format!("{{\"v\":{PROTO_VERSION},\"type\":\"shutdown\"}}")
            }
        }
    }

    pub fn parse(text: &str) -> Result<ToWorker> {
        let doc = Json::parse(text)?;
        match parse_envelope(&doc)? {
            "job" => Ok(ToWorker::Job(JobDesc {
                backend: field_str(&doc, "backend")?.to_string(),
                measure: field_str(&doc, "measure")?.to_string(),
                block_cols: field_usize(&doc, "block_cols")?,
                n_rows: field_usize(&doc, "n_rows")?,
                n_cols: field_usize(&doc, "n_cols")?,
            })),
            "task" => Ok(ToWorker::Task {
                id: field(&doc, "id")? as u64,
                task: BlockTask {
                    a_start: field_usize(&doc, "a_start")?,
                    a_len: field_usize(&doc, "a_len")?,
                    b_start: field_usize(&doc, "b_start")?,
                    b_len: field_usize(&doc, "b_len")?,
                },
            }),
            "shutdown" => Ok(ToWorker::Shutdown),
            other => Err(Error::Parse(format!("unknown coordinator message type '{other}'"))),
        }
    }
}

impl FromWorker {
    pub fn to_json(&self) -> String {
        match self {
            FromWorker::Hello { n_rows, n_cols } => format!(
                "{{\"v\":{PROTO_VERSION},\"type\":\"hello\",\"n_rows\":{n_rows},\
                 \"n_cols\":{n_cols}}}"
            ),
            FromWorker::Result { id, rows, cols, data } => {
                let mut out = String::with_capacity(data.len() * 20 + 80);
                out.push_str(&format!(
                    "{{\"v\":{PROTO_VERSION},\"type\":\"result\",\"id\":{id},\
                     \"rows\":{rows},\"cols\":{cols},\"data\":["
                ));
                for (k, v) in data.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    out.push_str(&fmt_cell(*v));
                }
                out.push_str("]}");
                out
            }
            FromWorker::Heartbeat => {
                format!("{{\"v\":{PROTO_VERSION},\"type\":\"heartbeat\"}}")
            }
            FromWorker::Error { message } => format!(
                "{{\"v\":{PROTO_VERSION},\"type\":\"error\",\"message\":\"{}\"}}",
                escape(message)
            ),
        }
    }

    pub fn parse(text: &str) -> Result<FromWorker> {
        let doc = Json::parse(text)?;
        match parse_envelope(&doc)? {
            "hello" => Ok(FromWorker::Hello {
                n_rows: field_usize(&doc, "n_rows")?,
                n_cols: field_usize(&doc, "n_cols")?,
            }),
            "result" => {
                let rows = field_usize(&doc, "rows")?;
                let cols = field_usize(&doc, "cols")?;
                let arr = doc
                    .get("data")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| Error::Parse("result message needs a 'data' array".into()))?;
                if arr.len() != rows * cols {
                    return Err(Error::Parse(format!(
                        "result data has {} cells for a {rows}x{cols} block",
                        arr.len()
                    )));
                }
                let data = arr.iter().map(parse_cell).collect::<Result<Vec<f64>>>()?;
                Ok(FromWorker::Result { id: field(&doc, "id")? as u64, rows, cols, data })
            }
            "heartbeat" => Ok(FromWorker::Heartbeat),
            "error" => Ok(FromWorker::Error { message: field_str(&doc, "message")?.to_string() }),
            other => Err(Error::Parse(format!("unknown worker message type '{other}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frames_round_trip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, "{\"a\":1}").unwrap();
        write_frame(&mut buf, "{}").unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap(), "{\"a\":1}");
        assert_eq!(read_frame(&mut r).unwrap(), "{}");
        // clean EOF (no more frames) is an Io error the caller maps
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn torn_frame_is_an_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, "{\"v\":1,\"type\":\"heartbeat\"}").unwrap();
        buf.truncate(buf.len() - 3);
        assert!(read_frame(&mut Cursor::new(buf)).is_err());
    }

    #[test]
    fn oversized_announcement_rejected() {
        let mut buf = (u32::MAX).to_le_bytes().to_vec();
        buf.extend_from_slice(b"xx");
        assert!(matches!(read_frame(&mut Cursor::new(buf)), Err(Error::Parse(_))));
    }

    #[test]
    fn to_worker_messages_round_trip() {
        let msgs = [
            ToWorker::Job(JobDesc {
                backend: "bulk-bitpack".into(),
                measure: "mi".into(),
                block_cols: 64,
                n_rows: 1000,
                n_cols: 256,
            }),
            ToWorker::Task {
                id: 7,
                task: BlockTask { a_start: 0, a_len: 64, b_start: 64, b_len: 32 },
            },
            ToWorker::Shutdown,
        ];
        for m in msgs {
            assert_eq!(ToWorker::parse(&m.to_json()).unwrap(), m, "{m:?}");
        }
    }

    #[test]
    fn from_worker_messages_round_trip_bit_exactly() {
        // finite values exercise shortest-Display round-tripping;
        // -0.0, NaN and infinities exercise the bits: escape hatch
        let data = vec![
            0.0,
            -0.0,
            1.0 / 3.0,
            0.123456789012345678,
            f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MIN_POSITIVE,
        ];
        let msg = FromWorker::Result { id: 3, rows: 2, cols: 4, data: data.clone() };
        let FromWorker::Result { id, rows, cols, data: got } =
            FromWorker::parse(&msg.to_json()).unwrap()
        else {
            panic!("wrong type");
        };
        assert_eq!((id, rows, cols), (3, 2, 4));
        let want: Vec<u64> = data.iter().map(|v| v.to_bits()).collect();
        let got: Vec<u64> = got.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want, "every cell must round-trip bit-identically");

        for m in [
            FromWorker::Hello { n_rows: 10, n_cols: 4 },
            FromWorker::Heartbeat,
            FromWorker::Error { message: "disk \"gone\"".into() },
        ] {
            assert_eq!(FromWorker::parse(&m.to_json()).unwrap(), m, "{m:?}");
        }
    }

    #[test]
    fn bad_version_type_and_shape_rejected() {
        assert!(ToWorker::parse("{\"v\":2,\"type\":\"shutdown\"}").is_err());
        assert!(ToWorker::parse("{\"v\":1,\"type\":\"warp\"}").is_err());
        assert!(ToWorker::parse("{\"type\":\"shutdown\"}").is_err());
        assert!(FromWorker::parse(
            "{\"v\":1,\"type\":\"result\",\"id\":0,\"rows\":2,\"cols\":2,\"data\":[1.0]}"
        )
        .is_err());
        assert!(FromWorker::parse(
            "{\"v\":1,\"type\":\"result\",\"id\":0,\"rows\":1,\"cols\":1,\"data\":[\"x\"]}"
        )
        .is_err());
    }
}
