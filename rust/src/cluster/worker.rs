//! The cluster worker: serve block tasks to one coordinator.
//!
//! `bulkmi worker --connect ADDR --input x.bmat` binds ADDR, accepts a
//! single coordinator connection, and then runs a strict loop: say
//! hello (input shape), receive the resolved job descriptor, and
//! compute each dispatched task with the *same* single-process core
//! ([`crate::coordinator::executor::compute_block`]) the local path
//! uses — which is what makes a sharded run bit-identical to a
//! monolithic one by construction. A `.bmat` v2 input is positioned-
//! read per task, so a worker touches only the column blocks of the
//! tasks it is handed, never the whole file.
//!
//! While a task computes, a background thread writes a heartbeat frame
//! every [`HEARTBEAT_INTERVAL`](super::messages::HEARTBEAT_INTERVAL)
//! so the coordinator can tell a long task from a dead worker. The
//! write side is shared through a mutex over a cloned stream handle;
//! the task loop owns the read side alone.

use super::messages::{
    read_frame, write_frame, FromWorker, ToWorker, HEARTBEAT_INTERVAL,
};
use crate::coordinator::executor::{compute_block, plan_inputs, NativeProvider};
use crate::mi::combine_kernels::LogTable;
use crate::coordinator::planner::plan_blocks;
use crate::data::colstore::ColumnSource;
use crate::mi::backend::Backend;
use crate::server::wire;
use crate::util::error::{Error, Result};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Bind `addr` and serve exactly one coordinator connection over
/// `input`, then return. Port 0 picks a free port (logged on bind).
pub fn serve(addr: &str, input: &Path) -> Result<()> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| Error::Coordinator(format!("worker cannot bind {addr}: {e}")))?;
    crate::info!(
        "worker listening on {} (input {})",
        listener.local_addr()?,
        input.display()
    );
    serve_listener(listener, input)
}

/// [`serve`] over an already-bound listener (tests and `cluster bench`
/// bind port 0 first so they know the address before spawning).
pub fn serve_listener(listener: TcpListener, input: &Path) -> Result<()> {
    let src = crate::server::open_source(input)?;
    let (stream, peer) = listener.accept()?;
    crate::info!("worker serving coordinator at {peer}");
    serve_conn(stream, &*src)
}

/// Serve one accepted coordinator connection from `src`. Public so
/// in-process tests and the scaling bench can run workers on threads
/// over any [`ColumnSource`] without touching the filesystem.
pub fn serve_conn(stream: TcpStream, src: &dyn ColumnSource) -> Result<()> {
    stream.set_nodelay(true).ok();
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let mut reader = stream;
    send(&writer, &FromWorker::Hello { n_rows: src.n_rows(), n_cols: src.n_cols() })?;

    // the first frame must be the resolved job descriptor
    let job = match ToWorker::parse(&read_frame(&mut reader)?)? {
        ToWorker::Job(job) => job,
        other => {
            return Err(Error::Coordinator(format!(
                "worker expected a job frame first, got {other:?}"
            )))
        }
    };
    // a failure from here on is reported to the coordinator as a fatal
    // error frame before the worker exits: a systematic problem (bad
    // descriptor, mismatched input) must abort the run, not retry
    match run_job(&writer, &mut reader, src, &job) {
        Ok(()) => Ok(()),
        Err(e) => {
            let _ = send(&writer, &FromWorker::Error { message: e.to_string() });
            Err(e)
        }
    }
}

fn run_job(
    writer: &Arc<Mutex<TcpStream>>,
    reader: &mut TcpStream,
    src: &dyn ColumnSource,
    job: &super::messages::JobDesc,
) -> Result<()> {
    if (job.n_rows, job.n_cols) != (src.n_rows(), src.n_cols()) {
        return Err(Error::Shape(format!(
            "worker input is {}x{} but the coordinator's dataset is {}x{} — \
             workers must share the coordinator's input file",
            src.n_rows(),
            src.n_cols(),
            job.n_rows,
            job.n_cols
        )));
    }
    let backend = wire::parse_native_backend(&job.backend)?;
    if backend == Backend::Auto {
        return Err(Error::Coordinator(
            "job descriptor names backend 'auto' — the coordinator must resolve \
             the backend once before dispatching"
                .into(),
        ));
    }
    let measure = wire::parse_measure(&job.measure)?;
    // the shared plan: same m, same block width -> same task set and,
    // through plan_inputs, the same column sums every worker computes
    let plan = plan_blocks(src.n_cols(), job.block_cols)?;
    let (n, colsums) = plan_inputs(src, &plan)?;
    // one log table per job, shared by every task this worker serves —
    // the cluster-side analogue of the executor's once-per-run build
    let lt = LogTable::new(src.n_rows());
    let provider = NativeProvider::new(src, backend.native_kind());

    // heartbeat: proves liveness while block_gram grinds
    let stop = Arc::new(AtomicBool::new(false));
    let hb = {
        let writer = Arc::clone(writer);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let slice = std::time::Duration::from_millis(50);
            'beat: loop {
                // sleep in short slices so a finished run joins fast
                let mut slept = std::time::Duration::ZERO;
                while slept < HEARTBEAT_INTERVAL {
                    if stop.load(Ordering::Relaxed) {
                        break 'beat;
                    }
                    std::thread::sleep(slice);
                    slept += slice;
                }
                if send(&writer, &FromWorker::Heartbeat).is_err() {
                    break; // coordinator gone; the task loop will see EOF
                }
            }
        })
    };

    let served = serve_tasks(writer, reader, &provider, &colsums, n, measure, &lt);
    stop.store(true, Ordering::Relaxed);
    let _ = hb.join();
    served
}

fn serve_tasks(
    writer: &Arc<Mutex<TcpStream>>,
    reader: &mut TcpStream,
    provider: &NativeProvider<'_>,
    colsums: &[f64],
    n: f64,
    measure: crate::mi::measure::CombineKind,
    lt: &LogTable,
) -> Result<()> {
    let mut served = 0u64;
    loop {
        match ToWorker::parse(&read_frame(reader)?)? {
            ToWorker::Task { id, task } => {
                if task.a_start + task.a_len > colsums.len()
                    || task.b_start + task.b_len > colsums.len()
                {
                    return Err(Error::Shape(format!(
                        "task {task:?} out of bounds for m = {}",
                        colsums.len()
                    )));
                }
                let block = compute_block(provider, &task, colsums, n, measure, lt)?;
                send(
                    writer,
                    &FromWorker::Result {
                        id,
                        rows: block.rows(),
                        cols: block.cols(),
                        data: block.data().to_vec(),
                    },
                )?;
                served += 1;
            }
            ToWorker::Shutdown => {
                crate::info!("worker done: served {served} tasks");
                return Ok(());
            }
            ToWorker::Job(_) => {
                return Err(Error::Coordinator("unexpected second job frame".into()))
            }
        }
    }
}

fn send(writer: &Arc<Mutex<TcpStream>>, msg: &FromWorker) -> Result<()> {
    let mut w = writer.lock().map_err(|_| {
        Error::Coordinator("worker write lock poisoned".into())
    })?;
    write_frame(&mut *w, &msg.to_json())
}
