//! Reproduces **Figure 3**: computation time vs dataset SPARSITY for the
//! four optimized implementations (paper: 100,000 x 1,000; sparsity
//! 50% -> 99.5%).
//!
//! Expected shape (the paper's key sparsity finding): the dense-substrate
//! implementations are ~flat across sparsity, while the sparse (CSR)
//! implementation's cost collapses as sparsity rises — orders of
//! magnitude — crossing below everything else at ≥99%.
//!
//! Default mode runs 20,000 rows (the CSR row-pair expansion at 50%
//! sparsity is the one genuinely expensive cell on one vCPU); the
//! relative shape is row-count independent. `BULKMI_BENCH_FULL=1`
//! restores the paper's 100,000.

use bulkmi::data::synth::SynthSpec;
use bulkmi::mi::backend::{compute_mi_with, Backend};
use bulkmi::util::bench::{
    emit_json, full_mode, measure, measure_result, print_header, print_row, Cell,
};

fn main() {
    const COLS: usize = 1000;
    let rows: usize = if full_mode() { 100_000 } else { 20_000 };
    let sparsities = [0.5, 0.9, 0.99, 0.995];
    let impls = [Backend::BulkOpt, Backend::BulkSparse, Backend::BulkBitpack, Backend::Xla];

    println!("=== Figure 3: time (s) vs sparsity ({rows} x {COLS}) ===\n");
    let headers: Vec<&str> = impls.iter().map(|b| b.name()).collect();
    print_header("sparsity", &headers);

    for &s in &sparsities {
        let ds = SynthSpec::new(rows, COLS).sparsity(s).seed(3).generate();
        let mut cells = Vec::new();
        for &b in &impls {
            let cell = if b == Backend::Xla {
                measure_result(b.name(), || compute_mi_with(&ds, b, 1))
            } else {
                Cell::Secs(measure(|| compute_mi_with(&ds, b, 1).unwrap()))
            };
            emit_json(
                "fig3_sparsity",
                &[("sparsity", format!("{s}")), ("impl", b.name().to_string())],
                &cell,
            );
            cells.push(cell);
        }
        print_row(&format!("{:.1}%", s * 100.0), &cells);
    }
    println!("\nexpected shape: dense/bitpack/xla ~flat vs sparsity; CSR drops");
    println!("by orders of magnitude and wins at >= 99% sparsity.");
}
