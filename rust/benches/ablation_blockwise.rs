//! Ablation B (ours): coordinator overhead. The blockwise plan exists
//! for memory-bounded execution (the paper's future-work feature); this
//! bench quantifies what it costs in time vs the monolithic run, across
//! block sizes — the overhead should be small (<~15%) at sane blocks,
//! and the memory savings are reported alongside.

use bulkmi::coordinator::executor::NativeKind;
use bulkmi::coordinator::planner::{dense_output_bytes, plan_blocks, task_bytes};
use bulkmi::coordinator::progress::Progress;
use bulkmi::coordinator::{run_plan, run_plan_dense, NativeProvider};
use bulkmi::data::synth::SynthSpec;
use bulkmi::mi::backend::{compute_mi_with, Backend};
use bulkmi::mi::measure::CombineKind;
use bulkmi::mi::sink::{MiSink, SinkSpec};
use bulkmi::util::bench::{emit_json, full_mode, measure, print_header, print_row, Cell};

fn main() {
    let (rows, cols) = if full_mode() { (100_000, 1_000) } else { (20_000, 1_000) };
    let ds = SynthSpec::new(rows, cols).sparsity(0.9).seed(11).generate();
    let blocks = [0usize, 512, 256, 128, 64, 32];

    println!("=== Ablation B: blockwise overhead ({rows} x {cols}, bitpack) ===\n");
    print_header("block cols", &["time (s)", "vs mono", "task MiB"]);

    let mono = measure(|| compute_mi_with(&ds, Backend::BulkBitpack, 1).unwrap());
    let provider = NativeProvider::new(&ds, NativeKind::Bitpack);
    for &b in &blocks {
        let (secs, label) = if b == 0 {
            (mono, "mono".to_string())
        } else {
            let plan = plan_blocks(cols, b).unwrap();
            let secs = measure(|| {
                let progress = Progress::new(plan.tasks.len());
                run_plan_dense(&ds, &plan, &provider, 1, &progress, CombineKind::Mi).unwrap()
            });
            (secs, b.to_string())
        };
        let overhead = secs / mono;
        let mib = if b == 0 {
            task_bytes(rows, cols) as f64 / (1 << 20) as f64
        } else {
            task_bytes(rows, b) as f64 / (1 << 20) as f64
        };
        let cells = [
            Cell::Secs(secs),
            Cell::Secs(overhead),
            Cell::Secs(mib),
        ];
        emit_json(
            "ablation_blockwise",
            &[("block", label.clone()), ("rows", rows.to_string())],
            &cells[0],
        );
        print_row(&label, &cells);
    }
    println!("\nexpected: overhead near 1.0x for blocks >= 128; working-set");
    println!("memory shrinks quadratically with block size.");

    // ---- sink ablation: what storing costs, vs what computing costs ----
    // Same engine, same blocks; only the sink changes. Peak result
    // state: dense = m^2 x 8 B; topk/threshold = O(k)/O(nnz) pairs.
    println!("\n=== sink ablation (block 256, bitpack) ===\n");
    print_header("m / sink", &["time (s)", "result MiB"]);
    let sink_specs = ["dense", "topk:1000", "threshold:0.01"];
    for &cols2 in &[1_000usize, 4_000] {
        let rows2 = 5_000;
        let ds2 = SynthSpec::new(rows2, cols2).sparsity(0.9).seed(12).generate();
        let provider2 = NativeProvider::new(&ds2, NativeKind::Bitpack);
        let plan2 = plan_blocks(cols2, 256).unwrap();
        for spec_str in sink_specs {
            let spec = SinkSpec::parse(spec_str).unwrap();
            let mut result_bytes = 0usize;
            let secs = measure(|| {
                let mut sink: Box<dyn MiSink> = spec.build(cols2, rows2).unwrap();
                let progress = Progress::new(plan2.tasks.len());
                run_plan(&ds2, &plan2, &provider2, 1, &progress, sink.as_mut(), CombineKind::Mi)
                    .unwrap();
                result_bytes = sink.finish().unwrap().state_bytes();
            });
            let mib = result_bytes as f64 / (1 << 20) as f64;
            let label = format!("{cols2}/{spec_str}");
            emit_json(
                "ablation_sinks",
                &[
                    ("cols", cols2.to_string()),
                    ("sink", spec_str.to_string()),
                    ("result_mib", format!("{mib:.3}")),
                ],
                &Cell::Secs(secs),
            );
            print_row(&label, &[Cell::Secs(secs), Cell::Secs(mib)]);
        }
        println!(
            "  (dense output for m={cols2}: {:.1} MiB; matrix-free sinks hold pairs only)",
            dense_output_bytes(cols2) as f64 / (1 << 20) as f64
        );
    }
    println!("\nexpected: near-identical time across sinks (compute dominates);");
    println!("result memory collapses from O(m^2) to O(k) for topk/threshold.");
}
