//! Ablation B (ours): coordinator overhead. The blockwise plan exists
//! for memory-bounded execution (the paper's future-work feature); this
//! bench quantifies what it costs in time vs the monolithic run, across
//! block sizes — the overhead should be small (<~15%) at sane blocks,
//! and the memory savings are reported alongside.

use bulkmi::coordinator::executor::NativeKind;
use bulkmi::coordinator::planner::{plan_blocks, task_bytes};
use bulkmi::coordinator::progress::Progress;
use bulkmi::coordinator::{execute_plan, NativeProvider};
use bulkmi::data::synth::SynthSpec;
use bulkmi::mi::backend::{compute_mi_with, Backend};
use bulkmi::util::bench::{emit_json, full_mode, measure, print_header, print_row, Cell};

fn main() {
    let (rows, cols) = if full_mode() { (100_000, 1_000) } else { (20_000, 1_000) };
    let ds = SynthSpec::new(rows, cols).sparsity(0.9).seed(11).generate();
    let blocks = [0usize, 512, 256, 128, 64, 32];

    println!("=== Ablation B: blockwise overhead ({rows} x {cols}, bitpack) ===\n");
    print_header("block cols", &["time (s)", "vs mono", "task MiB"]);

    let mono = measure(|| compute_mi_with(&ds, Backend::BulkBitpack, 1).unwrap());
    let provider = NativeProvider::new(&ds, NativeKind::Bitpack);
    for &b in &blocks {
        let (secs, label) = if b == 0 {
            (mono, "mono".to_string())
        } else {
            let plan = plan_blocks(cols, b).unwrap();
            let secs = measure(|| {
                let progress = Progress::new(plan.tasks.len());
                execute_plan(&ds, &plan, &provider, 1, &progress).unwrap()
            });
            (secs, b.to_string())
        };
        let overhead = secs / mono;
        let mib = if b == 0 {
            task_bytes(rows, cols) as f64 / (1 << 20) as f64
        } else {
            task_bytes(rows, b) as f64 / (1 << 20) as f64
        };
        let cells = [
            Cell::Secs(secs),
            Cell::Secs(overhead),
            Cell::Secs(mib),
        ];
        emit_json(
            "ablation_blockwise",
            &[("block", label.clone()), ("rows", rows.to_string())],
            &cells[0],
        );
        print_row(&label, &cells);
    }
    println!("\nexpected: overhead near 1.0x for blocks >= 128; working-set");
    println!("memory shrinks quadratically with block size.");
}
