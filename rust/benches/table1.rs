//! Reproduces **Table 1**: running times for MI across implementations
//! on the paper's three dataset shapes (90% sparsity).
//!
//!   paper columns: SKL Pairwise | Bas-NN | Opt-NN | Opt-SS | Opt-T
//!   ours adds    : Opt-bitpack (native popcount) — see DESIGN.md §5
//!
//! Pairwise on the largest shape is *estimated* from a column subsample
//! (marked `*`; its cost is exactly quadratic in columns — the paper's
//! own number took 5211 s on an M2). `BULKMI_BENCH_FULL=1` measures it
//! outright.

use bulkmi::data::synth::{SynthSpec, TABLE1_SHAPES};
use bulkmi::mi::backend::{compute_mi_with, Backend};
use bulkmi::util::bench::{
    emit_json, estimate_pairwise, full_mode, measure, measure_result, print_header, print_row,
    Cell,
};

/// Paper's reported seconds for reference printing (M2, 12 cores).
const PAPER: [[f64; 5]; 3] = [
    [1.430, 0.001, 0.001, 0.001, 0.021],
    [54.389, 0.064, 0.013, 0.033, 0.061],
    [5211.830, 1.941, 0.676, 2.286, 0.086],
];

fn main() {
    println!("=== Table 1: running times (s), 90% sparse ===");
    println!("(cells marked * are estimated from a column subsample; paper values in parens)\n");
    let impls: Vec<Backend> = vec![
        Backend::Pairwise,
        Backend::BulkBasic,
        Backend::BulkOpt,
        Backend::BulkSparse,
        Backend::BulkBitpack,
        Backend::Xla,
    ];
    let headers: Vec<&str> = impls.iter().map(|b| b.name()).collect();
    print_header("rows x cols", &headers);

    for (shape_idx, &(rows, cols)) in TABLE1_SHAPES.iter().enumerate() {
        let ds = SynthSpec::new(rows, cols).sparsity(0.9).seed(42).generate();
        let mut cells = Vec::new();
        for &b in &impls {
            let cell = match b {
                Backend::Pairwise => {
                    // full pairwise on the largest dataset is ~10 min on
                    // this container: estimate unless FULL is set.
                    // cost = pair-count * rows ~= row-iterations (7 ns each);
                    // 1e9 keeps the direct cell under ~10 s.
                    let cost = (cols * cols) as f64 / 2.0 * rows as f64;
                    if full_mode() || cost <= 1e9 {
                        Cell::Secs(measure(|| compute_mi_with(&ds, b, 1).unwrap()))
                    } else {
                        Cell::Estimated(estimate_pairwise(&ds, 100))
                    }
                }
                Backend::Xla => measure_result(b.name(), || compute_mi_with(&ds, b, 1)),
                _ => Cell::Secs(measure(|| compute_mi_with(&ds, b, 1).unwrap())),
            };
            emit_json(
                "table1",
                &[
                    ("rows", rows.to_string()),
                    ("cols", cols.to_string()),
                    ("impl", b.name().to_string()),
                ],
                &cell,
            );
            cells.push(cell);
        }
        print_row(&format!("{rows}x{cols}"), &cells);
        // paper reference row
        print!("{:<18}", "  (paper)");
        for (k, _) in impls.iter().enumerate() {
            if k < 5 {
                print!(" {:>14}", format!("({})", PAPER[shape_idx][k]));
            } else {
                print!(" {:>14}", "");
            }
        }
        println!();
    }

    println!("\nexpected shape: pairwise >> basic > opt; sparse ~ opt at 90%;");
    println!("hardware-optimized (bitpack / xla) fastest at the largest shape.");
}
