//! Ablation A (ours): Gram-computation strategy. The whole paper rests
//! on "one Gram matmul is the entire cost" — this bench isolates that
//! operation across the four substrates plus the naive triple loop, so
//! the backend-level differences in Table 1 can be attributed. The
//! bit-packed substrate additionally gets one row per dispatchable
//! AND-popcount kernel, attributing the kernel-layer win separately
//! from the packing win (the dispatcher's own pick is `bitpack`).

use bulkmi::data::synth::SynthSpec;
use bulkmi::linalg::{blas, kernels};
use bulkmi::util::bench::{emit_json, full_mode, measure, print_header, print_row, Cell};

fn main() {
    let shapes: &[(usize, usize)] = if full_mode() {
        &[(10_000, 250), (20_000, 500), (50_000, 1_000), (100_000, 1_000)]
    } else {
        &[(10_000, 250), (20_000, 500), (50_000, 1_000)]
    };
    // bitpack-ref = pre-unroll popcount Gram (one output at a time);
    // bitpack = the 4-wide unroll on the dispatched kernel; bitpack/<k>
    // = the same loop pinned to each kernel. The ref/unroll pair is the
    // before/after record for the accumulator-unroll optimization, the
    // kernel rows for the hardware-adaptive kernel layer.
    let mut impls: Vec<String> = vec![
        "naive".into(),
        "blocked-f32".into(),
        "bitpack-ref".into(),
        "bitpack".into(),
    ];
    for k in kernels::available() {
        impls.push(format!("bitpack/{}", k.name()));
    }
    impls.push("csr".into());
    let impl_names: Vec<&str> = impls.iter().map(|s| s.as_str()).collect();

    println!("=== Ablation A: Gram strategies, time (s), 90% sparse ===");
    println!("{}\n", kernels::KernelDispatch::global().summary());
    print_header("rows x cols", &impl_names);

    for &(rows, cols) in shapes {
        let ds = SynthSpec::new(rows, cols).sparsity(0.9).seed(7).generate();
        let dense = ds.to_mat32();
        let bits = ds.to_bitmatrix();
        let csr = ds.to_csr();
        let mut cells = Vec::new();
        for name in &impl_names {
            let cell = match *name {
                // naive is O(m² n) with no blocking: cap to small shapes
                "naive" => {
                    if rows * cols * cols <= 10_000 * 250 * 250 * 4 {
                        Cell::Secs(measure(|| blas::gram_naive(&dense)))
                    } else {
                        Cell::Skipped
                    }
                }
                "blocked-f32" => Cell::Secs(measure(|| blas::gram(&dense))),
                "bitpack-ref" => Cell::Secs(measure(|| bits.gram_reference())),
                "bitpack" => Cell::Secs(measure(|| bits.gram())),
                "csr" => Cell::Secs(measure(|| csr.gram())),
                pinned => {
                    let kernel = pinned
                        .strip_prefix("bitpack/")
                        .and_then(kernels::by_name)
                        .expect("kernel row");
                    Cell::Secs(measure(|| bits.gram_with(kernel)))
                }
            };
            emit_json(
                "ablation_gram",
                &[
                    ("rows", rows.to_string()),
                    ("cols", cols.to_string()),
                    ("impl", name.to_string()),
                ],
                &cell,
            );
            cells.push(cell);
        }
        print_row(&format!("{rows}x{cols}"), &cells);
    }
    println!("\nexpected: blocked >> naive; bitpack fastest dense-substrate;");
    println!("bitpack vs bitpack-ref shows the 4-wide popcount unroll win;");
    println!("bitpack/<kernel> rows attribute the kernel-dispatch win;");
    println!("csr competitive only because 90% sparse keeps nnz² small.");
}
