//! Reproduces **Figure 2**: computation time vs number of COLUMNS
//! (rows fixed at 100,000; 90% sparsity). The quadratic term.
//!
//! Paper series: Bas-NN, Opt-NN, Opt-SS, Opt-T over 500..10,000 cols.
//! Default mode applies per-impl column caps (this container has one
//! vCPU vs the paper's 12-core M2; the caps keep `cargo bench` under
//! control and are lifted by `BULKMI_BENCH_FULL=1`). The crossover
//! shapes — opt ~3-4x under basic, sparse losing ground as columns
//! grow, the optimized framework scaling best — appear well inside the
//! capped range.

use bulkmi::data::synth::SynthSpec;
use bulkmi::mi::backend::{compute_mi_with, Backend};
use bulkmi::util::bench::{
    emit_json, full_mode, measure, measure_result, print_header, print_row, Cell,
};

fn main() {
    let rows: usize = if full_mode() { 100_000 } else { 100_000 };
    let col_points: &[usize] =
        if full_mode() { &[500, 1_000, 2_000, 5_000, 10_000] } else { &[500, 1_000, 2_000, 4_000] };
    let impls = [
        Backend::BulkBasic,
        Backend::BulkOpt,
        Backend::BulkSparse,
        Backend::BulkBitpack,
        Backend::Xla,
    ];
    // default caps per implementation (columns)
    let cap = |b: Backend| -> usize {
        if full_mode() {
            return usize::MAX;
        }
        match b {
            Backend::BulkBasic => 1_000,  // 4 dense Grams, no skip
            Backend::BulkOpt => 4_000,    // 1 dense Gram with zero-skip
            Backend::BulkSparse => 2_000, // nnz² row expansion
            Backend::BulkBitpack => 4_000,
            Backend::Xla => 2_000, // largest xgram-chunked width kept cheap
            _ => usize::MAX,
        }
    };

    println!("=== Figure 2: time (s) vs cols (rows = {rows}, 90% sparse) ===\n");
    let headers: Vec<&str> = impls.iter().map(|b| b.name()).collect();
    print_header("cols", &headers);

    for &cols in col_points {
        let ds = SynthSpec::new(rows, cols).sparsity(0.9).seed(2).generate();
        let mut cells = Vec::new();
        for &b in &impls {
            let cell = if cols > cap(b) {
                Cell::Skipped
            } else {
                if b == Backend::Xla {
                    measure_result(b.name(), || compute_mi_with(&ds, b, 1))
                } else {
                    Cell::Secs(measure(|| compute_mi_with(&ds, b, 1).unwrap()))
                }
            };
            emit_json(
                "fig2_cols",
                &[("cols", cols.to_string()), ("impl", b.name().to_string())],
                &cell,
            );
            cells.push(cell);
        }
        print_row(&cols.to_string(), &cells);
    }
    println!("\nexpected shape: quadratic growth in cols; opt ~3-4x under basic;");
    println!("sparse overhead grows; optimized framework scales best.");
}
