//! Reproduces **Figure 1**: computation time vs number of ROWS
//! (columns fixed at 1000, 90% sparsity) for the bulk implementations.
//!
//! Paper series: Bas-NN, Opt-NN, Opt-SS, Opt-T (pairwise excluded — it
//! is off the chart). We add Opt-bitpack. Expected shape: all grow
//! roughly linearly in rows; basic is the slowest; the hardware-
//! optimized framework is fastest at scale.

use bulkmi::data::synth::SynthSpec;
use bulkmi::mi::backend::{compute_mi_with, Backend};
use bulkmi::util::bench::{
    emit_json, full_mode, measure, measure_result, print_header, print_row, Cell,
};

fn main() {
    const COLS: usize = 1000;
    let row_points: &[usize] = if full_mode() {
        &[1_000, 5_000, 10_000, 20_000, 50_000, 100_000]
    } else {
        // default: same sweep shape, capped at 50k rows for the slow
        // basic series (documented in EXPERIMENTS.md)
        &[1_000, 5_000, 10_000, 20_000, 50_000, 100_000]
    };
    let impls = [
        Backend::BulkBasic,
        Backend::BulkOpt,
        Backend::BulkSparse,
        Backend::BulkBitpack,
        Backend::Xla,
    ];
    // default-mode caps: basic is O(4 dense Grams) with no sparsity skip
    let basic_cap = if full_mode() { usize::MAX } else { 50_000 };

    println!("=== Figure 1: time (s) vs rows (cols = {COLS}, 90% sparse) ===\n");
    let headers: Vec<&str> = impls.iter().map(|b| b.name()).collect();
    print_header("rows", &headers);

    for &rows in row_points {
        let ds = SynthSpec::new(rows, COLS).sparsity(0.9).seed(1).generate();
        let mut cells = Vec::new();
        for &b in &impls {
            let cell = if b == Backend::BulkBasic && rows > basic_cap {
                Cell::Skipped
            } else {
                if b == Backend::Xla {
                    measure_result(b.name(), || compute_mi_with(&ds, b, 1))
                } else {
                    Cell::Secs(measure(|| compute_mi_with(&ds, b, 1).unwrap()))
                }
            };
            emit_json(
                "fig1_rows",
                &[("rows", rows.to_string()), ("impl", b.name().to_string())],
                &cell,
            );
            cells.push(cell);
        }
        print_row(&rows.to_string(), &cells);
    }
    println!("\nexpected shape: ~linear growth in rows; basic slowest; optimized");
    println!("framework (xla/bitpack) fastest for large row counts.");
}
