//! Fault-injection suite for crash-resumable spill runs: a run killed
//! after K tiles must resume with zero completed tiles recomputed and
//! assemble bit-identically to an uninterrupted run, on every native
//! backend; a corrupted tile (truncation or bit flip) must be a clean
//! `Error::Parse` naming the tile, never a silently wrong matrix.

use bulkmi::coordinator::executor::{run_plan, GramProvider, NativeKind, NativeProvider};
use bulkmi::coordinator::planner::{plan_blocks, BlockTask};
use bulkmi::coordinator::progress::Progress;
use bulkmi::data::colstore::InMemorySource;
use bulkmi::data::dataset::BinaryDataset;
use bulkmi::data::synth::SynthSpec;
use bulkmi::linalg::dense::Mat64;
use bulkmi::mi::measure::CombineKind;
use bulkmi::mi::sink::{
    assemble_spilled, read_spill_manifest, MiSink, SinkOutput, TileSpillSink,
};
use bulkmi::mi::MiMatrix;
use bulkmi::util::error::{Error, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

const M: usize = 30;
const BLOCK: usize = 7;
const CRASH_AFTER: usize = 6;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bulkmi-faults-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn dataset() -> BinaryDataset {
    SynthSpec::new(380, M).sparsity(0.85).seed(77).plant(2, 19, 0.03).generate()
}

/// A sink wrapper that errors on the (K+1)-th block *before* delegating
/// — the injected crash: tile K+1 is never written, the manifest holds
/// exactly K rows and no completion trailer.
struct FaultSink {
    inner: TileSpillSink,
    remaining: usize,
}

impl MiSink for FaultSink {
    fn name(&self) -> &'static str {
        "fault"
    }

    fn consume_block(&mut self, t: &BlockTask, block: &Mat64) -> Result<()> {
        if self.remaining == 0 {
            return Err(Error::Coordinator("injected crash".into()));
        }
        self.remaining -= 1;
        self.inner.consume_block(t, block)
    }

    fn finish(&mut self) -> Result<SinkOutput> {
        panic!("a crashed run must never reach finish()");
    }
}

/// A provider wrapper counting `block_gram` calls — the proof that a
/// resume recomputes exactly the missing tiles and nothing else.
struct CountingProvider<'a> {
    inner: NativeProvider<'a>,
    grams: AtomicUsize,
}

impl GramProvider for CountingProvider<'_> {
    fn name(&self) -> &'static str {
        "counting"
    }

    fn block_gram(&self, t: &BlockTask) -> Result<Mat64> {
        self.grams.fetch_add(1, Ordering::SeqCst);
        self.inner.block_gram(t)
    }
}

/// Uninterrupted spill run: the reference directory and matrix.
fn run_complete(ds: &BinaryDataset, kind: NativeKind, dir: &Path) -> MiMatrix {
    let src = InMemorySource::new(ds);
    let plan = plan_blocks(M, BLOCK).unwrap();
    let provider = NativeProvider::new(&src, kind);
    let progress = Progress::new(plan.tasks.len());
    let mut sink = TileSpillSink::new(dir, M).unwrap();
    run_plan(&src, &plan, &provider, 2, &progress, &mut sink, CombineKind::Mi).unwrap();
    sink.finish().unwrap();
    assemble_spilled(dir).unwrap()
}

/// Spill run that crashes after `CRASH_AFTER` tiles (single worker, so
/// exactly the first K tiles in plan order are durable).
fn run_interrupted(ds: &BinaryDataset, kind: NativeKind, dir: &Path) {
    let src = InMemorySource::new(ds);
    let plan = plan_blocks(M, BLOCK).unwrap();
    let provider = NativeProvider::new(&src, kind);
    let progress = Progress::new(plan.tasks.len());
    let mut sink =
        FaultSink { inner: TileSpillSink::new(dir, M).unwrap(), remaining: CRASH_AFTER };
    let err = run_plan(&src, &plan, &provider, 1, &progress, &mut sink, CombineKind::Mi)
        .expect_err("the injected crash must surface");
    assert!(err.to_string().contains("injected crash"), "unexpected error: {err}");
}

#[test]
fn resume_recomputes_zero_completed_tiles_on_every_backend() {
    let ds = dataset();
    let total = plan_blocks(M, BLOCK).unwrap().tasks.len();
    for kind in [NativeKind::Bitpack, NativeKind::Dense, NativeKind::Sparse] {
        let ref_dir = tmp(&format!("ref-{kind:?}"));
        let reference = run_complete(&ds, kind, &ref_dir);

        let dir = tmp(&format!("crash-{kind:?}"));
        run_interrupted(&ds, kind, &dir);
        let man = read_spill_manifest(&dir).unwrap();
        assert!(!man.complete, "{kind:?}: crashed manifest must lack the trailer");
        assert_eq!(man.tiles.len(), CRASH_AFTER, "{kind:?}: exactly K tiles durable");

        // resume: verify survivors, schedule only the rest
        let (mut sink, done) = TileSpillSink::resume(&dir).unwrap();
        assert_eq!(done.len(), CRASH_AFTER, "{kind:?}");
        let src = InMemorySource::new(&ds);
        let mut plan = plan_blocks(M, BLOCK).unwrap();
        plan.tasks.retain(|t| !done.contains(t));
        assert_eq!(plan.tasks.len(), total - CRASH_AFTER, "{kind:?}");
        let provider = CountingProvider {
            inner: NativeProvider::new(&src, kind),
            grams: AtomicUsize::new(0),
        };
        let progress = Progress::new(plan.tasks.len());
        run_plan(&src, &plan, &provider, 2, &progress, &mut sink, CombineKind::Mi).unwrap();
        sink.finish().unwrap();
        assert_eq!(
            provider.grams.load(Ordering::SeqCst),
            total - CRASH_AFTER,
            "{kind:?}: resume must recompute exactly the missing tiles"
        );

        let man = read_spill_manifest(&dir).unwrap();
        assert!(man.complete, "{kind:?}: resumed manifest must carry the trailer");
        assert_eq!(man.tiles.len(), total, "{kind:?}");
        let resumed = assemble_spilled(&dir).unwrap();
        assert_eq!(
            resumed.max_abs_diff(&reference),
            0.0,
            "{kind:?}: resumed assembly must be bit-identical to uninterrupted"
        );
        let _ = std::fs::remove_dir_all(&ref_dir);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn corrupt_tiles_are_clean_parse_errors_naming_the_tile() {
    let ds = dataset();
    let dir = tmp("corrupt");
    run_complete(&ds, NativeKind::Bitpack, &dir);
    let man = read_spill_manifest(&dir).unwrap();
    let victim_a = man.tiles[1].file();
    let victim_b = man.tiles[3].file();
    let orig_a = std::fs::read(dir.join(&victim_a)).unwrap();
    let orig_b = std::fs::read(dir.join(&victim_b)).unwrap();

    // truncation: detected by the manifest length
    std::fs::write(dir.join(&victim_a), &orig_a[..orig_a.len() - 8]).unwrap();
    let err = assemble_spilled(&dir).expect_err("truncated tile must not assemble");
    assert!(matches!(&err, Error::Parse(m) if m.contains(&victim_a)), "{err}");
    assert!(err.to_string().contains("truncated"), "{err}");
    std::fs::write(dir.join(&victim_a), &orig_a).unwrap();

    // single-bit flip: detected by the manifest checksum
    let mut flipped = orig_b.clone();
    flipped[5] ^= 0x10;
    std::fs::write(dir.join(&victim_b), &flipped).unwrap();
    let err = assemble_spilled(&dir).expect_err("bit-flipped tile must not assemble");
    assert!(matches!(&err, Error::Parse(m) if m.contains(&victim_b)), "{err}");
    assert!(err.to_string().contains("checksum"), "{err}");

    // resume refuses the same corruption instead of trusting the tile:
    // strip the completion trailer so the directory reads as crashed
    let manifest_path = dir.join("manifest.csv");
    let text = std::fs::read_to_string(&manifest_path).unwrap();
    let truncated = text.strip_suffix("complete,1\n").expect("trailer last");
    std::fs::write(&manifest_path, truncated).unwrap();
    let err = TileSpillSink::resume(&dir).map(|_| ()).expect_err("resume must verify");
    assert!(matches!(&err, Error::Parse(m) if m.contains(&victim_b)), "{err}");

    // healed tile + restored trailer assemble again
    std::fs::write(dir.join(&victim_b), &orig_b).unwrap();
    std::fs::write(&manifest_path, &text).unwrap();
    assemble_spilled(&dir).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn assembling_a_crashed_directory_points_at_resume() {
    let ds = dataset();
    let dir = tmp("incomplete");
    run_interrupted(&ds, NativeKind::Bitpack, &dir);
    let err = assemble_spilled(&dir).expect_err("incomplete run must not assemble");
    assert!(
        err.to_string().contains("resume"),
        "the error must point at `bulkmi resume`: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
