//! End-to-end cluster integration over real processes and real
//! sockets: `bulkmi worker` subprocesses driven by `bulkmi compute
//! --workers`, including SIGKILL fault injection mid-run. The in-crate
//! tests in `src/cluster/` cover the protocol and retry machinery
//! deterministically on loopback threads; this suite proves the same
//! guarantees hold across process boundaries — bit-identical CSV
//! output, clean exit codes, and a retried-task audit after a worker
//! is killed with work in flight.

use std::io::{BufRead, BufReader};
use std::path::PathBuf;
use std::process::{Child, ChildStderr, Command, Stdio};

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_bulkmi")
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("bulkmi-cluster-{}-{name}", std::process::id()))
}

/// Reserve a free loopback port: bind port 0, read the assignment
/// back, drop the listener. The race against other processes grabbing
/// it before the worker re-binds is negligible for a test.
fn free_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    l.local_addr().unwrap().to_string()
}

fn generate(data: &PathBuf, rows: &str, cols: &str) {
    let status = Command::new(bin())
        .args([
            "generate", "--rows", rows, "--cols", cols, "--sparsity", "0.85",
            "--seed", "5", "--plant", "1:7:0.05", "--out", data.to_str().unwrap(),
        ])
        .status()
        .unwrap();
    assert!(status.success(), "generate failed");
}

/// A `bulkmi worker` subprocess with its stderr held open so tests can
/// synchronize on the worker's own log lines instead of sleeping.
struct Worker {
    child: Child,
    stderr: BufReader<ChildStderr>,
}

fn spawn_worker(addr: &str, data: &PathBuf) -> Worker {
    let mut child = Command::new(bin())
        .args(["worker", "--connect", addr, "--input", data.to_str().unwrap()])
        .env("BULKMI_LOG", "info")
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    let stderr = BufReader::new(child.stderr.take().unwrap());
    Worker { child, stderr }
}

impl Worker {
    /// Block until the worker logs a line containing `needle` (bind
    /// and accept are both logged at info level).
    fn wait_for_log(&mut self, needle: &str) {
        let mut line = String::new();
        loop {
            line.clear();
            let n = self.stderr.read_line(&mut line).unwrap();
            assert!(n > 0, "worker stderr closed before logging '{needle}'");
            if line.contains(needle) {
                return;
            }
        }
    }
}

/// The count preceding a labelled field in the coordinator's summary
/// line, e.g. `field_count("... 3 retried, ...", "retried,")` -> 3.
fn field_count(stdout: &str, label: &str) -> u64 {
    let tokens: Vec<&str> = stdout.split_whitespace().collect();
    let at = tokens
        .iter()
        .position(|t| *t == label)
        .unwrap_or_else(|| panic!("no '{label}' in coordinator output:\n{stdout}"));
    tokens[at - 1].parse().unwrap()
}

#[test]
fn two_worker_processes_match_single_process_bit_for_bit() {
    let data = tmp("basic.bmat");
    generate(&data, "500", "32");

    // the single-process answer, via the same CLI surface
    let want = tmp("basic-want.csv");
    let status = Command::new(bin())
        .args([
            "compute", "--input", data.to_str().unwrap(), "--backend", "bulk-bitpack",
            "--block-cols", "8", "--out", want.to_str().unwrap(),
        ])
        .status()
        .unwrap();
    assert!(status.success());

    let (a, b) = (free_addr(), free_addr());
    let mut w1 = spawn_worker(&a, &data);
    let mut w2 = spawn_worker(&b, &data);
    w1.wait_for_log("listening");
    w2.wait_for_log("listening");

    let got = tmp("basic-got.csv");
    let out = Command::new(bin())
        .args([
            "compute", "--input", data.to_str().unwrap(), "--backend", "bulk-bitpack",
            "--block-cols", "8", "--workers", &format!("{a},{b}"),
            "--out", got.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "cluster compute failed: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8(out.stdout).unwrap();
    assert!(stdout.contains("across 2 workers"), "{stdout}");
    assert_eq!(field_count(&stdout, "retried,"), 0, "{stdout}");

    // byte-for-byte equal CSV: floats render through shortest
    // round-trip Display, so equal text means bit-identical values
    let want_text = std::fs::read_to_string(&want).unwrap();
    let got_text = std::fs::read_to_string(&got).unwrap();
    assert_eq!(want_text, got_text, "cluster CSV must equal the single-process CSV");

    // workers shut down cleanly after the coordinator's shutdown frame
    assert!(w1.child.wait().unwrap().success());
    assert!(w2.child.wait().unwrap().success());
    for p in [data, want, got] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn sigkilled_worker_tasks_are_retried_to_a_bit_identical_result() {
    // many cheap tasks stretch the run (~1000 round trips) so the kill
    // below lands mid-dispatch, not before the handshake or after the
    // last task
    let data = tmp("faults.bmat");
    generate(&data, "6000", "360");

    let want = tmp("faults-want.csv");
    let status = Command::new(bin())
        .args([
            "compute", "--input", data.to_str().unwrap(), "--backend", "bulk-bitpack",
            "--block-cols", "6", "--out", want.to_str().unwrap(),
        ])
        .status()
        .unwrap();
    assert!(status.success());

    let (a, b) = (free_addr(), free_addr());
    let mut w1 = spawn_worker(&a, &data);
    let mut w2 = spawn_worker(&b, &data);
    w1.wait_for_log("listening");
    w2.wait_for_log("listening");

    let got = tmp("faults-got.csv");
    let mut coordinator = Command::new(bin())
        .args([
            "compute", "--input", data.to_str().unwrap(), "--backend", "bulk-bitpack",
            "--block-cols", "6", "--workers", &format!("{a},{b}"),
            "--out", got.to_str().unwrap(),
        ])
        .stdout(Stdio::piped())
        .spawn()
        .unwrap();

    // kill worker 2 the moment it has accepted the coordinator (plus a
    // beat for the handshake to clear) — with ~1000 tasks in the plan
    // the run is guaranteed to still be in flight
    w2.wait_for_log("serving coordinator");
    std::thread::sleep(std::time::Duration::from_millis(25));
    w2.child.kill().unwrap();
    let _ = w2.child.wait();

    let out = coordinator.wait_with_output().unwrap();
    assert!(out.status.success(), "coordinator must survive a worker death");
    let stdout = String::from_utf8(out.stdout).unwrap();
    assert_eq!(field_count(&stdout, "worker"), 1, "exactly one failure: {stdout}");
    assert!(
        field_count(&stdout, "retried,") >= 1,
        "the killed worker's in-flight task must be retried: {stdout}"
    );

    let want_text = std::fs::read_to_string(&want).unwrap();
    let got_text = std::fs::read_to_string(&got).unwrap();
    assert_eq!(want_text, got_text, "retried run must stay bit-identical");

    assert!(w1.child.wait().unwrap().success(), "the survivor exits cleanly");
    for p in [data, want, got] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn worker_cli_validates_its_arguments() {
    let out = Command::new(bin()).args(["worker", "--connect", "127.0.0.1:0"]).output().unwrap();
    assert!(!out.status.success(), "worker needs --input");
    let out = Command::new(bin())
        .args(["worker", "--input", "/nonexistent.bmat"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "worker needs --connect");
    let out = Command::new(bin()).args(["cluster", "frobnicate"]).output().unwrap();
    assert!(!out.status.success(), "unknown cluster subcommand is an error");
}
