//! HTTP job-server acceptance tests, driven over real TCP sockets with
//! a hand-rolled client (the same zero-dependency discipline as the
//! server):
//!
//! * submit → poll → result round trip for two concurrent jobs whose
//!   aggregate estimate exceeds the admission budget — they serialize
//!   under the cap, and both results come back **bit-identical** to the
//!   library computation the CLI `compute` path uses;
//! * admission facts (estimated bytes, priority) surface in the status
//!   envelope, the result meta, and `/metrics`;
//! * the error mapping: unknown dataset/job → 404, bad version/id →
//!   400, cancelled result → 410, cancel-after-terminal → 409;
//! * drain: the admin endpoint and the SIGTERM latch both stop the
//!   accept loop, finish in-flight jobs, and return `Ok` (exit 0).
//!
//! Everything runs in ONE test function: the shutdown signal latch is
//! process-global, so concurrent server tests would drain each other.

use bulkmi::coordinator::admission::estimate_job_bytes;
use bulkmi::coordinator::service::JobSpec;
use bulkmi::data::io;
use bulkmi::data::synth::SynthSpec;
use bulkmi::mi::backend::{compute_mi, Backend};
use bulkmi::mi::sink::SinkSpec;
use bulkmi::mi::topk::top_k_pairs;
use bulkmi::server::{signal, Server, ServerConfig};
use bulkmi::util::json::Json;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("bulkmi-server-it-{}-{name}", std::process::id()))
}

/// Minimal HTTP/1.1 client: one request, `Connection: close`, returns
/// `(status, body)`.
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    stream.set_write_timeout(Some(Duration::from_secs(60))).unwrap();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: bulkmi-test\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line in {raw:?}"));
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

fn submit(addr: SocketAddr, body: &str) -> u64 {
    let (code, resp) = http(addr, "POST", "/v1/jobs", body);
    assert_eq!(code, 202, "submit failed: {resp}");
    let doc = Json::parse(&resp).unwrap();
    doc.get("job").and_then(Json::as_f64).expect("job id in ack") as u64
}

fn wait_done(addr: SocketAddr, id: u64) {
    let mut last = String::new();
    for _ in 0..6000 {
        let (code, body) = http(addr, "GET", &format!("/v1/jobs/{id}"), "");
        assert_eq!(code, 200, "{body}");
        let doc = Json::parse(&body).unwrap();
        match doc.get("state").and_then(Json::as_str).unwrap() {
            "done" => return,
            "failed" | "cancelled" => panic!("job {id} ended badly: {body}"),
            _ => {
                last = body;
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
    panic!("job {id} never finished; last status {last}");
}

#[test]
fn http_server_end_to_end() {
    // ---- the workload, and the answer the CLI compute path gives ----
    let (n, m) = (3000usize, 32usize);
    let ds = SynthSpec::new(n, m).sparsity(0.8).seed(5).plant(2, 9, 0.02).generate();
    let path = tmp("panel.bmat");
    io::write_bmat_v2(&ds, &path).unwrap();
    let want = compute_mi(&ds, Backend::BulkBitpack).unwrap();

    // ---- admission cap: either job fits alone, both at once do not ----
    let dense_spec = JobSpec::builder().block_cols(8).build().unwrap();
    let topk_spec = JobSpec::builder()
        .block_cols(8)
        .sink(SinkSpec::TopK { k: 5, per_column: false })
        .build()
        .unwrap();
    let dense_cost = estimate_job_bytes(n, m, true, &dense_spec);
    let topk_cost = estimate_job_bytes(n, m, true, &topk_spec);
    assert!(dense_cost > 0 && topk_cost > 0);
    let budget = dense_cost.max(topk_cost) + dense_cost.min(topk_cost) / 2;
    assert!(
        budget < dense_cost + topk_cost,
        "the cap ({budget}) must be smaller than both jobs resident together \
         ({dense_cost} + {topk_cost})"
    );
    let server = Arc::new(
        Server::bind(&ServerConfig {
            listen: "127.0.0.1:0".into(),
            workers: 2,
            max_queued: 8,
            memory_budget: Some(budget),
        })
        .unwrap(),
    );
    assert_eq!(server.register_dataset("panel", &path).unwrap(), (n, m));
    let addr = server.addr();
    assert_ne!(addr.port(), 0, "port 0 must resolve to a real port");
    let runner = {
        let server = Arc::clone(&server);
        std::thread::spawn(move || server.run())
    };

    let (code, body) = http(addr, "GET", "/healthz", "");
    assert_eq!(code, 200);
    let doc = Json::parse(&body).unwrap();
    assert_eq!(doc.get("ok").and_then(Json::as_bool), Some(true), "{body}");
    assert_eq!(doc.get("draining").and_then(Json::as_bool), Some(false), "{body}");

    let (code, body) = http(addr, "GET", "/v1/datasets", "");
    assert_eq!(code, 200);
    assert!(body.contains("\"name\":\"panel\""), "{body}");
    assert!(body.contains("\"out_of_core\":true"), "{body}");

    // ---- two concurrent jobs, aggregate estimate over the budget ----
    let dense_id = submit(addr, r#"{"v":1,"dataset":"panel","block_cols":8}"#);
    let topk_id = submit(
        addr,
        r#"{"v":1,"dataset":"panel","block_cols":8,"sink":"topk:5","priority":"interactive"}"#,
    );
    // the submit ack already carries the admission price
    let (_, status) = http(addr, "GET", &format!("/v1/jobs/{dense_id}"), "");
    let doc = Json::parse(&status).unwrap();
    assert_eq!(
        doc.get("estimated_bytes").and_then(Json::as_f64),
        Some(dense_cost as f64),
        "{status}"
    );
    wait_done(addr, dense_id);
    wait_done(addr, topk_id);

    // under the cap: the gate never held both jobs' bytes at once
    let gate = server.service().admission();
    assert_eq!(gate.budget_bytes(), Some(budget));
    assert!(
        gate.peak_bytes() >= dense_cost.min(topk_cost),
        "at least one job was priced in"
    );
    assert!(
        gate.peak_bytes() <= budget,
        "aggregate resident bytes exceeded the cap: peak {} > budget {budget}",
        gate.peak_bytes()
    );
    assert_eq!(gate.inflight_bytes(), 0, "all permits returned");
    assert_eq!(gate.admitted(), 2);

    // ---- results: bit-identical to the library/CLI computation ----
    let (code, body) = http(addr, "GET", &format!("/v1/jobs/{dense_id}/result"), "");
    assert_eq!(code, 200, "{body}");
    let doc = Json::parse(&body).unwrap();
    let result = doc.get("result").unwrap();
    assert_eq!(result.get("kind").and_then(Json::as_str), Some("dense"));
    assert_eq!(result.get("dim").and_then(Json::as_f64), Some(m as f64));
    let rows = result.get("rows").and_then(Json::as_arr).unwrap();
    assert_eq!(rows.len(), m);
    for (i, row) in rows.iter().enumerate() {
        let row = row.as_arr().unwrap();
        assert_eq!(row.len(), m);
        for (j, cell) in row.iter().enumerate() {
            assert_eq!(
                cell.as_f64(),
                Some(want.get(i, j)),
                "cell ({i},{j}) not bit-identical over the wire"
            );
        }
    }
    // admission facts recorded in the result meta
    let meta = doc.get("meta").unwrap();
    assert_eq!(meta.get("backend").and_then(Json::as_str), Some("bulk-bitpack"));
    let adm = meta.get("admission").expect("admission meta present");
    assert_eq!(adm.get("estimated_bytes").and_then(Json::as_f64), Some(dense_cost as f64));
    assert_eq!(adm.get("priority").and_then(Json::as_str), Some("batch"));

    let (code, body) = http(addr, "GET", &format!("/v1/jobs/{topk_id}/result"), "");
    assert_eq!(code, 200, "{body}");
    let doc = Json::parse(&body).unwrap();
    let result = doc.get("result").unwrap();
    assert_eq!(result.get("kind").and_then(Json::as_str), Some("topk"));
    let got = result.get("pairs").and_then(Json::as_arr).unwrap();
    let exp = top_k_pairs(&want, 5);
    assert_eq!(got.len(), exp.len());
    for (g, w) in got.iter().zip(&exp) {
        assert_eq!(g.get("i").and_then(Json::as_f64), Some(w.i as f64));
        assert_eq!(g.get("j").and_then(Json::as_f64), Some(w.j as f64));
        assert_eq!(g.get("value").and_then(Json::as_f64), Some(w.mi), "not bit-identical");
    }
    let adm = doc.get("meta").unwrap().get("admission").expect("admission meta");
    assert_eq!(adm.get("priority").and_then(Json::as_str), Some("interactive"));

    // ---- error mapping ----
    // result is one-shot: the second fetch finds no job
    let (code, _) = http(addr, "GET", &format!("/v1/jobs/{dense_id}/result"), "");
    assert_eq!(code, 404, "taken results are gone");
    let (code, body) = http(addr, "POST", "/v1/jobs", r#"{"v":1,"dataset":"nope"}"#);
    assert_eq!(code, 404, "{body}");
    assert!(body.contains("registered: panel"), "{body}");
    let (code, _) = http(addr, "POST", "/v1/jobs", r#"{"v":9,"dataset":"panel"}"#);
    assert_eq!(code, 400, "bad wire version");
    let (code, _) = http(addr, "GET", "/v1/jobs/xyz", "");
    assert_eq!(code, 400, "bad job id");
    let (code, _) = http(addr, "GET", "/v1/jobs/999999", "");
    assert_eq!(code, 404, "unknown job");
    let (code, _) = http(addr, "GET", "/v1/bogus", "");
    assert_eq!(code, 404, "unknown route");

    // ---- metrics expose the gate and the shared cache ----
    let (code, metrics) = http(addr, "GET", "/metrics", "");
    assert_eq!(code, 200);
    assert!(metrics.contains(&format!("admission budget_bytes = {budget}")), "{metrics}");
    assert!(metrics.contains("admission peak_bytes = "), "{metrics}");
    assert!(metrics.contains("cache shared hits = "), "{metrics}");
    assert!(metrics.contains("jobs_done"), "{metrics}");

    // ---- drain endpoint: loop exits, in-flight work finishes, Ok ----
    let (code, body) = http(addr, "POST", "/v1/admin/drain", "");
    assert_eq!(code, 200);
    assert!(body.contains("\"draining\":true"), "{body}");
    runner.join().unwrap().expect("drained server exits cleanly");

    // ---- cancel mapping needs a queued job: one worker, busy pool ----
    let server = Arc::new(
        Server::bind(&ServerConfig {
            listen: "127.0.0.1:0".into(),
            workers: 1,
            max_queued: 8,
            memory_budget: None,
        })
        .unwrap(),
    );
    // many tiny tasks (250 blocks -> ~31k tasks) keep the single worker
    // busy long enough that the cancel below always lands first
    let big = SynthSpec::new(512, 2000).sparsity(0.5).seed(7).generate();
    let big_path = tmp("big.bmat");
    io::write_bmat_v2(&big, &big_path).unwrap();
    server.register_dataset("big", &big_path).unwrap();
    server.register_dataset("panel", &path).unwrap();
    let addr = server.addr();
    let runner = {
        let server = Arc::clone(&server);
        std::thread::spawn(move || server.run())
    };
    let big_id = submit(addr, r#"{"v":1,"dataset":"big","block_cols":8}"#);
    let queued_id = submit(addr, r#"{"v":1,"dataset":"panel","block_cols":8}"#);
    let (code, body) = http(addr, "POST", &format!("/v1/jobs/{queued_id}/cancel"), "");
    assert_eq!(code, 200, "{body}");
    assert!(body.contains("\"state\":\"cancelled\""), "{body}");
    let (code, body) = http(addr, "GET", &format!("/v1/jobs/{queued_id}/result"), "");
    assert_eq!(code, 410, "cancelled result is Gone: {body}");
    let (code, _) = http(addr, "POST", &format!("/v1/jobs/{queued_id}/cancel"), "");
    assert_eq!(code, 409, "second cancel hits a terminal job");
    wait_done(addr, big_id);
    let (code, _) = http(addr, "POST", &format!("/v1/jobs/{big_id}/cancel"), "");
    assert_eq!(code, 409, "cancel after done is Conflict");

    // ---- SIGTERM latch: same graceful path as the admin endpoint ----
    signal::reset();
    signal::trigger();
    runner.join().unwrap().expect("signalled server exits cleanly");
    signal::reset();

    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&big_path);
}
