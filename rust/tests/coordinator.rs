//! Coordinator integration + property tests: plan coverage invariants,
//! blockwise == monolithic exactness, service lifecycle under load,
//! failure injection, and budget compliance.

use bulkmi::coordinator::executor::NativeKind;
use bulkmi::coordinator::planner::{block_for_budget, plan_blocks, task_bytes};
use bulkmi::coordinator::progress::Progress;
use bulkmi::coordinator::scheduler::{order_tasks, Schedule};
use bulkmi::coordinator::service::{JobService, JobSpec, JobStatus};
use bulkmi::coordinator::{run_plan_dense, GramProvider, NativeProvider};
use bulkmi::data::synth::SynthSpec;
use bulkmi::linalg::dense::Mat64;
use bulkmi::mi::backend::{compute_mi, Backend};
use bulkmi::mi::measure::CombineKind;
use bulkmi::util::error::Error;
use bulkmi::util::prop::{gen, prop_check, Config};

#[test]
fn prop_plan_covers_every_pair_exactly_once() {
    prop_check(
        "plan coverage",
        Config::with_cases(50),
        |rng| {
            let m = gen::int_in(rng, 1, 200);
            let b = gen::int_in(rng, 1, 64);
            (m, b)
        },
        |&(m, b)| {
            let plan = plan_blocks(m, b).map_err(|e| e.to_string())?;
            if plan.total_cells() != m * m {
                return Err(format!("total cells {} != {}", plan.total_cells(), m * m));
            }
            let mut covered = vec![0u8; m * m];
            for t in &plan.tasks {
                for i in t.a_start..t.a_start + t.a_len {
                    for j in t.b_start..t.b_start + t.b_len {
                        covered[i * m + j] += 1;
                        if !t.is_diagonal() {
                            covered[j * m + i] += 1;
                        }
                    }
                }
            }
            if covered.iter().any(|&c| c != 1) {
                return Err("some cell not covered exactly once".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_blockwise_equals_monolithic_bit_for_bit() {
    prop_check(
        "blockwise == monolithic",
        Config::with_cases(10),
        |rng| {
            let (n, m, bytes) = gen::binary_matrix(rng, 100, 30);
            let block = gen::int_in(rng, 1, 32);
            let workers = gen::int_in(rng, 1, 4);
            (n, m, bytes, block, workers)
        },
        |(n, m, bytes, block, workers)| {
            let ds = bulkmi::data::dataset::BinaryDataset::new(*n, *m, bytes.clone())
                .map_err(|e| e.to_string())?;
            let mono = compute_mi(&ds, Backend::BulkBitpack).unwrap();
            let plan = plan_blocks(*m, *block).unwrap();
            let provider = NativeProvider::new(&ds, NativeKind::Bitpack);
            let progress = Progress::new(plan.tasks.len());
            let got = run_plan_dense(&ds, &plan, &provider, *workers, &progress, CombineKind::Mi)
                .map_err(|e| e.to_string())?;
            if got.max_abs_diff(&mono) != 0.0 {
                return Err(format!("diff {}", got.max_abs_diff(&mono)));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_budget_respected_and_maximal() {
    prop_check(
        "budget block sizing",
        Config::with_cases(40),
        |rng| {
            let n = gen::int_in(rng, 100, 1_000_000);
            let m = gen::int_in(rng, 2, 20_000);
            let budget = gen::int_in(rng, 1 << 16, 1 << 30);
            (n, m, budget)
        },
        |&(n, m, budget)| {
            let b = block_for_budget(n, m, budget);
            if b == 0 || b > m {
                return Err(format!("block {b} out of range"));
            }
            if b > 1 && task_bytes(n, b) > budget {
                return Err(format!("block {b} exceeds budget"));
            }
            if b < m && task_bytes(n, b + 1) <= budget {
                return Err(format!("block {b} not maximal"));
            }
            Ok(())
        },
    );
}

#[test]
fn schedules_do_not_change_results() {
    let ds = SynthSpec::new(300, 40).sparsity(0.8).seed(3).generate();
    let provider = NativeProvider::new(&ds, NativeKind::Bitpack);
    let mono = compute_mi(&ds, Backend::BulkBitpack).unwrap();
    for policy in [
        Schedule::Sequential,
        Schedule::LargestFirst,
        Schedule::DiagonalFirst,
        Schedule::Panel,
    ] {
        let mut plan = plan_blocks(40, 7).unwrap();
        order_tasks(&mut plan.tasks, policy);
        let progress = Progress::new(plan.tasks.len());
        let got =
            run_plan_dense(&ds, &plan, &provider, 2, &progress, CombineKind::Mi).unwrap();
        assert_eq!(got.max_abs_diff(&mono), 0.0, "{policy:?}");
    }
}

/// Failure injection: a provider that errors on one specific task.
struct FailingProvider<'a> {
    inner: NativeProvider<'a>,
    fail_at: usize,
    calls: std::sync::atomic::AtomicUsize,
}

impl GramProvider for FailingProvider<'_> {
    fn name(&self) -> &'static str {
        "failing"
    }

    fn block_gram(
        &self,
        t: &bulkmi::coordinator::planner::BlockTask,
    ) -> bulkmi::util::error::Result<Mat64> {
        let k = self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if k == self.fail_at {
            return Err(Error::Runtime("injected failure".into()));
        }
        self.inner.block_gram(t)
    }
}

#[test]
fn executor_surfaces_provider_errors() {
    let ds = SynthSpec::new(80, 20).sparsity(0.5).seed(4).generate();
    let provider = FailingProvider {
        inner: NativeProvider::new(&ds, NativeKind::Bitpack),
        fail_at: 3,
        calls: Default::default(),
    };
    let plan = plan_blocks(20, 5).unwrap();
    let progress = Progress::new(plan.tasks.len());
    let err =
        run_plan_dense(&ds, &plan, &provider, 2, &progress, CombineKind::Mi).unwrap_err();
    assert!(matches!(err, Error::Runtime(_)), "got {err}");
}

#[test]
fn service_survives_many_small_jobs() {
    let svc = JobService::new(2, 32);
    let mut handles = Vec::new();
    for seed in 0..20 {
        let ds = SynthSpec::new(40, 6).sparsity(0.5).seed(seed).generate();
        let spec = JobSpec::builder().block_cols(2).build().unwrap();
        handles.push((seed, svc.submit(ds, spec).unwrap()));
    }
    for (seed, h) in handles {
        let status = svc.wait(h).unwrap();
        assert!(matches!(status, JobStatus::Done(_)), "job {seed}: {status:?}");
        let ds = SynthSpec::new(40, 6).sparsity(0.5).seed(seed).generate();
        let want = compute_mi(&ds, Backend::BulkBitpack).unwrap();
        let got = svc.take(h).unwrap().into_dense().unwrap();
        assert_eq!(got.max_abs_diff(&want), 0.0, "job {seed}");
    }
    assert_eq!(svc.metrics().counter("jobs_done").get(), 20);
}

#[test]
fn service_progress_is_monotonic() {
    let svc = JobService::new(1, 2);
    let ds = SynthSpec::new(3000, 100).sparsity(0.7).seed(6).generate();
    let h = svc.submit(ds, JobSpec::builder().block_cols(10).build().unwrap()).unwrap();
    let mut last = 0.0f64;
    loop {
        match svc.poll(h).unwrap() {
            JobStatus::Running(p) => {
                assert!(p >= last, "progress went backwards: {last} -> {p}");
                last = p;
            }
            s if s.is_terminal() => break,
            _ => {}
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert!(matches!(svc.wait(h).unwrap(), JobStatus::Done(_)));
}

#[test]
fn cancelled_queued_job_never_runs() {
    // one worker busy with a big job; the queued one is cancelled
    let svc = JobService::new(1, 8);
    let big = SynthSpec::new(8000, 128).sparsity(0.5).seed(7).generate();
    let h1 = svc.submit(big, JobSpec::builder().block_cols(16).build().unwrap()).unwrap();
    let small = SynthSpec::new(50, 5).seed(8).generate();
    let h2 = svc.submit(small, JobSpec::default()).unwrap();
    svc.cancel(h2).unwrap();
    let s2 = svc.wait(h2).unwrap();
    assert!(matches!(s2, JobStatus::Cancelled), "got {s2:?}");
    assert!(matches!(svc.wait(h1).unwrap(), JobStatus::Done(_)));
}
