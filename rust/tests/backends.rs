//! Cross-backend integration + property tests: every implementation of
//! the paper's algorithm must agree with the textbook pairwise baseline
//! on arbitrary inputs, and the MI matrix must satisfy its information-
//! theoretic invariants. Uses the in-crate property-testing framework
//! (`bulkmi::util::prop`) — the offline registry has no proptest.

// The numeric checks deliberately index by (row, col) to mirror the
// paper's pseudocode (same rationale as the crate-level allow in lib.rs).
#![allow(clippy::needless_range_loop)]

use bulkmi::data::dataset::BinaryDataset;
use bulkmi::data::synth::SynthSpec;
use bulkmi::mi::backend::{compute_mi, compute_mi_with, Backend};
use bulkmi::mi::counts::entropy_bits;
use bulkmi::mi::entropy::column_entropies;
use bulkmi::util::prop::{gen, prop_check, Config};

fn ds_from(n: usize, m: usize, bytes: Vec<u8>) -> BinaryDataset {
    BinaryDataset::new(n, m, bytes).unwrap()
}

#[test]
fn prop_all_native_backends_agree_with_pairwise() {
    prop_check(
        "native backends == pairwise",
        Config::with_cases(24),
        |rng| gen::binary_matrix(rng, 120, 24),
        |(n, m, bytes)| {
            let ds = ds_from(*n, *m, bytes.clone());
            let reference = compute_mi(&ds, Backend::Pairwise).unwrap();
            for b in [Backend::BulkBasic, Backend::BulkOpt, Backend::BulkSparse, Backend::BulkBitpack]
            {
                let got = compute_mi(&ds, b).unwrap();
                let diff = got.max_abs_diff(&reference);
                if diff >= 1e-10 {
                    return Err(format!("{b}: diff {diff}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mi_matrix_invariants() {
    prop_check(
        "MI invariants (symmetry, nonneg, diag=H, bound)",
        Config::with_cases(24),
        |rng| gen::binary_matrix(rng, 150, 16),
        |(n, m, bytes)| {
            let ds = ds_from(*n, *m, bytes.clone());
            let mi = compute_mi(&ds, Backend::BulkBitpack).unwrap();
            if mi.max_asymmetry() > 1e-12 {
                return Err(format!("asymmetry {}", mi.max_asymmetry()));
            }
            if mi.min_value() < -1e-12 {
                return Err(format!("negative MI {}", mi.min_value()));
            }
            let h = column_entropies(&ds);
            for i in 0..*m {
                if (mi.get(i, i) - h[i]).abs() > 1e-9 {
                    return Err(format!("diag[{i}] {} != H {}", mi.get(i, i), h[i]));
                }
                for j in 0..*m {
                    if mi.get(i, j) > h[i].min(h[j]) + 1e-9 {
                        return Err(format!("MI({i},{j}) exceeds min entropy"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mi_invariant_under_row_permutation() {
    prop_check(
        "row order does not change MI",
        Config::with_cases(12),
        |rng| {
            let (n, m, bytes) = gen::binary_matrix(rng, 80, 10);
            let mut perm: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut perm);
            (n, m, bytes, perm)
        },
        |(n, m, bytes, perm)| {
            let ds = ds_from(*n, *m, bytes.clone());
            let mut shuffled = vec![0u8; n * m];
            for (dst, &src) in perm.iter().enumerate() {
                shuffled[dst * m..(dst + 1) * m].copy_from_slice(
                    &bytes[src * m..(src + 1) * m],
                );
            }
            let ds2 = ds_from(*n, *m, shuffled);
            let a = compute_mi(&ds, Backend::BulkOpt).unwrap();
            let b = compute_mi(&ds2, Backend::BulkOpt).unwrap();
            let diff = a.max_abs_diff(&b);
            if diff > 1e-12 {
                return Err(format!("diff {diff}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mi_invariant_under_column_complement() {
    // MI(X, Y) = MI(¬X, Y): flipping a column's bits preserves MI
    prop_check(
        "column complement preserves MI",
        Config::with_cases(12),
        |rng| gen::binary_matrix(rng, 100, 8),
        |(n, m, bytes)| {
            let ds = ds_from(*n, *m, bytes.clone());
            let mut flipped = bytes.clone();
            for r in 0..*n {
                flipped[r * m] ^= 1; // complement column 0
            }
            let ds2 = ds_from(*n, *m, flipped);
            let a = compute_mi(&ds, Backend::BulkBitpack).unwrap();
            let b = compute_mi(&ds2, Backend::BulkBitpack).unwrap();
            for i in 0..*m {
                for j in 0..*m {
                    if (a.get(i, j) - b.get(i, j)).abs() > 1e-9 {
                        return Err(format!(
                            "MI({i},{j}) changed: {} -> {}",
                            a.get(i, j),
                            b.get(i, j)
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_duplicating_rows_preserves_mi() {
    // probabilities are unchanged when every row appears twice
    prop_check(
        "row duplication preserves MI",
        Config::with_cases(12),
        |rng| gen::binary_matrix(rng, 60, 8),
        |(n, m, bytes)| {
            let ds = ds_from(*n, *m, bytes.clone());
            let mut doubled = bytes.clone();
            doubled.extend_from_slice(bytes);
            let ds2 = ds_from(n * 2, *m, doubled);
            let a = compute_mi(&ds, Backend::BulkOpt).unwrap();
            let b = compute_mi(&ds2, Backend::BulkOpt).unwrap();
            let diff = a.max_abs_diff(&b);
            if diff > 1e-9 {
                return Err(format!("diff {diff}"));
            }
            Ok(())
        },
    );
}

#[test]
fn workers_do_not_change_results() {
    let ds = SynthSpec::new(500, 33).sparsity(0.7).seed(5).generate();
    let one = compute_mi_with(&ds, Backend::BulkBitpack, 1).unwrap();
    for w in [2, 3, 8] {
        let many = compute_mi_with(&ds, Backend::BulkBitpack, w).unwrap();
        assert_eq!(one.max_abs_diff(&many), 0.0, "workers={w}");
    }
}

#[test]
fn perfect_copy_reaches_entropy_bound() {
    let ds = SynthSpec::new(4000, 6).sparsity(0.65).seed(8).plant(1, 4, 0.0).generate();
    let mi = compute_mi(&ds, Backend::BulkOpt).unwrap();
    let p = ds.col_counts()[1] as f64 / 4000.0;
    assert!((mi.get(1, 4) - entropy_bits(p)).abs() < 1e-9);
}

#[test]
fn extreme_shapes() {
    // single column
    let ds = SynthSpec::new(100, 1).sparsity(0.5).seed(1).generate();
    let mi = compute_mi(&ds, Backend::BulkOpt).unwrap();
    assert_eq!(mi.dim(), 1);
    // wide and short
    let ds = SynthSpec::new(2, 300).sparsity(0.5).seed(2).generate();
    let reference = compute_mi(&ds, Backend::Pairwise).unwrap();
    for b in [Backend::BulkBasic, Backend::BulkOpt, Backend::BulkSparse, Backend::BulkBitpack] {
        assert!(compute_mi(&ds, b).unwrap().max_abs_diff(&reference) < 1e-10, "{b}");
    }
}

#[test]
fn all_zero_and_all_one_datasets() {
    for fill in [0u8, 1u8] {
        let ds = BinaryDataset::new(50, 8, vec![fill; 400]).unwrap();
        for b in [Backend::Pairwise, Backend::BulkBasic, Backend::BulkOpt, Backend::BulkSparse, Backend::BulkBitpack]
        {
            let mi = compute_mi(&ds, b).unwrap();
            assert!(
                mi.data().iter().all(|&v| v == 0.0),
                "{b}: constant data must give all-zero MI"
            );
        }
    }
}
