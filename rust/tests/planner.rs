//! Direct unit tests for the planner's block-width policy — previously
//! exercised only indirectly through the job service. Covers the
//! `block_policy` precedence chain (explicit > probe-throughput >
//! caller fallback) and the `throughput_block` latency-cap math,
//! including the combine-aware model `b² · (n/T_gram + 1/T_c)`.

use bulkmi::coordinator::planner::{
    block_policy, matrix_free_block, task_bytes, throughput_block, DEFAULT_TASK_LATENCY_SECS,
};

#[test]
fn explicit_width_beats_probe_and_fallback() {
    let t = DEFAULT_TASK_LATENCY_SECS;
    // an explicit caller width wins no matter what else is available
    let (b, src) = block_policy(9, Some(1e9), Some(1e7), 10_000, 500, 0, t, (7, "budget"));
    assert_eq!((b, src), (9, "explicit"));
    // ...even an absurdly small one
    let (b, src) = block_policy(1, Some(f64::MAX), None, 10_000, 500, 0, t, (7, "monolithic"));
    assert_eq!((b, src), (1, "explicit"));
}

#[test]
fn probe_throughput_beats_fallback() {
    let (n, m) = (10_000usize, 500usize);
    let t = DEFAULT_TASK_LATENCY_SECS;
    let (b, src) = block_policy(0, Some(1e8), None, n, m, 0, t, (7, "budget"));
    assert_eq!(src, "probe-throughput");
    assert_eq!(b, throughput_block(n, m, 0, 1e8, None, t));
    assert!(b >= 1);
    // the caller's latency target feeds straight through: a longer
    // target affords blocks at least as large
    let (short, _) = block_policy(0, Some(1e8), None, n, m, 0, 0.25, (7, "budget"));
    let (long, _) = block_policy(0, Some(1e8), None, n, m, 0, 16.0, (7, "budget"));
    assert!(long >= short, "target 16s gave {long} < target 0.25s {short}");
}

#[test]
fn fallback_applies_when_nothing_else_is_known() {
    let t = DEFAULT_TASK_LATENCY_SECS;
    // no explicit width, no probe: the caller's fallback rule verbatim
    assert_eq!(
        block_policy(0, None, None, 10_000, 500, 0, t, (0, "monolithic")),
        (0, "monolithic")
    );
    assert_eq!(
        block_policy(0, None, None, 10_000, 500, 0, t, (123, "budget")),
        (123, "budget")
    );
    // a combine figure alone never sizes blocks: still the fallback
    assert_eq!(
        block_policy(0, None, Some(1e7), 10_000, 500, 0, t, (123, "budget")),
        (123, "budget")
    );
}

#[test]
fn latency_cap_math_is_maximal_under_the_target() {
    // when the latency cap (not the memory cap) binds, the chosen b is
    // the largest with b² · n / throughput <= target
    let (n, m) = (10_000usize, 5_000usize);
    let (tput, target) = (1e8f64, 1.0f64);
    let b = throughput_block(n, m, usize::MAX, tput, None, target);
    assert!(b >= 1);
    if b < m {
        let latency = |w: usize| (w * w) as f64 * n as f64 / tput;
        assert!(latency(b) <= target + 1e-9, "b = {b} exceeds the target");
        assert!(latency(b + 1) > target, "b = {b} is not maximal");
    }
}

#[test]
fn combine_throughput_folds_into_the_latency_cap() {
    // with a probed combine throughput, the model charges each output
    // cell n/T_gram + 1/T_combine seconds — a slow (entropy-heavy)
    // combine stage shrinks blocks relative to Gram-only sizing
    let (n, m) = (10_000usize, 5_000usize);
    let (tput, target) = (1e8f64, 1.0f64);
    let gram_only = throughput_block(n, m, usize::MAX, tput, None, target);
    let combined = throughput_block(n, m, usize::MAX, tput, Some(1e6), target);
    assert!(combined >= 1);
    assert!(combined <= gram_only, "{combined} > gram-only {gram_only}");
    if combined < m {
        let per_cell = n as f64 / tput + 1.0 / 1e6;
        let latency = |w: usize| (w * w) as f64 * per_cell;
        assert!(latency(combined) <= target + 1e-9, "b = {combined} exceeds the target");
        assert!(latency(combined + 1) > target, "b = {combined} is not maximal");
    }
    // degenerate combine figures are ignored rather than fatal
    for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
        assert_eq!(
            throughput_block(n, m, usize::MAX, tput, Some(bad), target),
            gram_only,
            "combine = {bad}"
        );
    }
    // block_policy threads the figure through under the same source tag
    let (b, src) =
        block_policy(0, Some(tput), Some(1e6), n, m, usize::MAX, target, (7, "budget"));
    assert_eq!(src, "probe-throughput");
    assert_eq!(b, combined);
}

#[test]
fn faster_substrates_get_larger_blocks() {
    let (n, m) = (10_000usize, 5_000usize);
    let mut last = 0usize;
    for tput in [1e6, 1e7, 1e8, 1e9] {
        let b = throughput_block(n, m, 0, tput, None, DEFAULT_TASK_LATENCY_SECS);
        assert!(b >= last, "throughput {tput}: block shrank {last} -> {b}");
        last = b;
    }
}

#[test]
fn memory_cap_still_binds_an_arbitrarily_fast_probe() {
    let (n, m) = (100_000usize, 1_000_000usize);
    let b = throughput_block(n, m, 0, f64::MAX, None, 1e9);
    assert_eq!(b, matrix_free_block(n, m, 0), "latency cap can only shrink the memory cap");
    assert!(task_bytes(n, b) <= 256 << 20 || b == 1);
}

#[test]
fn degenerate_throughput_falls_back_to_the_memory_rule() {
    let (n, m) = (10_000usize, 500usize);
    for bad in [0.0, -5.0, f64::NAN, f64::INFINITY] {
        assert_eq!(
            throughput_block(n, m, 0, bad, None, DEFAULT_TASK_LATENCY_SECS),
            matrix_free_block(n, m, 0),
            "throughput = {bad}"
        );
    }
    // a zero/negative/non-finite target is equally degenerate
    for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
        assert_eq!(
            throughput_block(n, m, 0, 1e8, None, bad),
            matrix_free_block(n, m, 0),
            "target = {bad}"
        );
    }
}

#[test]
fn latency_cap_is_clamped_to_valid_widths() {
    // a probe so slow the latency cap would be 0 still yields >= 1
    assert!(throughput_block(1_000_000, 100, usize::MAX, 1.0, None, 1e-6) >= 1);
    // and never exceeds the column count
    let b = throughput_block(10, 4, usize::MAX, f64::MAX / 2.0, None, 1e6);
    assert!(b <= 4, "b = {b}");
}
